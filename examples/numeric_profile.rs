//! §8.1 element-wise numeric profiling (Tables 12-15) on the real
//! request path: the Pallas-kernel AOT artifacts executed through PJRT
//! (falls back to the native softfloat datapath if artifacts are not
//! built).
//!
//! ```sh
//! make artifacts && cargo run --release --example numeric_profile
//! ```

use tcbench::numerics::{profile_op, InitKind, MmaExec, NativeExec, NumericCfg, ProfileOp};
use tcbench::runtime::{ArtifactExec, ArtifactStore};

fn main() {
    let mut store = ArtifactStore::open_default().ok();
    println!(
        "backend: {}",
        if store.is_some() { "pjrt (AOT artifacts)" } else { "native softfloat" }
    );

    for (label, cfg, paper_low_acc) in [
        ("Table 12 — BF16 (C/D FP32)", NumericCfg::new("bf16", "f32", 16, 8, 8), 1.89e-8),
        ("Table 13 — FP16 (C/D FP32)", NumericCfg::new("fp16", "f32", 16, 8, 8), 0.0),
        ("Table 14 — FP16 (C/D FP16)", NumericCfg::new("fp16", "f16", 16, 8, 8), f64::NAN),
        ("Table 15 — TF32 (C/D FP32)", NumericCfg::new("tf32", "f32", 16, 8, 8), 0.0),
    ] {
        println!("\n{label}");
        let mut native;
        let mut artifact;
        let exec: &mut dyn MmaExec = match store.as_mut() {
            Some(s) => {
                artifact = ArtifactExec::new(s, cfg).expect("artifact");
                &mut artifact
            }
            None => {
                native = NativeExec::new(cfg);
                &mut native
            }
        };
        for init in [InitKind::LowPrecision, InitKind::Fp32] {
            for op in ProfileOp::ALL {
                let r = profile_op(exec, op, init, 1000, 7);
                println!(
                    "  {:<22} {:<14} err {:>9.2e}   (vs cvtFP16: {:>9.2e})",
                    op.paper_name(),
                    format!("{init:?}"),
                    r.mean_abs_err,
                    r.mean_abs_err_vs_cvt_fp16,
                );
            }
        }
        if paper_low_acc.is_finite() && paper_low_acc > 0.0 {
            println!("  (paper: accumulation error {paper_low_acc:.2e} under low-precision init)");
        }
    }
}
