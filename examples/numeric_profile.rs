//! §8.1 element-wise numeric profiling (Tables 12-15) on the real
//! request path: every probe is a first-class `Workload::Numeric` plan
//! executed through the `Runner` backend seam — the PJRT artifact
//! runtime when `make artifacts` has been run, the native softfloat
//! datapath otherwise (`runner_for(Auto)` resolves exactly like the
//! `repro` CLI and tcserved do).
//!
//! ```sh
//! make artifacts && cargo run --release --example numeric_profile
//! ```

use tcbench::coordinator::BackendKind;
use tcbench::numerics::{InitKind, ProfileOp};
use tcbench::workload::{runner_for, AccDtype, NumericProbe, Plan, ProbeDtype, Workload};

fn main() {
    let runner = runner_for(BackendKind::Auto).expect("auto never fails");
    println!("backend: {}", runner.name());

    for (label, ab, cd, paper_low_acc) in [
        ("Table 12 — BF16 (C/D FP32)", ProbeDtype::Bf16, AccDtype::F32, 1.89e-8),
        ("Table 13 — FP16 (C/D FP32)", ProbeDtype::Fp16, AccDtype::F32, 0.0),
        ("Table 14 — FP16 (C/D FP16)", ProbeDtype::Fp16, AccDtype::F16, f64::NAN),
        ("Table 15 — TF32 (C/D FP32)", ProbeDtype::Tf32, AccDtype::F32, 0.0),
    ] {
        println!("\n{label}");
        for init in [InitKind::LowPrecision, InitKind::Fp32] {
            for op in ProfileOp::ALL {
                let w = Workload::Numeric(NumericProbe::profile(ab, cd, op, init));
                let plan = Plan::new(w)
                    .point(1, 1)
                    .compile()
                    .expect("paper probes are valid workloads");
                let res = plan.run(runner.as_ref(), 1).expect("probe execution");
                let r = res.profile().expect("profile point unit requested");
                println!(
                    "  {:<22} {:<14} err {:>9.2e}   (vs cvtFP16: {:>9.2e})",
                    op.paper_name(),
                    format!("{init:?}"),
                    r.mean_abs_err,
                    r.mean_abs_err_vs_cvt_fp16,
                );
            }
        }
        if paper_low_acc.is_finite() && paper_low_acc > 0.0 {
            println!("  (paper: accumulation error {paper_low_acc:.2e} under low-precision init)");
        }
    }
}
