//! Sparse vs dense Tensor Cores (§6): reproduce the 2x-throughput /
//! same-latency finding and the A100 small-k anomaly, across devices.
//!
//! ```sh
//! cargo run --release --example sparse_vs_dense
//! ```

use tcbench::device::{a100, rtx3070ti};
use tcbench::isa::shapes::*;
use tcbench::isa::{AbType, CdType, MmaInstr};
use tcbench::microbench::{completion_latency_mma, measure_mma};

fn main() {
    let a = a100();
    println!("== {} ==", a.product);
    let dense = MmaInstr::dense(AbType::Fp16, CdType::Fp32, M16N8K16);
    let sp_big = MmaInstr::sp(AbType::Fp16, CdType::Fp32, M16N8K32);
    let sp_small = MmaInstr::sp(AbType::Fp16, CdType::Fp32, M16N8K16);

    println!(
        "completion latency: dense m16n8k16 {:.1} cy, sparse m16n8k32 {:.1} cy (same pipeline — the \
         dense path goes through the sparsity selector too)",
        completion_latency_mma(&a, &dense),
        completion_latency_mma(&a, &sp_big),
    );
    let d = measure_mma(&a, &dense, 8, 2);
    let s = measure_mma(&a, &sp_big, 8, 2);
    println!(
        "(8,2): dense {:.0} FMA/clk vs sparse {:.0} -> {:.2}x (2:4 sparsity skips the zero products)",
        d.throughput,
        s.throughput,
        s.throughput / d.throughput
    );
    let small = measure_mma(&a, &sp_small, 8, 2);
    println!(
        "small-k anomaly: mma.sp.m16n8k16 reaches only {:.0} of the 2048 sparse peak (paper: 1290)",
        small.throughput
    );

    let g = rtx3070ti();
    println!("\n== {} ==", g.product);
    let g_small = measure_mma(&g, &MmaInstr::sp(AbType::Fp16, CdType::Fp32, M16N8K16), 8, 1);
    let g_big = measure_mma(&g, &MmaInstr::sp(AbType::Fp16, CdType::Fp32, M16N8K32), 8, 1);
    println!(
        "no anomaly here: small-k {:.0} vs large-k {:.0} FMA/clk (paper: 506 vs 511)",
        g_small.throughput, g_big.throughput
    );
}
