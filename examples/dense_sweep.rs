//! Regenerate the Fig. 6 / Fig. 7 sweeps on any calibrated device and
//! print the latency/throughput grids plus the table-style convergence
//! points.
//!
//! ```sh
//! cargo run --release --example dense_sweep [device] [shape]
//! cargo run --release --example dense_sweep rtx3070ti m16n8k8
//! ```

use tcbench::device;
use tcbench::isa::{AbType, CdType, MmaInstr, MmaShape};
use tcbench::microbench::{convergence_point, sweep_mma};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let dev_name = args.get(1).map(String::as_str).unwrap_or("a100");
    let shape: MmaShape = args
        .get(2)
        .map(String::as_str)
        .unwrap_or("m16n8k16")
        .parse()
        .expect("shape like m16n8k16");

    let dev = device::by_name(dev_name).expect("device: a100|rtx3070ti|rtx2080ti");
    let ab = if dev.peaks.bf16 > 0 { AbType::Bf16 } else { AbType::Fp16 };
    let instr = MmaInstr::dense(ab, CdType::Fp32, shape);
    assert!(dev.supports(&instr), "{instr} unsupported on {}", dev.name);

    let sweep = sweep_mma(&dev, &instr);
    println!("== {} on {} ==", instr, dev.product);
    print!("{:>6}", "w\\ilp");
    for ilp in &sweep.ilp_axis {
        print!("{ilp:>16}");
    }
    println!();
    for &w in &sweep.warps_axis {
        print!("{w:>6}");
        for &ilp in &sweep.ilp_axis {
            let c = sweep.cell(w, ilp).unwrap();
            print!("{:>8.1}/{:<7.0}", c.latency, c.throughput);
        }
        println!();
    }
    println!("(cells are latency-cycles / FMA-per-clk-per-SM)");
    for warps in [4, 8] {
        let c = convergence_point(&sweep, warps);
        println!(
            "convergence at {warps} warps: ILP {} -> {:.1} cy, {:.1} FMA/clk/SM",
            c.ilp, c.latency, c.throughput
        );
    }
}
