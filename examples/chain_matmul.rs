//! Fig. 17: chain matrix multiplication — error growth per data type and
//! the FP16 overflow cliff, on the PJRT artifacts when available.
//!
//! ```sh
//! cargo run --release --example chain_matmul [N] [trials]
//! ```

use tcbench::numerics::{chain_errors, MmaExec, NativeExec, NumericCfg};
use tcbench::report::render_sparkline;
use tcbench::runtime::{ArtifactExec, ArtifactStore};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(14);
    let trials: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(250);
    let mut store = ArtifactStore::open_default().ok();
    println!(
        "chain D = A@B, N = {n}, {trials} trials, backend: {}",
        if store.is_some() { "pjrt" } else { "native" }
    );

    for (label, cfg, init_low) in [
        ("TF32 (init TF32)", NumericCfg::new("tf32", "f32", 16, 8, 8), true),
        ("FP16 (init FP16)", NumericCfg::new("fp16", "f16", 16, 8, 8), true),
        ("BF16 (init BF16)", NumericCfg::new("bf16", "f32", 16, 8, 8), true),
        ("BF16 (init FP32)", NumericCfg::new("bf16", "f32", 16, 8, 8), false),
    ] {
        let mut native;
        let mut artifact;
        let exec: &mut dyn MmaExec = match store.as_mut() {
            Some(s) => {
                artifact = ArtifactExec::new(s, cfg).expect("artifact");
                &mut artifact
            }
            None => {
                native = NativeExec::new(cfg);
                &mut native
            }
        };
        let r = chain_errors(exec, n, trials, init_low, 11);
        let last_finite = r
            .rel_err
            .iter()
            .rev()
            .find(|e| e.is_finite())
            .copied()
            .unwrap_or(f64::NAN);
        print!(
            "{label:>18}  {}  err(1)={:.1e} err(end)={:.1e}",
            render_sparkline(&r.rel_err),
            r.rel_err[0],
            last_finite
        );
        match r.overflow_at {
            Some(at) => println!("  OVERFLOW at N={at} (paper: FP16 stops at N=10)"),
            None => println!(),
        }
    }
}
