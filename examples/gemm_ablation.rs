//! Appendix-A ablations: the three GEMM kernels (sync-baseline, cp.async
//! pipeline, permuted smem layout) on tcsim at the paper's 2048^3 BF16
//! problem.
//!
//! ```sh
//! cargo run --release --example gemm_ablation [size]
//! ```

use tcbench::device::a100;
use tcbench::gemm::{run_gemm, table16, table17, GemmConfig, Variant};

fn main() {
    let size: u32 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(2048);
    let d = a100();
    let cfg = GemmConfig { size, ..GemmConfig::default() };
    println!("GEMM {size}^3 BF16 on simulated {}\n", d.product);

    for v in [Variant::Baseline, Variant::Pipeline, Variant::Permuted] {
        let r = run_gemm(&d, cfg, v);
        println!(
            "{:<16} {:>10} cy/CTA  {:>12} total  {:>7.1} FMA/clk/SM",
            v.paper_name(),
            r.cta_cycles,
            r.total_cycles,
            r.fma_per_clk
        );
    }

    let (b16, p16) = table16(&d, cfg);
    let (b17, p17) = table17(&d, cfg);
    println!(
        "\nTable 16 (async copy):      {:.2}x speedup   (paper: 913363/451560 = 2.02x)",
        b16.total_cycles as f64 / p16.total_cycles as f64
    );
    println!(
        "Table 17 (permuted layout): {:.2}x speedup   (paper: 913363/303227 = 3.01x)",
        b17.total_cycles as f64 / p17.total_cycles as f64
    );
}
