//! Appendix-A ablations: the three GEMM kernels (sync-baseline, cp.async
//! pipeline, permuted smem layout) on tcsim at the paper's 2048^3 BF16
//! problem.
//!
//! ```sh
//! cargo run --release --example gemm_ablation [size]
//! ```

use tcbench::device::a100;
use tcbench::gemm::{run_gemm, table16, table17, GemmConfig, Variant};
use tcbench::workload::{Plan, SimRunner, Workload};

fn main() {
    let size: u32 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(2048);
    let d = a100();
    let cfg = GemmConfig { size, ..GemmConfig::default() };
    println!("GEMM {size}^3 BF16 on simulated {}\n", d.product);

    for v in [Variant::Baseline, Variant::Pipeline, Variant::Permuted] {
        let r = run_gemm(&d, cfg, v);
        println!(
            "{:<16} {:>10} cy/CTA  {:>12} total  {:>7.1} FMA/clk/SM",
            v.paper_name(),
            r.cta_cycles,
            r.total_cycles,
            r.fma_per_clk
        );
    }

    let (b16, p16) = table16(&d, cfg);
    let (b17, p17) = table17(&d, cfg);
    println!(
        "\nTable 16 (async copy):      {:.2}x speedup   (paper: 913363/451560 = 2.02x)",
        b16.total_cycles as f64 / p16.total_cycles as f64
    );
    println!(
        "Table 17 (permuted layout): {:.2}x speedup   (paper: 913363/303227 = 3.01x)",
        b17.total_cycles as f64 / p17.total_cycles as f64
    );

    // The same kernels through the unified workload path — what `repro
    // sweep --instr "gemm ..."` and `POST /v1/plan` execute. Exec points
    // are (CTA warps, cp.async stages), so a stage-depth ablation is
    // just a plan with three points.
    let spec = format!("gemm pipeline bf16 f32 {size} 128x128x32");
    let workload = Workload::parse_spec(&spec).expect("gemm workload spec");
    let plan = Plan::new(workload)
        .device("a100")
        .points([(8, 1), (8, 2), (8, 4)])
        .compile()
        .expect("size must be a multiple of the 128x128x32 tile");
    let res = plan.run(&SimRunner, 2).expect("sim runner is infallible");
    println!("\nworkload path ({spec}): cp.async stage ablation at 8 warps");
    for stages in [1u32, 2, 4] {
        let m = res.point(8, stages).expect("requested point");
        println!(
            "  stages={stages}: {:>9.1} cy/k-step   {:>7.1} FMA/clk/SM",
            m.latency, m.throughput
        );
    }
}
