//! Quickstart: measure one Tensor-Core instruction the way the paper
//! does (§4) — completion latency, then a (warps, ILP) point — and print
//! the numbers next to the paper's.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use tcbench::device::a100;
use tcbench::isa::shapes::M16N8K16;
use tcbench::isa::{AbType, CdType, MmaInstr};
use tcbench::microbench::{completion_latency_mma, measure_mma};

fn main() {
    // 1. pick a calibrated device and an instruction
    let device = a100();
    let instr = MmaInstr::dense(AbType::Bf16, CdType::Fp32, M16N8K16);
    println!("device: {}", device.product);
    println!("instr:  {}", instr.ptx());

    // 2. completion/issue latency: ILP=1, one warp per SM
    let completion = completion_latency_mma(&device, &instr);
    println!("completion latency: {completion:.1} cycles   (paper: 24.7)");

    // 3. a saturated configuration: 8 warps, ILP=2
    let m = measure_mma(&device, &instr, 8, 2);
    println!(
        "(8 warps, ILP 2):   {:.1} cycles, {:.1} FMA/clk/SM   (paper: 32.6, 1004.2; vendor peak 1024)",
        m.latency, m.throughput
    );

    // 4. the 6-warp anomaly (Fig. 6 finding 5)
    let m4 = measure_mma(&device, &instr, 4, 3);
    let m6 = measure_mma(&device, &instr, 6, 3);
    println!(
        "6-warp dip at ILP 3: 4 warps -> {:.0} FMA/clk, 6 warps -> {:.0} (drops: sub-cores 0/1 carry two warps)",
        m4.throughput, m6.throughput
    );
}
