//! End-to-end driver: exercises the **whole stack on a real workload** —
//! the complete paper campaign (every table and figure) with the §8
//! numeric experiments executed through the PJRT runtime on the
//! AOT-compiled Pallas/JAX artifacts, all orchestrated by the
//! coordinator's worker pool, and a final scorecard of paper-vs-measured
//! headline numbers.
//!
//! This is the EXPERIMENTS.md driver:
//!
//! ```sh
//! make artifacts && cargo run --release --example end_to_end [--out results]
//! ```

use std::time::Instant;

use tcbench::coordinator::{BackendKind, run_experiment, EXPERIMENTS};
use tcbench::device::a100;
use tcbench::isa::shapes::*;
use tcbench::isa::{AbType, CdType, MmaInstr};
use tcbench::microbench::measure_mma;
use tcbench::numerics::{profile_op, InitKind, NativeExec, NumericCfg, ProfileOp};
use tcbench::workload::runner_for;

fn main() -> anyhow::Result<()> {
    let out_dir = std::env::args()
        .skip_while(|a| a != "--out")
        .nth(1)
        .unwrap_or_else(|| "results".to_string());
    std::fs::create_dir_all(&out_dir)?;

    let runner = runner_for(BackendKind::Auto).map_err(anyhow::Error::msg)?;
    println!(
        "== tcbench end-to-end campaign ({} experiments, numeric backend: {}) ==\n",
        EXPERIMENTS.len(),
        runner.name()
    );

    let t0 = Instant::now();
    let mut failures = 0;
    for e in EXPERIMENTS {
        let t = Instant::now();
        match run_experiment(e.id, runner.as_ref()) {
            Ok(report) => {
                std::fs::write(format!("{out_dir}/{}.txt", e.id), &report)?;
                println!("[{:>6.2?}] {:<6} {}", t.elapsed(), e.id, e.description);
            }
            Err(err) => {
                failures += 1;
                eprintln!("[FAILED ] {:<6} {err:#}", e.id);
            }
        }
    }
    println!("\ncampaign finished in {:.2?}; reports in {out_dir}/", t0.elapsed());

    // ------------------------------------------------ scorecard
    println!("\n== scorecard (paper vs reproduced) ==");
    let d = a100();
    let m = measure_mma(&d, &MmaInstr::dense(AbType::Fp16, CdType::Fp32, M16N8K16), 8, 2);
    score("mma.m16n8k16 (8,2) thr FMA/clk", 1004.2, m.throughput);
    let s = measure_mma(&d, &MmaInstr::sp(AbType::Fp16, CdType::Fp32, M16N8K32), 8, 2);
    score("mma.sp.m16n8k32 (8,2) thr", 1979.1, s.throughput);
    let anom = measure_mma(&d, &MmaInstr::sp(AbType::Fp16, CdType::Fp32, M16N8K16), 8, 2);
    score("mma.sp small-k anomaly thr", 1290.5, anom.throughput);
    let acc = profile_op(
        &mut NativeExec::new(NumericCfg::new("bf16", "f32", 16, 8, 8)),
        ProfileOp::Accumulation,
        InitKind::LowPrecision,
        1000,
        7,
    );
    score("BF16 accumulation error", 1.89e-8, acc.mean_abs_err);

    if failures > 0 {
        anyhow::bail!("{failures} experiments failed");
    }
    Ok(())
}

fn score(what: &str, paper: f64, measured: f64) {
    let dev = (measured - paper) / paper * 100.0;
    println!("{what:<36} paper {paper:>10.4e}  ours {measured:>10.4e}  ({dev:+.1}%)");
}
