//! Minimal offline stand-in for the [`anyhow`](https://docs.rs/anyhow)
//! crate, vendored because the build environment has no crates.io
//! access. It implements the subset of the API this repository uses:
//!
//! * [`Error`] — a chain of context messages (outermost first),
//! * [`Result<T>`] with the usual `E = Error` default,
//! * [`anyhow!`] / [`bail!`] macros,
//! * the [`Context`] extension trait for `Result` and `Option`.
//!
//! Formatting matches anyhow's conventions closely enough for this
//! repo's tests and logs: `{}` prints the outermost message, `{:#}`
//! prints the whole chain joined by `": "`, and `{:?}` prints the
//! message followed by a `Caused by:` list.

use std::error::Error as StdError;
use std::fmt;

/// A dynamic error carrying a chain of context messages.
pub struct Error {
    /// Outermost message first (the most recently attached context).
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// Like anyhow, `Error` deliberately does NOT implement `std::error::Error`
// so that this blanket conversion (which powers `?`) is coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait attaching context to `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error (or `None`) with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn debug_lists_causes() {
        let e = Error::msg("inner").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("outer"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("inner"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            let r: std::result::Result<(), std::io::Error> = Err(io_err());
            r?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert_eq!(format!("{e}"), "gone");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading manifest: gone");

        let n: Option<u32> = None;
        let e = n.with_context(|| "missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("x must be nonzero, got {x}");
            }
            Err(anyhow!("always fails with {}", x))
        }
        assert_eq!(format!("{}", f(0).unwrap_err()), "x must be nonzero, got 0");
        assert_eq!(format!("{}", f(3).unwrap_err()), "always fails with 3");
        let msg = String::from("owned message");
        assert_eq!(format!("{}", anyhow!(msg)), "owned message");
    }
}
