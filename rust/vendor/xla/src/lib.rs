//! Offline stand-in for the `xla` crate (PJRT bindings).
//!
//! The real crate needs a vendored XLA toolchain and is unavailable in
//! the offline build environment. This shim exposes the exact API
//! surface `tcbench::runtime::artifact` uses, so `cargo build --features
//! pjrt` type-checks the real runtime wiring — the CI feature-matrix leg
//! builds it on every push, keeping the gated code from rotting unbuilt.
//!
//! At run time, [`PjRtClient::cpu`] (the only entry point into the rest
//! of the API) always fails with an actionable message, which sends
//! every caller down the same native-backend fallback path as the
//! feature-off stub: `ArtifactStore::open` errors, `runner_for(Auto)`
//! picks native, and the PJRT integration tests skip themselves.

use std::fmt;

/// The error every shim operation returns.
pub struct XlaError(String);

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

fn offline() -> XlaError {
    XlaError(
        "offline xla shim: no PJRT runtime is linked in this build — \
         vendor the real xla crate to execute artifacts"
            .to_string(),
    )
}

/// A PJRT client. [`PjRtClient::cpu`] always fails in the shim, so no
/// instance is ever constructed.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(offline())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(offline())
    }
}

/// A compiled executable (never constructed in the shim).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(offline())
    }
}

/// A device buffer returned by execution (never constructed).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(offline())
    }
}

/// An HLO module parsed from its text form.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(offline())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A host-side literal (tensor value).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Err(offline())
    }

    pub fn to_tuple1(&self) -> Result<Literal, XlaError> {
        Err(offline())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(offline())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_actionably() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err:?}").contains("offline xla shim"));
        assert!(Literal::vec1(&[1.0]).reshape(&[1]).is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
