//! Bench: regenerate the dense-mma tables (paper Tables 3/4/5) and the
//! Fig. 6/7 sweeps end-to-end, reporting both the wall time of the
//! regeneration and the headline reproduced numbers.

use tcbench::coordinator::run_experiment;
use tcbench::workload::SimRunner;
use tcbench::device::a100;
use tcbench::isa::shapes::{M16N8K16, M16N8K8};
use tcbench::isa::{AbType, CdType, MmaInstr};
use tcbench::microbench::{measure_mma, sweep_mma};
use tcbench::util::Bencher;

fn main() {
    let mut b = Bencher::new();
    let d = a100();
    let k16 = MmaInstr::dense(AbType::Bf16, CdType::Fp32, M16N8K16);
    let k8 = MmaInstr::dense(AbType::Bf16, CdType::Fp32, M16N8K8);

    b.bench("fig6/sweep_mma_m16n8k16_a100", || sweep_mma(&d, &k16));
    b.bench("fig7/sweep_mma_m16n8k8_a100", || sweep_mma(&d, &k8));
    b.bench("mma/single_config_8w_ilp2", || measure_mma(&d, &k16, 8, 2));

    for id in ["t3", "t4", "t5"] {
        b.bench(&format!("table{}/full_regeneration", &id[1..]), || {
            run_experiment(id, &SimRunner).unwrap()
        });
    }

    // headline numbers (paper vs reproduced)
    let m = measure_mma(&d, &k16, 8, 2);
    println!(
        "\nheadline: mma.m16n8k16 (8,2) on A100 -> {:.1} cy, {:.1} FMA/clk/SM (paper: 32.6, 1004.2)",
        m.latency, m.throughput
    );
}
