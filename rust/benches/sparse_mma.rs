//! Bench: sparse-mma tables (paper Tables 6/7) and Fig. 10/11 sweeps,
//! including the A100 small-k anomaly check.

use tcbench::coordinator::run_experiment;
use tcbench::workload::SimRunner;
use tcbench::device::{a100, rtx3070ti};
use tcbench::isa::shapes::{M16N8K16, M16N8K32};
use tcbench::isa::{AbType, CdType, MmaInstr};
use tcbench::microbench::{measure_mma, sweep_mma};
use tcbench::util::Bencher;

fn main() {
    let mut b = Bencher::new();
    let d = a100();
    let g = rtx3070ti();
    let sp32 = MmaInstr::sp(AbType::Bf16, CdType::Fp32, M16N8K32);
    let sp16 = MmaInstr::sp(AbType::Bf16, CdType::Fp32, M16N8K16);

    b.bench("fig10/sweep_mma_sp_m16n8k32_a100", || sweep_mma(&d, &sp32));
    b.bench("fig11/sweep_mma_sp_m16n8k16_a100", || sweep_mma(&d, &sp16));

    for id in ["t6", "t7"] {
        b.bench(&format!("table{}/full_regeneration", &id[1..]), || {
            run_experiment(id, &SimRunner).unwrap()
        });
    }

    let big = measure_mma(&d, &sp32, 8, 2);
    let small = measure_mma(&d, &sp16, 8, 2);
    let fp16_small = MmaInstr::sp(AbType::Fp16, CdType::Fp32, M16N8K16);
    let g_small = measure_mma(&g, &fp16_small, 8, 1);
    println!(
        "\nheadline: A100 sparse large-k {:.0} vs small-k {:.0} FMA/clk (paper 1979 vs 1290);\n\
         RTX3070Ti small-k {:.0} (paper 506 — no anomaly)",
        big.throughput, small.throughput, g_small.throughput
    );
}
