//! Bench: §7 data movement — ldmatrix table (Table 9), the Fig. 15
//! sweep and the ld.shared conflict probe (Table 10).

use tcbench::coordinator::run_experiment;
use tcbench::workload::SimRunner;
use tcbench::device::a100;
use tcbench::isa::{LdMatrixNum, LdSharedWidth};
use tcbench::microbench::{measure_ld_shared, measure_ldmatrix, sweep_ldmatrix};
use tcbench::util::Bencher;

fn main() {
    let mut b = Bencher::new();
    let d = a100();

    b.bench("fig15/sweep_ldmatrix_x4_a100", || sweep_ldmatrix(&d, LdMatrixNum::X4));
    b.bench("ldmatrix/x4_8w_ilp1", || measure_ldmatrix(&d, LdMatrixNum::X4, 8, 1));
    b.bench("ld_shared/u32_4way", || measure_ld_shared(&d, LdSharedWidth::U32, 4));

    for id in ["t9", "t10", "fig15"] {
        b.bench(&format!("{id}/full_regeneration"), || {
            run_experiment(id, &SimRunner).unwrap()
        });
    }

    let m = measure_ldmatrix(&d, LdMatrixNum::X4, 8, 1);
    println!(
        "\nheadline: ldmatrix.x4 (8,1) -> {:.1} cy, {:.1} B/clk/SM (paper: 32.6, 125.9; fabric bound 128)",
        m.latency, m.throughput
    );
}
