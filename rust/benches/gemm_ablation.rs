//! Bench: Appendix-A ablations (Tables 16/17) — the three GEMM kernels
//! on tcsim at the paper's 2048^3 problem and a fast 512^3 variant.

use tcbench::device::a100;
use tcbench::gemm::{run_gemm, table16, table17, GemmConfig, Variant};
use tcbench::util::Bencher;

fn main() {
    let mut b = Bencher::new();
    let d = a100();
    let small = GemmConfig { size: 512, ..GemmConfig::default() };
    let full = GemmConfig::default();

    b.bench("gemm512/baseline", || run_gemm(&d, small, Variant::Baseline));
    b.bench("gemm512/pipeline", || run_gemm(&d, small, Variant::Pipeline));
    b.bench("gemm512/permuted", || run_gemm(&d, small, Variant::Permuted));
    b.bench("table16/2048_pair", || table16(&d, full));
    b.bench("table17/2048_pair", || table17(&d, full));

    let (b16, p16) = table16(&d, full);
    let (b17, p17) = table17(&d, full);
    println!(
        "\nheadline: async speedup {:.2}x (paper 2.02x); permuted speedup {:.2}x (paper 3.01x)",
        b16.total_cycles as f64 / p16.total_cycles as f64,
        b17.total_cycles as f64 / p17.total_cycles as f64,
    );
}
