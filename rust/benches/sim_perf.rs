//! Bench: tcsim engine performance itself (§Perf target: a full Fig-6
//! sweep well under a second). Tracks the simulator hot loop across
//! optimization iterations.

use tcbench::device::a100;
use tcbench::isa::shapes::M16N8K16;
use tcbench::isa::{AbType, CdType, MmaInstr};
use tcbench::microbench::{measure_mma, mma_program, sweep_mma, ITERS};
use tcbench::sim::SmSim;
use tcbench::util::Bencher;

fn main() {
    let mut b = Bencher::new();
    let d = a100();
    let i = MmaInstr::dense(AbType::Bf16, CdType::Fp32, M16N8K16);

    // single 32-warp simulation — the most expensive sweep cell
    b.bench("sim/32w_ilp6_single_run", || {
        let p = mma_program(&d, &i, 6, ITERS);
        SmSim::new(&d, vec![p; 32]).run()
    });
    // one cell with measurement plumbing
    b.bench("sim/measure_8w_ilp2", || measure_mma(&d, &i, 8, 2));
    // the full 48-cell grid (the §Perf headline target)
    let stats = b.bench("sim/full_fig6_sweep", || sweep_mma(&d, &i));
    println!(
        "\nheadline: full Fig-6 sweep in {:.1} ms (target < 1000 ms)",
        stats.median.as_secs_f64() * 1e3
    );
}
