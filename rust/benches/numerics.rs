//! Bench: §8 numeric experiments — Tables 12–15 profiling and the
//! Fig. 17 chain study, on the native backend and (when artifacts are
//! built) through the PJRT runtime, so the hot numeric path of both
//! backends is tracked.

use tcbench::coordinator::run_experiment;
use tcbench::workload::SimRunner;
use tcbench::numerics::{
    chain_errors, profile_op, InitKind, NativeExec, NumericCfg, ProfileOp,
};
use tcbench::runtime::{ArtifactExec, ArtifactStore};
use tcbench::util::Bencher;

fn main() {
    let mut b = Bencher::new();
    let cfg = NumericCfg::new("bf16", "f32", 16, 8, 8);

    b.bench("native/profile_accumulation_1000", || {
        profile_op(
            &mut NativeExec::new(cfg),
            ProfileOp::Accumulation,
            InitKind::LowPrecision,
            1000,
            7,
        )
    });
    b.bench("native/chain_n14_x250", || {
        chain_errors(&mut NativeExec::new(cfg), 14, 250, true, 11)
    });

    match ArtifactStore::open_default() {
        Ok(mut store) => {
            // compile once outside the timed region
            let _ = ArtifactExec::new(&mut store, cfg).expect("artifact");
            b.bench("pjrt/profile_accumulation_1000", || {
                let mut exec = ArtifactExec::new(&mut store, cfg).unwrap();
                profile_op(&mut exec, ProfileOp::Accumulation, InitKind::LowPrecision, 1000, 7)
            });
            b.bench("pjrt/chain_n14_x250", || {
                let mut exec = ArtifactExec::new(&mut store, cfg).unwrap();
                chain_errors(&mut exec, 14, 250, true, 11)
            });
        }
        Err(e) => eprintln!("skipping PJRT benches: {e:#}"),
    }

    for id in ["t12", "t13", "t14", "t15", "fig17"] {
        b.bench(&format!("{id}/full_regeneration"), || {
            run_experiment(id, &SimRunner).unwrap()
        });
    }

    let r = profile_op(
        &mut NativeExec::new(cfg),
        ProfileOp::Accumulation,
        InitKind::LowPrecision,
        1000,
        7,
    );
    println!(
        "\nheadline: BF16 accumulation error (init_BF16) = {:.2e} (paper: 1.89e-8)",
        r.mean_abs_err
    );
}
