//! Bench: simulator design-choice ablations — each calibrated knob of
//! tcsim is disabled in turn and the deviation from the paper's numbers
//! is reported (DESIGN.md §4's evidence table).

use tcbench::device::a100;
use tcbench::microbench::ablation;
use tcbench::util::Bencher;

fn main() {
    let d = a100();
    let mut b = Bencher::new();
    b.bench("ablation/all_knobs", || ablation::run_all(&d));
    let (_, table) = ablation::run_all(&d);
    println!("\n{table}");
}
