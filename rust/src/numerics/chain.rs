//! Chain matrix multiplication (§8.2, Fig. 17).
//!
//! `D = A x B` repeated N times: D feeds the next step's A, B is fresh
//! N(0,1) each step. The l2 relative error (Eq. 1) of the low-precision
//! chain against the FP32 CPU chain is averaged over trials. FP16 runs
//! into ±inf around N >= 10 (fewer exponent bits); BF16 accumulates the
//! largest error (fewer mantissa bits); TF32 and FP16 track each other
//! while FP16 stays in range.

use crate::util::Prng;

use super::rounding::quantize;
use super::tcmma::MmaExec;

/// Per-step output of a chain run.
#[derive(Debug, Clone)]
pub struct ChainResult {
    /// Mean l2 relative error after each step (Eq. 1), NaN once the
    /// low-precision chain has overflowed to inf.
    pub rel_err: Vec<f64>,
    /// First step (1-based) at which any trial produced a non-finite
    /// value, if any — Fig. 17's FP16 cut-off.
    pub overflow_at: Option<usize>,
}

/// Eq. 1: ||D_l - D_fp32||_2 / ||D_l||_2 (note: the paper normalizes by
/// the low-precision result).
fn l2_relative_error(d_low: &[f32], d_ref: &[f32]) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&l, &r) in d_low.iter().zip(d_ref) {
        num += ((l as f64) - (r as f64)).powi(2);
        den += (l as f64).powi(2);
    }
    (num.sqrt()) / den.sqrt().max(f64::MIN_POSITIVE)
}

/// Run the chain study on any executor backend.
///
/// `init_low`: pre-round the initial A and each fresh B to the operand
/// type (the "init with low precision" strategy); otherwise FP32 init.
pub fn chain_errors(
    exec: &mut dyn MmaExec,
    n_steps: usize,
    trials: usize,
    init_low: bool,
    seed: u64,
) -> ChainResult {
    let cfg = exec.cfg();
    let (m, n, k) = (cfg.m, cfg.n, cfg.k);
    assert_eq!(n, k, "chain feeds D (m x n) back as A (m x k): need n == k");
    let mut rng = Prng::new(seed);

    let mut a_tc = vec![0.0f32; trials * m * k];
    rng.fill_normal(&mut a_tc);
    if init_low {
        for v in a_tc.iter_mut() {
            *v = quantize(*v, cfg.ab);
        }
    }
    // CPU FP32 chain starts from the *same* initial values.
    let mut a_cpu = a_tc.clone();

    let zero_c = vec![0.0f32; trials * m * n];
    let mut rel_err = Vec::with_capacity(n_steps);
    let mut overflow_at = None;

    for step in 1..=n_steps {
        let mut b = vec![0.0f32; trials * k * n];
        rng.fill_normal(&mut b);
        if init_low {
            for v in b.iter_mut() {
                *v = quantize(*v, cfg.ab);
            }
        }
        let d_tc = exec.run(trials, &a_tc, &b, &zero_c);
        let d_cpu = super::tcmma::cpu_f32_baseline(trials, m, n, k, &a_cpu, &b, &zero_c);

        if overflow_at.is_none() && d_tc.iter().any(|v| !v.is_finite()) {
            overflow_at = Some(step);
        }
        // average Eq.1 over trials
        let mut err = 0.0f64;
        for t in 0..trials {
            err += l2_relative_error(
                &d_tc[t * m * n..(t + 1) * m * n],
                &d_cpu[t * m * n..(t + 1) * m * n],
            );
        }
        rel_err.push(err / trials as f64);

        a_tc = d_tc;
        a_cpu = d_cpu;
    }
    ChainResult { rel_err, overflow_at }
}

#[cfg(test)]
mod tests {
    use super::super::tcmma::{NativeExec, NumericCfg};
    use super::*;

    fn exec(ab: &'static str, cd: &'static str) -> NativeExec {
        NativeExec::new(NumericCfg::new(ab, cd, 16, 8, 8))
    }

    #[test]
    fn errors_grow_with_chain_length() {
        let r = chain_errors(&mut exec("tf32", "f32"), 6, 48, true, 3);
        assert!(r.rel_err[5] > r.rel_err[0]);
        assert!(r.rel_err[0] < 1e-5, "first step ~zero: {:e}", r.rel_err[0]);
        assert!(r.overflow_at.is_none());
    }

    #[test]
    fn bf16_worst_precision() {
        let bf = chain_errors(&mut exec("bf16", "f32"), 5, 48, true, 3);
        let tf = chain_errors(&mut exec("tf32", "f32"), 5, 48, true, 3);
        assert!(bf.rel_err[4] > 3.0 * tf.rel_err[4], "{} vs {}", bf.rel_err[4], tf.rel_err[4]);
    }

    #[test]
    fn fp16_overflows_near_n10() {
        let r = chain_errors(&mut exec("fp16", "f16"), 14, 48, true, 4);
        let at = r.overflow_at.expect("FP16 chain must overflow");
        assert!((8..=12).contains(&at), "overflow at {at}");
    }

    #[test]
    fn tf32_and_fp16_same_error_level_in_range() {
        let fp = chain_errors(&mut exec("fp16", "f32"), 4, 48, true, 5);
        let tf = chain_errors(&mut exec("tf32", "f32"), 4, 48, true, 5);
        let ratio = fp.rel_err[3] / tf.rel_err[3];
        assert!((0.4..2.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn fp32_init_always_worse() {
        let low = chain_errors(&mut exec("tf32", "f32"), 3, 48, true, 6);
        let f32i = chain_errors(&mut exec("tf32", "f32"), 3, 48, false, 6);
        for (l, h) in low.rel_err.iter().zip(&f32i.rel_err) {
            assert!(h > l, "init_fp32 {h:e} must exceed init_low {l:e}");
        }
    }
}
