//! Softfloat building blocks: FP32 -> {BF16, FP16, TF32} quantization
//! (round-to-nearest-even) and f64 -> f32 rounding with RNE / RZ.
//!
//! Bit-for-bit identical to `python/compile/kernels/quantize.py` — the
//! integration tests compare this module against the PJRT artifacts.

/// Rounding mode of the FP32 accumulation step (DESIGN.md §4: RZ on the
/// BF16 path, RNE elsewhere — the calibration that reproduces Table 12).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rounding {
    Rne,
    Rz,
}

/// FP32 -> BF16 -> FP32, RNE ties-to-even. BF16 = upper 16 bits of FP32.
pub fn quantize_bf16(x: f32) -> f32 {
    let bits = x.to_bits();
    if (bits >> 23) & 0xFF == 0xFF {
        return x; // inf / NaN pass through
    }
    let lsb = (bits >> 16) & 1;
    let r = bits.wrapping_add(0x7FFF + lsb) & 0xFFFF_0000;
    f32::from_bits(r)
}

/// FP32 -> TF32 -> FP32: same 8-bit exponent, mantissa cut to 10 bits
/// (RNE ties-to-even). TF32 still occupies a 32-bit register (Table 11).
pub fn quantize_tf32(x: f32) -> f32 {
    let bits = x.to_bits();
    if (bits >> 23) & 0xFF == 0xFF {
        return x;
    }
    let lsb = (bits >> 13) & 1;
    let r = bits.wrapping_add(0x0FFF + lsb) & !0x1FFF;
    f32::from_bits(r)
}

/// FP32 -> IEEE binary16 -> FP32, RNE, with overflow to ±inf and
/// gradual underflow (subnormals) — the Fig. 17 overflow behaviour
/// depends on the 65504 ceiling.
pub fn quantize_fp16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Canonical f32 -> binary16 conversion (RNE).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // inf / NaN
        return sign | 0x7C00 | if man != 0 { 0x0200 } else { 0 };
    }
    // unbiased exponent
    let e = exp - 127;
    if e > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if e >= -14 {
        // normal f16: round 23-bit mantissa to 10 bits, RNE
        let mut m = man >> 13;
        let rem = man & 0x1FFF;
        if rem > 0x1000 || (rem == 0x1000 && (m & 1) == 1) {
            m += 1;
        }
        let mut he = (e + 15) as u32;
        if m == 0x400 {
            m = 0;
            he += 1;
            if he >= 31 {
                return sign | 0x7C00;
            }
        }
        return sign | ((he as u16) << 10) | (m as u16);
    }
    if e >= -25 {
        // subnormal f16
        let full = man | 0x0080_0000; // implicit one
        let shift = (-14 - e) as u32 + 13;
        let m = full >> shift;
        let rem = full & ((1 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut m = m;
        if rem > half || (rem == half && (m & 1) == 1) {
            m += 1;
        }
        return sign | (m as u16); // may carry into the exponent: still valid
    }
    sign // underflow to zero
}

/// Canonical binary16 -> f32 conversion (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    let bits = match (exp, man) {
        (0, 0) => sign,
        (0, m) => {
            // subnormal: normalize
            let lead = m.leading_zeros() - 22; // zeros within the 10-bit field
            let shift = lead + 1;
            let man32 = (m << shift) & 0x03FF;
            let exp32 = 127 - 15 - shift + 1;
            sign | (exp32 << 23) | (man32 << 13)
        }
        (0x1F, 0) => sign | 0x7F80_0000,
        (0x1F, m) => sign | 0x7F80_0000 | (m << 13),
        (e, m) => sign | ((e + 127 - 15) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

/// FP32 -> FP8-E4M3 -> FP32 (RNE, saturating at ±448).
///
/// Forward-looking extension: Table 11 lists the two FP8 formats the
/// (then-unreleased) Hopper Tensor Cores add. E4M3 follows the
/// OCP/NVIDIA convention: no infinities, saturate to ±448.
pub fn quantize_fp8_e4m3(x: f32) -> f32 {
    if x.is_nan() {
        return x;
    }
    const MAX: f32 = 448.0;
    let clamped = x.clamp(-MAX, MAX);
    if x.abs() > MAX {
        return clamped; // saturating, no inf
    }
    round_to_format(clamped, 4, 3, 7)
}

/// FP32 -> FP8-E5M2 -> FP32 (RNE, overflow to ±inf like IEEE).
pub fn quantize_fp8_e5m2(x: f32) -> f32 {
    if x.is_nan() {
        return x;
    }
    const MAX: f32 = 57344.0;
    if x.abs() > MAX * (1.0 + 0.25) {
        return f32::INFINITY.copysign(x);
    }
    let r = round_to_format(x, 5, 2, 15);
    if r.abs() > MAX {
        f32::INFINITY.copysign(x)
    } else {
        r
    }
}

/// Round an f32 to a (exp_bits, man_bits, bias) mini-float with RNE and
/// gradual underflow; the result is returned as f32 (every mini-float
/// value is exactly representable in f32).
fn round_to_format(x: f32, exp_bits: u32, man_bits: u32, bias: i32) -> f32 {
    if x == 0.0 || !x.is_finite() {
        return x;
    }
    let _ = exp_bits;
    let e = x.abs().log2().floor() as i32;
    // normal range: e >= 1 - bias; subnormal ulp is fixed below that
    let ulp_exp = if e >= 1 - bias {
        e - man_bits as i32
    } else {
        1 - bias - man_bits as i32
    };
    let scale = (ulp_exp as f64).exp2();
    let q = (x as f64 / scale).round_ties_even() * scale;
    q as f32
}

/// Quantize by operand-type name (matching the Python config strings).
pub fn quantize(x: f32, ab: &str) -> f32 {
    match ab {
        "bf16" => quantize_bf16(x),
        "fp16" => quantize_fp16(x),
        "tf32" => quantize_tf32(x),
        "fp8e4m3" => quantize_fp8_e4m3(x),
        "fp8e5m2" => quantize_fp8_e5m2(x),
        "fp32" => x,
        other => panic!("unknown operand dtype {other:?}"),
    }
}

/// f64 -> f32, round-to-nearest-even (the hardware default).
pub fn f64_to_f32_rne(x: f64) -> f32 {
    x as f32
}

/// f64 -> f32, round-toward-zero. Mirrors the Pallas kernel: take the
/// RNE cast and step one ulp toward zero if it rounded away.
pub fn f64_to_f32_rz(x: f64) -> f32 {
    let y = x as f32;
    if y.is_infinite() && x.is_finite() {
        return f32::MAX.copysign(x as f32);
    }
    if !y.is_finite() {
        return y;
    }
    if (y as f64).abs() > x.abs() {
        next_toward_zero(y)
    } else {
        y
    }
}

fn next_toward_zero(y: f32) -> f32 {
    if y == 0.0 {
        return y;
    }
    f32::from_bits(y.to_bits() - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf16_resolution_and_ties() {
        assert_eq!(quantize_bf16(1.0 + 2f32.powi(-8)), 1.0);
        let y = 1.0 + 2f32.powi(-7);
        assert_eq!(quantize_bf16(y), y);
        // tie: 1 + 3*2^-8 is halfway -> even (1 + 2^-6)
        assert_eq!(quantize_bf16(1.0 + 3.0 * 2f32.powi(-8)), 1.0 + 2f32.powi(-6));
    }

    #[test]
    fn tf32_resolution() {
        let y = 1.0 + 2f32.powi(-10);
        assert_eq!(quantize_tf32(y), y);
        assert_eq!(quantize_tf32(1.0 + 2f32.powi(-11)), 1.0);
        assert!(quantize_tf32(f32::INFINITY).is_infinite());
        assert!(quantize_tf32(f32::NAN).is_nan());
        assert_eq!(quantize_tf32(3e38), quantize_tf32(quantize_tf32(3e38)));
    }

    #[test]
    fn fp16_basics() {
        assert_eq!(quantize_fp16(1.0), 1.0);
        assert_eq!(quantize_fp16(65504.0), 65504.0);
        assert!(quantize_fp16(70000.0).is_infinite());
        assert!(quantize_fp16(-70000.0).is_infinite());
        assert_eq!(quantize_fp16(1.0 + 2f32.powi(-11)), 1.0);
        let y = 1.0 + 2f32.powi(-10);
        assert_eq!(quantize_fp16(y), y);
        // subnormals survive
        let sub = 2f32.powi(-20);
        assert_eq!(quantize_fp16(sub), sub);
        // below half the smallest subnormal -> 0
        assert_eq!(quantize_fp16(2f32.powi(-26)), 0.0);
        assert!(quantize_fp16(f32::NAN).is_nan());
    }

    #[test]
    fn fp16_idempotent_on_random() {
        let mut p = crate::util::Prng::new(5);
        for _ in 0..10_000 {
            let x = p.normal_f32() * 100.0;
            let q1 = quantize_fp16(x);
            assert_eq!(q1, quantize_fp16(q1), "x={x}");
        }
    }

    #[test]
    fn rz_properties() {
        // rounds magnitude down where RNE rounds up
        let x = 1.0f64 + 1.5 * 2f64.powi(-24);
        assert_eq!(f64_to_f32_rne(x), 1.0 + 2f32.powi(-23));
        assert_eq!(f64_to_f32_rz(x), 1.0);
        assert_eq!(f64_to_f32_rz(-x), -1.0);
        // exact values unchanged
        assert_eq!(f64_to_f32_rz(0.5), 0.5);
        assert_eq!(f64_to_f32_rz(0.0), 0.0);
        // never exceeds |x|
        let mut p = crate::util::Prng::new(9);
        for _ in 0..50_000 {
            let v = p.normal() * 1e3;
            assert!((f64_to_f32_rz(v) as f64).abs() <= v.abs(), "v={v}");
        }
        // overflow clamps to MAX, not inf
        assert_eq!(f64_to_f32_rz(3.5e38), f32::MAX);
        assert_eq!(f64_to_f32_rz(-3.5e38), -f32::MAX);
    }

    #[test]
    fn quantize_by_name() {
        assert_eq!(quantize(1.5, "fp32"), 1.5);
        assert_eq!(quantize(1.0 + 2f32.powi(-8), "bf16"), 1.0);
    }

    #[test]
    fn fp8_e4m3_resolution_and_saturation() {
        // 3 mantissa bits: 1 + 2^-3 representable, 1 + 2^-4 rounds to 1
        let y = 1.0 + 2f32.powi(-3);
        assert_eq!(quantize_fp8_e4m3(y), y);
        assert_eq!(quantize_fp8_e4m3(1.0 + 2f32.powi(-4)), 1.0);
        // saturating at 448, never inf
        assert_eq!(quantize_fp8_e4m3(448.0), 448.0);
        assert_eq!(quantize_fp8_e4m3(1e6), 448.0);
        assert_eq!(quantize_fp8_e4m3(-1e6), -448.0);
        assert!(quantize_fp8_e4m3(f32::NAN).is_nan());
    }

    #[test]
    fn fp8_e5m2_resolution_and_overflow() {
        let y = 1.0 + 2f32.powi(-2);
        assert_eq!(quantize_fp8_e5m2(y), y);
        assert_eq!(quantize_fp8_e5m2(1.0 + 2f32.powi(-3)), 1.0);
        // IEEE-style overflow to inf
        assert_eq!(quantize_fp8_e5m2(57344.0), 57344.0);
        assert!(quantize_fp8_e5m2(1e6).is_infinite());
    }

    #[test]
    fn fp8_idempotent() {
        let mut p = crate::util::Prng::new(31);
        for _ in 0..5_000 {
            let x = p.normal_f32() * 10.0;
            for f in [quantize_fp8_e4m3 as fn(f32) -> f32, quantize_fp8_e5m2] {
                let q = f(x);
                assert_eq!(q, f(q), "x={x}");
            }
        }
    }

    #[test]
    fn fp8_error_hierarchy() {
        // fewer mantissa bits -> larger quantization error:
        // e5m2 (2) > e4m3 (3) > bf16 (7) > fp16/tf32 (10)
        let mut p = crate::util::Prng::new(8);
        let mut errs = [0.0f64; 4];
        let n = 20_000;
        for _ in 0..n {
            let x = p.normal_f32();
            errs[0] += (quantize_fp8_e5m2(x) - x).abs() as f64;
            errs[1] += (quantize_fp8_e4m3(x) - x).abs() as f64;
            errs[2] += (quantize_bf16(x) - x).abs() as f64;
            errs[3] += (quantize_fp16(x) - x).abs() as f64;
        }
        assert!(errs[0] > errs[1] && errs[1] > errs[2] && errs[2] > errs[3], "{errs:?}");
    }

    #[test]
    fn fp8_nan_and_inf_propagation() {
        // NaN propagates through both formats (E4M3 reserves a NaN
        // encoding even without infinities)
        assert!(quantize_fp8_e4m3(f32::NAN).is_nan());
        assert!(quantize_fp8_e5m2(f32::NAN).is_nan());
        assert!(quantize_fp8_e4m3(-f32::NAN).is_nan());
        // infinite inputs: E5M2 keeps them (IEEE-style), E4M3 has no
        // infinity — it saturates to the format maximum
        assert_eq!(quantize_fp8_e5m2(f32::INFINITY), f32::INFINITY);
        assert_eq!(quantize_fp8_e5m2(f32::NEG_INFINITY), f32::NEG_INFINITY);
        assert_eq!(quantize_fp8_e4m3(f32::INFINITY), 448.0);
        assert_eq!(quantize_fp8_e4m3(f32::NEG_INFINITY), -448.0);
    }

    #[test]
    fn fp8_overflow_saturation_vs_infinity() {
        // E4M3 (OCP/NVIDIA convention): saturating at ±448 — values just
        // past the max clamp instead of rounding away
        assert_eq!(quantize_fp8_e4m3(448.0), 448.0);
        assert_eq!(quantize_fp8_e4m3(449.0), 448.0);
        assert_eq!(quantize_fp8_e4m3(-1e30), -448.0);
        assert!(quantize_fp8_e4m3(1e30).is_finite());
        // E5M2: max normal 57344 = 1.75 * 2^15, ulp 2^13 at that binade.
        assert_eq!(quantize_fp8_e5m2(57344.0), 57344.0);
        // below the rounding midpoint -> stays at max
        assert_eq!(quantize_fp8_e5m2(57344.0 + 4095.0), 57344.0);
        // the exact midpoint ties to even (2.0 * 2^15 > max) -> inf
        assert!(quantize_fp8_e5m2(61440.0).is_infinite());
        assert!(quantize_fp8_e5m2(-61440.0).is_infinite());
        assert!(quantize_fp8_e5m2(1e30).is_infinite());
        // sign is preserved through overflow
        assert_eq!(quantize_fp8_e5m2(-1e30), f32::NEG_INFINITY);
    }

    #[test]
    fn fp8_subnormal_rounding() {
        // E4M3: min normal 2^-6, subnormal ulp 2^(1-7-3) = 2^-9
        let ulp4 = 2f32.powi(-9);
        assert_eq!(quantize_fp8_e4m3(ulp4), ulp4); // min subnormal survives
        assert_eq!(quantize_fp8_e4m3(0.6 * ulp4), ulp4); // rounds up
        assert_eq!(quantize_fp8_e4m3(0.4 * ulp4), 0.0); // rounds to zero
        assert_eq!(quantize_fp8_e4m3(0.5 * ulp4), 0.0); // tie -> even (0)
        assert_eq!(quantize_fp8_e4m3(1.5 * ulp4), 2.0 * ulp4); // tie -> even (2 ulp)
        assert_eq!(quantize_fp8_e4m3(-0.6 * ulp4), -ulp4); // sign preserved
        // E5M2: min normal 2^-14, subnormal ulp 2^(1-15-2) = 2^-16
        let ulp5 = 2f32.powi(-16);
        assert_eq!(quantize_fp8_e5m2(ulp5), ulp5);
        assert_eq!(quantize_fp8_e5m2(0.5 * ulp5), 0.0); // tie -> even
        assert_eq!(quantize_fp8_e5m2(2.5 * ulp5), 2.0 * ulp5); // tie -> even
        assert_eq!(quantize_fp8_e5m2(3.5 * ulp5), 4.0 * ulp5); // tie -> even
        // subnormals are idempotent fixed points
        for v in [ulp4, 3.0 * ulp4, ulp5, 3.0 * ulp5] {
            assert_eq!(quantize_fp8_e4m3(quantize_fp8_e4m3(v)), quantize_fp8_e4m3(v));
            assert_eq!(quantize_fp8_e5m2(quantize_fp8_e5m2(v)), quantize_fp8_e5m2(v));
        }
        // zero passes through with its sign
        assert_eq!(quantize_fp8_e4m3(0.0), 0.0);
        assert_eq!(quantize_fp8_e5m2(-0.0), -0.0);
    }

    #[test]
    #[should_panic(expected = "unknown operand dtype")]
    fn quantize_unknown_panics() {
        quantize(1.0, "fp8");
    }
}
