//! Element-wise numeric profiling (§8.1, Fig. 16, Tables 12–15).
//!
//! Three operations are isolated by sparse input patterns:
//! * multiplication:       `a00 x b00` (all else zero),
//! * inner-product add:    first row of A x first column of B,
//! * accumulation:         `a00 x b00 + c00`.
//!
//! Inputs are N(0,1) with a fixed seed; "init_<type>" pre-rounds the
//! operands to the low-precision type (eliminating conversion loss) while
//! "init_FP32" leaves them full-precision. Errors are mean |TC - CPU|
//! over the trial batch, with the CPU FP32 baseline of
//! [`super::cpu_f32_baseline`].

use crate::util::Prng;

use super::tcmma::{cpu_f32_baseline, MmaExec};
use super::rounding::{quantize, quantize_fp16};

/// Which of the three Fig. 16 operations to isolate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ProfileOp {
    Multiplication,
    InnerProduct,
    Accumulation,
}

impl ProfileOp {
    pub fn paper_name(self) -> &'static str {
        match self {
            ProfileOp::Multiplication => "multiplication",
            ProfileOp::InnerProduct => "add - Inner Product",
            ProfileOp::Accumulation => "accumulation",
        }
    }

    /// Canonical workload-spec token (`numeric profile <ab> <cd> <op>`).
    pub fn spec_name(self) -> &'static str {
        match self {
            ProfileOp::Multiplication => "mul",
            ProfileOp::InnerProduct => "inner",
            ProfileOp::Accumulation => "acc",
        }
    }

    /// Parse a spec token (canonical names plus the paper's long forms).
    pub fn parse_spec(s: &str) -> Result<ProfileOp, String> {
        match s.to_ascii_lowercase().as_str() {
            "mul" | "multiplication" => Ok(ProfileOp::Multiplication),
            "inner" | "inner-product" | "innerproduct" | "add" => Ok(ProfileOp::InnerProduct),
            "acc" | "accumulation" => Ok(ProfileOp::Accumulation),
            other => Err(format!("unknown profile op {other:?} (mul|inner|acc)")),
        }
    }

    pub const ALL: [ProfileOp; 3] =
        [ProfileOp::Multiplication, ProfileOp::InnerProduct, ProfileOp::Accumulation];
}

/// Initialization strategy (§8.1: low-precision init eliminates the
/// conversion loss; FP32 init exposes it).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum InitKind {
    /// Pre-round A/B (and a FP16 C when C/D is FP16) to the operand type.
    LowPrecision,
    /// Full FP32 initialization.
    Fp32,
}

impl InitKind {
    /// Canonical workload-spec token (`low` | `fp32`).
    pub fn spec_name(self) -> &'static str {
        match self {
            InitKind::LowPrecision => "low",
            InitKind::Fp32 => "fp32",
        }
    }

    /// Parse a spec token.
    pub fn parse_spec(s: &str) -> Result<InitKind, String> {
        match s.to_ascii_lowercase().as_str() {
            "low" | "init_low" | "lowprecision" => Ok(InitKind::LowPrecision),
            "fp32" | "init_fp32" | "f32" => Ok(InitKind::Fp32),
            other => Err(format!("unknown init strategy {other:?} (low|fp32)")),
        }
    }
}

/// Result of one profiling experiment.
#[derive(Debug, Clone, Copy)]
pub struct ProfileResult {
    pub op: ProfileOp,
    pub init: InitKind,
    /// mean |D_tc - D_cpu32| over the trials.
    pub mean_abs_err: f64,
    /// mean |D_tc - fp16(D_cpu32)| — the Table 14 extra baseline.
    pub mean_abs_err_vs_cvt_fp16: f64,
    pub trials: usize,
}

/// Run one §8.1 experiment on any executor backend.
pub fn profile_op(
    exec: &mut dyn MmaExec,
    op: ProfileOp,
    init: InitKind,
    trials: usize,
    seed: u64,
) -> ProfileResult {
    let cfg = exec.cfg();
    let (m, n, k) = (cfg.m, cfg.n, cfg.k);
    let mut rng = Prng::new(seed);
    let mut a = vec![0.0f32; trials * m * k];
    let mut b = vec![0.0f32; trials * k * n];
    let mut c = vec![0.0f32; trials * m * n];

    let q = |rng: &mut Prng, init: InitKind, ab: &str| -> f32 {
        let v = rng.normal_f32();
        match init {
            InitKind::LowPrecision => quantize(v, ab),
            InitKind::Fp32 => v,
        }
    };

    for t in 0..trials {
        match op {
            ProfileOp::Multiplication => {
                a[t * m * k] = q(&mut rng, init, cfg.ab);
                b[t * k * n] = q(&mut rng, init, cfg.ab);
            }
            ProfileOp::InnerProduct => {
                for p in 0..k {
                    a[t * m * k + p] = q(&mut rng, init, cfg.ab); // row 0
                    b[t * k * n + p * n] = q(&mut rng, init, cfg.ab); // col 0
                }
            }
            ProfileOp::Accumulation => {
                a[t * m * k] = q(&mut rng, init, cfg.ab);
                b[t * k * n] = q(&mut rng, init, cfg.ab);
                let cv = rng.normal_f32();
                // C/D type is FP32 for the *_f32 configs (never
                // quantized); for fp16_f16, C itself is FP16 and the
                // low-precision init pre-rounds it.
                c[t * m * n] = if cfg.cd == "f16" && init == InitKind::LowPrecision {
                    quantize_fp16(cv)
                } else {
                    cv
                };
            }
        }
    }

    let tc = exec.run(trials, &a, &b, &c);
    let cpu = cpu_f32_baseline(trials, m, n, k, &a, &b, &c);

    // Only d00 of each trial is populated — matching the paper's
    // element-wise profiling.
    let mut err = 0.0f64;
    let mut err_cvt = 0.0f64;
    for t in 0..trials {
        let d_tc = tc[t * m * n] as f64;
        let d_cpu = cpu[t * m * n] as f64;
        err += (d_tc - d_cpu).abs();
        err_cvt += (d_tc - quantize_fp16(d_cpu as f32) as f64).abs();
    }
    ProfileResult {
        op,
        init,
        mean_abs_err: err / trials as f64,
        mean_abs_err_vs_cvt_fp16: err_cvt / trials as f64,
        trials,
    }
}

#[cfg(test)]
mod tests {
    use super::super::tcmma::{NativeExec, NumericCfg};
    use super::*;

    const TRIALS: usize = 1000;

    fn run(cfg: NumericCfg, op: ProfileOp, init: InitKind) -> ProfileResult {
        profile_op(&mut NativeExec::new(cfg), op, init, TRIALS, 7)
    }

    #[test]
    fn table12_bf16() {
        let cfg = NumericCfg::new("bf16", "f32", 16, 8, 8);
        assert_eq!(run(cfg, ProfileOp::Multiplication, InitKind::LowPrecision).mean_abs_err, 0.0);
        assert_eq!(run(cfg, ProfileOp::InnerProduct, InitKind::LowPrecision).mean_abs_err, 0.0);
        let acc = run(cfg, ProfileOp::Accumulation, InitKind::LowPrecision).mean_abs_err;
        assert!((1e-9..1e-7).contains(&acc), "paper 1.89e-8, got {acc:e}");
        for op in ProfileOp::ALL {
            let e = run(cfg, op, InitKind::Fp32).mean_abs_err;
            assert!((1e-4..1e-2).contains(&e), "{op:?}: {e:e}");
        }
    }

    #[test]
    fn table13_fp16_f32() {
        let cfg = NumericCfg::new("fp16", "f32", 16, 8, 8);
        for op in ProfileOp::ALL {
            assert_eq!(run(cfg, op, InitKind::LowPrecision).mean_abs_err, 0.0, "{op:?}");
            let e = run(cfg, op, InitKind::Fp32).mean_abs_err;
            assert!((1e-5..1e-3).contains(&e), "{op:?}: {e:e}");
        }
    }

    #[test]
    fn table14_fp16_f16() {
        let cfg = NumericCfg::new("fp16", "f16", 16, 8, 8);
        for op in ProfileOp::ALL {
            let r = run(cfg, op, InitKind::LowPrecision);
            assert!(r.mean_abs_err > 0.0, "{op:?} vs CPU_FP32 must be nonzero");
            assert_eq!(r.mean_abs_err_vs_cvt_fp16, 0.0, "{op:?} vs cvtFP16 must be zero");
        }
    }

    #[test]
    fn table15_tf32() {
        let cfg = NumericCfg::new("tf32", "f32", 16, 8, 8);
        for op in ProfileOp::ALL {
            assert_eq!(run(cfg, op, InitKind::LowPrecision).mean_abs_err, 0.0, "{op:?}");
        }
        // same error level as FP16 (10 mantissa bits each)
        let fp16 = NumericCfg::new("fp16", "f32", 16, 8, 8);
        let e_tf32 = run(cfg, ProfileOp::Multiplication, InitKind::Fp32).mean_abs_err;
        let e_fp16 = run(fp16, ProfileOp::Multiplication, InitKind::Fp32).mean_abs_err;
        let ratio = e_tf32 / e_fp16;
        assert!((0.5..2.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn bf16_error_level_exceeds_fp16() {
        let bf = NumericCfg::new("bf16", "f32", 16, 8, 8);
        let fp = NumericCfg::new("fp16", "f32", 16, 8, 8);
        let e_bf = run(bf, ProfileOp::Multiplication, InitKind::Fp32).mean_abs_err;
        let e_fp = run(fp, ProfileOp::Multiplication, InitKind::Fp32).mean_abs_err;
        assert!(e_bf / e_fp > 4.0, "bf16 {e_bf:e} vs fp16 {e_fp:e}");
    }
}
