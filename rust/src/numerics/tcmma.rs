//! The emulated Tensor-Core MMA datapath (native implementation) and the
//! execution-backend abstraction shared with the PJRT runtime.

use super::rounding::{f64_to_f32_rne, f64_to_f32_rz, quantize, quantize_fp16, Rounding};

/// Numeric configuration of one emulated instruction — mirrors the
/// Python `TcMmaConfig` (and the artifact manifest entries).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NumericCfg {
    /// Operand type: "bf16" | "fp16" | "tf32".
    pub ab: &'static str,
    /// Accumulator/result type: "f32" | "f16".
    pub cd: &'static str,
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl NumericCfg {
    pub const fn new(ab: &'static str, cd: &'static str, m: usize, n: usize, k: usize) -> Self {
        Self { ab, cd, m, n, k }
    }

    /// Accumulation rounding: RZ on the BF16 path (Table 12), RNE else.
    pub fn acc_rounding(&self) -> Rounding {
        if self.ab == "bf16" {
            Rounding::Rz
        } else {
            Rounding::Rne
        }
    }

    /// The artifact name this config lowers to.
    pub fn artifact_name(&self) -> String {
        format!("tcmma_{}_{}_m{}n{}k{}", self.ab, self.cd, self.m, self.n, self.k)
    }
}

/// A batched emulated-MMA executor: `d = tcmma(a, b, c)` over
/// `batch x (m,k) x (k,n) + (m,n)` f32 buffers (row-major, batch-major).
pub trait MmaExec {
    fn cfg(&self) -> NumericCfg;

    /// Execute one batch. Slice lengths must match the config/batch.
    fn run(&mut self, batch: usize, a: &[f32], b: &[f32], c: &[f32]) -> Vec<f32>;
}

/// Native softfloat implementation of the datapath:
/// quantize (RNE) -> exact products -> f64 inner product -> one RNE
/// rounding into the FP32 result register -> accumulation of `+C` with
/// the type's rounding mode -> optional final FP16 conversion.
#[derive(Debug, Clone, Copy)]
pub struct NativeExec {
    pub cfg: NumericCfg,
}

impl NativeExec {
    pub fn new(cfg: NumericCfg) -> Self {
        Self { cfg }
    }

    /// One tile (no batch) — the core datapath.
    pub fn tile(&self, a: &[f32], b: &[f32], c: &[f32], out: &mut [f32]) {
        let NumericCfg { m, n, k, ab, cd } = self.cfg;
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        assert_eq!(c.len(), m * n);
        assert_eq!(out.len(), m * n);
        let rnd = self.cfg.acc_rounding();
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f64; // the wide adder
                for p in 0..k {
                    let aq = quantize(a[i * k + p], ab) as f64;
                    let bq = quantize(b[p * n + j], ab) as f64;
                    s += aq * bq;
                }
                let s32 = f64_to_f32_rne(s); // inner product rounds once
                let acc = s32 as f64 + c[i * n + j] as f64;
                let mut d = match rnd {
                    Rounding::Rne => f64_to_f32_rne(acc),
                    Rounding::Rz => f64_to_f32_rz(acc),
                };
                if cd == "f16" {
                    // high-precision compute, final conversion only
                    // (Table 14 finding)
                    d = quantize_fp16(d);
                }
                out[i * n + j] = d;
            }
        }
    }
}

impl MmaExec for NativeExec {
    fn cfg(&self) -> NumericCfg {
        self.cfg
    }

    fn run(&mut self, batch: usize, a: &[f32], b: &[f32], c: &[f32]) -> Vec<f32> {
        let NumericCfg { m, n, k, .. } = self.cfg;
        assert_eq!(a.len(), batch * m * k);
        assert_eq!(b.len(), batch * k * n);
        assert_eq!(c.len(), batch * m * n);
        let mut out = vec![0.0f32; batch * m * n];
        for t in 0..batch {
            self.tile(
                &a[t * m * k..(t + 1) * m * k],
                &b[t * k * n..(t + 1) * k * n],
                &c[t * m * n..(t + 1) * m * n],
                &mut out[t * m * n..(t + 1) * m * n],
            );
        }
        out
    }
}

/// The paper's CPU reference: plain FP32 `D = A@B + C` — exact products,
/// the inner product rounded once to f32, then an RNE f32 accumulate.
pub fn cpu_f32_baseline(
    batch: usize,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    c: &[f32],
) -> Vec<f32> {
    let mut out = vec![0.0f32; batch * m * n];
    for t in 0..batch {
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f64;
                for p in 0..k {
                    s += a[t * m * k + i * k + p] as f64 * b[t * k * n + p * n + j] as f64;
                }
                let s32 = s as f32;
                out[t * m * n + i * n + j] =
                    (s32 as f64 + c[t * m * n + i * n + j] as f64) as f32;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    const BF16: NumericCfg = NumericCfg::new("bf16", "f32", 16, 8, 8);
    const FP16: NumericCfg = NumericCfg::new("fp16", "f32", 16, 8, 8);
    const FP16_F16: NumericCfg = NumericCfg::new("fp16", "f16", 16, 8, 8);
    const TF32: NumericCfg = NumericCfg::new("tf32", "f32", 16, 8, 8);

    fn random_batch(cfg: NumericCfg, batch: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut p = Prng::new(seed);
        let mut a = vec![0.0; batch * cfg.m * cfg.k];
        let mut b = vec![0.0; batch * cfg.k * cfg.n];
        let mut c = vec![0.0; batch * cfg.m * cfg.n];
        p.fill_normal(&mut a);
        p.fill_normal(&mut b);
        p.fill_normal(&mut c);
        (a, b, c)
    }

    #[test]
    fn acc_rounding_per_type() {
        assert_eq!(BF16.acc_rounding(), Rounding::Rz);
        assert_eq!(FP16.acc_rounding(), Rounding::Rne);
        assert_eq!(TF32.acc_rounding(), Rounding::Rne);
    }

    #[test]
    fn artifact_names() {
        assert_eq!(BF16.artifact_name(), "tcmma_bf16_f32_m16n8k8");
        assert_eq!(FP16_F16.artifact_name(), "tcmma_fp16_f16_m16n8k8");
    }

    #[test]
    fn quantized_inputs_give_zero_error_vs_cpu_when_c_zero() {
        // Table 13/15 init_low rows: multiplication and inner product
        // match the CPU FP32 baseline exactly.
        for cfg in [FP16, TF32] {
            let batch = 32;
            let (mut a, mut b, _) = random_batch(cfg, batch, 3);
            for v in a.iter_mut() {
                *v = quantize(*v, cfg.ab);
            }
            for v in b.iter_mut() {
                *v = quantize(*v, cfg.ab);
            }
            let c = vec![0.0f32; batch * cfg.m * cfg.n];
            let tc = NativeExec::new(cfg).run(batch, &a, &b, &c);
            let cpu = cpu_f32_baseline(batch, cfg.m, cfg.n, cfg.k, &a, &b, &c);
            assert_eq!(tc, cpu, "{}", cfg.ab);
        }
    }

    #[test]
    fn bf16_rz_accumulation_differs_from_cpu() {
        // Table 12's nonzero accumulation error under init_BF16.
        let cfg = BF16;
        let batch = 64;
        let (mut a, mut b, c) = random_batch(cfg, batch, 4);
        for v in a.iter_mut() {
            *v = quantize(*v, "bf16");
        }
        for v in b.iter_mut() {
            *v = quantize(*v, "bf16");
        }
        let tc = NativeExec::new(cfg).run(batch, &a, &b, &c);
        let cpu = cpu_f32_baseline(batch, cfg.m, cfg.n, cfg.k, &a, &b, &c);
        let err: f64 = tc
            .iter()
            .zip(&cpu)
            .map(|(x, y)| (x - y).abs() as f64)
            .sum::<f64>()
            / tc.len() as f64;
        assert!(err > 0.0, "RZ accumulation must differ from RNE");
        assert!(err < 1e-6, "but only at the last-ulp level: {err}");
        // and |tc| <= |exact| everywhere (RZ property)
        for (x, y) in tc.iter().zip(&cpu) {
            if x != y {
                assert!(x.abs() <= y.abs() + 1e-6);
            }
        }
    }

    #[test]
    fn fp16_cd_saturates_to_inf() {
        let cfg = FP16_F16;
        let batch = 1;
        let a = vec![100.0f32; cfg.m * cfg.k];
        let b = vec![100.0f32; cfg.k * cfg.n];
        let c = vec![0.0f32; cfg.m * cfg.n];
        let out = NativeExec::new(cfg).run(batch, &a, &b, &c);
        assert!(out.iter().all(|v| v.is_infinite()));
    }

    #[test]
    fn identity_passthrough_is_quantization() {
        let cfg = NumericCfg::new("tf32", "f32", 8, 8, 8);
        let mut eye = vec![0.0f32; 64];
        for i in 0..8 {
            eye[i * 8 + i] = 1.0;
        }
        let mut p = Prng::new(7);
        let mut b = vec![0.0f32; 64];
        p.fill_normal(&mut b);
        let c = vec![0.0f32; 64];
        let out = NativeExec::new(cfg).run(1, &eye, &b, &c);
        let want: Vec<f32> = b.iter().map(|&v| quantize(v, "tf32")).collect();
        assert_eq!(out, want);
    }
}
