//! §8 numeric behaviors: quantization, the emulated Tensor-Core MMA
//! datapath, the element-wise profiling experiments (Tables 12–15) and
//! the chain matrix multiplication study (Fig. 17).
//!
//! The datapath exists twice in this repo: here (native Rust softfloat)
//! and as JAX/Pallas AOT artifacts executed through [`crate::runtime`].
//! Integration tests assert the two agree bit-exactly; the experiments
//! can run on either backend via the [`MmaExec`] trait.

mod chain;
mod profiling;
mod rounding;
mod tcmma;

pub use chain::{chain_errors, ChainResult};
pub use profiling::{profile_op, InitKind, ProfileOp, ProfileResult};
pub use rounding::{f64_to_f32_rne, f64_to_f32_rz, quantize, quantize_bf16, quantize_fp16, quantize_tf32, Rounding};
pub use tcmma::{cpu_f32_baseline, NativeExec, NumericCfg, MmaExec};
