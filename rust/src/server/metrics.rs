//! tcserved observability: request counters, cache hit rates (both the
//! per-unit result cache and the process-wide cell cache),
//! per-experiment compute cost, and request/phase latency histograms —
//! exported as JSON at `/v1/metrics` and in Prometheus text exposition
//! format at `/metrics`.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::Json;

use super::cache::CacheStats;
use super::histogram::{bucket_bound, HistogramSet, BUCKETS};

/// Intern a metrics label, returning a `&'static str` equal to it.
/// Each *distinct* label leaks exactly once; every label family here is
/// bounded (route labels, phase names, experiment ids), so the total
/// leak is bounded too — while dynamic strings can be recorded without
/// a per-call allocation or an unbounded leak.
pub fn intern(label: &str) -> &'static str {
    static INTERNED: Mutex<BTreeSet<&'static str>> = Mutex::new(BTreeSet::new());
    // Invariant: lock unwraps in this module only fail on poisoning,
    // and no thread can panic inside these critical sections — they
    // are pure map/counter bookkeeping with no user code.
    let mut set = INTERNED.lock().unwrap();
    if let Some(&s) = set.get(label) {
        return s;
    }
    let s: &'static str = Box::leak(label.to_string().into_boxed_str());
    set.insert(s);
    s
}

#[derive(Debug, Clone, Copy, Default)]
pub struct ComputeStat {
    pub count: u64,
    pub total_ms: f64,
}

pub struct Metrics {
    started: Instant,
    requests_total: AtomicU64,
    /// Connections shed with `503` because the accept queue was full.
    requests_rejected: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    cache_coalesced: AtomicU64,
    /// Error-severity tclint diagnostics surfaced through the server.
    lint_errors: AtomicU64,
    /// Warn-severity tclint diagnostics surfaced through the server.
    lint_warnings: AtomicU64,
    by_endpoint: Mutex<BTreeMap<&'static str, u64>>,
    by_status: Mutex<BTreeMap<u16, u64>>,
    computes: Mutex<BTreeMap<&'static str, ComputeStat>>,
    /// End-to-end request latency per endpoint label.
    request_latency: HistogramSet,
    /// Phase latency (`parse`, `cache_lookup`, `simulate`, `render`).
    phases: HistogramSet,
    /// `POST /v1/tune` autotuner runs.
    tune_runs: AtomicU64,
    /// Configurations scored by the analytic model across tuner runs.
    tune_configs_scored: AtomicU64,
    /// Frontier configurations confirmed through the cycle sim.
    tune_configs_confirmed: AtomicU64,
    /// Tuner predicted-vs-simulated latency relative error, in parts
    /// per million, keyed by workload family.
    tune_rel_err_ppm: HistogramSet,
    /// Timing units served from the calibrated analytic prediction
    /// because the request's `deadline_ms` budget was blown.
    degraded_total: AtomicU64,
    /// Degradations by workload family (`mma`, `ldmatrix`, ...).
    degraded_by_family: Mutex<BTreeMap<&'static str, u64>>,
    /// Requests answered `504 deadline_exceeded` (numeric units, which
    /// have no analytic model to degrade to).
    deadline_exceeded_total: AtomicU64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            requests_total: AtomicU64::new(0),
            requests_rejected: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            cache_coalesced: AtomicU64::new(0),
            lint_errors: AtomicU64::new(0),
            lint_warnings: AtomicU64::new(0),
            by_endpoint: Mutex::new(BTreeMap::new()),
            by_status: Mutex::new(BTreeMap::new()),
            computes: Mutex::new(BTreeMap::new()),
            request_latency: HistogramSet::new(),
            phases: HistogramSet::new(),
            tune_runs: AtomicU64::new(0),
            tune_configs_scored: AtomicU64::new(0),
            tune_configs_confirmed: AtomicU64::new(0),
            tune_rel_err_ppm: HistogramSet::new(),
            degraded_total: AtomicU64::new(0),
            degraded_by_family: Mutex::new(BTreeMap::new()),
            deadline_exceeded_total: AtomicU64::new(0),
        }
    }

    pub fn record_request(&self, endpoint: &str) {
        self.requests_total.fetch_add(1, Ordering::Relaxed);
        *self.by_endpoint.lock().unwrap().entry(intern(endpoint)).or_insert(0) += 1;
    }

    pub fn record_status(&self, status: u16) {
        *self.by_status.lock().unwrap().entry(status).or_insert(0) += 1;
    }

    /// One connection shed on the acceptor because the worker queue was
    /// full (answered `503` + `Retry-After` without parsing a request,
    /// so it is *not* part of `requests_total`).
    pub fn record_rejected(&self) {
        self.requests_rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_hit(&self) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_miss(&self) {
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_coalesced(&self) {
        self.cache_coalesced.fetch_add(1, Ordering::Relaxed);
    }

    /// One static-verification pass (`POST /v1/lint`) that produced
    /// `errors` Error-severity and `warnings` Warn-severity diagnostics.
    pub fn record_lint(&self, errors: u64, warnings: u64) {
        self.lint_errors.fetch_add(errors, Ordering::Relaxed);
        self.lint_warnings.fetch_add(warnings, Ordering::Relaxed);
    }

    /// One autotuner run (`POST /v1/tune`) that scored `scored`
    /// configurations analytically and confirmed `confirmed` of them
    /// through the cycle-accurate path.
    pub fn record_tune(&self, scored: u64, confirmed: u64) {
        self.tune_runs.fetch_add(1, Ordering::Relaxed);
        self.tune_configs_scored.fetch_add(scored, Ordering::Relaxed);
        self.tune_configs_confirmed.fetch_add(confirmed, Ordering::Relaxed);
    }

    /// One confirmed tuner configuration's predicted-vs-simulated
    /// relative error, recorded in parts per million under `family`.
    pub fn record_tune_rel_err(&self, family: &str, rel_err: f64) {
        self.tune_rel_err_ppm.record_us(family, (rel_err.abs() * 1e6) as u64);
    }

    /// One timing unit of `family` served degraded: its `deadline_ms`
    /// budget blew before the cycle simulation finished, so the
    /// calibrated analytic prediction was served instead.
    pub fn record_degraded(&self, family: &str) {
        self.degraded_total.fetch_add(1, Ordering::Relaxed);
        *self.degraded_by_family.lock().unwrap().entry(intern(family)).or_insert(0) += 1;
    }

    /// One request answered `504 deadline_exceeded` — the budget blew
    /// on a unit with no analytic model to degrade to.
    pub fn record_deadline_exceeded(&self) {
        self.deadline_exceeded_total.fetch_add(1, Ordering::Relaxed);
    }

    /// One completed computation of `id`, taking `ms` milliseconds.
    pub fn record_compute(&self, id: &str, ms: f64) {
        let mut computes = self.computes.lock().unwrap();
        let stat = computes.entry(intern(id)).or_default();
        stat.count += 1;
        stat.total_ms += ms;
    }

    /// One end-to-end request on `endpoint`, taking `us` microseconds.
    pub fn record_latency(&self, endpoint: &str, us: u64) {
        self.request_latency.record_us(endpoint, us);
    }

    /// One request phase (`parse`, `cache_lookup`, `simulate`,
    /// `render`), taking `us` microseconds.
    pub fn record_phase(&self, phase: &str, us: u64) {
        self.phases.record_us(phase, us);
    }

    pub fn requests_total(&self) -> u64 {
        self.requests_total.load(Ordering::Relaxed)
    }

    fn hit_rate(hits: u64, misses: u64, coalesced: u64) -> f64 {
        let looked_up = hits + misses + coalesced;
        if looked_up == 0 {
            0.0
        } else {
            // coalesced requests were served without recomputation too
            (hits + coalesced) as f64 / looked_up as f64
        }
    }

    pub fn to_json(&self, cache: CacheStats) -> Json {
        let hits = self.cache_hits.load(Ordering::Relaxed);
        let misses = self.cache_misses.load(Ordering::Relaxed);
        let coalesced = self.cache_coalesced.load(Ordering::Relaxed);

        let by_endpoint = Json::Obj(
            self.by_endpoint
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.to_string(), Json::num(*v as f64)))
                .collect(),
        );
        let by_status = Json::Obj(
            self.by_status
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.to_string(), Json::num(*v as f64)))
                .collect(),
        );
        let experiments = Json::Obj(
            self.computes
                .lock()
                .unwrap()
                .iter()
                .map(|(id, s)| {
                    (
                        id.to_string(),
                        Json::obj(vec![
                            ("computes", Json::num(s.count as f64)),
                            ("total_ms", Json::num(s.total_ms)),
                            (
                                "mean_ms",
                                Json::num(if s.count == 0 { 0.0 } else { s.total_ms / s.count as f64 }),
                            ),
                        ]),
                    )
                })
                .collect(),
        );

        Json::obj(vec![
            ("uptime_ms", Json::num(self.started.elapsed().as_secs_f64() * 1e3)),
            ("requests_total", Json::num(self.requests_total() as f64)),
            (
                "requests_rejected",
                Json::num(self.requests_rejected.load(Ordering::Relaxed) as f64),
            ),
            ("by_endpoint", by_endpoint),
            ("by_status", by_status),
            (
                "cache",
                Json::obj(vec![
                    ("hits", Json::num(hits as f64)),
                    ("misses", Json::num(misses as f64)),
                    ("coalesced", Json::num(coalesced as f64)),
                    ("hit_rate", Json::num(Self::hit_rate(hits, misses, coalesced))),
                    ("entries", Json::num(cache.entries as f64)),
                    ("capacity", Json::num(cache.capacity as f64)),
                    ("evictions", Json::num(cache.evictions as f64)),
                ]),
            ),
            // the cell-level execution engine's memoization layer —
            // process-wide (it outlives and is shared across AppStates),
            // counting single-cell simulations rather than plan units
            ("cell_cache", {
                let cells = crate::workload::cell_cache_stats();
                Json::obj(vec![
                    ("hits", Json::num(cells.hits as f64)),
                    ("misses", Json::num(cells.misses as f64)),
                    ("evictions", Json::num(cells.evictions as f64)),
                    ("cells_simulated", Json::num(cells.cells_simulated as f64)),
                    ("entries", Json::num(cells.entries as f64)),
                    ("capacity", Json::num(cells.capacity as f64)),
                ])
            }),
            // the shared on-disk cell store behind the cell cache;
            // `enabled: false` (all-zero counters) when no store is
            // attached, so the section's shape is scrape-stable
            ("cell_store", {
                let store = crate::workload::cell_store_stats();
                Json::obj(vec![
                    ("enabled", Json::Bool(store.is_some())),
                    ("hits", Json::num(store.as_ref().map_or(0, |s| s.hits) as f64)),
                    ("misses", Json::num(store.as_ref().map_or(0, |s| s.misses) as f64)),
                    ("writes", Json::num(store.as_ref().map_or(0, |s| s.writes) as f64)),
                    ("corrupt", Json::num(store.as_ref().map_or(0, |s| s.corrupt) as f64)),
                ])
            }),
            // tclint diagnostics surfaced through POST /v1/lint
            (
                "lint",
                Json::obj(vec![
                    ("errors", Json::num(self.lint_errors.load(Ordering::Relaxed) as f64)),
                    (
                        "warnings",
                        Json::num(self.lint_warnings.load(Ordering::Relaxed) as f64),
                    ),
                ]),
            ),
            // the /v1/tune autotuner: run counts, the analytic->sim
            // pruning funnel, and the predicted-vs-simulated error
            // distribution (ppm) per workload family
            ("tune", {
                let scored = self.tune_configs_scored.load(Ordering::Relaxed);
                let confirmed = self.tune_configs_confirmed.load(Ordering::Relaxed);
                Json::obj(vec![
                    ("runs", Json::num(self.tune_runs.load(Ordering::Relaxed) as f64)),
                    ("configs_scored", Json::num(scored as f64)),
                    ("configs_confirmed", Json::num(confirmed as f64)),
                    ("rel_err_ppm", self.tune_rel_err_ppm.to_json()),
                ])
            }),
            // deadline handling: analytic degradations (served 200 with
            // a `degraded` marker) and hard 504s (no model to fall to)
            (
                "robustness",
                Json::obj(vec![
                    (
                        "degraded_total",
                        Json::num(self.degraded_total.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "degraded_by_family",
                        Json::Obj(
                            self.degraded_by_family
                                .lock()
                                .unwrap()
                                .iter()
                                .map(|(k, v)| (k.to_string(), Json::num(*v as f64)))
                                .collect(),
                        ),
                    ),
                    (
                        "deadline_exceeded_total",
                        Json::num(self.deadline_exceeded_total.load(Ordering::Relaxed) as f64),
                    ),
                ]),
            ),
            // tcchaos fault injection; `enabled: false` (zeroed
            // counters) when the server runs without `--chaos`, so the
            // section's shape is scrape-stable
            ("chaos", {
                let stats = crate::chaos::stats();
                Json::obj(vec![
                    ("enabled", Json::Bool(stats.is_some())),
                    (
                        "spec",
                        stats.as_ref().map_or(Json::Null, |s| Json::Str(s.spec.clone())),
                    ),
                    ("seed", Json::num(stats.as_ref().map_or(0, |s| s.seed) as f64)),
                    (
                        "injected_total",
                        Json::num(stats.as_ref().map_or(0, |s| s.injected_total) as f64),
                    ),
                    (
                        "by_fault",
                        Json::Obj(
                            stats
                                .as_ref()
                                .map(|s| {
                                    s.by_fault
                                        .iter()
                                        .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
                                        .collect()
                                })
                                .unwrap_or_default(),
                        ),
                    ),
                ])
            }),
            ("experiments", experiments),
            ("latency_us", self.request_latency.to_json()),
            ("phases_us", self.phases.to_json()),
        ])
    }

    /// Render every counter, gauge and histogram in the Prometheus text
    /// exposition format (served at `GET /metrics`). The values are the
    /// same ones `/v1/metrics` reports as JSON.
    pub fn to_prometheus(&self, cache: CacheStats) -> String {
        let mut out = String::with_capacity(4096);
        let mut metric = |name: &str, kind: &str, help: &str, lines: &[(String, f64)]| {
            let _ = writeln!(out, "# HELP tcserved_{name} {help}");
            let _ = writeln!(out, "# TYPE tcserved_{name} {kind}");
            for (labels, value) in lines {
                let _ = writeln!(out, "tcserved_{name}{labels} {value}");
            }
        };

        metric(
            "uptime_seconds",
            "gauge",
            "Seconds since server start.",
            &[(String::new(), self.started.elapsed().as_secs_f64())],
        );
        metric(
            "requests_total",
            "counter",
            "Total HTTP requests received.",
            &[(String::new(), self.requests_total() as f64)],
        );
        metric(
            "requests_rejected_total",
            "counter",
            "Connections shed with 503 because the accept queue was full.",
            &[(String::new(), self.requests_rejected.load(Ordering::Relaxed) as f64)],
        );
        metric(
            "endpoint_requests_total",
            "counter",
            "HTTP requests by endpoint label.",
            &self
                .by_endpoint
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (format!("{{endpoint=\"{k}\"}}"), *v as f64))
                .collect::<Vec<_>>(),
        );
        metric(
            "responses_total",
            "counter",
            "HTTP responses by status code.",
            &self
                .by_status
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (format!("{{status=\"{k}\"}}"), *v as f64))
                .collect::<Vec<_>>(),
        );

        let hits = self.cache_hits.load(Ordering::Relaxed);
        let misses = self.cache_misses.load(Ordering::Relaxed);
        let coalesced = self.cache_coalesced.load(Ordering::Relaxed);
        for (name, help, value) in [
            ("result_cache_hits_total", "Result-cache hits (memory or disk).", hits as f64),
            ("result_cache_misses_total", "Result-cache misses (computed).", misses as f64),
            (
                "result_cache_coalesced_total",
                "Requests coalesced onto an in-flight computation.",
                coalesced as f64,
            ),
            ("result_cache_evictions_total", "Result-cache LRU evictions.", cache.evictions as f64),
        ] {
            metric(name, "counter", help, &[(String::new(), value)]);
        }
        metric(
            "result_cache_entries",
            "gauge",
            "Result-cache entries resident in memory.",
            &[(String::new(), cache.entries as f64)],
        );
        metric(
            "result_cache_capacity",
            "gauge",
            "Result-cache in-memory capacity.",
            &[(String::new(), cache.capacity as f64)],
        );

        let cells = crate::workload::cell_cache_stats();
        for (name, help, value) in [
            ("cell_cache_hits_total", "Cell-cache hits (process-wide).", cells.hits as f64),
            ("cell_cache_misses_total", "Cell-cache misses.", cells.misses as f64),
            ("cell_cache_evictions_total", "Cell-cache evictions.", cells.evictions as f64),
            (
                "cell_cache_cells_simulated_total",
                "Single-cell simulations executed.",
                cells.cells_simulated as f64,
            ),
        ] {
            metric(name, "counter", help, &[(String::new(), value)]);
        }
        metric(
            "cell_cache_entries",
            "gauge",
            "Cell-cache entries resident.",
            &[(String::new(), cells.entries as f64)],
        );
        metric(
            "cell_cache_capacity",
            "gauge",
            "Cell-cache capacity.",
            &[(String::new(), cells.capacity as f64)],
        );

        let store = crate::workload::cell_store_stats();
        metric(
            "cell_store_enabled",
            "gauge",
            "1 when a shared on-disk cell store is attached.",
            &[(String::new(), if store.is_some() { 1.0 } else { 0.0 })],
        );
        for (name, help, value) in [
            (
                "cell_store_hits_total",
                "Cell-store disk hits (cells simulated by an earlier run or another replica).",
                store.as_ref().map_or(0, |s| s.hits) as f64,
            ),
            (
                "cell_store_misses_total",
                "Cell-store misses (cell absent on disk).",
                store.as_ref().map_or(0, |s| s.misses) as f64,
            ),
            (
                "cell_store_writes_total",
                "Cells persisted to the shared store.",
                store.as_ref().map_or(0, |s| s.writes) as f64,
            ),
            (
                "cell_store_corrupt_total",
                "Unreadable cell files tolerated as misses.",
                store.as_ref().map_or(0, |s| s.corrupt) as f64,
            ),
        ] {
            metric(name, "counter", help, &[(String::new(), value)]);
        }

        for (name, help, value) in [
            (
                "lint_errors_total",
                "Error-severity tclint diagnostics served by POST /v1/lint.",
                self.lint_errors.load(Ordering::Relaxed) as f64,
            ),
            (
                "lint_warnings_total",
                "Warn-severity tclint diagnostics served by POST /v1/lint.",
                self.lint_warnings.load(Ordering::Relaxed) as f64,
            ),
        ] {
            metric(name, "counter", help, &[(String::new(), value)]);
        }

        for (name, help, value) in [
            (
                "tune_runs_total",
                "Autotuner runs served by POST /v1/tune.",
                self.tune_runs.load(Ordering::Relaxed) as f64,
            ),
            (
                "tune_configs_scored_total",
                "Configurations scored by the tuner's analytic model.",
                self.tune_configs_scored.load(Ordering::Relaxed) as f64,
            ),
            (
                "tune_configs_confirmed_total",
                "Frontier configurations confirmed through the cycle sim.",
                self.tune_configs_confirmed.load(Ordering::Relaxed) as f64,
            ),
        ] {
            metric(name, "counter", help, &[(String::new(), value)]);
        }

        metric(
            "degraded_total",
            "counter",
            "Timing units served from the analytic prediction after a blown deadline_ms.",
            &[(String::new(), self.degraded_total.load(Ordering::Relaxed) as f64)],
        );
        metric(
            "degraded_by_family_total",
            "counter",
            "Deadline degradations by workload family.",
            &self
                .degraded_by_family
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (format!("{{family=\"{k}\"}}"), *v as f64))
                .collect::<Vec<_>>(),
        );
        metric(
            "deadline_exceeded_total",
            "counter",
            "Requests answered 504 deadline_exceeded (no analytic fallback).",
            &[(String::new(), self.deadline_exceeded_total.load(Ordering::Relaxed) as f64)],
        );

        let chaos = crate::chaos::stats();
        metric(
            "chaos_enabled",
            "gauge",
            "1 when a tcchaos fault plan is installed (--chaos).",
            &[(String::new(), if chaos.is_some() { 1.0 } else { 0.0 })],
        );
        metric(
            "chaos_injected_total",
            "counter",
            "Faults injected by the tcchaos plan, all sites.",
            &[(String::new(), chaos.as_ref().map_or(0, |s| s.injected_total) as f64)],
        );
        metric(
            "chaos_faults_total",
            "counter",
            "Faults injected by the tcchaos plan, by site:kind.",
            &chaos
                .as_ref()
                .map(|s| {
                    s.by_fault
                        .iter()
                        .map(|(k, v)| (format!("{{fault=\"{k}\"}}"), *v as f64))
                        .collect::<Vec<_>>()
                })
                .unwrap_or_default(),
        );

        {
            let computes = self.computes.lock().unwrap();
            metric(
                "computes_total",
                "counter",
                "Completed computations by experiment/endpoint id.",
                &computes
                    .iter()
                    .map(|(id, s)| (format!("{{id=\"{id}\"}}"), s.count as f64))
                    .collect::<Vec<_>>(),
            );
            metric(
                "compute_ms_total",
                "counter",
                "Total compute milliseconds by experiment/endpoint id.",
                &computes
                    .iter()
                    .map(|(id, s)| (format!("{{id=\"{id}\"}}"), s.total_ms))
                    .collect::<Vec<_>>(),
            );
        }

        for (name, label_key, help, set) in [
            (
                "request_duration_us",
                "endpoint",
                "End-to-end request latency by endpoint (microseconds).",
                &self.request_latency,
            ),
            (
                "phase_duration_us",
                "phase",
                "Request-phase latency (parse/cache_lookup/simulate/render; microseconds).",
                &self.phases,
            ),
            (
                "tune_rel_err_ppm",
                "family",
                "Tuner predicted-vs-simulated relative error by workload family (ppm).",
                &self.tune_rel_err_ppm,
            ),
        ] {
            let mut lines: Vec<(String, f64)> = Vec::new();
            for (label, h) in set.snapshot() {
                let mut cumulative = 0u64;
                for (i, n) in h.bucket_counts().into_iter().enumerate() {
                    cumulative += n;
                    if n == 0 && i != BUCKETS - 1 {
                        continue; // sparse: only populated buckets + +Inf
                    }
                    let le = if i == BUCKETS - 1 {
                        "+Inf".to_string()
                    } else {
                        bucket_bound(i).to_string()
                    };
                    lines.push((
                        format!("_bucket{{{label_key}=\"{label}\",le=\"{le}\"}}"),
                        cumulative as f64,
                    ));
                }
                lines.push((format!("_sum{{{label_key}=\"{label}\"}}"), h.sum_us() as f64));
                lines.push((format!("_count{{{label_key}=\"{label}\"}}"), h.count() as f64));
            }
            // histogram suffixes are part of the line name, not the
            // family name, so append them manually under one HELP/TYPE
            let _ = writeln!(out, "# HELP tcserved_{name} {help}");
            let _ = writeln!(out, "# TYPE tcserved_{name} histogram");
            for (suffix, value) in lines {
                let _ = writeln!(out, "tcserved_{name}{suffix} {value}");
            }
        }
        out
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_aggregate_into_json() {
        let m = Metrics::new();
        m.record_request("run");
        m.record_request("run");
        m.record_request("metrics");
        m.record_status(200);
        m.record_status(200);
        m.record_status(404);
        m.record_miss();
        m.record_hit();
        m.record_hit();
        m.record_coalesced();
        m.record_compute("t3", 10.0);
        m.record_compute("t3", 20.0);
        m.record_lint(2, 3);
        m.record_lint(0, 1);
        m.record_tune(48, 8);
        m.record_tune_rel_err("mma", 0.05);
        m.record_degraded("mma");
        m.record_degraded("mma");
        m.record_degraded("ldmatrix");
        m.record_deadline_exceeded();

        m.record_rejected();

        let j = m.to_json(CacheStats { entries: 1, capacity: 8, evictions: 0 });
        assert_eq!(j.get_u64("requests_total"), Some(3));
        assert_eq!(j.get_u64("requests_rejected"), Some(1));
        assert_eq!(j.get("by_endpoint").unwrap().get_u64("run"), Some(2));
        assert_eq!(j.get("by_status").unwrap().get_u64("404"), Some(1));
        let cache = j.get("cache").unwrap();
        assert_eq!(cache.get_u64("hits"), Some(2));
        assert_eq!(cache.get_u64("misses"), Some(1));
        assert_eq!(cache.get_u64("coalesced"), Some(1));
        assert!((cache.get_f64("hit_rate").unwrap() - 0.75).abs() < 1e-9);
        let lint = j.get("lint").unwrap();
        assert_eq!(lint.get_u64("errors"), Some(2));
        assert_eq!(lint.get_u64("warnings"), Some(4));
        let tune = j.get("tune").unwrap();
        assert_eq!(tune.get_u64("runs"), Some(1));
        assert_eq!(tune.get_u64("configs_scored"), Some(48));
        assert_eq!(tune.get_u64("configs_confirmed"), Some(8));
        let err = tune.get("rel_err_ppm").unwrap().get("mma").unwrap();
        assert_eq!(err.get_u64("count"), Some(1));
        let t3 = j.get("experiments").unwrap().get("t3").unwrap();
        assert_eq!(t3.get_u64("computes"), Some(2));
        assert!((t3.get_f64("mean_ms").unwrap() - 15.0).abs() < 1e-9);
        // the cell-cache section is present with every counter (the
        // values are process-global, so only shape is asserted here;
        // the router tests assert traffic)
        let cells = j.get("cell_cache").unwrap();
        for field in ["hits", "misses", "evictions", "cells_simulated", "entries", "capacity"] {
            assert!(cells.get_u64(field).is_some(), "cell_cache.{field} missing");
        }
        let rob = j.get("robustness").unwrap();
        assert_eq!(rob.get_u64("degraded_total"), Some(3));
        assert_eq!(rob.get("degraded_by_family").unwrap().get_u64("mma"), Some(2));
        assert_eq!(rob.get("degraded_by_family").unwrap().get_u64("ldmatrix"), Some(1));
        assert_eq!(rob.get_u64("deadline_exceeded_total"), Some(1));
        // the chaos section is shape-stable whether or not a fault plan
        // is installed (process-global, so only shape is asserted here)
        let chaos = j.get("chaos").unwrap();
        assert!(chaos.get("enabled").and_then(Json::as_bool).is_some());
        assert!(chaos.get_u64("injected_total").is_some());
        assert!(chaos.get("by_fault").unwrap().as_obj().is_some());
        // the cell-store section is always present (enabled=false with
        // zeroed counters when no store is attached)
        let store = j.get("cell_store").unwrap();
        assert!(store.get("enabled").and_then(Json::as_bool).is_some());
        for field in ["hits", "misses", "writes", "corrupt"] {
            assert!(store.get_u64(field).is_some(), "cell_store.{field} missing");
        }
        // the whole document serializes to valid JSON
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn interning_returns_one_static_str_per_label() {
        let a = intern(&String::from("some-label"));
        let b = intern("some-label");
        assert_eq!(a, b);
        assert_eq!(a.as_ptr(), b.as_ptr(), "one leaked allocation per distinct label");
        assert_ne!(intern("other-label"), a);
    }

    #[test]
    fn dynamic_labels_and_latency_histograms_flow_into_json() {
        let m = Metrics::new();
        // &str (non-'static) labels are accepted everywhere
        let endpoint = String::from("sweep");
        m.record_request(&endpoint);
        m.record_latency(&endpoint, 1500);
        m.record_latency(&endpoint, 2500);
        m.record_phase("parse", 3);
        m.record_phase("simulate", 900);

        let j = m.to_json(CacheStats { entries: 0, capacity: 8, evictions: 0 });
        assert_eq!(j.get("by_endpoint").unwrap().get_u64("sweep"), Some(1));
        let lat = j.get("latency_us").unwrap().get("sweep").unwrap();
        assert_eq!(lat.get_u64("count"), Some(2));
        assert!((lat.get_f64("mean_us").unwrap() - 2000.0).abs() < 1e-9);
        assert!(lat.get_f64("p99_us").unwrap() >= lat.get_f64("p50_us").unwrap());
        let phases = j.get("phases_us").unwrap();
        assert_eq!(phases.get("parse").unwrap().get_u64("count"), Some(1));
        assert_eq!(phases.get("simulate").unwrap().get_u64("count"), Some(1));
    }

    #[test]
    fn prometheus_rendering_matches_the_json_counters() {
        let m = Metrics::new();
        m.record_request("run");
        m.record_request("plan");
        m.record_status(200);
        m.record_hit();
        m.record_miss();
        m.record_compute("plan", 12.5);
        m.record_latency("run", 42);
        m.record_phase("render", 7);
        m.record_lint(1, 4);
        m.record_tune(48, 8);
        m.record_tune_rel_err("mma", 0.05);
        m.record_degraded("mma");
        m.record_deadline_exceeded();

        let stats = CacheStats { entries: 2, capacity: 8, evictions: 1 };
        let text = m.to_prometheus(stats);
        // every non-comment line is `name{labels} value`
        let mut names_seen = BTreeSet::new();
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# HELP ") {
                let name = rest.split_whitespace().next().unwrap();
                assert!(names_seen.insert(name.to_string()), "duplicate HELP for {name}");
                continue;
            }
            if line.starts_with("# TYPE ") || line.is_empty() {
                continue;
            }
            let (name_labels, value) = line.rsplit_once(' ').unwrap();
            assert!(name_labels.starts_with("tcserved_"), "{line}");
            assert!(value.parse::<f64>().is_ok(), "{line}");
        }
        assert!(text.contains("tcserved_requests_total 2"));
        assert!(text.contains("tcserved_requests_rejected_total 0"));
        assert!(text.contains("tcserved_cell_store_enabled"));
        assert!(text.contains("tcserved_cell_store_hits_total"));
        assert!(text.contains("tcserved_endpoint_requests_total{endpoint=\"run\"} 1"));
        assert!(text.contains("tcserved_responses_total{status=\"200\"} 1"));
        assert!(text.contains("tcserved_result_cache_hits_total 1"));
        assert!(text.contains("tcserved_result_cache_misses_total 1"));
        assert!(text.contains("tcserved_result_cache_entries 2"));
        assert!(text.contains("tcserved_lint_errors_total 1"));
        assert!(text.contains("tcserved_lint_warnings_total 4"));
        assert!(text.contains("tcserved_tune_runs_total 1"));
        assert!(text.contains("tcserved_tune_configs_scored_total 48"));
        assert!(text.contains("tcserved_tune_configs_confirmed_total 8"));
        assert!(text.contains("tcserved_tune_rel_err_ppm_count{family=\"mma\"} 1"));
        assert!(text.contains("tcserved_degraded_total 1"));
        assert!(text.contains("tcserved_degraded_by_family_total{family=\"mma\"} 1"));
        assert!(text.contains("tcserved_deadline_exceeded_total 1"));
        assert!(text.contains("tcserved_chaos_enabled"));
        assert!(text.contains("tcserved_chaos_injected_total"));
        assert!(text.contains("tcserved_tune_rel_err_ppm_sum{family=\"mma\"} 50000"));
        assert!(text.contains("tcserved_computes_total{id=\"plan\"} 1"));
        assert!(text.contains("tcserved_compute_ms_total{id=\"plan\"} 12.5"));
        assert!(text.contains("tcserved_request_duration_us_count{endpoint=\"run\"} 1"));
        assert!(text.contains("tcserved_request_duration_us_sum{endpoint=\"run\"} 42"));
        // cumulative histogram ends at +Inf == count
        assert!(text
            .contains("tcserved_request_duration_us_bucket{endpoint=\"run\",le=\"+Inf\"} 1"));
        assert!(text.contains("tcserved_phase_duration_us_bucket{phase=\"render\",le=\"8\"} 1"));
    }
}
