//! tcserved observability: request counters, cache hit rates (both the
//! per-unit result cache and the process-wide cell cache) and
//! per-experiment compute cost, exported as JSON at `/v1/metrics`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::Json;

use super::cache::CacheStats;

#[derive(Debug, Clone, Copy, Default)]
pub struct ComputeStat {
    pub count: u64,
    pub total_ms: f64,
}

pub struct Metrics {
    started: Instant,
    requests_total: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    cache_coalesced: AtomicU64,
    by_endpoint: Mutex<BTreeMap<&'static str, u64>>,
    by_status: Mutex<BTreeMap<u16, u64>>,
    computes: Mutex<BTreeMap<String, ComputeStat>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            requests_total: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            cache_coalesced: AtomicU64::new(0),
            by_endpoint: Mutex::new(BTreeMap::new()),
            by_status: Mutex::new(BTreeMap::new()),
            computes: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn record_request(&self, endpoint: &'static str) {
        self.requests_total.fetch_add(1, Ordering::Relaxed);
        *self.by_endpoint.lock().unwrap().entry(endpoint).or_insert(0) += 1;
    }

    pub fn record_status(&self, status: u16) {
        *self.by_status.lock().unwrap().entry(status).or_insert(0) += 1;
    }

    pub fn record_hit(&self) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_miss(&self) {
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_coalesced(&self) {
        self.cache_coalesced.fetch_add(1, Ordering::Relaxed);
    }

    /// One completed computation of `id`, taking `ms` milliseconds.
    pub fn record_compute(&self, id: &str, ms: f64) {
        let mut computes = self.computes.lock().unwrap();
        let stat = computes.entry(id.to_string()).or_default();
        stat.count += 1;
        stat.total_ms += ms;
    }

    pub fn requests_total(&self) -> u64 {
        self.requests_total.load(Ordering::Relaxed)
    }

    pub fn to_json(&self, cache: CacheStats) -> Json {
        let hits = self.cache_hits.load(Ordering::Relaxed);
        let misses = self.cache_misses.load(Ordering::Relaxed);
        let coalesced = self.cache_coalesced.load(Ordering::Relaxed);
        let looked_up = hits + misses + coalesced;
        let hit_rate = if looked_up == 0 {
            0.0
        } else {
            // coalesced requests were served without recomputation too
            (hits + coalesced) as f64 / looked_up as f64
        };

        let by_endpoint = Json::Obj(
            self.by_endpoint
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.to_string(), Json::num(*v as f64)))
                .collect(),
        );
        let by_status = Json::Obj(
            self.by_status
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.to_string(), Json::num(*v as f64)))
                .collect(),
        );
        let experiments = Json::Obj(
            self.computes
                .lock()
                .unwrap()
                .iter()
                .map(|(id, s)| {
                    (
                        id.clone(),
                        Json::obj(vec![
                            ("computes", Json::num(s.count as f64)),
                            ("total_ms", Json::num(s.total_ms)),
                            (
                                "mean_ms",
                                Json::num(if s.count == 0 { 0.0 } else { s.total_ms / s.count as f64 }),
                            ),
                        ]),
                    )
                })
                .collect(),
        );

        Json::obj(vec![
            ("uptime_ms", Json::num(self.started.elapsed().as_secs_f64() * 1e3)),
            ("requests_total", Json::num(self.requests_total() as f64)),
            ("by_endpoint", by_endpoint),
            ("by_status", by_status),
            (
                "cache",
                Json::obj(vec![
                    ("hits", Json::num(hits as f64)),
                    ("misses", Json::num(misses as f64)),
                    ("coalesced", Json::num(coalesced as f64)),
                    ("hit_rate", Json::num(hit_rate)),
                    ("entries", Json::num(cache.entries as f64)),
                    ("capacity", Json::num(cache.capacity as f64)),
                    ("evictions", Json::num(cache.evictions as f64)),
                ]),
            ),
            // the cell-level execution engine's memoization layer —
            // process-wide (it outlives and is shared across AppStates),
            // counting single-cell simulations rather than plan units
            ("cell_cache", {
                let cells = crate::workload::cell_cache_stats();
                Json::obj(vec![
                    ("hits", Json::num(cells.hits as f64)),
                    ("misses", Json::num(cells.misses as f64)),
                    ("evictions", Json::num(cells.evictions as f64)),
                    ("cells_simulated", Json::num(cells.cells_simulated as f64)),
                    ("entries", Json::num(cells.entries as f64)),
                    ("capacity", Json::num(cells.capacity as f64)),
                ])
            }),
            ("experiments", experiments),
        ])
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_aggregate_into_json() {
        let m = Metrics::new();
        m.record_request("run");
        m.record_request("run");
        m.record_request("metrics");
        m.record_status(200);
        m.record_status(200);
        m.record_status(404);
        m.record_miss();
        m.record_hit();
        m.record_hit();
        m.record_coalesced();
        m.record_compute("t3", 10.0);
        m.record_compute("t3", 20.0);

        let j = m.to_json(CacheStats { entries: 1, capacity: 8, evictions: 0 });
        assert_eq!(j.get_u64("requests_total"), Some(3));
        assert_eq!(j.get("by_endpoint").unwrap().get_u64("run"), Some(2));
        assert_eq!(j.get("by_status").unwrap().get_u64("404"), Some(1));
        let cache = j.get("cache").unwrap();
        assert_eq!(cache.get_u64("hits"), Some(2));
        assert_eq!(cache.get_u64("misses"), Some(1));
        assert_eq!(cache.get_u64("coalesced"), Some(1));
        assert!((cache.get_f64("hit_rate").unwrap() - 0.75).abs() < 1e-9);
        let t3 = j.get("experiments").unwrap().get("t3").unwrap();
        assert_eq!(t3.get_u64("computes"), Some(2));
        assert!((t3.get_f64("mean_ms").unwrap() - 15.0).abs() < 1e-9);
        // the cell-cache section is present with every counter (the
        // values are process-global, so only shape is asserted here;
        // the router tests assert traffic)
        let cells = j.get("cell_cache").unwrap();
        for field in ["hits", "misses", "evictions", "cells_simulated", "entries", "capacity"] {
            assert!(cells.get_u64(field).is_some(), "cell_cache.{field} missing");
        }
        // the whole document serializes to valid JSON
        assert!(Json::parse(&j.to_string()).is_ok());
    }
}
