//! tcserved request routing: the `/v1` JSON API over the campaign.
//!
//! Heavy endpoints (`/v1/run/<id>`, `/v1/sweep`, `POST /v1/plan`) go
//! through the content-addressed [`ResultCache`]: the first request
//! computes via `coordinator::run_experiment` or the unified workload
//! layer ([`crate::workload`]), every identical later request is a
//! cache hit, and concurrent identical requests are coalesced into a
//! single computation. Plans are cached *per unit* — the unit token
//! carries every workload parameter — so two plans sharing units share
//! their cache entries, and the single-flight machinery dedups at unit
//! granularity. `POST /v1/lint` runs the tclint static verifier over a
//! plan's programs without simulating; it is compute-light and bypasses
//! the cache.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Instant;

use crate::coordinator::{self, run_parallel, BackendKind, ExperimentId, EXPERIMENTS};
use crate::device;
use crate::report;
use crate::util::Json;
use crate::workload::{self, BenchPlan, Plan, Runner, SimRunner, UnitKind, Workload};

use super::cache::{cache_key, CacheKey, Origin, ResultCache};
use super::http::{Request, Response};
use super::metrics::Metrics;

/// Shared state of one tcserved instance.
pub struct AppState {
    pub cache: ResultCache,
    pub metrics: Metrics,
}

impl AppState {
    pub fn new(cache: ResultCache) -> AppState {
        AppState { cache, metrics: Metrics::new() }
    }
}

fn endpoint_label(path: &str) -> &'static str {
    match path {
        "/healthz" => "healthz",
        "/v1/experiments" => "experiments",
        "/v1/devices" => "devices",
        "/v1/metrics" => "metrics",
        "/metrics" => "prometheus",
        "/v1/sweep" => "sweep",
        "/v1/plan" => "plan",
        "/v1/lint" => "lint",
        p if p.starts_with("/v1/run/") => "run",
        _ => "other",
    }
}

/// Dispatch one parsed request, recording its count and end-to-end
/// latency under the endpoint label.
pub fn handle(state: &AppState, req: &Request) -> Response {
    let label = endpoint_label(&req.path);
    state.metrics.record_request(label);
    let t0 = Instant::now();
    let response = route(state, req);
    state.metrics.record_latency(label, t0.elapsed().as_micros() as u64);
    response
}

fn route(state: &AppState, req: &Request) -> Response {
    if req.path == "/v1/plan" {
        if req.method != "POST" {
            return Response::error(
                405,
                format!(
                    "method {} not allowed; /v1/plan takes a POST with a JSON BenchPlan body",
                    req.method
                ),
            );
        }
        return plan(state, req);
    }
    if req.path == "/v1/lint" {
        if req.method != "POST" {
            return Response::error(
                405,
                format!(
                    "method {} not allowed; /v1/lint takes a POST with a JSON BenchPlan body",
                    req.method
                ),
            );
        }
        return lint(state, req);
    }
    if req.method != "GET" {
        return Response::error(
            405,
            format!(
                "method {} not allowed; this API is GET-only (except POST /v1/plan \
                 and /v1/lint)",
                req.method
            ),
        );
    }
    match req.path.as_str() {
        "/healthz" => healthz(),
        "/v1/experiments" => experiments(state),
        "/v1/devices" => devices(),
        "/v1/metrics" => metrics(state),
        "/metrics" => prometheus(state),
        "/v1/sweep" => sweep(state, req),
        p if p.starts_with("/v1/run/") => run(state, req, &p["/v1/run/".len()..]),
        other => Response::error(404, format!("no route for {other:?}")),
    }
}

fn healthz() -> Response {
    Response::json(
        200,
        &Json::obj(vec![
            ("status", Json::str("ok")),
            ("service", Json::str("tcserved")),
            ("version", Json::str(env!("CARGO_PKG_VERSION"))),
            ("experiments", Json::num(EXPERIMENTS.len() as f64)),
        ]),
    )
}

fn experiments(state: &AppState) -> Response {
    // report cache state for the default-backend key (auto, resolved —
    // the same key a parameterless /v1/run/<id> uses)
    let default_backend = BackendKind::Auto.resolve();
    let list: Vec<Json> = EXPERIMENTS
        .iter()
        .map(|e| {
            let key = cache_key(e.id, default_backend.name(), "-", "-");
            Json::obj(vec![
                ("id", Json::str(e.id)),
                ("description", Json::str(e.description)),
                ("kind", Json::str(if e.numeric { "numeric" } else { "sim" })),
                ("cached", Json::Bool(state.cache.contains(&key))),
                ("url", Json::Str(format!("/v1/run/{}", e.id))),
            ])
        })
        .collect();
    Response::json(
        200,
        &Json::obj(vec![
            ("count", Json::num(EXPERIMENTS.len() as f64)),
            ("experiments", Json::Arr(list)),
        ]),
    )
}

fn devices() -> Response {
    let list: Vec<Json> = device::registry()
        .into_iter()
        .map(|d| {
            Json::obj(vec![
                ("name", Json::str(d.name)),
                ("product", Json::str(d.product)),
                ("arch", Json::Str(format!("{:?}", d.arch))),
                ("sms", Json::num(d.sms as f64)),
                ("tensor_cores_per_sm", Json::num(d.arch.tensor_cores_per_sm() as f64)),
                ("supports_sparse", Json::Bool(d.arch.supports_sparse())),
                ("supports_ldmatrix", Json::Bool(d.arch.supports_ldmatrix())),
                ("supports_fp8", Json::Bool(d.supports_fp8())),
            ])
        })
        .collect();
    Response::json(200, &Json::obj(vec![("devices", Json::Arr(list))]))
}

fn metrics(state: &AppState) -> Response {
    Response::json(200, &state.metrics.to_json(state.cache.stats()))
}

/// `GET /metrics` — every counter, gauge and histogram in the
/// Prometheus text exposition format (the same values `/v1/metrics`
/// reports as JSON, so the two always agree).
fn prometheus(state: &AppState) -> Response {
    Response {
        status: 200,
        content_type: "text/plain; version=0.0.4",
        body: state.metrics.to_prometheus(state.cache.stats()),
    }
}

fn note_origin(state: &AppState, origin: Origin) {
    match origin {
        Origin::Memory | Origin::Disk => state.metrics.record_hit(),
        Origin::Computed => state.metrics.record_miss(),
        Origin::Coalesced => state.metrics.record_coalesced(),
    }
}

/// Wrap a cached payload for the wire: the payload is the content-addressed
/// value; `cached`/`origin` describe how this particular request got it.
/// Re-serializing the payload is the `render` phase.
fn respond_cached(
    state: &AppState,
    result: Result<String, String>,
    origin: Origin,
) -> Response {
    match result {
        Ok(body) => {
            let t0 = Instant::now();
            let inner = Json::parse(&body).unwrap_or(Json::Str(body));
            let response = Response::json(
                200,
                &Json::obj(vec![
                    ("cached", Json::Bool(origin != Origin::Computed)),
                    ("origin", Json::str(origin.name())),
                    ("result", inner),
                ]),
            );
            state.metrics.record_phase("render", t0.elapsed().as_micros() as u64);
            response
        }
        Err(e) => Response::error(500, e),
    }
}

// ------------------------------------------------------------ /v1/run/<id>

fn run(state: &AppState, req: &Request, id: &str) -> Response {
    let Some(exp) = coordinator::experiment(id) else {
        return Response::error(
            404,
            format!("unknown experiment {id:?}; see /v1/experiments for the registry"),
        );
    };
    // default matches the CLI: `auto` (pjrt when artifacts exist, else
    // native); the cache key uses whatever it resolves to
    let kind = match BackendKind::parse(req.param("backend").unwrap_or("auto")) {
        Ok(k) => k,
        Err(e) => return Response::error(400, format!("{e:#}")),
    };
    let (result, origin) = run_cached(state, exp, kind);
    respond_cached(state, result, origin)
}

/// Cached execution of one experiment — shared by the HTTP handler and
/// `--warm` precomputation.
pub fn run_cached(
    state: &AppState,
    exp: &'static ExperimentId,
    kind: BackendKind,
) -> (Result<String, String>, Origin) {
    // `auto` is keyed as whatever it resolves to, so its cache entries
    // are shared with the concrete backend and never go stale when the
    // environment (artifact availability) changes.
    let kind = kind.resolve();
    let key = cache_key(exp.id, kind.name(), "-", "-");
    let t0 = Instant::now();
    let (result, origin) =
        state.cache.get_or_compute(&key, || compute_experiment(state, exp, kind, &key));
    // a served-from-cache request's whole cost is the lookup; computed
    // requests record their cost as the `simulate` phase instead
    if origin != Origin::Computed {
        state.metrics.record_phase("cache_lookup", t0.elapsed().as_micros() as u64);
    }
    note_origin(state, origin);
    (result, origin)
}

fn compute_experiment(
    state: &AppState,
    exp: &'static ExperimentId,
    kind: BackendKind,
    key: &CacheKey,
) -> Result<String, String> {
    let t0 = Instant::now();
    let outcome = catch_unwind(AssertUnwindSafe(|| -> Result<(String, String), String> {
        // `kind` is already resolved; the runner is the backend seam for
        // the §8 numeric probes (native softfloat vs PJRT artifacts)
        let runner = workload::runner_for(kind)?;
        let backend_name = kind.name().to_string();
        let text = coordinator::run_experiment(exp.id, runner.as_ref())
            .map_err(|e| format!("{e:#}"))?;
        Ok((backend_name, text))
    }));
    let (backend_name, text) = match outcome {
        Ok(Ok(pair)) => pair,
        Ok(Err(e)) => return Err(e),
        Err(_) => return Err(format!("experiment {} panicked during computation", exp.id)),
    };
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    state.metrics.record_compute(exp.id, ms);
    state.metrics.record_phase("simulate", (ms * 1e3) as u64);
    Ok(Json::obj(vec![
        ("id", Json::str(exp.id)),
        ("backend", Json::Str(backend_name)),
        ("compute_ms", Json::num(ms)),
        ("key", Json::str(key.hash.clone())),
        ("report", report::report_to_json(exp.id, exp.description, &text)),
    ])
    .to_string())
}

/// Precompute every registered experiment through the worker pool so
/// steady-state request latency is cache-bound. Returns how many warmed
/// successfully.
pub fn warm(state: &AppState, threads: usize) -> usize {
    let jobs: Vec<_> = EXPERIMENTS
        .iter()
        .map(|e| move || run_cached(state, e, BackendKind::Auto).0.is_ok())
        .collect();
    // The table experiments parallelize internally; cap the outer pool
    // so warm-up does not oversubscribe the CPU quadratically.
    run_parallel(jobs, threads.min(4)).into_iter().filter(|ok| *ok).count()
}

// ---------------------------------------------------------------- /v1/sweep

/// `GET /v1/sweep?device=&instr=&sparse=` — a thin translator onto the
/// workload layer: the `instr` parameter accepts any [`Workload`] spec
/// (legacy mma specs included), the sweep runs as a one-unit
/// [`BenchPlan`] on the simulator runner.
fn sweep(state: &AppState, req: &Request) -> Response {
    let dev_name = req.param("device").unwrap_or("a100");
    let Some(dev) = device::by_name(dev_name) else {
        return Response::error(404, format!("unknown device {dev_name:?}; see /v1/devices"));
    };
    let Some(spec) = req.param("instr") else {
        return Response::error(
            400,
            "missing required query parameter `instr` (e.g. ?instr=bf16,f32,m16n8k16 \
             or ?instr=ldmatrix,x4)",
        );
    };
    let parsed = match Workload::parse_spec(spec) {
        Ok(w) => w,
        Err(e) => return Response::error(400, e),
    };
    let sparse = match req.param("sparse") {
        None => None,
        Some("1") | Some("true") | Some("yes") => Some(true),
        Some("0") | Some("false") | Some("no") => Some(false),
        Some(other) => {
            return Response::error(400, format!("bad sparse flag {other:?} (true|false)"))
        }
    };
    let load = match (sparse, parsed) {
        (None, w) => w,
        (
            Some(sparse),
            Workload::Mma { ab, cd, shape } | Workload::MmaSp { ab, cd, shape },
        ) => {
            if sparse {
                Workload::MmaSp { ab, cd, shape }
            } else {
                Workload::Mma { ab, cd, shape }
            }
        }
        (Some(_), w) => {
            return Response::error(
                400,
                format!("the sparse flag only applies to mma workloads, not {}", w.kind()),
            )
        }
    };
    let plan = match Plan::new(load).device(dev.name).sweep().compile() {
        Ok(p) => p,
        Err(e) => return Response::error(400, e),
    };
    // shared content address with the sweep unit of POST /v1/plan: a
    // plan that already swept this workload makes this a cache hit (and
    // vice versa) — the request-specific envelope (device, workload,
    // ptx, …) is added outside the cached payload
    let (result, origin) = unit_cached(state, &plan, UnitKind::Sweep, &SimRunner, "sweep");
    let body = match result {
        Ok(body) => body,
        Err(e) => return Response::error(500, e),
    };
    let Ok(Json::Obj(mut fields)) = Json::parse(&body) else {
        return Response::error(500, format!("corrupt cached sweep payload for {load}"));
    };
    fields.insert("device".to_string(), Json::str(plan.device.name));
    fields.insert("workload".to_string(), Json::Str(plan.workload.to_spec()));
    fields.insert("instr".to_string(), Json::Str(plan.workload.to_string()));
    if let Some(instr) = plan.workload.mma_instr() {
        fields.insert("ptx".to_string(), Json::Str(instr.ptx()));
        fields.insert("sparse".to_string(), Json::Bool(instr.sparse));
    }
    let t0 = Instant::now();
    let response = Response::json(
        200,
        &Json::obj(vec![
            ("cached", Json::Bool(origin != Origin::Computed)),
            ("origin", Json::str(origin.name())),
            ("result", Json::Obj(fields)),
        ]),
    );
    state.metrics.record_phase("render", t0.elapsed().as_micros() as u64);
    response
}

// ----------------------------------------------------------------- /v1/plan

/// `POST /v1/plan` — run a JSON [`BenchPlan`] and return the batched
/// unit results. Every unit is content-addressed individually (the
/// token carries all workload parameters and the exec point), so the
/// cache and single-flight machinery apply per workload unit and plans
/// sharing units share work.
fn plan(state: &AppState, req: &Request) -> Response {
    let body = match Json::parse(&req.body) {
        Ok(j) => j,
        Err(e) => return Response::error(400, format!("invalid JSON body: {e}")),
    };
    let plan = match Plan::from_json(&body) {
        Ok(p) => p,
        Err(e) => return Response::error(400, e),
    };
    let backend_name = match body.get("backend") {
        None => "auto",
        Some(Json::Str(s)) => s.as_str(),
        Some(other) => {
            return Response::error(
                400,
                format!("\"backend\" must be a string (native|pjrt|auto), got {other}"),
            )
        }
    };
    let kind = match BackendKind::parse(backend_name) {
        Ok(k) => k,
        Err(e) => return Response::error(400, format!("{e:#}")),
    };
    let runner = match workload::runner_for(kind) {
        Ok(r) => r,
        Err(e) => return Response::error(500, e),
    };
    let bench = match plan.compile() {
        Ok(b) => b,
        Err(e) => return Response::error(400, e),
    };

    let bench_ref = &bench;
    let runner_ref: &dyn Runner = runner.as_ref();
    let jobs: Vec<_> = bench
        .units
        .iter()
        .map(|&unit| move || unit_cached(state, bench_ref, unit, runner_ref, "plan"))
        .collect();
    let outcomes = run_parallel(jobs, coordinator::default_threads().min(4));

    let mut units = Vec::with_capacity(outcomes.len());
    let mut all_cached = true;
    for (unit, (result, origin)) in bench.units.iter().zip(outcomes) {
        let body = match result {
            Ok(body) => body,
            Err(e) => return Response::error(500, e),
        };
        all_cached &= origin != Origin::Computed;
        units.push(Json::obj(vec![
            ("unit", Json::Str(unit.label())),
            ("cached", Json::Bool(origin != Origin::Computed)),
            ("origin", Json::str(origin.name())),
            ("result", Json::parse(&body).unwrap_or(Json::Str(body))),
        ]));
    }
    let t0 = Instant::now();
    let response = Response::json(
        200,
        &Json::obj(vec![
            ("workload", Json::Str(bench.workload.to_spec())),
            ("device", Json::str(bench.device.name)),
            ("backend", Json::str(runner.name())),
            ("cached", Json::Bool(all_cached)),
            ("count", Json::num(units.len() as f64)),
            ("units", Json::Arr(units)),
        ]),
    );
    state.metrics.record_phase("render", t0.elapsed().as_micros() as u64);
    response
}

// ----------------------------------------------------------------- /v1/lint

/// `POST /v1/lint` — static analysis only. The body is the same JSON
/// [`Plan`] form `/v1/plan` takes; the response is the tclint
/// diagnostics over every warp program the plan would simulate, without
/// running any simulation. Status is 400 when any Error-severity
/// diagnostic fires (the program set is structurally broken), 200
/// otherwise (clean or warnings only).
fn lint(state: &AppState, req: &Request) -> Response {
    let body = match Json::parse(&req.body) {
        Ok(j) => j,
        Err(e) => return Response::error(400, format!("invalid JSON body: {e}")),
    };
    let plan = match Plan::from_json(&body) {
        Ok(p) => p,
        Err(e) => return Response::error(400, e),
    };
    let bench = match plan.compile() {
        Ok(b) => b,
        Err(e) => return Response::error(400, e),
    };
    let t0 = Instant::now();
    let records = bench.lint();
    state.metrics.record_phase("lint", t0.elapsed().as_micros() as u64);
    let errors = records.iter().filter(|r| r.is_error()).count();
    let warnings = records.len() - errors;
    state.metrics.record_lint(errors as u64, warnings as u64);
    let status = if errors > 0 { 400 } else { 200 };
    Response::json(
        status,
        &Json::obj(vec![
            ("workload", Json::Str(bench.workload.to_spec())),
            ("device", Json::str(bench.device.name)),
            ("errors", Json::num(errors as f64)),
            ("warnings", Json::num(warnings as f64)),
            ("diagnostics", report::lint_records_to_json(&records)),
        ]),
    )
}

/// Cached execution of one plan unit (content-addressed by the unit
/// token, which includes every workload parameter). `metrics_label`
/// attributes the compute time to the endpoint that paid for it
/// (`"plan"` or `"sweep"`) in `/v1/metrics`.
fn unit_cached(
    state: &AppState,
    bench: &BenchPlan,
    unit: UnitKind,
    runner: &dyn Runner,
    metrics_label: &'static str,
) -> (Result<String, String>, Origin) {
    let key = cache_key("plan", runner.name(), bench.device.name, &bench.unit_token(&unit));
    let t0 = Instant::now();
    let (result, origin) = state
        .cache
        .get_or_compute(&key, || compute_unit(state, bench, unit, runner, &key, metrics_label));
    if origin != Origin::Computed {
        state.metrics.record_phase("cache_lookup", t0.elapsed().as_micros() as u64);
    }
    note_origin(state, origin);
    (result, origin)
}

fn compute_unit(
    state: &AppState,
    bench: &BenchPlan,
    unit: UnitKind,
    runner: &dyn Runner,
    key: &CacheKey,
    metrics_label: &'static str,
) -> Result<String, String> {
    let t0 = Instant::now();
    let outcome = catch_unwind(AssertUnwindSafe(|| runner.run_unit(bench, &unit)));
    let output = match outcome {
        Ok(Ok(o)) => o,
        Ok(Err(e)) => return Err(e),
        Err(_) => {
            return Err(format!(
                "plan unit {} of {} panicked during computation",
                unit.label(),
                bench.workload
            ))
        }
    };
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    state.metrics.record_compute(metrics_label, ms);
    state.metrics.record_phase("simulate", (ms * 1e3) as u64);
    let Json::Obj(mut fields) = report::unit_output_to_json(&output) else {
        unreachable!("unit_output_to_json returns an object")
    };
    fields.insert("compute_ms".to_string(), Json::num(ms));
    fields.insert("key".to_string(), Json::str(key.hash.clone()));
    Ok(Json::Obj(fields).to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> AppState {
        AppState::new(ResultCache::new(32, None))
    }

    fn get(state: &AppState, target: &str) -> Response {
        let (path, query_raw) = match target.split_once('?') {
            Some((p, q)) => (p, Some(q)),
            None => (target, None),
        };
        let query = query_raw
            .map(|q| {
                q.split('&')
                    .filter(|p| !p.is_empty())
                    .map(|p| {
                        let (k, v) = p.split_once('=').unwrap_or((p, ""));
                        (k.to_string(), v.to_string())
                    })
                    .collect()
            })
            .unwrap_or_default();
        let req = Request {
            method: "GET".to_string(),
            path: path.to_string(),
            query,
            body: String::new(),
        };
        handle(state, &req)
    }

    fn post(state: &AppState, path: &str, body: &str) -> Response {
        let req = Request {
            method: "POST".to_string(),
            path: path.to_string(),
            query: vec![],
            body: body.to_string(),
        };
        handle(state, &req)
    }

    #[test]
    fn healthz_and_registry_endpoints() {
        let s = state();
        let r = get(&s, "/healthz");
        assert_eq!(r.status, 200);
        assert_eq!(Json::parse(&r.body).unwrap().get_str("status"), Some("ok"));

        let r = get(&s, "/v1/experiments");
        let j = Json::parse(&r.body).unwrap();
        assert_eq!(j.get_u64("count"), Some(19));
        assert_eq!(
            j.get("experiments").unwrap().as_arr().unwrap()[2].get_str("id"),
            Some("t3")
        );

        let r = get(&s, "/v1/devices");
        let j = Json::parse(&r.body).unwrap();
        let devices = j.get("devices").unwrap().as_arr().unwrap();
        assert_eq!(devices.len(), 4);
        // the projected Hopper target is addressable and fp8-capable
        let hopper = devices
            .iter()
            .find(|d| d.get_str("name") == Some("hopper-projected"))
            .expect("hopper-projected registered");
        assert_eq!(hopper.get("supports_fp8").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn unknown_routes_and_methods() {
        let s = state();
        assert_eq!(get(&s, "/nope").status, 404);
        assert_eq!(get(&s, "/v1/run/t99").status, 404);
        assert_eq!(post(&s, "/healthz", "").status, 405);
        // /v1/plan is POST-only
        assert_eq!(get(&s, "/v1/plan").status, 405);
    }

    #[test]
    fn run_caches_by_content_address() {
        let s = state();
        let r1 = get(&s, "/v1/run/t10");
        assert_eq!(r1.status, 200, "{}", r1.body);
        let j1 = Json::parse(&r1.body).unwrap();
        assert_eq!(j1.get("cached").and_then(Json::as_bool), Some(false));
        assert_eq!(j1.get("result").unwrap().get_str("id"), Some("t10"));

        let r2 = get(&s, "/v1/run/t10");
        let j2 = Json::parse(&r2.body).unwrap();
        assert_eq!(j2.get("cached").and_then(Json::as_bool), Some(true));
        assert_eq!(j2.get_str("origin"), Some("memory"));

        // `auto` resolves to native here (no PJRT offline), so it shares
        // the native content address and hits the same cache entry
        let r3 = get(&s, "/v1/run/t10?backend=auto");
        let j3 = Json::parse(&r3.body).unwrap();
        assert_eq!(j3.get("cached").and_then(Json::as_bool), Some(true));

        let m = Json::parse(&get(&s, "/v1/metrics").body).unwrap();
        let t10 = m.get("experiments").unwrap().get("t10").unwrap();
        assert_eq!(t10.get_u64("computes"), Some(1)); // auto coalesced onto native
        assert_eq!(m.get("cache").unwrap().get_u64("hits"), Some(2));
    }

    #[test]
    fn prometheus_endpoint_serves_text_exposition() {
        let s = state();
        // drive some traffic so the counters are non-trivial
        assert_eq!(get(&s, "/healthz").status, 200);
        assert_eq!(get(&s, "/v1/sweep?device=a100&instr=ldmatrix,x1").status, 200);
        assert_eq!(get(&s, "/v1/sweep?device=a100&instr=ldmatrix,x1").status, 200);

        // snapshot the JSON counters, then render Prometheus from the
        // same state (the /v1/metrics request itself bumps the counters,
        // so read the JSON response body, not a second scrape)
        let json = Json::parse(&get(&s, "/v1/metrics").body).unwrap();
        let r = get(&s, "/metrics");
        assert_eq!(r.status, 200, "{}", r.body);
        assert_eq!(r.content_type, "text/plain; version=0.0.4");

        // the JSON snapshot already counts its own request (recorded
        // before routing), so the later /metrics scrape is one ahead
        let expect_total = json.get_u64("requests_total").unwrap() + 1;
        assert!(
            r.body.contains(&format!("tcserved_requests_total {expect_total}")),
            "{}",
            r.body
        );
        let hits = json.get("cache").unwrap().get_u64("hits").unwrap();
        assert!(
            r.body.contains(&format!("tcserved_result_cache_hits_total {hits}")),
            "{}",
            r.body
        );
        let sweeps = json.get("by_endpoint").unwrap().get_u64("sweep").unwrap();
        assert!(r
            .body
            .contains(&format!("tcserved_endpoint_requests_total{{endpoint=\"sweep\"}} {sweeps}")));
        // phase histograms recorded: a computed sweep (simulate+render)
        // and a cached one (cache_lookup+render)
        for phase in ["simulate", "cache_lookup", "render"] {
            assert!(
                r.body.contains(&format!("phase_duration_us_count{{phase=\"{phase}\"}}")),
                "missing {phase} histogram:\n{}",
                r.body
            );
        }
        // request-latency histogram per endpoint label
        assert!(r.body.contains("tcserved_request_duration_us_bucket{endpoint=\"sweep\",le="));
    }

    #[test]
    fn sweep_validation() {
        let s = state();
        assert_eq!(get(&s, "/v1/sweep").status, 400);
        assert_eq!(get(&s, "/v1/sweep?instr=garbage").status, 400);
        assert_eq!(get(&s, "/v1/sweep?device=h100&instr=bf16,f32,m16n8k16").status, 404);
        // Turing has no sparse support
        assert_eq!(
            get(&s, "/v1/sweep?device=rtx2080ti&instr=fp16,f32,m16n8k16,sparse").status,
            400
        );
        assert_eq!(
            get(&s, "/v1/sweep?device=a100&instr=bf16,f32,m16n8k16&sparse=maybe").status,
            400
        );
    }

    #[test]
    fn sweep_returns_full_grid_and_caches() {
        let s = state();
        let r = get(&s, "/v1/sweep?device=a100&instr=bf16,f32,m16n8k16");
        assert_eq!(r.status, 200, "{}", r.body);
        let j = Json::parse(&r.body).unwrap();
        let result = j.get("result").unwrap();
        assert_eq!(result.get_str("device"), Some("a100"));
        assert_eq!(result.get_str("workload"), Some("mma bf16 f32 m16n8k16"));
        assert_eq!(result.get("cells").unwrap().as_arr().unwrap().len(), 48);
        assert_eq!(result.get("convergence").unwrap().as_arr().unwrap().len(), 2);
        let peak = result.get_f64("peak_throughput").unwrap();
        assert!((960.0..1030.0).contains(&peak), "peak {peak}");

        let r2 = get(&s, "/v1/sweep?device=a100&instr=bf16,f32,m16n8k16");
        let j2 = Json::parse(&r2.body).unwrap();
        assert_eq!(j2.get("cached").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn sweep_accepts_every_workload_kind() {
        // the endpoint is a thin translator onto the workload layer, so
        // data-movement sweeps work through the same route
        let s = state();
        let r = get(&s, "/v1/sweep?device=a100&instr=ldmatrix,x1");
        assert_eq!(r.status, 200, "{}", r.body);
        let j = Json::parse(&r.body).unwrap();
        assert_eq!(j.get("result").unwrap().get_str("workload"), Some("ldmatrix x1"));
        // sparse flag is mma-only
        assert_eq!(get(&s, "/v1/sweep?device=a100&instr=ldmatrix,x1&sparse=true").status, 400);
    }

    #[test]
    fn sweep_endpoint_shares_cache_with_plan_sweep_units() {
        let s = state();
        // a plan's sweep unit computes the grid once...
        let body = r#"{"workload":"ldmatrix x2","device":"a100","sweep":true,"backend":"native"}"#;
        let r = post(&s, "/v1/plan", body);
        assert_eq!(r.status, 200, "{}", r.body);
        // ...and the sweep endpoint reuses it (same per-unit content address)
        let r2 = get(&s, "/v1/sweep?device=a100&instr=ldmatrix,x2");
        let j2 = Json::parse(&r2.body).unwrap();
        assert_eq!(j2.get("cached").and_then(Json::as_bool), Some(true), "{}", r2.body);
        assert_eq!(
            j2.get("result").unwrap().get("cells").unwrap().as_arr().unwrap().len(),
            48
        );
    }

    #[test]
    fn plan_endpoint_caches_per_unit() {
        let s = state();
        let body = r#"{"workload":"ld.shared u32 4","device":"a100",
                       "points":[[1,1]],"completion_latency":true,"backend":"native"}"#;
        let r = post(&s, "/v1/plan", body);
        assert_eq!(r.status, 200, "{}", r.body);
        let j = Json::parse(&r.body).unwrap();
        assert_eq!(j.get_str("workload"), Some("ld.shared u32 4"));
        assert_eq!(j.get_str("backend"), Some("sim"));
        assert_eq!(j.get("cached").and_then(Json::as_bool), Some(false));
        let units = j.get("units").unwrap().as_arr().unwrap();
        assert_eq!(units.len(), 2);
        assert!(units.iter().all(|u| u.get("cached").and_then(Json::as_bool) == Some(false)));

        // identical plan: every unit is served from the cache
        let r2 = post(&s, "/v1/plan", body);
        let j2 = Json::parse(&r2.body).unwrap();
        assert_eq!(j2.get("cached").and_then(Json::as_bool), Some(true));
        let units2 = j2.get("units").unwrap().as_arr().unwrap();
        assert!(units2.iter().all(|u| u.get("cached").and_then(Json::as_bool) == Some(true)));

        // a plan differing only in ILP misses the cache (the exec point
        // is part of the content address)
        let body_ilp2 = r#"{"workload":"ld.shared u32 4","device":"a100",
                            "points":[[1,2]],"backend":"native"}"#;
        let r3 = post(&s, "/v1/plan", body_ilp2);
        let j3 = Json::parse(&r3.body).unwrap();
        let units3 = j3.get("units").unwrap().as_arr().unwrap();
        assert_eq!(units3[0].get_str("origin"), Some("computed"), "{}", r3.body);
    }

    #[test]
    fn sweep_then_point_reports_cell_cache_hits() {
        use crate::workload::{CellCache, ExecPoint};
        let s = state();
        // the sweep unit simulates (among others) cell (4,2) of this
        // workload through the process-wide cell cache…
        let sweep_body = r#"{"workload":"mma.sp bf16 f32 m16n8k32","device":"rtx3070ti",
                             "sweep":true,"backend":"native"}"#;
        let r = post(&s, "/v1/plan", sweep_body);
        assert_eq!(r.status, 200, "{}", r.body);
        // deterministic population check (the counters below are
        // process-global, so concurrent tests also move them)
        assert!(CellCache::global().contains(
            "mma.sp bf16 f32 m16n8k32",
            "rtx3070ti",
            ExecPoint::new(4, 2),
            "sim"
        ));
        let m = Json::parse(&get(&s, "/v1/metrics").body).unwrap();
        let hits_before = m.get("cell_cache").unwrap().get_u64("hits").unwrap();

        // …so the later point unit — a *miss* in the per-unit result
        // cache (different unit token) — is a cell-cache hit and costs
        // no simulation
        let point_body = r#"{"workload":"mma.sp bf16 f32 m16n8k32","device":"rtx3070ti",
                             "points":[[4,2]],"backend":"native"}"#;
        let r2 = post(&s, "/v1/plan", point_body);
        assert_eq!(r2.status, 200, "{}", r2.body);
        let j2 = Json::parse(&r2.body).unwrap();
        let units = j2.get("units").unwrap().as_arr().unwrap();
        assert_eq!(units[0].get_str("origin"), Some("computed"), "{}", r2.body);

        let m = Json::parse(&get(&s, "/v1/metrics").body).unwrap();
        let cells = m.get("cell_cache").unwrap();
        let hits_after = cells.get_u64("hits").unwrap();
        assert!(
            hits_after > hits_before,
            "point after sweep must hit the cell cache ({hits_before} -> {hits_after})"
        );
        // the sweep itself simulated a full grid's worth of cells
        assert!(cells.get_u64("cells_simulated").unwrap() >= 48);
    }

    #[test]
    fn plan_endpoint_accepts_gemm_specs() {
        let s = state();
        let body = r#"{"workload":"gemm pipeline bf16 f32 256 128x128x32","device":"a100",
                       "points":[[8,2]],"backend":"native"}"#;
        let r = post(&s, "/v1/plan", body);
        assert_eq!(r.status, 200, "{}", r.body);
        let j = Json::parse(&r.body).unwrap();
        assert_eq!(j.get_str("workload"), Some("gemm pipeline bf16 f32 256 128x128x32"));
        let units = j.get("units").unwrap().as_arr().unwrap();
        assert_eq!(units.len(), 1);
        let result = units[0].get("result").unwrap();
        assert!(result.get_f64("throughput").unwrap() > 0.0, "{result}");

        // an invalid tile is a 400 with an actionable error, not a 500
        let bad = r#"{"workload":"gemm pipeline bf16 f32 256 100x128x32","points":[[8,2]]}"#;
        let r = post(&s, "/v1/plan", bad);
        assert_eq!(r.status, 400, "{}", r.body);
        let err = Json::parse(&r.body).unwrap();
        assert!(err.get_str("error").unwrap().contains("tile_m"), "{}", r.body);

        // the sparse flag stays mma-only on the sweep translator
        let r = get(
            &s,
            "/v1/sweep?device=a100&instr=gemm,pipeline,bf16,f32,256,128x128x32&sparse=true",
        );
        assert_eq!(r.status, 400, "{}", r.body);
    }

    #[test]
    fn numeric_specs_flow_through_plan_and_sweep_routes() {
        let s = state();
        // a profile probe as a (1,1) point unit
        let body = r#"{"workload":"numeric profile fp16 f32 mul low","points":[[1,1]],
                       "backend":"native"}"#;
        let r = post(&s, "/v1/plan", body);
        assert_eq!(r.status, 200, "{}", r.body);
        let j = Json::parse(&r.body).unwrap();
        assert_eq!(j.get_str("workload"), Some("numeric profile fp16 f32 mul low"));
        let units = j.get("units").unwrap().as_arr().unwrap();
        let result = units[0].get("result").unwrap();
        assert_eq!(result.get_str("unit"), Some("numeric"));
        assert_eq!(result.get_str("probe"), Some("profile"));
        // Table 13: zero error under low-precision init
        assert_eq!(result.get_f64("mean_abs_err"), Some(0.0), "{result}");

        // the sweep route accepts numeric specs (chain-step x init grid)
        let r = get(&s, "/v1/sweep?device=a100&instr=numeric,chain,tf32,f32,5");
        assert_eq!(r.status, 200, "{}", r.body);
        let j = Json::parse(&r.body).unwrap();
        let result = j.get("result").unwrap();
        assert_eq!(result.get("cells").unwrap().as_arr().unwrap().len(), 10);
        assert_eq!(result.get_str("workload"), Some("numeric chain tf32 f32 5 low"));

        // invalid probes are 400s: fp8 on a non-fp8 device, off-(1,1)
        // points, completion probes
        for bad in [
            r#"{"workload":"numeric profile fp8e4m3 f32 mul","points":[[1,1]]}"#,
            r#"{"workload":"numeric profile bf16 f32 acc","points":[[4,1]]}"#,
            r#"{"workload":"numeric chain tf32 f32 5","completion_latency":true}"#,
        ] {
            let r = post(&s, "/v1/plan", bad);
            assert_eq!(r.status, 400, "{bad}: {}", r.body);
        }
        // ...while the fp8 probe is valid on the projected Hopper device
        let fp8 = r#"{"workload":"numeric profile fp8e4m3 f32 mul","points":[[1,1]],
                      "device":"hopper-projected","backend":"native"}"#;
        let r = post(&s, "/v1/plan", fp8);
        assert_eq!(r.status, 200, "{}", r.body);
    }

    #[test]
    fn lint_endpoint_reports_diagnostics() {
        let s = state();
        // a standard plan lints clean: 200 with an empty diagnostics array
        let clean = r#"{"workload":"mma bf16 f32 m16n8k16","device":"a100",
                        "points":[[4,3]],"sweep":true,"completion_latency":true}"#;
        let r = post(&s, "/v1/lint", clean);
        assert_eq!(r.status, 200, "{}", r.body);
        let j = Json::parse(&r.body).unwrap();
        assert_eq!(j.get_str("workload"), Some("mma bf16 f32 m16n8k16"));
        assert_eq!(j.get_str("device"), Some("a100"));
        assert_eq!(j.get_u64("errors"), Some(0));
        assert_eq!(j.get_u64("warnings"), Some(0));
        assert!(j.get("diagnostics").unwrap().as_arr().unwrap().is_empty(), "{}", r.body);

        // a 4-deep cp.async pipeline over 128x128x128 tiles keeps
        // 4 x 65536 B in flight — more shared memory than an A100 SM
        // has. The config is *legal* (compile succeeds; 16 k-steps
        // cover 4 stages), but structurally broken: 400 + the rule id.
        let overflow = r#"{"workload":"gemm pipeline bf16 f32 2048 128x128x128",
                           "device":"a100","points":[[8,4]]}"#;
        let r = post(&s, "/v1/lint", overflow);
        assert_eq!(r.status, 400, "{}", r.body);
        let j = Json::parse(&r.body).unwrap();
        assert!(j.get_u64("errors").unwrap() >= 1, "{}", r.body);
        let diags = j.get("diagnostics").unwrap().as_arr().unwrap();
        assert!(
            diags.iter().any(|d| d.get_str("rule") == Some("resource/smem-overflow")
                && d.get_str("severity") == Some("error")),
            "{}",
            r.body
        );

        // malformed bodies and uncompilable plans are 400s; GET is a 405
        assert_eq!(post(&s, "/v1/lint", "{not json").status, 400);
        assert_eq!(post(&s, "/v1/lint", r#"{"workload":"nonsense"}"#).status, 400);
        assert_eq!(get(&s, "/v1/lint").status, 405);

        // the lint counters observed the error-producing request
        let m = Json::parse(&get(&s, "/v1/metrics").body).unwrap();
        let lint = m.get("lint").unwrap();
        assert!(lint.get_u64("errors").unwrap() >= 1, "{m}");
        assert_eq!(m.get("by_endpoint").unwrap().get_u64("lint"), Some(5));
    }

    #[test]
    fn plan_endpoint_rejects_bad_requests() {
        let s = state();
        // malformed JSON
        let r = post(&s, "/v1/plan", "{not json");
        assert_eq!(r.status, 400);
        assert!(Json::parse(&r.body).unwrap().get_str("error").unwrap().contains("JSON"));
        // schema violations and impossible plans
        for body in [
            r#"{}"#,
            r#"{"workload":"nonsense"}"#,
            r#"{"workload":"mma bf16 f32 m16n8k16"}"#,
            r#"{"workload":"mma bf16 f32 m16n8k16","points":[[4,1]],"device":"h100"}"#,
            r#"{"workload":"mma bf16 f32 m16n8k16","points":[[4,1]],"backend":"cuda"}"#,
            r#"{"workload":"mma bf16 f32 m16n8k16","points":[[4,1]],"backend":false}"#,
            r#"{"workload":"fp16 f32 m16n8k16 sparse","points":[[4,1]],"device":"rtx2080ti"}"#,
        ] {
            assert_eq!(post(&s, "/v1/plan", body).status, 400, "{body}");
        }
    }
}
