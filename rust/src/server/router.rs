//! tcserved request routing: the `/v1` JSON API over the campaign.
//!
//! Every JSON endpoint answers in the one versioned envelope
//! ([`http::SCHEMA`](super::http::SCHEMA)): `{"schema": "tcserved/v1",
//! "data": ...}` on success, `{"schema": "tcserved/v1", "error":
//! {"code", "message", "status"}}` on failure, with machine-readable
//! error codes (`invalid_plan`, `unknown_device`, `lint_errors`, …).
//! The Prometheus text exposition at `/metrics` is the one deliberate
//! exception. Parameter reading is centralized in [`RequestParams`]:
//! POST bodies are the canonical form, GET+query is kept as a
//! deprecated alias that answers with a `Deprecation: true` header.
//!
//! Heavy endpoints (`/v1/run/<id>`, `/v1/sweep`, `POST /v1/plan`) go
//! through the content-addressed [`ResultCache`]: the first request
//! computes via `coordinator::run_experiment` or the unified workload
//! layer ([`crate::workload`]), every identical later request is a
//! cache hit, and concurrent identical requests are coalesced into a
//! single computation. Plans are cached *per unit* — the unit token
//! carries every workload parameter — so two plans sharing units share
//! their cache entries, and the single-flight machinery dedups at unit
//! granularity. Each unit executes under its owning shard's gate in
//! the [`ShardRouter`], which consistent-hashes the unit's content
//! address across replicas. `POST /v1/lint` runs the tclint static
//! verifier over a plan's programs without simulating; it is
//! compute-light and bypasses the cache.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::time::Instant;

use crate::coordinator::{self, run_parallel, BackendKind, ExperimentId, EXPERIMENTS};
use crate::device::{self, Device};
use crate::report;
use crate::sim::Budget;
use crate::util::Json;
use crate::workload::{self, BenchPlan, Plan, Runner, UnitKind, UnitRun, Workload};

use super::cache::{cache_key, CacheKey, Origin, ResultCache};
use super::http::{Request, Response};
use super::metrics::Metrics;
use super::shard::ShardRouter;

/// Private sentinel prefix on the error channel marking a typed
/// deadline failure (numeric units have no analytic fallback): the
/// cache's `Err` path carries plain strings, so the handler needs an
/// in-band marker to answer `504 deadline_exceeded` instead of `500`.
/// `\u{1}` cannot appear in any legitimate error message.
const DEADLINE_SENTINEL: &str = "\u{1}deadline_exceeded\u{1}";

/// Readiness state for `/readyz`: liveness (`/healthz`) says the
/// process answers; readiness says it is *worth sending traffic to* —
/// not still warming the experiment cache, and not sitting on a
/// saturated accept queue.
#[derive(Debug, Default)]
pub struct Readiness {
    warming: AtomicBool,
    queue_len: AtomicUsize,
    /// 0 = not configured (direct `AppState` use in tests/embedding):
    /// saturation never reports.
    queue_capacity: AtomicUsize,
}

impl Readiness {
    pub fn set_warming(&self, on: bool) {
        self.warming.store(on, Ordering::SeqCst);
    }

    pub fn warming(&self) -> bool {
        self.warming.load(Ordering::SeqCst)
    }

    pub fn set_queue_capacity(&self, capacity: usize) {
        self.queue_capacity.store(capacity, Ordering::SeqCst);
    }

    pub fn queue_enter(&self) {
        self.queue_len.fetch_add(1, Ordering::SeqCst);
    }

    pub fn queue_exit(&self) {
        // saturating: enter/exit are called from different threads and
        // the exit for a pre-registration connection must not wrap
        let _ = self.queue_len.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
            Some(n.saturating_sub(1))
        });
    }

    pub fn queue_len(&self) -> usize {
        self.queue_len.load(Ordering::SeqCst)
    }

    pub fn queue_capacity(&self) -> usize {
        self.queue_capacity.load(Ordering::SeqCst)
    }

    /// Full accept queue (only meaningful once the server configured a
    /// capacity).
    pub fn saturated(&self) -> bool {
        let cap = self.queue_capacity();
        cap > 0 && self.queue_len() >= cap
    }
}

/// Shared state of one tcserved instance.
pub struct AppState {
    pub cache: ResultCache,
    pub metrics: Metrics,
    pub shards: ShardRouter,
    pub readiness: Readiness,
}

impl AppState {
    pub fn new(cache: ResultCache) -> AppState {
        AppState::with_shards(cache, ShardRouter::single())
    }

    pub fn with_shards(cache: ResultCache, shards: ShardRouter) -> AppState {
        AppState { cache, metrics: Metrics::new(), shards, readiness: Readiness::default() }
    }
}

/// The one place request parameters are read: POST bodies (the
/// canonical form) and GET query strings (the deprecated alias)
/// resolve through identical code, so `backend`/`device` parsing
/// cannot drift between endpoints.
struct RequestParams<'a> {
    req: &'a Request,
    body: Option<Json>,
}

impl<'a> RequestParams<'a> {
    /// Parse the request's parameter source. A POST's source is its
    /// JSON body (empty body = empty object); anything else reads the
    /// query string.
    fn parse(req: &'a Request) -> Result<RequestParams<'a>, Response> {
        let body = if req.method == "POST" {
            if req.body.trim().is_empty() {
                Some(Json::obj(vec![]))
            } else {
                Some(Json::parse(&req.body).map_err(|e| {
                    Response::error(400, "invalid_json", format!("invalid JSON body: {e}"))
                })?)
            }
        } else {
            None
        };
        Ok(RequestParams { req, body })
    }

    /// The parsed POST body, when this request has one.
    fn body(&self) -> Option<&Json> {
        self.body.as_ref()
    }

    /// True when the request used the deprecated GET+query form.
    fn deprecated_alias(&self) -> bool {
        self.body.is_none()
    }

    /// One parameter as a string from whichever source this request
    /// uses. Body values may be JSON strings or booleans; anything
    /// else is a typed `invalid_param` error.
    fn get(&self, key: &str) -> Result<Option<String>, Response> {
        let Some(body) = &self.body else {
            return Ok(self.req.param(key).map(str::to_string));
        };
        match body.get(key) {
            None | Some(Json::Null) => Ok(None),
            Some(Json::Str(s)) => Ok(Some(s.clone())),
            Some(Json::Bool(b)) => Ok(Some(b.to_string())),
            Some(other) => Err(Response::error(
                400,
                "invalid_param",
                format!("\"{key}\" must be a string or boolean, got {other}"),
            )),
        }
    }

    /// The `backend` parameter (default `auto`), parsed but not yet
    /// resolved — resolution happens at the cache-key seam so `auto`
    /// and its resolution always share a content address.
    fn backend(&self) -> Result<BackendKind, Response> {
        let name = self.get("backend")?;
        BackendKind::parse(name.as_deref().unwrap_or("auto"))
            .map_err(|e| Response::error(400, "invalid_backend", format!("{e:#}")))
    }

    /// The `device` parameter (default `a100`), resolved against the
    /// registry.
    fn device(&self) -> Result<Device, Response> {
        let name = self.get("device")?;
        let name = name.as_deref().unwrap_or("a100");
        device::by_name(name).ok_or_else(|| {
            Response::error(
                404,
                "unknown_device",
                format!("unknown device {name:?}; see /v1/devices"),
            )
        })
    }

    /// The optional per-request compute budget: `deadline_ms` in the
    /// body (JSON number or numeric string) or query string, with the
    /// `X-Deadline-Ms` header as the out-of-band fallback (an in-body
    /// value wins). Zero is legal — an already-expired budget, which
    /// degrades every timing unit to its analytic prediction.
    fn deadline(&self) -> Result<Option<Budget>, Response> {
        fn bad(v: impl std::fmt::Display) -> Response {
            Response::error(
                400,
                "invalid_param",
                format!("bad deadline_ms {v} (a non-negative integer of milliseconds)"),
            )
        }
        let from_str = |s: &str| s.trim().parse::<u64>().map_err(|_| bad(format!("{s:?}")));
        if let Some(body) = &self.body {
            match body.get("deadline_ms") {
                None | Some(Json::Null) => {}
                Some(Json::Str(s)) => return Ok(Some(Budget::from_ms(from_str(s)?))),
                Some(v) => {
                    // as_u64 saturates negatives and truncates
                    // fractions; validate on the f64 instead
                    let n = v.as_f64().ok_or_else(|| bad(v))?;
                    if n < 0.0 || n.fract() != 0.0 {
                        return Err(bad(v));
                    }
                    return Ok(Some(Budget::from_ms(n as u64)));
                }
            }
        } else if let Some(s) = self.req.param("deadline_ms") {
            return Ok(Some(Budget::from_ms(from_str(s)?)));
        }
        match self.req.header("x-deadline-ms") {
            Some(s) => Ok(Some(Budget::from_ms(from_str(s)?))),
            None => Ok(None),
        }
    }
}

/// Add the `Deprecation` header when the request came in through the
/// GET+query alias.
fn deprecate(response: Response, params: &RequestParams) -> Response {
    if params.deprecated_alias() {
        response.with_header("Deprecation", "true")
    } else {
        response
    }
}

fn method_not_allowed(method: &str, hint: &str) -> Response {
    Response::error(405, "method_not_allowed", format!("method {method} not allowed; {hint}"))
}

fn endpoint_label(path: &str) -> &'static str {
    match path {
        "/healthz" => "healthz",
        "/readyz" => "readyz",
        "/v1/experiments" => "experiments",
        "/v1/devices" => "devices",
        "/v1/metrics" => "metrics",
        "/metrics" => "prometheus",
        "/v1/sweep" => "sweep",
        "/v1/plan" => "plan",
        "/v1/lint" => "lint",
        "/v1/tune" => "tune",
        p if p.starts_with("/v1/run/") => "run",
        _ => "other",
    }
}

/// Dispatch one parsed request, recording its count and end-to-end
/// latency under the endpoint label.
pub fn handle(state: &AppState, req: &Request) -> Response {
    let label = endpoint_label(&req.path);
    state.metrics.record_request(label);
    let t0 = Instant::now();
    let response = route(state, req);
    state.metrics.record_latency(label, t0.elapsed().as_micros() as u64);
    response
}

fn route(state: &AppState, req: &Request) -> Response {
    match dispatch(state, req) {
        Ok(r) | Err(r) => r,
    }
}

fn dispatch(state: &AppState, req: &Request) -> Result<Response, Response> {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/plan") => plan(state, req),
        (m, "/v1/plan") => Err(method_not_allowed(m, "/v1/plan takes a POST with a JSON body")),
        ("POST", "/v1/lint") => lint(state, req),
        (m, "/v1/lint") => Err(method_not_allowed(m, "/v1/lint takes a POST with a JSON body")),
        ("POST", "/v1/tune") => tune(state, req),
        (m, "/v1/tune") => Err(method_not_allowed(m, "/v1/tune takes a POST with a JSON body")),
        ("GET" | "POST", "/v1/sweep") => sweep(state, req),
        (m, "/v1/sweep") => {
            Err(method_not_allowed(m, "/v1/sweep takes a POST body (or the deprecated GET form)"))
        }
        ("GET", "/healthz") => Ok(healthz()),
        ("GET", "/readyz") => Ok(readyz(state)),
        ("GET", "/v1/experiments") => Ok(experiments(state)),
        ("GET", "/v1/devices") => Ok(devices()),
        ("GET", "/v1/metrics") => Ok(metrics(state)),
        ("GET", "/metrics") => Ok(prometheus(state)),
        ("GET" | "POST", p) if p.starts_with("/v1/run/") => {
            run(state, req, &p["/v1/run/".len()..])
        }
        (m, p) if p.starts_with("/v1/run/") => {
            Err(method_not_allowed(m, "/v1/run takes a POST body (or the deprecated GET form)"))
        }
        ("GET", other) => Err(Response::error(404, "not_found", format!("no route for {other:?}"))),
        (m, _) => Err(method_not_allowed(m, "this API serves GET and POST only")),
    }
}

fn healthz() -> Response {
    Response::ok(Json::obj(vec![
        ("status", Json::str("ok")),
        ("service", Json::str("tcserved")),
        ("version", Json::str(env!("CARGO_PKG_VERSION"))),
        ("experiments", Json::num(EXPERIMENTS.len() as f64)),
    ]))
}

/// `GET /readyz` — readiness, as distinct from `/healthz` liveness: a
/// replica that is still warming its experiment cache or whose accept
/// queue is saturated answers `503 not_ready` with `Retry-After`, so
/// load balancers steer traffic away without restarting the process.
fn readyz(state: &AppState) -> Response {
    let warming = state.readiness.warming();
    let saturated = state.readiness.saturated();
    if warming || saturated {
        let reason = if warming {
            "warming the experiment cache"
        } else {
            "accept queue saturated"
        };
        return Response::error(503, "not_ready", reason.to_string())
            .with_header("Retry-After", "1");
    }
    Response::ok(Json::obj(vec![
        ("status", Json::str("ready")),
        ("queue_len", Json::num(state.readiness.queue_len() as f64)),
        ("queue_capacity", Json::num(state.readiness.queue_capacity() as f64)),
    ]))
}

fn experiments(state: &AppState) -> Response {
    // report cache state for the default-backend key (auto, resolved —
    // the same key a parameterless /v1/run/<id> uses)
    let default_backend = BackendKind::Auto.resolve();
    let list: Vec<Json> = EXPERIMENTS
        .iter()
        .map(|e| {
            let key = cache_key(e.id, default_backend.name(), "-", "-");
            Json::obj(vec![
                ("id", Json::str(e.id)),
                ("description", Json::str(e.description)),
                ("kind", Json::str(if e.numeric { "numeric" } else { "sim" })),
                ("cached", Json::Bool(state.cache.contains(&key))),
                ("url", Json::Str(format!("/v1/run/{}", e.id))),
            ])
        })
        .collect();
    Response::ok(Json::obj(vec![
        ("count", Json::num(EXPERIMENTS.len() as f64)),
        ("experiments", Json::Arr(list)),
    ]))
}

fn devices() -> Response {
    let list: Vec<Json> = device::registry()
        .into_iter()
        .map(|d| {
            Json::obj(vec![
                ("name", Json::str(d.name)),
                ("product", Json::str(d.product)),
                ("arch", Json::Str(format!("{:?}", d.arch))),
                ("sms", Json::num(d.sms as f64)),
                ("tensor_cores_per_sm", Json::num(d.arch.tensor_cores_per_sm() as f64)),
                ("supports_sparse", Json::Bool(d.arch.supports_sparse())),
                ("supports_ldmatrix", Json::Bool(d.arch.supports_ldmatrix())),
                ("supports_fp8", Json::Bool(d.supports_fp8())),
            ])
        })
        .collect();
    Response::ok(Json::obj(vec![("devices", Json::Arr(list))]))
}

fn metrics(state: &AppState) -> Response {
    let mut json = state.metrics.to_json(state.cache.stats());
    if let Json::Obj(fields) = &mut json {
        fields.insert("shards".to_string(), state.shards.to_json());
    }
    Response::ok(json)
}

/// `GET /metrics` — every counter, gauge and histogram in the
/// Prometheus text exposition format (the same values `/v1/metrics`
/// reports as JSON, so the two always agree).
fn prometheus(state: &AppState) -> Response {
    let mut body = state.metrics.to_prometheus(state.cache.stats());
    body.push_str(&state.shards.to_prometheus());
    Response::text(200, "text/plain; version=0.0.4", body)
}

fn note_origin(state: &AppState, origin: Origin) {
    match origin {
        Origin::Memory | Origin::Disk => state.metrics.record_hit(),
        Origin::Computed => state.metrics.record_miss(),
        Origin::Coalesced => state.metrics.record_coalesced(),
    }
}

/// Wrap a cached payload for the wire: the payload is the content-addressed
/// value; `cached`/`origin` describe how this particular request got it.
/// Re-serializing the payload is the `render` phase.
fn respond_cached(
    state: &AppState,
    result: Result<String, String>,
    origin: Origin,
) -> Result<Response, Response> {
    match result {
        Ok(body) => {
            let t0 = Instant::now();
            let inner = Json::parse(&body).unwrap_or(Json::Str(body));
            let response = Response::ok(Json::obj(vec![
                ("cached", Json::Bool(origin != Origin::Computed)),
                ("origin", Json::str(origin.name())),
                ("result", inner),
            ]));
            state.metrics.record_phase("render", t0.elapsed().as_micros() as u64);
            Ok(response)
        }
        Err(e) => Err(Response::error(500, "internal", e)),
    }
}

/// Map a unit-compute error string onto its typed response: the
/// [`DEADLINE_SENTINEL`] prefix marks a deadline failure that must
/// answer `504 deadline_exceeded`; everything else is `500 internal`.
fn unit_error_response(e: String) -> Response {
    match e.strip_prefix(DEADLINE_SENTINEL) {
        Some(msg) => Response::error(504, "deadline_exceeded", msg.to_string()),
        None => Response::error(500, "internal", e),
    }
}

// ------------------------------------------------------------ /v1/run/<id>

/// `/v1/run/<id>` — POST `{"backend": ...}` (or the deprecated
/// `GET ?backend=` alias). Both forms parse through [`RequestParams`]
/// and key the cache by the *resolved* backend, so `auto` and its
/// resolution always share one entry.
fn run(state: &AppState, req: &Request, id: &str) -> Result<Response, Response> {
    let params = RequestParams::parse(req)?;
    let Some(exp) = coordinator::experiment(id) else {
        return Err(Response::error(
            404,
            "unknown_experiment",
            format!("unknown experiment {id:?}; see /v1/experiments for the registry"),
        ));
    };
    // default matches the CLI: `auto` (pjrt when artifacts exist, else
    // native); the cache key uses whatever it resolves to
    let kind = params.backend()?;
    let (result, origin) = run_cached(state, exp, kind);
    respond_cached(state, result, origin).map(|r| deprecate(r, &params))
}

/// Cached execution of one experiment — shared by the HTTP handler and
/// `--warm` precomputation.
pub fn run_cached(
    state: &AppState,
    exp: &'static ExperimentId,
    kind: BackendKind,
) -> (Result<String, String>, Origin) {
    // `auto` is keyed as whatever it resolves to, so its cache entries
    // are shared with the concrete backend and never go stale when the
    // environment (artifact availability) changes.
    let kind = kind.resolve();
    let key = cache_key(exp.id, kind.name(), "-", "-");
    let t0 = Instant::now();
    let (result, origin) =
        state.cache.get_or_compute(&key, || compute_experiment(state, exp, kind, &key));
    // a served-from-cache request's whole cost is the lookup; computed
    // requests record their cost as the `simulate` phase instead
    if origin != Origin::Computed {
        state.metrics.record_phase("cache_lookup", t0.elapsed().as_micros() as u64);
    }
    note_origin(state, origin);
    (result, origin)
}

fn compute_experiment(
    state: &AppState,
    exp: &'static ExperimentId,
    kind: BackendKind,
    key: &CacheKey,
) -> Result<String, String> {
    let t0 = Instant::now();
    let outcome = catch_unwind(AssertUnwindSafe(|| -> Result<(String, String), String> {
        if crate::chaos::inject(crate::chaos::Site::Sim) == Some(crate::chaos::Failure::SimPanic) {
            panic!("tcchaos: injected sim panic");
        }
        // `kind` is already resolved; the runner is the backend seam for
        // the §8 numeric probes (native softfloat vs PJRT artifacts)
        let runner = workload::runner_for(kind)?;
        let backend_name = kind.name().to_string();
        let text = coordinator::run_experiment(exp.id, runner.as_ref())
            .map_err(|e| format!("{e:#}"))?;
        Ok((backend_name, text))
    }));
    let (backend_name, text) = match outcome {
        Ok(Ok(pair)) => pair,
        Ok(Err(e)) => return Err(e),
        Err(_) => return Err(format!("experiment {} panicked during computation", exp.id)),
    };
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    state.metrics.record_compute(exp.id, ms);
    state.metrics.record_phase("simulate", (ms * 1e3) as u64);
    Ok(Json::obj(vec![
        ("id", Json::str(exp.id)),
        ("backend", Json::Str(backend_name)),
        ("compute_ms", Json::num(ms)),
        ("key", Json::str(key.hash.clone())),
        ("report", report::report_to_json(exp.id, exp.description, &text)),
    ])
    .to_string())
}

/// Precompute every registered experiment through the worker pool so
/// steady-state request latency is cache-bound. Returns how many warmed
/// successfully.
pub fn warm(state: &AppState, threads: usize) -> usize {
    let jobs: Vec<_> = EXPERIMENTS
        .iter()
        .map(|e| move || run_cached(state, e, BackendKind::Auto).0.is_ok())
        .collect();
    // The table experiments parallelize internally; cap the outer pool
    // so warm-up does not oversubscribe the CPU quadratically.
    run_parallel(jobs, threads.min(4)).into_iter().filter(|ok| *ok).count()
}

// ---------------------------------------------------------------- /v1/sweep

/// `/v1/sweep` — a thin translator onto the workload layer. POST a
/// JSON body (`{"instr": ..., "device": ..., "sparse": ...,
/// "backend": ...}`; `workload` is accepted as an alias for `instr`,
/// mirroring `/v1/plan`), or GET with the same names as query
/// parameters (the deprecated alias). The `instr` value accepts any
/// [`Workload`] spec (legacy mma specs included); the sweep runs as a
/// one-unit [`BenchPlan`] on the resolved backend's runner.
fn sweep(state: &AppState, req: &Request) -> Result<Response, Response> {
    let params = RequestParams::parse(req)?;
    let dev = params.device()?;
    let spec = match params.get("instr")? {
        Some(s) => Some(s),
        None => params.get("workload")?,
    };
    let Some(spec) = spec else {
        return Err(Response::error(
            400,
            "invalid_param",
            "missing required parameter `instr` (a workload spec, e.g. bf16,f32,m16n8k16 \
             or ldmatrix,x4)",
        ));
    };
    let parsed =
        Workload::parse_spec(&spec).map_err(|e| Response::error(400, "invalid_plan", e))?;
    let sparse = match params.get("sparse")?.as_deref() {
        None => None,
        Some("1") | Some("true") | Some("yes") => Some(true),
        Some("0") | Some("false") | Some("no") => Some(false),
        Some(other) => {
            return Err(Response::error(
                400,
                "invalid_param",
                format!("bad sparse flag {other:?} (true|false)"),
            ))
        }
    };
    let load = match (sparse, parsed) {
        (None, w) => w,
        (Some(sparse), Workload::Mma { ab, cd, shape } | Workload::MmaSp { ab, cd, shape }) => {
            if sparse {
                Workload::MmaSp { ab, cd, shape }
            } else {
                Workload::Mma { ab, cd, shape }
            }
        }
        (Some(_), w) => {
            return Err(Response::error(
                400,
                "invalid_param",
                format!("the sparse flag only applies to mma workloads, not {}", w.kind()),
            ))
        }
    };
    // the same backend seam as /v1/run and /v1/plan: parsed here,
    // resolved by runner_for, keyed by the runner's name
    let kind = params.backend()?;
    let budget = params.deadline()?;
    let runner = workload::runner_for(kind).map_err(|e| Response::error(500, "internal", e))?;
    let plan = Plan::new(load)
        .device(dev.name)
        .sweep()
        .compile()
        .map_err(|e| Response::error(400, "invalid_plan", e))?;
    // shared content address with the sweep unit of POST /v1/plan: a
    // plan that already swept this workload makes this a cache hit (and
    // vice versa) — the request-specific envelope (device, workload,
    // ptx, …) is added outside the cached payload
    let (result, origin) =
        unit_cached(state, &plan, UnitKind::Sweep, runner.as_ref(), "sweep", budget);
    let body = result.map_err(unit_error_response)?;
    let Ok(Json::Obj(mut fields)) = Json::parse(&body) else {
        return Err(Response::error(
            500,
            "internal",
            format!("corrupt cached sweep payload for {load}"),
        ));
    };
    fields.insert("device".to_string(), Json::str(plan.device.name));
    fields.insert("backend".to_string(), Json::str(runner.name()));
    fields.insert("workload".to_string(), Json::Str(plan.workload.to_spec()));
    fields.insert("instr".to_string(), Json::Str(plan.workload.to_string()));
    if let Some(instr) = plan.workload.mma_instr() {
        fields.insert("ptx".to_string(), Json::Str(instr.ptx()));
        fields.insert("sparse".to_string(), Json::Bool(instr.sparse));
    }
    let t0 = Instant::now();
    let response = Response::ok(Json::obj(vec![
        ("cached", Json::Bool(origin != Origin::Computed)),
        ("origin", Json::str(origin.name())),
        ("result", Json::Obj(fields)),
    ]));
    state.metrics.record_phase("render", t0.elapsed().as_micros() as u64);
    Ok(deprecate(response, &params))
}

// ----------------------------------------------------------------- /v1/plan

/// `POST /v1/plan` — run a JSON [`BenchPlan`] and return the batched
/// unit results. Every unit is content-addressed individually (the
/// token carries all workload parameters and the exec point), so the
/// cache and single-flight machinery apply per workload unit and plans
/// sharing units share work.
fn plan(state: &AppState, req: &Request) -> Result<Response, Response> {
    let params = RequestParams::parse(req)?;
    let empty = Json::obj(vec![]);
    let body = params.body().unwrap_or(&empty);
    let plan = Plan::from_json(body).map_err(|e| Response::error(400, "invalid_plan", e))?;
    let kind = params.backend()?;
    let budget = params.deadline()?;
    let runner = workload::runner_for(kind).map_err(|e| Response::error(500, "internal", e))?;
    let bench = plan.compile().map_err(|e| Response::error(400, "invalid_plan", e))?;

    let bench_ref = &bench;
    let runner_ref: &dyn Runner = runner.as_ref();
    let jobs: Vec<_> = bench
        .units
        .iter()
        .map(|&unit| move || unit_cached(state, bench_ref, unit, runner_ref, "plan", budget))
        .collect();
    let outcomes = run_parallel(jobs, coordinator::default_threads().min(4));

    let mut units = Vec::with_capacity(outcomes.len());
    let mut all_cached = true;
    for (unit, (result, origin)) in bench.units.iter().zip(outcomes) {
        let body = match result {
            Ok(body) => body,
            Err(e) => return Err(unit_error_response(e)),
        };
        all_cached &= origin != Origin::Computed;
        let mut inner = Json::parse(&body).unwrap_or(Json::Str(body));
        let mut entry = vec![
            ("unit", Json::Str(unit.label())),
            ("cached", Json::Bool(origin != Origin::Computed)),
            ("origin", Json::str(origin.name())),
        ];
        // hoist the degradation marker out of the payload into the
        // envelope: `result` stays shape-compatible with the simulated
        // form, and clients check `degraded` next to `cached`/`origin`
        let degraded = match &mut inner {
            Json::Obj(fields) => fields.remove("degraded"),
            _ => None,
        };
        if let Some(marker) = degraded {
            entry.push(("degraded", marker));
        }
        entry.push(("result", inner));
        units.push(Json::obj(entry));
    }
    let t0 = Instant::now();
    let response = Response::ok(Json::obj(vec![
        ("workload", Json::Str(bench.workload.to_spec())),
        ("device", Json::str(bench.device.name)),
        ("backend", Json::str(runner.name())),
        ("cached", Json::Bool(all_cached)),
        ("count", Json::num(units.len() as f64)),
        ("units", Json::Arr(units)),
    ]));
    state.metrics.record_phase("render", t0.elapsed().as_micros() as u64);
    Ok(response)
}

// ----------------------------------------------------------------- /v1/lint

/// `POST /v1/lint` — static analysis only. The body is the same JSON
/// [`Plan`] form `/v1/plan` takes; the response is the tclint
/// diagnostics over every warp program the plan would simulate, without
/// running any simulation. When any Error-severity diagnostic fires the
/// response is a 400 `lint_errors` envelope carrying the full
/// diagnostics as `error.details`; clean (or warnings-only) plans get a
/// 200 data envelope.
fn lint(state: &AppState, req: &Request) -> Result<Response, Response> {
    let params = RequestParams::parse(req)?;
    let empty = Json::obj(vec![]);
    let body = params.body().unwrap_or(&empty);
    let plan = Plan::from_json(body).map_err(|e| Response::error(400, "invalid_plan", e))?;
    let bench = plan.compile().map_err(|e| Response::error(400, "invalid_plan", e))?;
    let t0 = Instant::now();
    let records = bench.lint();
    state.metrics.record_phase("lint", t0.elapsed().as_micros() as u64);
    let errors = records.iter().filter(|r| r.is_error()).count();
    let warnings = records.len() - errors;
    state.metrics.record_lint(errors as u64, warnings as u64);
    let payload = Json::obj(vec![
        ("workload", Json::Str(bench.workload.to_spec())),
        ("device", Json::str(bench.device.name)),
        ("errors", Json::num(errors as f64)),
        ("warnings", Json::num(warnings as f64)),
        ("diagnostics", report::lint_records_to_json(&records)),
    ]);
    if errors > 0 {
        return Err(Response::error_with_details(
            400,
            "lint_errors",
            format!("{errors} lint error(s); see error.details.diagnostics"),
            Some(payload),
        ));
    }
    Ok(Response::ok(payload))
}

// ----------------------------------------------------------------- /v1/tune

/// `POST /v1/tune` — the analytic-first autotuner. The body names a
/// workload spec, a device, and an objective (`min-latency`,
/// `max-throughput`, or `target-occupancy:<warps>`); the closed-form
/// model scores the full legal grid, the top-`top` frontier is
/// confirmed through the cycle-accurate path (cell-cache backed), and
/// the response carries predicted *and* simulated numbers per
/// configuration plus the realized pruning ratio. Model or parameter
/// problems — numeric workloads, unknown objectives, `top` of zero —
/// answer as typed `invalid_param` errors, never panics.
fn tune(state: &AppState, req: &Request) -> Result<Response, Response> {
    let params = RequestParams::parse(req)?;
    let dev = params.device()?;
    let spec = match params.get("workload")? {
        Some(s) => Some(s),
        None => params.get("instr")?,
    };
    let Some(spec) = spec else {
        return Err(Response::error(
            400,
            "invalid_param",
            "missing required parameter `workload` (a spec, e.g. mma fp16 f32 m16n8k16)",
        ));
    };
    let load = Workload::parse_spec(&spec).map_err(|e| Response::error(400, "invalid_plan", e))?;
    let objective = params.get("objective")?.unwrap_or_else(|| "max-throughput".to_string());
    let objective = workload::Objective::parse_spec(&objective)
        .map_err(|e| Response::error(400, "invalid_param", e))?;
    // `top` is numeric, so it is accepted both as a JSON number and as
    // a string (the query-less POST body is the only source here)
    let top = match params.body().and_then(|b| b.get_u64("top")) {
        Some(n) => n as usize,
        None => match params.get("top")? {
            None => workload::DEFAULT_TUNE_TOP_K,
            Some(s) => s.parse().map_err(|_| {
                Response::error(400, "invalid_param", format!("bad top {s:?} (a positive integer)"))
            })?,
        },
    };
    let kind = params.backend()?;
    let budget = params.deadline()?;
    let runner = workload::runner_for(kind).map_err(|e| Response::error(500, "internal", e))?;
    let threads = coordinator::default_threads().min(4);
    let t0 = Instant::now();
    let report =
        workload::tune_workload(&load, &dev, objective, top, runner.name(), threads, budget)
            .map_err(|e| Response::error(400, "invalid_param", e))?;
    state.metrics.record_phase("tune", t0.elapsed().as_micros() as u64);
    state.metrics.record_tune(report.scored as u64, report.confirmed as u64);
    for cfg in &report.configs {
        // unconfirmed (deadline-degraded) configs have no simulated
        // numbers, hence no rel-err sample to record
        if let Some(err) = cfg.latency_rel_err {
            state.metrics.record_tune_rel_err(report.family, err);
        }
    }
    let t0 = Instant::now();
    let response = Response::ok(report.to_json());
    state.metrics.record_phase("render", t0.elapsed().as_micros() as u64);
    Ok(response)
}

/// Cached execution of one plan unit (content-addressed by the unit
/// token, which includes every workload parameter), executed under the
/// gate of the shard owning its content address. `metrics_label`
/// attributes the compute time to the endpoint that paid for it
/// (`"plan"` or `"sweep"`) in `/v1/metrics`.
fn unit_cached(
    state: &AppState,
    bench: &BenchPlan,
    unit: UnitKind,
    runner: &dyn Runner,
    metrics_label: &'static str,
    budget: Option<Budget>,
) -> (Result<String, String>, Origin) {
    let key = cache_key("plan", runner.name(), bench.device.name, &bench.unit_token(&unit));
    let canonical = key.canonical.clone();
    state.shards.run_on(&canonical, || {
        let t0 = Instant::now();
        // degraded payloads are served but never stored (cacheable =
        // false): the content address must always resolve to the
        // bit-exact simulated value, so a later un-budgeted request
        // recomputes instead of inheriting a prediction
        let (result, origin) = state.cache.get_or_compute_with(&key, || {
            compute_unit(state, bench, unit, runner, &key, metrics_label, budget)
        });
        if origin != Origin::Computed {
            state.metrics.record_phase("cache_lookup", t0.elapsed().as_micros() as u64);
        }
        note_origin(state, origin);
        (result, origin)
    })
}

fn compute_unit(
    state: &AppState,
    bench: &BenchPlan,
    unit: UnitKind,
    runner: &dyn Runner,
    key: &CacheKey,
    metrics_label: &'static str,
    budget: Option<Budget>,
) -> Result<(String, bool), String> {
    let t0 = Instant::now();
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        if crate::chaos::inject(crate::chaos::Site::Sim) == Some(crate::chaos::Failure::SimPanic) {
            panic!("tcchaos: injected sim panic");
        }
        workload::run_unit_budgeted(runner, bench, &unit, budget)
    }));
    let run = match outcome {
        Ok(Ok(run)) => run,
        Ok(Err(workload::UnitError::DeadlineExceeded(msg))) => {
            state.metrics.record_deadline_exceeded();
            return Err(format!("{DEADLINE_SENTINEL}{msg}"));
        }
        Ok(Err(workload::UnitError::Failed(e))) => return Err(e),
        Err(_) => {
            return Err(format!(
                "plan unit {} of {} panicked during computation",
                unit.label(),
                bench.workload
            ))
        }
    };
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    state.metrics.record_compute(metrics_label, ms);
    state.metrics.record_phase("simulate", (ms * 1e3) as u64);
    let (output, degraded) = match run {
        UnitRun::Simulated(output) => (output, None),
        UnitRun::Degraded { output, reason, within_calibration } => {
            state.metrics.record_degraded(bench.workload.kind());
            let marker = Json::obj(vec![
                ("reason", Json::Str(reason)),
                ("predicted", Json::Bool(true)),
                ("within_calibration", Json::Bool(within_calibration)),
            ]);
            (output, Some(marker))
        }
    };
    let Json::Obj(mut fields) = report::unit_output_to_json(&output) else {
        unreachable!("unit_output_to_json returns an object")
    };
    fields.insert("compute_ms".to_string(), Json::num(ms));
    fields.insert("key".to_string(), Json::str(key.hash.clone()));
    let cacheable = degraded.is_none();
    if let Some(marker) = degraded {
        fields.insert("degraded".to_string(), marker);
    }
    Ok((Json::Obj(fields).to_string(), cacheable))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> AppState {
        AppState::new(ResultCache::new(32, None))
    }

    fn get(state: &AppState, target: &str) -> Response {
        let (path, query_raw) = match target.split_once('?') {
            Some((p, q)) => (p, Some(q)),
            None => (target, None),
        };
        let query = query_raw
            .map(|q| {
                q.split('&')
                    .filter(|p| !p.is_empty())
                    .map(|p| {
                        let (k, v) = p.split_once('=').unwrap_or((p, ""));
                        (k.to_string(), v.to_string())
                    })
                    .collect()
            })
            .unwrap_or_default();
        let req = Request {
            method: "GET".to_string(),
            path: path.to_string(),
            query,
            headers: vec![],
            body: String::new(),
        };
        handle(state, &req)
    }

    fn post(state: &AppState, path: &str, body: &str) -> Response {
        let req = Request {
            method: "POST".to_string(),
            path: path.to_string(),
            query: vec![],
            headers: vec![],
            body: body.to_string(),
        };
        handle(state, &req)
    }

    /// Unwrap the success envelope, pinning its shape.
    fn data(r: &Response) -> Json {
        let j = Json::parse(&r.body).unwrap();
        assert_eq!(j.get_str("schema"), Some("tcserved/v1"), "{}", r.body);
        assert!(j.get("error").is_none(), "unexpected error envelope: {}", r.body);
        j.get("data").cloned().unwrap_or_else(|| panic!("no data field in {}", r.body))
    }

    /// Unwrap the error envelope, pinning its shape.
    fn error_of(r: &Response) -> Json {
        let j = Json::parse(&r.body).unwrap();
        assert_eq!(j.get_str("schema"), Some("tcserved/v1"), "{}", r.body);
        assert!(j.get("data").is_none(), "unexpected data envelope: {}", r.body);
        j.get("error").cloned().unwrap_or_else(|| panic!("no error field in {}", r.body))
    }

    fn is_deprecated(r: &Response) -> bool {
        r.headers.iter().any(|(n, v)| *n == "Deprecation" && v == "true")
    }

    #[test]
    fn healthz_and_registry_endpoints() {
        let s = state();
        let r = get(&s, "/healthz");
        assert_eq!(r.status, 200);
        assert_eq!(data(&r).get_str("status"), Some("ok"));

        let r = get(&s, "/v1/experiments");
        let j = data(&r);
        assert_eq!(j.get_u64("count"), Some(19));
        assert_eq!(
            j.get("experiments").unwrap().as_arr().unwrap()[2].get_str("id"),
            Some("t3")
        );

        let r = get(&s, "/v1/devices");
        let j = data(&r);
        let devices = j.get("devices").unwrap().as_arr().unwrap();
        assert_eq!(devices.len(), 4);
        // the projected Hopper target is addressable and fp8-capable
        let hopper = devices
            .iter()
            .find(|d| d.get_str("name") == Some("hopper-projected"))
            .expect("hopper-projected registered");
        assert_eq!(hopper.get("supports_fp8").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn every_endpoint_answers_in_the_versioned_envelope() {
        let s = state();
        // success envelopes: schema + data, no error
        for target in ["/healthz", "/v1/experiments", "/v1/devices", "/v1/metrics"] {
            let r = get(&s, target);
            assert_eq!(r.status, 200, "{target}");
            data(&r);
        }
        // error envelopes: schema + typed code + message + status
        for (target, status, code) in [
            ("/nope", 404, "not_found"),
            ("/v1/run/t99", 404, "unknown_experiment"),
            ("/v1/sweep?device=h100&instr=ldmatrix,x1", 404, "unknown_device"),
            ("/v1/sweep", 400, "invalid_param"),
            ("/v1/sweep?instr=garbage", 400, "invalid_plan"),
            ("/v1/run/t10?backend=cuda", 400, "invalid_backend"),
        ] {
            let r = get(&s, target);
            assert_eq!(r.status, status, "{target}: {}", r.body);
            let e = error_of(&r);
            assert_eq!(e.get_str("code"), Some(code), "{target}: {}", r.body);
            assert!(e.get_str("message").is_some(), "{target}");
            assert_eq!(e.get_u64("status"), Some(status as u64), "{target}");
        }
        // typed codes on POST bodies too
        let r = post(&s, "/v1/plan", "{not json");
        assert_eq!(error_of(&r).get_str("code"), Some("invalid_json"));
        let r = post(&s, "/v1/tune", r#"{"workload":"ldmatrix x4","objective":"bogus"}"#);
        assert_eq!(r.status, 400, "{}", r.body);
        assert_eq!(error_of(&r).get_str("code"), Some("invalid_param"));
        let r = post(&s, "/healthz", "");
        assert_eq!(r.status, 405);
        assert_eq!(error_of(&r).get_str("code"), Some("method_not_allowed"));
        // the Prometheus text exposition is the one deliberate exception
        let r = get(&s, "/metrics");
        assert!(r.content_type.starts_with("text/plain"), "{}", r.content_type);
        assert!(!r.body.contains("tcserved/v1"));
    }

    #[test]
    fn unknown_routes_and_methods() {
        let s = state();
        assert_eq!(get(&s, "/nope").status, 404);
        assert_eq!(get(&s, "/v1/run/t99").status, 404);
        assert_eq!(post(&s, "/healthz", "").status, 405);
        // /v1/plan is POST-only
        assert_eq!(get(&s, "/v1/plan").status, 405);
    }

    #[test]
    fn run_caches_by_content_address() {
        let s = state();
        let r1 = get(&s, "/v1/run/t10");
        assert_eq!(r1.status, 200, "{}", r1.body);
        let j1 = data(&r1);
        assert_eq!(j1.get("cached").and_then(Json::as_bool), Some(false));
        assert_eq!(j1.get("result").unwrap().get_str("id"), Some("t10"));

        let r2 = get(&s, "/v1/run/t10");
        let j2 = data(&r2);
        assert_eq!(j2.get("cached").and_then(Json::as_bool), Some(true));
        assert_eq!(j2.get_str("origin"), Some("memory"));

        // `auto` resolves to native here (no PJRT offline), so it shares
        // the native content address and hits the same cache entry
        let r3 = get(&s, "/v1/run/t10?backend=auto");
        let j3 = data(&r3);
        assert_eq!(j3.get("cached").and_then(Json::as_bool), Some(true));

        let m = data(&get(&s, "/v1/metrics"));
        let t10 = m.get("experiments").unwrap().get("t10").unwrap();
        assert_eq!(t10.get_u64("computes"), Some(1)); // auto coalesced onto native
        assert_eq!(m.get("cache").unwrap().get_u64("hits"), Some(2));
    }

    #[test]
    fn run_post_body_and_get_query_share_the_resolved_backend_key() {
        let s = state();
        // explicit native via the deprecated GET alias...
        let r = get(&s, "/v1/run/t10?backend=native");
        assert_eq!(r.status, 200, "{}", r.body);
        assert!(is_deprecated(&r), "GET alias must answer Deprecation");
        // ...then `auto` via the canonical POST body: resolves to
        // native, shares the content address, pure cache hit
        let r2 = post(&s, "/v1/run/t10", r#"{"backend":"auto"}"#);
        assert_eq!(r2.status, 200, "{}", r2.body);
        assert!(!is_deprecated(&r2), "POST form is canonical");
        let j2 = data(&r2);
        assert_eq!(j2.get("cached").and_then(Json::as_bool), Some(true), "{}", r2.body);
        assert_eq!(j2.get_str("origin"), Some("memory"));
        // an empty POST body is legal: all defaults (backend auto)
        let r3 = post(&s, "/v1/run/t10", "");
        assert_eq!(data(&r3).get("cached").and_then(Json::as_bool), Some(true), "{}", r3.body);
    }

    #[test]
    fn sweep_accepts_post_bodies_and_deprecates_the_get_alias() {
        let s = state();
        let r = post(&s, "/v1/sweep", r#"{"instr":"ldmatrix x2","device":"a100"}"#);
        assert_eq!(r.status, 200, "{}", r.body);
        assert!(!is_deprecated(&r));
        let d = data(&r);
        assert_eq!(d.get("result").unwrap().get_str("workload"), Some("ldmatrix x2"));
        assert_eq!(d.get("result").unwrap().get_str("backend"), Some("sim"));

        // the GET+query alias resolves identically — same content
        // address, so the POSTed sweep is already cached — and answers
        // with the Deprecation header
        let r2 = get(&s, "/v1/sweep?device=a100&instr=ldmatrix,x2");
        assert_eq!(r2.status, 200, "{}", r2.body);
        assert!(is_deprecated(&r2), "{:?}", r2.headers);
        assert_eq!(data(&r2).get("cached").and_then(Json::as_bool), Some(true), "{}", r2.body);

        // `workload` is accepted as an alias for `instr` (mirroring
        // /v1/plan), and `auto` shares the resolved backend's key
        let r3 = post(&s, "/v1/sweep", r#"{"workload":"ldmatrix x2","backend":"auto"}"#);
        assert_eq!(r3.status, 200, "{}", r3.body);
        assert_eq!(data(&r3).get("cached").and_then(Json::as_bool), Some(true), "{}", r3.body);

        // body params are typed
        let r4 = post(&s, "/v1/sweep", r#"{"instr":"ldmatrix x2","backend":[1]}"#);
        assert_eq!(r4.status, 400);
        assert_eq!(error_of(&r4).get_str("code"), Some("invalid_param"));
    }

    #[test]
    fn prometheus_endpoint_serves_text_exposition() {
        let s = state();
        // drive some traffic so the counters are non-trivial
        assert_eq!(get(&s, "/healthz").status, 200);
        assert_eq!(get(&s, "/v1/sweep?device=a100&instr=ldmatrix,x1").status, 200);
        assert_eq!(get(&s, "/v1/sweep?device=a100&instr=ldmatrix,x1").status, 200);

        // snapshot the JSON counters, then render Prometheus from the
        // same state (the /v1/metrics request itself bumps the counters,
        // so read the JSON response body, not a second scrape)
        let json = data(&get(&s, "/v1/metrics"));
        let r = get(&s, "/metrics");
        assert_eq!(r.status, 200, "{}", r.body);
        assert_eq!(r.content_type, "text/plain; version=0.0.4");

        // the JSON snapshot already counts its own request (recorded
        // before routing), so the later /metrics scrape is one ahead
        let expect_total = json.get_u64("requests_total").unwrap() + 1;
        assert!(
            r.body.contains(&format!("tcserved_requests_total {expect_total}")),
            "{}",
            r.body
        );
        let hits = json.get("cache").unwrap().get_u64("hits").unwrap();
        assert!(
            r.body.contains(&format!("tcserved_result_cache_hits_total {hits}")),
            "{}",
            r.body
        );
        let sweeps = json.get("by_endpoint").unwrap().get_u64("sweep").unwrap();
        assert!(r
            .body
            .contains(&format!("tcserved_endpoint_requests_total{{endpoint=\"sweep\"}} {sweeps}")));
        // phase histograms recorded: a computed sweep (simulate+render)
        // and a cached one (cache_lookup+render)
        for phase in ["simulate", "cache_lookup", "render"] {
            assert!(
                r.body.contains(&format!("phase_duration_us_count{{phase=\"{phase}\"}}")),
                "missing {phase} histogram:\n{}",
                r.body
            );
        }
        // request-latency histogram per endpoint label
        assert!(r.body.contains("tcserved_request_duration_us_bucket{endpoint=\"sweep\",le="));
    }

    #[test]
    fn metrics_report_cell_store_and_shard_sections() {
        let s = state();
        let r = post(
            &s,
            "/v1/plan",
            r#"{"workload":"ld.shared u32 4","device":"a100","points":[[1,1]],
                "completion_latency":true,"backend":"native"}"#,
        );
        assert_eq!(r.status, 200, "{}", r.body);
        let m = data(&get(&s, "/v1/metrics"));
        // the cell_store section exists even with no store attached
        // (enabled=false), so dashboards need no conditional scrape
        let store = m.get("cell_store").expect("cell_store section");
        assert!(store.get("enabled").and_then(Json::as_bool).is_some(), "{store}");
        for field in ["hits", "misses", "writes", "corrupt"] {
            assert!(store.get_u64(field).is_some(), "missing cell_store.{field}: {store}");
        }
        // the default router is one shard hosting everything; the two
        // plan units above executed under its gate
        let shards = m.get("shards").expect("shards section");
        assert_eq!(shards.get_u64("replicas"), Some(1));
        assert_eq!(shards.get_u64("forwarded_units"), Some(0));
        let units = shards.get("units").unwrap().as_arr().unwrap();
        assert_eq!(units.len(), 1);
        assert_eq!(units[0].as_u64(), Some(2), "{shards}");
        // and the Prometheus rendering carries the same series
        let p = get(&s, "/metrics").body;
        assert!(p.contains("tcserved_shard_units_total{shard=\"0\"} 2"), "{p}");
        assert!(p.contains("tcserved_shard_forwarded_units_total 0"), "{p}");
        assert!(p.contains("tcserved_cell_store_hits_total"), "{p}");
    }

    #[test]
    fn multi_shard_router_partitions_units_and_counts_forwarding() {
        let body = r#"{"workload":"ld.shared u32 4","device":"a100",
                       "points":[[1,1],[2,1],[4,1],[8,1]],"backend":"native"}"#;
        // one process hosting all three shards: units partition across
        // the per-shard gates, nothing is foreign
        let s = AppState::with_shards(ResultCache::new(32, None), ShardRouter::new(3, None, 4));
        assert_eq!(post(&s, "/v1/plan", body).status, 200);
        let shards = data(&get(&s, "/v1/metrics")).get("shards").cloned().unwrap();
        assert_eq!(shards.get_u64("replicas"), Some(3));
        assert_eq!(shards.get_u64("forwarded_units"), Some(0));
        let units: Vec<u64> = shards
            .get("units")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|u| u.as_u64().unwrap())
            .collect();
        assert_eq!(units.iter().sum::<u64>(), 4, "{shards}");

        // the same traffic into a process that *is* shard 0 of the
        // fleet: foreign-owned units are answered but counted forwarded
        let s = AppState::with_shards(ResultCache::new(32, None), ShardRouter::new(3, Some(0), 4));
        assert_eq!(post(&s, "/v1/plan", body).status, 200);
        let shards = data(&get(&s, "/v1/metrics")).get("shards").cloned().unwrap();
        assert_eq!(shards.get_u64("local"), Some(0));
        let units: Vec<u64> = shards
            .get("units")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|u| u.as_u64().unwrap())
            .collect();
        assert_eq!(units.iter().sum::<u64>(), 4);
        assert_eq!(shards.get_u64("forwarded_units"), Some(units[1] + units[2]), "{shards}");
    }

    #[test]
    fn sweep_validation() {
        let s = state();
        assert_eq!(get(&s, "/v1/sweep").status, 400);
        assert_eq!(get(&s, "/v1/sweep?instr=garbage").status, 400);
        assert_eq!(get(&s, "/v1/sweep?device=h100&instr=bf16,f32,m16n8k16").status, 404);
        // Turing has no sparse support
        assert_eq!(
            get(&s, "/v1/sweep?device=rtx2080ti&instr=fp16,f32,m16n8k16,sparse").status,
            400
        );
        assert_eq!(
            get(&s, "/v1/sweep?device=a100&instr=bf16,f32,m16n8k16&sparse=maybe").status,
            400
        );
    }

    #[test]
    fn sweep_returns_full_grid_and_caches() {
        let s = state();
        let r = get(&s, "/v1/sweep?device=a100&instr=bf16,f32,m16n8k16");
        assert_eq!(r.status, 200, "{}", r.body);
        let j = data(&r);
        let result = j.get("result").unwrap();
        assert_eq!(result.get_str("device"), Some("a100"));
        assert_eq!(result.get_str("workload"), Some("mma bf16 f32 m16n8k16"));
        assert_eq!(result.get("cells").unwrap().as_arr().unwrap().len(), 48);
        assert_eq!(result.get("convergence").unwrap().as_arr().unwrap().len(), 2);
        let peak = result.get_f64("peak_throughput").unwrap();
        assert!((960.0..1030.0).contains(&peak), "peak {peak}");

        let r2 = get(&s, "/v1/sweep?device=a100&instr=bf16,f32,m16n8k16");
        let j2 = data(&r2);
        assert_eq!(j2.get("cached").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn sweep_accepts_every_workload_kind() {
        // the endpoint is a thin translator onto the workload layer, so
        // data-movement sweeps work through the same route
        let s = state();
        let r = get(&s, "/v1/sweep?device=a100&instr=ldmatrix,x1");
        assert_eq!(r.status, 200, "{}", r.body);
        let j = data(&r);
        assert_eq!(j.get("result").unwrap().get_str("workload"), Some("ldmatrix x1"));
        // sparse flag is mma-only
        assert_eq!(get(&s, "/v1/sweep?device=a100&instr=ldmatrix,x1&sparse=true").status, 400);
    }

    #[test]
    fn sweep_endpoint_shares_cache_with_plan_sweep_units() {
        let s = state();
        // a plan's sweep unit computes the grid once...
        let body = r#"{"workload":"ldmatrix x2","device":"a100","sweep":true,"backend":"native"}"#;
        let r = post(&s, "/v1/plan", body);
        assert_eq!(r.status, 200, "{}", r.body);
        // ...and the sweep endpoint reuses it (same per-unit content address)
        let r2 = get(&s, "/v1/sweep?device=a100&instr=ldmatrix,x2");
        let j2 = data(&r2);
        assert_eq!(j2.get("cached").and_then(Json::as_bool), Some(true), "{}", r2.body);
        assert_eq!(
            j2.get("result").unwrap().get("cells").unwrap().as_arr().unwrap().len(),
            48
        );
    }

    #[test]
    fn plan_endpoint_caches_per_unit() {
        let s = state();
        let body = r#"{"workload":"ld.shared u32 4","device":"a100",
                       "points":[[1,1]],"completion_latency":true,"backend":"native"}"#;
        let r = post(&s, "/v1/plan", body);
        assert_eq!(r.status, 200, "{}", r.body);
        let j = data(&r);
        assert_eq!(j.get_str("workload"), Some("ld.shared u32 4"));
        assert_eq!(j.get_str("backend"), Some("sim"));
        assert_eq!(j.get("cached").and_then(Json::as_bool), Some(false));
        let units = j.get("units").unwrap().as_arr().unwrap();
        assert_eq!(units.len(), 2);
        assert!(units.iter().all(|u| u.get("cached").and_then(Json::as_bool) == Some(false)));

        // identical plan: every unit is served from the cache
        let r2 = post(&s, "/v1/plan", body);
        let j2 = data(&r2);
        assert_eq!(j2.get("cached").and_then(Json::as_bool), Some(true));
        let units2 = j2.get("units").unwrap().as_arr().unwrap();
        assert!(units2.iter().all(|u| u.get("cached").and_then(Json::as_bool) == Some(true)));

        // a plan differing only in ILP misses the cache (the exec point
        // is part of the content address)
        let body_ilp2 = r#"{"workload":"ld.shared u32 4","device":"a100",
                            "points":[[1,2]],"backend":"native"}"#;
        let r3 = post(&s, "/v1/plan", body_ilp2);
        let j3 = data(&r3);
        let units3 = j3.get("units").unwrap().as_arr().unwrap();
        assert_eq!(units3[0].get_str("origin"), Some("computed"), "{}", r3.body);
    }

    #[test]
    fn sweep_then_point_reports_cell_cache_hits() {
        use crate::workload::{CellCache, ExecPoint};
        let s = state();
        // the sweep unit simulates (among others) cell (4,2) of this
        // workload through the process-wide cell cache…
        let sweep_body = r#"{"workload":"mma.sp bf16 f32 m16n8k32","device":"rtx3070ti",
                             "sweep":true,"backend":"native"}"#;
        let r = post(&s, "/v1/plan", sweep_body);
        assert_eq!(r.status, 200, "{}", r.body);
        // deterministic population check (the counters below are
        // process-global, so concurrent tests also move them)
        assert!(CellCache::global().contains(
            "mma.sp bf16 f32 m16n8k32",
            "rtx3070ti",
            ExecPoint::new(4, 2),
            "sim"
        ));
        let m = data(&get(&s, "/v1/metrics"));
        let hits_before = m.get("cell_cache").unwrap().get_u64("hits").unwrap();

        // …so the later point unit — a *miss* in the per-unit result
        // cache (different unit token) — is a cell-cache hit and costs
        // no simulation
        let point_body = r#"{"workload":"mma.sp bf16 f32 m16n8k32","device":"rtx3070ti",
                             "points":[[4,2]],"backend":"native"}"#;
        let r2 = post(&s, "/v1/plan", point_body);
        assert_eq!(r2.status, 200, "{}", r2.body);
        let j2 = data(&r2);
        let units = j2.get("units").unwrap().as_arr().unwrap();
        assert_eq!(units[0].get_str("origin"), Some("computed"), "{}", r2.body);

        let m = data(&get(&s, "/v1/metrics"));
        let cells = m.get("cell_cache").unwrap();
        let hits_after = cells.get_u64("hits").unwrap();
        assert!(
            hits_after > hits_before,
            "point after sweep must hit the cell cache ({hits_before} -> {hits_after})"
        );
        // the sweep itself simulated a full grid's worth of cells
        assert!(cells.get_u64("cells_simulated").unwrap() >= 48);
    }

    #[test]
    fn plan_endpoint_accepts_gemm_specs() {
        let s = state();
        let body = r#"{"workload":"gemm pipeline bf16 f32 256 128x128x32","device":"a100",
                       "points":[[8,2]],"backend":"native"}"#;
        let r = post(&s, "/v1/plan", body);
        assert_eq!(r.status, 200, "{}", r.body);
        let j = data(&r);
        assert_eq!(j.get_str("workload"), Some("gemm pipeline bf16 f32 256 128x128x32"));
        let units = j.get("units").unwrap().as_arr().unwrap();
        assert_eq!(units.len(), 1);
        let result = units[0].get("result").unwrap();
        assert!(result.get_f64("throughput").unwrap() > 0.0, "{result}");

        // an invalid tile is a 400 with an actionable error, not a 500
        let bad = r#"{"workload":"gemm pipeline bf16 f32 256 100x128x32","points":[[8,2]]}"#;
        let r = post(&s, "/v1/plan", bad);
        assert_eq!(r.status, 400, "{}", r.body);
        let err = error_of(&r);
        assert_eq!(err.get_str("code"), Some("invalid_plan"));
        assert!(err.get_str("message").unwrap().contains("tile_m"), "{}", r.body);

        // the sparse flag stays mma-only on the sweep translator
        let r = get(
            &s,
            "/v1/sweep?device=a100&instr=gemm,pipeline,bf16,f32,256,128x128x32&sparse=true",
        );
        assert_eq!(r.status, 400, "{}", r.body);
    }

    #[test]
    fn numeric_specs_flow_through_plan_and_sweep_routes() {
        let s = state();
        // a profile probe as a (1,1) point unit
        let body = r#"{"workload":"numeric profile fp16 f32 mul low","points":[[1,1]],
                       "backend":"native"}"#;
        let r = post(&s, "/v1/plan", body);
        assert_eq!(r.status, 200, "{}", r.body);
        let j = data(&r);
        assert_eq!(j.get_str("workload"), Some("numeric profile fp16 f32 mul low"));
        let units = j.get("units").unwrap().as_arr().unwrap();
        let result = units[0].get("result").unwrap();
        assert_eq!(result.get_str("unit"), Some("numeric"));
        assert_eq!(result.get_str("probe"), Some("profile"));
        // Table 13: zero error under low-precision init
        assert_eq!(result.get_f64("mean_abs_err"), Some(0.0), "{result}");

        // the sweep route accepts numeric specs (chain-step x init grid)
        let r = get(&s, "/v1/sweep?device=a100&instr=numeric,chain,tf32,f32,5");
        assert_eq!(r.status, 200, "{}", r.body);
        let j = data(&r);
        let result = j.get("result").unwrap();
        assert_eq!(result.get("cells").unwrap().as_arr().unwrap().len(), 10);
        assert_eq!(result.get_str("workload"), Some("numeric chain tf32 f32 5 low"));

        // invalid probes are 400s: fp8 on a non-fp8 device, off-(1,1)
        // points, completion probes
        for bad in [
            r#"{"workload":"numeric profile fp8e4m3 f32 mul","points":[[1,1]]}"#,
            r#"{"workload":"numeric profile bf16 f32 acc","points":[[4,1]]}"#,
            r#"{"workload":"numeric chain tf32 f32 5","completion_latency":true}"#,
        ] {
            let r = post(&s, "/v1/plan", bad);
            assert_eq!(r.status, 400, "{bad}: {}", r.body);
        }
        // ...while the fp8 probe is valid on the projected Hopper device
        let fp8 = r#"{"workload":"numeric profile fp8e4m3 f32 mul","points":[[1,1]],
                      "device":"hopper-projected","backend":"native"}"#;
        let r = post(&s, "/v1/plan", fp8);
        assert_eq!(r.status, 200, "{}", r.body);
    }

    #[test]
    fn lint_endpoint_reports_diagnostics() {
        let s = state();
        // a standard plan lints clean: 200 with an empty diagnostics array
        let clean = r#"{"workload":"mma bf16 f32 m16n8k16","device":"a100",
                        "points":[[4,3]],"sweep":true,"completion_latency":true}"#;
        let r = post(&s, "/v1/lint", clean);
        assert_eq!(r.status, 200, "{}", r.body);
        let j = data(&r);
        assert_eq!(j.get_str("workload"), Some("mma bf16 f32 m16n8k16"));
        assert_eq!(j.get_str("device"), Some("a100"));
        assert_eq!(j.get_u64("errors"), Some(0));
        assert_eq!(j.get_u64("warnings"), Some(0));
        assert!(j.get("diagnostics").unwrap().as_arr().unwrap().is_empty(), "{}", r.body);

        // a 4-deep cp.async pipeline over 128x128x128 tiles keeps
        // 4 x 65536 B in flight — more shared memory than an A100 SM
        // has. The config is *legal* (compile succeeds; 16 k-steps
        // cover 4 stages), but structurally broken: a 400 `lint_errors`
        // envelope with the diagnostics as error.details.
        let overflow = r#"{"workload":"gemm pipeline bf16 f32 2048 128x128x128",
                           "device":"a100","points":[[8,4]]}"#;
        let r = post(&s, "/v1/lint", overflow);
        assert_eq!(r.status, 400, "{}", r.body);
        let e = error_of(&r);
        assert_eq!(e.get_str("code"), Some("lint_errors"));
        let details = e.get("details").expect("lint_errors carries details");
        assert!(details.get_u64("errors").unwrap() >= 1, "{}", r.body);
        let diags = details.get("diagnostics").unwrap().as_arr().unwrap();
        assert!(
            diags.iter().any(|d| d.get_str("rule") == Some("resource/smem-overflow")
                && d.get_str("severity") == Some("error")),
            "{}",
            r.body
        );

        // malformed bodies and uncompilable plans are 400s; GET is a 405
        assert_eq!(post(&s, "/v1/lint", "{not json").status, 400);
        assert_eq!(post(&s, "/v1/lint", r#"{"workload":"nonsense"}"#).status, 400);
        assert_eq!(get(&s, "/v1/lint").status, 405);

        // the lint counters observed the error-producing request
        let m = data(&get(&s, "/v1/metrics"));
        let lint = m.get("lint").unwrap();
        assert!(lint.get_u64("errors").unwrap() >= 1, "{m}");
        assert_eq!(m.get("by_endpoint").unwrap().get_u64("lint"), Some(5));
    }

    #[test]
    fn tune_endpoint_returns_ranked_predicted_vs_simulated_configs() {
        let s = state();
        let body = r#"{"workload":"mma fp16 f32 m16n8k16","device":"a100",
                       "objective":"max-throughput","top":4,"backend":"native"}"#;
        let r = post(&s, "/v1/tune", body);
        assert_eq!(r.status, 200, "{}", r.body);
        let j = data(&r);
        assert_eq!(j.get_str("schema"), Some("tcbench/tune/v1"));
        assert_eq!(j.get_str("objective"), Some("max-throughput"));
        assert_eq!(j.get_str("device"), Some("a100"));
        assert!(j.get_u64("scored").unwrap() >= 48, "{}", r.body);
        assert_eq!(j.get_u64("confirmed"), Some(4));
        assert!(j.get_f64("pruning_ratio").unwrap() > 0.9, "{}", r.body);
        let configs = j.get("configs").unwrap().as_arr().unwrap();
        assert_eq!(configs.len(), 4);
        let top = &configs[0];
        assert!(top.get_u64("warps").unwrap() >= 8, "{}", r.body);
        assert!(top.get("predicted").unwrap().get_f64("throughput").unwrap() > 950.0);
        assert!(top.get("simulated").unwrap().get_f64("throughput").unwrap() > 950.0);
        assert!(top.get_f64("latency_rel_err").is_some(), "{}", r.body);

        // the tune counters and the per-family error histogram observed
        // the run
        let m = data(&get(&s, "/v1/metrics"));
        let tune = m.get("tune").unwrap();
        assert_eq!(tune.get_u64("runs"), Some(1));
        assert!(tune.get_u64("configs_scored").unwrap() >= 48);
        assert_eq!(tune.get_u64("configs_confirmed"), Some(4));
        let err = tune.get("rel_err_ppm").unwrap().get("mma").unwrap();
        assert_eq!(err.get_u64("count"), Some(4));
        assert_eq!(m.get("by_endpoint").unwrap().get_u64("tune"), Some(1));
    }

    #[test]
    fn tune_endpoint_rejects_bad_requests() {
        let s = state();
        // missing workload spec
        let r = post(&s, "/v1/tune", "{}");
        assert_eq!(r.status, 400, "{}", r.body);
        assert_eq!(error_of(&r).get_str("code"), Some("invalid_param"));
        // unknown objective grammar
        let r = post(&s, "/v1/tune", r#"{"workload":"ldmatrix x4","objective":"fastest"}"#);
        assert_eq!(error_of(&r).get_str("code"), Some("invalid_param"));
        // numeric workloads have no timing model to tune
        let r = post(&s, "/v1/tune", r#"{"workload":"numeric chain tf32 f32 4"}"#);
        assert_eq!(r.status, 400, "{}", r.body);
        let e = error_of(&r);
        assert_eq!(e.get_str("code"), Some("invalid_param"));
        assert!(e.get_str("message").unwrap().contains("numeric"), "{}", r.body);
        // a zero frontier is a typed error, and devices resolve
        let r = post(&s, "/v1/tune", r#"{"workload":"ldmatrix x4","top":0}"#);
        assert_eq!(error_of(&r).get_str("code"), Some("invalid_param"));
        let r = post(&s, "/v1/tune", r#"{"workload":"ldmatrix x4","device":"h100"}"#);
        assert_eq!(error_of(&r).get_str("code"), Some("unknown_device"));
        // POST-only
        assert_eq!(get(&s, "/v1/tune").status, 405);
    }

    #[test]
    fn plan_endpoint_rejects_bad_requests() {
        let s = state();
        // malformed JSON
        let r = post(&s, "/v1/plan", "{not json");
        assert_eq!(r.status, 400);
        let e = error_of(&r);
        assert_eq!(e.get_str("code"), Some("invalid_json"));
        assert!(e.get_str("message").unwrap().contains("JSON"));
        // schema violations and impossible plans
        for body in [
            r#"{}"#,
            r#"{"workload":"nonsense"}"#,
            r#"{"workload":"mma bf16 f32 m16n8k16"}"#,
            r#"{"workload":"mma bf16 f32 m16n8k16","points":[[4,1]],"device":"h100"}"#,
            r#"{"workload":"mma bf16 f32 m16n8k16","points":[[4,1]],"backend":"cuda"}"#,
            r#"{"workload":"mma bf16 f32 m16n8k16","points":[[4,1]],"backend":false}"#,
            r#"{"workload":"fp16 f32 m16n8k16 sparse","points":[[4,1]],"device":"rtx2080ti"}"#,
        ] {
            assert_eq!(post(&s, "/v1/plan", body).status, 400, "{body}");
        }
    }

    #[test]
    fn deadline_zero_degrades_plan_units_to_the_analytic_prediction() {
        let s = state();
        let r = post(
            &s,
            "/v1/plan",
            r#"{"workload":"mma fp16 f32 m16n8k16","device":"a100",
                "points":[[4,2]],"backend":"native","deadline_ms":0}"#,
        );
        assert_eq!(r.status, 200, "{}", r.body);
        let j = data(&r);
        let units = j.get("units").unwrap().as_arr().unwrap();
        assert_eq!(units.len(), 1);
        let unit = &units[0];
        let marker = unit.get("degraded").expect("degraded marker in the unit envelope");
        assert_eq!(marker.get("predicted").and_then(Json::as_bool), Some(true), "{}", r.body);
        assert_eq!(
            marker.get("within_calibration").and_then(Json::as_bool),
            Some(true),
            "{}",
            r.body
        );
        assert!(marker.get_str("reason").unwrap().contains("analytic"), "{}", r.body);
        // the served numbers are bit-exactly the closed-form prediction
        let load = Workload::parse_spec("mma fp16 f32 m16n8k16").unwrap();
        let dev = device::by_name("a100").unwrap();
        let pred = load.predict(&dev, workload::ExecPoint::new(4, 2)).unwrap();
        let result = unit.get("result").unwrap();
        assert_eq!(result.get_f64("latency"), Some(pred.latency), "{}", r.body);
        assert_eq!(result.get_f64("throughput"), Some(pred.throughput), "{}", r.body);
        // degraded payloads are never cached: the same plan without the
        // deadline recomputes (origin "computed") and serves the
        // simulated value with no degradation marker
        let r = post(
            &s,
            "/v1/plan",
            r#"{"workload":"mma fp16 f32 m16n8k16","device":"a100",
                "points":[[4,2]],"backend":"native"}"#,
        );
        assert_eq!(r.status, 200, "{}", r.body);
        let unit = &data(&r).get("units").unwrap().as_arr().unwrap()[0];
        assert!(unit.get("degraded").is_none(), "{}", r.body);
        assert_eq!(unit.get_str("origin"), Some("computed"), "{}", r.body);
        // the degradation counter observed the first request, by family
        let m = data(&get(&s, "/v1/metrics"));
        let rob = m.get("robustness").unwrap();
        assert_eq!(rob.get_u64("degraded_total"), Some(1), "{m}");
        assert_eq!(rob.get("degraded_by_family").unwrap().get_u64("mma"), Some(1), "{m}");
    }

    #[test]
    fn deadline_on_the_sweep_route_degrades_inside_the_result() {
        let s = state();
        let r = get(&s, "/v1/sweep?instr=ldmatrix,x4&backend=native&deadline_ms=0");
        assert_eq!(r.status, 200, "{}", r.body);
        let result = data(&r).get("result").cloned().unwrap();
        let marker = result.get("degraded").expect("degraded marker inside the sweep result");
        assert_eq!(marker.get("predicted").and_then(Json::as_bool), Some(true), "{}", r.body);
        assert!(!result.get("cells").unwrap().as_arr().unwrap().is_empty(), "{}", r.body);
    }

    #[test]
    fn deadline_on_a_numeric_unit_is_a_typed_504() {
        let s = state();
        let r = post(
            &s,
            "/v1/plan",
            r#"{"workload":"numeric profile fp16 f32 mul low","points":[[1,1]],
                "backend":"native","deadline_ms":0}"#,
        );
        assert_eq!(r.status, 504, "{}", r.body);
        let e = error_of(&r);
        assert_eq!(e.get_str("code"), Some("deadline_exceeded"), "{}", r.body);
        assert!(e.get_str("message").unwrap().contains("numeric"), "{}", r.body);
        let m = data(&get(&s, "/v1/metrics"));
        let rob = m.get("robustness").unwrap();
        assert_eq!(rob.get_u64("deadline_exceeded_total"), Some(1), "{m}");
        assert_eq!(rob.get_u64("degraded_total"), Some(0), "{m}");
    }

    #[test]
    fn bad_deadlines_are_typed_400s() {
        let s = state();
        for body in [
            r#"{"workload":"mma fp16 f32 m16n8k16","points":[[4,2]],"deadline_ms":-5}"#,
            r#"{"workload":"mma fp16 f32 m16n8k16","points":[[4,2]],"deadline_ms":"soon"}"#,
            r#"{"workload":"mma fp16 f32 m16n8k16","points":[[4,2]],"deadline_ms":1.5}"#,
            r#"{"workload":"mma fp16 f32 m16n8k16","points":[[4,2]],"deadline_ms":true}"#,
        ] {
            let r = post(&s, "/v1/plan", body);
            assert_eq!(r.status, 400, "{body}: {}", r.body);
            assert_eq!(error_of(&r).get_str("code"), Some("invalid_param"), "{body}");
        }
        let r = get(&s, "/v1/sweep?instr=ldmatrix,x4&deadline_ms=never");
        assert_eq!(r.status, 400, "{}", r.body);
    }

    #[test]
    fn deadline_arrives_via_the_x_deadline_ms_header_too() {
        let s = state();
        let req = Request {
            method: "POST".to_string(),
            path: "/v1/plan".to_string(),
            query: vec![],
            headers: vec![("x-deadline-ms".to_string(), "0".to_string())],
            body: r#"{"workload":"mma fp16 f32 m16n8k16","device":"a100",
                      "points":[[4,2]],"backend":"native"}"#
                .to_string(),
        };
        let r = handle(&s, &req);
        assert_eq!(r.status, 200, "{}", r.body);
        let unit = &data(&r).get("units").unwrap().as_arr().unwrap()[0];
        assert!(unit.get("degraded").is_some(), "{}", r.body);
    }

    #[test]
    fn readyz_reflects_warming_and_queue_saturation() {
        let s = state();
        // fresh state: ready, queue capacity unconfigured (0)
        let r = get(&s, "/readyz");
        assert_eq!(r.status, 200, "{}", r.body);
        assert_eq!(data(&r).get_str("status"), Some("ready"));

        s.readiness.set_warming(true);
        let r = get(&s, "/readyz");
        assert_eq!(r.status, 503, "{}", r.body);
        assert_eq!(error_of(&r).get_str("code"), Some("not_ready"));
        assert!(
            r.headers.iter().any(|(n, v)| *n == "Retry-After" && !v.is_empty()),
            "503 must carry Retry-After"
        );
        s.readiness.set_warming(false);

        s.readiness.set_queue_capacity(2);
        s.readiness.queue_enter();
        s.readiness.queue_exit();
        assert_eq!(get(&s, "/readyz").status, 200);
        s.readiness.queue_enter();
        s.readiness.queue_enter();
        let r = get(&s, "/readyz");
        assert_eq!(r.status, 503, "{}", r.body);
        assert!(error_of(&r).get_str("message").unwrap().contains("queue"), "{}", r.body);
        s.readiness.queue_exit();
        assert_eq!(get(&s, "/readyz").status, 200);
        // exits never wrap below zero
        s.readiness.queue_exit();
        s.readiness.queue_exit();
        assert_eq!(s.readiness.queue_len(), 0);
    }
}
