//! tcserved request routing: the `/v1` JSON API over the campaign.
//!
//! Heavy endpoints (`/v1/run/<id>`, `/v1/sweep`) go through the
//! content-addressed [`ResultCache`]: the first request computes via
//! `coordinator::run_experiment` / `microbench::sweep_mma` (which fan
//! out over the coordinator's worker pool internally), every identical
//! later request is a cache hit, and concurrent identical requests are
//! coalesced into a single computation.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Instant;

use crate::coordinator::{self, run_parallel, BackendKind, ExperimentId, EXPERIMENTS};
use crate::device;
use crate::isa::MmaInstr;
use crate::microbench::{convergence_point, sweep_mma};
use crate::report;
use crate::util::Json;

use super::cache::{cache_key, CacheKey, Origin, ResultCache};
use super::http::{Request, Response};
use super::metrics::Metrics;

/// Shared state of one tcserved instance.
pub struct AppState {
    pub cache: ResultCache,
    pub metrics: Metrics,
}

impl AppState {
    pub fn new(cache: ResultCache) -> AppState {
        AppState { cache, metrics: Metrics::new() }
    }
}

fn endpoint_label(path: &str) -> &'static str {
    match path {
        "/healthz" => "healthz",
        "/v1/experiments" => "experiments",
        "/v1/devices" => "devices",
        "/v1/metrics" => "metrics",
        "/v1/sweep" => "sweep",
        p if p.starts_with("/v1/run/") => "run",
        _ => "other",
    }
}

/// Dispatch one parsed request.
pub fn handle(state: &AppState, req: &Request) -> Response {
    state.metrics.record_request(endpoint_label(&req.path));
    if req.method != "GET" {
        return Response::error(405, format!("method {} not allowed; this API is GET-only", req.method));
    }
    match req.path.as_str() {
        "/healthz" => healthz(),
        "/v1/experiments" => experiments(state),
        "/v1/devices" => devices(),
        "/v1/metrics" => metrics(state),
        "/v1/sweep" => sweep(state, req),
        p if p.starts_with("/v1/run/") => run(state, req, &p["/v1/run/".len()..]),
        other => Response::error(404, format!("no route for {other:?}")),
    }
}

fn healthz() -> Response {
    Response::json(
        200,
        &Json::obj(vec![
            ("status", Json::str("ok")),
            ("service", Json::str("tcserved")),
            ("version", Json::str(env!("CARGO_PKG_VERSION"))),
            ("experiments", Json::num(EXPERIMENTS.len() as f64)),
        ]),
    )
}

fn experiments(state: &AppState) -> Response {
    // report cache state for the default-backend key (auto, resolved —
    // the same key a parameterless /v1/run/<id> uses)
    let default_backend = BackendKind::Auto.resolve();
    let list: Vec<Json> = EXPERIMENTS
        .iter()
        .map(|e| {
            let key = cache_key(e.id, default_backend.name(), "-", "-");
            Json::obj(vec![
                ("id", Json::str(e.id)),
                ("description", Json::str(e.description)),
                ("kind", Json::str(if e.numeric { "numeric" } else { "sim" })),
                ("cached", Json::Bool(state.cache.contains(&key))),
                ("url", Json::Str(format!("/v1/run/{}", e.id))),
            ])
        })
        .collect();
    Response::json(
        200,
        &Json::obj(vec![
            ("count", Json::num(EXPERIMENTS.len() as f64)),
            ("experiments", Json::Arr(list)),
        ]),
    )
}

fn devices() -> Response {
    let list: Vec<Json> = device::registry()
        .into_iter()
        .map(|d| {
            Json::obj(vec![
                ("name", Json::str(d.name)),
                ("product", Json::str(d.product)),
                ("arch", Json::Str(format!("{:?}", d.arch))),
                ("sms", Json::num(d.sms as f64)),
                ("tensor_cores_per_sm", Json::num(d.arch.tensor_cores_per_sm() as f64)),
                ("supports_sparse", Json::Bool(d.arch.supports_sparse())),
                ("supports_ldmatrix", Json::Bool(d.arch.supports_ldmatrix())),
            ])
        })
        .collect();
    Response::json(200, &Json::obj(vec![("devices", Json::Arr(list))]))
}

fn metrics(state: &AppState) -> Response {
    Response::json(200, &state.metrics.to_json(state.cache.stats()))
}

fn note_origin(state: &AppState, origin: Origin) {
    match origin {
        Origin::Memory | Origin::Disk => state.metrics.record_hit(),
        Origin::Computed => state.metrics.record_miss(),
        Origin::Coalesced => state.metrics.record_coalesced(),
    }
}

/// Wrap a cached payload for the wire: the payload is the content-addressed
/// value; `cached`/`origin` describe how this particular request got it.
fn respond_cached(result: Result<String, String>, origin: Origin) -> Response {
    match result {
        Ok(body) => {
            let inner = Json::parse(&body).unwrap_or(Json::Str(body));
            Response::json(
                200,
                &Json::obj(vec![
                    ("cached", Json::Bool(origin != Origin::Computed)),
                    ("origin", Json::str(origin.name())),
                    ("result", inner),
                ]),
            )
        }
        Err(e) => Response::error(500, e),
    }
}

// ------------------------------------------------------------ /v1/run/<id>

fn run(state: &AppState, req: &Request, id: &str) -> Response {
    let Some(exp) = coordinator::experiment(id) else {
        return Response::error(
            404,
            format!("unknown experiment {id:?}; see /v1/experiments for the registry"),
        );
    };
    // default matches the CLI: `auto` (pjrt when artifacts exist, else
    // native); the cache key uses whatever it resolves to
    let kind = match BackendKind::parse(req.param("backend").unwrap_or("auto")) {
        Ok(k) => k,
        Err(e) => return Response::error(400, format!("{e:#}")),
    };
    let (result, origin) = run_cached(state, exp, kind);
    respond_cached(result, origin)
}

/// Cached execution of one experiment — shared by the HTTP handler and
/// `--warm` precomputation.
pub fn run_cached(
    state: &AppState,
    exp: &'static ExperimentId,
    kind: BackendKind,
) -> (Result<String, String>, Origin) {
    // `auto` is keyed as whatever it resolves to, so its cache entries
    // are shared with the concrete backend and never go stale when the
    // environment (artifact availability) changes.
    let kind = kind.resolve();
    let key = cache_key(exp.id, kind.name(), "-", "-");
    let (result, origin) =
        state.cache.get_or_compute(&key, || compute_experiment(state, exp, kind, &key));
    note_origin(state, origin);
    (result, origin)
}

fn compute_experiment(
    state: &AppState,
    exp: &'static ExperimentId,
    kind: BackendKind,
    key: &CacheKey,
) -> Result<String, String> {
    let t0 = Instant::now();
    let outcome = catch_unwind(AssertUnwindSafe(|| -> Result<(String, String), String> {
        let mut backend = kind.instantiate().map_err(|e| format!("{e:#}"))?;
        let backend_name = backend.name().to_string();
        let text = coordinator::run_experiment(exp.id, &mut backend).map_err(|e| format!("{e:#}"))?;
        Ok((backend_name, text))
    }));
    let (backend_name, text) = match outcome {
        Ok(Ok(pair)) => pair,
        Ok(Err(e)) => return Err(e),
        Err(_) => return Err(format!("experiment {} panicked during computation", exp.id)),
    };
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    state.metrics.record_compute(exp.id, ms);
    Ok(Json::obj(vec![
        ("id", Json::str(exp.id)),
        ("backend", Json::Str(backend_name)),
        ("compute_ms", Json::num(ms)),
        ("key", Json::str(key.hash.clone())),
        ("report", report::report_to_json(exp.id, exp.description, &text)),
    ])
    .to_string())
}

/// Precompute every registered experiment through the worker pool so
/// steady-state request latency is cache-bound. Returns how many warmed
/// successfully.
pub fn warm(state: &AppState, threads: usize) -> usize {
    let jobs: Vec<_> = EXPERIMENTS
        .iter()
        .map(|e| move || run_cached(state, e, BackendKind::Auto).0.is_ok())
        .collect();
    // The table experiments parallelize internally; cap the outer pool
    // so warm-up does not oversubscribe the CPU quadratically.
    run_parallel(jobs, threads.min(4)).into_iter().filter(|ok| *ok).count()
}

// ---------------------------------------------------------------- /v1/sweep

fn sweep(state: &AppState, req: &Request) -> Response {
    let dev_name = req.param("device").unwrap_or("a100");
    let Some(dev) = device::by_name(dev_name) else {
        return Response::error(404, format!("unknown device {dev_name:?}; see /v1/devices"));
    };
    let Some(spec) = req.param("instr") else {
        return Response::error(
            400,
            "missing required query parameter `instr` (e.g. ?instr=bf16,f32,m16n8k16)",
        );
    };
    let parsed = match MmaInstr::parse_spec(spec) {
        Ok(i) => i,
        Err(e) => return Response::error(400, e),
    };
    let instr = match req.param("sparse") {
        None => parsed,
        Some("1") | Some("true") | Some("yes") => {
            MmaInstr::sp(parsed.ab, parsed.cd, parsed.shape)
        }
        Some("0") | Some("false") | Some("no") => {
            MmaInstr::dense(parsed.ab, parsed.cd, parsed.shape)
        }
        Some(other) => {
            return Response::error(400, format!("bad sparse flag {other:?} (true|false)"))
        }
    };
    if !dev.supports(&instr) {
        return Response::error(400, format!("{instr} is not supported on {}", dev.name));
    }
    let key = cache_key("sweep", "sim", dev.name, &instr.ptx());
    let (result, origin) =
        state.cache.get_or_compute(&key, || compute_sweep(state, &dev, &instr, &key));
    note_origin(state, origin);
    respond_cached(result, origin)
}

fn compute_sweep(
    state: &AppState,
    dev: &device::Device,
    instr: &MmaInstr,
    key: &CacheKey,
) -> Result<String, String> {
    let t0 = Instant::now();
    let sweep = match catch_unwind(AssertUnwindSafe(|| sweep_mma(dev, instr))) {
        Ok(s) => s,
        Err(_) => return Err(format!("sweep of {instr} on {} panicked", dev.name)),
    };
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    state.metrics.record_compute("sweep", ms);
    // one serializer for every measured point (grid cells and the
    // table-style convergence summaries share the field layout)
    fn point_json(warps: u32, ilp: u32, latency: f64, throughput: f64) -> Json {
        Json::obj(vec![
            ("warps", Json::num(warps as f64)),
            ("ilp", Json::num(ilp as f64)),
            ("latency", Json::num(latency)),
            ("throughput", Json::num(throughput)),
        ])
    }
    let cells: Vec<Json> = sweep
        .cells
        .iter()
        .map(|c| point_json(c.warps, c.ilp, c.latency, c.throughput))
        .collect();
    let convergence: Vec<Json> = [4u32, 8]
        .iter()
        .map(|&w| {
            let c = convergence_point(&sweep, w);
            point_json(c.warps, c.ilp, c.latency, c.throughput)
        })
        .collect();
    Ok(Json::obj(vec![
        ("device", Json::str(dev.name)),
        ("instr", Json::Str(instr.to_string())),
        ("ptx", Json::Str(instr.ptx())),
        ("sparse", Json::Bool(instr.sparse)),
        (
            "warps_axis",
            Json::Arr(sweep.warps_axis.iter().map(|&w| Json::num(w as f64)).collect()),
        ),
        ("ilp_axis", Json::Arr(sweep.ilp_axis.iter().map(|&i| Json::num(i as f64)).collect())),
        ("cells", Json::Arr(cells)),
        ("convergence", Json::Arr(convergence)),
        ("peak_throughput", Json::num(sweep.peak_throughput())),
        ("compute_ms", Json::num(ms)),
        ("key", Json::str(key.hash.clone())),
    ])
    .to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> AppState {
        AppState::new(ResultCache::new(32, None))
    }

    fn get(state: &AppState, target: &str) -> Response {
        let (path, query_raw) = match target.split_once('?') {
            Some((p, q)) => (p, Some(q)),
            None => (target, None),
        };
        let query = query_raw
            .map(|q| {
                q.split('&')
                    .filter(|p| !p.is_empty())
                    .map(|p| {
                        let (k, v) = p.split_once('=').unwrap_or((p, ""));
                        (k.to_string(), v.to_string())
                    })
                    .collect()
            })
            .unwrap_or_default();
        let req = Request { method: "GET".to_string(), path: path.to_string(), query };
        handle(state, &req)
    }

    #[test]
    fn healthz_and_registry_endpoints() {
        let s = state();
        let r = get(&s, "/healthz");
        assert_eq!(r.status, 200);
        assert_eq!(Json::parse(&r.body).unwrap().get_str("status"), Some("ok"));

        let r = get(&s, "/v1/experiments");
        let j = Json::parse(&r.body).unwrap();
        assert_eq!(j.get_u64("count"), Some(19));
        assert_eq!(
            j.get("experiments").unwrap().as_arr().unwrap()[2].get_str("id"),
            Some("t3")
        );

        let r = get(&s, "/v1/devices");
        let j = Json::parse(&r.body).unwrap();
        assert_eq!(j.get("devices").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn unknown_routes_and_methods() {
        let s = state();
        assert_eq!(get(&s, "/nope").status, 404);
        assert_eq!(get(&s, "/v1/run/t99").status, 404);
        let req = Request { method: "POST".to_string(), path: "/healthz".to_string(), query: vec![] };
        assert_eq!(handle(&s, &req).status, 405);
    }

    #[test]
    fn run_caches_by_content_address() {
        let s = state();
        let r1 = get(&s, "/v1/run/t10");
        assert_eq!(r1.status, 200, "{}", r1.body);
        let j1 = Json::parse(&r1.body).unwrap();
        assert_eq!(j1.get("cached").and_then(Json::as_bool), Some(false));
        assert_eq!(j1.get("result").unwrap().get_str("id"), Some("t10"));

        let r2 = get(&s, "/v1/run/t10");
        let j2 = Json::parse(&r2.body).unwrap();
        assert_eq!(j2.get("cached").and_then(Json::as_bool), Some(true));
        assert_eq!(j2.get_str("origin"), Some("memory"));

        // `auto` resolves to native here (no PJRT offline), so it shares
        // the native content address and hits the same cache entry
        let r3 = get(&s, "/v1/run/t10?backend=auto");
        let j3 = Json::parse(&r3.body).unwrap();
        assert_eq!(j3.get("cached").and_then(Json::as_bool), Some(true));

        let m = Json::parse(&get(&s, "/v1/metrics").body).unwrap();
        let t10 = m.get("experiments").unwrap().get("t10").unwrap();
        assert_eq!(t10.get_u64("computes"), Some(1)); // auto coalesced onto native
        assert_eq!(m.get("cache").unwrap().get_u64("hits"), Some(2));
    }

    #[test]
    fn sweep_validation() {
        let s = state();
        assert_eq!(get(&s, "/v1/sweep").status, 400);
        assert_eq!(get(&s, "/v1/sweep?instr=garbage").status, 400);
        assert_eq!(get(&s, "/v1/sweep?device=h100&instr=bf16,f32,m16n8k16").status, 404);
        // Turing has no sparse support
        assert_eq!(
            get(&s, "/v1/sweep?device=rtx2080ti&instr=fp16,f32,m16n8k16,sparse").status,
            400
        );
        assert_eq!(
            get(&s, "/v1/sweep?device=a100&instr=bf16,f32,m16n8k16&sparse=maybe").status,
            400
        );
    }

    #[test]
    fn sweep_returns_full_grid_and_caches() {
        let s = state();
        let r = get(&s, "/v1/sweep?device=a100&instr=bf16,f32,m16n8k16");
        assert_eq!(r.status, 200, "{}", r.body);
        let j = Json::parse(&r.body).unwrap();
        let result = j.get("result").unwrap();
        assert_eq!(result.get_str("device"), Some("a100"));
        assert_eq!(result.get("cells").unwrap().as_arr().unwrap().len(), 48);
        assert_eq!(result.get("convergence").unwrap().as_arr().unwrap().len(), 2);
        let peak = result.get_f64("peak_throughput").unwrap();
        assert!((960.0..1030.0).contains(&peak), "peak {peak}");

        let r2 = get(&s, "/v1/sweep?device=a100&instr=bf16,f32,m16n8k16");
        let j2 = Json::parse(&r2.body).unwrap();
        assert_eq!(j2.get("cached").and_then(Json::as_bool), Some(true));
    }
}
