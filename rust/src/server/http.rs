//! Minimal HTTP/1.1 request parsing and response writing over
//! `std::net::TcpStream` — no external crates, matching the repo's
//! offline-substrate convention (`util::json`, `util::bench`).
//!
//! Scope: exactly what tcserved needs. Request line + headers (only
//! `Content-Length` is interpreted, for the `POST /v1/plan` body),
//! percent-decoded query strings, bounded JSON bodies,
//! `Connection: close` responses with an explicit `Content-Length`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use crate::util::Json;

/// Longest accepted request/header line, in bytes.
const MAX_LINE: usize = 16 * 1024;
/// Most accepted header lines per request.
const MAX_HEADERS: usize = 128;
/// Largest accepted request body. Generous — a JSON `BenchPlan` is tens
/// of kilobytes at most — but bounded: past it the request is rejected
/// with a typed `413` instead of buffering arbitrary client input.
pub const MAX_BODY_BYTES: usize = 4 * 1024 * 1024;
/// Hard cap on the bytes read per request (head + body). `read_line` is
/// only length-checked after it returns, so the reader itself must be
/// bounded or a client streaming an endless line would grow the buffer
/// without limit.
const MAX_REQUEST_BYTES: u64 = (MAX_BODY_BYTES + 64 * 1024) as u64;

/// A parsed request: method, decoded path, decoded query parameters,
/// retained headers and the raw body (empty for bodyless requests).
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub query: Vec<(String, String)>,
    /// Header fields in arrival order: lowercased names, trimmed values.
    pub headers: Vec<(String, String)>,
    pub body: String,
}

impl Request {
    /// Last value of a query parameter (so `?a=1&a=2` resolves to `2`).
    pub fn param(&self, key: &str) -> Option<&str> {
        self.query.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// Last value of a header field, by case-insensitive name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().rev().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }
}

/// Why a request could not be read off the wire — split by the status
/// the caller must answer with.
#[derive(Debug)]
pub enum ReadError {
    /// Connection closed without sending anything (port probe, the
    /// server's own shutdown wake-up) — nothing to respond to.
    Empty,
    /// The declared body exceeds [`MAX_BODY_BYTES`] (or the
    /// `Content-Length` value does not parse as a size at all) → `413`.
    TooLarge(String),
    /// Anything else wrong with the request head or body → `400`.
    Malformed(String),
}

/// Decode `%XX` escapes and `+` (as space). Malformed escapes pass
/// through literally rather than failing the whole request.
pub fn percent_decode(s: &str) -> String {
    let b = s.as_bytes();
    let mut out = Vec::with_capacity(b.len());
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'%' if i + 2 < b.len() => {
                let hex = std::str::from_utf8(&b[i + 1..i + 3]).unwrap_or("!");
                match u8::from_str_radix(hex, 16) {
                    Ok(v) => {
                        out.push(v);
                        i += 3;
                    }
                    Err(_) => {
                        out.push(b'%');
                        i += 1;
                    }
                }
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            c => {
                out.push(c);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Read and parse one request from the stream. Header fields are read
/// to the blank line and retained on the request (lowercased names);
/// `Content-Length` sizes the body read and `Expect: 100-continue`
/// triggers the interim response (tcserved closes the connection after
/// each response, so there is no pipelining to account for).
pub fn read_request(stream: &mut TcpStream) -> Result<Request, ReadError> {
    use std::io::Read as _;
    let malformed = ReadError::Malformed;
    // An OS-level dup for writing the interim `100 Continue` while the
    // buffered reader below owns the `&mut` borrow.
    let interim_writer = stream.try_clone();
    let mut reader = BufReader::new(stream.take(MAX_REQUEST_BYTES));

    let mut line = String::new();
    reader.read_line(&mut line).map_err(|e| malformed(format!("reading request line: {e}")))?;
    if line.is_empty() {
        return Err(ReadError::Empty);
    }
    if line.len() > MAX_LINE {
        return Err(malformed("request line too long".to_string()));
    }

    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| malformed("empty request line".into()))?.to_string();
    let target =
        parts.next().ok_or_else(|| malformed("missing request target".into()))?.to_string();
    let version = parts.next().ok_or_else(|| malformed("missing HTTP version".into()))?;
    if !version.starts_with("HTTP/") {
        return Err(malformed(format!("bad HTTP version {version:?}")));
    }

    let mut headers: Vec<(String, String)> = Vec::new();
    let mut content_length: usize = 0;
    let mut expect_continue = false;
    let mut headers_done = false;
    for _ in 0..MAX_HEADERS {
        let mut header = String::new();
        let n =
            reader.read_line(&mut header).map_err(|e| malformed(format!("reading header: {e}")))?;
        if n == 0 || header == "\r\n" || header == "\n" {
            headers_done = true;
            break;
        }
        if header.len() > MAX_LINE {
            return Err(malformed("header line too long".to_string()));
        }
        if let Some((name, value)) = header.split_once(':') {
            let name = name.trim().to_ascii_lowercase();
            let value = value.trim().to_string();
            if name == "content-length" {
                // an unparseable or overflowing size is still a size
                // claim we cannot honor — reject as too large, not as
                // a generic parse error
                content_length = value.parse().map_err(|_| {
                    ReadError::TooLarge(format!("bad Content-Length {value:?}"))
                })?;
            } else if name == "expect" && value.eq_ignore_ascii_case("100-continue") {
                expect_continue = true;
            }
            headers.push((name, value));
        }
    }
    // Never fall through with unread header lines: the body reader below
    // would consume them as the request body.
    if !headers_done {
        return Err(malformed(format!("too many header lines (limit {MAX_HEADERS})")));
    }
    if content_length > MAX_BODY_BYTES {
        return Err(ReadError::TooLarge(format!(
            "request body too large ({content_length} bytes; limit {MAX_BODY_BYTES})"
        )));
    }

    let mut body = String::new();
    if content_length > 0 {
        // Clients like curl wait for the interim response before sending
        // bodies over ~1 KB; without it every such POST stalls on the
        // client's ~1 s expect timeout. Best-effort: the client falls
        // back to its own timer if the write fails.
        if expect_continue {
            if let Ok(w) = &interim_writer {
                let mut w: &TcpStream = w;
                let _ = w.write_all(b"HTTP/1.1 100 Continue\r\n\r\n");
                let _ = w.flush();
            }
        }
        let mut buf = vec![0u8; content_length];
        reader
            .read_exact(&mut buf)
            .map_err(|e| malformed(format!("reading {content_length}-byte request body: {e}")))?;
        body = String::from_utf8(buf)
            .map_err(|_| malformed("request body is not UTF-8".to_string()))?;
    }

    let (path_raw, query_raw) = match target.split_once('?') {
        Some((p, q)) => (p, Some(q)),
        None => (target.as_str(), None),
    };
    let mut query = Vec::new();
    if let Some(q) = query_raw {
        for pair in q.split('&') {
            if pair.is_empty() {
                continue;
            }
            let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
            query.push((percent_decode(k), percent_decode(v)));
        }
    }
    Ok(Request { method, path: percent_decode(path_raw), query, headers, body })
}

/// Version tag of the one response envelope every JSON endpoint answers
/// in: `{"schema": "tcserved/v1", "data": ...}` on success,
/// `{"schema": "tcserved/v1", "error": {"code", "message", "status"}}`
/// on failure.
pub const SCHEMA: &str = "tcserved/v1";

/// A response ready to serialize.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: String,
    /// Extra response headers (e.g. `Deprecation`, `Retry-After`), on
    /// top of the always-written Content-Type/Content-Length/Connection.
    pub headers: Vec<(&'static str, String)>,
}

impl Response {
    /// A raw (un-enveloped) JSON response — internal plumbing; endpoint
    /// handlers answer via [`Response::ok`] / [`Response::error`].
    pub fn json(status: u16, body: &Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: body.to_string(),
            headers: Vec::new(),
        }
    }

    /// A non-JSON response (the Prometheus text exposition is the one
    /// endpoint exempt from the v1 envelope).
    pub fn text(status: u16, content_type: &'static str, body: String) -> Response {
        Response { status, content_type, body, headers: Vec::new() }
    }

    /// A 200 success envelope: `{"schema": "tcserved/v1", "data": ...}`.
    pub fn ok(data: Json) -> Response {
        Response::json(200, &Json::obj(vec![("schema", Json::str(SCHEMA)), ("data", data)]))
    }

    /// An error envelope with a machine-readable `code` (stable, typed)
    /// and a human-readable `message`.
    pub fn error(status: u16, code: &str, message: impl Into<String>) -> Response {
        Response::error_with_details(status, code, message, None)
    }

    /// [`Response::error`] carrying structured `details` (e.g. the lint
    /// diagnostics that explain a `lint_errors` rejection).
    pub fn error_with_details(
        status: u16,
        code: &str,
        message: impl Into<String>,
        details: Option<Json>,
    ) -> Response {
        let mut error = vec![
            ("code", Json::str(code)),
            ("message", Json::Str(message.into())),
            ("status", Json::num(status as f64)),
        ];
        if let Some(details) = details {
            error.push(("details", details));
        }
        Response::json(
            status,
            &Json::obj(vec![("schema", Json::str(SCHEMA)), ("error", Json::obj(error))]),
        )
    }

    /// Attach an extra response header.
    pub fn with_header(mut self, name: &'static str, value: impl Into<String>) -> Response {
        self.headers.push((name, value.into()));
        self
    }

    pub fn write_to(&self, stream: &mut TcpStream) -> std::io::Result<()> {
        write!(
            stream,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n",
            self.status,
            status_text(self.status),
            self.content_type,
            self.body.len()
        )?;
        for (name, value) in &self.headers {
            write!(stream, "{name}: {value}\r\n")?;
        }
        write!(stream, "Connection: close\r\n\r\n")?;
        stream.write_all(self.body.as_bytes())?;
        stream.flush()
    }
}

pub fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percent_decoding() {
        assert_eq!(percent_decode("bf16+f32+m16n8k16"), "bf16 f32 m16n8k16");
        assert_eq!(percent_decode("bf16%20f32"), "bf16 f32");
        assert_eq!(percent_decode("a%2Cb"), "a,b");
        assert_eq!(percent_decode("100%"), "100%"); // malformed escape passes through
        assert_eq!(percent_decode("%zz"), "%zz");
        assert_eq!(percent_decode(""), "");
    }

    #[test]
    fn error_bodies_are_enveloped_json_with_typed_codes() {
        let r = Response::error(404, "not_found", "nope");
        assert_eq!(r.status, 404);
        let j = Json::parse(&r.body).unwrap();
        assert_eq!(j.get_str("schema"), Some(SCHEMA));
        let e = j.get("error").expect("error object");
        assert_eq!(e.get_str("code"), Some("not_found"));
        assert_eq!(e.get_str("message"), Some("nope"));
        assert_eq!(e.get_u64("status"), Some(404));
        assert!(e.get("details").is_none());
        // details ride inside the error object when present
        let r = Response::error_with_details(
            400,
            "lint_errors",
            "1 error",
            Some(Json::obj(vec![("errors", Json::num(1.0))])),
        );
        let j = Json::parse(&r.body).unwrap();
        let d = j.get("error").and_then(|e| e.get("details")).expect("details");
        assert_eq!(d.get_u64("errors"), Some(1));
    }

    #[test]
    fn success_envelope_wraps_data() {
        let r = Response::ok(Json::obj(vec![("answer", Json::num(42.0))]));
        assert_eq!(r.status, 200);
        let j = Json::parse(&r.body).unwrap();
        assert_eq!(j.get_str("schema"), Some(SCHEMA));
        assert_eq!(j.get("data").and_then(|d| d.get_u64("answer")), Some(42));
        assert!(j.get("error").is_none());
    }

    #[test]
    fn status_texts() {
        assert_eq!(status_text(200), "OK");
        assert_eq!(status_text(404), "Not Found");
        assert_eq!(status_text(413), "Payload Too Large");
        assert_eq!(status_text(504), "Gateway Timeout");
        assert_eq!(status_text(599), "Unknown");
    }

    #[test]
    fn header_lookup_is_case_insensitive_and_last_wins() {
        let req = Request {
            method: "GET".to_string(),
            path: "/".to_string(),
            query: vec![],
            headers: vec![
                ("x-deadline-ms".to_string(), "100".to_string()),
                ("x-deadline-ms".to_string(), "250".to_string()),
            ],
            body: String::new(),
        };
        assert_eq!(req.header("X-Deadline-Ms"), Some("250"));
        assert_eq!(req.header("x-deadline-ms"), Some("250"));
        assert_eq!(req.header("content-length"), None);
    }
}
