//! Consistent-hash sharding of plan-unit work across replicas.
//!
//! The scaling model is N `tcserved` replicas over one shared
//! [`CellStore`](crate::workload::CellStore) directory: each cell key's
//! FNV-1a address — the same address the cell cache and store already
//! use — places it on a consistent-hash ring, and the shard owning that
//! ring segment is the replica meant to simulate it (everyone can
//! *read* every cell from the shared store; ownership only partitions
//! the cold-miss simulation work). Consistent hashing keeps the
//! partition stable when the replica count changes: going from N to
//! N+1 shards remaps only ~1/(N+1) of the keyspace instead of
//! reshuffling everything, so a resized fleet keeps most of its warm
//! ownership.
//!
//! Two deployment shapes share this module:
//!
//! * `repro serve --replicas N` — one process hosts all N shards. The
//!   [`ShardRouter`] is the "thin in-process router": every unit is
//!   executed under its owning shard's concurrency gate, so per-shard
//!   load is observable at `/v1/metrics` before any process is split
//!   out.
//! * `repro serve --shard i/N` — this process *is* shard `i` of an
//!   N-replica fleet. Units owned by other shards are still answered
//!   (any replica can serve any request) but are counted as
//!   `forwarded_units`: traffic a fronting balancer should have sent
//!   elsewhere, and simulation work whose cell-store write the owning
//!   replica would otherwise have produced.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

use crate::coordinator::default_threads;
use crate::util::{fnv1a, Json};

/// Virtual nodes per shard on the ring. 64 points per shard keeps the
/// expected keyspace imbalance between shards in the low percents
/// while the ring stays tiny (N*64 u64s, binary-searched).
const VNODES: usize = 64;

/// A consistent-hash ring over `replicas` shards.
pub struct HashRing {
    /// `(ring position, shard)`, sorted by position.
    points: Vec<(u64, usize)>,
    replicas: usize,
}

impl HashRing {
    pub fn new(replicas: usize) -> HashRing {
        let replicas = replicas.max(1);
        let mut points: Vec<(u64, usize)> = (0..replicas)
            .flat_map(|shard| {
                (0..VNODES).map(move |v| (fnv1a(format!("shard:{shard}:{v}").as_bytes()), shard))
            })
            .collect();
        points.sort_unstable();
        HashRing { points, replicas }
    }

    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// The shard owning a hash: the first ring point at or clockwise
    /// after it, wrapping past the top of the u64 space.
    pub fn owner(&self, hash: u64) -> usize {
        let i = self.points.partition_point(|&(p, _)| p < hash);
        self.points[i % self.points.len()].1
    }

    /// [`owner`](Self::owner) of a canonical cache key (hashed with the
    /// same FNV-1a the cell cache and store key by).
    pub fn owner_of(&self, canonical: &str) -> usize {
        self.owner(fnv1a(canonical.as_bytes()))
    }
}

/// One shard's concurrency gate plus its executed-unit counter. Same
/// permit discipline as the simulation gate: acquire before running,
/// return on drop (panic-safe), sleepers on a condvar.
struct ShardGate {
    permits: Mutex<usize>,
    freed: Condvar,
    units: AtomicU64,
}

impl ShardGate {
    fn run<T>(&self, f: impl FnOnce() -> T) -> T {
        struct Permit<'a>(&'a ShardGate);
        impl Drop for Permit<'_> {
            fn drop(&mut self) {
                *self.0.permits.lock().unwrap() += 1;
                self.0.freed.notify_one();
            }
        }
        // Invariant: lock/wait unwraps only fail on poisoning, which is
        // unreachable — only counter math runs under the lock; `f` runs
        // after `drop(permits)`, and a panicking `f` releases its permit
        // via `Permit`'s unwind-safe `Drop`.
        let mut permits = self.permits.lock().unwrap();
        while *permits == 0 {
            permits = self.freed.wait(permits).unwrap();
        }
        *permits -= 1;
        drop(permits);
        let _permit = Permit(self);
        f()
    }
}

/// Counter snapshot of a [`ShardRouter`] (the `/v1/metrics` `shards`
/// section).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardStats {
    pub replicas: usize,
    /// `Some(i)` when this process is shard `i` of a multi-process
    /// fleet (`--shard i/N`); `None` when it hosts every shard.
    pub local: Option<usize>,
    /// Units executed under each shard's gate, indexed by shard.
    pub units: Vec<u64>,
    /// Units owned by a non-local shard (always 0 when `local` is
    /// `None`).
    pub forwarded: u64,
}

/// Routes each unit of work to its owning shard's gate and keeps the
/// per-shard accounting.
pub struct ShardRouter {
    ring: HashRing,
    gates: Vec<ShardGate>,
    local: Option<usize>,
    forwarded: AtomicU64,
}

impl ShardRouter {
    /// A router over `replicas` shards splitting `worker_budget`
    /// concurrent-execution permits between them (at least one each).
    /// `local` marks which shard this process is, if the fleet is
    /// multi-process.
    pub fn new(replicas: usize, local: Option<usize>, worker_budget: usize) -> ShardRouter {
        let ring = HashRing::new(replicas);
        let per_shard = worker_budget.div_ceil(ring.replicas()).max(1);
        let gates = (0..ring.replicas())
            .map(|_| ShardGate {
                permits: Mutex::new(per_shard),
                freed: Condvar::new(),
                units: AtomicU64::new(0),
            })
            .collect();
        ShardRouter {
            ring,
            gates,
            local: local.filter(|&l| l < replicas),
            forwarded: AtomicU64::new(0),
        }
    }

    /// The degenerate single-shard router (a plain concurrency gate).
    pub fn single() -> ShardRouter {
        ShardRouter::new(1, None, default_threads())
    }

    /// Execute `f` under the gate of the shard owning `canonical`,
    /// counting it (and whether it was owned elsewhere).
    pub fn run_on<T>(&self, canonical: &str, f: impl FnOnce() -> T) -> T {
        let shard = self.ring.owner_of(canonical);
        if self.local.is_some_and(|local| local != shard) {
            self.forwarded.fetch_add(1, Ordering::Relaxed);
        }
        let gate = &self.gates[shard];
        gate.units.fetch_add(1, Ordering::Relaxed);
        gate.run(f)
    }

    pub fn stats(&self) -> ShardStats {
        ShardStats {
            replicas: self.ring.replicas(),
            local: self.local,
            units: self.gates.iter().map(|g| g.units.load(Ordering::Relaxed)).collect(),
            forwarded: self.forwarded.load(Ordering::Relaxed),
        }
    }

    /// The `shards` section of `/v1/metrics`.
    pub fn to_json(&self) -> Json {
        let s = self.stats();
        Json::obj(vec![
            ("replicas", Json::num(s.replicas as f64)),
            ("local", s.local.map_or(Json::Null, |l| Json::num(l as f64))),
            ("forwarded_units", Json::num(s.forwarded as f64)),
            ("units", Json::Arr(s.units.iter().map(|&u| Json::num(u as f64)).collect())),
        ])
    }

    /// Prometheus text-exposition lines for the same counters.
    pub fn to_prometheus(&self) -> String {
        let s = self.stats();
        let mut out = String::new();
        out.push_str("# HELP tcserved_shard_units_total Units executed per owning shard.\n");
        out.push_str("# TYPE tcserved_shard_units_total counter\n");
        for (shard, units) in s.units.iter().enumerate() {
            out.push_str(&format!("tcserved_shard_units_total{{shard=\"{shard}\"}} {units}\n"));
        }
        out.push_str(
            "# HELP tcserved_shard_forwarded_units_total Units owned by a non-local shard.\n",
        );
        out.push_str("# TYPE tcserved_shard_forwarded_units_total counter\n");
        out.push_str(&format!("tcserved_shard_forwarded_units_total {}\n", s.forwarded));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("cell|backend=sim|device=a100|spec=k{i}|w=4|i=2")).collect()
    }

    #[test]
    fn ring_is_deterministic_and_covers_every_shard() {
        let a = HashRing::new(4);
        let b = HashRing::new(4);
        let mut per_shard = [0usize; 4];
        for k in keys(2000) {
            let owner = a.owner_of(&k);
            assert_eq!(owner, b.owner_of(&k), "ring must be deterministic for {k}");
            per_shard[owner] += 1;
        }
        // vnodes keep the split roughly balanced: every shard owns a
        // real share of the keyspace
        for (shard, &n) in per_shard.iter().enumerate() {
            assert!(n > 200, "shard {shard} owns only {n}/2000 keys: {per_shard:?}");
        }
    }

    #[test]
    fn growing_the_ring_remaps_only_a_fraction_of_keys() {
        let four = HashRing::new(4);
        let five = HashRing::new(5);
        let keys = keys(2000);
        let moved = keys.iter().filter(|k| four.owner_of(k) != five.owner_of(k)).count();
        // consistent hashing: ~1/5 of keys move to the new shard; far
        // from the ~4/5 a modulo partition would reshuffle
        assert!(moved > 0, "the new shard must take some keys");
        assert!(moved < 2000 * 2 / 5, "{moved}/2000 keys moved — not a consistent ring");
        // every key that moved, moved *to* the new shard
        for k in &keys {
            if four.owner_of(k) != five.owner_of(k) {
                assert_eq!(five.owner_of(k), 4, "{k} moved between old shards");
            }
        }
    }

    #[test]
    fn router_counts_per_shard_units_and_forwards() {
        let router = ShardRouter::new(4, Some(1), 8);
        let ring = HashRing::new(4);
        let keys = keys(64);
        let mut expect_forwarded = 0;
        for k in &keys {
            let owner = router.run_on(k, || ring.owner_of(k));
            assert_eq!(owner, ring.owner_of(k));
            if owner != 1 {
                expect_forwarded += 1;
            }
        }
        let s = router.stats();
        assert_eq!(s.units.iter().sum::<u64>(), 64);
        assert_eq!(s.forwarded, expect_forwarded);
        assert_eq!((s.replicas, s.local), (4, Some(1)));
        // the single-shard router forwards nothing and owns everything
        let single = ShardRouter::single();
        for k in &keys {
            single.run_on(k, || ());
        }
        let s = single.stats();
        assert_eq!((s.replicas, s.local, s.forwarded), (1, None, 0));
        assert_eq!(s.units, vec![64]);
    }

    #[test]
    fn gate_serializes_beyond_its_permit_budget() {
        // 1 permit per shard: concurrent units on one shard's key must
        // never overlap
        let router = ShardRouter::new(1, None, 1);
        let running = AtomicU64::new(0);
        let peak = AtomicU64::new(0);
        std::thread::scope(|scope| {
            for _ in 0..6 {
                scope.spawn(|| {
                    router.run_on("cell|same-key", || {
                        let now = running.fetch_add(1, Ordering::SeqCst) + 1;
                        peak.fetch_max(now, Ordering::SeqCst);
                        std::thread::sleep(std::time::Duration::from_millis(2));
                        running.fetch_sub(1, Ordering::SeqCst);
                    });
                });
            }
        });
        assert_eq!(peak.load(Ordering::SeqCst), 1);
        let s = router.stats();
        assert_eq!(s.units, vec![6]);
    }
}
