//! Content-addressed result cache with single-flight request coalescing.
//!
//! Every cacheable unit of work (an experiment run, an ad-hoc sweep) is
//! identified by a canonical coordinate string — crate version,
//! experiment id, backend name, device, instruction — hashed (FNV-1a 64)
//! into its content address. Lookups go memory → disk → compute:
//!
//! * **memory**: a mutex-guarded LRU map (capacity-bounded, O(n) evict —
//!   the key space is tiny: 19 experiments x backends + sweeps);
//! * **disk**: optional write-through store under `results/cache/`,
//!   one `<hash>.json` per entry, surviving restarts;
//! * **compute**: exactly one thread runs the closure per key at a time
//!   — concurrent requesters of the same key block on a condvar and
//!   receive the leader's result (single-flight dedup), so a stampede
//!   of identical requests costs one simulation.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex};

/// 64-bit FNV-1a (re-exported from [`crate::util`] — the same hash keys
/// the in-process cell cache, so both cache layers share one content
/// address function).
pub use crate::util::fnv1a;

/// The content address of one cacheable computation.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// Human-readable canonical coordinates (stable field order).
    pub canonical: String,
    /// Hex content address: `fnv1a(canonical)`.
    pub hash: String,
}

/// Build the canonical key for (experiment, backend, device, instruction)
/// under this crate version. Experiments that bind their own devices
/// pass `"-"` for the free coordinates.
pub fn cache_key(experiment: &str, backend: &str, device: &str, instr: &str) -> CacheKey {
    let canonical = format!(
        "v={}|exp={}|backend={}|device={}|instr={}",
        env!("CARGO_PKG_VERSION"),
        experiment,
        backend,
        device,
        instr
    );
    let hash = format!("{:016x}", fnv1a(canonical.as_bytes()));
    CacheKey { canonical, hash }
}

/// Where a served result came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Origin {
    /// In-memory LRU hit.
    Memory,
    /// On-disk store hit (promoted into memory).
    Disk,
    /// This request ran the computation.
    Computed,
    /// Another in-flight request computed it; this one waited.
    Coalesced,
}

impl Origin {
    pub fn name(self) -> &'static str {
        match self {
            Origin::Memory => "memory",
            Origin::Disk => "disk",
            Origin::Computed => "computed",
            Origin::Coalesced => "coalesced",
        }
    }
}

struct Entry {
    value: String,
    last_used: u64,
}

struct Inner {
    map: HashMap<String, Entry>,
    tick: u64,
    evictions: u64,
}

struct Flight {
    result: Mutex<Option<Result<String, String>>>,
    done: Condvar,
}

/// Cache occupancy counters for `/v1/metrics`.
#[derive(Debug, Clone, Copy)]
pub struct CacheStats {
    pub entries: usize,
    pub capacity: usize,
    pub evictions: u64,
}

pub struct ResultCache {
    capacity: usize,
    disk_dir: Option<PathBuf>,
    // Invariant: lock unwraps on both mutexes only fail on poisoning,
    // which is unreachable — the critical sections are map bookkeeping
    // only, and `compute` closures run outside them.
    inner: Mutex<Inner>,
    inflight: Mutex<HashMap<String, Arc<Flight>>>,
}

impl ResultCache {
    pub fn new(capacity: usize, disk_dir: Option<PathBuf>) -> ResultCache {
        ResultCache {
            capacity: capacity.max(1),
            disk_dir,
            inner: Mutex::new(Inner { map: HashMap::new(), tick: 0, evictions: 0 }),
            inflight: Mutex::new(HashMap::new()),
        }
    }

    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock().unwrap();
        CacheStats { entries: inner.map.len(), capacity: self.capacity, evictions: inner.evictions }
    }

    /// Is the key already materialized (memory or disk)?
    pub fn contains(&self, key: &CacheKey) -> bool {
        if self.inner.lock().unwrap().map.contains_key(&key.hash) {
            return true;
        }
        self.disk_path(key).map(|p| p.is_file()).unwrap_or(false)
    }

    fn disk_path(&self, key: &CacheKey) -> Option<PathBuf> {
        self.disk_dir.as_ref().map(|d| d.join(format!("{}.json", key.hash)))
    }

    fn lookup_memory(&self, key: &CacheKey) -> Option<String> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let entry = inner.map.get_mut(&key.hash)?;
        entry.last_used = tick;
        Some(entry.value.clone())
    }

    fn insert_memory(&self, key: &CacheKey, value: String) {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        inner.map.insert(key.hash.clone(), Entry { value, last_used: tick });
        while inner.map.len() > self.capacity {
            let oldest = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
                .expect("non-empty map");
            inner.map.remove(&oldest);
            inner.evictions += 1;
        }
    }

    fn lookup_disk(&self, key: &CacheKey) -> Option<String> {
        let value = std::fs::read_to_string(self.disk_path(key)?).ok()?;
        // Every cached value is a JSON document; a truncated or corrupt
        // file (crash mid-write, concurrent writers) must not be served
        // — and must not shadow recomputation — forever.
        if crate::util::Json::parse(&value).is_err() {
            return None;
        }
        Some(value)
    }

    fn write_disk(&self, key: &CacheKey, value: &str) {
        let Some(path) = self.disk_path(key) else { return };
        if let Some(parent) = path.parent() {
            if std::fs::create_dir_all(parent).is_err() {
                return;
            }
        }
        // Best-effort (the disk store is an optimization, not a ledger),
        // but atomic: write a temp file and rename it into place so a
        // crash mid-write never leaves a truncated entry.
        let tmp = path.with_extension("tmp");
        if std::fs::write(&tmp, value).is_ok() {
            let _ = std::fs::rename(&tmp, &path);
        }
    }

    /// Serve `key` from cache, or run `compute` — at most once across
    /// all concurrent callers of the same key (single-flight).
    ///
    /// Invariant: `compute` must not panic (callers wrap fallible work
    /// in `catch_unwind` and return `Err`); a panicking closure would
    /// strand coalesced waiters on the condvar.
    pub fn get_or_compute<F>(&self, key: &CacheKey, compute: F) -> (Result<String, String>, Origin)
    where
        F: FnOnce() -> Result<String, String>,
    {
        self.get_or_compute_with(key, || compute().map(|v| (v, true)))
    }

    /// [`ResultCache::get_or_compute`] for computations that may
    /// produce a valid but *non-cacheable* value — a deadline-degraded
    /// payload that must not shadow the bit-exact simulated answer for
    /// later, un-hurried requests. `compute` returns `(value,
    /// cacheable)`; only cacheable values enter the memory/disk layers.
    /// Coalesced waiters of the same flight still receive the leader's
    /// value either way (they asked while it was being produced); the
    /// key simply stays vacant afterwards, so the next request
    /// recomputes.
    pub fn get_or_compute_with<F>(
        &self,
        key: &CacheKey,
        compute: F,
    ) -> (Result<String, String>, Origin)
    where
        F: FnOnce() -> Result<(String, bool), String>,
    {
        if let Some(v) = self.lookup_memory(key) {
            return (Ok(v), Origin::Memory);
        }
        if let Some(v) = self.lookup_disk(key) {
            self.insert_memory(key, v.clone());
            return (Ok(v), Origin::Disk);
        }

        let (flight, leader) = {
            let mut inflight = self.inflight.lock().unwrap();
            match inflight.get(&key.hash) {
                Some(f) => (Arc::clone(f), false),
                None => {
                    let f = Arc::new(Flight {
                        result: Mutex::new(None),
                        done: Condvar::new(),
                    });
                    inflight.insert(key.hash.clone(), Arc::clone(&f));
                    (f, true)
                }
            }
        };

        if !leader {
            let mut guard = flight.result.lock().unwrap();
            while guard.is_none() {
                guard = flight.done.wait(guard).unwrap();
            }
            return (guard.clone().expect("flight resolved"), Origin::Coalesced);
        }

        // Leader path. Re-check memory first: a previous leader may have
        // finished between our miss and our in-flight registration.
        let (result, origin) = match self.lookup_memory(key) {
            Some(v) => (Ok(v), Origin::Memory),
            None => {
                let result = match compute() {
                    Ok((v, cacheable)) => {
                        if cacheable {
                            self.insert_memory(key, v.clone());
                            self.write_disk(key, &v);
                        }
                        Ok(v)
                    }
                    Err(e) => Err(e),
                };
                (result, Origin::Computed)
            }
        };

        *flight.result.lock().unwrap() = Some(result.clone());
        flight.done.notify_all();
        self.inflight.lock().unwrap().remove(&key.hash);
        (result, origin)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn key(tag: &str) -> CacheKey {
        cache_key(tag, "native", "-", "-")
    }

    #[test]
    fn content_address_is_stable_and_distinct() {
        let a = cache_key("t3", "native", "-", "-");
        let b = cache_key("t3", "native", "-", "-");
        let c = cache_key("t3", "auto", "-", "-");
        assert_eq!(a, b);
        assert_ne!(a.hash, c.hash);
        assert_eq!(a.hash.len(), 16);
        assert!(a.canonical.contains("exp=t3"));
    }

    #[test]
    fn compute_once_then_memory_hits() {
        let cache = ResultCache::new(8, None);
        let calls = AtomicUsize::new(0);
        let k = key("a");
        let (r1, o1) = cache.get_or_compute(&k, || {
            calls.fetch_add(1, Ordering::SeqCst);
            Ok("value".to_string())
        });
        assert_eq!(r1.unwrap(), "value");
        assert_eq!(o1, Origin::Computed);
        let (r2, o2) = cache.get_or_compute(&k, || {
            calls.fetch_add(1, Ordering::SeqCst);
            Ok("other".to_string())
        });
        assert_eq!(r2.unwrap(), "value");
        assert_eq!(o2, Origin::Memory);
        assert_eq!(calls.load(Ordering::SeqCst), 1);
        assert!(cache.contains(&k));
        assert!(!cache.contains(&key("b")));
    }

    #[test]
    fn errors_are_not_cached() {
        let cache = ResultCache::new(8, None);
        let k = key("err");
        let (r, o) = cache.get_or_compute(&k, || Err("boom".to_string()));
        assert_eq!(r.unwrap_err(), "boom");
        assert_eq!(o, Origin::Computed);
        let (r, o) = cache.get_or_compute(&k, || Ok("recovered".to_string()));
        assert_eq!(r.unwrap(), "recovered");
        assert_eq!(o, Origin::Computed);
    }

    #[test]
    fn non_cacheable_values_are_served_but_not_stored() {
        let cache = ResultCache::new(8, None);
        let k = key("degraded");
        let (r, o) = cache.get_or_compute_with(&k, || Ok(("degraded payload".to_string(), false)));
        assert_eq!(r.unwrap(), "degraded payload");
        assert_eq!(o, Origin::Computed);
        assert!(!cache.contains(&k), "non-cacheable values must leave the key vacant");
        // the next request recomputes and may cache normally
        let (r, o) = cache.get_or_compute_with(&k, || Ok(("full payload".to_string(), true)));
        assert_eq!(r.unwrap(), "full payload");
        assert_eq!(o, Origin::Computed);
        assert!(cache.contains(&k));
    }

    #[test]
    fn lru_evicts_oldest() {
        let cache = ResultCache::new(2, None);
        for tag in ["a", "b"] {
            cache.get_or_compute(&key(tag), || Ok(tag.to_string()));
        }
        // touch "a" so "b" is the LRU victim
        assert_eq!(cache.get_or_compute(&key("a"), || Ok("x".into())).1, Origin::Memory);
        cache.get_or_compute(&key("c"), || Ok("c".to_string()));
        let stats = cache.stats();
        assert_eq!(stats.entries, 2);
        assert_eq!(stats.evictions, 1);
        assert!(cache.contains(&key("a")));
        assert!(!cache.contains(&key("b")));
        assert!(cache.contains(&key("c")));
    }

    #[test]
    fn single_flight_coalesces_concurrent_requests() {
        let cache = ResultCache::new(8, None);
        let calls = AtomicUsize::new(0);
        let k = key("slow");
        let origins: Vec<Origin> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    scope.spawn(|| {
                        let (r, o) = cache.get_or_compute(&k, || {
                            calls.fetch_add(1, Ordering::SeqCst);
                            std::thread::sleep(std::time::Duration::from_millis(50));
                            Ok("slow result".to_string())
                        });
                        assert_eq!(r.unwrap(), "slow result");
                        o
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(calls.load(Ordering::SeqCst), 1, "exactly one computation");
        assert_eq!(origins.iter().filter(|o| **o == Origin::Computed).count(), 1);
        assert!(origins
            .iter()
            .all(|o| matches!(o, Origin::Computed | Origin::Coalesced | Origin::Memory)));
    }

    #[test]
    fn disk_store_survives_a_fresh_cache() {
        let dir = std::env::temp_dir().join(format!("tcbench_cache_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let k = key("persist");
        let value = r#"{"report":"persisted"}"#;
        {
            let cache = ResultCache::new(8, Some(dir.clone()));
            cache.get_or_compute(&k, || Ok(value.to_string()));
        }
        let fresh = ResultCache::new(8, Some(dir.clone()));
        assert!(fresh.contains(&k));
        let (r, o) = fresh.get_or_compute(&k, || Err("should not recompute".to_string()));
        assert_eq!(r.unwrap(), value);
        assert_eq!(o, Origin::Disk);
        // now promoted to memory
        let (_, o) = fresh.get_or_compute(&k, || Err("no".to_string()));
        assert_eq!(o, Origin::Memory);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_disk_entries_are_ignored_and_recomputed() {
        let dir =
            std::env::temp_dir().join(format!("tcbench_cache_corrupt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let k = key("corrupt");
        // simulate a crash mid-write: truncated, unparseable JSON
        std::fs::write(dir.join(format!("{}.json", k.hash)), "{\"trunc").unwrap();
        let cache = ResultCache::new(8, Some(dir.clone()));
        let (r, o) = cache.get_or_compute(&k, || Ok("{\"ok\":true}".to_string()));
        assert_eq!(r.unwrap(), "{\"ok\":true}");
        assert_eq!(o, Origin::Computed, "corrupt entry must not be served");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
