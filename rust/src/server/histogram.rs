//! Lock-free fixed-bucket latency histograms for tcserved.
//!
//! A [`Histogram`] is a fixed array of power-of-two microsecond buckets
//! backed by relaxed atomics: recording is wait-free (one index
//! computation plus three `fetch_add`s, no allocation, no lock), so the
//! request hot path can time every phase without contention. Bucket `i`
//! covers `[2^(i-1), 2^i)` µs (bucket 0 is `[0, 1)`; the last bucket is
//! the overflow catch-all), and quantiles interpolate linearly inside
//! the covering bucket — the standard fixed-boundary estimate, exact at
//! bucket edges and within one bucket width everywhere else.
//!
//! [`HistogramSet`] is a small labeled family (per endpoint, per
//! compute phase) resolving dynamic labels through the metrics interner
//! so lookups never allocate in steady state.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::Json;

use super::metrics::intern;

/// Bucket count, overflow included: the regular buckets span
/// `[0, 2^(BUCKETS-2))` µs — just over a second — which covers every
/// phase this server times (whole campaign warms excepted, and those
/// land in the overflow bucket rather than getting lost).
pub const BUCKETS: usize = 22;

/// Exclusive upper bound of bucket `i` in µs (`u64::MAX` for the
/// overflow bucket).
pub fn bucket_bound(i: usize) -> u64 {
    if i >= BUCKETS - 1 {
        u64::MAX
    } else {
        1u64 << i
    }
}

fn bucket_index(us: u64) -> usize {
    match us {
        0 => 0,
        v => ((v.ilog2() as usize) + 1).min(BUCKETS - 1),
    }
}

/// One lock-free latency histogram (see the module docs for the bucket
/// layout).
#[derive(Debug, Default)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Record one observation of `us` microseconds.
    pub fn record_us(&self, us: u64) {
        self.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Point-in-time copy of the per-bucket counts.
    pub fn bucket_counts(&self) -> [u64; BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    /// Estimated `q`-quantile (`0.0..=1.0`) in µs; 0 when empty. Linear
    /// interpolation inside the covering bucket; the overflow bucket
    /// reports its lower bound (the estimate is then a floor).
    pub fn quantile(&self, q: f64) -> f64 {
        let counts = self.bucket_counts();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).max(1.0);
        let mut seen = 0u64;
        for (i, &n) in counts.iter().enumerate() {
            if n == 0 {
                continue;
            }
            if seen as f64 + n as f64 >= target {
                let lo = if i == 0 { 0 } else { 1u64 << (i - 1) };
                if i == BUCKETS - 1 {
                    return lo as f64;
                }
                let hi = 1u64 << i;
                let into = (target - seen as f64) / n as f64;
                return lo as f64 + into * (hi - lo) as f64;
            }
            seen += n;
        }
        0.0
    }

    /// `{count, mean_us, p50_us, p95_us, p99_us, buckets}` — buckets as
    /// `[le_us, count]` pairs, zero buckets omitted.
    pub fn to_json(&self) -> Json {
        let count = self.count();
        let mean = if count == 0 { 0.0 } else { self.sum_us() as f64 / count as f64 };
        let buckets: Vec<Json> = self
            .bucket_counts()
            .iter()
            .enumerate()
            .filter(|(_, &n)| n > 0)
            .map(|(i, &n)| {
                let le = if i >= BUCKETS - 1 {
                    Json::str("+Inf")
                } else {
                    Json::num(bucket_bound(i) as f64)
                };
                Json::Arr(vec![le, Json::num(n as f64)])
            })
            .collect();
        Json::obj(vec![
            ("count", Json::num(count as f64)),
            ("mean_us", Json::num(mean)),
            ("p50_us", Json::num(self.quantile(0.50))),
            ("p95_us", Json::num(self.quantile(0.95))),
            ("p99_us", Json::num(self.quantile(0.99))),
            ("buckets", Json::Arr(buckets)),
        ])
    }
}

/// A labeled family of histograms (label → [`Histogram`]); labels are
/// interned, so the family size is bounded by the distinct-label set.
/// The lock only guards the label map — recording into a resolved
/// histogram is lock-free.
#[derive(Debug, Default)]
pub struct HistogramSet {
    by_label: Mutex<BTreeMap<&'static str, Arc<Histogram>>>,
}

impl HistogramSet {
    pub fn new() -> HistogramSet {
        HistogramSet::default()
    }

    /// The histogram for `label`, created on first use.
    pub fn get(&self, label: &str) -> Arc<Histogram> {
        // Invariant: lock unwraps here and in `snapshot` only fail on
        // poisoning; nothing under the lock can panic (map lookup,
        // insert, and Arc clones).
        let mut map = self.by_label.lock().unwrap();
        if let Some(h) = map.get(label) {
            return Arc::clone(h);
        }
        let h = Arc::new(Histogram::new());
        map.insert(intern(label), Arc::clone(&h));
        h
    }

    pub fn record_us(&self, label: &str, us: u64) {
        self.get(label).record_us(us);
    }

    /// Point-in-time view of every labeled histogram.
    pub fn snapshot(&self) -> Vec<(&'static str, Arc<Histogram>)> {
        self.by_label
            .lock()
            .unwrap()
            .iter()
            .map(|(&label, h)| (label, Arc::clone(h)))
            .collect()
    }

    /// `{label: histogram}` over the family.
    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.snapshot()
                .into_iter()
                .map(|(label, h)| (label.to_string(), h.to_json()))
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_layout_is_power_of_two_microseconds() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(1023), 10);
        assert_eq!(bucket_index(1024), 11);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
        // bounds and indices agree: v < bucket_bound(i) for v in bucket i
        for v in [0u64, 1, 7, 100, 4096, 1 << 20] {
            assert!(v < bucket_bound(bucket_index(v)), "{v}");
        }
    }

    #[test]
    fn quantiles_interpolate_within_buckets() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0.0); // empty
        for _ in 0..100 {
            h.record_us(3); // bucket [2, 4)
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.sum_us(), 300);
        let p50 = h.quantile(0.5);
        assert!((2.0..4.0).contains(&p50), "{p50}");
        // the p99 stays in the same (only) bucket
        assert!((2.0..=4.0).contains(&h.quantile(0.99)));

        // a bimodal distribution: p50 in the low mode, p99 in the high
        let h = Histogram::new();
        for _ in 0..90 {
            h.record_us(10); // bucket [8, 16)
        }
        for _ in 0..10 {
            h.record_us(5000); // bucket [4096, 8192)
        }
        assert!((8.0..16.0).contains(&h.quantile(0.5)), "{}", h.quantile(0.5));
        assert!((4096.0..8192.0).contains(&h.quantile(0.99)), "{}", h.quantile(0.99));
    }

    #[test]
    fn overflow_bucket_reports_its_floor() {
        let h = Histogram::new();
        h.record_us(u64::MAX);
        assert_eq!(h.bucket_counts()[BUCKETS - 1], 1);
        assert_eq!(h.quantile(0.5), (1u64 << (BUCKETS - 2)) as f64);
    }

    #[test]
    fn labeled_sets_share_histograms_per_label() {
        let set = HistogramSet::new();
        // dynamic (String) labels resolve to one interned histogram
        set.record_us(&String::from("parse"), 10);
        set.record_us("parse", 20);
        set.record_us("simulate", 1000);
        let snap = set.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(set.get("parse").count(), 2);
        assert_eq!(set.get("simulate").count(), 1);

        let j = set.to_json();
        assert_eq!(j.get("parse").unwrap().get_u64("count"), Some(2));
        assert!((j.get("parse").unwrap().get_f64("mean_us").unwrap() - 15.0).abs() < 1e-9);
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn json_shape_lists_only_populated_buckets() {
        let h = Histogram::new();
        h.record_us(0);
        h.record_us(100);
        let j = h.to_json();
        let buckets = j.get("buckets").unwrap().as_arr().unwrap();
        assert_eq!(buckets.len(), 2);
        assert_eq!(buckets[0].as_arr().unwrap()[0].as_f64(), Some(1.0));
        assert_eq!(buckets[1].as_arr().unwrap()[0].as_f64(), Some(128.0));
        assert!(Json::parse(&j.to_string()).is_ok());
    }
}
