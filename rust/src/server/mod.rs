//! tcserved — the embedded campaign-serving subsystem.
//!
//! A dependency-light HTTP/1.1 service (std `TcpListener` + the
//! coordinator's scoped-thread worker pool; no external crates, like
//! `coordinator::pool`) that turns the one-shot `repro` campaign into a
//! query layer: expensive simulator/numeric computations run at most
//! once per content address and are then served from cache.
//!
//! ```text
//! repro serve [--addr 127.0.0.1:8321] [--threads N] [--warm]
//!
//! GET  /healthz             liveness + registry size
//! GET  /v1/experiments      the 19 registered experiments (+cache state)
//! GET  /v1/devices          calibrated devices
//! GET  /v1/run/<id>         one experiment, cached  [?backend=native|pjrt|auto]
//! GET  /v1/sweep            ad-hoc (ILP, warps) sweep [?device=&instr=&sparse=]
//! POST /v1/plan             run a JSON BenchPlan; batched, cached per unit
//! GET  /v1/metrics          request counts, cache hit rate, compute times,
//!                           latency histograms (JSON)
//! GET  /metrics             the same counters in Prometheus text format
//! ```
//!
//! Layering: [`http`] parses/writes the wire format, [`router`] maps
//! requests onto the campaign ([`cache`]-backed, single-flight),
//! [`metrics`] counts everything (with [`histogram`] supplying the
//! lock-free latency histograms), and this module owns sockets and
//! threads.

pub mod cache;
pub mod histogram;
pub mod http;
pub mod metrics;
pub mod router;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::{default_threads, EXPERIMENTS};

use cache::ResultCache;
use http::Response;
use router::AppState;

/// tcserved configuration (CLI flags map onto this 1:1).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bind address; port 0 picks an ephemeral port (used by tests).
    pub addr: String,
    /// Connection worker threads (also the `--warm` pool width).
    pub threads: usize,
    /// Precompute all registered experiments before accepting traffic.
    pub warm: bool,
    /// On-disk cache directory (`None` disables persistence).
    pub disk_cache: Option<PathBuf>,
    /// In-memory LRU capacity (entries).
    pub cache_capacity: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:8321".to_string(),
            threads: default_threads(),
            warm: false,
            disk_cache: Some(PathBuf::from("results/cache")),
            cache_capacity: 256,
        }
    }
}

/// A running tcserved instance.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    state: Arc<AppState>,
}

impl Server {
    /// Bind, optionally warm the cache, and start accepting connections
    /// on background threads. Returns once the socket is live.
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(cfg.addr.as_str())
            .with_context(|| format!("binding tcserved to {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        let state = Arc::new(AppState::new(ResultCache::new(
            cfg.cache_capacity,
            cfg.disk_cache.clone(),
        )));
        if cfg.warm {
            let warmed = router::warm(&state, cfg.threads);
            eprintln!("[tcserved] warmed {warmed}/{} experiments", EXPERIMENTS.len());
        }

        let (tx, rx) = mpsc::channel::<TcpStream>();
        let rx = Arc::new(Mutex::new(rx));
        for _ in 0..cfg.threads.max(1) {
            let rx = Arc::clone(&rx);
            let state = Arc::clone(&state);
            thread::spawn(move || worker_loop(rx, state));
        }

        let shutdown = Arc::new(AtomicBool::new(false));
        let stop = Arc::clone(&shutdown);
        let acceptor = thread::spawn(move || {
            for conn in listener.incoming() {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                if let Ok(stream) = conn {
                    if tx.send(stream).is_err() {
                        break;
                    }
                }
            }
            // dropping `tx` lets the workers drain and exit
        });

        Ok(Server { addr, shutdown, acceptor: Some(acceptor), state })
    }

    /// The bound address (resolves the ephemeral port for tests).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Shared state (cache + metrics) of this instance.
    pub fn state(&self) -> &AppState {
        &self.state
    }

    /// Block on the acceptor (i.e. forever, for the CLI).
    pub fn join(mut self) {
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting connections and join the acceptor. In-flight
    /// worker requests finish on their own threads.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // unblock the acceptor with a no-op connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
    }
}

fn worker_loop(rx: Arc<Mutex<mpsc::Receiver<TcpStream>>>, state: Arc<AppState>) {
    loop {
        // Lock only around `recv`: the guard is a temporary of this
        // statement, so request handling below runs unlocked and
        // connections are processed concurrently across workers.
        let stream = rx.lock().unwrap().recv();
        match stream {
            Ok(s) => handle_connection(&state, s),
            Err(_) => break, // acceptor gone
        }
    }
}

fn handle_connection(state: &AppState, mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(60)));
    let _ = stream.set_nodelay(true);
    let t_parse = std::time::Instant::now();
    let parsed = http::read_request(&mut stream);
    let response = match parsed {
        Ok(req) => {
            state
                .metrics
                .record_phase("parse", t_parse.elapsed().as_micros() as u64);
            router::handle(state, &req)
        }
        // A connection closed without sending anything (port probe,
        // stop()'s wake-up socket) is not a request — no response to
        // write, nothing to count.
        Err(e) if e.starts_with("empty request") => return,
        Err(e) => {
            // keep requests_total/by_endpoint reconciled with by_status
            state.metrics.record_request("malformed");
            Response::error(400, e)
        }
    };
    state.metrics.record_status(response.status);
    let _ = response.write_to(&mut stream);
}

/// CLI entrypoint: start and serve until the process is killed.
pub fn serve_blocking(cfg: ServerConfig) -> Result<()> {
    let threads = cfg.threads;
    let server = Server::start(cfg)?;
    eprintln!(
        "[tcserved] listening on http://{} ({threads} workers, {} experiments registered)",
        server.addr(),
        EXPERIMENTS.len()
    );
    eprintln!(
        "[tcserved] endpoints: /healthz /v1/experiments /v1/devices /v1/run/<id> /v1/sweep \
         POST:/v1/plan /v1/metrics /metrics"
    );
    server.join();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn start_binds_ephemeral_port_and_stops() {
        let server = Server::start(ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            threads: 2,
            warm: false,
            disk_cache: None,
            cache_capacity: 8,
        })
        .unwrap();
        let addr = server.addr();
        assert_ne!(addr.port(), 0);
        assert_eq!(server.state().metrics.requests_total(), 0);
        // stop() must not hang (it unblocks the acceptor itself)
        server.stop();
    }
}
