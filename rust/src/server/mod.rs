//! tcserved — the embedded campaign-serving subsystem.
//!
//! A dependency-light HTTP/1.1 service (std `TcpListener` + the
//! coordinator's scoped-thread worker pool; no external crates, like
//! `coordinator::pool`) that turns the one-shot `repro` campaign into a
//! query layer: expensive simulator/numeric computations run at most
//! once per content address and are then served from cache.
//!
//! ```text
//! repro serve [--addr 127.0.0.1:8321] [--threads N] [--warm]
//!             [--cell-store DIR|none] [--replicas N | --shard i/N]
//!             [--queue-depth N] [--chaos SPEC --chaos-seed N]
//!
//! GET  /healthz             liveness + registry size
//! GET  /readyz              readiness: 503 while warming or queue-saturated
//! GET  /v1/experiments      the 19 registered experiments (+cache state)
//! GET  /v1/devices          calibrated devices
//! POST /v1/run/<id>         one experiment, cached  {"backend": ...}
//! POST /v1/sweep            ad-hoc (ILP, warps) sweep {"instr", "device", ...}
//! POST /v1/plan             run a JSON BenchPlan; batched, cached per unit
//! POST /v1/lint             static diagnostics for a BenchPlan
//! GET  /v1/metrics          request counts, cache + cell-store hit rates,
//!                           per-shard load, latency histograms (JSON)
//! GET  /metrics             the same counters in Prometheus text format
//! ```
//!
//! Every JSON endpoint answers in the versioned `tcserved/v1` envelope
//! ([`http::SCHEMA`]); `/v1/run/<id>` and `/v1/sweep` also keep their
//! original GET+query form as a deprecated alias (answered with a
//! `Deprecation: true` header).
//!
//! Layering: [`http`] parses/writes the wire format, [`router`] maps
//! requests onto the campaign ([`cache`]-backed, single-flight),
//! [`shard`] consistent-hashes plan units across replicas, [`metrics`]
//! counts everything (with [`histogram`] supplying the lock-free
//! latency histograms), and this module owns sockets and threads. The
//! accept queue is bounded: when every worker is busy and the queue is
//! full, new connections get an immediate `503` (`overloaded`, with
//! `Retry-After`) instead of unbounded buffering.

pub mod cache;
pub mod histogram;
pub mod http;
pub mod metrics;
pub mod router;
pub mod shard;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::{default_threads, EXPERIMENTS};
use crate::workload::{CellCache, CellStore};

use cache::ResultCache;
use http::Response;
use router::AppState;
use shard::ShardRouter;

/// tcserved configuration (CLI flags map onto this 1:1).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bind address; port 0 picks an ephemeral port (used by tests).
    pub addr: String,
    /// Connection worker threads (also the `--warm` pool width).
    pub threads: usize,
    /// Precompute all registered experiments before accepting traffic.
    pub warm: bool,
    /// On-disk unit result cache directory (`None` disables persistence).
    pub disk_cache: Option<PathBuf>,
    /// In-memory LRU capacity (entries).
    pub cache_capacity: usize,
    /// Shared on-disk cell store directory (`None` disables it). Point
    /// every replica of a fleet at the same directory: cells simulated
    /// by one replica are then warm disk hits for all of them, and the
    /// store survives restarts.
    pub cell_store: Option<PathBuf>,
    /// Number of shards this process hosts (`--replicas N`): the
    /// in-process router partitions plan units across N gates.
    pub replicas: usize,
    /// `Some((i, n))` when this process is shard `i` of an n-replica
    /// multi-process fleet (`--shard i/n`). Overrides `replicas`.
    pub shard: Option<(usize, usize)>,
    /// Accepted-connection queue depth; beyond it new connections are
    /// answered `503` + `Retry-After` instead of queueing unboundedly.
    pub queue_depth: usize,
    /// tcchaos fault plan (`--chaos "store.read:err@0.05,..."`); `None`
    /// (the default) injects nothing. See [`crate::chaos`].
    pub chaos: Option<String>,
    /// Seed of the chaos PRNG (`--chaos-seed`), so fault sequences are
    /// reproducible run to run.
    pub chaos_seed: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:8321".to_string(),
            threads: default_threads(),
            warm: false,
            disk_cache: Some(PathBuf::from("results/cache")),
            cache_capacity: 256,
            cell_store: Some(PathBuf::from("results/cells")),
            replicas: 1,
            shard: None,
            queue_depth: 256,
            chaos: None,
            chaos_seed: 0,
        }
    }
}

/// A running tcserved instance.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    state: Arc<AppState>,
}

impl Server {
    /// Bind, optionally warm the cache, and start accepting connections
    /// on background threads. Returns once the socket is live.
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        if let Some(spec) = &cfg.chaos {
            // install before anything can race a fault site; a bad spec
            // (or a second install in this process) is a startup error,
            // never a silently fault-free server
            crate::chaos::install(spec, cfg.chaos_seed)
                .map_err(|e| anyhow::anyhow!("--chaos: {e}"))?;
        }
        let listener = TcpListener::bind(cfg.addr.as_str())
            .with_context(|| format!("binding tcserved to {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        if let Some(dir) = &cfg.cell_store {
            // the store is process-wide (the cell cache is a process
            // singleton); attaching twice is a no-op with a note
            if !CellCache::global().attach_store(CellStore::new(dir.clone())) {
                eprintln!(
                    "[tcserved] cell store already attached for this process; \
                     ignoring {}",
                    dir.display()
                );
            }
        }
        let (local, replicas) = match cfg.shard {
            Some((i, n)) => (Some(i), n),
            None => (None, cfg.replicas),
        };
        let state = Arc::new(AppState::with_shards(
            ResultCache::new(cfg.cache_capacity, cfg.disk_cache.clone()),
            ShardRouter::new(replicas, local, cfg.threads.max(1)),
        ));
        state.readiness.set_queue_capacity(cfg.queue_depth.max(1));
        if cfg.warm {
            // Warm in the background so the socket is live immediately;
            // `/readyz` answers 503 until the warm pass finishes (the
            // liveness probe `/healthz` answers 200 throughout).
            state.readiness.set_warming(true);
            let warm_state = Arc::clone(&state);
            let warm_threads = cfg.threads;
            thread::spawn(move || {
                let warmed = router::warm(&warm_state, warm_threads);
                eprintln!("[tcserved] warmed {warmed}/{} experiments", EXPERIMENTS.len());
                warm_state.readiness.set_warming(false);
            });
        }

        // Bounded hand-off: `try_send` in the acceptor keeps the queue at
        // most `queue_depth` deep, and overload is answered inline.
        let (tx, rx) = mpsc::sync_channel::<TcpStream>(cfg.queue_depth.max(1));
        let rx = Arc::new(Mutex::new(rx));
        for _ in 0..cfg.threads.max(1) {
            let rx = Arc::clone(&rx);
            let state = Arc::clone(&state);
            thread::spawn(move || worker_loop(rx, state));
        }

        let shutdown = Arc::new(AtomicBool::new(false));
        let stop = Arc::clone(&shutdown);
        let accept_state = Arc::clone(&state);
        let acceptor = thread::spawn(move || {
            for conn in listener.incoming() {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                // tcchaos queue site: a synthetic queue-full rejection,
                // exercising the same 503 + Retry-After shed path real
                // saturation takes
                if crate::chaos::inject(crate::chaos::Site::Queue).is_some() {
                    reject_overloaded(&accept_state, stream);
                    continue;
                }
                match tx.try_send(stream) {
                    Ok(()) => accept_state.readiness.queue_enter(),
                    Err(mpsc::TrySendError::Full(stream)) => {
                        reject_overloaded(&accept_state, stream)
                    }
                    Err(mpsc::TrySendError::Disconnected(_)) => break,
                }
            }
            // dropping `tx` lets the workers drain and exit
        });

        Ok(Server { addr, shutdown, acceptor: Some(acceptor), state })
    }

    /// The bound address (resolves the ephemeral port for tests).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Shared state (cache + metrics) of this instance.
    pub fn state(&self) -> &AppState {
        &self.state
    }

    /// Block on the acceptor (i.e. forever, for the CLI).
    pub fn join(mut self) {
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting connections and join the acceptor. In-flight
    /// worker requests finish on their own threads.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // unblock the acceptor with a no-op connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
    }
}

/// Backpressure path: the worker queue is full, so answer `503` on the
/// acceptor thread without reading the request (the client told us
/// nothing we need; the point is to shed load fast).
fn reject_overloaded(state: &AppState, mut stream: TcpStream) {
    state.metrics.record_rejected();
    let response = Response::error(
        503,
        "overloaded",
        "server at capacity (connection queue full); retry shortly",
    )
    .with_header("Retry-After", "1");
    state.metrics.record_status(response.status);
    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
    let _ = response.write_to(&mut stream);
}

fn worker_loop(rx: Arc<Mutex<mpsc::Receiver<TcpStream>>>, state: Arc<AppState>) {
    loop {
        // Lock only around `recv`: the guard is a temporary of this
        // statement, so request handling below runs unlocked and
        // connections are processed concurrently across workers. The
        // lock unwrap only fails on poisoning, which is unreachable —
        // `recv` is the sole operation ever run under this mutex.
        let stream = rx.lock().unwrap().recv();
        match stream {
            Ok(s) => {
                state.readiness.queue_exit();
                handle_connection(&state, s);
            }
            Err(_) => break, // acceptor gone
        }
    }
}

fn handle_connection(state: &AppState, mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(60)));
    let _ = stream.set_nodelay(true);
    let t_parse = std::time::Instant::now();
    let parsed = http::read_request(&mut stream);
    let response = match parsed {
        Ok(req) => {
            state
                .metrics
                .record_phase("parse", t_parse.elapsed().as_micros() as u64);
            router::handle(state, &req)
        }
        // A connection closed without sending anything (port probe,
        // stop()'s wake-up socket) is not a request — no response to
        // write, nothing to count.
        Err(http::ReadError::Empty) => return,
        Err(http::ReadError::TooLarge(e)) => {
            // keep requests_total/by_endpoint reconciled with by_status
            state.metrics.record_request("malformed");
            Response::error(413, "payload_too_large", e)
        }
        Err(http::ReadError::Malformed(e)) => {
            state.metrics.record_request("malformed");
            Response::error(400, "malformed_request", e)
        }
    };
    state.metrics.record_status(response.status);
    let _ = response.write_to(&mut stream);
}

/// CLI entrypoint: start and serve until the process is killed.
pub fn serve_blocking(cfg: ServerConfig) -> Result<()> {
    let threads = cfg.threads;
    let shard = cfg.shard;
    let replicas = cfg.replicas;
    let cell_store = cfg.cell_store.clone();
    let server = Server::start(cfg)?;
    eprintln!(
        "[tcserved] listening on http://{} ({threads} workers, {} experiments registered)",
        server.addr(),
        EXPERIMENTS.len()
    );
    match shard {
        Some((i, n)) => eprintln!("[tcserved] serving as shard {i}/{n} of a multi-process fleet"),
        None if replicas > 1 => eprintln!("[tcserved] hosting {replicas} shards in-process"),
        None => {}
    }
    match cell_store {
        Some(dir) => eprintln!("[tcserved] cell store: {}", dir.display()),
        None => eprintln!("[tcserved] cell store: disabled"),
    }
    if let Some(stats) = crate::chaos::stats() {
        eprintln!("[tcserved] tcchaos armed: {} (seed {})", stats.spec, stats.seed);
    }
    eprintln!(
        "[tcserved] endpoints: /healthz /readyz /v1/experiments /v1/devices POST:/v1/run/<id> \
         POST:/v1/sweep POST:/v1/plan POST:/v1/lint /v1/metrics /metrics"
    );
    server.join();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn start_binds_ephemeral_port_and_stops() {
        let server = Server::start(ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            threads: 2,
            warm: false,
            disk_cache: None,
            cache_capacity: 8,
            cell_store: None,
            ..ServerConfig::default()
        })
        .unwrap();
        let addr = server.addr();
        assert_ne!(addr.port(), 0);
        assert_eq!(server.state().metrics.requests_total(), 0);
        // stop() must not hang (it unblocks the acceptor itself)
        server.stop();
    }
}
