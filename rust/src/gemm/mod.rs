//! Appendix-A ablation kernels: a tiled 16-bit GEMM on tcsim in three
//! variants —
//!
//! * `mma_baseline`: synchronous global->shared staging, naive row-major
//!   shared-memory layout (bank conflicts on every `ldmatrix`),
//! * `mma_pipeline`: Ampere `cp.async` multi-buffering (Table 16; the
//!   paper's kernel double-buffers, i.e. `stages = 2`),
//! * `mma_permuted`: CUTLASS-style swizzled layout, conflict-free
//!   `ldmatrix` (Table 17).
//!
//! One CTA computes a `tile_m x tile_n` output tile over the full K
//! dimension in `tile_k`-wide k-steps; per-SM cycle counts are reported
//! and the full-matrix count is extrapolated over the CTA grid, like the
//! paper's per-GPU `clock64()` measurement. Absolute cycles are
//! simulator-scale; the reproduction targets are the *ratios*
//! (~2x from async staging, ~3x from the permuted layout).
//!
//! Since the `Workload::Gemm` promotion the configuration space is open:
//! CTA warp count (any power of two up to 32, mapped onto a near-square
//! warp grid), `cp.async` pipeline depth (`stages`), tile shape and the
//! A/B element type are all parameters. The Workload/Plan path runs
//! [`GemmConfig::validate`] before building a program; [`run_gemm`]
//! debug-asserts the same invariant for direct callers.

use crate::device::Device;
use crate::isa::{shapes, AbType, CdType, MmaInstr};
use crate::sim::{ldmatrix_transactions, ldmatrix_x4_row_addrs, Op, ProgramBuilder, SmSim, Swizzle, WarpProgram};

/// Effective global bandwidth (bytes/clk/SM) of the L2-resident regime
/// Table 17 runs in: the layout experiment isolates *on-chip* behaviour,
/// and its 2048^2 tiles are heavily reused across CTAs.
pub const L2_RESIDENT_BYTES_PER_CYCLE: u32 = 64;

/// GEMM kernel variant (the three Appendix-A CUDA kernels).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    Baseline,
    Pipeline,
    Permuted,
}

impl Variant {
    pub const ALL: [Variant; 3] = [Variant::Baseline, Variant::Pipeline, Variant::Permuted];

    pub fn paper_name(self) -> &'static str {
        match self {
            Variant::Baseline => "mma_baseline.cu",
            Variant::Pipeline => "mma_pipeline.cu",
            Variant::Permuted => "mma_permuted.cu",
        }
    }

    /// Canonical token in workload specs; the exact inverse of
    /// [`Variant::parse_spec`].
    pub fn spec_name(self) -> &'static str {
        match self {
            Variant::Baseline => "baseline",
            Variant::Pipeline => "pipeline",
            Variant::Permuted => "permuted",
        }
    }

    /// Parse one variant token of a gemm workload spec.
    pub fn parse_spec(token: &str) -> Result<Variant, String> {
        match token.to_ascii_lowercase().as_str() {
            "baseline" => Ok(Variant::Baseline),
            "pipeline" => Ok(Variant::Pipeline),
            "permuted" => Ok(Variant::Permuted),
            other => Err(format!(
                "unknown gemm variant {other:?} (baseline|pipeline|permuted)"
            )),
        }
    }

    fn swizzle(self) -> Swizzle {
        match self {
            Variant::Permuted => Swizzle::Permuted,
            _ => Swizzle::None,
        }
    }

    fn async_copy(self) -> bool {
        matches!(self, Variant::Pipeline)
    }
}

/// Problem + tiling configuration (defaults = the paper's 2048^3 BF16,
/// 8 warps per CTA, double-buffered `cp.async`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GemmConfig {
    /// A/B element type (16-bit: BF16 or FP16 — the staged-byte
    /// accounting assumes 2-byte elements).
    pub ab: AbType,
    /// Accumulator type.
    pub cd: CdType,
    pub size: u32,   // square matrix dimension
    pub tile_m: u32, // CTA tile
    pub tile_n: u32,
    pub tile_k: u32,
    pub warps: u32,
    /// `cp.async` pipeline depth (Pipeline variant only): the number of
    /// smem tile buffers. 2 = the paper's double buffering; 1 degrades
    /// to a fully synchronous `cp.async` wait each k-step.
    pub stages: u32,
}

impl Default for GemmConfig {
    fn default() -> Self {
        Self {
            ab: AbType::Bf16,
            cd: CdType::Fp32,
            size: 2048,
            tile_m: 128,
            tile_n: 128,
            tile_k: 32,
            warps: 8,
            stages: 2,
        }
    }
}

impl GemmConfig {
    pub fn k_steps(&self) -> u32 {
        self.size / self.tile_k
    }

    /// CTAs in the output grid.
    pub fn ctas(&self) -> u64 {
        (self.size as u64 / self.tile_m as u64) * (self.size as u64 / self.tile_n as u64)
    }

    /// The MMA instruction one warp issues (the paper's kernels are all
    /// built on `mma.m16n8k16`).
    pub fn instr(&self) -> MmaInstr {
        MmaInstr::dense(self.ab, self.cd, shapes::M16N8K16)
    }

    /// Split `warps` into a near-square `(rows, cols)` warp grid over the
    /// output tile — 8 warps map to the paper kernels' 4x2 grid. Assumes
    /// a power-of-two warp count ([`GemmConfig::validate`] enforces it).
    pub fn warp_grid(&self) -> (u32, u32) {
        let k = self.warps.trailing_zeros();
        (1u32 << k.div_ceil(2), 1u32 << (k / 2))
    }

    /// Is this configuration well-formed (device legality is checked
    /// separately, against a [`Device`])? Returns a user-facing reason
    /// when not.
    pub fn validate(&self) -> Result<(), String> {
        if !matches!(self.ab, AbType::Bf16 | AbType::Fp16) {
            return Err(format!(
                "gemm A/B type must be 16-bit (bf16|fp16), got {}",
                self.ab.spec_name()
            ));
        }
        if !(1..=32).contains(&self.warps) || !self.warps.is_power_of_two() {
            return Err(format!(
                "gemm warps must be a power of two in 1..=32, got {}",
                self.warps
            ));
        }
        if !(1..=8).contains(&self.stages) {
            return Err(format!("gemm stages must be in 1..=8, got {}", self.stages));
        }
        let (wr, wc) = self.warp_grid();
        if self.tile_m == 0 || self.tile_m % (wr * 16) != 0 {
            return Err(format!(
                "tile_m {} must be a positive multiple of {} ({} warp rows x mma m16)",
                self.tile_m,
                wr * 16,
                wr
            ));
        }
        if self.tile_n == 0 || self.tile_n % (wc * 8) != 0 {
            return Err(format!(
                "tile_n {} must be a positive multiple of {} ({} warp cols x mma n8)",
                self.tile_n,
                wc * 8,
                wc
            ));
        }
        if self.tile_k == 0 || self.tile_k % 16 != 0 {
            return Err(format!(
                "tile_k {} must be a positive multiple of the mma k16",
                self.tile_k
            ));
        }
        if self.size == 0
            || self.size % self.tile_m != 0
            || self.size % self.tile_n != 0
            || self.size % self.tile_k != 0
        {
            return Err(format!(
                "size {} must be a positive multiple of the {}x{}x{} tile",
                self.size, self.tile_m, self.tile_n, self.tile_k
            ));
        }
        // A pipeline deeper than the k-loop would prefetch tiles the
        // matrix does not have, inflating the modeled global traffic.
        if self.stages > self.k_steps() {
            return Err(format!(
                "gemm stages {} exceed the {} k-steps of a {}^3 problem with tile_k {}",
                self.stages,
                self.k_steps(),
                self.size,
                self.tile_k
            ));
        }
        Ok(())
    }

    /// Bytes of the A+B tiles staged per k-step (2-byte elements).
    pub fn staged_bytes(&self) -> u64 {
        2 * (self.tile_m as u64 * self.tile_k as u64 + self.tile_k as u64 * self.tile_n as u64)
    }

    /// `mma.m16n8k16` instructions per warp per k-step: each warp owns a
    /// `(tile_m/rows) x (tile_n/cols)` output slice of the warp grid.
    pub fn mmas_per_warp_step(&self) -> u32 {
        let (wr, wc) = self.warp_grid();
        (self.tile_m / wr / 16) * (self.tile_n / wc / 8) * (self.tile_k / 16)
    }
}

/// The per-warp, per-k-step traffic quantities [`build_program`] bakes
/// into a kernel trace, exposed as plain numbers so the closed-form
/// model ([`crate::sim::predict_gemm`]) and the program builder can
/// never drift on the accounting.
#[derive(Debug, Clone, Copy)]
pub struct StepTraffic {
    /// `ldmatrix.x4` fragment loads of the warp's A slice per k-step.
    pub a_loads: u32,
    /// `ldmatrix.x4` fragment loads of the warp's B slice per k-step.
    pub b_loads: u32,
    /// Shared-memory transactions of one A fragment load (bank model).
    pub a_txns: u32,
    /// Shared-memory transactions of one B fragment load (bank model).
    pub b_txns: u32,
    /// Transactions of the warp's synchronous smem tile store (0 for the
    /// `cp.async` variant, which stages gmem->smem without the LSU).
    pub store_txns: u32,
    /// Bytes of the staged A+B tile this warp copies per k-step.
    pub gmem_slice: u64,
}

/// Compute the [`StepTraffic`] of one warp of `variant` at `cfg` — the
/// exact quantities [`build_program`] emits, without building a trace.
pub fn step_traffic(cfg: &GemmConfig, variant: Variant) -> StepTraffic {
    let swz = variant.swizzle();
    let (wr, wc) = cfg.warp_grid();
    let a_row_bytes = if swz == Swizzle::Permuted { 128 } else { cfg.tile_k * 2 };
    let b_row_bytes = if swz == Swizzle::Permuted { 128 } else { cfg.tile_n * 2 };
    let a_frag_bytes = (cfg.tile_m as u64 / wr as u64) * cfg.tile_k as u64 * 2;
    let b_frag_bytes = cfg.tile_k as u64 * (cfg.tile_n as u64 / wc as u64) * 2;
    let gmem_slice = cfg.staged_bytes() / cfg.warps as u64;
    let store_txns = if variant.async_copy() {
        0
    } else {
        let store_conflict = if swz == Swizzle::Permuted { 1 } else { 8 };
        (gmem_slice / 128).max(1) as u32 * store_conflict
    };
    StepTraffic {
        a_loads: (a_frag_bytes / 512).max(1) as u32,
        b_loads: (b_frag_bytes / 512).max(1) as u32,
        a_txns: x4_txns(swz, a_row_bytes),
        b_txns: x4_txns(swz, b_row_bytes),
        store_txns,
        gmem_slice,
    }
}

/// ldmatrix.x4 transaction count against a staged tile with the given
/// row width, derived from real addresses through the bank model.
fn x4_txns(swz: Swizzle, row_bytes: u32) -> u32 {
    ldmatrix_transactions(&ldmatrix_x4_row_addrs(swz, 0, 0, row_bytes))
}

/// Build the per-warp trace of one CTA.
pub fn build_program(device: &Device, cfg: GemmConfig, variant: Variant, warp: u32) -> WarpProgram {
    let instr = cfg.instr();
    let timing = device.timing(&instr).expect("16-bit m16n8k16 timing required");

    // A tile rows are tile_k elements (x2 bytes); B tile rows are tile_n
    // elements. The naive layouts alias banks; Permuted swizzles 16-byte
    // chunks within a padded 128-byte row (the CUTLASS trick). Naive
    // row-major staging stores conflict exactly like the loads (32
    // threads striding by the row width — 8-way on these tiles); the
    // permuted layout writes conflict-free. Fragment loads per warp per
    // k-step cover the warp's A slice (tile_m/rows x tile_k) and B slice
    // (tile_k x tile_n/cols), 512 B per x4.
    let StepTraffic { a_loads, b_loads, a_txns, b_txns, store_txns, gmem_slice } =
        step_traffic(&cfg, variant);
    let mmas = cfg.mmas_per_warp_step();

    let mut b = ProgramBuilder::new();
    let _ = warp;
    // Accumulator registers (persist across k-steps; zero-initialized,
    // so they are seeded live-in for the def-use analysis).
    let accs: Vec<u32> = (0..4.min(mmas)).map(|_| b.init_reg()).collect();
    let frag = b.alloc_reg();
    let staged = b.alloc_reg();

    if variant.async_copy() {
        // Prologue: fill the pipeline — stage the first (stages - 1)
        // tiles asynchronously.
        for _ in 0..cfg.stages.saturating_sub(1) {
            b.push(Op::CpAsync { bytes: gmem_slice }, None, vec![]);
            b.push(Op::CpAsyncCommit, None, vec![]);
        }
    }

    for step in 0..cfg.k_steps() {
        match variant {
            Variant::Baseline | Variant::Permuted => {
                // a. synchronous copy gmem -> registers -> smem
                b.push(Op::GmemLoad { bytes: gmem_slice }, Some(staged), vec![]);
                // b. wait for every warp's copy (data hazard)
                b.push(Op::BarSync, None, vec![]);
                b.push(Op::SmemStore { txns: store_txns, bytes: gmem_slice }, None, vec![staged]);
                b.push(Op::BarSync, None, vec![]);
            }
            Variant::Pipeline => {
                // b. prefetch the tile (stages-1) steps ahead — guarded
                // off in the loop tail once all k_steps tiles have been
                // issued, like the real kernel's bounds check — then
                // wait until the current one has landed (at most
                // stages-1 groups keep flying).
                if step + cfg.stages <= cfg.k_steps() {
                    b.push(Op::CpAsync { bytes: gmem_slice }, None, vec![]);
                    b.push(Op::CpAsyncCommit, None, vec![]);
                }
                b.push(
                    Op::CpAsyncWait { max_pending: cfg.stages.saturating_sub(1) },
                    None,
                    vec![],
                );
                b.push(Op::BarSync, None, vec![]);
            }
        }
        // c. smem -> register fragments via ldmatrix
        for i in 0..a_loads {
            let dst = if i == 0 { frag } else { b.alloc_reg() };
            b.push(Op::SmemLoad { txns: a_txns, bytes: 512 }, Some(dst), vec![]);
        }
        for _ in 0..b_loads {
            let dst = b.alloc_reg();
            b.push(Op::SmemLoad { txns: b_txns, bytes: 512 }, Some(dst), vec![]);
        }
        // d. Tensor-Core compute consuming the fragments
        for i in 0..mmas {
            let acc = accs[i as usize % accs.len()];
            b.push(
                Op::Mma {
                    ii: timing.ii,
                    latency: timing.latency,
                    fmas: instr.fmas(),
                    fpu: false,
                },
                Some(acc),
                vec![acc, frag],
            );
        }
        b.sync_warp();
        if !variant.async_copy() {
            // Single smem buffer: no warp may overwrite the tile (next
            // step's staging) until every warp has finished reading it.
            // The cp.async variant multi-buffers and skips this barrier.
            b.push(Op::BarSync, None, vec![]);
        }
        b.iter_mark();
    }
    b.build()
}

/// One variant's simulated cost.
#[derive(Debug, Clone, Copy)]
pub struct GemmResult {
    pub variant: Variant,
    /// Cycles one CTA takes on one SM.
    pub cta_cycles: u64,
    /// Extrapolated whole-GEMM GPU cycles: CTA waves over all SMs.
    pub total_cycles: u64,
    /// Tensor-Core FMA throughput achieved during the CTA, FMA/clk/SM.
    pub fma_per_clk: f64,
}

/// Simulate one variant. The configuration must satisfy
/// [`GemmConfig::validate`] — the Workload/Plan path checks it before
/// reaching here; direct callers get a debug assertion (an invalid warp
/// grid would silently mis-account FMAs in release builds).
pub fn run_gemm(device: &Device, cfg: GemmConfig, variant: Variant) -> GemmResult {
    run_gemm_profiled(device, cfg, variant, &mut crate::sim::Profiler::Null)
}

/// [`run_gemm`] with stall attribution: every warp-cycle of the CTA is
/// accounted through `profiler` (a `Profiler::Null` makes this the
/// plain simulation — same schedule, zero overhead).
pub fn run_gemm_profiled(
    device: &Device,
    cfg: GemmConfig,
    variant: Variant,
    profiler: &mut crate::sim::Profiler,
) -> GemmResult {
    #[cfg(debug_assertions)]
    if let Err(e) = cfg.validate() {
        panic!("invalid GemmConfig {cfg:?}: {e}");
    }
    let programs: Vec<WarpProgram> =
        (0..cfg.warps).map(|w| build_program(device, cfg, variant, w)).collect();
    let fmas: u64 = programs.iter().map(|p| p.fmas_per_iteration()).sum::<u64>()
        * cfg.k_steps() as u64;
    let results = SmSim::new(device, programs).run_profiled(profiler);
    let cta_cycles = results.iter().map(|r| r.finish).max().unwrap_or(0);
    let waves = cfg.ctas().div_ceil(device.sms as u64);
    GemmResult {
        variant,
        cta_cycles,
        total_cycles: cta_cycles * waves,
        fma_per_clk: fmas as f64 / cta_cycles as f64,
    }
}

/// Run the Table 16 pair (baseline vs async pipeline).
pub fn table16(device: &Device, cfg: GemmConfig) -> (GemmResult, GemmResult) {
    (run_gemm(device, cfg, Variant::Baseline), run_gemm(device, cfg, Variant::Pipeline))
}

/// Run the Table 17 pair (baseline vs permuted layout).
///
/// The layout experiment isolates *on-chip* behaviour, so it runs in the
/// L2-resident regime ([`L2_RESIDENT_BYTES_PER_CYCLE`]): effective
/// global bandwidth is several times DRAM per SM.
pub fn table17(device: &Device, cfg: GemmConfig) -> (GemmResult, GemmResult) {
    let mut dev = device.clone();
    dev.gmem_bytes_per_cycle = dev.gmem_bytes_per_cycle.max(L2_RESIDENT_BYTES_PER_CYCLE);
    (run_gemm(&dev, cfg, Variant::Baseline), run_gemm(&dev, cfg, Variant::Permuted))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::a100;

    fn small() -> GemmConfig {
        // keep unit tests fast: 512^3
        GemmConfig { size: 512, ..GemmConfig::default() }
    }

    #[test]
    fn naive_layouts_conflict_permuted_does_not() {
        assert!(x4_txns(Swizzle::None, 64) > 4, "A-tile naive must conflict");
        assert!(x4_txns(Swizzle::None, 256) > 4, "B-tile naive must conflict");
        assert_eq!(x4_txns(Swizzle::Permuted, 128), 4);
    }

    #[test]
    fn async_pipeline_speedup_near_2x() {
        // Table 16: 913363 / 451560 = 2.02x on silicon.
        let d = a100();
        let (base, pipe) = table16(&d, small());
        let speedup = base.cta_cycles as f64 / pipe.cta_cycles as f64;
        assert!((1.4..3.0).contains(&speedup), "async speedup {speedup}");
    }

    #[test]
    fn permuted_layout_speedup_near_3x() {
        // Table 17: 913363 / 303227 = 3.01x on silicon.
        let d = a100();
        let (base, perm) = table17(&d, small());
        let speedup = base.cta_cycles as f64 / perm.cta_cycles as f64;
        assert!((1.8..4.5).contains(&speedup), "permuted speedup {speedup}");
    }

    #[test]
    fn pipeline_hides_latency_not_bandwidth() {
        // The async variant can never beat the pure-bandwidth bound.
        let d = a100();
        let cfg = small();
        let pipe = run_gemm(&d, cfg, Variant::Pipeline);
        let gmem_cycles = cfg.staged_bytes() * cfg.k_steps() as u64
            / d.gmem_bytes_per_cycle as u64;
        assert!(pipe.cta_cycles >= gmem_cycles, "{} < {gmem_cycles}", pipe.cta_cycles);
    }

    #[test]
    fn single_stage_pipeline_exposes_the_copy_latency() {
        // stages = 1 waits for the k-step's own copy every iteration;
        // double buffering (the paper's kernel) must be faster.
        let d = a100();
        let one = run_gemm(&d, GemmConfig { stages: 1, ..small() }, Variant::Pipeline);
        let two = run_gemm(&d, small(), Variant::Pipeline);
        assert!(
            one.cta_cycles > two.cta_cycles,
            "stages=1 {} vs stages=2 {}",
            one.cta_cycles,
            two.cta_cycles
        );
        // deeper pipelines never lose to double buffering (beyond the
        // few extra prologue issue slots)
        let four = run_gemm(&d, GemmConfig { stages: 4, ..small() }, Variant::Pipeline);
        assert!(
            four.cta_cycles <= two.cta_cycles * 101 / 100,
            "{} > {}",
            four.cta_cycles,
            two.cta_cycles
        );
    }

    #[test]
    fn extrapolation_scales_with_ctas() {
        let d = a100();
        let small_res = run_gemm(&d, small(), Variant::Pipeline);
        assert_eq!(
            small_res.total_cycles,
            small_res.cta_cycles * (16u64).div_ceil(d.sms as u64)
        );
    }

    #[test]
    fn mma_count_covers_tile() {
        // warps x mmas x 2048 FMA == tile_m * tile_n * tile_k, at every
        // legal warp count
        for warps in [1u32, 2, 4, 8, 16, 32] {
            let cfg = GemmConfig { warps, ..GemmConfig::default() };
            cfg.validate().unwrap_or_else(|e| panic!("warps {warps}: {e}"));
            let per_step = warps as u64 * cfg.mmas_per_warp_step() as u64 * 2048;
            assert_eq!(per_step, 128 * 128 * 32, "warps {warps}");
        }
    }

    #[test]
    fn warp_grid_is_near_square() {
        for (warps, grid) in
            [(1u32, (1, 1)), (2, (2, 1)), (4, (2, 2)), (8, (4, 2)), (16, (4, 4)), (32, (8, 4))]
        {
            let cfg = GemmConfig { warps, ..GemmConfig::default() };
            assert_eq!(cfg.warp_grid(), grid, "warps {warps}");
        }
    }

    #[test]
    fn validate_rejects_malformed_configs() {
        assert!(GemmConfig::default().validate().is_ok());
        let bad = [
            GemmConfig { ab: AbType::Tf32, ..GemmConfig::default() },
            GemmConfig { warps: 6, ..GemmConfig::default() },
            GemmConfig { warps: 0, ..GemmConfig::default() },
            GemmConfig { stages: 0, ..GemmConfig::default() },
            GemmConfig { stages: 9, ..GemmConfig::default() },
            // a pipeline deeper than the k-loop (4 k-steps here)
            GemmConfig {
                size: 64,
                tile_m: 16,
                tile_n: 16,
                tile_k: 16,
                warps: 1,
                stages: 5,
                ..GemmConfig::default()
            },
            GemmConfig { tile_m: 100, ..GemmConfig::default() },
            GemmConfig { tile_n: 12, ..GemmConfig::default() },
            GemmConfig { tile_k: 8, ..GemmConfig::default() },
            GemmConfig { size: 2000, ..GemmConfig::default() },
            GemmConfig { size: 0, ..GemmConfig::default() },
        ];
        for cfg in bad {
            assert!(cfg.validate().is_err(), "{cfg:?} must be rejected");
        }
    }

    #[test]
    fn variant_spec_round_trips() {
        for v in Variant::ALL {
            assert_eq!(Variant::parse_spec(v.spec_name()), Ok(v));
        }
        assert!(Variant::parse_spec("fancy").is_err());
    }
}
