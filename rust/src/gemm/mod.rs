//! Appendix-A ablation kernels: a tiled BF16 GEMM on tcsim in three
//! variants —
//!
//! * `mma_baseline`: synchronous global->shared staging, naive row-major
//!   shared-memory layout (bank conflicts on every `ldmatrix`),
//! * `mma_pipeline`: Ampere `cp.async` double buffering (Table 16),
//! * `mma_permuted`: CUTLASS-style swizzled layout, conflict-free
//!   `ldmatrix` (Table 17).
//!
//! One CTA (8 warps) computes a 128x128 output tile over the full K
//! dimension in 32-wide k-steps; per-SM cycle counts are reported and
//! the full-matrix count is extrapolated over the CTA grid, like the
//! paper's per-GPU `clock64()` measurement. Absolute cycles are
//! simulator-scale; the reproduction targets are the *ratios*
//! (~2x from async staging, ~3x from the permuted layout).

use crate::device::Device;
use crate::isa::{shapes, AbType, CdType, MmaInstr};
use crate::sim::{ldmatrix_transactions, ldmatrix_x4_row_addrs, Op, ProgramBuilder, SmSim, Swizzle, WarpProgram};

/// GEMM kernel variant (the three Appendix-A CUDA kernels).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    Baseline,
    Pipeline,
    Permuted,
}

impl Variant {
    pub fn paper_name(self) -> &'static str {
        match self {
            Variant::Baseline => "mma_baseline.cu",
            Variant::Pipeline => "mma_pipeline.cu",
            Variant::Permuted => "mma_permuted.cu",
        }
    }

    fn swizzle(self) -> Swizzle {
        match self {
            Variant::Permuted => Swizzle::Permuted,
            _ => Swizzle::None,
        }
    }

    fn async_copy(self) -> bool {
        matches!(self, Variant::Pipeline)
    }
}

/// Problem + tiling configuration (defaults = the paper's 2048^3 BF16).
#[derive(Debug, Clone, Copy)]
pub struct GemmConfig {
    pub size: u32,   // square matrix dimension
    pub tile_m: u32, // CTA tile
    pub tile_n: u32,
    pub tile_k: u32,
    pub warps: u32,
}

impl Default for GemmConfig {
    fn default() -> Self {
        Self { size: 2048, tile_m: 128, tile_n: 128, tile_k: 32, warps: 8 }
    }
}

impl GemmConfig {
    pub fn k_steps(&self) -> u32 {
        self.size / self.tile_k
    }

    /// CTAs in the output grid.
    pub fn ctas(&self) -> u64 {
        (self.size as u64 / self.tile_m as u64) * (self.size as u64 / self.tile_n as u64)
    }

    /// Bytes of the A+B tiles staged per k-step (BF16).
    fn staged_bytes(&self) -> u64 {
        2 * (self.tile_m as u64 * self.tile_k as u64 + self.tile_k as u64 * self.tile_n as u64)
    }

    /// `mma.m16n8k16` instructions per warp per k-step: each warp owns a
    /// (tile_m/4) x (tile_n/2) output slice (4x2 warp grid).
    fn mmas_per_warp_step(&self) -> u32 {
        let wm = self.tile_m / 4;
        let wn = self.tile_n / 2;
        (wm / 16) * (wn / 8) * (self.tile_k / 16)
    }
}

/// ldmatrix.x4 transaction count against a staged tile with the given
/// row width, derived from real addresses through the bank model.
fn x4_txns(swz: Swizzle, row_bytes: u32) -> u32 {
    ldmatrix_transactions(&ldmatrix_x4_row_addrs(swz, 0, 0, row_bytes))
}

/// Build the per-warp trace of one CTA.
pub fn build_program(device: &Device, cfg: GemmConfig, variant: Variant, warp: u32) -> WarpProgram {
    let instr = MmaInstr::dense(AbType::Bf16, CdType::Fp32, shapes::M16N8K16);
    let timing = device.timing(&instr).expect("BF16 m16n8k16 required");
    let swz = variant.swizzle();

    // A tile rows are tile_k elements (x2 bytes); B tile rows are tile_n
    // elements. The naive layouts alias banks; Permuted swizzles 16-byte
    // chunks within a padded 128-byte row (the CUTLASS trick).
    let a_row_bytes = if swz == Swizzle::Permuted { 128 } else { cfg.tile_k * 2 };
    let b_row_bytes = if swz == Swizzle::Permuted { 128 } else { cfg.tile_n * 2 };
    let a_txns = x4_txns(swz, a_row_bytes);
    let b_txns = x4_txns(swz, b_row_bytes);

    // Fragment loads per warp per k-step: the warp's A slice
    // (tile_m/4 x tile_k) and B slice (tile_k x tile_n/2), 512 B per x4.
    let a_frag_bytes = (cfg.tile_m as u64 / 4) * cfg.tile_k as u64 * 2;
    let b_frag_bytes = cfg.tile_k as u64 * (cfg.tile_n as u64 / 2) * 2;
    let a_loads = (a_frag_bytes / 512).max(1) as u32;
    let b_loads = (b_frag_bytes / 512).max(1) as u32;

    let gmem_slice = cfg.staged_bytes() / cfg.warps as u64;
    // Naive row-major staging stores conflict exactly like the loads
    // (32 threads striding by the row width — 8-way on these tiles);
    // the permuted layout writes conflict-free.
    let store_conflict = if swz == Swizzle::Permuted { 1 } else { 8 };
    let store_txns = (gmem_slice / 128).max(1) as u32 * store_conflict;
    let mmas = cfg.mmas_per_warp_step();

    let mut b = ProgramBuilder::new();
    let _ = warp;
    // Accumulator registers (persist across k-steps).
    let accs: Vec<u32> = (0..4.min(mmas)).map(|_| b.alloc_reg()).collect();
    let frag = b.alloc_reg();
    let staged = b.alloc_reg();

    if variant.async_copy() {
        // Prologue: stage the first tile asynchronously.
        b.push(Op::CpAsync { bytes: gmem_slice }, None, vec![]);
        b.push(Op::CpAsyncCommit, None, vec![]);
    }

    for _step in 0..cfg.k_steps() {
        match variant {
            Variant::Baseline | Variant::Permuted => {
                // a. synchronous copy gmem -> registers -> smem
                b.push(Op::GmemLoad { bytes: gmem_slice }, Some(staged), vec![]);
                // b. wait for every warp's copy (data hazard)
                b.push(Op::BarSync, None, vec![]);
                b.push(Op::SmemStore { txns: store_txns, bytes: gmem_slice }, None, vec![staged]);
                b.push(Op::BarSync, None, vec![]);
            }
            Variant::Pipeline => {
                // b. prefetch the *next* tile, then wait for the current.
                b.push(Op::CpAsync { bytes: gmem_slice }, None, vec![]);
                b.push(Op::CpAsyncCommit, None, vec![]);
                b.push(Op::CpAsyncWait { max_pending: 1 }, None, vec![]);
                b.push(Op::BarSync, None, vec![]);
            }
        }
        // c. smem -> register fragments via ldmatrix
        for i in 0..a_loads {
            let dst = if i == 0 { frag } else { b.alloc_reg() };
            b.push(Op::SmemLoad { txns: a_txns, bytes: 512 }, Some(dst), vec![]);
        }
        for _ in 0..b_loads {
            let dst = b.alloc_reg();
            b.push(Op::SmemLoad { txns: b_txns, bytes: 512 }, Some(dst), vec![]);
        }
        // d. Tensor-Core compute consuming the fragments
        for i in 0..mmas {
            let acc = accs[i as usize % accs.len()];
            b.push(
                Op::Mma {
                    ii: timing.ii,
                    latency: timing.latency,
                    fmas: instr.fmas(),
                    fpu: false,
                },
                Some(acc),
                vec![acc, frag],
            );
        }
        b.sync_warp();
        if !variant.async_copy() {
            // Single smem buffer: no warp may overwrite the tile (next
            // step's staging) until every warp has finished reading it.
            // The cp.async variant double-buffers and skips this barrier.
            b.push(Op::BarSync, None, vec![]);
        }
        b.iter_mark();
    }
    b.build()
}

/// One variant's simulated cost.
#[derive(Debug, Clone, Copy)]
pub struct GemmResult {
    pub variant: Variant,
    /// Cycles one CTA takes on one SM.
    pub cta_cycles: u64,
    /// Extrapolated whole-GEMM GPU cycles: CTA waves over all SMs.
    pub total_cycles: u64,
    /// Tensor-Core FMA throughput achieved during the CTA, FMA/clk/SM.
    pub fma_per_clk: f64,
}

/// Simulate one variant.
pub fn run_gemm(device: &Device, cfg: GemmConfig, variant: Variant) -> GemmResult {
    let programs: Vec<WarpProgram> =
        (0..cfg.warps).map(|w| build_program(device, cfg, variant, w)).collect();
    let fmas: u64 = programs.iter().map(|p| p.fmas_per_iteration()).sum::<u64>()
        * cfg.k_steps() as u64;
    let results = SmSim::new(device, programs).run();
    let cta_cycles = results.iter().map(|r| r.finish).max().unwrap_or(0);
    let waves = cfg.ctas().div_ceil(device.sms as u64);
    GemmResult {
        variant,
        cta_cycles,
        total_cycles: cta_cycles * waves,
        fma_per_clk: fmas as f64 / cta_cycles as f64,
    }
}

/// Run the Table 16 pair (baseline vs async pipeline).
pub fn table16(device: &Device, cfg: GemmConfig) -> (GemmResult, GemmResult) {
    (run_gemm(device, cfg, Variant::Baseline), run_gemm(device, cfg, Variant::Pipeline))
}

/// Run the Table 17 pair (baseline vs permuted layout).
///
/// The layout experiment isolates *on-chip* behaviour, so it runs in the
/// L2-resident regime (the 2048^2 tiles are heavily reused across CTAs):
/// effective global bandwidth is several times DRAM per SM.
pub fn table17(device: &Device, cfg: GemmConfig) -> (GemmResult, GemmResult) {
    let mut dev = device.clone();
    dev.gmem_bytes_per_cycle = dev.gmem_bytes_per_cycle.max(64);
    (run_gemm(&dev, cfg, Variant::Baseline), run_gemm(&dev, cfg, Variant::Permuted))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::a100;

    fn small() -> GemmConfig {
        // keep unit tests fast: 512^3
        GemmConfig { size: 512, ..GemmConfig::default() }
    }

    #[test]
    fn naive_layouts_conflict_permuted_does_not() {
        assert!(x4_txns(Swizzle::None, 64) > 4, "A-tile naive must conflict");
        assert!(x4_txns(Swizzle::None, 256) > 4, "B-tile naive must conflict");
        assert_eq!(x4_txns(Swizzle::Permuted, 128), 4);
    }

    #[test]
    fn async_pipeline_speedup_near_2x() {
        // Table 16: 913363 / 451560 = 2.02x on silicon.
        let d = a100();
        let (base, pipe) = table16(&d, small());
        let speedup = base.cta_cycles as f64 / pipe.cta_cycles as f64;
        assert!((1.4..3.0).contains(&speedup), "async speedup {speedup}");
    }

    #[test]
    fn permuted_layout_speedup_near_3x() {
        // Table 17: 913363 / 303227 = 3.01x on silicon.
        let d = a100();
        let (base, perm) = table17(&d, small());
        let speedup = base.cta_cycles as f64 / perm.cta_cycles as f64;
        assert!((1.8..4.5).contains(&speedup), "permuted speedup {speedup}");
    }

    #[test]
    fn pipeline_hides_latency_not_bandwidth() {
        // The async variant can never beat the pure-bandwidth bound.
        let d = a100();
        let cfg = small();
        let pipe = run_gemm(&d, cfg, Variant::Pipeline);
        let gmem_cycles = cfg.staged_bytes() * cfg.k_steps() as u64
            / d.gmem_bytes_per_cycle as u64;
        assert!(pipe.cta_cycles >= gmem_cycles, "{} < {gmem_cycles}", pipe.cta_cycles);
    }

    #[test]
    fn extrapolation_scales_with_ctas() {
        let d = a100();
        let small_res = run_gemm(&d, small(), Variant::Pipeline);
        assert_eq!(
            small_res.total_cycles,
            small_res.cta_cycles * (16u64).div_ceil(d.sms as u64)
        );
    }

    #[test]
    fn mma_count_covers_tile() {
        let cfg = GemmConfig::default();
        // 8 warps x mmas x 2048 FMA == tile_m * tile_n * tile_k
        let per_step = 8 * cfg.mmas_per_warp_step() as u64 * 2048;
        assert_eq!(per_step, 128 * 128 * 32);
    }
}
