//! # tcbench — Dissecting Tensor Cores via Microbenchmarks (TPDS 2022)
//!
//! Full-system reproduction of Sun et al., *Dissecting Tensor Cores via
//! Microbenchmarks: Latency, Throughput and Numeric Behaviors*.
//!
//! The paper measures real Ampere/Turing silicon; this crate substitutes a
//! **cycle-level Tensor-Core SM simulator** ([`sim`]) calibrated from the
//! paper's published tables, driven by the same instruction-level
//! microbenchmark methodology ([`microbench`], paper §4), and a
//! **bit-accurate emulated-MMA numeric datapath** for the §8 studies —
//! implemented twice: natively in Rust ([`numerics`]) and as JAX/Pallas
//! AOT artifacts executed through PJRT ([`runtime`]); the two are
//! cross-checked in integration tests.
//!
//! Layer map (DESIGN.md §2):
//! - [`isa`]      — PTX-level instruction model (`mma`, `mma.sp`,
//!   `ldmatrix`, `ld.shared`, `cp.async`), shapes, data types, FMA/byte
//!   accounting and per-architecture legality.
//! - [`device`]   — calibrated device descriptions (A100, RTX3070Ti,
//!   RTX2080Ti).
//! - [`sim`]      — tcsim: sub-cores, warp schedulers, scoreboards,
//!   Tensor-Core token-bucket pipelines, shared-memory banks, LSUs,
//!   global-memory pipe with `cp.async`.
//! - [`microbench`] — the §4 harness: kernel builder, (ILP, #warps)
//!   sweeps, convergence-point detection.
//! - [`numerics`] — §8: softfloat quantization, emulated MMA, chain
//!   matmul, error metrics.
//! - [`runtime`]  — PJRT client wrapper that loads `artifacts/*.hlo.txt`.
//! - [`gemm`]     — Appendix-A ablation kernels (sync vs async copy,
//!   naive vs permuted shared-memory layout), parameterized over tile
//!   shape, warp grid, `cp.async` stage depth and 16-bit element type.
//! - [`workload`] — the unified workload API: one typed [`Workload`]
//!   enum for all seven benchmarked families (the five instruction
//!   kinds, the Appendix-A `gemm` pipeline and the §8 `numeric`
//!   probes), a `BenchPlan` builder compiling to runnable units, the
//!   `Runner` backend seam, and the cell-level execution engine
//!   (per-cell scheduling over the worker pool, backed by the
//!   process-wide content-addressed cell cache) — the single execution
//!   path behind the CLI, the coordinator experiments and tcserved's
//!   `POST /v1/plan`.
//! - [`coordinator`] — campaign orchestration: every paper table/figure
//!   is a registered experiment run by a scoped-thread worker pool.
//! - [`report`]   — table/figure renderers (text + machine-readable
//!   JSON) + the paper's expected values.
//! - [`server`]   — tcserved: an embedded campaign service (std-only
//!   HTTP/1.1) with a versioned `tcserved/v1` JSON envelope, a
//!   content-addressed result cache, single-flight request coalescing,
//!   a shared disk-backed cell store and consistent-hash replica
//!   sharding, started via `repro serve`.
//! - [`loadgen`]  — the load harness: deterministic mixed traffic
//!   against a running tcserved, reporting client p50/p99 next to the
//!   server's cache/cell-store hit rates (`repro loadgen`).
//! - [`analysis`] — tclint: a static verifier over the warp-program IR
//!   (def-use, cp.async protocol, barrier arity, loop uniformity,
//!   resource bounds) run by debug-mode `SmSim`, `repro lint` and
//!   tcserved's `POST /v1/lint` — no cycle is simulated to check a
//!   program.
//! - [`chaos`]    — tcchaos: seeded, deterministic fault injection at
//!   the cell-store, worker-pool and accept-queue seams, enabled only
//!   by `repro serve --chaos`, with every injected fault counted in
//!   `/v1/metrics`.

pub mod analysis;
pub mod chaos;
pub mod coordinator;
pub mod device;
pub mod gemm;
pub mod isa;
pub mod loadgen;
pub mod microbench;
pub mod numerics;
pub mod report;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod util;
pub mod workload;

pub use device::Device;
pub use isa::{AbType, CdType, MmaShape};
pub use workload::Workload;
