//! tcsim — a cycle-level model of one Tensor-Core SM.
//!
//! Structure (paper Fig. 1): four sub-cores, each with its own warp
//! scheduler and Tensor-Core pipeline; SM-level data-movement units
//! (LSUs) in front of a 32-bank shared memory; a global-memory pipe with
//! synchronous loads and Ampere `cp.async`.
//!
//! Calibrated mechanisms (derived from the paper's tables, DESIGN.md §4):
//!
//! * **Tensor-Core engine = token bucket** per sub-core: work credit
//!   refills 1 cycle/cycle up to a burst cap of `latency` cycles; an
//!   `mma` consumes `ii` credits at issue and completes `latency` cycles
//!   later. This yields a sustained rate of one instruction per `ii`
//!   cycles with a burst window of `latency/ii` in flight — exactly the
//!   pipeline behaviour behind the paper's ILP/#warp convergence points,
//!   the 6-warp throughput dip, and the 12-vs-16-warp latency step.
//! * **`mma.sync` completion barrier**: `__syncwarp()` after an ILP
//!   group waits for the warp's outstanding MMA results (the intra-warp
//!   synchronization stalls of §5 finding 3), then costs `sync_cost`.
//! * **LSU pair**: a warp's shared-memory transactions go to LSU
//!   `warp_id % 2`; each 128-byte transaction occupies its unit for 2
//!   cycles (64 B/clk/unit, 128 B/clk/SM); a load completes `lsu_tail`
//!   cycles after its last transaction; a warp may have at most
//!   `lsu_pending_per_warp` loads outstanding. Loads do *not* block
//!   `__syncwarp` (they are `ld`-style asynchronous writebacks), which
//!   is why `ldmatrix` throughput saturates while `mma` does not.

mod analytic;
pub mod budget;
mod core;
mod profile;
mod program;
mod smem;

pub use analytic::{
    calibration_bound, predict_gemm, predict_ld_shared, predict_ldmatrix, predict_mma,
    predict_wmma, AnalyticPrediction, CalibrationBound, CALIBRATION_BOUNDS,
};
pub use budget::{Budget, BudgetBlown};
pub use core::{SmSim, WarpResult};
pub use profile::{
    Blocked, ProfileMode, Profiler, SimProfile, Stall, TraceEvent, MAX_TRACE_EVENTS,
    STALL_CATEGORIES,
};
pub use program::{Instr, Op, ProgramBuilder, Reg, WarpProgram};
pub use smem::{ld_shared_transactions, ldmatrix_transactions, ldmatrix_x4_row_addrs, Swizzle};
