//! Per-request wall-clock budgets for the simulate path.
//!
//! A [`Budget`] carries a request deadline. [`scoped`] installs it in a
//! thread-local for the duration of a closure; the [`SmSim`](super::SmSim)
//! cycle loop polls [`poll`] at *iteration-mark* granularity — the same
//! cadence as the steady-state convergence check, never once per cycle —
//! so the hot loop stays branch-cheap. When the deadline has passed, the
//! loop breaks out with whatever marks it accumulated and latches a
//! thread-local *blown* flag:
//!
//! * the cell layer ([`workload::cell`](crate::workload)) refuses to
//!   cache or persist the truncated result, so a later un-budgeted
//!   request re-simulates from scratch and gets the bit-exact answer;
//! * the workload layer sees the flag via the value returned by
//!   [`scoped`] and degrades to the calibrated analytic prediction
//!   instead of serving truncated cycle counts.
//!
//! Programs measured by total cycles rather than iteration marks (the
//! GEMM kernels) emit no marks mid-run and therefore cannot be
//! interrupted once started; for those the up-front `exceeded` check at
//! the unit boundary is the only watchdog.

use std::cell::Cell;
use std::time::{Duration, Instant};

/// A wall-clock deadline for one request's compute.
#[derive(Clone, Copy, Debug)]
pub struct Budget {
    deadline: Instant,
}

/// Marker error: a measurement was abandoned (or never started) because
/// the active [`Budget`]'s deadline passed. Callers degrade to the
/// calibrated analytic prediction or surface a typed `deadline_exceeded`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BudgetBlown;

impl Budget {
    /// A budget expiring `ms` milliseconds from now.
    pub fn from_ms(ms: u64) -> Self {
        Budget { deadline: Instant::now() + Duration::from_millis(ms) }
    }

    /// Has the deadline passed?
    pub fn exceeded(&self) -> bool {
        Instant::now() >= self.deadline
    }
}

thread_local! {
    static ACTIVE: Cell<Option<Budget>> = const { Cell::new(None) };
    static BLOWN: Cell<bool> = const { Cell::new(false) };
}

/// Run `f` with `budget` active on this thread and report whether any
/// simulation inside blew it: `(result, blown)`. The flag is scoped to
/// this call — cleared on entry, restored (with the previous budget) on
/// exit, including on unwind, so a panicking closure cannot leak a
/// stale budget into unrelated work on a pooled thread.
pub fn scoped<T>(budget: Option<Budget>, f: impl FnOnce() -> T) -> (T, bool) {
    struct Restore {
        prev: Option<Budget>,
        prev_blown: bool,
    }
    impl Drop for Restore {
        fn drop(&mut self) {
            ACTIVE.with(|a| a.set(self.prev));
            BLOWN.with(|b| b.set(self.prev_blown));
        }
    }
    let guard = Restore {
        prev: ACTIVE.with(|a| a.replace(budget)),
        prev_blown: BLOWN.with(|b| b.replace(false)),
    };
    let out = f();
    let blown = BLOWN.with(|b| b.get());
    drop(guard);
    (out, blown)
}

/// Polled by the sim cycle loop whenever the iteration-mark count moves:
/// returns `true` (and latches the blown flag) once the active budget's
/// deadline has passed. One thread-local read when no budget is active.
pub fn poll() -> bool {
    match ACTIVE.with(|a| a.get()) {
        Some(b) if b.exceeded() => {
            BLOWN.with(|f| f.set(true));
            true
        }
        _ => false,
    }
}

/// Has a simulation in the current [`scoped`] call blown its budget?
/// Read by the cell layer to keep truncated results out of the caches.
pub fn blown() -> bool {
    BLOWN.with(|b| b.get())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_budget_never_polls_blown() {
        let ((), blown) = scoped(None, || {
            assert!(!poll());
            assert!(!blown());
        });
        assert!(!blown);
    }

    #[test]
    fn expired_budget_latches_blown_within_scope_only() {
        let ((), blown) = scoped(Some(Budget::from_ms(0)), || {
            assert!(poll(), "a 0 ms budget is already exceeded");
            assert!(super::blown());
        });
        assert!(blown);
        assert!(!super::blown(), "flag must not leak past the scope");
    }

    #[test]
    fn generous_budget_does_not_trip() {
        let ((), blown) = scoped(Some(Budget::from_ms(60_000)), || {
            assert!(!poll());
        });
        assert!(!blown);
    }

    #[test]
    fn scope_restores_previous_budget_on_unwind() {
        let caught = std::panic::catch_unwind(|| {
            scoped(Some(Budget::from_ms(0)), || {
                assert!(poll());
                panic!("boom");
            })
        });
        assert!(caught.is_err());
        assert!(!blown(), "unwind must restore the outer (clean) flag");
        assert!(!poll(), "unwind must restore the outer (absent) budget");
    }
}
