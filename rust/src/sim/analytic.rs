//! Closed-form steady-state model of the microbenchmark loop.
//!
//! Used as a property-test oracle for tcsim and to sanity-check the
//! calibration: for an `mma` loop the measured iteration latency is
//!
//! ```text
//! P = max( L + (ILP-1) + sync ,  W_sc * ILP * ii )        [per sub-core]
//! latency    = max over sub-cores of P
//! throughput = total FMAs per iteration / latency
//! ```
//!
//! (dependency/issue path vs token-bucket rate path), and for a
//! data-movement loop
//!
//! ```text
//! P = max( L_load + sync ,  W_lsu * ILP * txns * txn_cycles )  [per LSU]
//! ```
//!
//! with `L_load = lsu_tail + txn_cycles * txns` and the pending-cap
//! correction when `ILP >= lsu_pending_per_warp`.

use crate::device::Device;
use crate::isa::{LdMatrixNum, MmaInstr};

/// Prediction for one (#warps, ILP) configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AnalyticPrediction {
    /// Cycles per loop iteration (bottleneck warp).
    pub latency: f64,
    /// FMA/clk/SM for mma loops; bytes/clk/SM for data movement.
    pub throughput: f64,
}

/// Warps resident on the most loaded of `n_units` units under
/// round-robin assignment.
fn worst_unit_load(warps: u32, n_units: u32) -> u32 {
    warps.div_ceil(n_units)
}

/// Steady-state prediction of the §5/§6 mma microbenchmark.
pub fn predict_mma(device: &Device, instr: &MmaInstr, warps: u32, ilp: u32) -> AnalyticPrediction {
    let timing = device
        .timing(instr)
        .unwrap_or_else(|| panic!("{instr} unsupported on {}", device.name));
    let l = timing.latency as f64;
    let ii = timing.ii as f64;
    let sync = device.sync_cost as f64;
    let w_sc = worst_unit_load(warps, device.subcores) as f64;

    let dep_path = l + (ilp as f64 - 1.0) + sync;
    let rate_path = w_sc * ilp as f64 * ii;
    // Per-warp dispatch recovery: one warp alone sustains 1/(ii+1).
    let warp_path = ilp as f64 * (ii + 1.0);
    let latency = dep_path.max(rate_path).max(warp_path);
    let fmas = warps as f64 * ilp as f64 * instr.fmas() as f64;
    AnalyticPrediction { latency, throughput: fmas / latency }
}

/// Steady-state prediction of the §7 ldmatrix microbenchmark.
pub fn predict_ldmatrix(
    device: &Device,
    num: LdMatrixNum,
    warps: u32,
    ilp: u32,
) -> AnalyticPrediction {
    let txns = num.count() as f64;
    let txn_cy = device.lsu_txn_cycles as f64;
    let tail = device.lsu_tail as f64;
    let w_lsu = worst_unit_load(warps, device.lsu_units) as f64;

    // Each ILP slot is a pointer-chase chain: the next load's address
    // depends on the previous result, so a slot's period is bounded by
    // the load completion latency.
    let completion = txns * txn_cy + tail;
    let rate_path = w_lsu * ilp as f64 * txns * txn_cy;
    // Pending-cap stall: beyond `lsu_pending_per_warp` outstanding
    // loads, each extra slot waits for an older completion (completions
    // are spaced one LSU round apart) — Table 9's ldmatrix.x1 4-warp
    // point.
    let cap = device.lsu_pending_per_warp as f64;
    let pend = (ilp as f64 - cap).max(0.0) * txns * txn_cy * w_lsu;
    let latency = rate_path.max(completion + pend);
    let bytes = warps as f64 * ilp as f64 * num.bytes_per_warp() as f64;
    AnalyticPrediction { latency, throughput: bytes / latency }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::a100;
    use crate::isa::shapes::*;
    use crate::isa::{AbType, CdType};

    #[test]
    fn table3_key_points_fp16_f32_k16() {
        // paper: (4,3) -> 27.4 cy / 897.6 FMA/clk; (8,2) -> 32.6 / 1004.2
        let d = a100();
        let i = MmaInstr::dense(AbType::Fp16, CdType::Fp32, M16N8K16);
        let p43 = predict_mma(&d, &i, 4, 3);
        assert!((p43.latency - 27.4).abs() < 1.5, "{p43:?}");
        assert!((p43.throughput - 897.6).abs() < 60.0, "{p43:?}");
        let p82 = predict_mma(&d, &i, 8, 2);
        assert!((p82.latency - 32.6).abs() < 1.5, "{p82:?}");
        assert!((p82.throughput - 1004.2).abs() < 40.0, "{p82:?}");
    }

    #[test]
    fn table6_sparse_small_k_anomaly() {
        // paper: mma.sp m16n8k16 FP16/FP32 (8,2) -> 25.4 cy, 1290 FMA/clk
        // (far below the 2000 sparse peak).
        let d = a100();
        let i = MmaInstr::sp(AbType::Fp16, CdType::Fp32, M16N8K16);
        let p = predict_mma(&d, &i, 8, 2);
        assert!((p.latency - 25.4).abs() < 1.5, "{p:?}");
        assert!((p.throughput - 1290.5).abs() < 80.0, "{p:?}");
        // and the large-k shape does reach ~2x dense:
        let big = MmaInstr::sp(AbType::Fp16, CdType::Fp32, M16N8K32);
        let pb = predict_mma(&d, &big, 8, 2);
        assert!(pb.throughput > 1900.0, "{pb:?}");
    }

    #[test]
    fn ldmatrix_saturation_points() {
        // Table 9: x4 (4,2) -> 32.2 cy / 127 B/clk; x4 (1,4) -> 64 B/clk.
        let d = a100();
        let p42 = predict_ldmatrix(&d, LdMatrixNum::X4, 4, 2);
        assert!((p42.latency - 32.0).abs() < 1.0, "{p42:?}");
        assert!((p42.throughput - 127.0).abs() < 4.0, "{p42:?}");
        let p14 = predict_ldmatrix(&d, LdMatrixNum::X4, 1, 4);
        assert!((p14.throughput - 64.0).abs() < 3.0, "{p14:?}");
    }

    #[test]
    fn six_warps_match_eight_warps_latency() {
        // §5 finding 5: latency(6 warps) == latency(8 warps) at any ILP.
        let d = a100();
        let i = MmaInstr::dense(AbType::Bf16, CdType::Fp32, M16N8K16);
        for ilp in 1..=4 {
            let p6 = predict_mma(&d, &i, 6, ilp);
            let p8 = predict_mma(&d, &i, 8, ilp);
            assert_eq!(p6.latency, p8.latency, "ILP={ilp}");
            assert!(p6.throughput <= p8.throughput);
        }
    }
}
