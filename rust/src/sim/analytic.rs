//! Closed-form steady-state model of every timing family — the
//! predictive fast path of the tuner.
//!
//! Historically this module was a property-test oracle for the `mma`
//! and `ldmatrix` loops; it is now a first-class predictive backend
//! covering all five timing families (`mma`/`mma.sp`, `ldmatrix`,
//! `ld.shared`, `wmma` via its compiled HMMA pieces, and the Appendix-A
//! `gemm` kernels), calibrated against the cycle simulator by the
//! pinned per-family error bounds in [`CALIBRATION_BOUNDS`]
//! (`tests/analytic_calibration.rs` is the CI drift gate). The tuner
//! ([`crate::workload::tune_workload`]) scores whole configuration
//! grids through these formulas — orders of magnitude faster than
//! cycle simulation — and confirms only the top-K frontier in the
//! simulator.
//!
//! For an `mma` loop the measured iteration latency is
//!
//! ```text
//! P = max( L + (ILP-1) + sync ,  W_sc * ILP * ii ,  ILP * (ii+1) )
//! latency    = max over sub-cores of P
//! throughput = total FMAs per iteration / latency
//! ```
//!
//! (dependency path vs token-bucket rate path vs single-warp dispatch
//! recovery), and for a data-movement loop
//!
//! ```text
//! P = max( txns*txn_cycles + tail + pend ,  W_lsu * ILP * txns * txn_cycles )
//! ```
//!
//! with the pending-cap correction `pend` when `ILP` exceeds
//! `lsu_pending_per_warp`. The `gemm` model composes the same unit
//! models along one k-step of the kernel (gmem pipe occupancy + latency
//! exposure, LSU staging/fragment traffic, Tensor-Core drain), using
//! the exact per-step traffic [`crate::gemm::step_traffic`] reports for
//! the warp programs.
//!
//! Every `predict_*` returns `Result` — an unsupported instruction or a
//! malformed configuration is a typed error (`invalid_param` at the
//! serving layer), never a panic on a serving thread.

use crate::device::Device;
use crate::gemm::{self, GemmConfig, Variant};
use crate::isa::{AbType, CdType, LdMatrixNum, LdSharedWidth, MmaInstr};
use crate::microbench::wmma::WmmaShape;

/// Prediction for one (#warps, ILP) configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AnalyticPrediction {
    /// Cycles per loop iteration (bottleneck warp); for `gemm`, cycles
    /// per k-step — the same unit the simulator's `Measurement` reports.
    pub latency: f64,
    /// FMA/clk/SM for compute loops; bytes/clk/SM for data movement.
    pub throughput: f64,
}

/// Pinned calibration contract of one timing family: the analytic
/// prediction must stay within `max_rel` relative error *or* `max_abs`
/// cycles of the cycle simulator over the family's full sweep grid on
/// every registry device. `tests/analytic_calibration.rs` asserts these
/// bounds — model or simulator drift fails CI before it can mislead the
/// tuner's pruning.
#[derive(Debug, Clone, Copy)]
pub struct CalibrationBound {
    /// Workload family keyword ([`crate::workload::Workload::kind`]);
    /// `mma` covers `mma.sp` too (same engine model).
    pub family: &'static str,
    /// Maximum relative latency error vs the cycle simulator.
    pub max_rel: f64,
    /// Absolute slack (cycles) admitted when the relative bound trips —
    /// short loops quantize on whole issue slots.
    pub max_abs: f64,
}

impl CalibrationBound {
    /// Does a (predicted, simulated) latency pair satisfy the bound?
    pub fn admits(&self, predicted: f64, simulated: f64) -> bool {
        let abs = (simulated - predicted).abs();
        abs / predicted.max(f64::MIN_POSITIVE) < self.max_rel || abs <= self.max_abs
    }
}

/// The per-family calibration table. Bounds are pinned with a small
/// margin over the observed worst case so the gate trips on genuine
/// drift, not on grid growth: the instruction families inherit the
/// tolerances the `sim_properties` oracle tests have always enforced;
/// `gemm` is the coarsest model (a per-k-step composition of the unit
/// models) and carries a correspondingly wider contract.
pub const CALIBRATION_BOUNDS: [CalibrationBound; 5] = [
    CalibrationBound { family: "mma", max_rel: 0.18, max_abs: 4.0 },
    CalibrationBound { family: "ldmatrix", max_rel: 0.20, max_abs: 5.0 },
    CalibrationBound { family: "ld.shared", max_rel: 0.20, max_abs: 5.0 },
    CalibrationBound { family: "wmma", max_rel: 0.22, max_abs: 6.0 },
    CalibrationBound { family: "gemm", max_rel: 0.50, max_abs: 250.0 },
];

/// Look up the pinned [`CalibrationBound`] of a workload family keyword
/// (`mma.sp` maps to the `mma` entry; `numeric` has no timing model and
/// returns `None`).
pub fn calibration_bound(family: &str) -> Option<&'static CalibrationBound> {
    let family = if family == "mma.sp" { "mma" } else { family };
    CALIBRATION_BOUNDS.iter().find(|b| b.family == family)
}

/// Warps resident on the most loaded of `n_units` units under
/// round-robin assignment.
fn worst_unit_load(warps: u32, n_units: u32) -> u32 {
    warps.div_ceil(n_units)
}

/// Shared closed form of the compute families: `chains` independent
/// accumulator chains per warp on the `(latency, ii)` pipeline.
fn compute_loop(device: &Device, latency: u32, ii: u32, warps: u32, chains: u32) -> f64 {
    let l = latency as f64;
    let ii = ii as f64;
    let sync = device.sync_cost as f64;
    let w_sc = worst_unit_load(warps, device.subcores) as f64;
    let dep_path = l + (chains as f64 - 1.0) + sync;
    let rate_path = w_sc * chains as f64 * ii;
    // Per-warp dispatch recovery: one warp alone sustains 1/(ii+1).
    let warp_path = chains as f64 * (ii + 1.0);
    dep_path.max(rate_path).max(warp_path)
}

/// Steady-state prediction of the §5/§6 mma microbenchmark.
pub fn predict_mma(
    device: &Device,
    instr: &MmaInstr,
    warps: u32,
    ilp: u32,
) -> Result<AnalyticPrediction, String> {
    let timing = device
        .timing(instr)
        .ok_or_else(|| format!("{instr} is not supported on {}", device.name))?;
    let latency = compute_loop(device, timing.latency, timing.ii, warps, ilp);
    let fmas = warps as f64 * ilp as f64 * instr.fmas() as f64;
    Ok(AnalyticPrediction { latency, throughput: fmas / latency })
}

/// Shared closed form of the pointer-chase load families: `ilp`
/// independent chains per warp, each load costing `txns` LSU
/// transactions and returning `bytes` per warp.
fn smem_chase_loop(device: &Device, txns: u32, warps: u32, ilp: u32) -> f64 {
    let txns = txns as f64;
    let txn_cy = device.lsu_txn_cycles as f64;
    let tail = device.lsu_tail as f64;
    let w_lsu = worst_unit_load(warps, device.lsu_units) as f64;
    // Each ILP slot is a pointer-chase chain: the next load's address
    // depends on the previous result, so a slot's period is bounded by
    // the load completion latency.
    let completion = txns * txn_cy + tail;
    let rate_path = w_lsu * ilp as f64 * txns * txn_cy;
    // Pending-cap stall: beyond `lsu_pending_per_warp` outstanding
    // loads, each extra slot waits for an older completion (completions
    // are spaced one LSU round apart) — Table 9's ldmatrix.x1 4-warp
    // point.
    let cap = device.lsu_pending_per_warp as f64;
    let pend = (ilp as f64 - cap).max(0.0) * txns * txn_cy * w_lsu;
    rate_path.max(completion + pend)
}

/// Steady-state prediction of the §7 ldmatrix microbenchmark.
pub fn predict_ldmatrix(
    device: &Device,
    num: LdMatrixNum,
    warps: u32,
    ilp: u32,
) -> Result<AnalyticPrediction, String> {
    if !device.arch.supports_ldmatrix() {
        return Err(format!("ldmatrix is not available on {} ({:?})", device.name, device.arch));
    }
    let latency = smem_chase_loop(device, num.count(), warps, ilp);
    let bytes = warps as f64 * ilp as f64 * num.bytes_per_warp() as f64;
    Ok(AnalyticPrediction { latency, throughput: bytes / latency })
}

/// Steady-state prediction of the Table-10 `ld.shared` bank-conflict
/// microbenchmark: `ways`-way conflicted loads are `ways` serialized
/// transactions on the warp's LSU (never fewer than the access width's
/// intrinsic minimum).
pub fn predict_ld_shared(
    device: &Device,
    width: LdSharedWidth,
    ways: u32,
    warps: u32,
    ilp: u32,
) -> Result<AnalyticPrediction, String> {
    if !(1..=32).contains(&ways) || !ways.is_power_of_two() {
        return Err(format!("ld.shared conflict ways must be a power of two in 1..=32, got {ways}"));
    }
    if ways < width.min_transactions() {
        return Err(format!(
            "{width} is intrinsically {}-transaction wide; ways must be >= {}",
            width.min_transactions(),
            width.min_transactions()
        ));
    }
    let txns = ways.max(width.min_transactions());
    let latency = smem_chase_loop(device, txns, warps, ilp);
    let bytes = warps as f64 * ilp as f64 * width.bytes_per_warp() as f64;
    Ok(AnalyticPrediction { latency, throughput: bytes / latency })
}

/// Steady-state prediction of the legacy `wmma.mma` interface (§2.2):
/// one wmma op compiles to `n/8` HMMA pieces, each an independent
/// accumulator chain, so the loop behaves like `mma` at an effective
/// ILP of `ilp * pieces` on the piece instruction's timing.
pub fn predict_wmma(
    device: &Device,
    shape: WmmaShape,
    ab: AbType,
    cd: CdType,
    warps: u32,
    ilp: u32,
) -> Result<AnalyticPrediction, String> {
    if shape.m == 0 || shape.k == 0 || shape.n == 0 || shape.n % 8 != 0 {
        return Err(format!(
            "wmma shape m{}n{}k{} is not fragmentable: m and k must be positive and n a \
             positive multiple of 8",
            shape.m, shape.n, shape.k
        ));
    }
    let pieces = shape.compiled_mmas(ab, cd);
    let piece = pieces.first().expect("a fragmentable wmma shape has pieces");
    let timing = device.timing(piece).ok_or_else(|| {
        format!("wmma compiles to {piece}, which is not supported on {}", device.name)
    })?;
    let chains = ilp * pieces.len() as u32;
    let latency = compute_loop(device, timing.latency, timing.ii, warps, chains);
    let fmas = warps as f64 * ilp as f64 * shape.fmas() as f64;
    Ok(AnalyticPrediction { latency, throughput: fmas / latency })
}

/// Steady-state prediction of one k-step of the Appendix-A GEMM
/// kernels, in the simulator's units (latency = cycles per k-step,
/// throughput = FMA/clk/SM).
///
/// The model composes the unit models along the step's structure:
///
/// * the global pipe serializes every warp's tile slice
///   (`staged_bytes / gmem_bpc` occupancy) and adds `gmem_latency` to
///   the last slice's arrival;
/// * the synchronous variants then drain the smem tile stores and the
///   fragment loads through the LSUs and the MMAs through the
///   Tensor-Core engine *serially* — the per-step CTA barriers forbid
///   cross-step overlap;
/// * the `cp.async` variant overlaps the copy for step `s` with the
///   `stages - 1` preceding steps, so its steady-state period is the
///   max of the bandwidth bound, the on-chip work, and the latency the
///   pipeline depth fails to hide.
pub fn predict_gemm(
    device: &Device,
    cfg: &GemmConfig,
    variant: Variant,
    l2_resident: bool,
) -> Result<AnalyticPrediction, String> {
    cfg.validate()?;
    let instr = cfg.instr();
    let timing = device
        .timing(&instr)
        .ok_or_else(|| format!("gemm needs {instr}, which is not supported on {}", device.name))?;
    if variant == Variant::Pipeline && !device.arch.supports_cp_async() {
        return Err(format!(
            "the gemm pipeline variant needs cp.async, which {} ({:?}) lacks",
            device.name, device.arch
        ));
    }
    let traffic = gemm::step_traffic(cfg, variant);
    let gmem_bpc = if l2_resident {
        device.gmem_bytes_per_cycle.max(gemm::L2_RESIDENT_BYTES_PER_CYCLE)
    } else {
        device.gmem_bytes_per_cycle
    } as f64;
    let txn_cy = device.lsu_txn_cycles as f64;
    let w_lsu = worst_unit_load(cfg.warps, device.lsu_units) as f64;
    let w_sc = worst_unit_load(cfg.warps, device.subcores) as f64;
    let mmas = cfg.mmas_per_warp_step() as f64;
    let ii = timing.ii as f64;

    // Whole-CTA gmem occupancy per step, and one warp's slice of it.
    let bw_total = (cfg.staged_bytes() as f64 / gmem_bpc).max(1.0);
    let slice_occ = (traffic.gmem_slice as f64 / gmem_bpc).max(1.0);
    let gmem_latency = device.gmem_latency as f64;
    // All warps' fragment loads serialize on the shared LSUs; the last
    // completion pays the writeback tail before its MMAs can start.
    let load_txns = (traffic.a_loads * traffic.a_txns + traffic.b_loads * traffic.b_txns) as f64;
    let lsu_loads = w_lsu * load_txns * txn_cy + device.lsu_tail as f64;
    // Tensor-Core drain of the step: the engine's busy time per
    // sub-core, but never less than one pipeline traversal + syncwarp.
    let mma_drain = (mmas * ii).max(timing.latency as f64) + device.sync_cost as f64;
    let tc_busy = w_sc * mmas * ii;
    // Barrier releases and issue slots of the step's fixed ops.
    let overhead = 4.0;

    let step = match variant {
        Variant::Baseline | Variant::Permuted => {
            let store = traffic.store_txns as f64 * txn_cy;
            // Stores drain inside the stagger shadow of the serialized
            // gmem slices except the last warp's own; when one store
            // outlasts the stagger window the LSU queue extends the
            // phase instead.
            let store_phase = store.max(w_lsu * store - (bw_total - slice_occ));
            let serial = bw_total + gmem_latency + store_phase + lsu_loads + mma_drain + overhead;
            serial.max(tc_busy)
        }
        Variant::Pipeline => {
            let work = lsu_loads + mma_drain + overhead;
            if cfg.stages == 1 {
                // A one-deep pipeline waits for its own copy every step:
                // the full occupancy + latency is exposed serially.
                bw_total + gmem_latency + work
            } else {
                // The copy for step s is issued stages-1 steps early; if
                // those steps are shorter than occupancy + latency, the
                // wait exposes the remainder as the period floor.
                let lat_need = (bw_total + gmem_latency) / (cfg.stages - 1) as f64;
                work.max(bw_total).max(tc_busy).max(lat_need)
            }
        }
    };
    let fmas_step = cfg.warps as f64 * mmas * instr.fmas() as f64;
    Ok(AnalyticPrediction { latency: step, throughput: fmas_step / step })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{a100, rtx2080ti};
    use crate::isa::shapes::*;

    #[test]
    fn table3_key_points_fp16_f32_k16() {
        // paper: (4,3) -> 27.4 cy / 897.6 FMA/clk; (8,2) -> 32.6 / 1004.2
        let d = a100();
        let i = MmaInstr::dense(AbType::Fp16, CdType::Fp32, M16N8K16);
        let p43 = predict_mma(&d, &i, 4, 3).unwrap();
        assert!((p43.latency - 27.4).abs() < 1.5, "{p43:?}");
        assert!((p43.throughput - 897.6).abs() < 60.0, "{p43:?}");
        let p82 = predict_mma(&d, &i, 8, 2).unwrap();
        assert!((p82.latency - 32.6).abs() < 1.5, "{p82:?}");
        assert!((p82.throughput - 1004.2).abs() < 40.0, "{p82:?}");
    }

    #[test]
    fn table6_sparse_small_k_anomaly() {
        // paper: mma.sp m16n8k16 FP16/FP32 (8,2) -> 25.4 cy, 1290 FMA/clk
        // (far below the 2000 sparse peak).
        let d = a100();
        let i = MmaInstr::sp(AbType::Fp16, CdType::Fp32, M16N8K16);
        let p = predict_mma(&d, &i, 8, 2).unwrap();
        assert!((p.latency - 25.4).abs() < 1.5, "{p:?}");
        assert!((p.throughput - 1290.5).abs() < 80.0, "{p:?}");
        // and the large-k shape does reach ~2x dense:
        let big = MmaInstr::sp(AbType::Fp16, CdType::Fp32, M16N8K32);
        let pb = predict_mma(&d, &big, 8, 2).unwrap();
        assert!(pb.throughput > 1900.0, "{pb:?}");
    }

    #[test]
    fn unsupported_instruction_is_an_error_not_a_panic() {
        // the serving tier maps this to error.code invalid_param
        let d = rtx2080ti();
        let i = MmaInstr::dense(AbType::Bf16, CdType::Fp32, M16N8K16);
        let err = predict_mma(&d, &i, 4, 2).unwrap_err();
        assert!(err.contains("not supported"), "{err}");
    }

    #[test]
    fn ldmatrix_saturation_points() {
        // Table 9: x4 (4,2) -> 32.2 cy / 127 B/clk; x4 (1,4) -> 64 B/clk.
        let d = a100();
        let p42 = predict_ldmatrix(&d, LdMatrixNum::X4, 4, 2).unwrap();
        assert!((p42.latency - 32.0).abs() < 1.0, "{p42:?}");
        assert!((p42.throughput - 127.0).abs() < 4.0, "{p42:?}");
        let p14 = predict_ldmatrix(&d, LdMatrixNum::X4, 1, 4).unwrap();
        assert!((p14.throughput - 64.0).abs() < 3.0, "{p14:?}");
    }

    #[test]
    fn ld_shared_matches_table_10_conflict_scaling() {
        // Table 10 (u32, 1 warp, ILP 1): 1-way 23 cy, 2-way 25, 4-way
        // 29, 8-way 37 — completion = ways * txn_cycles + tail.
        let d = a100();
        for (ways, cycles) in [(1u32, 23.0), (2, 25.0), (4, 29.0), (8, 37.0)] {
            let p = predict_ld_shared(&d, LdSharedWidth::U32, ways, 1, 1).unwrap();
            assert!((p.latency - cycles).abs() < 1.5, "ways {ways}: {p:?}");
        }
        // u64 is intrinsically two transactions wide.
        let p = predict_ld_shared(&d, LdSharedWidth::U64, 2, 1, 1).unwrap();
        assert!((p.latency - 25.0).abs() < 1.5, "{p:?}");
        assert!(predict_ld_shared(&d, LdSharedWidth::U64, 1, 1, 1).is_err());
        assert!(predict_ld_shared(&d, LdSharedWidth::U32, 3, 1, 1).is_err());
    }

    #[test]
    fn wmma_behaves_like_mma_at_effective_ilp() {
        // m16n16k16 compiles to 2 HMMA pieces: wmma at ILP i must match
        // the piece instruction at ILP 2i, with twice the FMAs.
        let d = a100();
        let shape = WmmaShape { m: 16, n: 16, k: 16 };
        let piece = MmaInstr::dense(AbType::Fp16, CdType::Fp32, M16N8K16);
        for (warps, ilp) in [(1u32, 1u32), (4, 2), (8, 2), (16, 1)] {
            let w = predict_wmma(&d, shape, AbType::Fp16, CdType::Fp32, warps, ilp).unwrap();
            let m = predict_mma(&d, &piece, warps, 2 * ilp).unwrap();
            assert_eq!(w.latency, m.latency, "({warps},{ilp})");
            assert!((w.throughput - m.throughput).abs() < 1e-9, "({warps},{ilp})");
        }
        // unsupported pieces surface as an error, not a panic
        assert!(predict_wmma(&rtx2080ti(), shape, AbType::Fp16, CdType::Fp32, 4, 1).is_err());
    }

    #[test]
    fn gemm_model_orders_the_variants_like_the_paper() {
        // Table 16/17 directions: async staging beats synchronous
        // staging, and the permuted layout beats baseline in the
        // L2-resident regime.
        let d = a100();
        let cfg = GemmConfig { size: 512, ..GemmConfig::default() };
        let base = predict_gemm(&d, &cfg, Variant::Baseline, false).unwrap();
        let pipe = predict_gemm(&d, &cfg, Variant::Pipeline, false).unwrap();
        assert!(
            base.latency > pipe.latency * 1.3,
            "baseline {base:?} vs pipeline {pipe:?}"
        );
        let base_l2 = predict_gemm(&d, &cfg, Variant::Baseline, true).unwrap();
        let perm_l2 = predict_gemm(&d, &cfg, Variant::Permuted, true).unwrap();
        assert!(
            base_l2.latency > perm_l2.latency * 1.3,
            "baseline {base_l2:?} vs permuted {perm_l2:?}"
        );
        // a one-deep pipeline exposes the copy latency
        let one = predict_gemm(
            &d,
            &GemmConfig { size: 512, stages: 1, ..GemmConfig::default() },
            Variant::Pipeline,
            false,
        )
        .unwrap();
        assert!(one.latency > pipe.latency, "stages 1 {one:?} vs 2 {pipe:?}");
        // malformed configurations are typed errors
        let bad = GemmConfig { warps: 6, ..GemmConfig::default() };
        assert!(predict_gemm(&d, &bad, Variant::Baseline, false).is_err());
    }

    #[test]
    fn six_warps_match_eight_warps_latency() {
        // §5 finding 5: latency(6 warps) == latency(8 warps) at any ILP.
        let d = a100();
        let i = MmaInstr::dense(AbType::Bf16, CdType::Fp32, M16N8K16);
        for ilp in 1..=4 {
            let p6 = predict_mma(&d, &i, 6, ilp).unwrap();
            let p8 = predict_mma(&d, &i, 8, ilp).unwrap();
            assert_eq!(p6.latency, p8.latency, "ILP={ilp}");
            assert!(p6.throughput <= p8.throughput);
        }
    }

    #[test]
    fn calibration_table_covers_every_timing_family() {
        for family in ["mma", "mma.sp", "ldmatrix", "ld.shared", "wmma", "gemm"] {
            let b = calibration_bound(family)
                .unwrap_or_else(|| panic!("no calibration bound for {family}"));
            assert!(b.max_rel > 0.0 && b.max_abs > 0.0);
            assert!(b.admits(100.0, 100.0));
            assert!(!b.admits(100.0, 100.0 * (1.0 + b.max_rel) + b.max_abs + 1.0));
        }
        assert!(calibration_bound("numeric").is_none());
    }
}
