//! The tcsim cycle loop: sub-core schedulers, scoreboards, token-bucket
//! Tensor-Core engines, LSUs, global-memory pipe, barriers, clocks.

use std::sync::Arc;

use crate::device::Device;

use super::profile::{Blocked, Profiler, Stall};
use super::program::{Op, WarpProgram};

/// Steady-state early exit: a warp counts as converged once it has at
/// least this many iteration marks (deep enough that the reported
/// `latency_per_iteration` window is dominated by settled iterations)…
const STEADY_MIN_MARKS: usize = 56;
/// …and its mean per-iteration latency over the trailing window of this
/// many marks matches the window before it…
const STEADY_WINDOW: usize = 12;
/// …within this relative tolerance. The window length is divisible by
/// 2, 3, 4 and 6 so the token-bucket engine's burst/stall oscillations
/// (period 2–6 at the paper's ILP depths) average identically in both
/// windows instead of aliasing.
const STEADY_REL_TOL: f64 = 5e-4;

/// Per-warp measurement output.
#[derive(Debug, Clone)]
pub struct WarpResult {
    pub warp_id: usize,
    /// Cycle of every IterMark.
    pub iter_marks: Vec<u64>,
    /// Cycle the warp retired its last instruction.
    pub finish: u64,
}

impl WarpResult {
    /// Steady-state cycles per iteration: mean over the back half of the
    /// marks (first half treated as warm-up), matching the paper's
    /// `Δclock64 / ITERS` with enough ITERS to hide the ramp.
    pub fn latency_per_iteration(&self) -> f64 {
        let n = self.iter_marks.len();
        if n < 2 {
            return self.finish as f64;
        }
        // Δclock64 / ITERS like the paper (Fig. 4), skipping only a short
        // pipeline-fill prefix. Averaging a long window matters: the
        // token-bucket engine can oscillate between burst and stall
        // phases, and a short window would alias with them.
        let i0 = (n - 1) / 8;
        let span = self.iter_marks[n - 1] - self.iter_marks[i0];
        span as f64 / (n - 1 - i0) as f64
    }
}

/// Token-bucket compute engine (one Tensor-Core pipeline per sub-core;
/// a second instance models the CUDA-core FPU fallback path).
#[derive(Debug, Clone, Default)]
struct Engine {
    /// Work credit in cycles; refills 1/cycle up to `cap`.
    level: f64,
    cap: f64,
    last_update: u64,
}

impl Engine {
    fn refill(&mut self, now: u64, cap: u32) {
        let cap = cap as f64;
        if cap > self.cap {
            // Burst window follows the deepest pipeline seen so far; the
            // newly visible capacity is immediately available (an empty
            // pipeline holds full burst credit).
            self.level += cap - self.cap;
            self.cap = cap;
        }
        self.level = (self.level + (now - self.last_update) as f64).min(self.cap);
        self.last_update = now;
    }

    fn can_accept(&self, ii: u32) -> bool {
        self.level + 1e-9 >= ii as f64
    }

    fn accept(&mut self, ii: u32) {
        self.level -= ii as f64;
    }
}

/// One shared-memory data-movement unit.
#[derive(Debug, Clone, Default)]
struct Lsu {
    free_at: u64,
}

#[derive(Debug, Clone)]
struct WarpState {
    pc: usize,
    /// Earliest cycle the warp may issue its next instruction.
    next_issue: u64,
    /// reg -> ready cycle (indexed by register id; grown on demand).
    scoreboard: Vec<u64>,
    /// Outstanding MMA completion times (what SyncWarp waits for).
    mma_inflight: Vec<u64>,
    /// Per-warp dispatch bucket (rate 1/(ii+1), burst = pipeline depth):
    /// one warp alone sustains only 1/(ii+1) — the paper's ~230-of-256
    /// single-warp ceiling; a co-resident warp fills the bubble, which
    /// is why small-k shapes need 8 warps (§5 finding 8).
    dispatch: Engine,
    /// Outstanding load completion times (pending-cap bookkeeping).
    loads_inflight: Vec<u64>,
    /// Completion cycles of committed-but-unwaited cp.async groups.
    cpasync_groups: Vec<u64>,
    /// Latest completion among cp.asyncs not yet committed to a group.
    cpasync_open: u64,
    iter_marks: Vec<u64>,
    finish: u64,
}

impl WarpState {
    fn set_ready(&mut self, reg: u32, at: u64) {
        let idx = reg as usize;
        if idx >= self.scoreboard.len() {
            self.scoreboard.resize(idx + 1, 0);
        }
        self.scoreboard[idx] = at;
    }

    fn new() -> Self {
        Self {
            pc: 0,
            next_issue: 0,
            scoreboard: Vec::new(),
            mma_inflight: Vec::new(),
            dispatch: Engine::default(),
            loads_inflight: Vec::new(),
            cpasync_groups: Vec::new(),
            cpasync_open: 0,
            iter_marks: Vec::new(),
            finish: 0,
        }
    }

    fn gc(&mut self, now: u64) {
        self.mma_inflight.retain(|&t| t > now);
        self.loads_inflight.retain(|&t| t > now);
    }
}

/// Cycle-level simulator of one SM running `programs` (warp i runs
/// `programs[i]`; warp -> sub-core assignment is `i % subcores`, warp ->
/// LSU assignment `i % lsu_units`, both round-robin like the hardware's
/// even distribution).
pub struct SmSim<'d> {
    device: &'d Device,
    /// Per-warp traces. `Arc`-shared: the microbenchmark harness runs
    /// the *same* unrolled program on every warp, and an ITERS-deep
    /// trace deep-cloned 32 times used to dominate setup cost.
    programs: Vec<Arc<WarpProgram>>,
    tc_engines: Vec<Engine>,
    fpu_engines: Vec<Engine>,
    lsus: Vec<Lsu>,
    gmem_free_at: u64,
    warps: Vec<WarpState>,
    /// Per-sub-core LRR pointer (index into that sub-core's warp list).
    lrr: Vec<usize>,
    /// Precomputed warp lists per sub-core (round-robin residency).
    subcore_warps: Vec<Vec<usize>>,
    now: u64,
    /// Hard cap to catch deadlocked programs in tests.
    max_cycles: u64,
    /// Stop simulating once every warp's per-iteration latency has
    /// converged (see [`SmSim::with_steady_state_exit`]).
    steady_exit: bool,
    /// Total iteration marks at the last convergence/budget check (so
    /// those checks run once per new mark, not once per cycle).
    marks_at_last_check: usize,
}

impl<'d> SmSim<'d> {
    pub fn new(device: &'d Device, programs: Vec<WarpProgram>) -> Self {
        Self::from_shared(device, programs.into_iter().map(Arc::new).collect())
    }

    /// Run the same program on `warps` warps without deep-cloning the
    /// trace: every warp shares one `Arc` of it. This is the
    /// microbenchmark configuration (§4: identical loops on every
    /// resident warp).
    pub fn replicated(device: &'d Device, program: WarpProgram, warps: u32) -> Self {
        let shared = Arc::new(program);
        Self::from_shared(device, (0..warps).map(|_| Arc::clone(&shared)).collect())
    }

    /// General form: warp `i` runs `programs[i]`, programs may alias.
    ///
    /// Debug builds run the tclint static verifier first and panic (with
    /// the rule id) on any Error-severity diagnostic — a malformed
    /// program must fail loudly before it can hang or silently
    /// mis-attribute cycles. Release builds skip the pass entirely: the
    /// simulate path stays bit-identical with zero analysis overhead
    /// (`repro lint` / `POST /v1/lint` cover release-mode checking).
    pub fn from_shared(device: &'d Device, programs: Vec<Arc<WarpProgram>>) -> Self {
        assert!(!programs.is_empty(), "need at least one warp");
        #[cfg(debug_assertions)]
        crate::analysis::verify_or_panic(&programs, device);
        let warps: Vec<WarpState> = programs.iter().map(|_| WarpState::new()).collect();
        Self {
            device,
            tc_engines: vec![Engine::default(); device.subcores as usize],
            fpu_engines: vec![Engine::default(); device.subcores as usize],
            lsus: vec![Lsu::default(); device.lsu_units as usize],
            gmem_free_at: 0,
            subcore_warps: {
                let mut m = vec![Vec::new(); device.subcores as usize];
                for w in 0..warps.len() {
                    m[w % device.subcores as usize].push(w);
                }
                m
            },
            warps,
            lrr: vec![0; device.subcores as usize],
            programs,
            now: 0,
            max_cycles: 200_000_000,
            steady_exit: false,
            marks_at_last_check: 0,
        }
    }

    pub fn with_max_cycles(mut self, max: u64) -> Self {
        self.max_cycles = max;
        self
    }

    /// Stop the cycle loop early once **every** warp's
    /// `latency_per_iteration` has converged: at least
    /// `STEADY_MIN_MARKS` marks, and the mean mark-to-mark delta over
    /// the trailing `STEADY_WINDOW` marks within `STEADY_REL_TOL` of
    /// the window before it. Returned `iter_marks` are then a truncated
    /// (but steady-state) prefix.
    ///
    /// Only meaningful for uniform measurement loops whose result is
    /// `latency_per_iteration()` — programs measured by *total* cycles
    /// (the GEMM kernels read `finish`) must run to completion and keep
    /// this off. Programs with fewer than `STEADY_MIN_MARKS`
    /// iterations can never satisfy the bound, so short-ITERS runs are
    /// exhaustive with or without the flag.
    pub fn with_steady_state_exit(mut self) -> Self {
        self.steady_exit = true;
        self
    }

    /// Has every warp's trailing-window iteration latency converged?
    fn steady_state_reached(&self) -> bool {
        self.warps.iter().all(|st| {
            let n = st.iter_marks.len();
            if n < STEADY_MIN_MARKS || n < 2 * STEADY_WINDOW + 1 {
                return false;
            }
            let recent = (st.iter_marks[n - 1] - st.iter_marks[n - 1 - STEADY_WINDOW]) as f64;
            let prior = (st.iter_marks[n - 1 - STEADY_WINDOW]
                - st.iter_marks[n - 1 - 2 * STEADY_WINDOW]) as f64;
            prior > 0.0 && ((recent - prior) / prior).abs() <= STEADY_REL_TOL
        })
    }

    fn subcore_of(&self, warp: usize) -> usize {
        warp % self.device.subcores as usize
    }

    fn lsu_of(&self, warp: usize) -> usize {
        warp % self.device.lsu_units as usize
    }

    fn all_done(&self) -> bool {
        self.warps
            .iter()
            .zip(&self.programs)
            .all(|(w, p)| w.pc >= p.instrs.len())
    }

    /// Can `warp` issue its next instruction at `now`? Returns the
    /// stall-release lower bound when blocked (for event skipping),
    /// tagged with the pipeline cause (for stall attribution).
    fn issue_block(&mut self, warp: usize) -> Result<(), Blocked> {
        let now = self.now;
        // Retire completed in-flight entries first — a warp blocked on
        // the pending cap must see completions even while not issuing.
        self.warps[warp].gc(now);
        let st = &self.warps[warp];
        if st.pc >= self.programs[warp].instrs.len() {
            return Err(Blocked::new(u64::MAX, Stall::Done));
        }
        if st.next_issue > now {
            // Issue recovery, a sync tail or a barrier-release wait: the
            // slot is unavailable rather than a pipeline resource.
            return Err(Blocked::new(st.next_issue, Stall::IssueSlot));
        }
        let instr = &self.programs[warp].instrs[st.pc];
        // Operand readiness.
        let mut ready_at = now;
        for s in &instr.srcs {
            if let Some(&t) = st.scoreboard.get(*s as usize) {
                ready_at = ready_at.max(t);
            }
        }
        if ready_at > now {
            return Err(Blocked::new(ready_at, Stall::ScoreboardDep));
        }
        match &instr.op {
            Op::Mma { ii, latency, fpu, .. } => {
                let (ii, latency) = (*ii, *latency);
                let wd = &mut self.warps[warp].dispatch;
                wd.refill(now, latency.max(ii + 1));
                if !wd.can_accept(ii + 1) {
                    let deficit = (ii + 1) as f64 - wd.level;
                    return Err(Blocked::new(now + deficit.ceil() as u64, Stall::TokenBucket));
                }
                let sc = self.subcore_of(warp);
                let eng = if *fpu { &mut self.fpu_engines[sc] } else { &mut self.tc_engines[sc] };
                eng.refill(now, latency.max(ii));
                if !eng.can_accept(ii) {
                    let deficit = ii as f64 - eng.level;
                    return Err(Blocked::new(now + deficit.ceil() as u64, Stall::TokenBucket));
                }
                Ok(())
            }
            Op::SmemLoad { .. } | Op::GmemLoad { .. } => {
                let st = &self.warps[warp];
                if st.loads_inflight.len() >= self.device.lsu_pending_per_warp as usize {
                    let earliest = st.loads_inflight.iter().copied().min().unwrap();
                    return Err(Blocked::new(earliest, Stall::SmemConflict));
                }
                Ok(())
            }
            Op::SmemStore { .. } | Op::CpAsync { .. } | Op::CpAsyncCommit => Ok(()),
            Op::CpAsyncWait { max_pending } => {
                let st = &self.warps[warp];
                let pending: Vec<u64> =
                    st.cpasync_groups.iter().copied().filter(|&t| t > now).collect();
                if pending.len() > *max_pending as usize {
                    // Wait for the oldest groups to complete.
                    let mut sorted = pending;
                    sorted.sort_unstable();
                    let release = sorted[sorted.len() - 1 - *max_pending as usize];
                    return Err(Blocked::new(release, Stall::CpAsyncWait));
                }
                Ok(())
            }
            Op::SyncWarp => {
                let st = &self.warps[warp];
                let last_mma = st.mma_inflight.iter().copied().max().unwrap_or(0);
                if last_mma > now {
                    // Waiting on outstanding mma results: a data
                    // dependency, even though no register is named.
                    return Err(Blocked::new(last_mma, Stall::ScoreboardDep));
                }
                Ok(())
            }
            Op::BarSync => {
                // Handled collectively in `try_release_barrier`.
                Err(Blocked::new(u64::MAX - 1, Stall::IssueSlot))
            }
            Op::IterMark => Ok(()),
        }
    }

    /// Static name and modeled occupancy of `warp`'s next instruction —
    /// a rendering hint for trace events, read before [`Self::issue`].
    fn trace_info(&self, warp: usize) -> (&'static str, u64) {
        let d = self.device;
        match &self.programs[warp].instrs[self.warps[warp].pc].op {
            Op::Mma { latency, fpu, .. } => (if *fpu { "fma" } else { "mma" }, *latency as u64),
            Op::SmemLoad { txns, .. } => (
                "smem_load",
                (*txns as u64) * d.lsu_txn_cycles as u64 + d.lsu_tail as u64,
            ),
            Op::SmemStore { txns, .. } => {
                ("smem_store", (*txns as u64) * d.lsu_txn_cycles as u64)
            }
            Op::GmemLoad { bytes } => (
                "gmem_load",
                bytes.div_ceil(d.gmem_bytes_per_cycle as u64).max(1) + d.gmem_latency as u64,
            ),
            Op::CpAsync { bytes } => (
                "cp_async",
                bytes.div_ceil(d.gmem_bytes_per_cycle as u64).max(1) + d.gmem_latency as u64,
            ),
            Op::CpAsyncCommit => ("cp_async_commit", 1),
            Op::CpAsyncWait { .. } => ("cp_async_wait", 1),
            Op::SyncWarp => ("sync_warp", d.sync_cost as u64),
            Op::BarSync => ("bar_sync", 1),
            Op::IterMark => ("iter_mark", 1),
        }
    }

    /// Execute the (already admissible) instruction of `warp`.
    fn issue(&mut self, warp: usize) {
        let now = self.now;
        let lsu_idx = self.lsu_of(warp);
        let sc = self.subcore_of(warp);
        let pc = self.warps[warp].pc;
        // Only the (plain-data) op and the dst register are needed here —
        // never clone the src Vec on the hot path.
        let (op, dst) = {
            let i = &self.programs[warp].instrs[pc];
            (i.op.clone(), i.dst)
        };
        let device = self.device;
        let st = &mut self.warps[warp];
        st.pc += 1;
        st.next_issue = now + 1;
        match op {
            Op::Mma { ii, latency, fpu, .. } => {
                let eng = if fpu { &mut self.fpu_engines[sc] } else { &mut self.tc_engines[sc] };
                eng.refill(now, latency.max(ii));
                eng.accept(ii);
                // per-warp dispatch recovery (1 extra cycle per mma)
                st.dispatch.refill(now, latency.max(ii + 1));
                st.dispatch.accept(ii + 1);
                let done = now + latency as u64;
                st.mma_inflight.push(done);
                if let Some(d) = dst {
                    st.set_ready(d, done);
                }
            }
            Op::SmemLoad { txns, .. } => {
                let lsu = &mut self.lsus[lsu_idx];
                let start = lsu.free_at.max(now);
                lsu.free_at = start + (txns as u64) * device.lsu_txn_cycles as u64;
                let done = lsu.free_at + device.lsu_tail as u64;
                st.loads_inflight.push(done);
                if let Some(d) = dst {
                    st.set_ready(d, done);
                }
            }
            Op::SmemStore { txns, .. } => {
                // Stores occupy the fabric but have no writeback tail.
                let lsu = &mut self.lsus[lsu_idx];
                let start = lsu.free_at.max(now);
                lsu.free_at = start + (txns as u64) * device.lsu_txn_cycles as u64;
            }
            Op::GmemLoad { bytes } => {
                let occupancy = bytes.div_ceil(device.gmem_bytes_per_cycle as u64).max(1);
                let start = self.gmem_free_at.max(now);
                self.gmem_free_at = start + occupancy;
                let done = self.gmem_free_at + device.gmem_latency as u64;
                st.loads_inflight.push(done);
                if let Some(d) = dst {
                    st.set_ready(d, done);
                }
            }
            Op::CpAsync { bytes } => {
                let occupancy = bytes.div_ceil(device.gmem_bytes_per_cycle as u64).max(1);
                let start = self.gmem_free_at.max(now + 1);
                self.gmem_free_at = start + occupancy;
                let done = self.gmem_free_at + device.gmem_latency as u64;
                st.cpasync_open = st.cpasync_open.max(done);
            }
            Op::CpAsyncCommit => {
                let open = std::mem::take(&mut st.cpasync_open);
                st.cpasync_groups.push(open);
            }
            Op::CpAsyncWait { .. } => {
                st.cpasync_groups.retain(|&t| t > now);
            }
            Op::SyncWarp => {
                st.mma_inflight.clear();
                st.next_issue = now + device.sync_cost as u64;
            }
            Op::BarSync => unreachable!("BarSync released collectively"),
            Op::IterMark => {
                // clock64() read: free in the timing model.
                st.iter_marks.push(now);
                st.next_issue = now;
            }
        }
        st.finish = st.finish.max(now);
        st.gc(now);
    }

    /// Release the CTA barrier if every unfinished warp is parked on one.
    fn try_release_barrier(&mut self) -> bool {
        let mut arrivals = Vec::new();
        for (i, (st, p)) in self.warps.iter().zip(&self.programs).enumerate() {
            if st.pc >= p.instrs.len() {
                continue; // retired warps do not participate
            }
            match p.instrs[st.pc].op {
                Op::BarSync => arrivals.push(i),
                _ => return false,
            }
        }
        if arrivals.is_empty() {
            return false;
        }
        // All active warps arrived: everyone must also have drained its
        // issue stalls; release one cycle later.
        let release = self
            .warps
            .iter()
            .zip(&self.programs)
            .filter(|(st, p)| st.pc < p.instrs.len())
            .map(|(st, _)| st.next_issue)
            .max()
            .unwrap_or(self.now)
            .max(self.now)
            + 1;
        for i in arrivals {
            let st = &mut self.warps[i];
            st.pc += 1;
            st.next_issue = release;
            st.finish = st.finish.max(release);
        }
        true
    }

    /// Run to completion; returns per-warp measurements. Equivalent to
    /// [`Self::run_profiled`] with a [`Profiler::Null`] — the unprofiled
    /// fast path every pinned timing result goes through.
    pub fn run(self) -> Vec<WarpResult> {
        self.run_profiled(&mut Profiler::Null)
    }

    /// Run to completion, attributing every warp-cycle to a stall
    /// category through `profiler` (extract the accumulated
    /// [`SimProfile`](super::SimProfile) with
    /// [`Profiler::take_profile`] afterwards).
    ///
    /// The timing schedule is *identical* in all three profiler modes:
    /// the profiler only observes the stall causes the event-skipping
    /// loop already computes, never adds probes, and a warp that was not
    /// scanned this cycle (the sub-core found an issuer before reaching
    /// it) is attributed `issue_slot` rather than probed — probing would
    /// touch the token-bucket refill clocks and could perturb the
    /// schedule of heterogeneous programs.
    pub fn run_profiled(mut self, profiler: &mut Profiler) -> Vec<WarpResult> {
        let profiling = profiler.is_on();
        profiler.begin(self.warps.len() as u64);
        // One stall cause per warp per simulated cycle; only allocated
        // when profiling is on (the Null path never touches it).
        let mut causes: Vec<Stall> =
            if profiling { vec![Stall::IssueSlot; self.warps.len()] } else { Vec::new() };
        while !self.all_done() {
            if self.now >= self.max_cycles {
                panic!("tcsim exceeded max_cycles — deadlocked program?");
            }
            // clock64() reads are free: drain any IterMarks first so a
            // mark never steals an issue slot from a real instruction.
            let mut marks_total = 0;
            for w in 0..self.warps.len() {
                let st = &mut self.warps[w];
                while st.pc < self.programs[w].instrs.len()
                    && matches!(self.programs[w].instrs[st.pc].op, Op::IterMark)
                    && st.next_issue <= self.now
                {
                    st.iter_marks.push(self.now.max(st.next_issue));
                    st.finish = st.finish.max(self.now);
                    st.pc += 1;
                }
                marks_total += st.iter_marks.len();
            }
            if self.all_done() {
                break;
            }
            if marks_total != self.marks_at_last_check {
                self.marks_at_last_check = marks_total;
                // Per-request deadline watchdog, polled at the same
                // mark granularity as the convergence check so the
                // per-cycle path gains no branch. A blown budget
                // latches the thread-local flag and exits with a
                // truncated trace; the cell layer never caches it.
                if super::budget::poll() {
                    break;
                }
                if self.steady_exit && self.steady_state_reached() {
                    break;
                }
            }
            if profiling {
                // Default attribution, refined by the scan below: a
                // retired warp is `done`, an unscanned one lost the slot.
                for (w, cause) in causes.iter_mut().enumerate() {
                    *cause = if self.warps[w].pc >= self.programs[w].instrs.len() {
                        Stall::Done
                    } else {
                        Stall::IssueSlot
                    };
                }
            }
            let mut issued_any = false;
            let mut next_event = u64::MAX;
            // Each sub-core issues at most one instruction per cycle,
            // round-robin over its resident warps (LRR).
            for sc in 0..self.device.subcores as usize {
                let warps_here = std::mem::take(&mut self.subcore_warps[sc]);
                if warps_here.is_empty() {
                    self.subcore_warps[sc] = warps_here;
                    continue;
                }
                // Loose round-robin: resume scanning just after the warp
                // that issued last so one warp cannot monopolize the
                // pipeline (a `now % n` rotation aliases with the token
                // refill period and convoys the warps).
                let rot = self.lrr[sc] % warps_here.len();
                let mut issued = false;
                for off in 0..warps_here.len() {
                    let idx = (rot + off) % warps_here.len();
                    let w = warps_here[idx];
                    match self.issue_block(w) {
                        Ok(()) => {
                            if profiler.is_tracing() {
                                let (name, dur) = self.trace_info(w);
                                profiler.record_issue(w, name, self.now, dur);
                            }
                            if profiling {
                                causes[w] = Stall::Issued;
                            }
                            self.issue(w);
                            self.lrr[sc] = idx + 1;
                            issued = true;
                            issued_any = true;
                            break;
                        }
                        Err(b) => {
                            if profiling {
                                causes[w] = b.stall;
                            }
                            next_event = next_event.min(b.release);
                        }
                    }
                }
                if issued {
                    next_event = next_event.min(self.now + 1);
                }
                self.subcore_warps[sc] = warps_here;
            }
            if !issued_any && self.try_release_barrier() {
                // The barrier release moves no clock: the re-scan next
                // iteration recomputes every cause, so nothing is
                // accounted here.
                continue;
            }
            if issued_any {
                profiler.account(&causes, 1);
                self.now += 1;
            } else {
                // Event skip: jump to the earliest stall release. The
                // skipped span is attributed to the causes just
                // computed — by construction nothing changes until the
                // earliest release cycle.
                let target = next_event.max(self.now + 1);
                if target >= u64::MAX - 1 {
                    panic!("tcsim deadlock: no warp can ever issue");
                }
                profiler.account(&causes, target - self.now);
                self.now = target;
            }
        }
        self.warps
            .iter()
            .enumerate()
            .map(|(i, st)| WarpResult {
                warp_id: i,
                iter_marks: st.iter_marks.clone(),
                finish: st.finish,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::program::ProgramBuilder;
    use super::*;
    use crate::device::a100;

    fn mma_loop(iters: usize, ilp: usize, ii: u32, lat: u32) -> WarpProgram {
        let mut b = ProgramBuilder::new();
        let slots: Vec<u32> = (0..ilp).map(|_| b.init_reg()).collect();
        for _ in 0..iters {
            for &d in &slots {
                b.mma(ii, lat, 2048, d, vec![d]);
            }
            b.sync_warp();
            b.iter_mark();
        }
        b.build()
    }

    #[test]
    fn single_warp_completion_latency() {
        // ILP=1, 1 warp: iteration period == pipeline depth + sync cost.
        let d = a100();
        let res = SmSim::new(&d, vec![mma_loop(64, 1, 8, 24)]).run();
        let lat = res[0].latency_per_iteration();
        assert!((lat - 25.0).abs() < 1.0, "got {lat}");
    }

    #[test]
    fn ilp3_unsaturated_period_is_latency_bound() {
        // 1 warp, ILP=3, k16-like (ii=8, L=24): period ≈ L + ILP - 1 + 1.
        let d = a100();
        let res = SmSim::new(&d, vec![mma_loop(64, 3, 8, 24)]).run();
        let lat = res[0].latency_per_iteration();
        assert!((26.0..29.0).contains(&lat), "got {lat}");
    }

    #[test]
    fn ilp4_rate_bound() {
        // 1 warp, ILP=4: the token bucket caps at one instr per ii.
        let d = a100();
        let res = SmSim::new(&d, vec![mma_loop(64, 4, 8, 24)]).run();
        let lat = res[0].latency_per_iteration();
        assert!((32.0..38.0).contains(&lat), "got {lat}");
    }

    #[test]
    fn two_warps_per_subcore_saturate() {
        // 8 warps ILP=2 on 4 sub-cores: period = 2*2*8 = 32 (+ε).
        let d = a100();
        let progs = vec![mma_loop(64, 2, 8, 24); 8];
        let res = SmSim::new(&d, progs).run();
        let worst = res.iter().map(|r| r.latency_per_iteration()).fold(0.0, f64::max);
        assert!((32.0..34.5).contains(&worst), "got {worst}");
    }

    #[test]
    fn six_warp_dip() {
        // 6 warps ILP=3: sub-cores 0,1 carry two warps (period ~48),
        // sub-cores 2,3 one (≈27) — the paper's Fig. 6 anomaly.
        let d = a100();
        let progs = vec![mma_loop(64, 3, 8, 24); 6];
        let res = SmSim::new(&d, progs).run();
        let worst = res.iter().map(|r| r.latency_per_iteration()).fold(0.0, f64::max);
        let best = res.iter().map(|r| r.latency_per_iteration()).fold(f64::MAX, f64::min);
        assert!((46.0..51.0).contains(&worst), "got {worst}");
        assert!((26.0..30.0).contains(&best), "got {best}");
    }

    #[test]
    fn barrier_releases_all_warps_together() {
        let d = a100();
        let mk = |n_mma: usize| {
            let mut b = ProgramBuilder::new();
            for _ in 0..n_mma {
                let r = b.init_reg();
                b.mma(8, 24, 2048, r, vec![r]);
            }
            b.sync_warp();
            b.push(Op::BarSync, None, vec![]);
            b.iter_mark();
            b.build()
        };
        // Unbalanced warps: the barrier holds the fast one back.
        let res = SmSim::new(&d, vec![mk(1), mk(8)]).run();
        assert_eq!(res[0].iter_marks.len(), 1);
        let delta = res[0].iter_marks[0].abs_diff(res[1].iter_marks[0]);
        assert!(delta <= 1, "barrier skew {delta}");
    }

    #[test]
    fn smem_load_loop_throughput() {
        // 8 warps x ldmatrix.x4 (4 txns): 4 warps per LSU, period
        // = 4 warps * 4 txns * 2 cycles = 32 -> 128 B/clk/SM.
        let d = a100();
        let mk = || {
            let mut b = ProgramBuilder::new();
            let r = b.init_reg();
            for _ in 0..64 {
                // pointer-chase: next address depends on the last result
                b.push(Op::SmemLoad { txns: 4, bytes: 512 }, Some(r), vec![r]);
                b.sync_warp();
                b.iter_mark();
            }
            b.build()
        };
        let res = SmSim::new(&d, vec![mk(); 8]).run();
        let worst = res.iter().map(|r| r.latency_per_iteration()).fold(0.0, f64::max);
        let thr = 8.0 * 512.0 / worst;
        assert!((115.0..132.0).contains(&thr), "thr {thr} lat {worst}");
    }

    #[test]
    fn gmem_load_has_long_latency() {
        let d = a100();
        let mut b = ProgramBuilder::new();
        let r = b.alloc_reg();
        b.push(Op::GmemLoad { bytes: 128 }, Some(r), vec![]);
        // consume the loaded value so the dependency is exercised
        b.mma(8, 24, 2048, r, vec![r]);
        b.sync_warp();
        b.iter_mark();
        let res = SmSim::new(&d, vec![b.build()]).run();
        assert!(res[0].iter_marks[0] > d.gmem_latency as u64);
    }

    #[test]
    fn replicated_matches_deep_cloned_programs() {
        // Arc-sharing the trace is a pure setup optimization: the
        // schedule must be identical to per-warp deep clones.
        let d = a100();
        let cloned = SmSim::new(&d, vec![mma_loop(64, 2, 8, 24); 8]).run();
        let shared = SmSim::replicated(&d, mma_loop(64, 2, 8, 24), 8).run();
        assert_eq!(cloned.len(), shared.len());
        for (a, b) in cloned.iter().zip(&shared) {
            assert_eq!(a.iter_marks, b.iter_marks, "warp {}", a.warp_id);
            assert_eq!(a.finish, b.finish, "warp {}", a.warp_id);
        }
    }

    #[test]
    fn steady_state_exit_truncates_long_runs_without_moving_the_answer() {
        let d = a100();
        let full = SmSim::new(&d, vec![mma_loop(96, 2, 8, 24)]).run();
        let early = SmSim::new(&d, vec![mma_loop(96, 2, 8, 24)])
            .with_steady_state_exit()
            .run();
        let n = early[0].iter_marks.len();
        assert!(n < 96, "exit must fire before the full 96 iterations, got {n}");
        assert!(n >= 56, "exit must not fire before the minimum mark count, got {n}");
        let (f, e) = (full[0].latency_per_iteration(), early[0].latency_per_iteration());
        assert!((f - e).abs() / f < 5e-3, "full {f} vs early {e}");
    }

    #[test]
    fn steady_state_exit_never_fires_on_short_programs() {
        // Fewer iterations than the convergence minimum: the run is
        // exhaustive, flag or no flag.
        let d = a100();
        for iters in [8usize, 24, 55] {
            let res = SmSim::new(&d, vec![mma_loop(iters, 1, 8, 24)])
                .with_steady_state_exit()
                .run();
            assert_eq!(res[0].iter_marks.len(), iters, "iters {iters}");
        }
    }

    #[test]
    fn steady_state_exit_waits_for_every_warp() {
        // Two warps on different sub-cores with different loop depths:
        // the heavier warp converges later, and the light one must not
        // trigger the exit alone (its marks keep accumulating past the
        // heavy warp's convergence point, proving the all-warps gate).
        let d = a100();
        let res = SmSim::from_shared(
            &d,
            vec![
                std::sync::Arc::new(mma_loop(96, 1, 8, 24)),
                std::sync::Arc::new(mma_loop(96, 4, 8, 24)),
            ],
        )
        .with_steady_state_exit()
        .run();
        for r in &res {
            assert!(
                r.iter_marks.len() >= 56,
                "warp {} stopped at {} marks",
                r.warp_id,
                r.iter_marks.len()
            );
        }
    }

    #[test]
    fn profiled_run_is_bit_identical_and_accounts_every_warp_cycle() {
        use super::super::profile::Profiler;
        let d = a100();
        let plain = SmSim::new(&d, vec![mma_loop(64, 2, 8, 24); 6]).run();
        let mut prof = Profiler::counting();
        let profiled =
            SmSim::new(&d, vec![mma_loop(64, 2, 8, 24); 6]).run_profiled(&mut prof);
        for (a, b) in plain.iter().zip(&profiled) {
            assert_eq!(a.iter_marks, b.iter_marks, "warp {}", a.warp_id);
            assert_eq!(a.finish, b.finish, "warp {}", a.warp_id);
        }
        let p = prof.take_profile().unwrap();
        assert_eq!(p.warps, 6);
        assert_eq!(p.total(), p.warp_cycles, "categories must sum to warps x cycles");
        assert_eq!(p.warp_cycles, 6 * p.cycles);
        assert!(p.issued > 0, "{p:?}");
    }

    #[test]
    fn tracing_records_a_monotonic_per_warp_timeline() {
        use super::super::profile::Profiler;
        let d = a100();
        let mut prof = Profiler::tracing();
        SmSim::new(&d, vec![mma_loop(16, 2, 8, 24); 2]).run_profiled(&mut prof);
        let p = prof.take_profile().unwrap();
        assert!(!p.events.is_empty());
        assert_eq!(p.events_dropped, 0);
        for warp in 0..2 {
            let ts: Vec<u64> =
                p.events.iter().filter(|e| e.warp == warp).map(|e| e.ts).collect();
            assert!(!ts.is_empty(), "warp {warp} has no events");
            assert!(ts.windows(2).all(|w| w[0] <= w[1]), "warp {warp} not monotonic");
        }
        assert!(p.events.iter().any(|e| e.name == "mma"));
        assert!(p.events.iter().any(|e| e.name == "sync_warp"));
    }

    #[test]
    #[should_panic(expected = "max_cycles")]
    fn runaway_detection() {
        let d = a100();
        let mut b = ProgramBuilder::new();
        for _ in 0..100 {
            let r = b.init_reg();
            b.mma(8, 24, 2048, r, vec![r]);
        }
        let sim = SmSim::new(&d, vec![b.build()]).with_max_cycles(10);
        sim.run();
    }
}
