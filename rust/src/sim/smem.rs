//! Shared-memory bank model (paper §7).
//!
//! Modern NVIDIA shared memory has 32 banks x 4 bytes (128 B/clk
//! theoretical bandwidth). A warp-wide access is split into transactions:
//! within one transaction each bank can serve one 4-byte word (broadcast
//! if every request to the bank hits the same word). The transaction
//! count is what the simulator charges the LSU with, and each extra
//! transaction costs ~2 cycles of latency (Table 10's 2 cycles/way).
//!
//! Address math lives here — the microbenchmark and GEMM kernel builders
//! generate real byte addresses and this module derives the conflict
//! degree, including the CUTLASS-style permuted (swizzled) layout of
//! Appendix A.2.

use std::collections::HashMap;

pub const BANKS: u32 = 32;
pub const BANK_BYTES: u32 = 4;

/// Transactions needed to serve per-thread word accesses of
/// `access_bytes` (4 for u32, 8 for u64) at the given byte addresses.
///
/// u64 (and wider) accesses are decomposed into 4-byte words first; the
/// fabric then needs `max over banks of distinct words per bank`
/// transactions *per 128-byte wavefront*, and at least
/// `total_bytes / 128` wavefronts.
pub fn ld_shared_transactions(addrs: &[u32], access_bytes: u32) -> u32 {
    assert!(access_bytes % BANK_BYTES == 0, "accesses must be word-multiples");
    let words_per_access = access_bytes / BANK_BYTES;
    // bank -> set of distinct word addresses requested from it
    let mut per_bank: HashMap<u32, Vec<u32>> = HashMap::new();
    for &addr in addrs {
        assert!(addr % access_bytes == 0, "misaligned shared-memory access");
        for w in 0..words_per_access {
            let word_addr = addr / BANK_BYTES + w;
            let bank = word_addr % BANKS;
            let words = per_bank.entry(bank).or_default();
            if !words.contains(&word_addr) {
                words.push(word_addr);
            }
        }
    }
    per_bank.values().map(|w| w.len() as u32).max().unwrap_or(0)
}

/// Transactions for one `ldmatrix.xN` (N = `row_addrs.len() / 8`): each
/// address points at a 16-byte row fragment held by a group of four
/// threads (Fig. 13). A conflict-free `ldmatrix.xN` needs exactly N
/// transactions (N x 128 bytes over a 128 B/clk fabric); layouts that
/// map multiple rows onto the same banks need proportionally more.
pub fn ldmatrix_transactions(row_addrs: &[u32]) -> u32 {
    assert!(
        row_addrs.len() % 8 == 0 && !row_addrs.is_empty(),
        "ldmatrix loads 8 rows per 128-byte fragment"
    );
    // Each 16-byte row covers 4 consecutive banks.
    let mut per_bank: HashMap<u32, Vec<u32>> = HashMap::new();
    for &addr in row_addrs {
        assert!(addr % 16 == 0, "ldmatrix rows must be 16-byte aligned");
        for w in 0..4 {
            let word_addr = addr / BANK_BYTES + w;
            let bank = word_addr % BANKS;
            let words = per_bank.entry(bank).or_default();
            if !words.contains(&word_addr) {
                words.push(word_addr);
            }
        }
    }
    per_bank.values().map(|w| w.len() as u32).max().unwrap_or(0)
}

/// Shared-memory layout transform for a staged tile (Appendix A.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Swizzle {
    /// Naive row-major staging: rows with a stride that aliases banks.
    None,
    /// CUTLASS-style permuted layout: the 16-byte column slot is XORed
    /// with the row index so consecutive rows spread over all banks.
    Permuted,
}

impl Swizzle {
    /// Byte address of the 16-byte chunk `(row, col16)` of a staged tile
    /// whose row stride is `row_bytes`.
    pub fn address(self, row: u32, col16: u32, row_bytes: u32) -> u32 {
        assert!(row_bytes % 16 == 0);
        let chunks_per_row = row_bytes / 16;
        let col = match self {
            Swizzle::None => col16,
            Swizzle::Permuted => (col16 ^ row) % chunks_per_row,
        };
        row * row_bytes + col * 16
    }
}

/// The row addresses one `ldmatrix.x4` issues against a staged tile:
/// 4 fragments x 8 rows starting at `(row0 + 8*f, col16)`.
pub fn ldmatrix_x4_row_addrs(
    swz: Swizzle,
    row0: u32,
    col16: u32,
    row_bytes: u32,
) -> Vec<u32> {
    let mut out = Vec::with_capacity(32);
    for frag in 0..4 {
        for r in 0..8 {
            out.push(swz.address(row0 + frag * 8 + r, col16, row_bytes));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Conflict-free: thread t reads word t.
    #[test]
    fn u32_conflict_free() {
        let addrs: Vec<u32> = (0..32).map(|t| t * 4).collect();
        assert_eq!(ld_shared_transactions(&addrs, 4), 1);
    }

    /// Classic n-way conflict: stride of n words.
    #[test]
    fn u32_strided_conflicts() {
        for ways in [2u32, 4, 8] {
            let addrs: Vec<u32> = (0..32).map(|t| t * 4 * ways).collect();
            assert_eq!(ld_shared_transactions(&addrs, 4), ways, "{ways}-way");
        }
    }

    /// Broadcast: all threads read the same word -> one transaction.
    #[test]
    fn u32_broadcast() {
        let addrs = vec![64u32; 32];
        assert_eq!(ld_shared_transactions(&addrs, 4), 1);
    }

    /// u64 needs at least two transactions (256 B through a 128 B/clk
    /// fabric) even when conflict-free per wavefront.
    #[test]
    fn u64_minimum_two() {
        let addrs: Vec<u32> = (0..32).map(|t| t * 8).collect();
        assert_eq!(ld_shared_transactions(&addrs, 8), 2);
    }

    #[test]
    fn u64_strided_conflicts() {
        // stride 2*8B = 4 words: banks repeat every 8 threads over 2
        // words each -> 4 distinct words on the hottest bank... verify
        // against Table 10's u64 rows (ways == transactions).
        let addrs: Vec<u32> = (0..32).map(|t| t * 16).collect();
        assert_eq!(ld_shared_transactions(&addrs, 8), 4);
    }

    #[test]
    fn ldmatrix_x1_conflict_free() {
        // 8 rows of 16 B packed consecutively: covers all 32 banks once.
        let addrs: Vec<u32> = (0..8).map(|r| r * 16).collect();
        assert_eq!(ldmatrix_transactions(&addrs), 1);
    }

    #[test]
    fn ldmatrix_x4_packed_is_four() {
        let addrs: Vec<u32> = (0..32).map(|r| r * 16).collect();
        assert_eq!(ldmatrix_transactions(&addrs), 4);
    }

    /// Naive row-major staging of a bf16 tile with 32-byte rows: rows 4
    /// apart alias the same banks -> 8 transactions instead of 4
    /// (the Appendix-A.2 baseline).
    #[test]
    fn ldmatrix_x4_naive_layout_conflicts() {
        let addrs = ldmatrix_x4_row_addrs(Swizzle::None, 0, 0, 32);
        assert_eq!(ldmatrix_transactions(&addrs), 8);
    }

    /// The permuted layout restores the conflict-free 4 transactions
    /// when the row holds enough 16-byte chunks to spread across banks.
    #[test]
    fn ldmatrix_x4_permuted_layout_conflict_free() {
        let addrs = ldmatrix_x4_row_addrs(Swizzle::Permuted, 0, 0, 128);
        assert_eq!(ldmatrix_transactions(&addrs), 4);
        // while the naive layout at the same 128-byte row stride still
        // conflicts (all rows hit the same 4 banks):
        let naive = ldmatrix_x4_row_addrs(Swizzle::None, 0, 0, 128);
        assert_eq!(ldmatrix_transactions(&naive), 32);
    }
}
