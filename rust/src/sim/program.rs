//! Warp programs: the unrolled instruction traces tcsim executes.
//!
//! The microbenchmark harness and the Appendix-A GEMM kernels both
//! compile down to this tiny IR. Timing-relevant facts (engine class,
//! ii/latency, transaction counts) are resolved at build time so the
//! simulator core stays a pure scheduler.

/// Virtual per-warp register id.
pub type Reg = u32;

/// One dynamic instruction.
#[derive(Debug, Clone, PartialEq)]
pub struct Instr {
    pub op: Op,
    /// Destination register (written at completion).
    pub dst: Option<Reg>,
    /// Source registers (must be ready at issue).
    pub srcs: Vec<Reg>,
}

/// Operation kinds, pre-resolved against a device.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Tensor-Core (or FPU-fallback) MMA.
    Mma { ii: u32, latency: u32, fmas: u64, fpu: bool },
    /// Shared-memory load (`ldmatrix` / `ld.shared`): `txns` serialized
    /// 128-byte transactions on the warp's LSU.
    SmemLoad { txns: u32, bytes: u64 },
    /// Shared-memory store (same fabric; used by the GEMM staging path).
    SmemStore { txns: u32, bytes: u64 },
    /// Synchronous global-memory load.
    GmemLoad { bytes: u64 },
    /// Ampere asynchronous global->shared copy (no register writeback).
    CpAsync { bytes: u64 },
    /// Close the current cp.async group.
    CpAsyncCommit,
    /// Stall until at most `max_pending` cp.async groups are in flight.
    CpAsyncWait { max_pending: u32 },
    /// `__syncwarp()`: wait for the warp's outstanding MMA results, then
    /// `sync_cost` cycles of issue stall.
    SyncWarp,
    /// CTA-wide barrier (`bar.sync`): all warps arrive, release together.
    BarSync,
    /// Measurement-iteration boundary (`clock64()` read, paper Fig. 4).
    IterMark,
}

impl Op {
    pub fn fmas(&self) -> u64 {
        match self {
            Op::Mma { fmas, .. } => *fmas,
            _ => 0,
        }
    }

    pub fn smem_bytes(&self) -> u64 {
        match self {
            Op::SmemLoad { bytes, .. } | Op::SmemStore { bytes, .. } => *bytes,
            _ => 0,
        }
    }
}

/// The full trace one warp executes.
#[derive(Debug, Clone, Default)]
pub struct WarpProgram {
    pub instrs: Vec<Instr>,
    /// Registers holding a defined value before the first instruction
    /// (kernel arguments / zero-initialized accumulators). Pure
    /// metadata for the static analyzer — the simulator's scoreboard
    /// already treats unwritten registers as ready-at-0, so seeding a
    /// register changes no schedule.
    pub live_in: Vec<Reg>,
}

impl WarpProgram {
    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Steady-state FMAs per iteration: the work between the first and
    /// last IterMark averaged over those iterations, so a staging
    /// prologue (or any work outside the measured window) cannot skew
    /// the per-iteration figure. Falls back to a whole-program average
    /// when there are fewer than two marks.
    pub fn fmas_per_iteration(&self) -> u64 {
        self.per_iteration(|op| op.fmas())
    }

    /// Steady-state shared-memory bytes moved per iteration (same
    /// windowing as [`WarpProgram::fmas_per_iteration`]).
    pub fn smem_bytes_per_iteration(&self) -> u64 {
        self.per_iteration(|op| op.smem_bytes())
    }

    fn per_iteration(&self, work: impl Fn(&Op) -> u64) -> u64 {
        let marks: Vec<usize> = self
            .instrs
            .iter()
            .enumerate()
            .filter(|(_, i)| matches!(i.op, Op::IterMark))
            .map(|(i, _)| i)
            .collect();
        if marks.len() < 2 {
            let total: u64 = self.instrs.iter().map(|i| work(&i.op)).sum();
            return total / marks.len().max(1) as u64;
        }
        let window: u64 = self.instrs[marks[0] + 1..marks[marks.len() - 1]]
            .iter()
            .map(|i| work(&i.op))
            .sum();
        window / (marks.len() - 1) as u64
    }

    pub fn iter_marks(&self) -> usize {
        self.instrs.iter().filter(|i| matches!(i.op, Op::IterMark)).count()
    }
}

/// Convenience builder with automatic register allocation.
#[derive(Debug, Default)]
pub struct ProgramBuilder {
    instrs: Vec<Instr>,
    next_reg: Reg,
    live_in: Vec<Reg>,
}

impl ProgramBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn alloc_reg(&mut self) -> Reg {
        let r = self.next_reg;
        self.next_reg += 1;
        r
    }

    /// Allocate a register that starts *defined* (a kernel argument or a
    /// zero-initialized accumulator). Use this for registers the program
    /// reads before its first write — e.g. the `D_s = A*B + D_s`
    /// accumulator chains — so tclint's def-use rule knows the first
    /// read is legal. Emits no instruction and changes no timing.
    pub fn init_reg(&mut self) -> Reg {
        let r = self.alloc_reg();
        self.live_in.push(r);
        r
    }

    pub fn push(&mut self, op: Op, dst: Option<Reg>, srcs: Vec<Reg>) -> &mut Self {
        self.instrs.push(Instr { op, dst, srcs });
        self
    }

    pub fn mma(&mut self, ii: u32, latency: u32, fmas: u64, dst: Reg, srcs: Vec<Reg>) -> &mut Self {
        self.push(Op::Mma { ii, latency, fmas, fpu: false }, Some(dst), srcs)
    }

    pub fn smem_load(&mut self, txns: u32, bytes: u64, dst: Reg) -> &mut Self {
        self.push(Op::SmemLoad { txns, bytes }, Some(dst), vec![])
    }

    pub fn sync_warp(&mut self) -> &mut Self {
        self.push(Op::SyncWarp, None, vec![])
    }

    pub fn iter_mark(&mut self) -> &mut Self {
        self.push(Op::IterMark, None, vec![])
    }

    pub fn build(self) -> WarpProgram {
        WarpProgram { instrs: self.instrs, live_in: self.live_in }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_allocates_unique_regs() {
        let mut b = ProgramBuilder::new();
        let r0 = b.alloc_reg();
        let r1 = b.alloc_reg();
        assert_ne!(r0, r1);
    }

    #[test]
    fn per_iteration_accounting() {
        let mut b = ProgramBuilder::new();
        let d = b.init_reg();
        for _ in 0..4 {
            b.mma(8, 24, 2048, d, vec![d]);
            b.mma(8, 24, 2048, d, vec![d]);
            b.sync_warp();
            b.iter_mark();
        }
        let p = b.build();
        assert_eq!(p.iter_marks(), 4);
        assert_eq!(p.fmas_per_iteration(), 4096);
        assert_eq!(p.smem_bytes_per_iteration(), 0);
    }

    #[test]
    fn init_reg_seeds_live_in_without_emitting_instructions() {
        let mut b = ProgramBuilder::new();
        let seeded = b.init_reg();
        let plain = b.alloc_reg();
        b.mma(8, 24, 2048, seeded, vec![seeded]);
        let p = b.build();
        assert_eq!(p.live_in, vec![seeded]);
        assert_ne!(seeded, plain);
        assert_eq!(p.instrs.len(), 1, "seeding must not emit instructions");
    }

    #[test]
    fn per_iteration_accounting_ignores_prologue_and_epilogue() {
        // A staging prologue (one extra mma + a smem store before the
        // first mark) and epilogue work must not skew the steady-state
        // per-iteration figures.
        let mut b = ProgramBuilder::new();
        let d = b.init_reg();
        b.mma(8, 24, 999, d, vec![d]);
        b.push(Op::SmemStore { txns: 1, bytes: 777 }, None, vec![d]);
        for _ in 0..4 {
            b.mma(8, 24, 2048, d, vec![d]);
            b.push(Op::SmemLoad { txns: 1, bytes: 128 }, Some(d), vec![d]);
            b.iter_mark();
        }
        b.mma(8, 24, 555, d, vec![d]);
        let p = b.build();
        assert_eq!(p.fmas_per_iteration(), 2048);
        assert_eq!(p.smem_bytes_per_iteration(), 128);
    }

    #[test]
    fn per_iteration_accounting_single_mark_falls_back_to_totals() {
        let mut b = ProgramBuilder::new();
        let d = b.init_reg();
        b.mma(8, 24, 2048, d, vec![d]);
        b.mma(8, 24, 2048, d, vec![d]);
        b.iter_mark();
        let p = b.build();
        assert_eq!(p.fmas_per_iteration(), 4096);
    }
}
