//! Warp programs: the unrolled instruction traces tcsim executes.
//!
//! The microbenchmark harness and the Appendix-A GEMM kernels both
//! compile down to this tiny IR. Timing-relevant facts (engine class,
//! ii/latency, transaction counts) are resolved at build time so the
//! simulator core stays a pure scheduler.

/// Virtual per-warp register id.
pub type Reg = u32;

/// One dynamic instruction.
#[derive(Debug, Clone, PartialEq)]
pub struct Instr {
    pub op: Op,
    /// Destination register (written at completion).
    pub dst: Option<Reg>,
    /// Source registers (must be ready at issue).
    pub srcs: Vec<Reg>,
}

/// Operation kinds, pre-resolved against a device.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Tensor-Core (or FPU-fallback) MMA.
    Mma { ii: u32, latency: u32, fmas: u64, fpu: bool },
    /// Shared-memory load (`ldmatrix` / `ld.shared`): `txns` serialized
    /// 128-byte transactions on the warp's LSU.
    SmemLoad { txns: u32, bytes: u64 },
    /// Shared-memory store (same fabric; used by the GEMM staging path).
    SmemStore { txns: u32, bytes: u64 },
    /// Synchronous global-memory load.
    GmemLoad { bytes: u64 },
    /// Ampere asynchronous global->shared copy (no register writeback).
    CpAsync { bytes: u64 },
    /// Close the current cp.async group.
    CpAsyncCommit,
    /// Stall until at most `max_pending` cp.async groups are in flight.
    CpAsyncWait { max_pending: u32 },
    /// `__syncwarp()`: wait for the warp's outstanding MMA results, then
    /// `sync_cost` cycles of issue stall.
    SyncWarp,
    /// CTA-wide barrier (`bar.sync`): all warps arrive, release together.
    BarSync,
    /// Measurement-iteration boundary (`clock64()` read, paper Fig. 4).
    IterMark,
}

impl Op {
    pub fn fmas(&self) -> u64 {
        match self {
            Op::Mma { fmas, .. } => *fmas,
            _ => 0,
        }
    }

    pub fn smem_bytes(&self) -> u64 {
        match self {
            Op::SmemLoad { bytes, .. } | Op::SmemStore { bytes, .. } => *bytes,
            _ => 0,
        }
    }
}

/// The full trace one warp executes.
#[derive(Debug, Clone, Default)]
pub struct WarpProgram {
    pub instrs: Vec<Instr>,
}

impl WarpProgram {
    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Total FMAs between consecutive IterMarks (assumes a uniform loop
    /// body, which every generated program has).
    pub fn fmas_per_iteration(&self) -> u64 {
        let iters = self.iter_marks().max(1) as u64;
        let total: u64 = self.instrs.iter().map(|i| i.op.fmas()).sum();
        total / iters
    }

    /// Total shared-memory bytes moved between consecutive IterMarks.
    pub fn smem_bytes_per_iteration(&self) -> u64 {
        let iters = self.iter_marks().max(1) as u64;
        let total: u64 = self.instrs.iter().map(|i| i.op.smem_bytes()).sum();
        total / iters
    }

    pub fn iter_marks(&self) -> usize {
        self.instrs.iter().filter(|i| matches!(i.op, Op::IterMark)).count()
    }
}

/// Convenience builder with automatic register allocation.
#[derive(Debug, Default)]
pub struct ProgramBuilder {
    instrs: Vec<Instr>,
    next_reg: Reg,
}

impl ProgramBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn alloc_reg(&mut self) -> Reg {
        let r = self.next_reg;
        self.next_reg += 1;
        r
    }

    pub fn push(&mut self, op: Op, dst: Option<Reg>, srcs: Vec<Reg>) -> &mut Self {
        self.instrs.push(Instr { op, dst, srcs });
        self
    }

    pub fn mma(&mut self, ii: u32, latency: u32, fmas: u64, dst: Reg, srcs: Vec<Reg>) -> &mut Self {
        self.push(Op::Mma { ii, latency, fmas, fpu: false }, Some(dst), srcs)
    }

    pub fn smem_load(&mut self, txns: u32, bytes: u64, dst: Reg) -> &mut Self {
        self.push(Op::SmemLoad { txns, bytes }, Some(dst), vec![])
    }

    pub fn sync_warp(&mut self) -> &mut Self {
        self.push(Op::SyncWarp, None, vec![])
    }

    pub fn iter_mark(&mut self) -> &mut Self {
        self.push(Op::IterMark, None, vec![])
    }

    pub fn build(self) -> WarpProgram {
        WarpProgram { instrs: self.instrs }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_allocates_unique_regs() {
        let mut b = ProgramBuilder::new();
        let r0 = b.alloc_reg();
        let r1 = b.alloc_reg();
        assert_ne!(r0, r1);
    }

    #[test]
    fn per_iteration_accounting() {
        let mut b = ProgramBuilder::new();
        for _ in 0..4 {
            let d = b.alloc_reg();
            b.mma(8, 24, 2048, d, vec![d]);
            b.mma(8, 24, 2048, d, vec![d]);
            b.sync_warp();
            b.iter_mark();
        }
        let p = b.build();
        assert_eq!(p.iter_marks(), 4);
        assert_eq!(p.fmas_per_iteration(), 4096);
        assert_eq!(p.smem_bytes_per_iteration(), 0);
    }
}
