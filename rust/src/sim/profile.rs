//! Stall attribution for the tcsim cycle loop.
//!
//! The paper's method is *dissection* — explaining where Tensor-Core
//! cycles go — so the simulator must be able to say not just *how many*
//! cycles a kernel took but *why*: every warp-cycle is accounted to
//! exactly one category, and the categories sum to `warps × cycles`.
//!
//! The cost contract is graded by [`Profiler`] variant:
//!
//! * [`Profiler::Null`] — zero cost. Every profiling call is a no-op on
//!   an empty enum arm; the cycle loop takes the exact same schedule as
//!   before the profiler existed, so all pinned bit-identical timing
//!   results are untouched.
//! * [`Profiler::Counting`] — seven `u64` counters bumped per
//!   time-advance. The timing schedule is still bit-identical (the
//!   profiler only observes the stall causes [`SmSim::issue_block`]
//!   already computes); only wall-clock overhead is added. This is the
//!   variant the cell cache stores.
//! * [`Profiler::Tracing`] — Counting plus one [`TraceEvent`] per
//!   issued instruction (capped at [`MAX_TRACE_EVENTS`]), enough to
//!   render a per-warp issue timeline as Chrome trace-event JSON
//!   ([`crate::report`]'s trace exporter). Never cached.
//!
//! [`SmSim::issue_block`]: super::SmSim

/// Why a warp could not (or did not need to) issue on a cycle. One
/// category per warp per simulated cycle; `Issued` is the productive
/// category.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stall {
    /// The warp issued an instruction this cycle.
    Issued,
    /// A source register's scoreboard entry (or an outstanding `mma`
    /// before `__syncwarp`) was not ready.
    ScoreboardDep,
    /// The Tensor-Core token bucket (per-warp dispatch or sub-core
    /// engine) had insufficient credit.
    TokenBucket,
    /// `cp.async.wait_group` waiting for commit groups to land.
    CpAsyncWait,
    /// The LSU pending-load cap (shared-memory / global-load pressure).
    SmemConflict,
    /// The warp was ready but lost the sub-core issue slot (or sits in
    /// the 1-cycle issue recovery / barrier-release window).
    IssueSlot,
    /// The warp had retired its program.
    Done,
}

/// A refusal from `issue_block`: the earliest cycle at which the warp
/// could possibly issue, and the pipeline cause of the wait.
#[derive(Debug, Clone, Copy)]
pub struct Blocked {
    pub release: u64,
    pub stall: Stall,
}

impl Blocked {
    pub fn new(release: u64, stall: Stall) -> Blocked {
        Blocked { release, stall }
    }
}

/// Stable JSON/report names of the seven categories, in the canonical
/// order used everywhere a breakdown is rendered.
pub const STALL_CATEGORIES: [&str; 7] = [
    "issued",
    "scoreboard_dep",
    "token_bucket",
    "cp_async_wait",
    "smem_conflict",
    "issue_slot",
    "done",
];

/// Most trace events kept per run; later issues only bump
/// `events_dropped` so a runaway program cannot exhaust memory.
pub const MAX_TRACE_EVENTS: usize = 1 << 20;

/// One issued instruction on the per-warp timeline (Tracing only).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    pub warp: usize,
    /// Static op name (`"mma"`, `"ldmatrix"`, …).
    pub name: &'static str,
    /// Issue cycle.
    pub ts: u64,
    /// Modeled occupancy in cycles (a rendering hint, not a timing
    /// claim — the simulator's latencies live in the scoreboard).
    pub dur: u64,
}

/// Cycle accounting for one simulation run (or, after [`merge`], the
/// sum over several). Invariant: the seven category counters sum to
/// [`warp_cycles`] — every warp-cycle lands in exactly one bucket.
///
/// [`merge`]: SimProfile::merge
/// [`warp_cycles`]: SimProfile::warp_cycles
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SimProfile {
    pub issued: u64,
    pub scoreboard_dep: u64,
    pub token_bucket: u64,
    pub cp_async_wait: u64,
    pub smem_conflict: u64,
    pub issue_slot: u64,
    pub done: u64,
    /// Simulation runs folded into this profile (1 until merged).
    pub runs: u64,
    /// Warps of the (last folded) run.
    pub warps: u64,
    /// Simulated cycles of this run; summed across merges.
    pub cycles: u64,
    /// Σ `warps × cycles` over the folded runs — the accounting total.
    pub warp_cycles: u64,
    /// Per-warp issue timeline (Tracing only; empty when Counting).
    pub events: Vec<TraceEvent>,
    /// Events beyond [`MAX_TRACE_EVENTS`] that were not recorded.
    pub events_dropped: u64,
}

impl SimProfile {
    /// Attribute `delta` cycles to every warp's current cause.
    pub fn account(&mut self, causes: &[Stall], delta: u64) {
        for cause in causes {
            *self.bucket_mut(*cause) += delta;
        }
        self.cycles += delta;
        self.warp_cycles += delta * causes.len() as u64;
    }

    fn bucket_mut(&mut self, stall: Stall) -> &mut u64 {
        match stall {
            Stall::Issued => &mut self.issued,
            Stall::ScoreboardDep => &mut self.scoreboard_dep,
            Stall::TokenBucket => &mut self.token_bucket,
            Stall::CpAsyncWait => &mut self.cp_async_wait,
            Stall::SmemConflict => &mut self.smem_conflict,
            Stall::IssueSlot => &mut self.issue_slot,
            Stall::Done => &mut self.done,
        }
    }

    /// `(name, count)` per category, in [`STALL_CATEGORIES`] order.
    pub fn categories(&self) -> [(&'static str, u64); 7] {
        [
            ("issued", self.issued),
            ("scoreboard_dep", self.scoreboard_dep),
            ("token_bucket", self.token_bucket),
            ("cp_async_wait", self.cp_async_wait),
            ("smem_conflict", self.smem_conflict),
            ("issue_slot", self.issue_slot),
            ("done", self.done),
        ]
    }

    /// Sum of the seven category counters. Equals [`warp_cycles`] by
    /// construction.
    ///
    /// [`warp_cycles`]: SimProfile::warp_cycles
    pub fn total(&self) -> u64 {
        self.categories().iter().map(|(_, n)| n).sum()
    }

    /// `(name, fraction)` per category; fractions sum to 1 (all zeros
    /// for an empty profile).
    pub fn fractions(&self) -> [(&'static str, f64); 7] {
        let total = self.total();
        self.categories().map(|(name, n)| {
            (name, if total == 0 { 0.0 } else { n as f64 / total as f64 })
        })
    }

    /// Fold another run's accounting into this one (sweep aggregation).
    /// Trace events are appended up to [`MAX_TRACE_EVENTS`].
    pub fn merge(&mut self, other: &SimProfile) {
        self.issued += other.issued;
        self.scoreboard_dep += other.scoreboard_dep;
        self.token_bucket += other.token_bucket;
        self.cp_async_wait += other.cp_async_wait;
        self.smem_conflict += other.smem_conflict;
        self.issue_slot += other.issue_slot;
        self.done += other.done;
        self.runs += other.runs;
        self.warps = other.warps;
        self.cycles += other.cycles;
        self.warp_cycles += other.warp_cycles;
        let room = MAX_TRACE_EVENTS.saturating_sub(self.events.len());
        let take = other.events.len().min(room);
        self.events.extend_from_slice(&other.events[..take]);
        self.events_dropped += other.events_dropped + (other.events.len() - take) as u64;
    }
}

/// What to collect for a run. The plumbing-level twin of [`Profiler`]:
/// callers pick a mode, the measurement layer builds one profiler per
/// simulation from it.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum ProfileMode {
    #[default]
    Off,
    Counting,
    Tracing,
}

impl ProfileMode {
    pub fn is_off(self) -> bool {
        self == ProfileMode::Off
    }

    /// A fresh profiler of this mode.
    pub fn profiler(self) -> Profiler {
        match self {
            ProfileMode::Off => Profiler::Null,
            ProfileMode::Counting => Profiler::Counting(SimProfile::default()),
            ProfileMode::Tracing => Profiler::Tracing(SimProfile::default()),
        }
    }
}

/// The profiling hook handed to `SmSim::run_profiled`. `Null` keeps
/// every hook a no-op (zero cost, bit-identical schedule); the other
/// variants accumulate into their [`SimProfile`].
#[derive(Debug, Default)]
pub enum Profiler {
    #[default]
    Null,
    Counting(SimProfile),
    Tracing(SimProfile),
}

impl Profiler {
    pub fn counting() -> Profiler {
        ProfileMode::Counting.profiler()
    }

    pub fn tracing() -> Profiler {
        ProfileMode::Tracing.profiler()
    }

    /// Whether the cycle loop needs to track per-warp stall causes at
    /// all (false ⇒ the loop allocates nothing).
    pub fn is_on(&self) -> bool {
        !matches!(self, Profiler::Null)
    }

    pub fn is_tracing(&self) -> bool {
        matches!(self, Profiler::Tracing(_))
    }

    /// Called once before the cycle loop with the warp count.
    pub fn begin(&mut self, warps: u64) {
        if let Some(p) = self.profile_mut() {
            p.runs = 1;
            p.warps = warps;
        }
    }

    /// Attribute `delta` cycles to every warp's current stall cause.
    pub fn account(&mut self, causes: &[Stall], delta: u64) {
        if let Some(p) = self.profile_mut() {
            p.account(causes, delta);
        }
    }

    /// Record one issued instruction on the timeline (Tracing only).
    pub fn record_issue(&mut self, warp: usize, name: &'static str, ts: u64, dur: u64) {
        if let Profiler::Tracing(p) = self {
            if p.events.len() < MAX_TRACE_EVENTS {
                p.events.push(TraceEvent { warp, name, ts, dur });
            } else {
                p.events_dropped += 1;
            }
        }
    }

    fn profile_mut(&mut self) -> Option<&mut SimProfile> {
        match self {
            Profiler::Null => None,
            Profiler::Counting(p) | Profiler::Tracing(p) => Some(p),
        }
    }

    /// Consume the accumulated profile, resetting this profiler to
    /// `Null`. Returns `None` for `Null` (profiling was off).
    pub fn take_profile(&mut self) -> Option<SimProfile> {
        match std::mem::take(self) {
            Profiler::Null => None,
            Profiler::Counting(p) | Profiler::Tracing(p) => Some(p),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_preserves_the_warp_cycle_invariant() {
        let mut prof = Profiler::counting();
        prof.begin(3);
        let causes = [Stall::Issued, Stall::ScoreboardDep, Stall::Done];
        prof.account(&causes, 1);
        prof.account(&causes, 4);
        let p = prof.take_profile().unwrap();
        assert_eq!(p.total(), p.warp_cycles);
        assert_eq!(p.warp_cycles, 3 * 5);
        assert_eq!(p.cycles, 5);
        assert_eq!(p.warps, 3);
        assert_eq!((p.issued, p.scoreboard_dep, p.done), (5, 5, 5));
        let fr = p.fractions();
        let sum: f64 = fr.iter().map(|(_, f)| f).sum();
        assert!((sum - 1.0).abs() < 1e-12, "{sum}");
    }

    #[test]
    fn null_profiler_is_inert() {
        let mut prof = Profiler::Null;
        prof.begin(8);
        prof.account(&[Stall::Issued], 10);
        prof.record_issue(0, "mma", 0, 4);
        assert!(!prof.is_on());
        assert!(prof.take_profile().is_none());
    }

    #[test]
    fn merge_sums_runs_and_keeps_the_invariant() {
        let mut a = Profiler::counting();
        a.begin(2);
        a.account(&[Stall::Issued, Stall::IssueSlot], 3);
        let mut b = Profiler::counting();
        b.begin(4);
        b.account(&[Stall::Issued; 4], 2);
        let (a, b) = (a.take_profile().unwrap(), b.take_profile().unwrap());
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged.runs, 2);
        assert_eq!(merged.total(), a.total() + b.total());
        assert_eq!(merged.warp_cycles, a.warp_cycles + b.warp_cycles);
        assert_eq!(merged.total(), merged.warp_cycles);
    }

    #[test]
    fn tracing_caps_events() {
        let mut prof = Profiler::tracing();
        prof.begin(1);
        for i in 0..8 {
            prof.record_issue(0, "mma", i, 4);
        }
        let p = prof.take_profile().unwrap();
        assert_eq!(p.events.len(), 8);
        assert_eq!(p.events_dropped, 0);
        assert_eq!(p.events[3].ts, 3);
    }

    #[test]
    fn profile_mode_builds_matching_profilers() {
        assert!(!ProfileMode::Off.profiler().is_on());
        assert!(ProfileMode::Counting.profiler().is_on());
        assert!(!ProfileMode::Counting.profiler().is_tracing());
        assert!(ProfileMode::Tracing.profiler().is_tracing());
        assert!(ProfileMode::Off.is_off());
    }
}
