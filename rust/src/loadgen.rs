//! repro loadgen — a self-contained load harness for tcserved fleets.
//!
//! Replays a deterministic mixed workload (`--mix plan:sweep:numeric:tune`)
//! against a running server over plain `TcpStream` HTTP/1.1 (no client
//! crates, mirroring `server::http`), then reports client-side latency
//! percentiles next to the server's own `/v1/metrics` counters — so one
//! run answers both "how fast" and "how warm": p50/p99 per the client
//! clock, result-cache hit rate and the combined cell-cache +
//! cell-store rate per the server.
//!
//! ```text
//! repro loadgen --addr 127.0.0.1:8321 --mix plan:sweep:numeric:tune \
//!               --concurrency 8 --duration 10 [--seed S] [--out f.json]
//! ```
//!
//! Traffic is drawn per worker from a seeded [`Prng`], so two runs with
//! the same seed, mix and concurrency issue the same request multiset —
//! comparable across replicas and across CI runs. Requests use the
//! canonical POST forms of the v1 API; `503` (`overloaded`) responses
//! are counted as shed load, not errors, because backpressure is the
//! server behaving as configured.
//!
//! Backpressure is also *acted on*: a `503` is retried up to
//! `--retries` times (default 2), honoring the server's `Retry-After`
//! hint with capped exponential backoff and seeded jitter. A logical
//! request that succeeds on a retry counts as `retried_ok`; one that
//! exhausts its retry budget counts as `gave_up`; with `--retries 0`
//! sheds stay `rejected`. `--deadline-ms` stamps every request with an
//! `X-Deadline-Ms` header so the server's graceful-degradation path can
//! be driven from the client side.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::util::{Json, Prng};

/// One traffic class of the mix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MixKind {
    /// `POST /v1/plan` point plans (`ld.shared` exec-point grid).
    Plan,
    /// `POST /v1/sweep` full (ILP, warps) grids.
    Sweep,
    /// §8 numeric probes through both routes.
    Numeric,
    /// `POST /v1/tune` analytic-first autotuner runs.
    Tune,
}

impl MixKind {
    pub fn name(&self) -> &'static str {
        match self {
            MixKind::Plan => "plan",
            MixKind::Sweep => "sweep",
            MixKind::Numeric => "numeric",
            MixKind::Tune => "tune",
        }
    }
}

/// Parse a `:`-separated mix spec. Repeating a class weights it
/// (`plan:plan:sweep` is 2/3 plans).
pub fn parse_mix(spec: &str) -> Result<Vec<MixKind>> {
    let mut mix = Vec::new();
    for token in spec.split(':').filter(|t| !t.is_empty()) {
        mix.push(match token {
            "plan" => MixKind::Plan,
            "sweep" => MixKind::Sweep,
            "numeric" => MixKind::Numeric,
            "tune" => MixKind::Tune,
            other => bail!("unknown mix class {other:?} (plan|sweep|numeric|tune)"),
        });
    }
    if mix.is_empty() {
        bail!("empty mix; give at least one of plan|sweep|numeric|tune");
    }
    Ok(mix)
}

/// Load-harness configuration (CLI flags map onto this 1:1).
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Target server, `host:port`.
    pub addr: String,
    /// Traffic classes, sampled uniformly per request.
    pub mix: Vec<MixKind>,
    /// Concurrent client workers.
    pub concurrency: usize,
    /// Wall-clock run length in seconds.
    pub duration_secs: f64,
    /// PRNG seed: same seed + mix + concurrency = same request multiset.
    pub seed: u64,
    /// Retry budget per logical request for `503` sheds (0 = never
    /// retry, count sheds as `rejected` like older harness versions).
    pub retries: u32,
    /// When set, every request carries `X-Deadline-Ms` with this value.
    pub deadline_ms: Option<u64>,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:8321".to_string(),
            mix: vec![MixKind::Plan, MixKind::Sweep, MixKind::Numeric],
            concurrency: 4,
            duration_secs: 5.0,
            seed: 0x1cbe_11c5,
            retries: 2,
            deadline_ms: None,
        }
    }
}

/// Milliseconds to wait before retry number `attempt` (0-based) of a
/// shed request: the server's `Retry-After` hint (seconds) — or a
/// 100 ms default — doubled per attempt, capped at 5 s, then jittered
/// into `[backoff/2, backoff]` with the caller's seeded [`Prng`] so
/// synchronized clients don't re-converge on the server in lockstep.
pub fn backoff_ms(retry_after_secs: Option<u64>, attempt: u32, prng: &mut Prng) -> u64 {
    // clamp before shifting so no Retry-After value can overflow bits
    let base = retry_after_secs.map_or(100, |s| s.saturating_mul(1000).max(1)).min(5_000);
    let backoff = base.checked_shl(attempt.min(16)).unwrap_or(u64::MAX).min(5_000);
    backoff / 2 + prng.below(backoff / 2 + 1)
}

/// One sampled request: method is always POST (the canonical v1 form).
fn template(kind: MixKind, prng: &mut Prng) -> (&'static str, String) {
    match kind {
        MixKind::Plan => {
            let warps = [1u64, 2, 4, 8][prng.below(4) as usize];
            let ilp = 1 + prng.below(2);
            (
                "/v1/plan",
                format!(
                    "{{\"workload\":\"ld.shared u32 4\",\"device\":\"a100\",\
                     \"points\":[[{warps},{ilp}]],\"backend\":\"native\"}}"
                ),
            )
        }
        MixKind::Sweep => {
            let instr = ["ldmatrix x1", "ldmatrix x2", "ldmatrix x4", "bf16,f32,m16n8k16"]
                [prng.below(4) as usize];
            (
                "/v1/sweep",
                format!("{{\"instr\":\"{instr}\",\"device\":\"a100\",\"backend\":\"native\"}}"),
            )
        }
        MixKind::Numeric => {
            if prng.below(2) == 0 {
                let probe = ["numeric profile fp16 f32 mul low", "numeric profile bf16 f32 acc"]
                    [prng.below(2) as usize];
                (
                    "/v1/plan",
                    format!(
                        "{{\"workload\":\"{probe}\",\"points\":[[1,1]],\"backend\":\"native\"}}"
                    ),
                )
            } else {
                (
                    "/v1/sweep",
                    "{\"instr\":\"numeric,chain,tf32,f32,5\",\"backend\":\"native\"}".to_string(),
                )
            }
        }
        MixKind::Tune => {
            // small frontiers over cheap families: the analytic scorer
            // does the heavy pruning, the confirmed cells ride the cell
            // cache, so repeated tune traffic is cache-warm
            let workload = ["ldmatrix x4", "ld.shared u32 4", "mma fp16 f32 m16n8k16"]
                [prng.below(3) as usize];
            let objective = ["max-throughput", "min-latency"][prng.below(2) as usize];
            (
                "/v1/tune",
                format!(
                    "{{\"workload\":\"{workload}\",\"device\":\"a100\",\
                     \"objective\":\"{objective}\",\"top\":2,\"backend\":\"native\"}}"
                ),
            )
        }
    }
}

/// One blocking HTTP/1.1 exchange (`Connection: close`, like the server
/// answers anyway). Returns `(status, body)`.
pub fn http_request(addr: &str, method: &str, path: &str, body: &str) -> Result<(u16, String)> {
    let (status, _, body) = http_exchange(addr, method, path, body, None)?;
    Ok((status, body))
}

/// [`http_request`] plus the pieces the retry loop needs: an optional
/// `X-Deadline-Ms` request header, and the response's `Retry-After`
/// seconds (when present and numeric) next to status and body.
pub fn http_exchange(
    addr: &str,
    method: &str,
    path: &str,
    body: &str,
    deadline_ms: Option<u64>,
) -> Result<(u16, Option<u64>, String)> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(120)))?;
    stream.set_write_timeout(Some(Duration::from_secs(30)))?;
    stream.set_nodelay(true)?;
    let deadline_header =
        deadline_ms.map_or(String::new(), |ms| format!("X-Deadline-Ms: {ms}\r\n"));
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\n{deadline_header}Connection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    let mut raw = String::new();
    stream.read_to_string(&mut raw).context("reading response")?;
    parse_response_full(&raw)
}

fn parse_response(raw: &str) -> Result<(u16, String)> {
    let (status, _, body) = parse_response_full(raw)?;
    Ok((status, body))
}

fn parse_response_full(raw: &str) -> Result<(u16, Option<u64>, String)> {
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("bad status line in {:?}", raw.lines().next().unwrap_or("")))?;
    let (head, body) = match raw.split_once("\r\n\r\n") {
        Some((h, b)) => (h, b.to_string()),
        None => (raw, String::new()),
    };
    let retry_after = head.lines().skip(1).find_map(|line| {
        let (name, value) = line.split_once(':')?;
        if name.trim().eq_ignore_ascii_case("retry-after") {
            value.trim().parse::<u64>().ok()
        } else {
            None
        }
    });
    Ok((status, retry_after, body))
}

/// `sorted` must be ascending; `q` in [0, 100].
pub fn percentile_us(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = (q / 100.0 * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Counter totals plus client-side latency of one load run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Logical requests issued (each may take several attempts).
    pub requests: u64,
    /// HTTP attempts on the wire, retries included.
    pub attempts: u64,
    /// Logical requests that answered `200` on the first attempt.
    pub ok: u64,
    /// Logical requests that answered `200` after at least one retry.
    pub retried_ok: u64,
    /// `503 overloaded` sheds taken as final because the retry budget
    /// is zero: shed load, not failures.
    pub rejected: u64,
    /// Logical requests still `503` after exhausting a non-zero retry
    /// budget.
    pub gave_up: u64,
    /// Non-503 error statuses (4xx/5xx).
    pub http_errors: u64,
    /// Connect/read failures (server down, timeout).
    pub transport_errors: u64,
    pub elapsed_secs: f64,
    /// Ascending client-observed latencies, microseconds.
    pub latencies_us: Vec<u64>,
    pub per_mix: Vec<(&'static str, u64)>,
    /// The server's post-run `/v1/metrics` data document, when the
    /// scrape succeeded.
    pub server_metrics: Option<Json>,
}

impl LoadReport {
    pub fn p50_us(&self) -> u64 {
        percentile_us(&self.latencies_us, 50.0)
    }

    pub fn p99_us(&self) -> u64 {
        percentile_us(&self.latencies_us, 99.0)
    }

    /// The per-unit result cache's hit rate as the server reports it.
    pub fn result_cache_hit_rate(&self) -> Option<f64> {
        self.server_metrics.as_ref()?.get("cache")?.get_f64("hit_rate")
    }

    /// Fraction of cell lookups served without simulation: memory
    /// cell-cache hits plus shared cell-store disk hits, over all
    /// lookups. The acceptance bar for a warmed replica is ≥ 0.9.
    pub fn combined_cell_hit_rate(&self) -> Option<f64> {
        let m = self.server_metrics.as_ref()?;
        let cells = m.get("cell_cache")?;
        let hits = cells.get_u64("hits")?;
        let misses = cells.get_u64("misses")?;
        let store_hits =
            m.get("cell_store").and_then(|s| s.get_u64("hits")).unwrap_or(0);
        if hits + misses == 0 {
            return None;
        }
        Some((hits + store_hits) as f64 / (hits + misses) as f64)
    }

    /// Machine-readable form (`--out`), schema `tcbench/loadgen/v1`.
    pub fn to_json(&self) -> Json {
        let lat = |q: f64| Json::num(percentile_us(&self.latencies_us, q) as f64);
        let mean = if self.latencies_us.is_empty() {
            0.0
        } else {
            self.latencies_us.iter().sum::<u64>() as f64 / self.latencies_us.len() as f64
        };
        Json::obj(vec![
            ("schema", Json::str("tcbench/loadgen/v1")),
            ("requests", Json::num(self.requests as f64)),
            ("attempts", Json::num(self.attempts as f64)),
            ("ok", Json::num(self.ok as f64)),
            ("retried_ok", Json::num(self.retried_ok as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("gave_up", Json::num(self.gave_up as f64)),
            ("http_errors", Json::num(self.http_errors as f64)),
            ("transport_errors", Json::num(self.transport_errors as f64)),
            ("elapsed_secs", Json::num(self.elapsed_secs)),
            (
                "throughput_rps",
                Json::num(if self.elapsed_secs > 0.0 {
                    self.requests as f64 / self.elapsed_secs
                } else {
                    0.0
                }),
            ),
            (
                "latency_us",
                Json::obj(vec![
                    ("p50", lat(50.0)),
                    ("p90", lat(90.0)),
                    ("p99", lat(99.0)),
                    ("max", lat(100.0)),
                    ("mean", Json::num(mean)),
                ]),
            ),
            (
                "per_mix",
                Json::Obj(
                    self.per_mix
                        .iter()
                        .map(|(k, v)| (k.to_string(), Json::num(*v as f64)))
                        .collect(),
                ),
            ),
            (
                "result_cache_hit_rate",
                self.result_cache_hit_rate().map_or(Json::Null, Json::num),
            ),
            (
                "combined_cell_hit_rate",
                self.combined_cell_hit_rate().map_or(Json::Null, Json::num),
            ),
            (
                "server_metrics",
                self.server_metrics.clone().unwrap_or(Json::Null),
            ),
        ])
    }

    /// Human-readable summary for the terminal.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("loadgen report\n");
        out.push_str(&format!(
            "  requests          {} ({} ok, {} retried ok, {} rejected, {} gave up, \
             {} http errors, {} transport errors; {} attempts)\n",
            self.requests,
            self.ok,
            self.retried_ok,
            self.rejected,
            self.gave_up,
            self.http_errors,
            self.transport_errors,
            self.attempts,
        ));
        out.push_str(&format!(
            "  duration          {:.2} s  ({:.1} req/s)\n",
            self.elapsed_secs,
            if self.elapsed_secs > 0.0 { self.requests as f64 / self.elapsed_secs } else { 0.0 }
        ));
        out.push_str(&format!(
            "  latency           p50 {} us   p90 {} us   p99 {} us   max {} us\n",
            percentile_us(&self.latencies_us, 50.0),
            percentile_us(&self.latencies_us, 90.0),
            percentile_us(&self.latencies_us, 99.0),
            percentile_us(&self.latencies_us, 100.0),
        ));
        for (name, n) in &self.per_mix {
            out.push_str(&format!("  mix {name:<13} {n}\n"));
        }
        match self.result_cache_hit_rate() {
            Some(rate) => {
                out.push_str(&format!("  result cache      {:.1}% hit rate\n", rate * 100.0))
            }
            None => out.push_str("  result cache      (metrics scrape failed)\n"),
        }
        if let Some(rate) = self.combined_cell_hit_rate() {
            out.push_str(&format!(
                "  cell cache+store  {:.1}% served without simulation\n",
                rate * 100.0
            ));
        }
        if let Some(m) = &self.server_metrics {
            if let Some(store) = m.get("cell_store") {
                out.push_str(&format!(
                    "  cell store        enabled={} hits={} misses={} writes={}\n",
                    store.get("enabled").and_then(Json::as_bool).unwrap_or(false),
                    store.get_u64("hits").unwrap_or(0),
                    store.get_u64("misses").unwrap_or(0),
                    store.get_u64("writes").unwrap_or(0),
                ));
            }
        }
        out
    }
}

/// Scrape the server's `/v1/metrics` and unwrap the v1 envelope.
pub fn scrape_metrics(addr: &str) -> Result<Json> {
    let (status, body) = http_request(addr, "GET", "/v1/metrics", "")?;
    if status != 200 {
        bail!("GET /v1/metrics answered {status}");
    }
    let envelope = Json::parse(&body).map_err(|e| anyhow::anyhow!("bad metrics JSON: {e}"))?;
    envelope
        .get("data")
        .cloned()
        .context("metrics response has no data field (not a tcserved/v1 envelope?)")
}

/// Run the harness: `concurrency` workers replaying the mix until the
/// deadline, then one `/v1/metrics` scrape.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadReport> {
    if cfg.mix.is_empty() {
        bail!("empty mix");
    }
    // fail fast (and outside the worker threads) if the target is down
    let (status, _) = http_request(&cfg.addr, "GET", "/healthz", "")
        .with_context(|| format!("tcserved not reachable at {}", cfg.addr))?;
    if status != 200 {
        bail!("healthz answered {status}; refusing to run load");
    }

    let latencies: Mutex<Vec<u64>> = Mutex::new(Vec::new());
    let attempts = AtomicU64::new(0);
    let ok = AtomicU64::new(0);
    let retried_ok = AtomicU64::new(0);
    let rejected = AtomicU64::new(0);
    let gave_up = AtomicU64::new(0);
    let http_errors = AtomicU64::new(0);
    let transport_errors = AtomicU64::new(0);
    let per_mix: Vec<AtomicU64> = cfg.mix.iter().map(|_| AtomicU64::new(0)).collect();

    let t0 = Instant::now();
    let deadline = t0 + Duration::from_secs_f64(cfg.duration_secs.max(0.0));
    std::thread::scope(|scope| {
        for worker in 0..cfg.concurrency.max(1) {
            let latencies = &latencies;
            let (attempts, ok, retried_ok) = (&attempts, &ok, &retried_ok);
            let (rejected, gave_up) = (&rejected, &gave_up);
            let (http_errors, transport_errors) = (&http_errors, &transport_errors);
            let per_mix = &per_mix;
            scope.spawn(move || {
                // distinct deterministic stream per worker
                let stream = 0x9e37_79b9_7f4a_7c15u64.wrapping_mul(worker as u64 + 1);
                let mut prng = Prng::new(cfg.seed ^ stream);
                while Instant::now() < deadline {
                    let pick = prng.below(cfg.mix.len() as u64) as usize;
                    let (path, body) = template(cfg.mix[pick], &mut prng);
                    per_mix[pick].fetch_add(1, Ordering::Relaxed);
                    let t = Instant::now();
                    // one logical request: retry 503 sheds with
                    // Retry-After-guided backoff, everything else final
                    let mut attempt: u32 = 0;
                    loop {
                        attempts.fetch_add(1, Ordering::Relaxed);
                        match http_exchange(&cfg.addr, "POST", path, &body, cfg.deadline_ms) {
                            Ok((503, retry_after, _)) if attempt < cfg.retries => {
                                let wait = backoff_ms(retry_after, attempt, &mut prng);
                                std::thread::sleep(Duration::from_millis(wait));
                                attempt += 1;
                            }
                            Ok((status, _, _)) => {
                                latencies.lock().unwrap().push(t.elapsed().as_micros() as u64);
                                match (status, attempt) {
                                    (200, 0) => ok.fetch_add(1, Ordering::Relaxed),
                                    (200, _) => retried_ok.fetch_add(1, Ordering::Relaxed),
                                    (503, _) if cfg.retries == 0 => {
                                        rejected.fetch_add(1, Ordering::Relaxed)
                                    }
                                    (503, _) => gave_up.fetch_add(1, Ordering::Relaxed),
                                    _ => http_errors.fetch_add(1, Ordering::Relaxed),
                                };
                                break;
                            }
                            Err(_) => {
                                transport_errors.fetch_add(1, Ordering::Relaxed);
                                break;
                            }
                        }
                    }
                }
            });
        }
    });
    let elapsed_secs = t0.elapsed().as_secs_f64();

    let mut latencies = latencies.into_inner().unwrap();
    latencies.sort_unstable();
    let counts: Vec<u64> = per_mix.iter().map(|c| c.load(Ordering::Relaxed)).collect();
    // aggregate by class name: a weighted mix ("plan:plan:sweep") has
    // repeated entries that must not become duplicate report keys
    let mut mix_totals: Vec<(&'static str, u64)> = Vec::new();
    for (name, n) in cfg.mix.iter().map(|k| k.name()).zip(&counts) {
        match mix_totals.iter_mut().find(|(k, _)| *k == name) {
            Some((_, total)) => *total += n,
            None => mix_totals.push((name, *n)),
        }
    }
    Ok(LoadReport {
        requests: counts.iter().sum(),
        attempts: attempts.into_inner(),
        ok: ok.into_inner(),
        retried_ok: retried_ok.into_inner(),
        rejected: rejected.into_inner(),
        gave_up: gave_up.into_inner(),
        http_errors: http_errors.into_inner(),
        transport_errors: transport_errors.into_inner(),
        elapsed_secs,
        latencies_us: latencies,
        per_mix: mix_totals,
        server_metrics: scrape_metrics(&cfg.addr).ok(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_specs_parse_with_weights() {
        assert_eq!(
            parse_mix("plan:sweep:numeric:tune").unwrap(),
            vec![MixKind::Plan, MixKind::Sweep, MixKind::Numeric, MixKind::Tune]
        );
        assert_eq!(parse_mix("sweep").unwrap(), vec![MixKind::Sweep]);
        // repetition weights a class; empty segments are tolerated
        assert_eq!(
            parse_mix("plan:plan::sweep").unwrap(),
            vec![MixKind::Plan, MixKind::Plan, MixKind::Sweep]
        );
        assert!(parse_mix("").is_err());
        assert!(parse_mix("plan:gemm").is_err());
    }

    #[test]
    fn percentiles_on_sorted_latencies() {
        let lat: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile_us(&lat, 50.0), 51); // nearest-rank on 0..=99
        assert_eq!(percentile_us(&lat, 99.0), 99);
        assert_eq!(percentile_us(&lat, 100.0), 100);
        assert_eq!(percentile_us(&lat, 0.0), 1);
        assert_eq!(percentile_us(&[], 50.0), 0);
        assert_eq!(percentile_us(&[7], 99.0), 7);
    }

    #[test]
    fn templates_are_deterministic_valid_json_posts() {
        for kind in [MixKind::Plan, MixKind::Sweep, MixKind::Numeric, MixKind::Tune] {
            let mut a = Prng::new(42);
            let mut b = Prng::new(42);
            for _ in 0..16 {
                let (path, body) = template(kind, &mut a);
                assert_eq!((path, body.clone()), template(kind, &mut b), "{kind:?}");
                assert!(path.starts_with("/v1/"), "{path}");
                let parsed = Json::parse(&body).expect("template body is valid JSON");
                // every template pins the backend so loadgen traffic is
                // cacheable under one resolved key
                assert_eq!(parsed.get_str("backend"), Some("native"), "{body}");
            }
        }
    }

    #[test]
    fn responses_parse_and_hit_rates_extract() {
        let (status, body) =
            parse_response("HTTP/1.1 503 Service Unavailable\r\nRetry-After: 1\r\n\r\n{}").unwrap();
        assert_eq!((status, body.as_str()), (503, "{}"));
        assert!(parse_response("garbage").is_err());

        let metrics = Json::parse(
            r#"{"cache":{"hit_rate":0.8},
                "cell_cache":{"hits":90,"misses":10},
                "cell_store":{"enabled":true,"hits":8,"misses":2,"writes":2,"corrupt":0}}"#,
        )
        .unwrap();
        let report = LoadReport {
            requests: 6,
            attempts: 9,
            ok: 3,
            retried_ok: 1,
            rejected: 1,
            gave_up: 1,
            http_errors: 0,
            transport_errors: 0,
            elapsed_secs: 2.0,
            latencies_us: vec![100, 200, 300, 400],
            per_mix: vec![("plan", 6)],
            server_metrics: Some(metrics),
        };
        assert_eq!(report.result_cache_hit_rate(), Some(0.8));
        // (90 memory + 8 disk) / 100 lookups
        assert!((report.combined_cell_hit_rate().unwrap() - 0.98).abs() < 1e-9);
        // the accounting identity every run must satisfy
        assert_eq!(
            report.ok
                + report.retried_ok
                + report.rejected
                + report.gave_up
                + report.http_errors
                + report.transport_errors,
            report.requests
        );
        let j = report.to_json();
        assert_eq!(j.get_str("schema"), Some("tcbench/loadgen/v1"));
        assert_eq!(j.get("latency_us").unwrap().get_u64("p50"), Some(300));
        assert_eq!(j.get_u64("rejected"), Some(1));
        assert_eq!(j.get_u64("retried_ok"), Some(1));
        assert_eq!(j.get_u64("gave_up"), Some(1));
        assert_eq!(j.get_u64("attempts"), Some(9));
        assert!((j.get_f64("throughput_rps").unwrap() - 3.0).abs() < 1e-9);
        let text = report.render();
        assert!(text.contains("p50 300 us"), "{text}");
        assert!(text.contains("retried ok"), "{text}");
        assert!(text.contains("cell cache+store"), "{text}");
    }

    #[test]
    fn retry_after_header_is_extracted_case_insensitively() {
        let (status, retry_after, body) = parse_response_full(
            "HTTP/1.1 503 Service Unavailable\r\nretry-after: 3\r\n\r\n{}",
        )
        .unwrap();
        assert_eq!((status, retry_after, body.as_str()), (503, Some(3), "{}"));
        // absent or non-numeric hints degrade to None, never errors
        let (_, retry_after, _) =
            parse_response_full("HTTP/1.1 200 OK\r\nContent-Length: 2\r\n\r\n{}").unwrap();
        assert_eq!(retry_after, None);
        let (_, retry_after, _) =
            parse_response_full("HTTP/1.1 503 X\r\nRetry-After: Thu, 01 Jan\r\n\r\n").unwrap();
        assert_eq!(retry_after, None);
    }

    #[test]
    fn backoff_honors_retry_after_doubles_and_caps() {
        let mut prng = Prng::new(7);
        for _ in 0..64 {
            // default base 100 ms, jittered into [50, 100]
            let d = backoff_ms(None, 0, &mut prng);
            assert!((50..=100).contains(&d), "{d}");
            // attempt 1 doubles: [100, 200]
            let d = backoff_ms(None, 1, &mut prng);
            assert!((100..=200).contains(&d), "{d}");
            // a 2 s Retry-After hint dominates the default
            let d = backoff_ms(Some(2), 0, &mut prng);
            assert!((1000..=2000).contains(&d), "{d}");
            // the cap holds against huge hints, shifts and overflow
            let d = backoff_ms(Some(u64::MAX), 40, &mut prng);
            assert!(d <= 5_000, "{d}");
        }
        // deterministic under a fixed seed
        let seq_a: Vec<u64> = (0..8).map(|i| backoff_ms(None, i % 3, &mut Prng::new(11))).collect();
        let seq_b: Vec<u64> = (0..8).map(|i| backoff_ms(None, i % 3, &mut Prng::new(11))).collect();
        assert_eq!(seq_a, seq_b);
    }
}
