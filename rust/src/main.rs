//! `repro` — the tcbench campaign CLI (L3 leader entrypoint).
//!
//! ```text
//! repro list                         # show every registered experiment
//! repro run <id>... [--backend B]    # regenerate specific tables/figures
//! repro all [--backend B] [--out D]  # the full campaign
//! repro sweep --device D --instr I   # ad-hoc instruction sweep
//! repro devices                      # calibrated devices
//! ```
//!
//! Backends for the §8 numeric experiments: `native` (Rust softfloat),
//! `pjrt` (AOT artifacts through the PJRT CPU client; requires
//! `make artifacts`), or `auto` (default: pjrt if artifacts exist).

use std::io::Write as _;

use anyhow::{anyhow, bail, Result};

use tcbench::coordinator::{run_experiment, Backend, EXPERIMENTS};
use tcbench::device;
use tcbench::isa::MmaInstr;
use tcbench::microbench::{convergence_point, sweep_mma};
use tcbench::runtime::ArtifactStore;

fn usage() -> &'static str {
    "repro — Dissecting Tensor Cores, reproduction CLI\n\
     \n\
     USAGE:\n\
       repro list\n\
       repro devices\n\
       repro run <id>... [--backend native|pjrt|auto] [--out DIR]\n\
       repro all [--backend native|pjrt|auto] [--out DIR]\n\
       repro sweep --device <a100|rtx3070ti|rtx2080ti> --instr \"<ab> <cd> <shape> [sparse]\"\n\
     \n\
     EXAMPLES:\n\
       repro run t3 t6 fig11\n\
       repro all --out results\n\
       repro sweep --device a100 --instr \"bf16 f32 m16n8k16\"\n"
}

/// Minimal flag parser: positional args + `--key value` pairs.
struct Args {
    positional: Vec<String>,
    flags: Vec<(String, String)>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let value = it
                    .next()
                    .ok_or_else(|| anyhow!("flag --{key} needs a value"))?
                    .clone();
                flags.push((key.to_string(), value));
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Args { positional, flags })
    }

    fn flag(&self, key: &str) -> Option<&str> {
        self.flags.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

fn make_backend(kind: &str) -> Result<Backend> {
    match kind {
        "native" => Ok(Backend::Native),
        "pjrt" => Ok(Backend::Pjrt(ArtifactStore::open_default()?)),
        "auto" => Ok(Backend::auto()),
        other => bail!("unknown backend {other:?} (native|pjrt|auto)"),
    }
}

fn parse_instr(spec: &str) -> Result<MmaInstr> {
    use tcbench::isa::{AbType, CdType};
    let parts: Vec<&str> = spec.split_whitespace().collect();
    if parts.len() < 3 {
        bail!("instr spec must be \"<ab> <cd> <shape> [sparse]\", got {spec:?}");
    }
    let ab = match parts[0].to_ascii_lowercase().as_str() {
        "fp16" | "f16" => AbType::Fp16,
        "bf16" => AbType::Bf16,
        "tf32" => AbType::Tf32,
        "int8" | "s8" => AbType::Int8,
        "int4" | "s4" => AbType::Int4,
        "binary" | "b1" => AbType::Binary,
        other => bail!("unknown A/B type {other:?}"),
    };
    let cd = match parts[1].to_ascii_lowercase().as_str() {
        "fp16" | "f16" => CdType::Fp16,
        "fp32" | "f32" => CdType::Fp32,
        "int32" | "s32" => CdType::Int32,
        other => bail!("unknown C/D type {other:?}"),
    };
    let shape = parts[2].parse().map_err(|e: String| anyhow!(e))?;
    let sparse = parts.get(3).is_some_and(|s| *s == "sparse" || *s == "sp");
    Ok(if sparse { MmaInstr::sp(ab, cd, shape) } else { MmaInstr::dense(ab, cd, shape) })
}

fn emit(out_dir: Option<&str>, id: &str, report: &str) -> Result<()> {
    println!("{report}");
    if let Some(dir) = out_dir {
        std::fs::create_dir_all(dir)?;
        let path = format!("{dir}/{id}.txt");
        let mut f = std::fs::File::create(&path)?;
        f.write_all(report.as_bytes())?;
        eprintln!("[repro] wrote {path}");
    }
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().map(String::as_str) else {
        print!("{}", usage());
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;

    match cmd {
        "list" => {
            println!("{:<8} {:<8} {}", "id", "backend", "description");
            for e in EXPERIMENTS {
                println!(
                    "{:<8} {:<8} {}",
                    e.id,
                    if e.numeric { "numeric" } else { "sim" },
                    e.description
                );
            }
        }
        "devices" => {
            for d in device::registry() {
                println!(
                    "{:<10} {} — {:?}, {} SMs, {} TCs/SM, sparse: {}",
                    d.name,
                    d.product,
                    d.arch,
                    d.sms,
                    d.arch.tensor_cores_per_sm(),
                    d.arch.supports_sparse()
                );
            }
        }
        "run" | "all" => {
            let ids: Vec<&str> = if cmd == "all" {
                EXPERIMENTS.iter().map(|e| e.id).collect()
            } else {
                let ids: Vec<&str> = args.positional.iter().map(String::as_str).collect();
                if ids.is_empty() {
                    bail!("`repro run` needs experiment ids; see `repro list`");
                }
                ids
            };
            let mut backend = make_backend(args.flag("backend").unwrap_or("auto"))?;
            eprintln!("[repro] numeric backend: {}", backend.name());
            for id in ids {
                let t0 = std::time::Instant::now();
                let report = run_experiment(id, &mut backend)?;
                emit(args.flag("out"), id, &report)?;
                eprintln!("[repro] {id} done in {:.2?}", t0.elapsed());
            }
        }
        "sweep" => {
            let dev_name = args.flag("device").unwrap_or("a100");
            let dev = device::by_name(dev_name)
                .ok_or_else(|| anyhow!("unknown device {dev_name:?}; see `repro devices`"))?;
            let instr = parse_instr(args.flag("instr").ok_or_else(|| anyhow!("--instr required"))?)?;
            if !dev.supports(&instr) {
                bail!("{instr} is not supported on {}", dev.name);
            }
            let sweep = sweep_mma(&dev, &instr);
            println!("sweep of {instr} on {}:", dev.name);
            println!("{:>6} {:>4} {:>10} {:>14}", "warps", "ILP", "lat(cy)", "thr(FMA/clk)");
            for c in &sweep.cells {
                println!("{:>6} {:>4} {:>10.1} {:>14.1}", c.warps, c.ilp, c.latency, c.throughput);
            }
            for warps in [4, 8] {
                let c = convergence_point(&sweep, warps);
                println!(
                    "convergence at {warps} warps: ILP {} -> {:.1} cy, {:.1} FMA/clk/SM",
                    c.ilp, c.latency, c.throughput
                );
            }
        }
        "help" | "--help" | "-h" => print!("{}", usage()),
        other => {
            eprintln!("unknown command {other:?}\n");
            print!("{}", usage());
            std::process::exit(2);
        }
    }
    Ok(())
}
