//! `repro` — the tcbench campaign CLI (L3 leader entrypoint).
//!
//! ```text
//! repro list                         # show every registered experiment
//! repro run <id>... [--backend B]    # regenerate specific tables/figures
//! repro all [--backend B] [--out D]  # the full campaign (+ summary.json)
//! repro sweep --device D --instr I [--profile] [--trace F]  # ad-hoc sweep
//! repro devices                      # calibrated devices
//! repro serve [--addr A] [--threads N] [--warm] [--cell-store D]
//!             [--replicas N | --shard i/N] [--queue-depth N]   # tcserved
//! repro loadgen [--addr A] [--mix M] [--concurrency C] [--duration S]
//!             # load harness against a running tcserved
//! repro lint <spec>... | repro lint --all         # tclint static verifier
//! repro tune <spec> [--device D] [--objective O] [--top K]   # autotuner
//! ```
//!
//! Backends for the §8 numeric experiments: `native` (Rust softfloat),
//! `pjrt` (AOT artifacts through the PJRT CPU client; requires
//! `make artifacts`), or `auto` (default: pjrt if artifacts exist).

use std::io::Write as _;

use anyhow::{anyhow, bail, Result};

use tcbench::coordinator::{
    default_threads, lint_all, run_all, run_experiment, BackendKind, EXPERIMENTS,
};
use tcbench::device;
use tcbench::loadgen;
use tcbench::report;
use tcbench::server::{serve_blocking, ServerConfig};
use tcbench::sim::{ProfileMode, SimProfile};
use tcbench::util::Json;
use tcbench::workload::{
    runner_for, tune_workload, ExecPoint, LintRecord, Objective, Plan, Runner, SimRunner,
    UnitOutput, Workload, DEFAULT_TUNE_TOP_K,
};

fn usage() -> &'static str {
    "repro — Dissecting Tensor Cores, reproduction CLI\n\
     \n\
     USAGE:\n\
       repro list\n\
       repro devices\n\
       repro run <id>... [--backend native|pjrt|auto] [--out DIR]\n\
       repro all [--backend native|pjrt|auto] [--out DIR]\n\
       repro sweep --device <a100|rtx3070ti|rtx2080ti> --instr \"<workload>\"\n\
                   [--profile] [--trace FILE]\n\
       repro serve [--addr HOST:PORT] [--threads N] [--warm]\n\
                   [--cell-store DIR|none] [--replicas N | --shard i/N]\n\
                   [--queue-depth N] [--chaos SPEC] [--chaos-seed N]\n\
       repro loadgen [--addr HOST:PORT] [--mix plan:sweep:numeric:tune]\n\
                   [--concurrency C] [--duration SECONDS] [--seed S] [--out FILE]\n\
                   [--retries R] [--deadline-ms MS]\n\
       repro lint <spec>... [--device D] [--out DIR]   # tclint workload specs\n\
       repro lint --all [--out DIR]        # every program the campaign generates\n\
       repro tune <spec|mma|mma.sp|ldmatrix|ld.shared|wmma|gemm> [--device D]\n\
                   [--objective min-latency|max-throughput|target-occupancy:<warps>]\n\
                   [--top K] [--out DIR]   # analytic-first config autotuner\n\
     \n\
     WORKLOAD SPECS (repro sweep, POST /v1/plan):\n\
       mma <ab> <cd> <shape>        e.g. \"mma bf16 f32 m16n8k16\"\n\
       mma.sp <ab> <cd> <shape>     e.g. \"mma.sp fp16 f32 m16n8k32\"\n\
       ldmatrix <x1|x2|x4>          e.g. \"ldmatrix x4\"\n\
       ld.shared <u32|u64> <ways>   e.g. \"ld.shared u32 8\"\n\
       wmma <ab> <cd> <shape>       e.g. \"wmma fp16 f32 m16n16k16\"\n\
       gemm <variant> <ab> <cd> <size> <MxNxK> [l2]\n\
                                    e.g. \"gemm pipeline bf16 f32 2048 128x128x32\"\n\
                                    (variant: baseline|pipeline|permuted; the sweep\n\
                                    axes are CTA warps x cp.async stages)\n\
       numeric profile <ab> <cd> <op> [init]\n\
                                    e.g. \"numeric profile bf16 f32 acc fp32\"\n\
       numeric chain <ab> <cd> <len> [init]\n\
                                    e.g. \"numeric chain tf32 f32 14\"\n\
                                    (§8 probes; ab: bf16|fp16|tf32|fp8e4m3|fp8e5m2,\n\
                                    op: mul|inner|acc, init: low|fp32; the sweep\n\
                                    axes are chain step x init kind)\n\
       (legacy \"<ab> <cd> <shape> [sparse]\" mma specs still work)\n\
     \n\
     EXAMPLES:\n\
       repro run t3 t6 fig11\n\
       repro all --out results          # also writes summary.json + bench_summary.json\n\
       repro sweep --device a100 --instr \"bf16 f32 m16n8k16\"\n\
       repro sweep --device a100 --instr \"ldmatrix x4\"\n\
       repro sweep --device a100 --instr \"gemm pipeline bf16 f32 512 128x128x32\"\n\
       repro sweep --device a100 --instr \"numeric chain tf32 f32 14\"\n\
       repro sweep --device a100 --instr \"bf16 f32 m16n8k16\" --profile --trace trace.json\n\
       repro serve --addr 127.0.0.1:8321 --warm\n\
       repro serve --shard 0/3 --cell-store /shared/cells   # replica 0 of a fleet\n\
       repro loadgen --addr 127.0.0.1:8321 --mix plan:sweep --duration 10\n\
       repro lint \"gemm pipeline bf16 f32 2048 128x128x32\"\n\
       repro lint --all --out out          # exits nonzero on any Error diagnostic\n\
       repro tune mma --device a100 --objective max-throughput --top 8 --out out\n\
       repro tune \"gemm pipeline bf16 f32 512 128x128x32\" --objective min-latency\n\
     \n\
     AUTOTUNING (repro tune, POST /v1/tune):\n\
       The calibrated closed-form model scores every legal (warps, ILP,\n\
       cp.async stages, tile) configuration, the top-K frontier is confirmed\n\
       on the cycle simulator (cell-cache backed), and the ranked list shows\n\
       predicted vs simulated numbers plus the realized pruning ratio.\n\
       Objectives: min-latency | max-throughput | target-occupancy:<warps>.\n\
       Bare family names expand to a canonical spec (mma -> \"mma fp16 f32\n\
       m16n8k16\", gemm -> \"gemm pipeline bf16 f32 512 128x128x32\", ...).\n\
       --out writes tune_report.json (schema tcbench/tune/v1).\n\
     \n\
     STATIC ANALYSIS (repro lint, POST /v1/lint):\n\
       tclint verifies every warp program a plan would launch — def-use,\n\
       cp.async protocol, barrier safety, loop-body uniformity, resource\n\
       bounds — without simulating. Error diagnostics fail the command\n\
       (exit 1); warnings are reported and exit 0. --out writes lint.json.\n\
     \n\
     OBSERVABILITY (timing workloads only):\n\
       --profile      append a cycle-level stall-attribution breakdown to the sweep\n\
       --trace FILE   write a Chrome trace-event JSON of one representative cell\n\
                      (open in https://ui.perfetto.dev)\n\
     \n\
     SERVING AT SCALE (repro serve / repro loadgen):\n\
       Every JSON endpoint answers in the tcserved/v1 envelope; POST bodies are\n\
       canonical, the GET+query aliases of /v1/run and /v1/sweep answer with a\n\
       Deprecation header. --cell-store points replicas at one shared directory\n\
       of simulated cells (atomic writes; survives restarts); --replicas N hosts\n\
       N consistent-hash shards in-process, --shard i/N marks this process as one\n\
       replica of a fleet. --queue-depth bounds the accept queue (overflow gets\n\
       503 + Retry-After). repro loadgen replays a deterministic plan/sweep/\n\
       numeric mix and reports p50/p99 plus the served cache hit rates; 503\n\
       sheds are retried up to --retries times (default 2) honoring\n\
       Retry-After with capped exponential backoff and seeded jitter.\n\
     \n\
     ROBUSTNESS (deadlines + tcchaos):\n\
       Every request may carry a deadline_ms body field (or X-Deadline-Ms\n\
       header). A blown deadline on a timing unit degrades to the calibrated\n\
       analytic prediction (200 with a `degraded` marker, never cached);\n\
       numeric probes have no model to fall to and answer 504\n\
       deadline_exceeded. --chaos installs a seeded fault plan, grammar\n\
       site:kind[=arg]@probability, comma-separated, e.g.\n\
         --chaos \"store.read:err@0.05,store.read:delay_ms=50@0.1,\\\n\
                  sim:panic@0.01,queue:full@0.02\" --chaos-seed 7\n\
       Faults surface as the API's typed errors and are counted under\n\
       `chaos` in /v1/metrics.\n\
     \n\
     SERVE ENDPOINTS:\n\
       /healthz /readyz (503 while warming or saturated) /v1/experiments\n\
       /v1/devices POST:/v1/run/<id> POST:/v1/sweep POST:/v1/plan\n\
       POST:/v1/lint (400 on Error diagnostics) POST:/v1/tune\n\
       /v1/metrics (JSON incl. latency histograms)  /metrics (Prometheus text)\n"
}

/// Flags that take no value (presence means `true`).
const BOOL_FLAGS: &[&str] = &["warm", "profile", "all"];

/// Minimal flag parser: positional args + `--key value` pairs, plus
/// valueless boolean flags ([`BOOL_FLAGS`]).
struct Args {
    positional: Vec<String>,
    flags: Vec<(String, String)>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if BOOL_FLAGS.contains(&key) {
                    flags.push((key.to_string(), "true".to_string()));
                    continue;
                }
                let value = it
                    .next()
                    .ok_or_else(|| anyhow!("flag --{key} needs a value"))?
                    .clone();
                flags.push((key.to_string(), value));
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Args { positional, flags })
    }

    fn flag(&self, key: &str) -> Option<&str> {
        self.flags.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

/// Parse the `--backend` flag into a [`Runner`] — the backend seam of
/// the workload layer (the §8 numeric probes run on its numeric leg;
/// timing stays on the simulator everywhere). `auto` never fails: it
/// falls back to the simulator backend when the PJRT artifacts are
/// absent or unopenable. The returned kind is the backend that will
/// *actually* run, derived from the constructed runner.
fn make_runner(kind: &str) -> Result<(BackendKind, Box<dyn Runner>)> {
    let runner = runner_for(BackendKind::parse(kind)?).map_err(|e| anyhow!(e))?;
    let effective = match runner.name() {
        "pjrt" => BackendKind::Pjrt,
        _ => BackendKind::Native,
    };
    Ok((effective, runner))
}

/// Render a stall-attribution breakdown (the `--profile` tail of
/// `repro sweep`): one line per non-empty category, as a percentage of
/// all accounted warp-cycles.
fn render_stall_profile(p: &SimProfile) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "stall attribution ({} run(s), {} warp-cycles accounted):",
        p.runs, p.warp_cycles
    );
    for ((name, count), (_, frac)) in p.categories().iter().zip(p.fractions()) {
        if *count == 0 {
            continue;
        }
        let _ = writeln!(out, "  {name:<14} {:>7.3}%  ({count} warp-cycles)", frac * 100.0);
    }
    out
}

fn emit(out_dir: Option<&str>, id: &str, report: &str) -> Result<()> {
    println!("{report}");
    if let Some(dir) = out_dir {
        std::fs::create_dir_all(dir)?;
        let path = format!("{dir}/{id}.txt");
        let mut f = std::fs::File::create(&path)?;
        f.write_all(report.as_bytes())?;
        eprintln!("[repro] wrote {path}");
    }
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().map(String::as_str) else {
        print!("{}", usage());
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;

    match cmd {
        "list" => {
            println!("{:<8} {:<8} {}", "id", "backend", "description");
            for e in EXPERIMENTS {
                println!(
                    "{:<8} {:<8} {}",
                    e.id,
                    if e.numeric { "numeric" } else { "sim" },
                    e.description
                );
            }
        }
        "devices" => {
            for d in device::registry() {
                println!(
                    "{:<10} {} — {:?}, {} SMs, {} TCs/SM, sparse: {}",
                    d.name,
                    d.product,
                    d.arch,
                    d.sms,
                    d.arch.tensor_cores_per_sm(),
                    d.arch.supports_sparse()
                );
            }
        }
        "run" => {
            let ids: Vec<&str> = args.positional.iter().map(String::as_str).collect();
            if ids.is_empty() {
                bail!("`repro run` needs experiment ids; see `repro list`");
            }
            let (kind, runner) = make_runner(args.flag("backend").unwrap_or("auto"))?;
            eprintln!("[repro] numeric backend: {}", kind.name());
            for id in ids {
                let t0 = std::time::Instant::now();
                let report = run_experiment(id, runner.as_ref())?;
                emit(args.flag("out"), id, &report)?;
                eprintln!("[repro] {id} done in {:.2?}", t0.elapsed());
            }
        }
        "all" => {
            let (kind, runner) = make_runner(args.flag("backend").unwrap_or("auto"))?;
            eprintln!("[repro] numeric backend: {}", kind.name());
            let t0 = std::time::Instant::now();
            // every experiment fans out over the worker pool
            let runs = run_all(runner.as_ref())?;
            let mut entries = Vec::new();
            for r in &runs {
                emit(args.flag("out"), r.id, &r.report)?;
                eprintln!("[repro] {} done in {:.1} ms", r.id, r.wall_ms);
                let deviation = match report::deviation_stats(&r.report) {
                    Some(d) => d.to_json(),
                    None => Json::Null,
                };
                entries.push(Json::obj(vec![
                    ("id", Json::str(r.id)),
                    ("wall_ms", Json::num(r.wall_ms)),
                    ("deviation", deviation),
                ]));
            }
            // GEMM workload rows: the three Appendix-A kernels run as
            // first-class plans through the same workload path that
            // `repro sweep` and POST /v1/plan use, so their perf rows
            // land in bench_summary.json next to the experiments
            let gemm_plans = [
                // (id, spec, stages): the paper's 8-warp CTA; only the
                // pipeline variant has a stage axis (double-buffered)
                ("gemm_baseline", "gemm baseline bf16 f32 2048 128x128x32", 1),
                ("gemm_pipeline", "gemm pipeline bf16 f32 2048 128x128x32", 2),
                ("gemm_permuted", "gemm permuted bf16 f32 2048 128x128x32 l2", 1),
            ];
            let mut gemm_rows = Vec::new();
            let mut profile_rows = Vec::new();
            for (id, spec, stages) in gemm_plans {
                let workload = Workload::parse_spec(spec).map_err(|e| anyhow!(e))?;
                let plan = Plan::new(workload)
                    .device("a100")
                    .point(8, stages)
                    .completion_latency()
                    .compile()
                    .map_err(|e| anyhow!(e))?;
                // timing plans run with counting stall attribution on:
                // the counters ride the cell cache, so warm reruns still
                // report attribution, and profile_summary.json gets a
                // row per plan without a second simulation pass
                let result = plan
                    .run_profiled(&SimRunner, 1, ProfileMode::Counting)
                    .map_err(|e| anyhow!(e))?;
                emit(args.flag("out"), id, &report::render_bench(&result))?;
                eprintln!("[repro] {id} done in {:.1} ms", result.wall_ms);
                if let Some(dir) = args.flag("out") {
                    let path = format!("{dir}/{id}.json");
                    std::fs::write(&path, report::bench_to_json(&result).pretty())?;
                    eprintln!("[repro] wrote {path}");
                }
                gemm_rows.push(Json::obj(vec![
                    ("id", Json::str(id)),
                    ("workload", Json::str(spec)),
                    // gemm plans are simulator-timed regardless of the
                    // campaign's numeric --backend; label the row with
                    // the runner that actually produced it
                    ("backend", Json::str(result.runner)),
                    ("wall_ms", Json::num(result.wall_ms)),
                ]));
                if let Some(p) = result.stall_profile() {
                    profile_rows.push((id, p));
                }
            }
            // Numeric workload rows: canonical §8 probes run as
            // first-class plans through the campaign's runner (these
            // ARE backend-sensitive — the runner's numeric leg does the
            // arithmetic), so the PR-3 CI gate watches the numeric path
            // next to the timing plans
            let numeric_plans = [
                ("numeric_profile_bf16", "numeric profile bf16 f32 acc fp32"),
                ("numeric_profile_fp16", "numeric profile fp16 f16 acc low"),
                ("numeric_chain_tf32", "numeric chain tf32 f32 14 low"),
            ];
            let mut numeric_rows = Vec::new();
            for (id, spec) in numeric_plans {
                let workload = Workload::parse_spec(spec).map_err(|e| anyhow!(e))?;
                let plan = Plan::new(workload)
                    .device("a100")
                    .point(1, 1)
                    .compile()
                    .map_err(|e| anyhow!(e))?;
                let result = plan.run(runner.as_ref(), 1).map_err(|e| anyhow!(e))?;
                emit(args.flag("out"), id, &report::render_bench(&result))?;
                eprintln!("[repro] {id} done in {:.1} ms", result.wall_ms);
                if let Some(dir) = args.flag("out") {
                    let path = format!("{dir}/{id}.json");
                    std::fs::write(&path, report::bench_to_json(&result).pretty())?;
                    eprintln!("[repro] wrote {path}");
                }
                numeric_rows.push(Json::obj(vec![
                    ("id", Json::str(id)),
                    ("workload", Json::str(spec)),
                    ("backend", Json::str(result.runner)),
                    ("wall_ms", Json::num(result.wall_ms)),
                ]));
            }
            let total_ms = t0.elapsed().as_secs_f64() * 1e3;
            eprintln!("[repro] campaign finished in {total_ms:.1} ms");
            if let Some(dir) = args.flag("out") {
                let summary = Json::obj(vec![
                    ("version", Json::str(env!("CARGO_PKG_VERSION"))),
                    ("backend", Json::str(kind.name())),
                    ("total_wall_ms", Json::num(total_ms)),
                    ("experiments", Json::Arr(entries)),
                ]);
                std::fs::create_dir_all(dir)?;
                let path = format!("{dir}/summary.json");
                std::fs::write(&path, summary.pretty())?;
                eprintln!("[repro] wrote {path}");

                // machine-readable perf snapshot: per-plan wall time
                // only, in a stable schema meant to be archived as
                // bench_baseline.json and diffed across PRs (the CI
                // bench job runs scripts/bench_diff.py over it)
                let bench = Json::obj(vec![
                    ("schema", Json::str("tcbench/bench_summary/v1")),
                    ("version", Json::str(env!("CARGO_PKG_VERSION"))),
                    ("backend", Json::str(kind.name())),
                    ("threads", Json::num(default_threads() as f64)),
                    ("total_wall_ms", Json::num(total_ms)),
                    (
                        "plans",
                        Json::Arr(
                            runs.iter()
                                .map(|r| {
                                    Json::obj(vec![
                                        ("id", Json::str(r.id)),
                                        ("wall_ms", Json::num(r.wall_ms)),
                                    ])
                                })
                                .chain(gemm_rows)
                                .chain(numeric_rows)
                                .collect(),
                        ),
                    ),
                ]);
                let path = format!("{dir}/bench_summary.json");
                std::fs::write(&path, bench.pretty())?;
                eprintln!("[repro] wrote {path}");

                // stall attribution next to the perf snapshot: which
                // category each plan's warp-cycles went to (numeric
                // rows run no cycle simulation, so they have no row)
                let profiles = Json::obj(vec![
                    ("schema", Json::str("tcbench/profile_summary/v1")),
                    ("version", Json::str(env!("CARGO_PKG_VERSION"))),
                    (
                        "plans",
                        Json::Arr(
                            profile_rows
                                .iter()
                                .map(|(id, p)| {
                                    Json::obj(vec![
                                        ("id", Json::str(id)),
                                        ("profile", report::sim_profile_to_json(p)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ]);
                let path = format!("{dir}/profile_summary.json");
                std::fs::write(&path, profiles.pretty())?;
                eprintln!("[repro] wrote {path}");
            }
        }
        "lint" => {
            // (scope label, its diagnostics) — an experiment id under
            // --all, the workload spec string otherwise. Clean scopes
            // stay in the list so the JSON artifact shows coverage.
            let mut scopes: Vec<(String, Vec<LintRecord>)> = Vec::new();
            if args.flag("all").is_some() {
                if !args.positional.is_empty() {
                    bail!("`repro lint --all` lints the whole campaign; drop the specs");
                }
                for (id, records) in lint_all()? {
                    scopes.push((id.to_string(), records));
                }
            } else {
                if args.positional.is_empty() {
                    bail!("`repro lint` needs workload specs or --all; see `repro help`");
                }
                let dev_name = args.flag("device").unwrap_or("a100");
                for spec in &args.positional {
                    let workload = Workload::parse_spec(spec).map_err(|e| anyhow!(e))?;
                    // the full sweep grid covers every exec point the
                    // workload can run at; numeric probes have no
                    // completion latency to probe
                    let mut plan = Plan::new(workload).device(dev_name).sweep();
                    if !matches!(workload, Workload::Numeric(_)) {
                        plan = plan.completion_latency();
                    }
                    let plan = plan.compile().map_err(|e| anyhow!(e))?;
                    scopes.push((spec.clone(), plan.lint()));
                }
            }
            let (mut errors, mut warns) = (0usize, 0usize);
            for (scope, records) in &scopes {
                for r in records {
                    if r.is_error() {
                        errors += 1;
                    } else {
                        warns += 1;
                    }
                    println!("{scope}: {r}");
                }
            }
            println!(
                "tclint: {} scope(s) checked, {errors} error(s), {warns} warning(s)",
                scopes.len()
            );
            if let Some(dir) = args.flag("out") {
                std::fs::create_dir_all(dir)?;
                let path = format!("{dir}/lint.json");
                std::fs::write(&path, report::lint_to_json(&scopes).pretty())?;
                eprintln!("[repro] wrote {path}");
            }
            if errors > 0 {
                std::process::exit(1);
            }
        }
        "serve" => {
            let threads = match args.flag("threads") {
                Some(t) => t
                    .parse::<usize>()
                    .map_err(|_| anyhow!("--threads must be a positive integer, got {t:?}"))?
                    .max(1),
                None => default_threads(),
            };
            let cell_store = match args.flag("cell-store") {
                Some("none") | Some("off") => None,
                Some(dir) => Some(std::path::PathBuf::from(dir)),
                None => ServerConfig::default().cell_store,
            };
            let shard = match args.flag("shard") {
                Some(spec) => {
                    let (i, n) = spec
                        .split_once('/')
                        .and_then(|(i, n)| i.parse::<usize>().ok().zip(n.parse::<usize>().ok()))
                        .ok_or_else(|| {
                            anyhow!("--shard must look like i/N (e.g. 0/3), got {spec:?}")
                        })?;
                    if i >= n {
                        bail!("--shard index {i} out of range for {n} replica(s)");
                    }
                    Some((i, n))
                }
                None => None,
            };
            let replicas = match args.flag("replicas") {
                Some(r) => {
                    if shard.is_some() {
                        bail!(
                            "--replicas conflicts with --shard \
                             (--shard i/N already fixes the fleet size)"
                        );
                    }
                    let r = r
                        .parse::<usize>()
                        .map_err(|_| anyhow!("--replicas must be a positive integer, got {r:?}"))?;
                    if r == 0 {
                        bail!("--replicas must be at least 1");
                    }
                    r
                }
                None => 1,
            };
            let queue_depth = match args.flag("queue-depth") {
                Some(q) => q
                    .parse::<usize>()
                    .map_err(|_| anyhow!("--queue-depth must be a positive integer, got {q:?}"))?
                    .max(1),
                None => ServerConfig::default().queue_depth,
            };
            let chaos = args.flag("chaos").map(str::to_string);
            let chaos_seed = match args.flag("chaos-seed") {
                Some(s) => s
                    .parse::<u64>()
                    .map_err(|_| anyhow!("--chaos-seed must be an unsigned integer, got {s:?}"))?,
                None => 0,
            };
            if chaos.is_none() && args.flag("chaos-seed").is_some() {
                bail!("--chaos-seed without --chaos has no effect; give a fault spec");
            }
            let cfg = ServerConfig {
                addr: args.flag("addr").unwrap_or("127.0.0.1:8321").to_string(),
                threads,
                warm: args.flag("warm").is_some(),
                cell_store,
                replicas,
                shard,
                queue_depth,
                chaos,
                chaos_seed,
                ..ServerConfig::default()
            };
            serve_blocking(cfg)?;
        }
        "loadgen" => {
            let mut cfg = loadgen::LoadgenConfig::default();
            if let Some(addr) = args.flag("addr") {
                cfg.addr = addr.to_string();
            }
            if let Some(mix) = args.flag("mix") {
                cfg.mix = loadgen::parse_mix(mix).map_err(|e| anyhow!(e))?;
            }
            if let Some(c) = args.flag("concurrency") {
                cfg.concurrency = c
                    .parse::<usize>()
                    .map_err(|_| anyhow!("--concurrency must be a positive integer, got {c:?}"))?
                    .max(1);
            }
            if let Some(d) = args.flag("duration") {
                cfg.duration_secs = d
                    .parse::<f64>()
                    .map_err(|_| anyhow!("--duration must be seconds (e.g. 2.5), got {d:?}"))?;
                if !cfg.duration_secs.is_finite() || cfg.duration_secs <= 0.0 {
                    bail!("--duration must be positive");
                }
            }
            if let Some(s) = args.flag("seed") {
                cfg.seed = s
                    .parse::<u64>()
                    .map_err(|_| anyhow!("--seed must be an unsigned integer, got {s:?}"))?;
            }
            if let Some(r) = args.flag("retries") {
                cfg.retries = r
                    .parse::<u32>()
                    .map_err(|_| anyhow!("--retries must be a non-negative integer, got {r:?}"))?;
            }
            if let Some(ms) = args.flag("deadline-ms") {
                cfg.deadline_ms = Some(ms.parse::<u64>().map_err(|_| {
                    anyhow!("--deadline-ms must be milliseconds (an unsigned integer), got {ms:?}")
                })?);
            }
            let report = loadgen::run(&cfg).map_err(|e| anyhow!(e))?;
            print!("{}", report.render());
            if let Some(path) = args.flag("out") {
                std::fs::write(path, report.to_json().pretty())?;
                eprintln!("[repro] wrote {path}");
            }
            if report.requests > 0 && report.ok == 0 {
                bail!("loadgen: {} request(s) sent, none succeeded", report.requests);
            }
        }
        "sweep" => {
            // a thin translator into the unified plan path: parse the
            // workload spec, compile a completion+sweep plan, run it on
            // the simulator runner and render the uniform result
            let dev_name = args.flag("device").unwrap_or("a100");
            let spec = args
                .flag("instr")
                .ok_or_else(|| anyhow!("--instr required (a workload spec; see `repro help`)"))?;
            let workload = Workload::parse_spec(spec).map_err(|e| anyhow!(e))?;
            let profile_on = args.flag("profile").is_some();
            let trace_path = args.flag("trace");
            if (profile_on || trace_path.is_some()) && matches!(workload, Workload::Numeric(_)) {
                bail!(
                    "--profile/--trace attribute simulator cycles, and numeric probes run no \
                     cycle simulation; drop the flags or pick a timing workload"
                );
            }
            let mut plan = Plan::new(workload).device(dev_name).sweep();
            // numeric probes have no completion/issue latency; every
            // other workload gets the §4 step-1 probe alongside
            if !matches!(workload, Workload::Numeric(_)) {
                plan = plan.completion_latency();
            }
            let plan = plan.compile().map_err(|e| anyhow!(e))?;
            let mode = if profile_on || trace_path.is_some() {
                ProfileMode::Counting
            } else {
                ProfileMode::Off
            };
            let result = plan
                .run_profiled(&SimRunner, default_threads().min(4), mode)
                .map_err(|e| anyhow!(e))?;
            println!("{}", report::render_bench(&result));
            if let Some(p) = result.stall_profile() {
                print!("{}", render_stall_profile(&p));
            }
            if let Some(path) = trace_path {
                // re-measure the sweep's peak cell under the tracing
                // profiler (tracing bypasses the cell cache by design,
                // so this is one extra uncached simulation)
                let point = result
                    .units
                    .iter()
                    .find_map(|(_, out)| match out {
                        UnitOutput::Sweep { sweep, .. } => Some(ExecPoint::new(
                            sweep.warps_axis.last().copied().unwrap_or(1),
                            sweep.ilp_axis.last().copied().unwrap_or(1),
                        )),
                        _ => None,
                    })
                    .ok_or_else(|| anyhow!("no sweep unit to trace"))?;
                let dev = device::by_name(dev_name)
                    .ok_or_else(|| anyhow!("unknown device {dev_name:?}"))?;
                let (_, profile) = workload.measure_cached_profiled(
                    &dev,
                    point,
                    result.runner,
                    ProfileMode::Tracing,
                );
                let profile = profile.ok_or_else(|| anyhow!("tracing produced no profile"))?;
                std::fs::write(path, report::trace_to_json(&profile).pretty())?;
                eprintln!(
                    "[repro] wrote {path} ({} trace events, {} warps at {}x ILP; open in \
                     https://ui.perfetto.dev)",
                    profile.events.len(),
                    point.warps,
                    point.ilp
                );
            }
        }
        "tune" => {
            let dev_name = args.flag("device").unwrap_or("a100");
            let dev = device::by_name(dev_name)
                .ok_or_else(|| anyhow!("unknown device {dev_name:?}; see `repro devices`"))?;
            let spec = args
                .positional
                .first()
                .ok_or_else(|| anyhow!("`repro tune` needs a workload spec or family prefix"))?;
            // bare family prefixes expand to a canonical representative
            // spec, so `repro tune mma` works without memorizing shapes
            let spec = match spec.as_str() {
                "mma" => "mma fp16 f32 m16n8k16",
                "mma.sp" => "mma.sp fp16 f32 m16n8k32",
                "ldmatrix" => "ldmatrix x4",
                "ld.shared" => "ld.shared u32 1",
                "wmma" => "wmma fp16 f32 m16n16k16",
                "gemm" => "gemm pipeline bf16 f32 512 128x128x32",
                full => full,
            };
            let workload = Workload::parse_spec(spec).map_err(|e| anyhow!(e))?;
            let objective_spec = args.flag("objective").unwrap_or("max-throughput");
            let objective = Objective::parse_spec(objective_spec).map_err(|e| anyhow!(e))?;
            let top = match args.flag("top") {
                Some(t) => t
                    .parse::<usize>()
                    .map_err(|_| anyhow!("--top must be a positive integer, got {t:?}"))?,
                None => DEFAULT_TUNE_TOP_K,
            };
            // the analytic model proposes, the simulator disposes: the
            // confirmation pass always runs on the cycle simulator
            let report =
                tune_workload(&workload, &dev, objective, top, "sim", default_threads(), None)
                    .map_err(|e| anyhow!(e))?;
            println!(
                "tune {} on {} — objective {}",
                report.workload,
                report.device,
                report.objective.spec_name()
            );
            println!(
                "analytic: {} config(s) scored in {:.1} us ({:.3e} configs/s)",
                report.scored,
                report.analytic_seconds * 1e6,
                report.analytic_configs_per_sec
            );
            println!(
                "confirmed: top {} via cycle sim (pruning ratio {:.3})",
                report.confirmed, report.pruning_ratio
            );
            println!(
                "{:<4} {:>5} {:>4} {:>10} {:>10} {:>10} {:>10} {:>6}  spec",
                "rank", "warps", "ilp", "pred_lat", "sim_lat", "pred_thr", "sim_thr", "calib"
            );
            for (i, c) in report.configs.iter().enumerate() {
                // unconfirmed rows (deadline fell over before the cycle-sim
                // pass) have no simulated columns; the calib verdict says so
                let sim_lat = c.simulated_latency.map_or("-".to_string(), |v| format!("{v:.2}"));
                let sim_thr = c.simulated_throughput.map_or("-".to_string(), |v| format!("{v:.1}"));
                let calib = if !c.confirmed {
                    "pred"
                } else if c.within_calibration {
                    "ok"
                } else {
                    "drift"
                };
                println!(
                    "{:<4} {:>5} {:>4} {:>10.2} {:>10} {:>10.1} {:>10} {:>6}  {}",
                    i + 1,
                    c.point.warps,
                    c.point.ilp,
                    c.predicted.latency,
                    sim_lat,
                    c.predicted.throughput,
                    sim_thr,
                    calib,
                    c.spec
                );
            }
            if let Some(dir) = args.flag("out") {
                std::fs::create_dir_all(dir)?;
                let path = format!("{dir}/tune_report.json");
                std::fs::write(&path, report.to_json().pretty())?;
                eprintln!("[repro] wrote {path}");
            }
        }
        "help" | "--help" | "-h" => print!("{}", usage()),
        other => {
            eprintln!("unknown command {other:?}\n");
            print!("{}", usage());
            std::process::exit(2);
        }
    }
    Ok(())
}
