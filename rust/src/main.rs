//! `repro` — the tcbench campaign CLI (L3 leader entrypoint).
//!
//! ```text
//! repro list                         # show every registered experiment
//! repro run <id>... [--backend B]    # regenerate specific tables/figures
//! repro all [--backend B] [--out D]  # the full campaign (+ summary.json)
//! repro sweep --device D --instr I   # ad-hoc instruction sweep
//! repro devices                      # calibrated devices
//! repro serve [--addr A] [--threads N] [--warm]   # tcserved campaign service
//! ```
//!
//! Backends for the §8 numeric experiments: `native` (Rust softfloat),
//! `pjrt` (AOT artifacts through the PJRT CPU client; requires
//! `make artifacts`), or `auto` (default: pjrt if artifacts exist).

use std::io::Write as _;

use anyhow::{anyhow, bail, Result};

use tcbench::coordinator::{
    default_threads, run_all, run_experiment, Backend, BackendKind, EXPERIMENTS,
};
use tcbench::device;
use tcbench::isa::MmaInstr;
use tcbench::microbench::{convergence_point, sweep_mma};
use tcbench::report;
use tcbench::server::{serve_blocking, ServerConfig};
use tcbench::util::Json;

fn usage() -> &'static str {
    "repro — Dissecting Tensor Cores, reproduction CLI\n\
     \n\
     USAGE:\n\
       repro list\n\
       repro devices\n\
       repro run <id>... [--backend native|pjrt|auto] [--out DIR]\n\
       repro all [--backend native|pjrt|auto] [--out DIR]\n\
       repro sweep --device <a100|rtx3070ti|rtx2080ti> --instr \"<ab> <cd> <shape> [sparse]\"\n\
       repro serve [--addr HOST:PORT] [--threads N] [--warm]\n\
     \n\
     EXAMPLES:\n\
       repro run t3 t6 fig11\n\
       repro all --out results          # also writes results/summary.json\n\
       repro sweep --device a100 --instr \"bf16 f32 m16n8k16\"\n\
       repro serve --addr 127.0.0.1:8321 --warm\n\
     \n\
     SERVE ENDPOINTS:\n\
       /healthz /v1/experiments /v1/devices /v1/run/<id> /v1/sweep /v1/metrics\n"
}

/// Flags that take no value (presence means `true`).
const BOOL_FLAGS: &[&str] = &["warm"];

/// Minimal flag parser: positional args + `--key value` pairs, plus
/// valueless boolean flags ([`BOOL_FLAGS`]).
struct Args {
    positional: Vec<String>,
    flags: Vec<(String, String)>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if BOOL_FLAGS.contains(&key) {
                    flags.push((key.to_string(), "true".to_string()));
                    continue;
                }
                let value = it
                    .next()
                    .ok_or_else(|| anyhow!("flag --{key} needs a value"))?
                    .clone();
                flags.push((key.to_string(), value));
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Args { positional, flags })
    }

    fn flag(&self, key: &str) -> Option<&str> {
        self.flags.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

fn make_backend(kind: &str) -> Result<Backend> {
    BackendKind::parse(kind)?.instantiate()
}

fn parse_instr(spec: &str) -> Result<MmaInstr> {
    MmaInstr::parse_spec(spec).map_err(|e| anyhow!(e))
}

fn emit(out_dir: Option<&str>, id: &str, report: &str) -> Result<()> {
    println!("{report}");
    if let Some(dir) = out_dir {
        std::fs::create_dir_all(dir)?;
        let path = format!("{dir}/{id}.txt");
        let mut f = std::fs::File::create(&path)?;
        f.write_all(report.as_bytes())?;
        eprintln!("[repro] wrote {path}");
    }
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().map(String::as_str) else {
        print!("{}", usage());
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;

    match cmd {
        "list" => {
            println!("{:<8} {:<8} {}", "id", "backend", "description");
            for e in EXPERIMENTS {
                println!(
                    "{:<8} {:<8} {}",
                    e.id,
                    if e.numeric { "numeric" } else { "sim" },
                    e.description
                );
            }
        }
        "devices" => {
            for d in device::registry() {
                println!(
                    "{:<10} {} — {:?}, {} SMs, {} TCs/SM, sparse: {}",
                    d.name,
                    d.product,
                    d.arch,
                    d.sms,
                    d.arch.tensor_cores_per_sm(),
                    d.arch.supports_sparse()
                );
            }
        }
        "run" => {
            let ids: Vec<&str> = args.positional.iter().map(String::as_str).collect();
            if ids.is_empty() {
                bail!("`repro run` needs experiment ids; see `repro list`");
            }
            let mut backend = make_backend(args.flag("backend").unwrap_or("auto"))?;
            eprintln!("[repro] numeric backend: {}", backend.name());
            for id in ids {
                let t0 = std::time::Instant::now();
                let report = run_experiment(id, &mut backend)?;
                emit(args.flag("out"), id, &report)?;
                eprintln!("[repro] {id} done in {:.2?}", t0.elapsed());
            }
        }
        "all" => {
            let mut backend = make_backend(args.flag("backend").unwrap_or("auto"))?;
            eprintln!("[repro] numeric backend: {}", backend.name());
            let t0 = std::time::Instant::now();
            // simulator experiments fan out over the worker pool
            let runs = run_all(&mut backend)?;
            let total_ms = t0.elapsed().as_secs_f64() * 1e3;
            let mut entries = Vec::new();
            for r in &runs {
                emit(args.flag("out"), r.id, &r.report)?;
                eprintln!("[repro] {} done in {:.1} ms", r.id, r.wall_ms);
                let deviation = match report::deviation_stats(&r.report) {
                    Some(d) => d.to_json(),
                    None => Json::Null,
                };
                entries.push(Json::obj(vec![
                    ("id", Json::str(r.id)),
                    ("wall_ms", Json::num(r.wall_ms)),
                    ("deviation", deviation),
                ]));
            }
            eprintln!("[repro] campaign finished in {total_ms:.1} ms");
            if let Some(dir) = args.flag("out") {
                let summary = Json::obj(vec![
                    ("version", Json::str(env!("CARGO_PKG_VERSION"))),
                    ("backend", Json::str(backend.name())),
                    ("total_wall_ms", Json::num(total_ms)),
                    ("experiments", Json::Arr(entries)),
                ]);
                std::fs::create_dir_all(dir)?;
                let path = format!("{dir}/summary.json");
                std::fs::write(&path, summary.pretty())?;
                eprintln!("[repro] wrote {path}");
            }
        }
        "serve" => {
            let threads = match args.flag("threads") {
                Some(t) => t
                    .parse::<usize>()
                    .map_err(|_| anyhow!("--threads must be a positive integer, got {t:?}"))?
                    .max(1),
                None => default_threads(),
            };
            let cfg = ServerConfig {
                addr: args.flag("addr").unwrap_or("127.0.0.1:8321").to_string(),
                threads,
                warm: args.flag("warm").is_some(),
                ..ServerConfig::default()
            };
            serve_blocking(cfg)?;
        }
        "sweep" => {
            let dev_name = args.flag("device").unwrap_or("a100");
            let dev = device::by_name(dev_name)
                .ok_or_else(|| anyhow!("unknown device {dev_name:?}; see `repro devices`"))?;
            let instr = parse_instr(args.flag("instr").ok_or_else(|| anyhow!("--instr required"))?)?;
            if !dev.supports(&instr) {
                bail!("{instr} is not supported on {}", dev.name);
            }
            let sweep = sweep_mma(&dev, &instr);
            println!("sweep of {instr} on {}:", dev.name);
            println!("{:>6} {:>4} {:>10} {:>14}", "warps", "ILP", "lat(cy)", "thr(FMA/clk)");
            for c in &sweep.cells {
                println!("{:>6} {:>4} {:>10.1} {:>14.1}", c.warps, c.ilp, c.latency, c.throughput);
            }
            for warps in [4, 8] {
                let c = convergence_point(&sweep, warps);
                println!(
                    "convergence at {warps} warps: ILP {} -> {:.1} cy, {:.1} FMA/clk/SM",
                    c.ilp, c.latency, c.throughput
                );
            }
        }
        "help" | "--help" | "-h" => print!("{}", usage()),
        other => {
            eprintln!("unknown command {other:?}\n");
            print!("{}", usage());
            std::process::exit(2);
        }
    }
    Ok(())
}
