//! tclint — static verification of warp programs.
//!
//! Every table the repo reproduces is compiled down to the
//! [`WarpProgram`] IR and handed to `SmSim`, which trusts it blindly: a
//! read-before-write register silently reads a zero-ready scoreboard
//! slot, a `CpAsyncWait` with no commit waits on nothing, and unequal
//! `BarSync` counts across warps mis-synchronize in the simulator (the
//! barrier excuses retired warps) but hang on real hardware. This module
//! is the static pass that makes those failure modes loud *without
//! simulating a cycle*: [`verify`] walks the programs once and returns
//! typed [`Diagnostic`]s.
//!
//! Wiring (see the README's "Static analysis (tclint)" section):
//! * `SmSim::from_shared` runs [`verify`] under `debug_assertions` and
//!   panics with the rule id on the first [`Severity::Error`] — debug
//!   and test builds cannot simulate a malformed program. Release
//!   builds skip the pass entirely (zero overhead, bit-identical
//!   schedules).
//! * `BenchPlan::lint` runs it over every program a compiled plan would
//!   simulate; `repro lint <spec...>` / `repro lint --all` and tcserved's
//!   `POST /v1/lint` expose that (the endpoint answers 400 when any
//!   Error-severity diagnostic fires).
//!
//! ## Rule catalog
//!
//! | rule id | severity | fires on |
//! |---|---|---|
//! | `def-use/undefined-read`   | Error | a source register read before any write and not seeded via `init_reg` (the scoreboard self-dependency class) |
//! | `def-use/dead-write`       | Warn  | a write overwritten before any read (the register's final, live-out write is exempt) |
//! | `cpasync/wait-before-commit` | Error | `CpAsyncWait` with no `CpAsyncCommit` anywhere before it |
//! | `cpasync/empty-commit`     | Warn  | `CpAsyncCommit` closing a group with no `CpAsync` in it |
//! | `cpasync/wait-noop`        | Warn  | `max_pending` ≥ the groups ever committed before the wait (it can never block) |
//! | `cpasync/uncommitted`      | Warn  | `CpAsync` transfers never closed by a commit |
//! | `barrier/arrival-mismatch` | Error | unequal `BarSync` counts across warps in a multi-warp launch |
//! | `loop/nonuniform-body`     | Warn  | FMA or smem-byte work differs between `IterMark` segments (breaks the per-iteration accounting) |
//! | `loop/prologue-skew`       | Warn  | counted work before the first / after the last `IterMark` differs from a steady iteration |
//! | `resource/register-pressure` | Error | more than 256 distinct registers in one warp program |
//! | `resource/zero-cost-op`    | Error | an `Mma` with `ii`/`latency` 0, a smem op with 0 transactions, or a 0-byte transfer |
//! | `resource/smem-overflow`   | Error | a single smem/cp.async transfer, or the peak cp.async bytes in flight across the launch, exceeding the device's per-SM shared memory |

use std::fmt;
use std::sync::Arc;

use crate::device::Device;
use crate::sim::{Op, WarpProgram};

/// Hardware register-file bound per thread (255 architectural registers
/// on Volta..Hopper; the virtual IR gets one extra for slack).
const MAX_REGS_PER_WARP: usize = 256;

/// Diagnostic severity. `Error` means the program is structurally
/// malformed — the simulator would hang, deadlock or silently
/// mis-attribute cycles; `Warn` flags suspicious-but-runnable shapes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warn,
    Error,
}

impl Severity {
    pub fn as_str(&self) -> &'static str {
        match self {
            Severity::Warn => "warn",
            Severity::Error => "error",
        }
    }
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// The analyzer rules. Each has a stable string id (`Rule::id`) used in
/// panics, JSON output and tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Rule {
    UndefinedRead,
    DeadWrite,
    WaitBeforeCommit,
    EmptyCommit,
    WaitNoop,
    Uncommitted,
    BarrierMismatch,
    NonuniformBody,
    PrologueSkew,
    RegisterPressure,
    ZeroCostOp,
    SmemOverflow,
}

impl Rule {
    pub const ALL: [Rule; 12] = [
        Rule::UndefinedRead,
        Rule::DeadWrite,
        Rule::WaitBeforeCommit,
        Rule::EmptyCommit,
        Rule::WaitNoop,
        Rule::Uncommitted,
        Rule::BarrierMismatch,
        Rule::NonuniformBody,
        Rule::PrologueSkew,
        Rule::RegisterPressure,
        Rule::ZeroCostOp,
        Rule::SmemOverflow,
    ];

    /// Stable rule identifier (`category/name`).
    pub fn id(&self) -> &'static str {
        match self {
            Rule::UndefinedRead => "def-use/undefined-read",
            Rule::DeadWrite => "def-use/dead-write",
            Rule::WaitBeforeCommit => "cpasync/wait-before-commit",
            Rule::EmptyCommit => "cpasync/empty-commit",
            Rule::WaitNoop => "cpasync/wait-noop",
            Rule::Uncommitted => "cpasync/uncommitted",
            Rule::BarrierMismatch => "barrier/arrival-mismatch",
            Rule::NonuniformBody => "loop/nonuniform-body",
            Rule::PrologueSkew => "loop/prologue-skew",
            Rule::RegisterPressure => "resource/register-pressure",
            Rule::ZeroCostOp => "resource/zero-cost-op",
            Rule::SmemOverflow => "resource/smem-overflow",
        }
    }

    /// The severity this rule always fires at.
    pub fn severity(&self) -> Severity {
        match self {
            Rule::UndefinedRead
            | Rule::WaitBeforeCommit
            | Rule::BarrierMismatch
            | Rule::RegisterPressure
            | Rule::ZeroCostOp
            | Rule::SmemOverflow => Severity::Error,
            Rule::DeadWrite
            | Rule::EmptyCommit
            | Rule::WaitNoop
            | Rule::Uncommitted
            | Rule::NonuniformBody
            | Rule::PrologueSkew => Severity::Warn,
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.id())
    }
}

/// One analyzer finding, anchored to a warp and (usually) an
/// instruction index in that warp's program.
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    pub rule: Rule,
    pub severity: Severity,
    /// Index of the first warp running the offending program (replicated
    /// launches share one trace, so the finding applies to every warp
    /// aliasing it).
    pub warp: usize,
    /// Instruction index inside the warp program, when the finding is
    /// anchored to one (launch-wide findings like the barrier rule are
    /// not).
    pub instr: Option<usize>,
    pub message: String,
}

impl Diagnostic {
    fn new(rule: Rule, warp: usize, instr: Option<usize>, message: String) -> Self {
        Self { rule, severity: rule.severity(), warp, instr, message }
    }

    pub fn is_error(&self) -> bool {
        self.severity == Severity::Error
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {} warp {}", self.rule.id(), self.severity, self.warp)?;
        if let Some(i) = self.instr {
            write!(f, " instr {i}")?;
        }
        write!(f, ": {}", self.message)
    }
}

/// Do any of `diags` carry [`Severity::Error`]?
pub fn has_errors(diags: &[Diagnostic]) -> bool {
    diags.iter().any(Diagnostic::is_error)
}

/// Statically verify the warp programs of one launch (warp `i` runs
/// `programs[i]`, exactly the `SmSim::from_shared` contract) against
/// `device`. Returns every finding; no simulation happens.
///
/// Per-program rules run once per *distinct* trace (replicated launches
/// share `Arc`s), launch-wide rules (barrier arity, aggregate smem
/// residency) see all warps.
pub fn verify(programs: &[Arc<WarpProgram>], device: &Device) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let mut seen: Vec<*const WarpProgram> = Vec::new();
    for (warp, p) in programs.iter().enumerate() {
        let ptr = Arc::as_ptr(p);
        if seen.contains(&ptr) {
            continue;
        }
        seen.push(ptr);
        check_def_use(warp, p, &mut diags);
        check_cpasync(warp, p, &mut diags);
        check_loop_uniformity(warp, p, &mut diags);
        check_resources(warp, p, device, &mut diags);
    }
    check_barriers(programs, &mut diags);
    check_smem_residency(programs, device, &mut diags);
    diags.sort_by(|a, b| b.severity.cmp(&a.severity).then(a.warp.cmp(&b.warp)));
    diags
}

/// Convenience: verify and panic on the first Error — the
/// `debug_assertions` hook `SmSim::from_shared` uses.
pub fn verify_or_panic(programs: &[Arc<WarpProgram>], device: &Device) {
    let diags = verify(programs, device);
    if let Some(d) = diags.iter().find(|d| d.is_error()) {
        panic!("tclint rejected the launch: {d}");
    }
}

// --------------------------------------------------------------- def-use

/// Per-register def-use walk: reads happen at issue, the `dst` write
/// lands at completion, so a source is defined only by a *strictly
/// earlier* instruction (or by `live_in` seeding). An instruction with
/// `dst == src` therefore reads the previous value — the accumulator
/// chain idiom — and is only legal once the register has been seeded.
fn check_def_use(warp: usize, p: &WarpProgram, diags: &mut Vec<Diagnostic>) {
    #[derive(Clone, Copy)]
    struct RegState {
        written: bool,
        /// Latest write not yet read (dead-store candidate).
        pending_write: Option<usize>,
        /// Only report the first undefined read per register.
        reported: bool,
    }
    let max_reg = p
        .instrs
        .iter()
        .flat_map(|i| i.srcs.iter().copied().chain(i.dst))
        .chain(p.live_in.iter().copied())
        .max()
        .map(|r| r as usize + 1)
        .unwrap_or(0);
    let mut regs =
        vec![RegState { written: false, pending_write: None, reported: false }; max_reg];
    for &r in &p.live_in {
        regs[r as usize].written = true;
    }
    for (i, instr) in p.instrs.iter().enumerate() {
        for &s in &instr.srcs {
            let st = &mut regs[s as usize];
            if !st.written && !st.reported {
                st.reported = true;
                diags.push(Diagnostic::new(
                    Rule::UndefinedRead,
                    warp,
                    Some(i),
                    format!(
                        "r{s} is read before any write (the scoreboard would treat it \
                         as ready-at-0; seed it with ProgramBuilder::init_reg)"
                    ),
                ));
            }
            st.pending_write = None;
        }
        if let Some(d) = instr.dst {
            let st = &mut regs[d as usize];
            if let Some(prev) = st.pending_write {
                diags.push(Diagnostic::new(
                    Rule::DeadWrite,
                    warp,
                    Some(prev),
                    format!("write to r{d} is overwritten at instr {i} without being read"),
                ));
            }
            st.written = true;
            st.pending_write = Some(i);
        }
    }
    // A register's final write is its live-out value — not a dead store.
}

// -------------------------------------------------------------- cp.async

fn check_cpasync(warp: usize, p: &WarpProgram, diags: &mut Vec<Diagnostic>) {
    let mut commits = 0u32;
    let mut open_cps = 0u32; // CpAsyncs since the last commit
    let mut last_open_cp = 0usize;
    for (i, instr) in p.instrs.iter().enumerate() {
        match instr.op {
            Op::CpAsync { .. } => {
                open_cps += 1;
                last_open_cp = i;
            }
            Op::CpAsyncCommit => {
                if open_cps == 0 {
                    diags.push(Diagnostic::new(
                        Rule::EmptyCommit,
                        warp,
                        Some(i),
                        "CpAsyncCommit closes a group with no CpAsync in it".into(),
                    ));
                }
                open_cps = 0;
                commits += 1;
            }
            Op::CpAsyncWait { max_pending } => {
                if commits == 0 {
                    diags.push(Diagnostic::new(
                        Rule::WaitBeforeCommit,
                        warp,
                        Some(i),
                        format!(
                            "CpAsyncWait(max_pending={max_pending}) before any \
                             CpAsyncCommit — nothing can ever be waited on"
                        ),
                    ));
                } else if max_pending >= commits {
                    diags.push(Diagnostic::new(
                        Rule::WaitNoop,
                        warp,
                        Some(i),
                        format!(
                            "CpAsyncWait(max_pending={max_pending}) can never block: only \
                             {commits} group(s) were ever committed before it"
                        ),
                    ));
                }
            }
            _ => {}
        }
    }
    if open_cps > 0 {
        diags.push(Diagnostic::new(
            Rule::Uncommitted,
            warp,
            Some(last_open_cp),
            format!("{open_cps} CpAsync transfer(s) are never closed by a CpAsyncCommit"),
        ));
    }
}

// ------------------------------------------------------- loop uniformity

/// Counted work of an instruction span: the two quantities the
/// per-iteration accessors (`fmas_per_iteration`,
/// `smem_bytes_per_iteration`) report. `cp.async`/gmem traffic is
/// excluded on purpose: a pipelined prologue or a guarded loop tail
/// legitimately varies it without skewing either accessor.
fn span_work(instrs: &[crate::sim::Instr]) -> (u64, u64) {
    let fmas = instrs.iter().map(|i| i.op.fmas()).sum();
    let smem = instrs.iter().map(|i| i.op.smem_bytes()).sum();
    (fmas, smem)
}

fn check_loop_uniformity(warp: usize, p: &WarpProgram, diags: &mut Vec<Diagnostic>) {
    let marks: Vec<usize> = p
        .instrs
        .iter()
        .enumerate()
        .filter(|(_, i)| matches!(i.op, Op::IterMark))
        .map(|(i, _)| i)
        .collect();
    if marks.len() < 2 {
        return;
    }
    // Interior segments: the spans between consecutive IterMarks — the
    // exact window the prologue-aware per-iteration accessors average.
    let first_seg = span_work(&p.instrs[marks[0] + 1..marks[1]]);
    for w in marks.windows(2).skip(1) {
        let seg = span_work(&p.instrs[w[0] + 1..w[1]]);
        if seg != first_seg {
            diags.push(Diagnostic::new(
                Rule::NonuniformBody,
                warp,
                Some(w[0] + 1),
                format!(
                    "iteration work is not uniform: segment after mark at instr {} does \
                     {:?} (fmas, smem bytes) vs {:?} in the first segment — \
                     per-iteration accounting would be skewed",
                    w[0], seg, first_seg
                ),
            ));
            break; // one finding per program is enough to flag the shape
        }
    }
    // Prologue (before the first mark) and epilogue (after the last):
    // loop-built programs end each iteration with a mark, so the
    // prologue is exactly one iteration body and the epilogue is empty.
    let prologue = span_work(&p.instrs[..marks[0]]);
    if prologue != first_seg {
        diags.push(Diagnostic::new(
            Rule::PrologueSkew,
            warp,
            Some(0),
            format!(
                "work before the first IterMark {prologue:?} (fmas, smem bytes) differs \
                 from a steady iteration {first_seg:?}"
            ),
        ));
    }
    let epilogue = span_work(&p.instrs[marks[marks.len() - 1] + 1..]);
    if epilogue != (0, 0) {
        diags.push(Diagnostic::new(
            Rule::PrologueSkew,
            warp,
            Some(marks[marks.len() - 1] + 1),
            format!(
                "counted work {epilogue:?} (fmas, smem bytes) after the last IterMark is \
                 outside the measured window"
            ),
        ));
    }
}

// -------------------------------------------------------------- resources

fn check_resources(warp: usize, p: &WarpProgram, device: &Device, diags: &mut Vec<Diagnostic>) {
    let mut regs: Vec<u32> = p
        .instrs
        .iter()
        .flat_map(|i| i.srcs.iter().copied().chain(i.dst))
        .chain(p.live_in.iter().copied())
        .collect();
    regs.sort_unstable();
    regs.dedup();
    if regs.len() > MAX_REGS_PER_WARP {
        diags.push(Diagnostic::new(
            Rule::RegisterPressure,
            warp,
            None,
            format!(
                "{} distinct registers exceed the {MAX_REGS_PER_WARP}-register per-warp \
                 file",
                regs.len()
            ),
        ));
    }
    let cap = device.smem_bytes_per_sm as u64;
    for (i, instr) in p.instrs.iter().enumerate() {
        let problem = match instr.op {
            Op::Mma { ii, latency, .. } if ii == 0 || latency == 0 => {
                Some(format!("Mma with ii={ii}, latency={latency} (both must be nonzero)"))
            }
            Op::SmemLoad { txns, bytes } | Op::SmemStore { txns, bytes }
                if txns == 0 || bytes == 0 =>
            {
                Some(format!("smem op with txns={txns}, bytes={bytes} (both must be nonzero)"))
            }
            Op::GmemLoad { bytes } | Op::CpAsync { bytes } if bytes == 0 => {
                Some("zero-byte global transfer".into())
            }
            _ => None,
        };
        if let Some(msg) = problem {
            diags.push(Diagnostic::new(Rule::ZeroCostOp, warp, Some(i), msg));
        }
        let bytes = match instr.op {
            Op::SmemLoad { bytes, .. } | Op::SmemStore { bytes, .. } | Op::CpAsync { bytes } => {
                bytes
            }
            _ => 0,
        };
        if bytes > cap {
            diags.push(Diagnostic::new(
                Rule::SmemOverflow,
                warp,
                Some(i),
                format!(
                    "single transfer of {bytes} B exceeds the {cap} B of shared memory \
                     per SM on {}",
                    device.name
                ),
            ));
        }
    }
}

// --------------------------------------------------------------- barriers

/// Every warp in a multi-warp launch must arrive at the same number of
/// `BarSync`s: tcsim's barrier excuses retired warps (silently skewing
/// the schedule) but real hardware hangs the CTA.
fn check_barriers(programs: &[Arc<WarpProgram>], diags: &mut Vec<Diagnostic>) {
    if programs.len() < 2 {
        return;
    }
    let counts: Vec<usize> = programs
        .iter()
        .map(|p| p.instrs.iter().filter(|i| matches!(i.op, Op::BarSync)).count())
        .collect();
    let first = counts[0];
    if let Some(w) = counts.iter().position(|&c| c != first) {
        diags.push(Diagnostic::new(
            Rule::BarrierMismatch,
            w,
            None,
            format!(
                "warp {w} arrives at {} BarSync(s) but warp 0 at {first} — the CTA \
                 barrier would hang on hardware",
                counts[w]
            ),
        ));
    }
}

// --------------------------------------------------------- smem residency

/// Peak cp.async bytes in flight, summed across the launch (each warp
/// stages its own slice of the shared tile): an upper bound on the
/// shared-memory footprint the pipeline prefetches, which must fit the
/// device's per-SM capacity. `CpAsyncWait(p)` retires all but the `p`
/// newest groups.
fn check_smem_residency(
    programs: &[Arc<WarpProgram>],
    device: &Device,
    diags: &mut Vec<Diagnostic>,
) {
    let mut total_peak = 0u64;
    for p in programs {
        let mut open = 0u64;
        let mut groups: Vec<u64> = Vec::new();
        let mut peak = 0u64;
        for instr in &p.instrs {
            match instr.op {
                Op::CpAsync { bytes } => {
                    open += bytes;
                    peak = peak.max(open + groups.iter().sum::<u64>());
                }
                Op::CpAsyncCommit => {
                    groups.push(std::mem::take(&mut open));
                }
                Op::CpAsyncWait { max_pending } => {
                    let keep = max_pending as usize;
                    if groups.len() > keep {
                        groups.drain(..groups.len() - keep);
                    }
                }
                _ => {}
            }
        }
        total_peak += peak.max(open + groups.iter().sum::<u64>());
    }
    let cap = device.smem_bytes_per_sm as u64;
    if total_peak > cap {
        diags.push(Diagnostic::new(
            Rule::SmemOverflow,
            0,
            None,
            format!(
                "peak cp.async bytes in flight across the launch ({total_peak} B) exceed \
                 the {cap} B of shared memory per SM on {}",
                device.name
            ),
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::a100;
    use crate::sim::ProgramBuilder;

    fn ids(diags: &[Diagnostic]) -> Vec<&'static str> {
        diags.iter().map(|d| d.rule.id()).collect()
    }

    #[test]
    fn seeded_accumulator_chain_is_clean() {
        let mut b = ProgramBuilder::new();
        let d = b.init_reg();
        for _ in 0..4 {
            b.mma(8, 24, 2048, d, vec![d]);
            b.sync_warp();
            b.iter_mark();
        }
        let diags = verify(&[Arc::new(b.build())], &a100());
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn unseeded_accumulator_chain_is_an_undefined_read() {
        let mut b = ProgramBuilder::new();
        let d = b.alloc_reg();
        b.mma(8, 24, 2048, d, vec![d]);
        let diags = verify(&[Arc::new(b.build())], &a100());
        assert_eq!(ids(&diags), vec!["def-use/undefined-read"]);
        assert!(diags[0].is_error());
        assert_eq!(diags[0].instr, Some(0));
    }

    #[test]
    fn replicated_launch_reports_each_program_once() {
        let mut b = ProgramBuilder::new();
        let d = b.alloc_reg();
        b.mma(8, 24, 2048, d, vec![d]);
        let p = Arc::new(b.build());
        let diags = verify(&[Arc::clone(&p), Arc::clone(&p), p], &a100());
        assert_eq!(diags.len(), 1, "{diags:?}");
    }

    #[test]
    fn display_carries_the_rule_id() {
        let d = Diagnostic::new(Rule::UndefinedRead, 3, Some(7), "r0 bad".into());
        let s = d.to_string();
        assert!(s.contains("def-use/undefined-read"), "{s}");
        assert!(s.contains("warp 3"), "{s}");
        assert!(s.contains("instr 7"), "{s}");
    }

    #[test]
    fn every_rule_id_is_unique_and_categorized() {
        let mut seen = std::collections::HashSet::new();
        for r in Rule::ALL {
            assert!(seen.insert(r.id()), "duplicate id {}", r.id());
            assert!(r.id().contains('/'), "{} must be category/name", r.id());
        }
    }
}
