//! Legacy `wmma` interface model (paper §2.2, Fig. 2/3).
//!
//! On Turing/Ampere a legacy `wmma.mma.m16n16k16` is compiled into a set
//! of new-style HMMA instructions — e.g. two `HMMA.16816`
//! (= `mma.m16n8k16`) — and `wmma.load` requires the whole matrix to be
//! stored consecutively in shared memory, which forfeits the
//! conflict-avoiding layouts `ldmatrix` permits. This module models a
//! wmma-programmed microbenchmark as its compiled mma sequence so the
//! paper's "use the new interface" guidance is measurable.

use crate::device::Device;
use crate::isa::{AbType, CdType, MmaInstr, MmaShape};
use crate::sim::{Op, Profiler, ProgramBuilder, SmSim, WarpProgram};

use super::{measure_mma, Measurement, ITERS};

/// A legacy wmma.mma operand shape (m16n16k16 is the canonical one).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct WmmaShape {
    pub m: u32,
    pub n: u32,
    pub k: u32,
}

pub const WMMA_M16N16K16: WmmaShape = WmmaShape { m: 16, n: 16, k: 16 };

impl WmmaShape {
    /// The new-style mma instructions one wmma.mma compiles into
    /// (Fig. 3: fragment along n into m16n8 pieces).
    pub fn compiled_mmas(&self, ab: AbType, cd: CdType) -> Vec<MmaInstr> {
        let pieces = (self.n / 8).max(1);
        (0..pieces)
            .map(|_| MmaInstr::dense(ab, cd, MmaShape::new(self.m, 8, self.k)))
            .collect()
    }

    pub fn fmas(&self) -> u64 {
        self.m as u64 * self.n as u64 * self.k as u64
    }
}

/// Build the wmma microbenchmark program: each ILP slot issues the
/// compiled HMMA pair back-to-back into the same accumulator fragment
/// (both pieces write disjoint halves of D, so they are independent of
/// each other but chained across iterations).
pub fn wmma_program(
    device: &Device,
    shape: WmmaShape,
    ab: AbType,
    cd: CdType,
    ilp: u32,
    iters: usize,
) -> WarpProgram {
    let parts = shape.compiled_mmas(ab, cd);
    let timings: Vec<_> = parts
        .iter()
        .map(|i| {
            (
                *i,
                device
                    .timing(i)
                    .unwrap_or_else(|| panic!("{i} not supported on {}", device.name)),
            )
        })
        .collect();
    let mut b = ProgramBuilder::new();
    // one accumulator register per (slot, piece); seeded — the fragment
    // is zero-initialized before the measurement loop
    let slots: Vec<Vec<u32>> = (0..ilp)
        .map(|_| (0..parts.len()).map(|_| b.init_reg()).collect())
        .collect();
    for _ in 0..iters {
        for slot in &slots {
            for (piece, (instr, t)) in slot.iter().zip(&timings) {
                b.push(
                    Op::Mma {
                        ii: t.ii,
                        latency: t.latency,
                        fmas: instr.fmas(),
                        fpu: false,
                    },
                    Some(*piece),
                    vec![*piece],
                );
            }
        }
        b.sync_warp();
        b.iter_mark();
    }
    b.build()
}

/// Measure a wmma.mma configuration (latency per wmma iteration and
/// FMA/clk/SM).
pub fn measure_wmma(
    device: &Device,
    shape: WmmaShape,
    ab: AbType,
    cd: CdType,
    warps: u32,
    ilp: u32,
) -> Measurement {
    measure_wmma_profiled(device, shape, ab, cd, warps, ilp, &mut Profiler::Null)
}

/// [`measure_wmma`] with stall attribution through `profiler`.
pub fn measure_wmma_profiled(
    device: &Device,
    shape: WmmaShape,
    ab: AbType,
    cd: CdType,
    warps: u32,
    ilp: u32,
    profiler: &mut Profiler,
) -> Measurement {
    let program = wmma_program(device, shape, ab, cd, ilp, ITERS);
    let per_iter_fmas = program.fmas_per_iteration() * warps as u64;
    let results = SmSim::replicated(device, program, warps)
        .with_steady_state_exit()
        .run_profiled(profiler);
    let latency = results.iter().map(|r| r.latency_per_iteration()).fold(0.0, f64::max);
    Measurement { warps, ilp, latency, throughput: per_iter_fmas as f64 / latency }
}

/// The §2.2 comparison: legacy wmma vs new mma at the same FMA volume.
/// Returns (wmma, equivalent-mma) measurements at a saturated point.
pub fn wmma_vs_mma(device: &Device, ab: AbType, cd: CdType) -> (Measurement, Measurement) {
    let wmma = measure_wmma(device, WMMA_M16N16K16, ab, cd, 8, 1);
    // the same work expressed directly: 2 x mma.m16n8k16 per iteration
    let instr = MmaInstr::dense(ab, cd, MmaShape::new(16, 8, 16));
    let mma = measure_mma(device, &instr, 8, 2);
    (wmma, mma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::a100;

    #[test]
    fn m16n16k16_compiles_to_two_hmma_16816() {
        // Fig. 3's example mapping
        let parts = WMMA_M16N16K16.compiled_mmas(AbType::Fp16, CdType::Fp32);
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].shape, MmaShape::new(16, 8, 16));
        assert_eq!(parts[0].fmas() * 2, WMMA_M16N16K16.fmas());
    }

    #[test]
    fn wmma_throughput_matches_compiled_mma_sequence() {
        // The compute cost is identical once compiled — the wmma
        // interface loses on *data movement* flexibility (wmma.load),
        // not on the FMA pipeline.
        let d = a100();
        let (wmma, mma) = wmma_vs_mma(&d, AbType::Fp16, CdType::Fp32);
        let ratio = wmma.throughput / mma.throughput;
        assert!((0.9..1.1).contains(&ratio), "wmma {wmma:?} vs mma {mma:?}");
    }

    #[test]
    fn wmma_single_warp_latency_double_the_piece() {
        // One wmma = two chained-issue HMMAs: iteration period grows by
        // roughly one extra issue slot, not 2x (pieces are independent).
        let d = a100();
        let w = measure_wmma(&d, WMMA_M16N16K16, AbType::Fp16, CdType::Fp32, 1, 1);
        let piece = MmaInstr::dense(AbType::Fp16, CdType::Fp32, MmaShape::new(16, 8, 16));
        let m = measure_mma(&d, &piece, 1, 1);
        assert!(w.latency > m.latency, "{w:?} vs {m:?}");
        assert!(w.latency < 2.0 * m.latency, "{w:?} vs {m:?}");
    }

    #[test]
    #[should_panic(expected = "not supported")]
    fn wmma_requires_supported_pieces() {
        let d = crate::device::rtx2080ti();
        // Turing has no m16n8k16 FP16 row in our calibration
        wmma_program(&d, WMMA_M16N16K16, AbType::Fp16, CdType::Fp32, 1, 1);
    }
}
