//! Ablations over the simulator's own calibrated design choices
//! (DESIGN.md §4): each knob is disabled in turn and the resulting
//! deviation from the paper's published numbers is measured. This is the
//! evidence that every mechanism in tcsim is *load-bearing* — removing
//! any of them breaks a specific paper finding.

use crate::device::Device;
use crate::isa::{AbType, CdType, LdMatrixNum, MmaInstr};
use crate::report::Table;

use super::{measure_ldmatrix, measure_mma};

/// One ablation outcome: a paper observable with the knob on vs off.
#[derive(Debug, Clone)]
pub struct AblationRow {
    pub knob: &'static str,
    pub observable: &'static str,
    pub paper: f64,
    pub with_knob: f64,
    pub without_knob: f64,
}

impl AblationRow {
    /// Does disabling the knob move the observable away from the paper?
    pub fn knob_is_load_bearing(&self) -> bool {
        (self.with_knob - self.paper).abs() < (self.without_knob - self.paper).abs()
    }
}

/// Ablate the A100 sparse small-k ii penalty (ii 6 -> ideal 4).
pub fn ablate_sparse_small_k(device: &Device) -> AblationRow {
    let instr = MmaInstr::sp(AbType::Fp16, CdType::Fp32, crate::isa::shapes::M16N8K16);
    // Saturated point (8,3): the penalty caps the instruction well below
    // the 2x-dense sparse peak.
    let with_knob = measure_mma(device, &instr, 8, 3).throughput;
    let mut no_penalty = device.clone();
    for (i, t) in no_penalty.mma_timings.iter_mut() {
        if *i == instr {
            t.ii = 4; // the ideal ii from the vendor peak
        }
    }
    let without_knob = measure_mma(&no_penalty, &instr, 8, 3).throughput;
    AblationRow {
        knob: "sparse small-k ii penalty (ii=6)",
        observable: "mma.sp.m16n8k16 (8,3) FMA/clk",
        paper: 1290.5,
        with_knob,
        without_knob,
    }
}

/// Ablate the INT8 m8n8k16 half-rate anomaly (ii 4 -> ideal 2).
pub fn ablate_int8_m8n8k16(device: &Device) -> AblationRow {
    let instr = MmaInstr::dense(AbType::Int8, CdType::Int32, crate::isa::shapes::M8N8K16);
    // Saturated point (8,4): the half-rate knob caps the instruction at
    // ~half the 2048 INT8 peak (the paper's best observed: 998.3).
    let with_knob = measure_mma(device, &instr, 8, 4).throughput;
    let mut ideal = device.clone();
    for (i, t) in ideal.mma_timings.iter_mut() {
        if *i == instr {
            t.ii = 2;
        }
    }
    let without_knob = measure_mma(&ideal, &instr, 8, 4).throughput;
    AblationRow {
        knob: "INT8 m8n8k16 half-rate (ii=4)",
        observable: "mma.m8n8k16 INT8 (8,4) FMA/clk",
        paper: 998.3,
        with_knob,
        without_knob,
    }
}

/// Ablate the dual-LSU structure (2 units -> 1 double-speed unit): the
/// paper's "one warp caps at 64 B/clk" finding needs two units with
/// per-warp affinity.
pub fn ablate_dual_lsu(device: &Device) -> AblationRow {
    let with_knob = measure_ldmatrix(device, LdMatrixNum::X4, 1, 4).throughput;
    let mut single = device.clone();
    single.lsu_units = 1;
    single.lsu_txn_cycles = 1; // same aggregate 128 B/clk
    let without_knob = measure_ldmatrix(&single, LdMatrixNum::X4, 1, 4).throughput;
    AblationRow {
        knob: "two 64 B/clk LSUs (vs one 128 B/clk)",
        observable: "ldmatrix.x4 single-warp B/clk",
        paper: 64.0,
        with_knob,
        without_knob,
    }
}

/// Ablate the per-warp LSU pending cap: Table 9's ldmatrix.x1 (4,5)
/// point sits below the fabric bound only because of it.
pub fn ablate_lsu_pending_cap(device: &Device) -> AblationRow {
    let with_knob = measure_ldmatrix(device, LdMatrixNum::X1, 4, 5).throughput;
    let mut uncapped = device.clone();
    uncapped.lsu_pending_per_warp = 64;
    let without_knob = measure_ldmatrix(&uncapped, LdMatrixNum::X1, 4, 5).throughput;
    AblationRow {
        knob: "per-warp pending-load cap (4)",
        observable: "ldmatrix.x1 (4,5) B/clk",
        paper: 95.4,
        with_knob,
        without_knob,
    }
}

/// Run every ablation and render the table.
pub fn run_all(device: &Device) -> (Vec<AblationRow>, String) {
    let rows = vec![
        ablate_sparse_small_k(device),
        ablate_int8_m8n8k16(device),
        ablate_dual_lsu(device),
        ablate_lsu_pending_cap(device),
    ];
    let mut t = Table::new(
        "Simulator design-choice ablations (A100)",
        &["knob", "observable", "paper", "with", "without", "load-bearing"],
    );
    for r in &rows {
        t.row(vec![
            r.knob.to_string(),
            r.observable.to_string(),
            format!("{:.1}", r.paper),
            format!("{:.1}", r.with_knob),
            format!("{:.1}", r.without_knob),
            if r.knob_is_load_bearing() { "yes".into() } else { "NO".into() },
        ]);
    }
    (rows, t.render())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::a100;

    #[test]
    fn every_calibrated_knob_is_load_bearing() {
        let d = a100();
        let (rows, _) = run_all(&d);
        for r in rows {
            assert!(
                r.knob_is_load_bearing(),
                "{}: with {} / without {} / paper {}",
                r.knob,
                r.with_knob,
                r.without_knob,
                r.paper
            );
        }
    }

    #[test]
    fn sparse_penalty_ablation_restores_ideal_peak() {
        let d = a100();
        let r = ablate_sparse_small_k(&d);
        // without the penalty the instruction would reach ~2000
        assert!(r.without_knob > 1900.0, "{r:?}");
        assert!(r.with_knob < 1450.0, "{r:?}");
    }

    #[test]
    fn single_lsu_would_hide_the_one_warp_ceiling() {
        let d = a100();
        let r = ablate_dual_lsu(&d);
        assert!(
            r.without_knob > 75.0,
            "single fast LSU lifts the 1-warp ceiling well above 64: {r:?}"
        );
    }
}
