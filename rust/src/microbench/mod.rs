//! The paper's §4 microbenchmark methodology, ported to tcsim.
//!
//! For every instruction we measure
//! 1. the completion/issue latency (ILP=1, one warp), and
//! 2. latency/throughput under a full (ILP, #warps) sweep,
//! exactly as Fig. 4 does on silicon (ITERS-iteration loop of ILP
//! independent accumulator chains, `__syncwarp()` per iteration,
//! `clock64()` timestamps).

pub mod ablation;
mod kernels;
mod sweep;
pub mod wmma;

pub use kernels::{ld_shared_program, ldmatrix_program, mma_program, ITERS};
pub use sweep::{
    convergence_point, sweep_ldmatrix, sweep_mma, ConvergencePoint, Sweep, SweepCell,
    SWEEP_ILPS, SWEEP_WARPS,
};

use crate::device::Device;
use crate::isa::{LdMatrixNum, LdSharedWidth, MmaInstr};
use crate::sim::{Profiler, SmSim};

/// One measured configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Measurement {
    pub warps: u32,
    pub ilp: u32,
    /// Cycles per loop iteration (bottleneck warp, steady state).
    pub latency: f64,
    /// FMA/clk/SM for compute, bytes/clk/SM for data movement.
    pub throughput: f64,
}

/// Run the dense/sparse `mma` microbenchmark at one configuration.
///
/// Every warp shares one `Arc` of the unrolled trace
/// ([`SmSim::replicated`] — no per-warp deep clones), and the cycle
/// loop stops at the steady state instead of grinding all ITERS
/// iterations; both are pure engine optimizations, the measured
/// latency/throughput semantics are the paper's.
pub fn measure_mma(device: &Device, instr: &MmaInstr, warps: u32, ilp: u32) -> Measurement {
    measure_mma_profiled(device, instr, warps, ilp, &mut Profiler::Null)
}

/// [`measure_mma`] with stall attribution: every warp-cycle of the run
/// is accounted through `profiler` (a [`Profiler::Null`] makes this the
/// plain measurement — same schedule, zero overhead).
pub fn measure_mma_profiled(
    device: &Device,
    instr: &MmaInstr,
    warps: u32,
    ilp: u32,
    profiler: &mut Profiler,
) -> Measurement {
    let program = mma_program(device, instr, ilp, ITERS);
    let per_iter_fmas: u64 = program.fmas_per_iteration() * warps as u64;
    let results = SmSim::replicated(device, program, warps)
        .with_steady_state_exit()
        .run_profiled(profiler);
    let latency = results.iter().map(|r| r.latency_per_iteration()).fold(0.0, f64::max);
    Measurement { warps, ilp, latency, throughput: per_iter_fmas as f64 / latency }
}

/// Completion/issue latency: ILP = 1, one warp per SM (§4 step 1).
pub fn completion_latency_mma(device: &Device, instr: &MmaInstr) -> f64 {
    measure_mma(device, instr, 1, 1).latency
}

/// Run the `ldmatrix` microbenchmark at one configuration.
pub fn measure_ldmatrix(
    device: &Device,
    num: LdMatrixNum,
    warps: u32,
    ilp: u32,
) -> Measurement {
    measure_ldmatrix_profiled(device, num, warps, ilp, &mut Profiler::Null)
}

/// [`measure_ldmatrix`] with stall attribution through `profiler`.
pub fn measure_ldmatrix_profiled(
    device: &Device,
    num: LdMatrixNum,
    warps: u32,
    ilp: u32,
    profiler: &mut Profiler,
) -> Measurement {
    let program = ldmatrix_program(device, num, ilp, ITERS);
    let per_iter_bytes = program.smem_bytes_per_iteration() * warps as u64;
    let results = SmSim::replicated(device, program, warps)
        .with_steady_state_exit()
        .run_profiled(profiler);
    let latency = results.iter().map(|r| r.latency_per_iteration()).fold(0.0, f64::max);
    Measurement { warps, ilp, latency, throughput: per_iter_bytes as f64 / latency }
}

pub fn completion_latency_ldmatrix(device: &Device, num: LdMatrixNum) -> f64 {
    measure_ldmatrix(device, num, 1, 1).latency
}

/// Run the `ld.shared` bank-conflict probe (Table 10): one warp, ILP=1,
/// addresses strided to produce `ways`-way conflicts.
pub fn measure_ld_shared(device: &Device, width: LdSharedWidth, ways: u32) -> Measurement {
    measure_ld_shared_at(device, width, ways, 1, 1)
}

/// Run the `ld.shared` conflict microbenchmark at an arbitrary
/// (#warps, ILP) point — the general form behind [`measure_ld_shared`],
/// used by the unified workload sweep path.
pub fn measure_ld_shared_at(
    device: &Device,
    width: LdSharedWidth,
    ways: u32,
    warps: u32,
    ilp: u32,
) -> Measurement {
    measure_ld_shared_at_profiled(device, width, ways, warps, ilp, &mut Profiler::Null)
}

/// [`measure_ld_shared_at`] with stall attribution through `profiler`.
pub fn measure_ld_shared_at_profiled(
    device: &Device,
    width: LdSharedWidth,
    ways: u32,
    warps: u32,
    ilp: u32,
    profiler: &mut Profiler,
) -> Measurement {
    let program = ld_shared_program(device, width, ways, ilp, ITERS);
    let per_iter_bytes = program.smem_bytes_per_iteration() * warps as u64;
    let results = SmSim::replicated(device, program, warps)
        .with_steady_state_exit()
        .run_profiled(profiler);
    let latency = results.iter().map(|r| r.latency_per_iteration()).fold(0.0, f64::max);
    Measurement { warps, ilp, latency, throughput: per_iter_bytes as f64 / latency }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::a100;
    use crate::isa::shapes::*;
    use crate::isa::{AbType, CdType};

    #[test]
    fn completion_latency_matches_paper_fp16_k16() {
        // paper Table 3: 24.7 cycles
        let d = a100();
        let i = MmaInstr::dense(AbType::Fp16, CdType::Fp32, M16N8K16);
        let lat = completion_latency_mma(&d, &i);
        assert!((24.0..26.0).contains(&lat), "got {lat}");
    }

    #[test]
    fn table3_key_point_8_2() {
        // paper: (8,2) -> 32.6 cycles, 1004.2 FMA/clk/SM
        let d = a100();
        let i = MmaInstr::dense(AbType::Fp16, CdType::Fp32, M16N8K16);
        let m = measure_mma(&d, &i, 8, 2);
        assert!((31.5..34.0).contains(&m.latency), "{m:?}");
        assert!((960.0..1030.0).contains(&m.throughput), "{m:?}");
    }

    #[test]
    fn table3_key_point_4_3() {
        // paper: (4,3) -> 27.4 cycles, 897.6 FMA/clk/SM
        let d = a100();
        let i = MmaInstr::dense(AbType::Fp16, CdType::Fp32, M16N8K16);
        let m = measure_mma(&d, &i, 4, 3);
        assert!((26.0..29.0).contains(&m.latency), "{m:?}");
        assert!((850.0..950.0).contains(&m.throughput), "{m:?}");
    }

    #[test]
    fn sparse_doubles_dense_throughput_large_k() {
        let d = a100();
        let dense = MmaInstr::dense(AbType::Bf16, CdType::Fp32, M16N8K16);
        let sp = MmaInstr::sp(AbType::Bf16, CdType::Fp32, M16N8K32);
        let md = measure_mma(&d, &dense, 8, 2);
        let ms = measure_mma(&d, &sp, 8, 2);
        // same latency, ~2x throughput (§6 findings 1-2)
        assert!((ms.latency - md.latency).abs() < 2.0, "{md:?} {ms:?}");
        let ratio = ms.throughput / md.throughput;
        assert!((1.85..2.15).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn sparse_small_k_underperforms_on_a100() {
        // Fig. 11: peak only ~1300 of the theoretical 2000
        let d = a100();
        let sp = MmaInstr::sp(AbType::Fp16, CdType::Fp32, M16N8K16);
        let m = measure_mma(&d, &sp, 8, 2);
        assert!(m.throughput < 1450.0, "{m:?}");
        assert!(m.throughput > 1150.0, "{m:?}");
    }

    #[test]
    fn ldmatrix_completion_latencies() {
        // Table 9: 23.1 / 25.1 / 29.3 cycles
        let d = a100();
        for (num, want) in [
            (LdMatrixNum::X1, 23.0),
            (LdMatrixNum::X2, 25.0),
            (LdMatrixNum::X4, 29.0),
        ] {
            let lat = completion_latency_ldmatrix(&d, num);
            assert!((lat - want).abs() < 1.5, "{num}: got {lat}, want ~{want}");
        }
    }

    #[test]
    fn ldmatrix_peak_needs_two_warps() {
        // §7 finding 2: one warp caps at ~64 B/clk, two reach ~128.
        let d = a100();
        let one = measure_ldmatrix(&d, LdMatrixNum::X4, 1, 4);
        let two = measure_ldmatrix(&d, LdMatrixNum::X4, 2, 4);
        assert!((58.0..70.0).contains(&one.throughput), "{one:?}");
        assert!(two.throughput > 115.0, "{two:?}");
    }

    #[test]
    fn ld_shared_conflict_latencies_match_table10() {
        let d = a100();
        for (ways, want) in [(1u32, 23.0), (2, 25.0), (4, 29.0), (8, 37.0)] {
            let m = measure_ld_shared(&d, LdSharedWidth::U32, ways);
            assert!(
                (m.latency - want).abs() < 1.5,
                "u32 {ways}-way: got {}, want ~{want}",
                m.latency
            );
        }
        for (ways, want) in [(2u32, 25.0), (4, 29.0), (8, 37.0)] {
            let m = measure_ld_shared(&d, LdSharedWidth::U64, ways);
            assert!(
                (m.latency - want).abs() < 1.5,
                "u64 {ways}-way: got {}, want ~{want}",
                m.latency
            );
        }
    }
}
