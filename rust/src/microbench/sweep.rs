//! (ILP, #warps) sweeps and convergence-point detection (§4 step 2).
//!
//! The paper's figures plot latency and throughput over
//! ILP ∈ {1..6} x #warps ∈ {1, 2, 4, 6, 8, 12, 16, 32}; its tables
//! summarize each instruction by two *convergence points* — the smallest
//! ILP at 4 warps and at 8 warps beyond which throughput stops improving.

use crate::device::Device;
use crate::isa::{LdMatrixNum, MmaInstr};

use super::{measure_ldmatrix, measure_mma, Measurement};

/// Default sweep axes (Fig. 6/7/10/11/15).
pub const SWEEP_WARPS: [u32; 8] = [1, 2, 4, 6, 8, 12, 16, 32];
pub const SWEEP_ILPS: [u32; 6] = [1, 2, 3, 4, 5, 6];

/// One sweep cell.
#[derive(Debug, Clone, Copy)]
pub struct SweepCell {
    pub warps: u32,
    pub ilp: u32,
    pub latency: f64,
    pub throughput: f64,
}

/// A full latency/throughput grid for one instruction.
#[derive(Debug, Clone)]
pub struct Sweep {
    pub label: String,
    pub warps_axis: Vec<u32>,
    pub ilp_axis: Vec<u32>,
    /// Row-major: `cells[w_idx * ilp_axis.len() + ilp_idx]`.
    pub cells: Vec<SweepCell>,
}

impl Sweep {
    pub fn cell(&self, warps: u32, ilp: u32) -> Option<&SweepCell> {
        let wi = self.warps_axis.iter().position(|&w| w == warps)?;
        let ii = self.ilp_axis.iter().position(|&i| i == ilp)?;
        self.cells.get(wi * self.ilp_axis.len() + ii)
    }

    /// Highest throughput anywhere in the grid.
    pub fn peak_throughput(&self) -> f64 {
        self.cells.iter().map(|c| c.throughput).fold(0.0, f64::max)
    }
}

fn sweep_grid(
    label: String,
    warps_axis: &[u32],
    ilp_axis: &[u32],
    mut f: impl FnMut(u32, u32) -> Measurement,
) -> Sweep {
    let mut cells = Vec::with_capacity(warps_axis.len() * ilp_axis.len());
    for &w in warps_axis {
        for &ilp in ilp_axis {
            let m = f(w, ilp);
            cells.push(SweepCell { warps: w, ilp, latency: m.latency, throughput: m.throughput });
        }
    }
    Sweep { label, warps_axis: warps_axis.to_vec(), ilp_axis: ilp_axis.to_vec(), cells }
}

/// Full §5/§6 sweep of an `mma`/`mma.sp` instruction.
pub fn sweep_mma(device: &Device, instr: &MmaInstr) -> Sweep {
    sweep_grid(instr.to_string(), &SWEEP_WARPS, &SWEEP_ILPS, |w, ilp| {
        measure_mma(device, instr, w, ilp)
    })
}

/// Full §7 sweep of an `ldmatrix` instruction.
pub fn sweep_ldmatrix(device: &Device, num: LdMatrixNum) -> Sweep {
    sweep_grid(num.to_string(), &SWEEP_WARPS, &SWEEP_ILPS, |w, ilp| {
        measure_ldmatrix(device, num, w, ilp)
    })
}

/// A table-style convergence summary at a fixed #warps.
#[derive(Debug, Clone, Copy)]
pub struct ConvergencePoint {
    pub warps: u32,
    pub ilp: u32,
    pub latency: f64,
    pub throughput: f64,
}

/// The smallest ILP at `warps` whose throughput is within 2% of the best
/// achieved at that warp count — the paper's "(#warp, ILP)" table points.
pub fn convergence_point(sweep: &Sweep, warps: u32) -> ConvergencePoint {
    let row: Vec<&SweepCell> = sweep
        .cells
        .iter()
        .filter(|c| c.warps == warps)
        .collect();
    assert!(!row.is_empty(), "warp count {warps} not in sweep");
    let best = row.iter().map(|c| c.throughput).fold(0.0, f64::max);
    let cell = row
        .iter()
        .find(|c| c.throughput >= 0.98 * best)
        .expect("at least one cell reaches 98% of the row max");
    ConvergencePoint {
        warps,
        ilp: cell.ilp,
        latency: cell.latency,
        throughput: cell.throughput,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::a100;
    use crate::isa::shapes::*;
    use crate::isa::{AbType, CdType};

    fn k16() -> MmaInstr {
        MmaInstr::dense(AbType::Bf16, CdType::Fp32, M16N8K16)
    }

    #[test]
    fn sweep_has_full_grid() {
        let d = a100();
        let s = sweep_mma(&d, &k16());
        assert_eq!(s.cells.len(), SWEEP_WARPS.len() * SWEEP_ILPS.len());
        assert!(s.cell(8, 2).is_some());
        assert!(s.cell(5, 1).is_none());
    }

    #[test]
    fn peak_near_vendor_claim() {
        // Fig. 6 finding 1: measured peak ~1000 vs vendor 1024.
        let d = a100();
        let s = sweep_mma(&d, &k16());
        let peak = s.peak_throughput();
        assert!((960.0..1030.0).contains(&peak), "peak {peak}");
    }

    #[test]
    fn throughput_scales_with_warps_up_to_four() {
        // Fig. 6 finding 3: 1 -> 2 -> 4 warps scales, latency flat.
        let d = a100();
        let s = sweep_mma(&d, &k16());
        let t1 = s.cell(1, 2).unwrap();
        let t2 = s.cell(2, 2).unwrap();
        let t4 = s.cell(4, 2).unwrap();
        assert!((t2.throughput / t1.throughput - 2.0).abs() < 0.15);
        assert!((t4.throughput / t1.throughput - 4.0).abs() < 0.3);
        assert!((t1.latency - t4.latency).abs() < 1.5);
    }

    #[test]
    fn six_warp_throughput_dip_at_high_ilp() {
        // Fig. 6 finding 5: at ILP >= 3, 6 warps < 4 warps throughput.
        let d = a100();
        let s = sweep_mma(&d, &k16());
        let t4 = s.cell(4, 3).unwrap().throughput;
        let t6 = s.cell(6, 3).unwrap().throughput;
        assert!(t6 < t4, "t4={t4} t6={t6}");
        // and latency(6) == latency(8):
        let l6 = s.cell(6, 3).unwrap().latency;
        let l8 = s.cell(8, 3).unwrap().latency;
        assert!((l6 - l8).abs() < 1.0, "l6={l6} l8={l8}");
    }

    #[test]
    fn twelve_warps_one_extra_cycle_sixteen_significant() {
        // Fig. 6 finding 4 at ILP=1.
        let d = a100();
        let s = sweep_mma(&d, &k16());
        let l4 = s.cell(4, 1).unwrap().latency;
        let l12 = s.cell(12, 1).unwrap().latency;
        let l16 = s.cell(16, 1).unwrap().latency;
        assert!(l12 - l4 <= 2.0, "l4={l4} l12={l12}");
        assert!(l16 - l12 >= 4.0, "l12={l12} l16={l16}");
    }

    #[test]
    fn convergence_points_match_table3() {
        let d = a100();
        let i = MmaInstr::dense(AbType::Fp16, CdType::Fp32, M16N8K16);
        let s = sweep_mma(&d, &i);
        let c4 = convergence_point(&s, 4);
        let c8 = convergence_point(&s, 8);
        // paper: (4,3) 897.6 and (8,2) 1004.2
        assert!(c4.ilp >= 3, "{c4:?}");
        assert!((c4.throughput - 897.6).abs() < 100.0, "{c4:?}");
        assert_eq!(c8.ilp, 2, "{c8:?}");
        assert!((c8.throughput - 1004.2).abs() < 50.0, "{c8:?}");
    }
}
