//! Microbenchmark kernel builders — the Fig. 4 loop structure compiled
//! to tcsim warp programs.
//!
//! `mma`: each ILP slot is an independent accumulator chain
//! (`D_s = A*B + D_s`, RAW across iterations), `__syncwarp()` closes
//! each iteration, `clock64()` is an IterMark.
//!
//! Loads: each ILP slot is a pointer-chase chain (the next address
//! depends on the loaded value) so the completion latency is observable,
//! exactly like classic shared-memory latency microbenchmarks [25].
//! Transactions are derived from real byte addresses via the bank model
//! in [`crate::sim::smem`].

use crate::device::Device;
use crate::isa::{LdMatrixNum, LdSharedWidth, MmaInstr};
use crate::sim::{ld_shared_transactions, ldmatrix_transactions, Op, ProgramBuilder, WarpProgram};

/// Loop iterations per measurement (the paper's ITERS; enough for a
/// steady state with the warm-up half discarded).
pub const ITERS: usize = 96;

/// Build the `mma`/`mma.sp` microbenchmark program for one warp.
pub fn mma_program(device: &Device, instr: &MmaInstr, ilp: u32, iters: usize) -> WarpProgram {
    let timing = device
        .timing(instr)
        .unwrap_or_else(|| panic!("{instr} not supported on {}", device.name));
    let mut b = ProgramBuilder::new();
    // Accumulators start defined (the kernel zero-initializes them), so
    // the first `D_s = A*B + D_s` read is a seeded read, not a
    // def-use violation.
    let slots: Vec<u32> = (0..ilp).map(|_| b.init_reg()).collect();
    for _ in 0..iters {
        for &d in &slots {
            // D_s = A x B + D_s: the accumulator is both src and dst.
            b.push(
                Op::Mma {
                    ii: timing.ii,
                    latency: timing.latency,
                    fmas: instr.fmas(),
                    fpu: timing.fpu_fallback == crate::device::FpuFallback::Yes,
                },
                Some(d),
                vec![d],
            );
        }
        b.sync_warp();
        b.iter_mark();
    }
    b.build()
}

/// Byte addresses of the 16-byte rows one `ldmatrix.xN` touches when the
/// fragments are packed consecutively in shared memory (the §7 layout —
/// conflict-free by construction).
fn packed_ldmatrix_addrs(num: LdMatrixNum) -> Vec<u32> {
    (0..num.count() * 8).map(|r| r * 16).collect()
}

/// Build the `ldmatrix` microbenchmark program for one warp.
pub fn ldmatrix_program(
    _device: &Device,
    num: LdMatrixNum,
    ilp: u32,
    iters: usize,
) -> WarpProgram {
    let txns = ldmatrix_transactions(&packed_ldmatrix_addrs(num));
    debug_assert_eq!(txns, num.count());
    let bytes = num.bytes_per_warp();
    let mut b = ProgramBuilder::new();
    // Chase pointers start on a valid address (seeded).
    let slots: Vec<u32> = (0..ilp).map(|_| b.init_reg()).collect();
    for _ in 0..iters {
        for &d in &slots {
            // pointer-chase: the next fragment address comes from the
            // previously loaded data.
            b.push(Op::SmemLoad { txns, bytes }, Some(d), vec![d]);
        }
        b.sync_warp();
        b.iter_mark();
    }
    b.build()
}

/// Per-thread byte addresses producing a `ways`-way conflict for
/// `ld.shared` (stride pattern; Table 10's probe).
fn strided_ld_shared_addrs(width: LdSharedWidth, ways: u32) -> Vec<u32> {
    let stride = match width {
        LdSharedWidth::U32 => 4 * ways,
        // u64 is intrinsically 2-way (256 B); `ways` counts total
        // transactions, so the address stride contributes ways/2.
        LdSharedWidth::U64 => 8 * (ways / 2).max(1),
    };
    (0..32).map(|t| t * stride).collect()
}

/// Build the `ld.shared` conflict microbenchmark program for one warp.
pub fn ld_shared_program(
    _device: &Device,
    width: LdSharedWidth,
    ways: u32,
    ilp: u32,
    iters: usize,
) -> WarpProgram {
    let addrs = strided_ld_shared_addrs(width, ways);
    let txns = ld_shared_transactions(&addrs, width.bytes_per_thread() as u32);
    assert_eq!(txns, ways.max(width.min_transactions()), "address pattern must produce the requested conflict");
    let bytes = width.bytes_per_warp();
    let mut b = ProgramBuilder::new();
    // Chase pointers start on a valid address (seeded).
    let slots: Vec<u32> = (0..ilp).map(|_| b.init_reg()).collect();
    for _ in 0..iters {
        for &d in &slots {
            b.push(Op::SmemLoad { txns, bytes }, Some(d), vec![d]);
        }
        b.sync_warp();
        b.iter_mark();
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::a100;
    use crate::isa::shapes::*;
    use crate::isa::{AbType, CdType};

    #[test]
    fn mma_program_shape() {
        let d = a100();
        let i = MmaInstr::dense(AbType::Bf16, CdType::Fp32, M16N8K16);
        let p = mma_program(&d, &i, 3, 10);
        assert_eq!(p.iter_marks(), 10);
        assert_eq!(p.fmas_per_iteration(), 3 * 2048);
        // slots chain on themselves
        let first = &p.instrs[0];
        assert_eq!(first.srcs, vec![first.dst.unwrap()]);
    }

    #[test]
    fn ldmatrix_txns_from_addresses() {
        let d = a100();
        for (num, want) in [(LdMatrixNum::X1, 1), (LdMatrixNum::X2, 2), (LdMatrixNum::X4, 4)] {
            let p = ldmatrix_program(&d, num, 1, 2);
            match p.instrs[0].op {
                Op::SmemLoad { txns, .. } => assert_eq!(txns, want),
                ref other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn ld_shared_address_patterns_hit_requested_ways() {
        for ways in [1u32, 2, 4, 8] {
            let addrs = strided_ld_shared_addrs(LdSharedWidth::U32, ways);
            assert_eq!(ld_shared_transactions(&addrs, 4), ways);
        }
        for ways in [2u32, 4, 8] {
            let addrs = strided_ld_shared_addrs(LdSharedWidth::U64, ways);
            assert_eq!(ld_shared_transactions(&addrs, 8), ways);
        }
    }

    #[test]
    #[should_panic(expected = "not supported")]
    fn unsupported_instruction_panics() {
        let d = crate::device::rtx2080ti();
        let i = MmaInstr::dense(AbType::Tf32, CdType::Fp32, M16N8K8);
        mma_program(&d, &i, 1, 1);
    }
}
