//! Instruction descriptors: the semantic identity of an `mma`/`mma.sp`
//! instruction and of the data-movement instructions (§7, Table 8).

use std::fmt;

use super::{AbType, CdType, MmaShape};

/// Peak dense Tensor-Core throughput fraction that an instruction is
/// expected to reach ("near peak performance", Table 3 caption).
pub const MMA_FULL_THROUGHPUT: f64 = 0.95;

/// One dense or sparse Tensor-Core FMA instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MmaInstr {
    pub ab: AbType,
    pub cd: CdType,
    pub shape: MmaShape,
    /// `mma.sp` — fine-grained 2:4 structured sparsity on A (§6).
    pub sparse: bool,
}

impl MmaInstr {
    pub const fn dense(ab: AbType, cd: CdType, shape: MmaShape) -> Self {
        Self { ab, cd, shape, sparse: false }
    }

    pub const fn sp(ab: AbType, cd: CdType, shape: MmaShape) -> Self {
        Self { ab, cd, shape, sparse: true }
    }

    /// Dense-equivalent FMAs per instruction executed (paper §4).
    pub fn fmas(&self) -> u64 {
        self.shape.fmas()
    }

    /// Register-file footprint of the A operand in bytes per warp.
    /// For `mma.sp`, A is compressed to `m x k/2` non-zeros plus 2-bit
    /// metadata per element of the original k (Fig. 8/9).
    pub fn a_reg_bytes(&self) -> u64 {
        let dense = self.shape.a_bytes(self.ab.storage_bits());
        if self.sparse {
            let meta_bits = self.shape.m as u64 * self.shape.k as u64 * 2;
            dense / 2 + meta_bits / 8
        } else {
            dense
        }
    }

    /// Does the operand/accumulator pairing satisfy the PTX ISA?
    pub fn is_well_formed(&self) -> bool {
        self.cd.legal_for(self.ab) && self.shape.m > 0 && self.shape.n > 0 && self.shape.k > 0
    }

    /// PTX mnemonic, e.g. `mma.sync.aligned.m16n8k16.row.col.f32.bf16.bf16.f32`.
    pub fn ptx(&self) -> String {
        let op = if self.sparse { "mma.sp" } else { "mma" };
        let cd = match self.cd {
            CdType::Fp16 => "f16",
            CdType::Fp32 => "f32",
            CdType::Fp64 => "f64",
            CdType::Int32 => "s32",
        };
        let ab = self.ab.ptx();
        format!("{op}.sync.aligned.{}.row.col.{cd}.{ab}.{ab}.{cd}", self.shape)
    }

    /// Parse a user-facing instruction spec `"<ab> <cd> <shape> [sparse]"`
    /// with whitespace or `,` separators — shared by the `repro sweep`
    /// CLI and the tcserved `/v1/sweep` endpoint (where commas survive
    /// URL encoding untouched), e.g. `"bf16 f32 m16n8k16"` or
    /// `"fp16,f32,m16n8k32,sparse"`. The exact inverse of
    /// [`MmaInstr::to_spec`].
    pub fn parse_spec(spec: &str) -> Result<MmaInstr, String> {
        let parts: Vec<&str> = spec
            .split(|c: char| c.is_whitespace() || c == ',')
            .filter(|s| !s.is_empty())
            .collect();
        if parts.len() < 3 {
            return Err(format!(
                "instr spec must be \"<ab> <cd> <shape> [sparse]\", got {spec:?}"
            ));
        }
        let ab = AbType::parse_spec(parts[0])?;
        let cd = CdType::parse_spec(parts[1])?;
        let shape: MmaShape = parts[2].parse()?;
        let trailing: Vec<String> = parts[3..].iter().map(|t| t.to_ascii_lowercase()).collect();
        let sparse = match trailing.as_slice() {
            [] => false,
            [tok] if tok == "sparse" || tok == "sp" => true,
            [tok] => {
                return Err(format!(
                    "unknown 4th token {tok:?} after the shape: the only accepted \
                     trailing token is \"sparse\" (or \"sp\"); dense is the default"
                ))
            }
            many if many.iter().all(|t| t == "sparse" || t == "sp") => {
                return Err(format!(
                    "duplicate \"sparse\" tokens in instr spec {spec:?}: \
                     \"sparse\" may appear at most once"
                ))
            }
            _ => {
                return Err(format!(
                    "too many tokens in instr spec {spec:?}: expected \
                     \"<ab> <cd> <shape> [sparse]\""
                ))
            }
        };
        Ok(if sparse { MmaInstr::sp(ab, cd, shape) } else { MmaInstr::dense(ab, cd, shape) })
    }

    /// Canonical spec string, e.g. `"bf16 f32 m16n8k16"` or
    /// `"fp16 f32 m16n8k32 sparse"` — round-trips through
    /// [`MmaInstr::parse_spec`].
    pub fn to_spec(&self) -> String {
        let mut s = format!(
            "{} {} {}",
            self.ab.spec_name(),
            self.cd.spec_name(),
            self.shape
        );
        if self.sparse {
            s.push_str(" sparse");
        }
        s
    }
}

impl fmt::Display for MmaInstr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}{} {}/{} {}",
            if self.sparse { "mma.sp" } else { "mma" },
            if self.sparse { " (2:4)" } else { "" },
            self.ab,
            self.cd,
            self.shape
        )
    }
}

/// `ldmatrix` fragment count (Fig. 13): N x 128 bytes per warp.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LdMatrixNum {
    X1,
    X2,
    X4,
}

impl LdMatrixNum {
    pub fn count(self) -> u32 {
        match self {
            LdMatrixNum::X1 => 1,
            LdMatrixNum::X2 => 2,
            LdMatrixNum::X4 => 4,
        }
    }

    /// Bytes loaded per warp (Table 8).
    pub fn bytes_per_warp(self) -> u64 {
        128 * self.count() as u64
    }
}

impl fmt::Display for LdMatrixNum {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ldmatrix.x{}", self.count())
    }
}

/// `ld.shared` access width (Table 8/10).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LdSharedWidth {
    U32,
    U64,
}

impl LdSharedWidth {
    pub fn bytes_per_thread(self) -> u64 {
        match self {
            LdSharedWidth::U32 => 4,
            LdSharedWidth::U64 => 8,
        }
    }

    pub fn bytes_per_warp(self) -> u64 {
        32 * self.bytes_per_thread()
    }

    /// Minimum shared-memory transactions a warp-wide access needs even
    /// when conflict-free: u64 moves 256 B against a 128 B/clk fabric.
    pub fn min_transactions(self) -> u32 {
        match self {
            LdSharedWidth::U32 => 1,
            LdSharedWidth::U64 => 2,
        }
    }
}

impl fmt::Display for LdSharedWidth {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LdSharedWidth::U32 => f.write_str("ld.shared.u32"),
            LdSharedWidth::U64 => f.write_str("ld.shared.u64"),
        }
    }
}

/// A data-movement instruction as swept by §7.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DataMovement {
    LdMatrix(LdMatrixNum),
    LdShared { width: LdSharedWidth, conflict_ways: u32 },
}

impl DataMovement {
    pub fn bytes_per_warp(&self) -> u64 {
        match self {
            DataMovement::LdMatrix(n) => n.bytes_per_warp(),
            DataMovement::LdShared { width, .. } => width.bytes_per_warp(),
        }
    }
}

impl fmt::Display for DataMovement {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DataMovement::LdMatrix(n) => n.fmt(f),
            DataMovement::LdShared { width, conflict_ways } => {
                write!(f, "{width} ({conflict_ways}-way)")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::shape::shapes::*;
    use super::*;

    #[test]
    fn ptx_mnemonics() {
        let i = MmaInstr::dense(AbType::Bf16, CdType::Fp32, M16N8K8);
        assert_eq!(
            i.ptx(),
            "mma.sync.aligned.m16n8k8.row.col.f32.bf16.bf16.f32"
        );
        let s = MmaInstr::sp(AbType::Fp16, CdType::Fp16, M16N8K32);
        assert!(s.ptx().starts_with("mma.sp.sync.aligned.m16n8k32"));
    }

    #[test]
    fn sparse_halves_a_footprint_plus_metadata() {
        let d = MmaInstr::dense(AbType::Fp16, CdType::Fp32, M16N8K32);
        let s = MmaInstr::sp(AbType::Fp16, CdType::Fp32, M16N8K32);
        assert_eq!(d.a_reg_bytes(), 1024); // 16x32 fp16
        // 16x16 non-zeros (512 B) + 16x32x2 bits metadata (128 B)
        assert_eq!(s.a_reg_bytes(), 512 + 128);
    }

    #[test]
    fn sparse_fma_accounting_is_dense_equivalent() {
        let s = MmaInstr::sp(AbType::Fp16, CdType::Fp32, M16N8K32);
        assert_eq!(s.fmas(), 4096); // not halved — paper Table 6 convention
    }

    #[test]
    fn well_formedness() {
        assert!(MmaInstr::dense(AbType::Tf32, CdType::Fp32, M16N8K8).is_well_formed());
        assert!(!MmaInstr::dense(AbType::Tf32, CdType::Fp16, M16N8K8).is_well_formed());
        assert!(!MmaInstr::dense(AbType::Int8, CdType::Fp32, M8N8K16).is_well_formed());
    }

    #[test]
    fn ldmatrix_bytes_match_table8() {
        assert_eq!(LdMatrixNum::X1.bytes_per_warp(), 128);
        assert_eq!(LdMatrixNum::X2.bytes_per_warp(), 256);
        assert_eq!(LdMatrixNum::X4.bytes_per_warp(), 512);
        assert_eq!(LdSharedWidth::U32.bytes_per_warp(), 128);
        assert_eq!(LdSharedWidth::U64.bytes_per_warp(), 256);
    }

    #[test]
    fn parse_spec_accepts_cli_and_url_styles() {
        let a = MmaInstr::parse_spec("bf16 f32 m16n8k16").unwrap();
        assert_eq!(a, MmaInstr::dense(AbType::Bf16, CdType::Fp32, M16N8K16));
        let b = MmaInstr::parse_spec("fp16,f32,m16n8k32,sparse").unwrap();
        assert_eq!(b, MmaInstr::sp(AbType::Fp16, CdType::Fp32, M16N8K32));
        let c = MmaInstr::parse_spec("  int8  s32  m16n8k32  sp ").unwrap();
        assert!(c.sparse);
        assert_eq!(c.ab, AbType::Int8);
    }

    #[test]
    fn parse_spec_rejects_garbage() {
        assert!(MmaInstr::parse_spec("").is_err());
        assert!(MmaInstr::parse_spec("bf16 f32").is_err());
        assert!(MmaInstr::parse_spec("qf8 f32 m16n8k16").is_err());
        assert!(MmaInstr::parse_spec("bf16 f99 m16n8k16").is_err());
        assert!(MmaInstr::parse_spec("bf16 f32 m16n8").is_err());
        assert!(MmaInstr::parse_spec("bf16 f32 m16n8k16 dense").is_err());
        assert!(MmaInstr::parse_spec("bf16 f32 m16n8k16 sparse extra").is_err());
    }

    #[test]
    fn parse_spec_trailing_token_errors_are_specific() {
        // unknown 4th token: names the token and what is accepted
        let err = MmaInstr::parse_spec("bf16 f32 m16n8k16 dense").unwrap_err();
        assert!(err.contains("dense") && err.contains("sparse"), "{err}");
        // duplicate sparse tokens get their own diagnosis
        let err = MmaInstr::parse_spec("bf16 f32 m16n8k16 sparse sparse").unwrap_err();
        assert!(err.contains("duplicate"), "{err}");
        let err = MmaInstr::parse_spec("bf16 f32 m16n8k16 sp sparse").unwrap_err();
        assert!(err.contains("duplicate"), "{err}");
        // anything else past the 4th token is a count problem
        let err = MmaInstr::parse_spec("bf16 f32 m16n8k16 sparse extra").unwrap_err();
        assert!(err.contains("too many"), "{err}");
    }

    #[test]
    fn spec_round_trips_for_every_legal_instr() {
        // proptest-style: enumerate the full (ab, cd, shape, sparse)
        // grid and require spec -> instr -> spec to be the identity on
        // every well-formed combination.
        let abs = [
            AbType::Fp16,
            AbType::Bf16,
            AbType::Tf32,
            AbType::Fp64,
            AbType::Int8,
            AbType::Int4,
            AbType::Binary,
        ];
        let cds = [CdType::Fp16, CdType::Fp32, CdType::Fp64, CdType::Int32];
        let shapes = [M16N8K4, M16N8K8, M16N8K16, M16N8K32, M16N8K64, M8N8K16, M8N8K4];
        let mut checked = 0;
        for ab in abs {
            for cd in cds {
                for shape in shapes {
                    for sparse in [false, true] {
                        let instr = MmaInstr { ab, cd, shape, sparse };
                        if !instr.is_well_formed() {
                            continue;
                        }
                        let spec = instr.to_spec();
                        let parsed = MmaInstr::parse_spec(&spec)
                            .unwrap_or_else(|e| panic!("{spec:?} failed to re-parse: {e}"));
                        assert_eq!(parsed, instr, "{spec:?}");
                        assert_eq!(parsed.to_spec(), spec);
                        checked += 1;
                    }
                }
            }
        }
        assert!(checked > 50, "grid too small ({checked})");
    }
}
