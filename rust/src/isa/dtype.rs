//! Operand and accumulator data types of Tensor-Core MMA instructions
//! (paper Tables 1 and 11).

use std::fmt;

/// Data type of the A/B input operands.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AbType {
    /// IEEE half: 1+5+10, 16-bit registers.
    Fp16,
    /// bfloat16: 1+8+7 — FP32's range, 7-bit mantissa (Ampere+).
    Bf16,
    /// TF32: 1+8+10, stored in 32-bit registers (Ampere+).
    Tf32,
    /// IEEE double on the FP64 Tensor Core path (A100 only; not swept
    /// by the paper's tables, kept for the legality matrix).
    Fp64,
    /// 8-bit integer (Turing+).
    Int8,
    /// 4-bit integer (Turing+).
    Int4,
    /// 1-bit (binary) operands, XOR+POPC semantics (Turing+).
    Binary,
}

impl AbType {
    /// Storage bits per element in the register file (Table 11: TF32
    /// occupies a full 32-bit register despite its 19 payload bits).
    pub fn storage_bits(self) -> u32 {
        match self {
            AbType::Fp16 | AbType::Bf16 => 16,
            AbType::Tf32 => 32,
            AbType::Fp64 => 64,
            AbType::Int8 => 8,
            AbType::Int4 => 4,
            AbType::Binary => 1,
        }
    }

    /// Significand bits including the implicit leading one (floats only).
    pub fn mantissa_bits(self) -> Option<u32> {
        match self {
            AbType::Fp16 | AbType::Tf32 => Some(10),
            AbType::Bf16 => Some(7),
            AbType::Fp64 => Some(52),
            _ => None,
        }
    }

    /// Exponent bits (floats only).
    pub fn exponent_bits(self) -> Option<u32> {
        match self {
            AbType::Fp16 => Some(5),
            AbType::Bf16 | AbType::Tf32 => Some(8),
            AbType::Fp64 => Some(11),
            _ => None,
        }
    }

    pub fn is_float(self) -> bool {
        matches!(self, AbType::Fp16 | AbType::Bf16 | AbType::Tf32 | AbType::Fp64)
    }

    pub fn is_integer(self) -> bool {
        !self.is_float()
    }

    /// PTX spelling used in instruction names.
    pub fn ptx(self) -> &'static str {
        match self {
            AbType::Fp16 => "f16",
            AbType::Bf16 => "bf16",
            AbType::Tf32 => "tf32",
            AbType::Fp64 => "f64",
            AbType::Int8 => "s8",
            AbType::Int4 => "s4",
            AbType::Binary => "b1",
        }
    }

    /// Human name as printed in the paper's tables.
    pub fn paper_name(self) -> &'static str {
        match self {
            AbType::Fp16 => "FP16",
            AbType::Bf16 => "BF16",
            AbType::Tf32 => "TF32",
            AbType::Fp64 => "FP64",
            AbType::Int8 => "INT8",
            AbType::Int4 => "INT4",
            AbType::Binary => "Binary",
        }
    }

    /// Canonical token in user-facing workload/instruction specs; the
    /// exact inverse of [`AbType::parse_spec`].
    pub fn spec_name(self) -> &'static str {
        match self {
            AbType::Fp16 => "fp16",
            AbType::Bf16 => "bf16",
            AbType::Tf32 => "tf32",
            AbType::Fp64 => "fp64",
            AbType::Int8 => "int8",
            AbType::Int4 => "int4",
            AbType::Binary => "binary",
        }
    }

    /// Parse one A/B-type token of an instruction/workload spec
    /// (case-insensitive; accepts both the spec and the PTX spelling).
    pub fn parse_spec(token: &str) -> Result<AbType, String> {
        match token.to_ascii_lowercase().as_str() {
            "fp16" | "f16" => Ok(AbType::Fp16),
            "bf16" => Ok(AbType::Bf16),
            "tf32" => Ok(AbType::Tf32),
            "fp64" | "f64" => Ok(AbType::Fp64),
            "int8" | "s8" => Ok(AbType::Int8),
            "int4" | "s4" => Ok(AbType::Int4),
            "binary" | "b1" => Ok(AbType::Binary),
            other => Err(format!(
                "unknown A/B type {other:?} (fp16|bf16|tf32|fp64|int8|int4|binary)"
            )),
        }
    }
}

impl fmt::Display for AbType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.paper_name())
    }
}

/// Data type of the C accumulator / D result.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CdType {
    Fp16,
    Fp32,
    Fp64,
    Int32,
}

impl CdType {
    pub fn storage_bits(self) -> u32 {
        match self {
            CdType::Fp16 => 16,
            CdType::Fp32 | CdType::Int32 => 32,
            CdType::Fp64 => 64,
        }
    }

    pub fn paper_name(self) -> &'static str {
        match self {
            CdType::Fp16 => "FP16",
            CdType::Fp32 => "FP32",
            CdType::Fp64 => "FP64",
            CdType::Int32 => "INT32",
        }
    }

    /// Canonical token in user-facing workload/instruction specs; the
    /// exact inverse of [`CdType::parse_spec`].
    pub fn spec_name(self) -> &'static str {
        match self {
            CdType::Fp16 => "f16",
            CdType::Fp32 => "f32",
            CdType::Fp64 => "f64",
            CdType::Int32 => "s32",
        }
    }

    /// Parse one C/D-type token of an instruction/workload spec.
    pub fn parse_spec(token: &str) -> Result<CdType, String> {
        match token.to_ascii_lowercase().as_str() {
            "fp16" | "f16" => Ok(CdType::Fp16),
            "fp32" | "f32" => Ok(CdType::Fp32),
            "fp64" | "f64" => Ok(CdType::Fp64),
            "int32" | "s32" => Ok(CdType::Int32),
            other => Err(format!("unknown C/D type {other:?} (f16|f32|f64|s32)")),
        }
    }

    /// Is `self` a legal accumulator for the given operand type?
    /// (PTX ISA: float ops accumulate in FP16/FP32, FP64 in FP64,
    /// integer/binary ops in INT32.)
    pub fn legal_for(self, ab: AbType) -> bool {
        match ab {
            AbType::Fp16 => matches!(self, CdType::Fp16 | CdType::Fp32),
            AbType::Bf16 | AbType::Tf32 => matches!(self, CdType::Fp32),
            AbType::Fp64 => matches!(self, CdType::Fp64),
            AbType::Int8 | AbType::Int4 | AbType::Binary => matches!(self, CdType::Int32),
        }
    }
}

impl fmt::Display for CdType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.paper_name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn storage_bits_match_table11() {
        assert_eq!(AbType::Fp16.storage_bits(), 16);
        assert_eq!(AbType::Bf16.storage_bits(), 16);
        assert_eq!(AbType::Tf32.storage_bits(), 32); // 19 payload, 32 stored
        assert_eq!(AbType::Int4.storage_bits(), 4);
        assert_eq!(AbType::Binary.storage_bits(), 1);
    }

    #[test]
    fn mantissa_bits_match_table11() {
        assert_eq!(AbType::Fp16.mantissa_bits(), Some(10));
        assert_eq!(AbType::Tf32.mantissa_bits(), Some(10));
        assert_eq!(AbType::Bf16.mantissa_bits(), Some(7));
        assert_eq!(AbType::Int8.mantissa_bits(), None);
    }

    #[test]
    fn bf16_tf32_share_fp32_exponent() {
        assert_eq!(AbType::Bf16.exponent_bits(), Some(8));
        assert_eq!(AbType::Tf32.exponent_bits(), Some(8));
        assert_eq!(AbType::Fp16.exponent_bits(), Some(5));
    }

    #[test]
    fn accumulator_legality() {
        assert!(CdType::Fp32.legal_for(AbType::Fp16));
        assert!(CdType::Fp16.legal_for(AbType::Fp16));
        assert!(!CdType::Fp16.legal_for(AbType::Bf16)); // BF16 needs FP32 C/D
        assert!(CdType::Int32.legal_for(AbType::Binary));
        assert!(!CdType::Fp32.legal_for(AbType::Int8));
    }

    #[test]
    fn float_integer_split() {
        assert!(AbType::Tf32.is_float());
        assert!(AbType::Binary.is_integer());
    }

    #[test]
    fn spec_tokens_round_trip() {
        for ab in [
            AbType::Fp16,
            AbType::Bf16,
            AbType::Tf32,
            AbType::Fp64,
            AbType::Int8,
            AbType::Int4,
            AbType::Binary,
        ] {
            assert_eq!(AbType::parse_spec(ab.spec_name()), Ok(ab));
        }
        for cd in [CdType::Fp16, CdType::Fp32, CdType::Fp64, CdType::Int32] {
            assert_eq!(CdType::parse_spec(cd.spec_name()), Ok(cd));
        }
        // PTX spellings are accepted too; garbage is not
        assert_eq!(AbType::parse_spec("S8"), Ok(AbType::Int8));
        assert_eq!(CdType::parse_spec("INT32"), Ok(CdType::Int32));
        assert!(AbType::parse_spec("qf8").is_err());
        assert!(CdType::parse_spec("f99").is_err());
    }
}
