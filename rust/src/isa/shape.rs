//! `mma` operand shapes (`mMnNkK` segments, paper Fig. 5/8).

use std::fmt;
use std::str::FromStr;

/// The `m16n8k16`-style shape segment of an `mma`/`mma.sp` instruction:
/// A is `m x k`, B is `k x n`, C/D are `m x n`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MmaShape {
    pub m: u32,
    pub n: u32,
    pub k: u32,
}

impl MmaShape {
    pub const fn new(m: u32, n: u32, k: u32) -> Self {
        Self { m, n, k }
    }

    /// FMA count of one instruction: an `m x n x k` matrix multiplication
    /// counts as `m*n*k` FMAs (paper §4). For `mma.sp` the FMA accounting
    /// uses the *dense-equivalent* k — the paper reports sparse
    /// throughput that way (Table 6 reaches ~2x the dense peak).
    pub fn fmas(&self) -> u64 {
        self.m as u64 * self.n as u64 * self.k as u64
    }

    /// Bytes of A at the given element width, dense storage.
    pub fn a_bytes(&self, elem_bits: u32) -> u64 {
        (self.m as u64 * self.k as u64 * elem_bits as u64) / 8
    }

    /// Bytes of B at the given element width.
    pub fn b_bytes(&self, elem_bits: u32) -> u64 {
        (self.k as u64 * self.n as u64 * elem_bits as u64) / 8
    }

    /// Bytes of C/D at the given element width.
    pub fn cd_bytes(&self, elem_bits: u32) -> u64 {
        (self.m as u64 * self.n as u64 * elem_bits as u64) / 8
    }
}

impl fmt::Display for MmaShape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "m{}n{}k{}", self.m, self.n, self.k)
    }
}

/// Parse `"m16n8k16"` (as printed in the paper's tables).
impl FromStr for MmaShape {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let err = || format!("invalid mma shape {s:?} (expected mMnNkK)");
        let rest = s.strip_prefix('m').ok_or_else(err)?;
        let (m, rest) = rest.split_once('n').ok_or_else(err)?;
        let (n, k) = rest.split_once('k').ok_or_else(err)?;
        Ok(MmaShape {
            m: m.parse().map_err(|_| err())?,
            n: n.parse().map_err(|_| err())?,
            k: k.parse().map_err(|_| err())?,
        })
    }
}

/// Common shapes from the paper's tables.
pub mod shapes {
    use super::MmaShape;

    pub const M16N8K16: MmaShape = MmaShape::new(16, 8, 16);
    pub const M16N8K8: MmaShape = MmaShape::new(16, 8, 8);
    pub const M16N8K4: MmaShape = MmaShape::new(16, 8, 4);
    pub const M8N8K16: MmaShape = MmaShape::new(8, 8, 16);
    pub const M8N8K4: MmaShape = MmaShape::new(8, 8, 4);
    pub const M16N8K32: MmaShape = MmaShape::new(16, 8, 32);
    pub const M16N8K64: MmaShape = MmaShape::new(16, 8, 64);
    pub const M16N8K128: MmaShape = MmaShape::new(16, 8, 128);
    pub const M16N8K256: MmaShape = MmaShape::new(16, 8, 256);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in ["m16n8k16", "m8n8k4", "m16n8k256"] {
            let shape: MmaShape = s.parse().unwrap();
            assert_eq!(shape.to_string(), s);
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!("16n8k16".parse::<MmaShape>().is_err());
        assert!("m16n8".parse::<MmaShape>().is_err());
        assert!("m16nXk8".parse::<MmaShape>().is_err());
    }

    #[test]
    fn fma_accounting() {
        // paper §4: m x n x k MM counts as m*n*k FMAs
        assert_eq!(MmaShape::new(16, 8, 16).fmas(), 2048);
        assert_eq!(MmaShape::new(16, 8, 8).fmas(), 1024);
        assert_eq!(MmaShape::new(16, 8, 256).fmas(), 32768);
    }

    #[test]
    fn byte_accounting() {
        let s = MmaShape::new(16, 8, 16);
        assert_eq!(s.a_bytes(16), 512); // 16x16 fp16
        assert_eq!(s.b_bytes(16), 256);
        assert_eq!(s.cd_bytes(32), 512); // 16x8 fp32
    }
}
