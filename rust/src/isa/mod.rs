//! PTX-level instruction model for Tensor-Core GPUs.
//!
//! Covers the three instruction families the paper microbenchmarks —
//! `mma` (§5), `mma.sp` (§6) and the data-movement family `ldmatrix` /
//! `ld.shared` (§7) — plus `cp.async` for the Appendix-A pipeline
//! ablation. The module owns *semantics-level* facts: operand shapes,
//! data types, FMA and byte accounting, and the per-architecture
//! legality matrix (paper Tables 1 and 3–7).

mod dtype;
mod instruction;
mod shape;

pub use dtype::{AbType, CdType};
pub use instruction::{
    DataMovement, LdMatrixNum, LdSharedWidth, MmaInstr, MMA_FULL_THROUGHPUT,
};
pub use shape::{shapes, MmaShape};
