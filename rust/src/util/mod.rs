//! In-repo substrates for what would normally be external crates — the
//! build is fully offline, so the PRNG, JSON handling, CLI parsing and
//! the micro-bench harness are implemented from scratch here.

pub mod bench;
pub mod json;
pub mod prng;

pub use bench::Bencher;
pub use json::Json;
pub use prng::Prng;
