//! In-repo substrates for what would normally be external crates — the
//! build is fully offline, so the PRNG, JSON handling, CLI parsing and
//! the micro-bench harness are implemented from scratch here.

pub mod bench;
pub mod json;
pub mod prng;

pub use bench::Bencher;
pub use json::Json;
pub use prng::Prng;

/// 64-bit FNV-1a — the content-address hash shared by the tcserved
/// result cache and the in-process cell cache (stable, dependency-free).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::fnv1a;

    #[test]
    fn fnv1a_is_stable_and_distinct() {
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), fnv1a(b"a"));
        assert_ne!(fnv1a(b"a"), fnv1a(b"b"));
        assert_ne!(fnv1a(b"ab"), fnv1a(b"ba"));
    }
}
