//! Minimal JSON parser/serializer (enough for `artifacts/manifest.json`
//! and the coordinator's result files; no external crates available).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|n| n as u64)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// `get(key)` then `as_str`.
    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Json::as_str)
    }

    /// `get(key)` then `as_f64`.
    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Json::as_f64)
    }

    /// `get(key)` then `as_u64`.
    pub fn get_u64(&self, key: &str) -> Option<u64> {
        self.get(key).and_then(Json::as_u64)
    }

    /// Two-space-indented rendering (for files meant to be read by
    /// humans: `summary.json`, the on-disk result cache).
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.pretty_into(&mut out, 0);
        out.push('\n');
        out
    }

    fn pretty_into(&self, out: &mut String, depth: usize) {
        let pad = "  ".repeat(depth + 1);
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    out.push_str(&pad);
                    v.pretty_into(out, depth + 1);
                    if i + 1 < a.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&"  ".repeat(depth));
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    out.push_str(&pad);
                    out.push_str(&Json::Str(k.clone()).to_string());
                    out.push_str(": ");
                    v.pretty_into(out, depth + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&"  ".repeat(depth));
                out.push('}');
            }
            other => out.push_str(&other.to_string()),
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b.get(self.i..self.i + 4).ok_or("bad \\u")?,
                            )
                            .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(c) => {
                    // copy raw UTF-8 bytes through
                    let start = self.i;
                    let len = utf8_len(c);
                    self.i += len;
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "bad utf8")?,
                    );
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                f.write_str("\"")?;
                for c in s.chars() {
                    match c {
                        '"' => f.write_str("\\\"")?,
                        '\\' => f.write_str("\\\\")?,
                        '\n' => f.write_str("\\n")?,
                        '\t' => f.write_str("\\t")?,
                        '\r' => f.write_str("\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                f.write_str("\"")
            }
            Json::Arr(a) => {
                f.write_str("[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    v.fmt(f)?;
                }
                f.write_str("]")
            }
            Json::Obj(m) => {
                f.write_str("{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                f.write_str("}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let s = r#"{
            "tcmma_bf16_f32_m16n8k8": {
                "file": "tcmma_bf16_f32_m16n8k8.hlo.txt",
                "ab": "bf16", "cd": "f32", "acc_rnd": "rz",
                "m": 16, "n": 8, "k": 8, "batch": 256
            }
        }"#;
        let j = Json::parse(s).unwrap();
        let e = j.get("tcmma_bf16_f32_m16n8k8").unwrap();
        assert_eq!(e.get("ab").unwrap().as_str(), Some("bf16"));
        assert_eq!(e.get("k").unwrap().as_u64(), Some(8));
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"a":[1,2.5,-3],"b":"x\ny","c":true,"d":null}"#,
            "[]",
            "{}",
            "-1.25e-3",
        ];
        for c in cases {
            let j = Json::parse(c).unwrap();
            let again = Json::parse(&j.to_string()).unwrap();
            assert_eq!(j, again, "{c}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn pretty_round_trips() {
        let j = Json::parse(r#"{"a":[1,2],"b":{"c":"x"},"d":[],"e":{}}"#).unwrap();
        let pretty = j.pretty();
        assert!(pretty.contains("\n  \"a\": [\n"));
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn typed_getters() {
        let j = Json::parse(r#"{"s":"x","n":2.5,"b":true}"#).unwrap();
        assert_eq!(j.get_str("s"), Some("x"));
        assert_eq!(j.get_f64("n"), Some(2.5));
        assert_eq!(j.get_u64("n"), Some(2));
        assert_eq!(j.get("b").and_then(Json::as_bool), Some(true));
        assert_eq!(j.get_str("missing"), None);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }
}
