//! Deterministic PRNG (xoshiro256**) with uniform/normal helpers.
//!
//! The paper's §8 experiments draw from N(0, 1) with a fixed seed so the
//! same sequence initializes every data type; this PRNG provides that
//! reproducibility on the Rust side (Box–Muller for normals).

/// xoshiro256** — fast, high-quality, no dependencies.
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    spare: Option<f64>,
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Self { s, spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let [s0, s1, s2, s3] = self.s;
        let result = s1.wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s1 << 17;
        let mut s = [s0, s1, s2, s3];
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        self.s = s;
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // multiply-shift; bias is negligible for our n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal (Box–Muller), f64.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Standard normal rounded to f32 (the experiments' native type).
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a buffer with N(0,1) f32 samples.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out {
            *v = self.normal_f32();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Prng::new(7);
        let mut b = Prng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Prng::new(8);
        assert_ne!(Prng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut p = Prng::new(1);
        for _ in 0..10_000 {
            let u = p.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(42);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = p.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut p = Prng::new(3);
        for _ in 0..10_000 {
            assert!(p.below(17) < 17);
        }
    }
}
