//! Tiny benchmark harness (criterion is not available offline).
//!
//! `cargo bench` targets use [`Bencher`]: warm-up, timed repetitions,
//! median/mean/min reporting, and an optional baseline file so the §Perf
//! optimization pass can track before/after across runs.

use std::hint::black_box;
use std::time::{Duration, Instant};

/// Statistics of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub samples: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
}

impl BenchStats {
    pub fn mean_ns(&self) -> f64 {
        self.mean.as_secs_f64() * 1e9
    }
}

/// Micro-bench runner. Prints one line per benchmark in a stable,
/// greppable format:
/// `bench <name> ... mean 1.234ms  median 1.200ms  min 1.180ms  (N=30)`
pub struct Bencher {
    /// Minimum wall time to spend measuring each benchmark.
    pub budget: Duration,
    /// Maximum samples per benchmark.
    pub max_samples: usize,
    results: Vec<BenchStats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { budget: Duration::from_millis(900), max_samples: 61, results: Vec::new() }
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_secs_f64() * 1e9;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_budget(mut self, budget: Duration) -> Self {
        self.budget = budget;
        self
    }

    /// Time `f`, returning (and printing) its stats. The closure's result
    /// is passed through `black_box` so the work is not optimized away.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> BenchStats {
        // Warm-up: one untimed call.
        black_box(f());
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while samples.len() < self.max_samples
            && (samples.len() < 5 || start.elapsed() < self.budget)
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort_unstable();
        let n = samples.len();
        let mean = samples.iter().sum::<Duration>() / n as u32;
        let stats = BenchStats {
            name: name.to_string(),
            samples: n,
            mean,
            median: samples[n / 2],
            min: samples[0],
        };
        println!(
            "bench {name:<48} mean {:>10}  median {:>10}  min {:>10}  (N={n})",
            fmt_dur(stats.mean),
            fmt_dur(stats.median),
            fmt_dur(stats.min),
        );
        self.results.push(stats.clone());
        stats
    }

    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let mut b = Bencher::new().with_budget(Duration::from_millis(20));
        let stats = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(stats.samples >= 5);
        assert!(stats.min > Duration::ZERO);
        assert!(stats.min <= stats.median && stats.median <= stats.mean * 3);
    }

    #[test]
    fn results_accumulate() {
        let mut b = Bencher::new().with_budget(Duration::from_millis(5));
        b.bench("a", || 1 + 1);
        b.bench("b", || 2 + 2);
        assert_eq!(b.results().len(), 2);
    }
}
