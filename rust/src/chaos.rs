//! tcchaos — seeded, deterministic fault injection for tcserved.
//!
//! A [`FaultPlan`] is parsed from a compact spec grammar, one clause per
//! fault, `site:kind[=value]@probability`:
//!
//! ```text
//! store.read:err@0.05,store.read:delay_ms=50@0.1,sim:panic@0.01,queue:full@0.02
//! ```
//!
//! Sites are the three seams the serving stack already treats as
//! fallible, so every injected fault exercises a *real* recovery path:
//!
//! | site         | kinds                 | effect when drawn                        |
//! |--------------|-----------------------|------------------------------------------|
//! | `store.read` | `err`, `delay_ms=N`   | cell-store load fails (counted miss) / stalls |
//! | `sim`        | `panic`, `delay_ms=N` | unit computation panics (typed `internal`) / stalls |
//! | `queue`      | `full`                | accept queue sheds the connection (503)  |
//!
//! Draws come from a single seeded PRNG stream shared across worker
//! threads: the *sequence* of draws is deterministic for a given seed;
//! which request observes which draw depends on thread interleaving.
//! Every injected fault is counted per `site:kind` and exported under
//! the `chaos` section of `/v1/metrics` (JSON and Prometheus) so tests
//! can assert injection actually happened.
//!
//! Injection is process-global and **off by default**: nothing is
//! installed unless `repro serve --chaos <spec>` calls [`install`], and
//! the call sites cost one `OnceLock::get` when disabled.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

use crate::util::Prng;

/// An injection seam in the serving stack.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Site {
    /// `CellStore::load` — the disk-tier read path.
    StoreRead,
    /// The worker-pool unit boundary, inside the request `catch_unwind`.
    Sim,
    /// The accept queue in front of the worker pool.
    Queue,
}

impl Site {
    fn name(self) -> &'static str {
        match self {
            Site::StoreRead => "store.read",
            Site::Sim => "sim",
            Site::Queue => "queue",
        }
    }
}

#[derive(Clone, Copy, PartialEq, Debug)]
enum Kind {
    Err,
    DelayMs(u64),
    Panic,
    Full,
}

impl Kind {
    fn label(self) -> &'static str {
        match self {
            Kind::Err => "err",
            Kind::DelayMs(_) => "delay_ms",
            Kind::Panic => "panic",
            Kind::Full => "full",
        }
    }
}

/// A failure drawn at an injection site. Delay faults never surface
/// here — [`inject`] serves them in place (the call itself sleeps), so
/// call sites only see the kinds they must act on.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Failure {
    /// Fail the store read as if the entry were corrupt/unreadable.
    StoreReadErr,
    /// Panic the unit computation (must die inside `catch_unwind`).
    SimPanic,
    /// Treat the accept queue as saturated: shed with 503.
    QueueFull,
}

#[derive(Clone, Copy, Debug)]
struct Fault {
    site: Site,
    kind: Kind,
    prob: f64,
}

/// A parsed, validated chaos spec.
#[derive(Debug, Default)]
pub struct FaultPlan {
    faults: Vec<Fault>,
}

impl FaultPlan {
    /// Parse the `site:kind[=value]@probability[,…]` grammar. Rejects
    /// unknown sites/kinds, kind/site mismatches, and probabilities
    /// outside `(0, 1]` — a chaos spec typo must fail startup, not
    /// silently inject nothing.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut faults = Vec::new();
        for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            let (head, prob) = clause
                .rsplit_once('@')
                .ok_or_else(|| format!("chaos clause '{clause}': missing '@probability'"))?;
            let prob: f64 = prob
                .parse()
                .map_err(|_| format!("chaos clause '{clause}': bad probability '{prob}'"))?;
            if !(prob > 0.0 && prob <= 1.0) {
                return Err(format!("chaos clause '{clause}': probability must be in (0, 1]"));
            }
            let (site, kind) = head
                .split_once(':')
                .ok_or_else(|| format!("chaos clause '{clause}': expected 'site:kind'"))?;
            let site = match site {
                "store.read" => Site::StoreRead,
                "sim" => Site::Sim,
                "queue" => Site::Queue,
                _ => {
                    return Err(format!(
                        "chaos clause '{clause}': unknown site '{site}' (store.read|sim|queue)"
                    ))
                }
            };
            let kind = if let Some(ms) = kind.strip_prefix("delay_ms=") {
                Kind::DelayMs(
                    ms.parse()
                        .map_err(|_| format!("chaos clause '{clause}': bad delay '{ms}'"))?,
                )
            } else {
                match kind {
                    "err" => Kind::Err,
                    "panic" => Kind::Panic,
                    "full" => Kind::Full,
                    _ => {
                        return Err(format!(
                            "chaos clause '{clause}': unknown kind '{kind}' \
                             (err|delay_ms=N|panic|full)"
                        ))
                    }
                }
            };
            let valid = matches!(
                (site, kind),
                (Site::StoreRead, Kind::Err | Kind::DelayMs(_))
                    | (Site::Sim, Kind::Panic | Kind::DelayMs(_))
                    | (Site::Queue, Kind::Full)
            );
            if !valid {
                return Err(format!(
                    "chaos clause '{clause}': kind '{}' is not valid for site '{}'",
                    kind.label(),
                    site.name()
                ));
            }
            faults.push(Fault { site, kind, prob });
        }
        if faults.is_empty() {
            return Err("chaos spec is empty".into());
        }
        Ok(FaultPlan { faults })
    }
}

/// Injection counters, as exported under `/v1/metrics`'s `chaos` section.
#[derive(Debug, Clone)]
pub struct ChaosStats {
    pub spec: String,
    pub seed: u64,
    pub injected_total: u64,
    /// Per-fault counts keyed `site:kind`, sorted by key.
    pub by_fault: Vec<(String, u64)>,
}

struct Chaos {
    spec: String,
    seed: u64,
    plan: FaultPlan,
    prng: Mutex<Prng>,
    injected_total: AtomicU64,
    by_fault: Mutex<BTreeMap<String, u64>>,
}

impl Chaos {
    fn new(spec: String, seed: u64, plan: FaultPlan) -> Self {
        Chaos {
            spec,
            seed,
            plan,
            prng: Mutex::new(Prng::new(seed)),
            injected_total: AtomicU64::new(0),
            by_fault: Mutex::new(BTreeMap::new()),
        }
    }

    fn count(&self, f: &Fault) {
        self.injected_total.fetch_add(1, Ordering::Relaxed);
        let key = format!("{}:{}", f.site.name(), f.kind.label());
        // A poisoned counter lock only means another thread panicked
        // mid-increment; the map itself is never left inconsistent.
        let mut map = self.by_fault.lock().unwrap_or_else(|e| e.into_inner());
        *map.entry(key).or_insert(0) += 1;
    }

    fn inject(&self, site: Site) -> Option<Failure> {
        let mut failure = None;
        for f in self.plan.faults.iter().filter(|f| f.site == site) {
            let hit = {
                let mut prng = self.prng.lock().unwrap_or_else(|e| e.into_inner());
                prng.uniform() < f.prob
            };
            if !hit {
                continue;
            }
            self.count(f);
            match f.kind {
                Kind::DelayMs(ms) => std::thread::sleep(Duration::from_millis(ms)),
                Kind::Err => failure = failure.or(Some(Failure::StoreReadErr)),
                Kind::Panic => failure = failure.or(Some(Failure::SimPanic)),
                Kind::Full => failure = failure.or(Some(Failure::QueueFull)),
            }
        }
        failure
    }

    fn stats(&self) -> ChaosStats {
        let by_fault = self
            .by_fault
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .map(|(k, v)| (k.clone(), *v))
            .collect();
        ChaosStats {
            spec: self.spec.clone(),
            seed: self.seed,
            injected_total: self.injected_total.load(Ordering::Relaxed),
            by_fault,
        }
    }
}

static CHAOS: OnceLock<Chaos> = OnceLock::new();

/// Install the process-global fault plan. Called once at server startup
/// (`repro serve --chaos <spec> --chaos-seed N`); a second install is an
/// error rather than a silent swap, so a running server's fault plan can
/// never change underneath an experiment.
pub fn install(spec: &str, seed: u64) -> Result<(), String> {
    let plan = FaultPlan::parse(spec)?;
    CHAOS
        .set(Chaos::new(spec.to_string(), seed, plan))
        .map_err(|_| "chaos plan already installed".to_string())
}

/// Is fault injection active in this process?
pub fn enabled() -> bool {
    CHAOS.get().is_some()
}

/// Draw faults for `site`. Delay faults are served in place (this call
/// sleeps); at most one failure kind is returned, in spec order. Free
/// (one `OnceLock::get`) when chaos is not installed.
pub fn inject(site: Site) -> Option<Failure> {
    CHAOS.get()?.inject(site)
}

/// Injection counters for `/v1/metrics`; `None` when chaos is off.
pub fn stats() -> Option<ChaosStats> {
    CHAOS.get().map(Chaos::stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_documented_grammar() {
        let plan = FaultPlan::parse(
            "store.read:err@0.05,store.read:delay_ms=50@0.1,sim:panic@0.01,queue:full@0.02",
        )
        .unwrap();
        assert_eq!(plan.faults.len(), 4);
        assert_eq!(plan.faults[1].kind, Kind::DelayMs(50));
        assert_eq!(plan.faults[3].site, Site::Queue);
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "",
            "store.read:err",          // missing probability
            "store.read:err@1.5",      // out of range
            "store.read:err@0",        // zero never fires: reject loudly
            "store.read:err@x",        // unparseable probability
            "disk:err@0.5",            // unknown site
            "store.read:panic@0.5",    // kind/site mismatch
            "sim:err@0.5",             // kind/site mismatch
            "queue:delay_ms=10@0.5",   // kind/site mismatch
            "store.read:delay_ms=x@0.5",
            "sim@0.5",                 // no kind
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "spec '{bad}' must be rejected");
        }
    }

    #[test]
    fn draw_sequence_is_deterministic_per_seed() {
        let plan = || FaultPlan::parse("store.read:err@0.3,queue:full@0.2").unwrap();
        let a = Chaos::new("spec".into(), 7, plan());
        let b = Chaos::new("spec".into(), 7, plan());
        let draws = |c: &Chaos| -> Vec<Option<Failure>> {
            (0..200)
                .map(|i| c.inject(if i % 2 == 0 { Site::StoreRead } else { Site::Queue }))
                .collect()
        };
        assert_eq!(draws(&a), draws(&b));
        assert!(a.stats().injected_total > 0, "p=0.3 over 100 draws must fire");
        assert_eq!(a.stats().injected_total, b.stats().injected_total);
    }

    #[test]
    fn counts_per_fault_and_in_total() {
        let plan = FaultPlan::parse("store.read:err@1,sim:panic@1").unwrap();
        let c = Chaos::new("spec".into(), 1, plan);
        assert_eq!(c.inject(Site::StoreRead), Some(Failure::StoreReadErr));
        assert_eq!(c.inject(Site::Sim), Some(Failure::SimPanic));
        assert_eq!(c.inject(Site::Queue), None, "no queue fault in this plan");
        let s = c.stats();
        assert_eq!(s.injected_total, 2);
        assert_eq!(
            s.by_fault,
            vec![("sim:panic".to_string(), 1), ("store.read:err".to_string(), 1)]
        );
    }

    #[test]
    fn probability_one_always_fires_and_zero_probability_is_rejected() {
        let plan = FaultPlan::parse("queue:full@1.0").unwrap();
        let c = Chaos::new("spec".into(), 9, plan);
        for _ in 0..50 {
            assert_eq!(c.inject(Site::Queue), Some(Failure::QueueFull));
        }
    }
}
