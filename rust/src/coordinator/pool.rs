//! Worker pool: run a batch of independent jobs across threads with a
//! shared work queue (no external crates; scoped threads + atomics).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of workers to use by default.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Run every job, in parallel, preserving output order.
///
/// Jobs are pulled from a shared atomic cursor so long jobs do not
/// stall the queue (the coordinator's sweeps vary 100x in cost).
pub fn run_parallel<T, F>(jobs: Vec<F>, threads: usize) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return jobs.into_iter().map(|f| f()).collect();
    }
    let jobs: Vec<Mutex<Option<F>>> = jobs.into_iter().map(|f| Mutex::new(Some(f))).collect();
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let job = jobs[i].lock().unwrap().take().expect("job taken twice");
                let out = job();
                *results[i].lock().unwrap() = Some(out);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("missing result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<_> = (0..100).map(|i| move || i * 2).collect();
        let out = run_parallel(jobs, 8);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_fallback() {
        let jobs: Vec<_> = (0..5).map(|i| move || i).collect();
        assert_eq!(run_parallel(jobs, 1), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn empty_is_fine() {
        let jobs: Vec<Box<dyn FnOnce() -> i32 + Send>> = vec![];
        assert!(run_parallel(jobs, 4).is_empty());
    }

    #[test]
    fn uneven_work_balances() {
        let jobs: Vec<_> = (0..32)
            .map(|i| {
                move || {
                    // one job 100x the others
                    let spins = if i == 0 { 2_000_000 } else { 20_000 };
                    let mut acc = 0u64;
                    for j in 0..spins {
                        acc = acc.wrapping_add(j);
                    }
                    acc
                }
            })
            .collect();
        let out = run_parallel(jobs, 8);
        assert_eq!(out.len(), 32);
    }
}
