//! Campaign coordinator: every paper table/figure is a registered
//! experiment; a worker pool runs simulator jobs in parallel; results
//! are rendered with paper-vs-measured columns and optionally persisted
//! under `results/`.

mod experiments;
mod pool;

pub use pool::{default_threads, run_parallel};

use anyhow::Result;

use crate::runtime::ArtifactStore;

/// Numeric-experiment backend: the native softfloat datapath or the
/// PJRT-executed AOT artifacts (L1/L2). Both produce identical numbers —
/// integration tests assert it — so the campaign defaults to whichever
/// is available.
pub enum Backend {
    Native,
    Pjrt(ArtifactStore),
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Pjrt(_) => "pjrt",
        }
    }

    /// Prefer PJRT artifacts when present, else native.
    pub fn auto() -> Backend {
        match ArtifactStore::open_default() {
            Ok(store) => Backend::Pjrt(store),
            Err(_) => Backend::Native,
        }
    }
}

/// A registered experiment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExperimentId {
    pub id: &'static str,
    pub description: &'static str,
    /// Needs a numeric backend (vs pure-simulator experiments).
    pub numeric: bool,
}

/// Every table and figure of the paper's evaluation (DESIGN.md §3).
pub const EXPERIMENTS: &[ExperimentId] = &[
    ExperimentId { id: "fig6", description: "mma.m16n8k16 sweep on A100", numeric: false },
    ExperimentId { id: "fig7", description: "mma.m16n8k8 sweep on A100", numeric: false },
    ExperimentId { id: "t3", description: "dense mma table, A100", numeric: false },
    ExperimentId { id: "t4", description: "dense mma table, RTX3070Ti", numeric: false },
    ExperimentId { id: "t5", description: "dense mma table, RTX2080Ti", numeric: false },
    ExperimentId { id: "fig10", description: "mma.sp.m16n8k32 sweep on A100", numeric: false },
    ExperimentId { id: "fig11", description: "mma.sp.m16n8k16 sweep (small-k anomaly)", numeric: false },
    ExperimentId { id: "t6", description: "sparse mma table, A100", numeric: false },
    ExperimentId { id: "t7", description: "sparse mma table, RTX3070Ti", numeric: false },
    ExperimentId { id: "fig15", description: "ldmatrix.x4 sweep on A100", numeric: false },
    ExperimentId { id: "t9", description: "ldmatrix table, A100", numeric: false },
    ExperimentId { id: "t10", description: "ld.shared bank-conflict latency", numeric: false },
    ExperimentId { id: "t12", description: "BF16 numeric profiling", numeric: true },
    ExperimentId { id: "t13", description: "FP16 (C/D=FP32) numeric profiling", numeric: true },
    ExperimentId { id: "t14", description: "FP16 (C/D=FP16) numeric profiling", numeric: true },
    ExperimentId { id: "t15", description: "TF32 numeric profiling", numeric: true },
    ExperimentId { id: "fig17", description: "chain matmul relative error", numeric: true },
    ExperimentId { id: "t16", description: "sync vs cp.async GEMM (Appendix A.1)", numeric: false },
    ExperimentId { id: "t17", description: "naive vs permuted layout (Appendix A.2)", numeric: false },
];

/// Run one experiment by id, returning the rendered report.
pub fn run_experiment(id: &str, backend: &mut Backend) -> Result<String> {
    let report = match id {
        "t3" => experiments::run_table3(),
        "t4" => experiments::run_table4(),
        "t5" => experiments::run_table5(),
        "t6" => experiments::run_table6(),
        "t7" => experiments::run_table7(),
        "t9" => experiments::run_table9(),
        "t10" => experiments::run_table10(),
        "t12" => experiments::run_table12(backend),
        "t13" => experiments::run_table13(backend),
        "t14" => experiments::run_table14(backend),
        "t15" => experiments::run_table15(backend),
        "t16" => experiments::run_table16(),
        "t17" => experiments::run_table17(),
        "fig6" => experiments::run_fig6(),
        "fig7" => experiments::run_fig7(),
        "fig10" => experiments::run_fig10(),
        "fig11" => experiments::run_fig11(),
        "fig15" => experiments::run_fig15(),
        "fig17" => experiments::run_fig17(backend),
        other => anyhow::bail!(
            "unknown experiment {other:?}; known: {}",
            EXPERIMENTS.iter().map(|e| e.id).collect::<Vec<_>>().join(", ")
        ),
    };
    Ok(report)
}

/// Run the whole campaign; returns (id, report) pairs in registry order.
pub fn run_all(backend: &mut Backend) -> Result<Vec<(&'static str, String)>> {
    let mut out = Vec::new();
    for e in EXPERIMENTS {
        let report = run_experiment(e.id, backend)?;
        out.push((e.id, report));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_all_paper_artifacts() {
        let ids: Vec<&str> = EXPERIMENTS.iter().map(|e| e.id).collect();
        for want in [
            "fig6", "fig7", "fig10", "fig11", "fig15", "fig17", "t3", "t4", "t5", "t6",
            "t7", "t9", "t10", "t12", "t13", "t14", "t15", "t16", "t17",
        ] {
            assert!(ids.contains(&want), "{want} missing");
        }
        assert_eq!(ids.len(), 19);
    }

    #[test]
    fn unknown_experiment_errors() {
        let mut b = Backend::Native;
        assert!(run_experiment("t99", &mut b).is_err());
    }

    #[test]
    fn table5_runs_quickly_and_mentions_turing_rows() {
        let mut b = Backend::Native;
        let r = run_experiment("t5", &mut b).unwrap();
        assert!(r.contains("m16n8k8"));
        assert!(r.contains("INT8"));
    }

    #[test]
    fn table10_deviations_small() {
        let mut b = Backend::Native;
        let r = run_experiment("t10", &mut b).unwrap();
        // every deviation row within a few percent
        for line in r.lines().skip(2) {
            if let Some(dev) = line.split('|').next_back() {
                let dev = dev.trim().trim_start_matches('+').trim_end_matches('%');
                if let Ok(pct) = dev.parse::<f64>() {
                    assert!(pct.abs() < 6.0, "line {line}");
                }
            }
        }
    }

    #[test]
    fn numeric_table_on_native_backend() {
        let mut b = Backend::Native;
        let r = run_experiment("t13", &mut b).unwrap();
        assert!(r.contains("multiplication"));
        assert!(r.contains("0.00e0"), "init_fp16 rows must be exactly zero:\n{r}");
    }
}
