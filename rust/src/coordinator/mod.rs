//! Campaign coordinator: every paper table/figure is a registered
//! experiment; a worker pool runs simulator jobs in parallel; results
//! are rendered with paper-vs-measured columns and optionally persisted
//! under `results/`.

mod experiments;
mod pool;

pub use experiments::experiment_plans;
pub use pool::{default_threads, run_parallel};

use anyhow::{bail, Result};

use crate::runtime::ArtifactStore;
use crate::workload::{LintRecord, Runner};

/// Requested numeric backend, parsed from a CLI flag or an HTTP query
/// parameter. `Copy` + `Send`, so per-request jobs can carry it into
/// worker threads and construct the actual [`Runner`] where it runs
/// ([`crate::workload::runner_for`]) — the tcserved request path and
/// the parallel campaign both rely on this.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Native,
    Pjrt,
    /// PJRT when artifacts are available, native otherwise.
    Auto,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "native" => Ok(BackendKind::Native),
            "pjrt" => Ok(BackendKind::Pjrt),
            "auto" => Ok(BackendKind::Auto),
            other => bail!("unknown backend {other:?} (native|pjrt|auto)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
            BackendKind::Auto => "auto",
        }
    }

    /// Resolve `Auto` to the concrete backend it would use *right now*
    /// (a cheap artifact-availability stat, not a full store open);
    /// `Native`/`Pjrt` pass through. tcserved keys its result cache on
    /// the resolved kind so `?backend=auto` shares content addresses
    /// with the backend that actually runs, instead of caching
    /// environment-dependent results under an unstable name.
    pub fn resolve(self) -> BackendKind {
        match self {
            BackendKind::Auto => {
                if ArtifactStore::available() {
                    BackendKind::Pjrt
                } else {
                    BackendKind::Native
                }
            }
            concrete => concrete,
        }
    }
}

/// A registered experiment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExperimentId {
    pub id: &'static str,
    pub description: &'static str,
    /// Exercises the backend-sensitive numeric datapath (descriptive
    /// metadata for `repro list` and `/v1/experiments`; dispatch no
    /// longer forks on it — every experiment runs through the same
    /// [`Runner`]-based path).
    pub numeric: bool,
}

/// Every table and figure of the paper's evaluation (DESIGN.md §3).
pub const EXPERIMENTS: &[ExperimentId] = &[
    ExperimentId { id: "fig6", description: "mma.m16n8k16 sweep on A100", numeric: false },
    ExperimentId { id: "fig7", description: "mma.m16n8k8 sweep on A100", numeric: false },
    ExperimentId { id: "t3", description: "dense mma table, A100", numeric: false },
    ExperimentId { id: "t4", description: "dense mma table, RTX3070Ti", numeric: false },
    ExperimentId { id: "t5", description: "dense mma table, RTX2080Ti", numeric: false },
    ExperimentId { id: "fig10", description: "mma.sp.m16n8k32 sweep on A100", numeric: false },
    ExperimentId { id: "fig11", description: "mma.sp.m16n8k16 sweep (small-k anomaly)", numeric: false },
    ExperimentId { id: "t6", description: "sparse mma table, A100", numeric: false },
    ExperimentId { id: "t7", description: "sparse mma table, RTX3070Ti", numeric: false },
    ExperimentId { id: "fig15", description: "ldmatrix.x4 sweep on A100", numeric: false },
    ExperimentId { id: "t9", description: "ldmatrix table, A100", numeric: false },
    ExperimentId { id: "t10", description: "ld.shared bank-conflict latency", numeric: false },
    ExperimentId { id: "t12", description: "BF16 numeric profiling", numeric: true },
    ExperimentId { id: "t13", description: "FP16 (C/D=FP32) numeric profiling", numeric: true },
    ExperimentId { id: "t14", description: "FP16 (C/D=FP16) numeric profiling", numeric: true },
    ExperimentId { id: "t15", description: "TF32 numeric profiling", numeric: true },
    ExperimentId { id: "fig17", description: "chain matmul relative error", numeric: true },
    ExperimentId { id: "t16", description: "sync vs cp.async GEMM (Appendix A.1)", numeric: false },
    ExperimentId { id: "t17", description: "naive vs permuted layout (Appendix A.2)", numeric: false },
];

/// Run one experiment by id, returning the rendered report. The runner
/// is the backend seam: the §8 numeric experiments execute their probes
/// on its numeric leg (native softfloat or PJRT artifacts); timing
/// experiments are simulator-measured on every backend.
pub fn run_experiment(id: &str, runner: &dyn Runner) -> Result<String> {
    let report = match id {
        "t3" => experiments::run_table3(),
        "t4" => experiments::run_table4(),
        "t5" => experiments::run_table5(),
        "t6" => experiments::run_table6(),
        "t7" => experiments::run_table7(),
        "t9" => experiments::run_table9(),
        "t10" => experiments::run_table10(),
        "t12" => experiments::run_table12(runner),
        "t13" => experiments::run_table13(runner),
        "t14" => experiments::run_table14(runner),
        "t15" => experiments::run_table15(runner),
        "t16" => experiments::run_table16(),
        "t17" => experiments::run_table17(),
        "fig6" => experiments::run_fig6(),
        "fig7" => experiments::run_fig7(),
        "fig10" => experiments::run_fig10(),
        "fig11" => experiments::run_fig11(),
        "fig15" => experiments::run_fig15(),
        "fig17" => experiments::run_fig17(runner),
        other => anyhow::bail!(
            "unknown experiment {other:?}; known: {}",
            EXPERIMENTS.iter().map(|e| e.id).collect::<Vec<_>>().join(", ")
        ),
    };
    Ok(report)
}

/// Look up a registered experiment by id.
pub fn experiment(id: &str) -> Option<&'static ExperimentId> {
    EXPERIMENTS.iter().find(|e| e.id == id)
}

/// Statically verify every warp program the whole campaign generates:
/// each registered experiment's plans ([`experiment_plans`]) are
/// compiled and run through the tclint verifier — nothing is simulated.
/// Returns one `(experiment id, records)` entry per experiment in
/// registry order, clean experiments included (their record list is
/// empty), so callers can report coverage, not just hits.
pub fn lint_all() -> Result<Vec<(&'static str, Vec<LintRecord>)>> {
    let mut out = Vec::with_capacity(EXPERIMENTS.len());
    for e in EXPERIMENTS {
        let mut records = Vec::new();
        for plan in experiments::experiment_plans(e.id) {
            let compiled = plan
                .compile()
                .map_err(|err| anyhow::anyhow!("experiment {}: {err}", e.id))?;
            records.extend(compiled.lint());
        }
        out.push((e.id, records));
    }
    Ok(out)
}

/// One completed campaign entry.
#[derive(Debug, Clone)]
pub struct ExperimentRun {
    pub id: &'static str,
    pub report: String,
    pub wall_ms: f64,
}

/// Run the whole campaign, in registry order.
///
/// Every experiment — timing *and* numeric — is one independent job
/// over the shared [`Runner`] (`Runner: Sync`, so the pool can fan the
/// reference out; the PJRT runner serializes its numeric leg internally
/// because the artifact store is a single stateful compilation cache).
/// The old `numeric: bool` dispatch fork is gone.
///
/// Below the experiment jobs, the cell-level execution engine
/// deduplicates the campaign's overlapping simulations through the
/// process-wide [`crate::workload::CellCache`]: Fig. 6 *is* the sweep of
/// Table 3's BF16 row, Fig. 11 is Table 6's small-k row, and every
/// table point re-appears in its own sweep. A cell is simulated once
/// and every later requester hits the cache; two experiments racing on
/// the *same still-cold* cell may both simulate it (the cache
/// deliberately has no per-cell single-flight — results are
/// deterministic and the simulation gate bounds the cost), so the
/// dedup is best-effort during the cold start and total afterwards.
pub fn run_all(runner: &dyn Runner) -> Result<Vec<ExperimentRun>> {
    use std::time::Instant;

    let jobs: Vec<_> = EXPERIMENTS
        .iter()
        .map(|e| {
            let id = e.id;
            move || {
                let t0 = Instant::now();
                let report = run_experiment(id, runner);
                (id, report, t0.elapsed().as_secs_f64() * 1e3)
            }
        })
        .collect();
    // Cap the outer pool well below the core count: the table
    // experiments fan out over `run_parallel(default_threads())`
    // internally (and their sweep units fan cell jobs out once more),
    // and two uncapped levels would oversubscribe the CPU
    // quadratically (outer x inner threads). The inner levels are
    // short-lived scoped threads, so the transient oversubscription of
    // the third (cell) level is noise next to the simulations it
    // parallelizes.
    let outer_threads = default_threads().min(4);
    let mut runs = Vec::with_capacity(EXPERIMENTS.len());
    for (id, report, wall_ms) in run_parallel(jobs, outer_threads) {
        runs.push(ExperimentRun { id, report: report?, wall_ms });
    }
    Ok(runs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::SimRunner;

    #[test]
    fn registry_covers_all_paper_artifacts() {
        let ids: Vec<&str> = EXPERIMENTS.iter().map(|e| e.id).collect();
        for want in [
            "fig6", "fig7", "fig10", "fig11", "fig15", "fig17", "t3", "t4", "t5", "t6",
            "t7", "t9", "t10", "t12", "t13", "t14", "t15", "t16", "t17",
        ] {
            assert!(ids.contains(&want), "{want} missing");
        }
        assert_eq!(ids.len(), 19);
    }

    #[test]
    fn unknown_experiment_errors() {
        assert!(run_experiment("t99", &SimRunner).is_err());
    }

    #[test]
    fn every_experiment_enumerates_plans_and_lints_clean() {
        // every registered experiment exposes its plan surface...
        for e in EXPERIMENTS {
            assert!(
                !experiment_plans(e.id).is_empty(),
                "{} enumerates no plans for lint",
                e.id
            );
        }
        assert!(experiment_plans("t99").is_empty());
        // ...and the whole campaign's programs pass the verifier (the
        // `repro lint --all` contract; CI fails on any Error)
        let lints = lint_all().unwrap();
        assert_eq!(lints.len(), EXPERIMENTS.len());
        for (id, records) in &lints {
            assert!(records.is_empty(), "{id}: {records:?}");
        }
    }

    #[test]
    fn experiment_lookup() {
        assert_eq!(experiment("t3").unwrap().id, "t3");
        assert!(experiment("t3").unwrap().description.contains("A100"));
        assert!(experiment("t99").is_none());
    }

    #[test]
    fn backend_kind_parses_and_resolves() {
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("auto").unwrap().name(), "auto");
        assert!(BackendKind::parse("cuda").is_err());
        // resolve() pins auto to the backend that would actually run;
        // runner_for(auto) therefore never fails (native fallback)
        let resolved = BackendKind::Auto.resolve();
        assert_ne!(resolved, BackendKind::Auto);
        let runner = crate::workload::runner_for(BackendKind::Auto).unwrap();
        assert!(matches!(runner.name(), "sim" | "pjrt"));
        assert_eq!(BackendKind::Native.resolve(), BackendKind::Native);
        assert_eq!(BackendKind::Pjrt.resolve(), BackendKind::Pjrt);
    }

    #[test]
    fn run_all_parallel_preserves_registry_order() {
        let runs = run_all(&SimRunner).unwrap();
        assert_eq!(runs.len(), EXPERIMENTS.len());
        for (r, e) in runs.iter().zip(EXPERIMENTS) {
            assert_eq!(r.id, e.id);
            assert!(r.report.contains("##"), "{} report missing title", r.id);
            assert!(r.wall_ms >= 0.0);
        }
    }

    #[test]
    fn table5_runs_quickly_and_mentions_turing_rows() {
        let r = run_experiment("t5", &SimRunner).unwrap();
        assert!(r.contains("m16n8k8"));
        assert!(r.contains("INT8"));
    }

    #[test]
    fn table10_deviations_small() {
        let r = run_experiment("t10", &SimRunner).unwrap();
        // every deviation row within a few percent
        for line in r.lines().skip(2) {
            if let Some(dev) = line.split('|').next_back() {
                let dev = dev.trim().trim_start_matches('+').trim_end_matches('%');
                if let Ok(pct) = dev.parse::<f64>() {
                    assert!(pct.abs() < 6.0, "line {line}");
                }
            }
        }
    }

    #[test]
    fn numeric_table_on_the_sim_runner() {
        let r = run_experiment("t13", &SimRunner).unwrap();
        assert!(r.contains("multiplication"));
        assert!(r.contains("0.00e0"), "init_fp16 rows must be exactly zero:\n{r}");
    }
}
