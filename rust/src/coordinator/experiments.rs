//! Experiment implementations: every table and figure of the paper's
//! evaluation, regenerated against the simulator / numeric backends and
//! rendered next to the paper's published values.

use crate::device::{self, Device};
use crate::gemm;
use crate::isa::shapes::{M16N8K16, M16N8K32, M16N8K8};
use crate::isa::{AbType, CdType, LdMatrixNum, LdSharedWidth};
use crate::microbench::Measurement;
use crate::numerics::{InitKind, ProfileOp, ProfileResult};
use crate::report::expected::{self, PaperLdmatrixRow, PaperMmaRow};
use crate::report::{
    deviation, render_figure_csv, render_sparkline, render_sweep_figure, Table,
};
use crate::workload::{
    AccDtype, GemmParams, NumericProbe, Plan, ProbeDtype, Runner, SimRunner, Workload,
};

use super::pool::{default_threads, run_parallel};

fn fmt1(x: f64) -> String {
    format!("{x:.1}")
}

// ---------------------------------------------------- plan enumeration
//
// Each experiment's plans are built by a dedicated constructor shared
// between its run path and [`experiment_plans`], so `repro lint --all`
// verifies exactly the warp programs the campaign launches — the two
// can not drift apart.

/// Every [`Plan`] one experiment compiles, without running any of it —
/// the enumeration seam behind `repro lint --all`. Numeric experiments
/// are enumerated too (the campaign's full plan surface stays covered)
/// but launch no warp programs, so they always lint clean. Unknown ids
/// enumerate nothing.
pub fn experiment_plans(id: &str) -> Vec<Plan> {
    let mma_plans = |device: &Device, rows: &[PaperMmaRow]| -> Vec<Plan> {
        rows.iter().map(|r| mma_row_plan(device.name, r)).collect()
    };
    match id {
        "t3" => mma_plans(&device::a100(), &expected::table3()),
        "t4" => mma_plans(&device::rtx3070ti(), &expected::table4()),
        "t5" => mma_plans(&device::rtx2080ti(), &expected::table5()),
        "t6" => mma_plans(&device::a100(), &expected::table6()),
        "t7" => mma_plans(&device::rtx3070ti(), &expected::table7()),
        "fig6" | "fig7" | "fig10" | "fig11" | "fig15" => {
            vec![figure_plan(figure_workload(id))]
        }
        "t9" => expected::table9().iter().map(ldmatrix_row_plan).collect(),
        "t10" => expected::table10()
            .into_iter()
            .map(|(width_name, ways, _paper)| table10_plan(width_name, ways))
            .collect(),
        "t12" => profile_table_plans(ProbeDtype::Bf16, AccDtype::F32, true),
        "t13" => profile_table_plans(ProbeDtype::Fp16, AccDtype::F32, true),
        "t14" => profile_table_plans(ProbeDtype::Fp16, AccDtype::F16, false),
        "t15" => profile_table_plans(ProbeDtype::Tf32, AccDtype::F32, true),
        "fig17" => {
            fig17_series().into_iter().map(|(_, probe)| profile_plan(probe)).collect()
        }
        "t16" => vec![
            gemm_plan(gemm::Variant::Baseline, false, 1),
            gemm_plan(gemm::Variant::Pipeline, false, 2),
        ],
        "t17" => vec![
            gemm_plan(gemm::Variant::Baseline, true, 1),
            gemm_plan(gemm::Variant::Permuted, true, 1),
        ],
        _ => Vec::new(),
    }
}

// ------------------------------------------------------------ mma tables

/// Regenerate one dense/sparse instruction table (Tables 3–7).
///
/// Latency/throughput are measured at the paper's own (#warps, ILP)
/// points for an apples-to-apples comparison; the sweep-based
/// convergence detector's pick is shown alongside (`conv`). Each row is
/// one compiled [`Plan`] — completion probe, two fixed points, and the
/// sweep with its 4/8-warp convergence summaries — run on the shared
/// workload path.
/// One Table 3–7 row's plan: completion probe, the paper's two fixed
/// points, and the sweep with its 4/8-warp convergence summaries.
fn mma_row_plan(device_name: &str, r: &PaperMmaRow) -> Plan {
    Plan::new(Workload::from_instr(r.instr))
        .device(device_name)
        .completion_latency()
        .point(4, r.p4.0)
        .point(8, r.p8.0)
        .sweep()
}

pub fn mma_table(device: &Device, rows: &[PaperMmaRow], title: &str) -> String {
    struct RowData {
        cmpl: f64,
        at4: Measurement,
        at8: Measurement,
        conv4: u32,
        conv8: u32,
    }
    let device_name = device.name;
    let measured: Vec<RowData> = run_parallel(
        rows.iter()
            .map(|r| {
                let r = *r;
                move || {
                    let plan = mma_row_plan(device_name, &r)
                        .compile()
                        .expect("paper table rows are valid workloads");
                    // units run serially: the rows are the parallel
                    // axis here, and each row's sweep unit fans its
                    // cells out through the cell engine (hitting the
                    // cells its completion probe and fixed points just
                    // simulated instead of redoing them)
                    let res = plan.run(&SimRunner, 1).expect("sim runner is infallible");
                    RowData {
                        cmpl: res.completion().expect("completion unit requested"),
                        at4: *res.point(4, r.p4.0).expect("(4, ILP) point requested"),
                        at8: *res.point(8, r.p8.0).expect("(8, ILP) point requested"),
                        conv4: res.convergence(4).expect("4-warp convergence").ilp,
                        conv8: res.convergence(8).expect("8-warp convergence").ilp,
                    }
                }
            })
            .collect(),
        default_threads(),
    );
    let mut t = Table::new(
        title,
        &[
            "A/B", "C/D", "Shape", "Cmpl (paper)", "Cmpl (sim)", "(w,ILP)", "conv",
            "Lat p/s", "Thr (paper)", "Thr (sim)", "dev",
        ],
    );
    for (r, m) in rows.iter().zip(&measured) {
        for (paper, sim, conv, warps) in
            [(&r.p4, &m.at4, m.conv4, 4u32), (&r.p8, &m.at8, m.conv8, 8)]
        {
            let first = warps == 4;
            t.row(vec![
                if first { r.instr.ab.to_string() } else { String::new() },
                if first { r.instr.cd.to_string() } else { String::new() },
                if first { r.instr.shape.to_string() } else { String::new() },
                if first { fmt1(r.completion) } else { String::new() },
                if first { fmt1(m.cmpl) } else { String::new() },
                format!("({warps},{})", paper.0),
                format!("({warps},{conv})"),
                format!("{}/{}", fmt1(paper.1), fmt1(sim.latency)),
                fmt1(paper.2),
                fmt1(sim.throughput),
                deviation(sim.throughput, paper.2),
            ]);
        }
    }
    t.render()
}

pub fn run_table3() -> String {
    mma_table(&device::a100(), &expected::table3(), "Table 3: dense mma, A100")
}

pub fn run_table4() -> String {
    mma_table(&device::rtx3070ti(), &expected::table4(), "Table 4: dense mma, RTX3070Ti")
}

pub fn run_table5() -> String {
    mma_table(&device::rtx2080ti(), &expected::table5(), "Table 5: dense mma, RTX2080Ti")
}

pub fn run_table6() -> String {
    mma_table(&device::a100(), &expected::table6(), "Table 6: sparse mma, A100")
}

pub fn run_table7() -> String {
    mma_table(&device::rtx3070ti(), &expected::table7(), "Table 7: sparse mma, RTX3070Ti")
}

// ------------------------------------------------------- mma/ld figures

/// The swept workload of each figure experiment, by registry id.
fn figure_workload(id: &str) -> Workload {
    match id {
        "fig6" => Workload::Mma { ab: AbType::Bf16, cd: CdType::Fp32, shape: M16N8K16 },
        "fig7" => Workload::Mma { ab: AbType::Bf16, cd: CdType::Fp32, shape: M16N8K8 },
        "fig10" => Workload::MmaSp { ab: AbType::Bf16, cd: CdType::Fp32, shape: M16N8K32 },
        "fig11" => Workload::MmaSp { ab: AbType::Bf16, cd: CdType::Fp32, shape: M16N8K16 },
        "fig15" => Workload::Ldmatrix { num: LdMatrixNum::X4 },
        other => unreachable!("{other} is not a sweep-figure experiment"),
    }
}

/// A figure's sweep-only plan on the A100.
fn figure_plan(workload: Workload) -> Plan {
    Plan::new(workload).device("a100").sweep()
}

/// Run a sweep-only plan for `workload` and render the Fig. 6/7/10/11/15
/// grid — one shared path regardless of the instruction family.
fn figure_sweep(workload: Workload, title: &str) -> String {
    let plan = figure_plan(workload)
        .compile()
        .expect("figure workloads are valid on a100");
    let res = plan.run(&SimRunner, 1).expect("sim runner is infallible");
    render_sweep_figure(title, res.sweep().expect("sweep unit requested"))
}

pub fn run_fig6() -> String {
    figure_sweep(figure_workload("fig6"), "Fig. 6: mma.m16n8k16 (BF16) on A100")
}

pub fn run_fig7() -> String {
    figure_sweep(figure_workload("fig7"), "Fig. 7: mma.m16n8k8 (BF16) on A100")
}

pub fn run_fig10() -> String {
    figure_sweep(figure_workload("fig10"), "Fig. 10: mma.sp.m16n8k32 (BF16) on A100")
}

pub fn run_fig11() -> String {
    figure_sweep(
        figure_workload("fig11"),
        "Fig. 11: mma.sp.m16n8k16 (BF16) on A100 — small-k anomaly",
    )
}

pub fn run_fig15() -> String {
    figure_sweep(figure_workload("fig15"), "Fig. 15: ldmatrix.x4 on A100 (bytes/clk/SM)")
}

// ---------------------------------------------------------- §7 tables

/// One Table 9 row's plan: completion probe plus the paper's points.
fn ldmatrix_row_plan(r: &PaperLdmatrixRow) -> Plan {
    Plan::new(Workload::Ldmatrix { num: r.num })
        .device("a100")
        .completion_latency()
        .point(4, r.p4.0)
        .point(8, r.p8.0)
}

pub fn run_table9() -> String {
    let rows: Vec<PaperLdmatrixRow> = expected::table9();
    let measured: Vec<(f64, Measurement, Measurement)> = run_parallel(
        rows.iter()
            .map(|r| {
                let r = *r;
                move || {
                    let plan = ldmatrix_row_plan(&r)
                        .compile()
                        .expect("ldmatrix rows are valid on a100");
                    let res = plan.run(&SimRunner, 1).expect("sim runner is infallible");
                    (
                        res.completion().expect("completion unit requested"),
                        *res.point(4, r.p4.0).expect("(4, ILP) point requested"),
                        *res.point(8, r.p8.0).expect("(8, ILP) point requested"),
                    )
                }
            })
            .collect(),
        default_threads(),
    );
    let mut t = Table::new(
        "Table 9: ldmatrix on A100 (bytes/clk/SM at the paper's points)",
        &["instr", "B/warp", "Cmpl p/s", "(4,ILP) thr p/s", "(8,ILP) thr p/s"],
    );
    for (r, (cmpl, m4, m8)) in rows.iter().zip(&measured) {
        t.row(vec![
            r.num.to_string(),
            r.bytes_per_warp.to_string(),
            format!("{}/{}", fmt1(r.completion), fmt1(*cmpl)),
            format!("({},{}) {} / {}", 4, r.p4.0, fmt1(r.p4.2), fmt1(m4.throughput)),
            format!("({},{}) {} / {}", 8, r.p8.0, fmt1(r.p8.2), fmt1(m8.throughput)),
        ]);
    }
    t.render()
}

/// One Table 10 probe's plan: a single (1, 1) latency point.
fn table10_plan(width_name: &str, ways: u32) -> Plan {
    let width = if width_name == "u32" { LdSharedWidth::U32 } else { LdSharedWidth::U64 };
    Plan::new(Workload::LdShared { width, ways }).device("a100").point(1, 1)
}

pub fn run_table10() -> String {
    let mut t = Table::new(
        "Table 10: ld.shared latency under bank conflicts (cycles)",
        &["instr", "ways", "paper", "sim", "dev"],
    );
    for (width_name, ways, paper) in expected::table10() {
        let width = if width_name == "u32" { LdSharedWidth::U32 } else { LdSharedWidth::U64 };
        let plan = table10_plan(width_name, ways)
            .compile()
            .expect("Table 10 probes are valid on a100");
        let res = plan.run(&SimRunner, 1).expect("sim runner is infallible");
        let m = res.point(1, 1).expect("(1,1) point requested");
        t.row(vec![
            width.to_string(),
            format!("{ways}-way"),
            fmt1(paper),
            fmt1(m.latency),
            deviation(m.latency, paper),
        ]);
    }
    t.render()
}

// ------------------------------------------------------- §8 numerics

/// A numeric probe's plan: the pinned (1, 1) point unit.
fn profile_plan(probe: NumericProbe) -> Plan {
    Plan::new(Workload::Numeric(probe)).point(1, 1)
}

/// Every probe plan one §8.1 table runs: all three profile ops, for
/// the low-precision init and (where the table has an `init_FP32`
/// block) the FP32 init too.
fn profile_table_plans(ab: ProbeDtype, cd: AccDtype, fp32_init: bool) -> Vec<Plan> {
    let inits: &[InitKind] = if fp32_init {
        &[InitKind::LowPrecision, InitKind::Fp32]
    } else {
        &[InitKind::LowPrecision]
    };
    let mut plans = Vec::new();
    for &init in inits {
        for op in ProfileOp::ALL {
            plans.push(profile_plan(NumericProbe::profile(ab, cd, op, init)));
        }
    }
    plans
}

/// Run one §8.1 profile probe as a plan-backed `(1,1)` point unit on
/// `runner` — the same path `POST /v1/plan` takes, so tcserved serves
/// these tables from its per-unit cache and the runner's numeric leg
/// (native softfloat or PJRT artifacts) does the arithmetic.
fn profile_result(
    runner: &dyn Runner,
    ab: ProbeDtype,
    cd: AccDtype,
    op: ProfileOp,
    init: InitKind,
) -> ProfileResult {
    let plan = profile_plan(NumericProbe::profile(ab, cd, op, init))
        .compile()
        .expect("the paper's profile probes are valid workloads");
    let res = plan.run(runner, 1).expect("numeric probe execution failed");
    *res.profile().expect("profile point unit requested")
}

fn numeric_table(
    runner: &dyn Runner,
    title: &str,
    ab: ProbeDtype,
    cd: AccDtype,
    paper_low: [f64; 3],
    paper_fp32: Option<[f64; 3]>,
) -> String {
    let mut t = Table::new(title, &["operation", "init", "paper", "measured"]);
    for (init, paper) in [(InitKind::LowPrecision, Some(paper_low)), (InitKind::Fp32, paper_fp32)]
    {
        let Some(paper) = paper else { continue };
        for (i, op) in ProfileOp::ALL.iter().enumerate() {
            let r = profile_result(runner, ab, cd, *op, init);
            t.row(vec![
                op.paper_name().to_string(),
                format!("{init:?}"),
                format!("{:.2e}", paper[i]),
                format!("{:.2e}", r.mean_abs_err),
            ]);
        }
    }
    t.render()
}

pub fn run_table12(runner: &dyn Runner) -> String {
    numeric_table(
        runner,
        "Table 12: BF16 numeric profiling (w.r.t. FP32 CPU)",
        ProbeDtype::Bf16,
        AccDtype::F32,
        [0.0, 0.0, 1.89e-8],
        Some([1.29e-3, 1.72e-3, 1.13e-3]),
    )
}

pub fn run_table13(runner: &dyn Runner) -> String {
    numeric_table(
        runner,
        "Table 13: FP16 (C/D=FP32) numeric profiling",
        ProbeDtype::Fp16,
        AccDtype::F32,
        [0.0, 0.0, 0.0],
        Some([1.59e-4, 2.18e-4, 1.36e-4]),
    )
}

pub fn run_table14(runner: &dyn Runner) -> String {
    let mut t = Table::new(
        "Table 14: FP16 (C/D=FP16) vs CPU_FP32 and CPU_FP32cvtFP16",
        &["operation", "vs FP32 (paper/meas)", "vs cvtFP16 (paper/meas)"],
    );
    let paper = [(1.22e-4, 0.0), (1.81e-4, 0.0), (1.81e-4, 0.0)];
    for (op, (p32, pcvt)) in ProfileOp::ALL.iter().zip(paper) {
        let r = profile_result(
            runner,
            ProbeDtype::Fp16,
            AccDtype::F16,
            *op,
            InitKind::LowPrecision,
        );
        t.row(vec![
            op.paper_name().to_string(),
            format!("{:.2e} / {:.2e}", p32, r.mean_abs_err),
            format!("{:.2e} / {:.2e}", pcvt, r.mean_abs_err_vs_cvt_fp16),
        ]);
    }
    t.render()
}

pub fn run_table15(runner: &dyn Runner) -> String {
    numeric_table(
        runner,
        "Table 15: TF32 numeric profiling",
        ProbeDtype::Tf32,
        AccDtype::F32,
        [0.0, 0.0, 0.0],
        Some([1.59e-4, 2.17e-4, 1.36e-4]),
    )
}

/// The chain length of every Fig. 17 series (the paper's x-axis).
const FIG17_CHAIN_N: u32 = 14;

/// The Fig. 17 chain-probe series: one labelled probe per plotted line.
fn fig17_series() -> Vec<(&'static str, NumericProbe)> {
    [
        ("TF32 (init TF32)", ProbeDtype::Tf32, AccDtype::F32, InitKind::LowPrecision),
        ("BF16 (init BF16)", ProbeDtype::Bf16, AccDtype::F32, InitKind::LowPrecision),
        ("FP16 (init FP16)", ProbeDtype::Fp16, AccDtype::F16, InitKind::LowPrecision),
        ("TF32 (init FP32)", ProbeDtype::Tf32, AccDtype::F32, InitKind::Fp32),
        ("BF16 (init FP32)", ProbeDtype::Bf16, AccDtype::F32, InitKind::Fp32),
    ]
    .into_iter()
    .map(|(label, ab, cd, init)| (label, NumericProbe::chain(ab, cd, FIG17_CHAIN_N, init)))
    .collect()
}

pub fn run_fig17(runner: &dyn Runner) -> String {
    let mut out = String::from("## Fig. 17: chain matrix multiplication relative error\n\n");
    let mut series: Vec<(String, Vec<f64>)> = Vec::new();
    for (label, probe) in fig17_series() {
        // one plan-backed chain probe per series; the full per-step
        // error series and the overflow step ride in the typed output
        let plan = profile_plan(probe)
            .compile()
            .expect("the Fig. 17 chain probes are valid workloads");
        let res = plan.run(runner, 1).expect("numeric probe execution failed");
        let r = res.chain().expect("chain point unit requested");
        if let Some(at) = r.overflow_at {
            out.push_str(&format!("{label}: overflow (inf) at N = {at} (paper: N >= 10 for FP16)\n"));
        }
        series.push((label.to_string(), r.rel_err.clone()));
    }
    out.push('\n');
    for (name, ys) in &series {
        out.push_str(&format!("{name:>18} {}\n", render_sparkline(ys)));
    }
    let xs: Vec<f64> = (1..=FIG17_CHAIN_N).map(|i| i as f64).collect();
    let named: Vec<(&str, Vec<f64>)> = series.iter().map(|(n, y)| (n.as_str(), y.clone())).collect();
    out.push_str("\ncsv:\n");
    out.push_str(&render_figure_csv("N", &xs, &named));
    out
}

// ------------------------------------------------------ Appendix A

/// Whole-GEMM cycle count of one Appendix-A kernel, measured through a
/// plan-backed [`Workload::Gemm`] point unit — the same path `repro
/// sweep` and `POST /v1/plan` take, so tcserved can serve these tables
/// from its per-unit cache.
/// One Appendix-A kernel's plan: the paper's 8-warp CTA at the given
/// cp.async stage depth (the exec point's ILP coordinate).
fn gemm_plan(variant: gemm::Variant, l2_resident: bool, stages: u32) -> Plan {
    Plan::new(Workload::Gemm(GemmParams::paper(variant, l2_resident)))
        .device("a100")
        .point(8, stages)
}

fn gemm_total_cycles(variant: gemm::Variant, l2_resident: bool, stages: u32) -> u64 {
    let params = GemmParams::paper(variant, l2_resident);
    let plan = gemm_plan(variant, l2_resident, stages)
        .compile()
        .expect("the paper's gemm configuration is valid on a100");
    let res = plan.run(&SimRunner, 1).expect("sim runner is infallible");
    let m = res.point(8, stages).expect("(8, stages) point requested");
    // the measurement's latency is cycles per k-step; recover the CTA
    // count and extrapolate over CTA waves like the paper's per-GPU
    // clock64() measurement
    let k_steps = (params.size / params.tile_k) as f64;
    let cta_cycles = (m.latency * k_steps).round() as u64;
    let ctas =
        (params.size as u64 / params.tile_m as u64) * (params.size as u64 / params.tile_n as u64);
    cta_cycles * ctas.div_ceil(res.sms as u64)
}

pub fn run_table16() -> String {
    let base = gemm_total_cycles(gemm::Variant::Baseline, false, 1);
    let pipe = gemm_total_cycles(gemm::Variant::Pipeline, false, 2);
    let mut t = Table::new(
        "Table 16: sync staging vs cp.async pipeline (2048^3 BF16)",
        &["implementation", "paper cycles", "sim cycles/SM", "speedup paper", "speedup sim"],
    );
    let paper_speedup = expected::TABLE16_BASELINE as f64 / expected::TABLE16_PIPELINE as f64;
    let sim_speedup = base as f64 / pipe as f64;
    t.row(vec![
        gemm::Variant::Baseline.paper_name().into(),
        expected::TABLE16_BASELINE.to_string(),
        base.to_string(),
        "1.00x".into(),
        "1.00x".into(),
    ]);
    t.row(vec![
        gemm::Variant::Pipeline.paper_name().into(),
        expected::TABLE16_PIPELINE.to_string(),
        pipe.to_string(),
        format!("{paper_speedup:.2}x"),
        format!("{sim_speedup:.2}x"),
    ]);
    t.render()
}

pub fn run_table17() -> String {
    let base = gemm_total_cycles(gemm::Variant::Baseline, true, 1);
    let perm = gemm_total_cycles(gemm::Variant::Permuted, true, 1);
    let mut t = Table::new(
        "Table 17: naive vs permuted shared-memory layout (2048^3 BF16)",
        &["implementation", "paper cycles", "sim cycles/SM", "speedup paper", "speedup sim"],
    );
    let paper_speedup = expected::TABLE16_BASELINE as f64 / expected::TABLE17_PERMUTED as f64;
    let sim_speedup = base as f64 / perm as f64;
    t.row(vec![
        gemm::Variant::Baseline.paper_name().into(),
        expected::TABLE16_BASELINE.to_string(),
        base.to_string(),
        "1.00x".into(),
        "1.00x".into(),
    ]);
    t.row(vec![
        gemm::Variant::Permuted.paper_name().into(),
        expected::TABLE17_PERMUTED.to_string(),
        perm.to_string(),
        format!("{paper_speedup:.2}x"),
        format!("{sim_speedup:.2}x"),
    ]);
    t.render()
}
