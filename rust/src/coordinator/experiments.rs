//! Experiment implementations: every table and figure of the paper's
//! evaluation, regenerated against the simulator / numeric backends and
//! rendered next to the paper's published values.

use crate::device::{self, Device};
use crate::gemm::{self, GemmConfig};
use crate::isa::{LdMatrixNum, LdSharedWidth, MmaInstr};
use crate::microbench::{
    completion_latency_ldmatrix, completion_latency_mma, convergence_point, measure_ld_shared,
    sweep_ldmatrix, sweep_mma, Sweep,
};
use crate::numerics::{
    chain_errors, profile_op, InitKind, MmaExec, NativeExec, NumericCfg, ProfileOp,
};
use crate::report::expected::{self, PaperLdmatrixRow, PaperMmaRow};
use crate::report::{deviation, render_figure_csv, render_sparkline, Table};

use super::pool::{default_threads, run_parallel};
use super::Backend;

fn fmt1(x: f64) -> String {
    format!("{x:.1}")
}

// ------------------------------------------------------------ mma tables

/// Regenerate one dense/sparse instruction table (Tables 3–7).
///
/// Latency/throughput are measured at the paper's own (#warps, ILP)
/// points for an apples-to-apples comparison; the sweep-based
/// convergence detector's pick is shown alongside (`conv`).
pub fn mma_table(device: &Device, rows: &[PaperMmaRow], title: &str) -> String {
    struct RowData {
        cmpl: f64,
        at4: crate::microbench::Measurement,
        at8: crate::microbench::Measurement,
        conv4: u32,
        conv8: u32,
    }
    let measured: Vec<RowData> = run_parallel(
        rows.iter()
            .map(|r| {
                let d = device.clone();
                let r = *r;
                move || {
                    let sweep = sweep_mma(&d, &r.instr);
                    RowData {
                        cmpl: completion_latency_mma(&d, &r.instr),
                        at4: crate::microbench::measure_mma(&d, &r.instr, 4, r.p4.0),
                        at8: crate::microbench::measure_mma(&d, &r.instr, 8, r.p8.0),
                        conv4: convergence_point(&sweep, 4).ilp,
                        conv8: convergence_point(&sweep, 8).ilp,
                    }
                }
            })
            .collect(),
        default_threads(),
    );
    let mut t = Table::new(
        title,
        &[
            "A/B", "C/D", "Shape", "Cmpl (paper)", "Cmpl (sim)", "(w,ILP)", "conv",
            "Lat p/s", "Thr (paper)", "Thr (sim)", "dev",
        ],
    );
    for (r, m) in rows.iter().zip(&measured) {
        for (paper, sim, conv, warps) in
            [(&r.p4, &m.at4, m.conv4, 4u32), (&r.p8, &m.at8, m.conv8, 8)]
        {
            let first = warps == 4;
            t.row(vec![
                if first { r.instr.ab.to_string() } else { String::new() },
                if first { r.instr.cd.to_string() } else { String::new() },
                if first { r.instr.shape.to_string() } else { String::new() },
                if first { fmt1(r.completion) } else { String::new() },
                if first { fmt1(m.cmpl) } else { String::new() },
                format!("({warps},{})", paper.0),
                format!("({warps},{conv})"),
                format!("{}/{}", fmt1(paper.1), fmt1(sim.latency)),
                fmt1(paper.2),
                fmt1(sim.throughput),
                deviation(sim.throughput, paper.2),
            ]);
        }
    }
    t.render()
}

pub fn run_table3() -> String {
    mma_table(&device::a100(), &expected::table3(), "Table 3: dense mma, A100")
}

pub fn run_table4() -> String {
    mma_table(&device::rtx3070ti(), &expected::table4(), "Table 4: dense mma, RTX3070Ti")
}

pub fn run_table5() -> String {
    mma_table(&device::rtx2080ti(), &expected::table5(), "Table 5: dense mma, RTX2080Ti")
}

pub fn run_table6() -> String {
    mma_table(&device::a100(), &expected::table6(), "Table 6: sparse mma, A100")
}

pub fn run_table7() -> String {
    mma_table(&device::rtx3070ti(), &expected::table7(), "Table 7: sparse mma, RTX3070Ti")
}

// ------------------------------------------------------- mma/ld figures

/// Render a Fig. 6/7/10/11/15-style grid: latency and throughput versus
/// ILP, one series per #warps.
fn render_sweep_figure(title: &str, sweep: &Sweep) -> String {
    let xs: Vec<f64> = sweep.ilp_axis.iter().map(|&i| i as f64).collect();
    let mut out = format!("## {title}\n\n");
    for metric in ["throughput", "latency"] {
        let series: Vec<(String, Vec<f64>)> = sweep
            .warps_axis
            .iter()
            .map(|&w| {
                let ys: Vec<f64> = sweep
                    .ilp_axis
                    .iter()
                    .map(|&ilp| {
                        let c = sweep.cell(w, ilp).unwrap();
                        if metric == "throughput" {
                            c.throughput
                        } else {
                            c.latency
                        }
                    })
                    .collect();
                (format!("{w}w"), ys)
            })
            .collect();
        out.push_str(&format!("### {metric} vs ILP\n"));
        for (name, ys) in &series {
            out.push_str(&format!("{name:>4} {}  {}\n", render_sparkline(ys),
                ys.iter().map(|y| format!("{y:.0}")).collect::<Vec<_>>().join(" ")));
        }
        let named: Vec<(&str, Vec<f64>)> =
            series.iter().map(|(n, y)| (n.as_str(), y.clone())).collect();
        out.push_str("\ncsv:\n");
        out.push_str(&render_figure_csv("ilp", &xs, &named));
        out.push('\n');
    }
    out
}

fn figure_mma(device: &Device, instr: MmaInstr, title: &str) -> String {
    let sweep = sweep_mma(device, &instr);
    render_sweep_figure(title, &sweep)
}

pub fn run_fig6() -> String {
    let i: MmaInstr = "m16n8k16".parse::<crate::isa::MmaShape>().map(|s| {
        MmaInstr::dense(crate::isa::AbType::Bf16, crate::isa::CdType::Fp32, s)
    }).unwrap();
    figure_mma(&device::a100(), i, "Fig. 6: mma.m16n8k16 (BF16) on A100")
}

pub fn run_fig7() -> String {
    let i = MmaInstr::dense(
        crate::isa::AbType::Bf16,
        crate::isa::CdType::Fp32,
        "m16n8k8".parse().unwrap(),
    );
    figure_mma(&device::a100(), i, "Fig. 7: mma.m16n8k8 (BF16) on A100")
}

pub fn run_fig10() -> String {
    let i = MmaInstr::sp(
        crate::isa::AbType::Bf16,
        crate::isa::CdType::Fp32,
        "m16n8k32".parse().unwrap(),
    );
    figure_mma(&device::a100(), i, "Fig. 10: mma.sp.m16n8k32 (BF16) on A100")
}

pub fn run_fig11() -> String {
    let i = MmaInstr::sp(
        crate::isa::AbType::Bf16,
        crate::isa::CdType::Fp32,
        "m16n8k16".parse().unwrap(),
    );
    figure_mma(&device::a100(), i, "Fig. 11: mma.sp.m16n8k16 (BF16) on A100 — small-k anomaly")
}

pub fn run_fig15() -> String {
    let sweep = sweep_ldmatrix(&device::a100(), LdMatrixNum::X4);
    render_sweep_figure("Fig. 15: ldmatrix.x4 on A100 (bytes/clk/SM)", &sweep)
}

// ---------------------------------------------------------- §7 tables

pub fn run_table9() -> String {
    let d = device::a100();
    let rows: Vec<PaperLdmatrixRow> = expected::table9();
    let measured: Vec<(f64, crate::microbench::Measurement, crate::microbench::Measurement)> =
        run_parallel(
            rows.iter()
                .map(|r| {
                    let d = d.clone();
                    let r = *r;
                    move || {
                        (
                            completion_latency_ldmatrix(&d, r.num),
                            crate::microbench::measure_ldmatrix(&d, r.num, 4, r.p4.0),
                            crate::microbench::measure_ldmatrix(&d, r.num, 8, r.p8.0),
                        )
                    }
                })
                .collect(),
            default_threads(),
        );
    let mut t = Table::new(
        "Table 9: ldmatrix on A100 (bytes/clk/SM at the paper's points)",
        &["instr", "B/warp", "Cmpl p/s", "(4,ILP) thr p/s", "(8,ILP) thr p/s"],
    );
    for (r, (cmpl, m4, m8)) in rows.iter().zip(&measured) {
        t.row(vec![
            r.num.to_string(),
            r.bytes_per_warp.to_string(),
            format!("{}/{}", fmt1(r.completion), fmt1(*cmpl)),
            format!("({},{}) {} / {}", 4, r.p4.0, fmt1(r.p4.2), fmt1(m4.throughput)),
            format!("({},{}) {} / {}", 8, r.p8.0, fmt1(r.p8.2), fmt1(m8.throughput)),
        ]);
    }
    t.render()
}

pub fn run_table10() -> String {
    let d = device::a100();
    let mut t = Table::new(
        "Table 10: ld.shared latency under bank conflicts (cycles)",
        &["instr", "ways", "paper", "sim", "dev"],
    );
    for (width_name, ways, paper) in expected::table10() {
        let width = if width_name == "u32" { LdSharedWidth::U32 } else { LdSharedWidth::U64 };
        let m = measure_ld_shared(&d, width, ways);
        t.row(vec![
            width.to_string(),
            format!("{ways}-way"),
            fmt1(paper),
            fmt1(m.latency),
            deviation(m.latency, paper),
        ]);
    }
    t.render()
}

// ------------------------------------------------------- §8 numerics

fn make_exec<'a>(
    backend: &'a mut Backend,
    cfg: NumericCfg,
) -> Box<dyn MmaExec + 'a> {
    match backend {
        Backend::Native => Box::new(NativeExec::new(cfg)),
        Backend::Pjrt(store) => Box::new(
            crate::runtime::ArtifactExec::new(store, cfg)
                .expect("artifact missing — run `make artifacts`"),
        ),
    }
}

const TRIALS: usize = 1000;

fn numeric_table(
    backend: &mut Backend,
    title: &str,
    cfg: NumericCfg,
    paper_low: [f64; 3],
    paper_fp32: Option<[f64; 3]>,
) -> String {
    let mut t = Table::new(title, &["operation", "init", "paper", "measured"]);
    let mut exec = make_exec(backend, cfg);
    for (init, paper) in [(InitKind::LowPrecision, Some(paper_low)), (InitKind::Fp32, paper_fp32)]
    {
        let Some(paper) = paper else { continue };
        for (i, op) in ProfileOp::ALL.iter().enumerate() {
            let r = profile_op(exec.as_mut(), *op, init, TRIALS, 7);
            t.row(vec![
                op.paper_name().to_string(),
                format!("{init:?}"),
                format!("{:.2e}", paper[i]),
                format!("{:.2e}", r.mean_abs_err),
            ]);
        }
    }
    t.render()
}

pub fn run_table12(backend: &mut Backend) -> String {
    numeric_table(
        backend,
        "Table 12: BF16 numeric profiling (w.r.t. FP32 CPU)",
        NumericCfg::new("bf16", "f32", 16, 8, 8),
        [0.0, 0.0, 1.89e-8],
        Some([1.29e-3, 1.72e-3, 1.13e-3]),
    )
}

pub fn run_table13(backend: &mut Backend) -> String {
    numeric_table(
        backend,
        "Table 13: FP16 (C/D=FP32) numeric profiling",
        NumericCfg::new("fp16", "f32", 16, 8, 8),
        [0.0, 0.0, 0.0],
        Some([1.59e-4, 2.18e-4, 1.36e-4]),
    )
}

pub fn run_table14(backend: &mut Backend) -> String {
    let cfg = NumericCfg::new("fp16", "f16", 16, 8, 8);
    let mut t = Table::new(
        "Table 14: FP16 (C/D=FP16) vs CPU_FP32 and CPU_FP32cvtFP16",
        &["operation", "vs FP32 (paper/meas)", "vs cvtFP16 (paper/meas)"],
    );
    let paper = [(1.22e-4, 0.0), (1.81e-4, 0.0), (1.81e-4, 0.0)];
    let mut exec = make_exec(backend, cfg);
    for (op, (p32, pcvt)) in ProfileOp::ALL.iter().zip(paper) {
        let r = profile_op(exec.as_mut(), *op, InitKind::LowPrecision, TRIALS, 7);
        t.row(vec![
            op.paper_name().to_string(),
            format!("{:.2e} / {:.2e}", p32, r.mean_abs_err),
            format!("{:.2e} / {:.2e}", pcvt, r.mean_abs_err_vs_cvt_fp16),
        ]);
    }
    t.render()
}

pub fn run_table15(backend: &mut Backend) -> String {
    numeric_table(
        backend,
        "Table 15: TF32 numeric profiling",
        NumericCfg::new("tf32", "f32", 16, 8, 8),
        [0.0, 0.0, 0.0],
        Some([1.59e-4, 2.17e-4, 1.36e-4]),
    )
}

pub fn run_fig17(backend: &mut Backend) -> String {
    const N: usize = 14;
    const CHAIN_TRIALS: usize = 250; // x4 artifact batches ≈ paper's 1000
    let mut out = String::from("## Fig. 17: chain matrix multiplication relative error\n\n");
    let mut series: Vec<(String, Vec<f64>)> = Vec::new();
    for (label, ab, cd, init_low) in [
        ("TF32 (init TF32)", "tf32", "f32", true),
        ("BF16 (init BF16)", "bf16", "f32", true),
        ("FP16 (init FP16)", "fp16", "f16", true),
        ("TF32 (init FP32)", "tf32", "f32", false),
        ("BF16 (init FP32)", "bf16", "f32", false),
    ] {
        let cfg = NumericCfg::new(
            match ab {
                "tf32" => "tf32",
                "bf16" => "bf16",
                _ => "fp16",
            },
            if cd == "f16" { "f16" } else { "f32" },
            16,
            8,
            8,
        );
        let mut exec = make_exec(backend, cfg);
        let r = chain_errors(exec.as_mut(), N, CHAIN_TRIALS, init_low, 11);
        if let Some(at) = r.overflow_at {
            out.push_str(&format!("{label}: overflow (inf) at N = {at} (paper: N >= 10 for FP16)\n"));
        }
        series.push((label.to_string(), r.rel_err));
    }
    out.push('\n');
    for (name, ys) in &series {
        out.push_str(&format!("{name:>18} {}\n", render_sparkline(ys)));
    }
    let xs: Vec<f64> = (1..=N).map(|i| i as f64).collect();
    let named: Vec<(&str, Vec<f64>)> = series.iter().map(|(n, y)| (n.as_str(), y.clone())).collect();
    out.push_str("\ncsv:\n");
    out.push_str(&render_figure_csv("N", &xs, &named));
    out
}

// ------------------------------------------------------ Appendix A

pub fn run_table16() -> String {
    let d = device::a100();
    let (base, pipe) = gemm::table16(&d, GemmConfig::default());
    let mut t = Table::new(
        "Table 16: sync staging vs cp.async pipeline (2048^3 BF16)",
        &["implementation", "paper cycles", "sim cycles/SM", "speedup paper", "speedup sim"],
    );
    let paper_speedup = expected::TABLE16_BASELINE as f64 / expected::TABLE16_PIPELINE as f64;
    let sim_speedup = base.total_cycles as f64 / pipe.total_cycles as f64;
    t.row(vec![
        "mma_baseline.cu".into(),
        expected::TABLE16_BASELINE.to_string(),
        base.total_cycles.to_string(),
        "1.00x".into(),
        "1.00x".into(),
    ]);
    t.row(vec![
        "mma_pipeline.cu".into(),
        expected::TABLE16_PIPELINE.to_string(),
        pipe.total_cycles.to_string(),
        format!("{paper_speedup:.2}x"),
        format!("{sim_speedup:.2}x"),
    ]);
    t.render()
}

pub fn run_table17() -> String {
    let d = device::a100();
    let (base, perm) = gemm::table17(&d, GemmConfig::default());
    let mut t = Table::new(
        "Table 17: naive vs permuted shared-memory layout (2048^3 BF16)",
        &["implementation", "paper cycles", "sim cycles/SM", "speedup paper", "speedup sim"],
    );
    let paper_speedup = expected::TABLE16_BASELINE as f64 / expected::TABLE17_PERMUTED as f64;
    let sim_speedup = base.total_cycles as f64 / perm.total_cycles as f64;
    t.row(vec![
        "mma_baseline.cu".into(),
        expected::TABLE16_BASELINE.to_string(),
        base.total_cycles.to_string(),
        "1.00x".into(),
        "1.00x".into(),
    ]);
    t.row(vec![
        "mma_permuted.cu".into(),
        expected::TABLE17_PERMUTED.to_string(),
        perm.total_cycles.to_string(),
        format!("{paper_speedup:.2}x"),
        format!("{sim_speedup:.2}x"),
    ]);
    t.render()
}
