//! [`Plan`] builder → [`BenchPlan`] (a compiled batch of runnable
//! units) → [`BenchResult`] (a uniform, renderable result).
//!
//! A plan describes *what to measure* — fixed [`ExecPoint`]s, a full
//! (ILP, #warps) sweep with convergence summaries, a completion-latency
//! probe — for one [`Workload`] on one device. Compilation resolves the
//! device, validates the workload against it and materializes the unit
//! batch; execution hands each unit to a [`Runner`](super::Runner) over
//! the coordinator worker pool, and inside each timing unit the
//! cell-level engine takes over: sweep units fan their cells out across
//! the same pool and every cell reads through the process-wide
//! [`CellCache`](super::CellCache). Every unit has a canonical token
//! ([`BenchPlan::unit_token`]) carrying *all* workload parameters, which
//! tcserved uses as the content-address coordinate for its per-unit
//! result cache — units key under the runner's resolved name, cells
//! under its [`Runner::timing_backend`](super::Runner::timing_backend)
//! (the simulator's, on every current backend).

use std::time::Instant;

use crate::analysis::{self, Diagnostic};
use crate::coordinator::run_parallel;
use crate::device::{self, Device};
use crate::microbench::{ConvergencePoint, Measurement, Sweep};
use crate::sim::{ProfileMode, SimProfile};
use crate::util::Json;

use crate::numerics::{ChainResult, ProfileResult};

use super::numeric::NumericOutput;
use super::runner::Runner;
use super::{ExecPoint, Workload};

/// One runnable unit of a compiled plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UnitKind {
    /// Measure at one fixed (#warps, ILP) point.
    Point(ExecPoint),
    /// The full sweep grid plus convergence points.
    Sweep,
    /// Completion/issue latency (one warp, ILP = 1).
    Completion,
}

impl UnitKind {
    /// Short display label (`point(4,3)`, `sweep`, `completion`).
    pub fn label(&self) -> String {
        match self {
            UnitKind::Point(p) => format!("point{p}"),
            UnitKind::Sweep => "sweep".to_string(),
            UnitKind::Completion => "completion".to_string(),
        }
    }
}

/// Builder for a [`BenchPlan`]. Defaults: device `a100`, convergence
/// summaries at 4 and 8 warps when a sweep is requested.
#[derive(Debug, Clone)]
pub struct Plan {
    workload: Workload,
    device: String,
    points: Vec<ExecPoint>,
    sweep: bool,
    completion: bool,
    convergence: Vec<u32>,
}

impl Plan {
    pub fn new(workload: Workload) -> Plan {
        Plan {
            workload,
            device: "a100".to_string(),
            points: Vec::new(),
            sweep: false,
            completion: false,
            convergence: Vec::new(),
        }
    }

    /// Target device by registry name (see `repro devices`).
    pub fn device(mut self, name: &str) -> Plan {
        self.device = name.to_string();
        self
    }

    /// Add one fixed measurement point.
    pub fn point(mut self, warps: u32, ilp: u32) -> Plan {
        self.points.push(ExecPoint::new(warps, ilp));
        self
    }

    /// Add many fixed measurement points.
    pub fn points<I: IntoIterator<Item = (u32, u32)>>(mut self, pts: I) -> Plan {
        for (warps, ilp) in pts {
            self.points.push(ExecPoint::new(warps, ilp));
        }
        self
    }

    /// Request the full (ILP, #warps) sweep grid.
    pub fn sweep(mut self) -> Plan {
        self.sweep = true;
        self
    }

    /// Request convergence summaries at the given warp counts (implies
    /// [`Plan::sweep`]; the default is 4 and 8, the paper's table
    /// points).
    pub fn convergence(mut self, warps: &[u32]) -> Plan {
        self.sweep = true;
        self.convergence = warps.to_vec();
        self
    }

    /// Request the completion/issue-latency probe.
    pub fn completion_latency(mut self) -> Plan {
        self.completion = true;
        self
    }

    /// Strict u32 from a JSON number: rejects fractions and
    /// out-of-range values instead of silently truncating them (the
    /// value becomes part of a cache key, so what the client sent and
    /// what runs must be identical).
    fn as_exact_u32(v: &Json, what: &str) -> Result<u32, String> {
        let n = v
            .as_f64()
            .ok_or_else(|| format!("{what} must be a number, got {v}"))?;
        if n.fract() != 0.0 || !(0.0..=u32::MAX as f64).contains(&n) {
            return Err(format!("{what} must be a non-negative integer, got {v}"));
        }
        Ok(n as u32)
    }

    /// Parse a plan from its JSON wire form (the `POST /v1/plan` body):
    ///
    /// ```json
    /// {"workload": "mma bf16 f32 m16n8k16", "device": "a100",
    ///  "points": [[4,3],[8,2]], "sweep": true,
    ///  "completion_latency": true, "convergence": [4,8]}
    /// ```
    ///
    /// Points may also be objects: `{"warps": 4, "ilp": 3}`. The
    /// `"backend"` and `"deadline_ms"` fields are tolerated (the server
    /// interprets them); any other unknown field is rejected.
    pub fn from_json(j: &Json) -> Result<Plan, String> {
        let obj = j.as_obj().ok_or("plan must be a JSON object")?;
        for key in obj.keys() {
            if !matches!(
                key.as_str(),
                "workload" | "device" | "points" | "sweep" | "completion_latency"
                    | "convergence" | "backend" | "deadline_ms"
            ) {
                return Err(format!(
                    "unknown plan field {key:?} (workload, device, points, sweep, \
                     completion_latency, convergence, backend, deadline_ms)"
                ));
            }
        }
        let spec = j
            .get_str("workload")
            .ok_or("plan needs a \"workload\" spec string")?;
        let mut plan = Plan::new(Workload::parse_spec(spec)?);
        match j.get("device") {
            None => {}
            Some(Json::Str(d)) => plan = plan.device(d),
            Some(other) => {
                return Err(format!("\"device\" must be a device-name string, got {other}"))
            }
        }
        if let Some(points) = j.get("points") {
            let arr = points.as_arr().ok_or("\"points\" must be an array")?;
            for p in arr {
                let (warps, ilp) = if let Some(pair) = p.as_arr() {
                    if pair.len() != 2 {
                        return Err(format!("each point must be [warps, ilp], got {p}"));
                    }
                    (
                        Self::as_exact_u32(&pair[0], "point warps")?,
                        Self::as_exact_u32(&pair[1], "point ilp")?,
                    )
                } else if p.as_obj().is_some() {
                    (
                        Self::as_exact_u32(
                            p.get("warps").ok_or("point object needs \"warps\"")?,
                            "point warps",
                        )?,
                        Self::as_exact_u32(
                            p.get("ilp").ok_or("point object needs \"ilp\"")?,
                            "point ilp",
                        )?,
                    )
                } else {
                    return Err(format!(
                        "each point must be [warps, ilp] or {{\"warps\":..,\"ilp\":..}}, got {p}"
                    ));
                };
                plan = plan.point(warps, ilp);
            }
        }
        let mut sweep_declined = false;
        match j.get("sweep") {
            None => {}
            Some(Json::Bool(true)) => plan = plan.sweep(),
            Some(Json::Bool(false)) => sweep_declined = true,
            Some(other) => return Err(format!("\"sweep\" must be a boolean, got {other}")),
        }
        match j.get("completion_latency") {
            None | Some(Json::Bool(false)) => {}
            Some(Json::Bool(true)) => plan = plan.completion_latency(),
            Some(other) => {
                return Err(format!("\"completion_latency\" must be a boolean, got {other}"))
            }
        }
        if let Some(conv) = j.get("convergence") {
            // convergence() implies sweep(), so honoring it after an
            // explicit "sweep": false would run work the client declined
            if sweep_declined {
                return Err(
                    "\"convergence\" requires a sweep; remove it or set \"sweep\": true"
                        .to_string(),
                );
            }
            let arr = conv.as_arr().ok_or("\"convergence\" must be an array of warp counts")?;
            let mut warps = Vec::with_capacity(arr.len());
            for w in arr {
                warps.push(Self::as_exact_u32(w, "convergence warp count")?);
            }
            plan = plan.convergence(&warps);
        }
        Ok(plan)
    }

    /// The JSON wire form of this plan (inverse of [`Plan::from_json`]).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("workload", Json::Str(self.workload.to_spec())),
            ("device", Json::Str(self.device.clone())),
        ];
        if !self.points.is_empty() {
            fields.push((
                "points",
                Json::Arr(
                    self.points
                        .iter()
                        .map(|p| {
                            Json::Arr(vec![Json::num(p.warps as f64), Json::num(p.ilp as f64)])
                        })
                        .collect(),
                ),
            ));
        }
        if self.sweep {
            fields.push(("sweep", Json::Bool(true)));
        }
        if self.completion {
            fields.push(("completion_latency", Json::Bool(true)));
        }
        if !self.convergence.is_empty() {
            fields.push((
                "convergence",
                Json::Arr(self.convergence.iter().map(|&w| Json::num(w as f64)).collect()),
            ));
        }
        Json::obj(fields)
    }

    /// Validate the plan and compile it into the runnable unit batch.
    pub fn compile(self) -> Result<BenchPlan, String> {
        let device = device::by_name(&self.device).ok_or_else(|| {
            format!(
                "unknown device {:?}; known: {}",
                self.device,
                device::registry()
                    .iter()
                    .map(|d| d.name)
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })?;
        self.workload.validate(&device)?;
        let mut units = Vec::new();
        if self.completion {
            if matches!(self.workload, Workload::Numeric(_)) {
                return Err(
                    "numeric probes have no completion/issue latency; request a \
                     point (1,1) or a sweep instead"
                        .to_string(),
                );
            }
            units.push(UnitKind::Completion);
        }
        let mut seen: Vec<ExecPoint> = Vec::new();
        for p in &self.points {
            // workload-aware: gemm additionally checks the warp grid and
            // reads ilp as the cp.async stage depth
            self.workload.validate_point(*p)?;
            if seen.contains(p) {
                continue; // identical points are one unit of work
            }
            seen.push(*p);
            units.push(UnitKind::Point(*p));
        }
        let convergence_warps = if self.sweep {
            let axis = self.workload.sweep_warps_axis();
            let warps = if self.convergence.is_empty() {
                // default summaries at the paper's 4/8 warps, restricted
                // to this workload's axis (a small gemm tile may not
                // admit them); fall back to the axis maximum so a sweep
                // the user never parameterized always compiles
                let defaults: Vec<u32> =
                    [4, 8].into_iter().filter(|w| axis.contains(w)).collect();
                if defaults.is_empty() {
                    axis.iter().copied().max().into_iter().collect()
                } else {
                    defaults
                }
            } else {
                self.convergence
            };
            for &w in &warps {
                if !axis.contains(&w) {
                    return Err(format!(
                        "convergence warp count {w} is not on the sweep axis {axis:?}"
                    ));
                }
            }
            units.push(UnitKind::Sweep);
            warps
        } else {
            Vec::new()
        };
        if units.is_empty() {
            return Err(
                "empty plan: request at least one of points(), sweep() or completion_latency()"
                    .to_string(),
            );
        }
        #[allow(unused_mut)]
        let mut plan = BenchPlan {
            workload: self.workload,
            device,
            convergence_warps,
            units,
            diagnostics: Vec::new(),
        };
        // Debug builds lint at compile time so every test and dev run
        // surfaces diagnostics for free; release builds skip it (the
        // simulate path must carry zero verification overhead) and lint
        // only on demand via [`BenchPlan::lint`] (the `repro lint` CLI
        // and the `POST /v1/lint` endpoint).
        #[cfg(debug_assertions)]
        {
            plan.diagnostics = plan.lint();
        }
        Ok(plan)
    }
}

/// One [`Diagnostic`](crate::analysis::Diagnostic) with its plan
/// coordinates: which workload spec, device and (#warps, ILP) point
/// built the flagged program.
#[derive(Debug, Clone)]
pub struct LintRecord {
    /// Canonical workload spec (round-trips through
    /// [`Workload::parse_spec`](super::Workload::parse_spec)).
    pub spec: String,
    pub device: &'static str,
    pub warps: u32,
    pub ilp: u32,
    pub diagnostic: Diagnostic,
}

impl LintRecord {
    /// Whether the underlying diagnostic is an [`Error`](crate::analysis::Severity::Error).
    pub fn is_error(&self) -> bool {
        self.diagnostic.is_error()
    }
}

impl std::fmt::Display for LintRecord {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} @ {} point({},{}): {}",
            self.spec, self.device, self.warps, self.ilp, self.diagnostic
        )
    }
}

/// A validated, compiled plan: the workload, its resolved device, and
/// the batch of units to run.
#[derive(Debug, Clone)]
pub struct BenchPlan {
    pub workload: Workload,
    pub device: Device,
    /// Warp counts the sweep unit summarizes with convergence points.
    pub convergence_warps: Vec<u32>,
    pub units: Vec<UnitKind>,
    /// tclint diagnostics over every program this plan launches.
    /// Populated by [`Plan::compile`] in debug builds only — release
    /// builds leave it empty and lint on demand ([`BenchPlan::lint`])
    /// so the simulate path carries no verification overhead.
    pub diagnostics: Vec<LintRecord>,
}

impl BenchPlan {
    /// Canonical coordinate of one unit, carrying every workload
    /// parameter (type, shape, ldmatrix num, ld.shared width/ways, exec
    /// point) — the content-address input of tcserved's per-unit cache.
    ///
    /// Sweep tokens include the convergence warp list deliberately: the
    /// cached payload embeds the convergence summaries, so two plans
    /// with different lists are different content. Plans using the
    /// default list (4 and 8) all share one entry. A *numeric* sweep
    /// always covers both init kinds (the init axis), so its token
    /// canonicalizes the probe's own init token away — two specs
    /// differing only in init would otherwise cache the identical grid
    /// twice.
    pub fn unit_token(&self, unit: &UnitKind) -> String {
        let base = match (unit, self.workload) {
            (UnitKind::Sweep, Workload::Numeric(p)) => {
                Workload::Numeric(p.with_init(crate::numerics::InitKind::LowPrecision))
                    .to_spec()
            }
            _ => self.workload.to_spec(),
        };
        match unit {
            UnitKind::Completion => format!("{base}|completion"),
            UnitKind::Point(p) => format!("{base}|point:w{}:i{}", p.warps, p.ilp),
            UnitKind::Sweep => format!(
                "{base}|sweep:conv={}",
                self.convergence_warps
                    .iter()
                    .map(|w| w.to_string())
                    .collect::<Vec<_>>()
                    .join("+")
            ),
        }
    }

    /// The distinct [`ExecPoint`]s this plan's units cover, in unit
    /// order: fixed points as requested, the completion probe at its
    /// (1, 1) pin, and a sweep expanded over the workload's full
    /// (#warps, ILP) grid.
    fn lint_points(&self) -> Vec<ExecPoint> {
        let mut points: Vec<ExecPoint> = Vec::new();
        for unit in &self.units {
            let unit_points: Vec<ExecPoint> = match unit {
                UnitKind::Point(p) => vec![*p],
                UnitKind::Completion => vec![ExecPoint::new(1, 1)],
                UnitKind::Sweep => {
                    let ilps = self.workload.sweep_ilp_axis();
                    self.workload
                        .sweep_warps_axis()
                        .into_iter()
                        .flat_map(|w| ilps.iter().map(move |&i| ExecPoint::new(w, i)))
                        .collect()
                }
            };
            for p in unit_points {
                if !points.contains(&p) {
                    points.push(p); // a sweep subsumes equal fixed points
                }
            }
        }
        points
    }

    /// Run the tclint static verifier ([`crate::analysis::verify`])
    /// over every warp program this plan's units would launch, without
    /// simulating anything. Each diagnostic is wrapped in a
    /// [`LintRecord`] carrying its plan coordinates. Numeric probes
    /// launch no warp programs and always lint clean.
    pub fn lint(&self) -> Vec<LintRecord> {
        let spec = self.workload.to_spec();
        let mut records = Vec::new();
        for point in self.lint_points() {
            let programs = self.workload.programs(&self.device, point);
            if programs.is_empty() {
                continue;
            }
            for diagnostic in analysis::verify(&programs, &self.device) {
                records.push(LintRecord {
                    spec: spec.clone(),
                    device: self.device.name,
                    warps: point.warps,
                    ilp: point.ilp,
                    diagnostic,
                });
            }
        }
        records
    }

    /// Execute every unit on `runner` across `threads` pool workers,
    /// collecting a uniform [`BenchResult`]. Unit order is preserved.
    pub fn run(&self, runner: &dyn Runner, threads: usize) -> Result<BenchResult, String> {
        self.run_profiled(runner, threads, ProfileMode::Off)
    }

    /// [`BenchPlan::run`] with stall attribution: every timing unit's
    /// simulations run through a profiler of `mode` and the per-unit
    /// [`SimProfile`]s land in [`BenchResult::unit_profiles`] (all
    /// `None` when `mode` is off or the backend has no profiled path).
    pub fn run_profiled(
        &self,
        runner: &dyn Runner,
        threads: usize,
        mode: ProfileMode,
    ) -> Result<BenchResult, String> {
        let t0 = Instant::now();
        let jobs: Vec<_> = self
            .units
            .iter()
            .map(|&unit| {
                move || {
                    runner
                        .run_unit_profiled(self, &unit, mode)
                        .map(|(out, profile)| (unit, out, profile))
                }
            })
            .collect();
        let mut units = Vec::with_capacity(self.units.len());
        let mut unit_profiles = Vec::with_capacity(self.units.len());
        for result in run_parallel(jobs, threads) {
            let (unit, out, profile) = result?;
            units.push((unit, out));
            unit_profiles.push(profile);
        }
        Ok(BenchResult {
            workload: self.workload,
            runner: runner.name(),
            device_name: self.device.name,
            arch: format!("{:?}", self.device.arch),
            sms: self.device.sms,
            throughput_unit: self.workload.throughput_unit(),
            units,
            unit_profiles,
            diagnostics: self.diagnostics.clone(),
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        })
    }
}

/// The output of one executed unit.
#[derive(Debug, Clone)]
pub enum UnitOutput {
    Point(Measurement),
    Sweep { sweep: Sweep, convergence: Vec<ConvergencePoint> },
    Completion(f64),
    /// A numeric probe's result — what a point unit of a
    /// [`Workload::Numeric`] produces (errors, not cycles).
    Numeric(NumericOutput),
}

/// A uniform plan result: measurements, convergence points and device
/// metadata, consumed by [`crate::report::render_bench`] (text) and
/// [`crate::report::bench_to_json`] (machine-readable).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub workload: Workload,
    /// Name of the [`Runner`](super::Runner) that executed the plan.
    pub runner: &'static str,
    pub device_name: &'static str,
    pub arch: String,
    pub sms: u32,
    pub throughput_unit: &'static str,
    /// Unit outputs, in plan order.
    pub units: Vec<(UnitKind, UnitOutput)>,
    /// Per-unit stall attribution, parallel to [`BenchResult::units`]
    /// (`None` per unit unless the plan ran via
    /// [`BenchPlan::run_profiled`] with profiling on — numeric units
    /// never carry one).
    pub unit_profiles: Vec<Option<SimProfile>>,
    /// tclint diagnostics carried over from the compiled plan
    /// ([`BenchPlan::diagnostics`]) — empty in release builds, where
    /// linting is on-demand only.
    pub diagnostics: Vec<LintRecord>,
    pub wall_ms: f64,
}

impl BenchResult {
    /// The completion-latency probe's result, if the plan requested one.
    pub fn completion(&self) -> Option<f64> {
        self.units.iter().find_map(|(_, out)| match out {
            UnitOutput::Completion(latency) => Some(*latency),
            _ => None,
        })
    }

    /// The measurement at one fixed point, if the plan requested it.
    pub fn point(&self, warps: u32, ilp: u32) -> Option<&Measurement> {
        self.units.iter().find_map(|(_, out)| match out {
            UnitOutput::Point(m) if m.warps == warps && m.ilp == ilp => Some(m),
            _ => None,
        })
    }

    /// The sweep grid, if the plan requested one.
    pub fn sweep(&self) -> Option<&Sweep> {
        self.units.iter().find_map(|(_, out)| match out {
            UnitOutput::Sweep { sweep, .. } => Some(sweep),
            _ => None,
        })
    }

    /// The sweep's convergence summary at `warps`, if computed.
    pub fn convergence(&self, warps: u32) -> Option<&ConvergencePoint> {
        self.units.iter().find_map(|(_, out)| match out {
            UnitOutput::Sweep { convergence, .. } => {
                convergence.iter().find(|c| c.warps == warps)
            }
            _ => None,
        })
    }

    /// The numeric probe's output, if the plan ran one.
    pub fn numeric(&self) -> Option<&NumericOutput> {
        self.units.iter().find_map(|(_, out)| match out {
            UnitOutput::Numeric(n) => Some(n),
            _ => None,
        })
    }

    /// The §8.1 profiling result, if the plan ran a profile probe.
    pub fn profile(&self) -> Option<&ProfileResult> {
        match self.numeric() {
            Some(NumericOutput::Profile(p)) => Some(p),
            _ => None,
        }
    }

    /// The §8.2 chain result, if the plan ran a chain probe.
    pub fn chain(&self) -> Option<&ChainResult> {
        match self.numeric() {
            Some(NumericOutput::Chain(c)) => Some(c),
            _ => None,
        }
    }

    /// Stall attribution merged over every profiled unit, if the plan
    /// ran profiled. (Named `stall_profile` because
    /// [`BenchResult::profile`] is the §8.1 *numeric* profile.)
    pub fn stall_profile(&self) -> Option<SimProfile> {
        let mut merged: Option<SimProfile> = None;
        for p in self.unit_profiles.iter().flatten() {
            match &mut merged {
                Some(m) => m.merge(p),
                None => merged = Some(p.clone()),
            }
        }
        merged
    }

    /// The stall profile of the unit at `index` (plan order), if that
    /// unit was profiled.
    pub fn unit_stall_profile(&self, index: usize) -> Option<&SimProfile> {
        self.unit_profiles.get(index).and_then(|p| p.as_ref())
    }
}

#[cfg(test)]
mod tests {
    use super::super::SimRunner;
    use super::*;
    use crate::isa::{AbType, CdType, LdMatrixNum};
    use crate::isa::shapes::*;
    use crate::server::cache::cache_key;

    fn k16() -> Workload {
        Workload::Mma { ab: AbType::Bf16, cd: CdType::Fp32, shape: M16N8K16 }
    }

    #[test]
    fn builder_compiles_units_in_order() {
        let plan = Plan::new(k16())
            .completion_latency()
            .point(4, 3)
            .point(8, 2)
            .point(4, 3) // duplicate collapses
            .sweep()
            .compile()
            .unwrap();
        assert_eq!(plan.units.len(), 4);
        assert_eq!(plan.units[0], UnitKind::Completion);
        assert_eq!(plan.units[1], UnitKind::Point(ExecPoint::new(4, 3)));
        assert_eq!(plan.units[2], UnitKind::Point(ExecPoint::new(8, 2)));
        assert_eq!(plan.units[3], UnitKind::Sweep);
        assert_eq!(plan.convergence_warps, vec![4, 8]);
    }

    #[test]
    fn builder_validation_errors() {
        // empty plan
        let err = Plan::new(k16()).compile().unwrap_err();
        assert!(err.contains("empty plan"), "{err}");
        // unknown device
        let err = Plan::new(k16()).device("h100").point(4, 1).compile().unwrap_err();
        assert!(err.contains("unknown device") && err.contains("a100"), "{err}");
        // workload illegal on the device
        let sp = Workload::MmaSp { ab: AbType::Fp16, cd: CdType::Fp32, shape: M16N8K32 };
        let err = Plan::new(sp).device("rtx2080ti").point(4, 1).compile().unwrap_err();
        assert!(err.contains("not supported"), "{err}");
        // out-of-range point
        let err = Plan::new(k16()).point(0, 1).compile().unwrap_err();
        assert!(err.contains("warps"), "{err}");
        // convergence warp off the sweep axis
        let err = Plan::new(k16()).convergence(&[5]).compile().unwrap_err();
        assert!(err.contains("sweep axis"), "{err}");
    }

    #[test]
    fn run_produces_uniform_result_with_accessors() {
        let plan = Plan::new(k16())
            .completion_latency()
            .point(8, 2)
            .sweep()
            .compile()
            .unwrap();
        let r = plan.run(&SimRunner, 2).unwrap();
        assert_eq!(r.device_name, "a100");
        assert_eq!(r.runner, "sim");
        assert_eq!(r.throughput_unit, "FMA/clk/SM");
        assert_eq!(r.units.len(), 3);
        let cmpl = r.completion().unwrap();
        assert!((24.0..27.0).contains(&cmpl), "{cmpl}");
        let p = r.point(8, 2).unwrap();
        assert!((960.0..1030.0).contains(&p.throughput), "{p:?}");
        assert!(r.point(8, 3).is_none());
        assert_eq!(r.sweep().unwrap().cells.len(), 48);
        assert_eq!(r.convergence(8).unwrap().ilp, 2);
        assert!(r.convergence(6).is_none());
        assert!(r.wall_ms >= 0.0);
    }

    #[test]
    fn profiled_runs_attach_stall_profiles_per_unit() {
        let plan = Plan::new(k16()).completion_latency().point(8, 2).compile().unwrap();
        let off = plan.run(&SimRunner, 2).unwrap();
        assert_eq!(off.unit_profiles.len(), 2);
        assert!(off.unit_profiles.iter().all(|p| p.is_none()));
        assert!(off.stall_profile().is_none());

        let on = plan.run_profiled(&SimRunner, 2, ProfileMode::Counting).unwrap();
        assert_eq!(on.unit_profiles.len(), 2);
        for (i, p) in on.unit_profiles.iter().enumerate() {
            let p = p.as_ref().unwrap_or_else(|| panic!("unit {i} unprofiled"));
            assert_eq!(p.total(), p.warp_cycles, "unit {i}: {p:?}");
            assert!(p.warp_cycles > 0 && p.issued > 0, "unit {i}: {p:?}");
            assert_eq!(on.unit_stall_profile(i), Some(p));
        }
        let merged = on.stall_profile().unwrap();
        assert_eq!(merged.runs, 2);
        assert_eq!(merged.total(), merged.warp_cycles);

        // profiling leaves the measurements bit-identical
        assert_eq!(off.point(8, 2), on.point(8, 2));
        assert_eq!(off.completion(), on.completion());
    }

    #[test]
    fn json_round_trip() {
        let body = r#"{"workload":"ldmatrix x4","device":"a100",
                       "points":[[4,2],{"warps":8,"ilp":1}],
                       "sweep":true,"completion_latency":true,"convergence":[4]}"#;
        let plan = Plan::from_json(&Json::parse(body).unwrap()).unwrap();
        let again = Plan::from_json(&plan.to_json()).unwrap();
        let (a, b) = (plan.compile().unwrap(), again.compile().unwrap());
        assert_eq!(a.units, b.units);
        assert_eq!(a.convergence_warps, b.convergence_warps);
        assert_eq!(a.workload, Workload::Ldmatrix { num: LdMatrixNum::X4 });
    }

    #[test]
    fn json_rejects_malformed_plans() {
        for body in [
            r#"[]"#,
            r#"{}"#,
            r#"{"workload":"mma bf16 f32 m16n8k16","typo":1}"#,
            r#"{"workload":"nonsense"}"#,
            r#"{"workload":"mma bf16 f32 m16n8k16","points":[[4]]}"#,
            r#"{"workload":"mma bf16 f32 m16n8k16","points":["x"]}"#,
            // non-integer coordinates must be rejected, not truncated:
            // the value becomes a cache-key coordinate
            r#"{"workload":"mma bf16 f32 m16n8k16","points":[[4.7,2]]}"#,
            r#"{"workload":"mma bf16 f32 m16n8k16","points":[{"warps":4,"ilp":2.5}]}"#,
            r#"{"workload":"mma bf16 f32 m16n8k16","points":[[-4,2]]}"#,
            r#"{"workload":"mma bf16 f32 m16n8k16","sweep":"yes"}"#,
            r#"{"workload":"mma bf16 f32 m16n8k16","convergence":"all"}"#,
            r#"{"workload":"mma bf16 f32 m16n8k16","points":[[4,1]],"device":3070}"#,
            r#"{"workload":"mma bf16 f32 m16n8k16","convergence":[4.5]}"#,
            // convergence implies a sweep the client explicitly declined
            r#"{"workload":"mma bf16 f32 m16n8k16","sweep":false,"convergence":[4]}"#,
        ] {
            let j = Json::parse(body).unwrap();
            assert!(Plan::from_json(&j).is_err(), "{body} should be rejected");
        }
    }

    #[test]
    fn gemm_plans_compile_and_run_like_instruction_plans() {
        use super::super::GemmParams;
        use crate::gemm::Variant;
        let w = Workload::Gemm(GemmParams {
            size: 256,
            ..GemmParams::paper(Variant::Pipeline, false)
        });
        let plan = Plan::new(w).completion_latency().point(8, 2).compile().unwrap();
        let r = plan.run(&SimRunner, 2).unwrap();
        assert!(r.completion().unwrap() > 0.0);
        assert!(r.point(8, 2).unwrap().throughput > 0.0);
        assert_eq!(r.throughput_unit, "FMA/clk/SM");

        // tile params are cache-key coordinates: two tiles address
        // different slots, and the stage depth is in the token too
        let w2 = Workload::Gemm(GemmParams {
            size: 256,
            tile_n: 64,
            ..GemmParams::paper(Variant::Pipeline, false)
        });
        let a = Plan::new(w).point(8, 2).compile().unwrap();
        let b = Plan::new(w2).point(8, 2).compile().unwrap();
        let c = Plan::new(w).point(8, 3).compile().unwrap();
        assert_ne!(a.unit_token(&a.units[0]), b.unit_token(&b.units[0]));
        assert_ne!(a.unit_token(&a.units[0]), c.unit_token(&c.units[0]));

        // a warp count off the tile's grid is rejected at compile time,
        // as is a convergence warp off the gemm sweep axis
        let err = Plan::new(w).point(6, 2).compile().unwrap_err();
        assert!(err.contains("power of two"), "{err}");
        let err = Plan::new(w).convergence(&[6]).compile().unwrap_err();
        assert!(err.contains("sweep axis"), "{err}");

        // a tile too small to admit the default 4/8-warp convergence
        // points still sweeps: the default falls back to the axis max
        let tiny = Workload::Gemm(GemmParams {
            size: 64,
            tile_m: 16,
            tile_n: 16,
            tile_k: 16,
            ..GemmParams::paper(Variant::Pipeline, false)
        });
        let plan = Plan::new(tiny).sweep().compile().unwrap();
        assert_eq!(plan.convergence_warps, vec![1]);
    }

    #[test]
    fn numeric_plans_pin_points_and_reject_completion() {
        let w = Workload::parse_spec("numeric profile bf16 f32 acc fp32").unwrap();
        // the probe runs as a (1,1) point unit and returns typed output
        let plan = Plan::new(w).point(1, 1).compile().unwrap();
        let r = plan.run(&SimRunner, 1).unwrap();
        let p = r.profile().expect("profile output");
        assert!(p.mean_abs_err > 0.0, "{p:?}"); // Table 12's init_FP32 row
        assert!(r.chain().is_none());
        assert_eq!(r.throughput_unit, "mean |err|");
        // no completion probe, no off-(1,1) points
        let err = Plan::new(w).completion_latency().point(1, 1).compile().unwrap_err();
        assert!(err.contains("completion"), "{err}");
        let err = Plan::new(w).point(4, 2).compile().unwrap_err();
        assert!(err.contains("(1,1)"), "{err}");
        // two probes differing only in init address different cache slots
        let low = Workload::parse_spec("numeric profile bf16 f32 acc low").unwrap();
        let a = Plan::new(w).point(1, 1).compile().unwrap();
        let b = Plan::new(low).point(1, 1).compile().unwrap();
        assert_ne!(a.unit_token(&a.units[0]), b.unit_token(&b.units[0]));
        // fp8 probes validate per device
        let fp8 = Workload::parse_spec("numeric profile fp8e4m3 f32 mul").unwrap();
        assert!(Plan::new(fp8).point(1, 1).compile().is_err()); // a100 default
        assert!(Plan::new(fp8).device("hopper-projected").point(1, 1).compile().is_ok());
    }

    #[test]
    fn numeric_chain_sweep_through_the_plan_path() {
        let w = Workload::parse_spec("numeric chain tf32 f32 6").unwrap();
        let plan = Plan::new(w).sweep().compile().unwrap();
        assert_eq!(plan.convergence_warps, vec![4]); // default ∩ step axis
        let r = plan.run(&SimRunner, 2).unwrap();
        let sweep = r.sweep().unwrap();
        assert_eq!(sweep.warps_axis, vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(sweep.ilp_axis, vec![1, 2]);
        // error grows along the chain on the low-precision column
        assert!(sweep.cell(6, 1).unwrap().latency > sweep.cell(1, 1).unwrap().latency);

        // the sweep covers BOTH init kinds whatever the spec's init
        // token says, so the two specs share one sweep content address
        // (while their point units stay distinct)
        let fp32 = Workload::parse_spec("numeric chain tf32 f32 6 fp32").unwrap();
        let a = Plan::new(w).sweep().compile().unwrap();
        let b = Plan::new(fp32).sweep().compile().unwrap();
        assert_eq!(a.unit_token(&UnitKind::Sweep), b.unit_token(&UnitKind::Sweep));
        let pa = Plan::new(w).point(1, 1).compile().unwrap();
        let pb = Plan::new(fp32).point(1, 1).compile().unwrap();
        assert_ne!(pa.unit_token(&pa.units[0]), pb.unit_token(&pb.units[0]));
    }

    #[test]
    fn unit_tokens_carry_every_workload_parameter() {
        // two plans differing only in ILP address different cache slots
        let a = Plan::new(k16()).point(4, 1).compile().unwrap();
        let b = Plan::new(k16()).point(4, 2).compile().unwrap();
        let ta = a.unit_token(&a.units[0]);
        let tb = b.unit_token(&b.units[0]);
        assert_ne!(ta, tb);
        let ka = cache_key("plan", "sim", a.device.name, &ta);
        let kb = cache_key("plan", "sim", b.device.name, &tb);
        assert_ne!(ka.hash, kb.hash, "{ta} vs {tb}");

        // ldmatrix num and ld.shared width/ways are in the address too
        let x2 = Plan::new(Workload::Ldmatrix { num: LdMatrixNum::X2 })
            .point(4, 1)
            .compile()
            .unwrap();
        let x4 = Plan::new(Workload::Ldmatrix { num: LdMatrixNum::X4 })
            .point(4, 1)
            .compile()
            .unwrap();
        assert_ne!(x2.unit_token(&x2.units[0]), x4.unit_token(&x4.units[0]));

        use crate::isa::LdSharedWidth;
        let u32_4 = Plan::new(Workload::LdShared { width: LdSharedWidth::U32, ways: 4 })
            .point(1, 1)
            .compile()
            .unwrap();
        let u64_4 = Plan::new(Workload::LdShared { width: LdSharedWidth::U64, ways: 4 })
            .point(1, 1)
            .compile()
            .unwrap();
        let u32_8 = Plan::new(Workload::LdShared { width: LdSharedWidth::U32, ways: 8 })
            .point(1, 1)
            .compile()
            .unwrap();
        let t32_4 = u32_4.unit_token(&u32_4.units[0]);
        assert_ne!(t32_4, u64_4.unit_token(&u64_4.units[0]));
        assert_ne!(t32_4, u32_8.unit_token(&u32_8.units[0]));

        // sweep tokens include the convergence warps
        let s48 = Plan::new(k16()).sweep().compile().unwrap();
        let s4 = Plan::new(k16()).convergence(&[4]).compile().unwrap();
        let sweep_unit = UnitKind::Sweep;
        assert_ne!(s48.unit_token(&sweep_unit), s4.unit_token(&sweep_unit));
    }

    #[test]
    fn plans_lint_clean_and_results_carry_the_diagnostics() {
        use super::super::GemmParams;
        use crate::gemm::Variant;
        // every unit kind over an instruction family lints clean, and
        // the completion probe's (1,1) pin plus the sweep's full grid
        // subsume the explicit point — a sweep covers 48 cells but the
        // lint pass visits each distinct exec point exactly once
        let plan = Plan::new(k16())
            .completion_latency()
            .point(4, 3)
            .sweep()
            .compile()
            .unwrap();
        let records = plan.lint();
        assert!(records.is_empty(), "{records:?}");
        // in debug builds compile() already ran the same pass
        #[cfg(debug_assertions)]
        assert!(plan.diagnostics.is_empty());
        let r = plan.run(&SimRunner, 2).unwrap();
        assert_eq!(r.diagnostics.len(), plan.diagnostics.len());

        // the gemm pipeline's cp.async protocol passes the verifier at
        // every stage depth on the sweep axis
        let w = Workload::Gemm(GemmParams {
            size: 256,
            ..GemmParams::paper(Variant::Pipeline, false)
        });
        let plan = Plan::new(w).completion_latency().sweep().compile().unwrap();
        let records = plan.lint();
        assert!(records.is_empty(), "{records:?}");

        // numeric probes launch no warp programs: trivially clean
        let w = Workload::parse_spec("numeric profile bf16 f32 acc fp32").unwrap();
        assert!(Plan::new(w).point(1, 1).compile().unwrap().lint().is_empty());
    }
}
