//! The autotuner: analytic pruning + cycle-sim confirmation.
//!
//! The paper's configuration space — (shape, precision, #warps, ILP,
//! `cp.async` stages, tile) — explodes combinatorially, and full cycle
//! simulation of every cell is exactly what makes interactive placement
//! questions impossible. [`tune_workload`] applies the hybrid strategy
//! of Raihan et al.: score the *whole* legal grid with the closed-form
//! model ([`Workload::predict`], orders of magnitude cheaper than
//! simulation — `tests/analytic_calibration.rs` pins the ≥100× ratio),
//! prune to a top-K frontier under the requested [`Objective`], then
//! confirm only those K cells through the cycle simulator via the
//! process-wide [`CellCache`](super::CellCache) — the same cell-level
//! machinery the sweeps use, so a tune after a sweep is all cache hits
//! and a sweep after a tune finds the frontier cells warm.
//!
//! Every reported config carries its predicted *and* simulated numbers
//! plus the relative error between them, and the final ranking is by
//! the simulated metric — the analytic model proposes, the simulator
//! disposes. The realized `pruning_ratio` (`1 - confirmed/scored`) is
//! the fraction of the grid that never paid for simulation.
//!
//! For `gemm` workloads the grid additionally spans a CTA-tile axis
//! ([`GEMM_TUNE_TILES`] plus the requested tile), with stages bounded by
//! the device's shared-memory capacity; the other families tune over
//! their sweep axes. Numeric probes have no timing grid and are
//! rejected with a typed error.

use std::cmp::Ordering;
use std::time::Instant;

use crate::coordinator::run_parallel;
use crate::device::Device;
use crate::sim::{calibration_bound, AnalyticPrediction, Budget};
use crate::util::Json;

use super::{ExecPoint, Workload};

/// JSON schema tag of a serialized [`TuneReport`].
pub const TUNE_SCHEMA: &str = "tcbench/tune/v1";

/// Frontier size confirmed in the simulator when the caller does not ask
/// for a specific `top`.
pub const DEFAULT_TUNE_TOP_K: usize = 8;

/// CTA tiles the gemm tuner explores in addition to the requested one
/// (all `tile_k = 32` like the paper's kernels; per-device legality and
/// shared-memory capacity filter the axis down).
pub const GEMM_TUNE_TILES: [(u32, u32, u32); 4] =
    [(128, 128, 32), (128, 64, 32), (64, 64, 32), (256, 128, 32)];

/// What "best" means for a tune request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Minimize iteration latency (cycles per iteration / k-step).
    MinLatency,
    /// Maximize throughput (FMA/clk/SM or bytes/clk/SM).
    MaxThroughput,
    /// Maximize throughput using at most this many warps — the
    /// placement question of a kernel that must co-reside with others.
    TargetOccupancy(u32),
}

impl Objective {
    /// Parse an objective token: `min-latency`, `max-throughput` or
    /// `target-occupancy:<warps>`. The exact inverse of
    /// [`Objective::spec_name`].
    pub fn parse_spec(token: &str) -> Result<Objective, String> {
        let lower = token.to_ascii_lowercase();
        match lower.as_str() {
            "min-latency" => Ok(Objective::MinLatency),
            "max-throughput" => Ok(Objective::MaxThroughput),
            other => {
                let Some(budget) = other.strip_prefix("target-occupancy:") else {
                    return Err(format!(
                        "unknown objective {token:?} \
                         (min-latency | max-throughput | target-occupancy:<warps>)"
                    ));
                };
                let warps: u32 = budget.parse().map_err(|_| {
                    format!("target-occupancy warp budget must be a number, got {budget:?}")
                })?;
                if !(1..=32).contains(&warps) {
                    return Err(format!(
                        "target-occupancy warp budget must be in 1..=32, got {warps}"
                    ));
                }
                Ok(Objective::TargetOccupancy(warps))
            }
        }
    }

    /// Canonical token — round-trips through [`Objective::parse_spec`].
    pub fn spec_name(&self) -> String {
        match self {
            Objective::MinLatency => "min-latency".to_string(),
            Objective::MaxThroughput => "max-throughput".to_string(),
            Objective::TargetOccupancy(w) => format!("target-occupancy:{w}"),
        }
    }

    /// May a candidate at `point` compete under this objective?
    fn admits_point(&self, point: ExecPoint) -> bool {
        match self {
            Objective::TargetOccupancy(budget) => point.warps <= *budget,
            _ => true,
        }
    }

    /// Order two (latency, throughput) metric pairs, best first. Ties on
    /// the primary metric break toward lower latency — the saturated
    /// region of a throughput sweep is a plateau, and the cheapest point
    /// on it is the right answer.
    fn rank(&self, a_lat: f64, a_thr: f64, b_lat: f64, b_thr: f64) -> Ordering {
        let primary = match self {
            Objective::MinLatency => a_lat.total_cmp(&b_lat),
            Objective::MaxThroughput | Objective::TargetOccupancy(_) => b_thr.total_cmp(&a_thr),
        };
        primary.then(a_lat.total_cmp(&b_lat))
    }
}

/// One analytically scored grid cell.
#[derive(Debug, Clone, Copy)]
struct Candidate {
    workload: Workload,
    point: ExecPoint,
    predicted: AnalyticPrediction,
}

/// One frontier configuration of a [`TuneReport`]: the analytic
/// prediction that promoted it and — when the request's budget allowed
/// the cycle simulation to run — the simulated numbers that rank it,
/// with the realized model error between them. A blown
/// [`Budget`] leaves the config *unconfirmed*: the simulated fields are
/// `None` and the ranking falls back to the prediction.
#[derive(Debug, Clone)]
pub struct TunedConfig {
    /// Full workload spec of the cell (differs from the request for
    /// gemm, where the tile is a tuned axis).
    pub spec: String,
    /// (#warps, ILP) — for gemm, (CTA warps, `cp.async` stages).
    pub point: ExecPoint,
    pub predicted: AnalyticPrediction,
    /// Did the cycle simulator confirm this cell within the budget?
    pub confirmed: bool,
    pub simulated_latency: Option<f64>,
    pub simulated_throughput: Option<f64>,
    /// `|sim - predicted| / predicted` on the latency.
    pub latency_rel_err: Option<f64>,
    /// `|sim - predicted| / predicted` on the throughput.
    pub throughput_rel_err: Option<f64>,
    /// Does the (predicted, simulated) pair satisfy the family's pinned
    /// [`CalibrationBound`](crate::sim::CalibrationBound)? Always
    /// `false` for unconfirmed configs — there is no pair to check.
    pub within_calibration: bool,
}

/// The result of one [`tune_workload`] run: the confirmed frontier,
/// ranked best-first by the *simulated* objective metric, plus the
/// realized pruning and scoring-rate numbers.
#[derive(Debug, Clone)]
pub struct TuneReport {
    /// The requested workload spec.
    pub workload: String,
    /// Its family keyword ([`Workload::kind`]).
    pub family: &'static str,
    pub device: &'static str,
    pub objective: Objective,
    /// Grid cells scored analytically (the whole legal grid).
    pub scored: usize,
    /// Cells actually confirmed in the cycle simulator — below the
    /// frontier size when a request [`Budget`] blew mid-confirmation.
    pub confirmed: usize,
    /// `1 - frontier/scored`: the fraction of the grid that was pruned
    /// before the cycle-simulation phase.
    pub pruning_ratio: f64,
    /// Wall time of the analytic scoring pass.
    pub analytic_seconds: f64,
    /// Scoring rate of the analytic pass, configs/second.
    pub analytic_configs_per_sec: f64,
    pub configs: Vec<TunedConfig>,
}

impl TuneReport {
    /// Serialize under the `tcbench/tune/v1` schema.
    pub fn to_json(&self) -> Json {
        let configs: Vec<Json> = self
            .configs
            .iter()
            .map(|c| {
                // unconfirmed configs (budget blew before their cycle
                // simulation) simply omit the simulated/rel_err fields
                let mut fields = vec![
                    ("spec", Json::str(c.spec.clone())),
                    ("warps", Json::num(c.point.warps as f64)),
                    ("ilp", Json::num(c.point.ilp as f64)),
                    (
                        "predicted",
                        Json::obj(vec![
                            ("latency", Json::num(c.predicted.latency)),
                            ("throughput", Json::num(c.predicted.throughput)),
                        ]),
                    ),
                    ("confirmed", Json::Bool(c.confirmed)),
                ];
                if let (Some(lat), Some(thr)) = (c.simulated_latency, c.simulated_throughput) {
                    fields.push((
                        "simulated",
                        Json::obj(vec![
                            ("latency", Json::num(lat)),
                            ("throughput", Json::num(thr)),
                        ]),
                    ));
                }
                if let Some(e) = c.latency_rel_err {
                    fields.push(("latency_rel_err", Json::num(e)));
                }
                if let Some(e) = c.throughput_rel_err {
                    fields.push(("throughput_rel_err", Json::num(e)));
                }
                fields.push(("within_calibration", Json::Bool(c.within_calibration)));
                Json::obj(fields)
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::str(TUNE_SCHEMA)),
            ("workload", Json::str(self.workload.clone())),
            ("family", Json::str(self.family)),
            ("device", Json::str(self.device)),
            ("objective", Json::str(self.objective.spec_name())),
            ("scored", Json::num(self.scored as f64)),
            ("confirmed", Json::num(self.confirmed as f64)),
            ("pruning_ratio", Json::num(self.pruning_ratio)),
            ("analytic_seconds", Json::num(self.analytic_seconds)),
            ("analytic_configs_per_sec", Json::num(self.analytic_configs_per_sec)),
            ("configs", Json::Arr(configs)),
        ])
    }
}

/// Enumerate the legal tuning grid of `workload` on `device`: every
/// (workload-variant, point) cell the tuner may score. For gemm this
/// spans the tile axis and bounds the staged footprint by the device's
/// shared-memory capacity; the other timing families tune over their
/// sweep axes.
fn tuning_grid(workload: &Workload, device: &Device) -> Result<Vec<(Workload, ExecPoint)>, String> {
    if matches!(workload, Workload::Numeric(_)) {
        return Err(
            "numeric probes have no (#warps, ILP) timing grid to tune; \
             tune a timing family (mma | mma.sp | ldmatrix | ld.shared | wmma | gemm)"
            .to_string(),
        );
    }
    let variants: Vec<Workload> = match workload {
        Workload::Gemm(g) => {
            let mut tiles = vec![(g.tile_m, g.tile_n, g.tile_k)];
            for t in GEMM_TUNE_TILES {
                if !tiles.contains(&t) {
                    tiles.push(t);
                }
            }
            let mut out = Vec::new();
            for (tile_m, tile_n, tile_k) in tiles {
                let mut params = *g;
                params.tile_m = tile_m;
                params.tile_n = tile_n;
                params.tile_k = tile_k;
                let w = Workload::Gemm(params);
                if w.validate(device).is_ok() {
                    out.push(w);
                }
            }
            if out.is_empty() {
                // the requested tile itself is illegal — surface its reason
                workload.validate(device)?;
            }
            out
        }
        _ => {
            workload.validate(device)?;
            vec![*workload]
        }
    };
    let mut cells = Vec::new();
    for w in variants {
        for warps in w.sweep_warps_axis() {
            for ilp in w.sweep_ilp_axis() {
                let point = ExecPoint::new(warps, ilp);
                if w.validate_point(point).is_err() {
                    continue;
                }
                if let Workload::Gemm(g) = w {
                    // a `stages`-deep pipeline keeps `stages` staged
                    // tiles resident; don't tune configs the SM cannot
                    // physically hold (the tclint resource rule would
                    // reject their programs)
                    let staged = g.config(point).staged_bytes() * point.ilp as u64;
                    if staged > device.smem_bytes_per_sm as u64 {
                        continue;
                    }
                }
                cells.push((w, point));
            }
        }
    }
    Ok(cells)
}

/// Tune `workload` on `device` for `objective`: score the whole legal
/// grid analytically, prune to the best `top_k` candidates, confirm
/// exactly those in the cycle simulator (through the process-wide cell
/// cache under `backend`'s name, fanned out over `threads` workers) and
/// return the frontier ranked by the simulated metric.
///
/// When a request [`Budget`] is given, the confirmation phase honors
/// it: cells whose simulation the budget cuts off stay *unconfirmed*
/// (`confirmed: false`, no simulated numbers) and rank by their
/// analytic prediction — the analytic scoring pass itself is cheap
/// enough that it always runs. The report never fails on a blown
/// budget; it degrades.
pub fn tune_workload(
    workload: &Workload,
    device: &Device,
    objective: Objective,
    top_k: usize,
    backend: &str,
    threads: usize,
    budget: Option<Budget>,
) -> Result<TuneReport, String> {
    if top_k == 0 {
        return Err("top must be at least 1".to_string());
    }
    let cells = tuning_grid(workload, device)?;

    // Phase 1: closed-form scoring of every cell (the fast path — no
    // cycle is simulated here).
    let start = Instant::now();
    let mut scored: Vec<Candidate> = Vec::with_capacity(cells.len());
    for (w, point) in &cells {
        let predicted = w.predict(device, *point)?;
        scored.push(Candidate { workload: *w, point: *point, predicted });
    }
    let analytic_seconds = start.elapsed().as_secs_f64().max(1e-9);

    // Phase 2: prune to the objective's top-K frontier. Ties break
    // deterministically toward fewer warps, lower ILP, then spec order,
    // so a tune is reproducible across runs and machines.
    let mut frontier: Vec<Candidate> =
        scored.iter().copied().filter(|c| objective.admits_point(c.point)).collect();
    if frontier.is_empty() {
        return Err(format!(
            "objective {} admits none of the {} legal configs",
            objective.spec_name(),
            scored.len()
        ));
    }
    frontier.sort_by(|a, b| {
        let (p, q) = (&a.predicted, &b.predicted);
        objective
            .rank(p.latency, p.throughput, q.latency, q.throughput)
            .then(a.point.warps.cmp(&b.point.warps))
            .then(a.point.ilp.cmp(&b.point.ilp))
            .then(a.workload.to_spec().cmp(&b.workload.to_spec()))
    });
    frontier.truncate(top_k);

    // Phase 3: confirm only the frontier in the cycle simulator — every
    // cell reads through the process-wide CellCache exactly like a
    // sweep cell, so repeated tunes (and later sweeps) are warm. Under
    // a budget each cell confirms independently: a blown cell yields
    // `None` and the rest keep trying (warm cells still confirm even
    // after the deadline has technically passed — only fresh simulation
    // is cut off by the up-front check in `measure_cached_budgeted`).
    let jobs: Vec<_> = frontier
        .iter()
        .map(|c| {
            let c = *c;
            move || match budget {
                Some(b) => c.workload.measure_cached_budgeted(device, c.point, backend, b).ok(),
                None => Some(c.workload.measure_cached(device, c.point, backend)),
            }
        })
        .collect();
    let measured = run_parallel(jobs, threads);

    let bound = calibration_bound(workload.kind());
    let mut configs: Vec<TunedConfig> = frontier
        .iter()
        .zip(measured)
        .map(|(c, m)| match m {
            Some(m) => TunedConfig {
                spec: c.workload.to_spec(),
                point: c.point,
                predicted: c.predicted,
                confirmed: true,
                simulated_latency: Some(m.latency),
                simulated_throughput: Some(m.throughput),
                latency_rel_err: Some(
                    (m.latency - c.predicted.latency).abs()
                        / c.predicted.latency.max(f64::MIN_POSITIVE),
                ),
                throughput_rel_err: Some(
                    (m.throughput - c.predicted.throughput).abs()
                        / c.predicted.throughput.max(f64::MIN_POSITIVE),
                ),
                within_calibration: bound
                    .map(|b| b.admits(c.predicted.latency, m.latency))
                    .unwrap_or(false),
            },
            None => TunedConfig {
                spec: c.workload.to_spec(),
                point: c.point,
                predicted: c.predicted,
                confirmed: false,
                simulated_latency: None,
                simulated_throughput: None,
                latency_rel_err: None,
                throughput_rel_err: None,
                within_calibration: false,
            },
        })
        .collect();
    // Final ranking by the *simulated* metric where available: the
    // analytic model only decided what was worth simulating. Configs
    // the budget left unconfirmed rank by their prediction — and a
    // confirmed config always outranks an unconfirmed tie.
    configs.sort_by(|a, b| {
        let metric = |c: &TunedConfig| {
            (
                c.simulated_latency.unwrap_or(c.predicted.latency),
                c.simulated_throughput.unwrap_or(c.predicted.throughput),
            )
        };
        let ((al, at), (bl, bt)) = (metric(a), metric(b));
        objective
            .rank(al, at, bl, bt)
            .then(b.confirmed.cmp(&a.confirmed))
            .then(a.point.warps.cmp(&b.point.warps))
            .then(a.point.ilp.cmp(&b.point.ilp))
            .then(a.spec.cmp(&b.spec))
    });

    let scored_n = scored.len();
    let frontier_n = configs.len();
    let confirmed = configs.iter().filter(|c| c.confirmed).count();
    Ok(TuneReport {
        workload: workload.to_spec(),
        family: workload.kind(),
        device: device.name,
        objective,
        scored: scored_n,
        confirmed,
        pruning_ratio: 1.0 - frontier_n as f64 / scored_n as f64,
        analytic_seconds,
        analytic_configs_per_sec: scored_n as f64 / analytic_seconds,
        configs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::a100;

    fn tune(spec: &str, objective: &str, top: usize) -> TuneReport {
        let w = Workload::parse_spec(spec).unwrap();
        let o = Objective::parse_spec(objective).unwrap();
        tune_workload(&w, &a100(), o, top, "sim", 2, None).unwrap()
    }

    #[test]
    fn objective_spec_round_trips() {
        for token in ["min-latency", "max-throughput", "target-occupancy:8"] {
            let o = Objective::parse_spec(token).unwrap();
            assert_eq!(o.spec_name(), token);
        }
        assert!(Objective::parse_spec("fastest").is_err());
        assert!(Objective::parse_spec("target-occupancy:").is_err());
        assert!(Objective::parse_spec("target-occupancy:0").is_err());
        assert!(Objective::parse_spec("target-occupancy:64").is_err());
    }

    #[test]
    fn mma_max_throughput_finds_the_saturated_region() {
        let r = tune("mma fp16 f32 m16n8k16", "max-throughput", 4);
        assert_eq!(r.confirmed, 4);
        assert!(r.scored >= 48, "full sweep grid, got {}", r.scored);
        assert!(r.pruning_ratio > 0.9, "{}", r.pruning_ratio);
        let top = &r.configs[0];
        // Table 3: FP16/FP32 m16n8k16 saturates from (8, 2) on — the
        // winner must be in the saturated plateau (≥ 8 warps, ≥ 16
        // concurrent chains), near the 1024 peak. The plateau ties
        // exactly at peak analytically ((8,2), (16,1), (12,2), …), so
        // the pinned region covers it rather than one coordinate.
        assert!(
            top.point.warps >= 8 && top.point.warps * top.point.ilp >= 16,
            "{:?}",
            top.point
        );
        assert!(top.simulated_throughput.unwrap() > 950.0, "{top:?}");
        for c in &r.configs {
            assert!(c.confirmed, "no budget was set: {c:?}");
            assert!(c.predicted.latency > 0.0 && c.simulated_latency.unwrap() > 0.0);
            assert!(c.within_calibration, "{c:?}");
        }
    }

    #[test]
    fn mma_min_latency_prefers_the_cheapest_tie() {
        let r = tune("mma fp16 f32 m16n8k16", "min-latency", 3);
        // ILP 1 latency is flat in #warps until the rate path binds;
        // deterministic tie-breaking must pick the 1-warp point.
        let top = &r.configs[0];
        assert_eq!((top.point.warps, top.point.ilp), (1, 1), "{:?}", top.point);
    }

    #[test]
    fn target_occupancy_caps_the_warp_budget() {
        let r = tune("mma fp16 f32 m16n8k16", "target-occupancy:4", 5);
        assert!(!r.configs.is_empty());
        for c in &r.configs {
            assert!(c.point.warps <= 4, "{:?}", c.point);
        }
        // the budget-constrained winner cannot beat the unconstrained one
        let free = tune("mma fp16 f32 m16n8k16", "max-throughput", 1);
        assert!(
            r.configs[0].simulated_throughput.unwrap()
                <= free.configs[0].simulated_throughput.unwrap() + 1e-9
        );
    }

    #[test]
    fn gemm_grid_spans_tiles_and_respects_smem_capacity() {
        let w = Workload::parse_spec("gemm pipeline bf16 f32 512 128x128x32").unwrap();
        let dev = a100();
        let cells = tuning_grid(&w, &dev).unwrap();
        let specs: std::collections::BTreeSet<&str> =
            cells.iter().map(|(w, _)| w.kind()).collect();
        assert_eq!(specs.into_iter().collect::<Vec<_>>(), ["gemm"]);
        let tiles: std::collections::BTreeSet<String> =
            cells.iter().map(|(w, _)| w.to_spec()).collect();
        assert!(tiles.len() > 1, "expected a tile axis, got {tiles:?}");
        for (w, point) in &cells {
            let Workload::Gemm(g) = w else { panic!("gemm grid") };
            let staged = g.config(*point).staged_bytes() * point.ilp as u64;
            assert!(staged <= dev.smem_bytes_per_sm as u64);
        }
    }

    #[test]
    fn gemm_tune_reports_confirmed_frontier() {
        let r = tune("gemm pipeline bf16 f32 512 128x128x32", "max-throughput", 3);
        assert_eq!(r.confirmed, 3);
        assert!(r.scored > r.confirmed);
        for c in &r.configs {
            assert!(c.spec.starts_with("gemm pipeline"));
            assert!(c.simulated_throughput.unwrap() > 0.0);
        }
        // ranked best-first by the simulated metric
        for pair in r.configs.windows(2) {
            assert!(
                pair[0].simulated_throughput.unwrap()
                    >= pair[1].simulated_throughput.unwrap() - 1e-9
            );
        }
    }

    #[test]
    fn numeric_and_zero_top_are_typed_errors() {
        let w = Workload::parse_spec("numeric chain tf32 f32 4").unwrap();
        let err =
            tune_workload(&w, &a100(), Objective::MaxThroughput, 4, "sim", 1, None).unwrap_err();
        assert!(err.contains("numeric"), "{err}");
        let m = Workload::parse_spec("mma fp16 f32 m16n8k16").unwrap();
        assert!(tune_workload(&m, &a100(), Objective::MinLatency, 0, "sim", 1, None).is_err());
    }

    #[test]
    fn expired_budget_degrades_to_predicted_only_ranking() {
        // a fresh workload spec not used by any other test in this
        // module, so the process-wide cell cache holds none of its
        // cells and the 0 ms budget cuts off every fresh simulation
        let w = Workload::parse_spec("mma fp16 f16 m16n8k8").unwrap();
        let r = tune_workload(
            &w,
            &a100(),
            Objective::MaxThroughput,
            4,
            "sim",
            2,
            Some(Budget::from_ms(0)),
        )
        .unwrap();
        assert_eq!(r.confirmed, 0, "{r:?}");
        assert_eq!(r.configs.len(), 4, "frontier still reported");
        for c in &r.configs {
            assert!(!c.confirmed);
            assert!(c.simulated_latency.is_none() && c.latency_rel_err.is_none());
            assert!(!c.within_calibration, "no pair to check: {c:?}");
            assert!(c.predicted.throughput > 0.0);
        }
        // ranked by the prediction, best first
        for pair in r.configs.windows(2) {
            assert!(pair[0].predicted.throughput >= pair[1].predicted.throughput - 1e-9);
        }
        let j = r.to_json();
        let first = &j.get("configs").unwrap().as_arr().unwrap()[0];
        assert_eq!(first.get("confirmed").unwrap().as_bool(), Some(false));
        assert!(first.get("simulated").is_none());
    }

    #[test]
    fn report_serializes_under_the_v1_schema() {
        let r = tune("ldmatrix x4", "max-throughput", 2);
        let j = r.to_json();
        assert_eq!(j.get_str("schema"), Some(TUNE_SCHEMA));
        assert_eq!(j.get_str("objective"), Some("max-throughput"));
        assert_eq!(j.get_u64("confirmed"), Some(2));
        let configs = j.get("configs").unwrap().as_arr().unwrap();
        assert_eq!(configs.len(), 2);
        for c in configs {
            assert!(c.get("predicted").unwrap().get_f64("latency").unwrap() > 0.0);
            assert!(c.get("simulated").unwrap().get_f64("latency").unwrap() > 0.0);
            assert!(c.get_f64("latency_rel_err").is_some());
            assert_eq!(c.get("confirmed").unwrap().as_bool(), Some(true));
        }
        let ratio = j.get_f64("pruning_ratio").unwrap();
        assert!((0.0..1.0).contains(&ratio), "{ratio}");
    }
}
