//! Unified workload API — one typed execution layer for every
//! microbenchmarked instruction family.
//!
//! The paper's central §2.2 contrast is between programming interfaces
//! (legacy `wmma` vs. current `mma`/`mma.sp`); this module removes the
//! same fragmentation from our own programming interface. Instead of a
//! separate family of free functions per instruction kind
//! (`measure_mma`, `sweep_ldmatrix`, `completion_latency_mma`, …) there
//! is one [`Workload`] enum covering all five microbenchmarked kinds —
//! `mma`, `mma.sp`, `ldmatrix`, `ld.shared` and `wmma` — with
//! per-variant typed parameters, a shared [`ExecPoint`] (#warps, ILP)
//! coordinate, and spec-string round-tripping
//! ([`Workload::parse_spec`] / [`Workload::to_spec`]).
//!
//! On top of it, [`Plan`] builds a [`BenchPlan`] — a batch of runnable
//! units (fixed points, a full sweep, a completion-latency probe) that a
//! [`Runner`] executes, producing a uniform [`BenchResult`] consumed by
//! [`crate::report::render_bench`] and [`crate::report::bench_to_json`].
//! The CLI `repro sweep`, the coordinator's table/figure experiments and
//! the tcserved `POST /v1/plan` endpoint are all thin translators into
//! this one path.
//!
//! ```
//! use tcbench::workload::{Plan, SimRunner, Workload};
//!
//! let w = Workload::parse_spec("mma bf16 f32 m16n8k16").unwrap();
//! let plan = Plan::new(w)
//!     .device("a100")
//!     .point(8, 2)
//!     .completion_latency()
//!     .compile()
//!     .unwrap();
//! let result = plan.run(&SimRunner, 1).unwrap();
//! assert!(result.point(8, 2).unwrap().throughput > 900.0);
//! ```

mod plan;
mod runner;

pub use plan::{BenchPlan, BenchResult, Plan, UnitKind, UnitOutput};
pub use runner::{runner_for, ArtifactRunner, Runner, SimRunner};

use std::fmt;

use crate::device::Device;
use crate::isa::{AbType, CdType, LdMatrixNum, LdSharedWidth, MmaInstr, MmaShape};
use crate::microbench::wmma::{measure_wmma, WmmaShape};
use crate::microbench::{
    measure_ld_shared_at, measure_ldmatrix, measure_mma, Measurement, Sweep, SweepCell,
    SWEEP_ILPS, SWEEP_WARPS,
};

/// One (#warps, ILP) execution coordinate — the paper's per-measurement
/// configuration, shared by every workload kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ExecPoint {
    pub warps: u32,
    pub ilp: u32,
}

impl ExecPoint {
    pub const fn new(warps: u32, ilp: u32) -> ExecPoint {
        ExecPoint { warps, ilp }
    }

    /// Range check against what the SM simulator meaningfully models
    /// (the paper sweeps warps up to 32 and ILP up to 6).
    pub fn validate(&self) -> Result<(), String> {
        if !(1..=32).contains(&self.warps) {
            return Err(format!("warps must be in 1..=32, got {}", self.warps));
        }
        if !(1..=8).contains(&self.ilp) {
            return Err(format!("ilp must be in 1..=8, got {}", self.ilp));
        }
        Ok(())
    }
}

impl fmt::Display for ExecPoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "({},{})", self.warps, self.ilp)
    }
}

/// One microbenchmarkable workload: the five instruction families of the
/// paper, each with its typed parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Workload {
    /// Dense Tensor-Core FMA (`mma.sync`, §5).
    Mma { ab: AbType, cd: CdType, shape: MmaShape },
    /// 2:4 structured-sparse FMA (`mma.sp.sync`, §6).
    MmaSp { ab: AbType, cd: CdType, shape: MmaShape },
    /// Fragment loads from shared memory (`ldmatrix.xN`, §7).
    Ldmatrix { num: LdMatrixNum },
    /// Plain shared-memory loads under `ways`-way bank conflicts
    /// (`ld.shared`, Table 10).
    LdShared { width: LdSharedWidth, ways: u32 },
    /// The legacy `wmma.mma` interface, modeled as its compiled HMMA
    /// sequence (§2.2, Fig. 2/3).
    Wmma { ab: AbType, cd: CdType, shape: WmmaShape },
}

impl Workload {
    /// Lift an [`MmaInstr`] into the workload space (`sparse` selects
    /// [`Workload::MmaSp`]).
    pub fn from_instr(instr: MmaInstr) -> Workload {
        if instr.sparse {
            Workload::MmaSp { ab: instr.ab, cd: instr.cd, shape: instr.shape }
        } else {
            Workload::Mma { ab: instr.ab, cd: instr.cd, shape: instr.shape }
        }
    }

    /// The `mma`/`mma.sp` instruction behind this workload, if any.
    pub fn mma_instr(&self) -> Option<MmaInstr> {
        match *self {
            Workload::Mma { ab, cd, shape } => Some(MmaInstr::dense(ab, cd, shape)),
            Workload::MmaSp { ab, cd, shape } => Some(MmaInstr::sp(ab, cd, shape)),
            _ => None,
        }
    }

    /// The workload family keyword (first token of the spec).
    pub fn kind(&self) -> &'static str {
        match self {
            Workload::Mma { .. } => "mma",
            Workload::MmaSp { .. } => "mma.sp",
            Workload::Ldmatrix { .. } => "ldmatrix",
            Workload::LdShared { .. } => "ld.shared",
            Workload::Wmma { .. } => "wmma",
        }
    }

    /// Unit of the throughput column (paper convention: FMA/clk/SM for
    /// compute, bytes/clk/SM for data movement).
    pub fn throughput_unit(&self) -> &'static str {
        match self {
            Workload::Mma { .. } | Workload::MmaSp { .. } | Workload::Wmma { .. } => "FMA/clk/SM",
            Workload::Ldmatrix { .. } | Workload::LdShared { .. } => "bytes/clk/SM",
        }
    }

    /// Parse a workload spec: the kind keyword followed by its typed
    /// parameters, whitespace- or comma-separated —
    ///
    /// ```text
    /// mma <ab> <cd> <shape>          mma bf16 f32 m16n8k16
    /// mma.sp <ab> <cd> <shape>       mma.sp fp16 f32 m16n8k32
    /// ldmatrix <x1|x2|x4>            ldmatrix x4   (also "ldmatrix.x4")
    /// ld.shared <u32|u64> <ways>     ld.shared u32 8
    /// wmma <ab> <cd> <shape>         wmma fp16 f32 m16n16k16
    /// ```
    ///
    /// A legacy `mma` spec without the keyword (`"<ab> <cd> <shape>
    /// [sparse]"`, as accepted by [`MmaInstr::parse_spec`]) keeps
    /// working. The exact inverse of [`Workload::to_spec`].
    pub fn parse_spec(spec: &str) -> Result<Workload, String> {
        let parts: Vec<&str> = spec
            .split(|c: char| c.is_whitespace() || c == ',')
            .filter(|s| !s.is_empty())
            .collect();
        let Some(&head) = parts.first() else {
            return Err(format!("empty workload spec {spec:?}"));
        };
        let head_lower = head.to_ascii_lowercase();
        match head_lower.as_str() {
            "mma" | "mma.sp" => {
                if parts.len() != 4 {
                    return Err(format!(
                        "{head_lower} workload spec must be \"{head_lower} <ab> <cd> <shape>\", \
                         got {spec:?}"
                    ));
                }
                let ab = AbType::parse_spec(parts[1])?;
                let cd = CdType::parse_spec(parts[2])?;
                let shape: MmaShape = parts[3].parse()?;
                Ok(if head_lower == "mma.sp" {
                    Workload::MmaSp { ab, cd, shape }
                } else {
                    Workload::Mma { ab, cd, shape }
                })
            }
            "wmma" => {
                if parts.len() != 4 {
                    return Err(format!(
                        "wmma workload spec must be \"wmma <ab> <cd> <shape>\", got {spec:?}"
                    ));
                }
                let ab = AbType::parse_spec(parts[1])?;
                let cd = CdType::parse_spec(parts[2])?;
                let s: MmaShape = parts[3].parse()?;
                Ok(Workload::Wmma { ab, cd, shape: WmmaShape { m: s.m, n: s.n, k: s.k } })
            }
            "ld.shared" => {
                if parts.len() != 3 {
                    return Err(format!(
                        "ld.shared workload spec must be \"ld.shared <u32|u64> <ways>\", \
                         got {spec:?}"
                    ));
                }
                let width = match parts[1].to_ascii_lowercase().as_str() {
                    "u32" => LdSharedWidth::U32,
                    "u64" => LdSharedWidth::U64,
                    other => return Err(format!("unknown ld.shared width {other:?} (u32|u64)")),
                };
                let ways: u32 = parts[2]
                    .parse()
                    .map_err(|_| format!("ld.shared conflict ways must be a number, got {:?}", parts[2]))?;
                Ok(Workload::LdShared { width, ways })
            }
            tok if tok == "ldmatrix" || tok.starts_with("ldmatrix.") => {
                let num_tok = if let Some(suffix) = tok.strip_prefix("ldmatrix.") {
                    if parts.len() != 1 {
                        return Err(format!(
                            "ldmatrix workload spec must be \"ldmatrix <x1|x2|x4>\", got {spec:?}"
                        ));
                    }
                    suffix.to_string()
                } else {
                    if parts.len() != 2 {
                        return Err(format!(
                            "ldmatrix workload spec must be \"ldmatrix <x1|x2|x4>\", got {spec:?}"
                        ));
                    }
                    parts[1].to_ascii_lowercase()
                };
                let num = match num_tok.as_str() {
                    "x1" | "1" => LdMatrixNum::X1,
                    "x2" | "2" => LdMatrixNum::X2,
                    "x4" | "4" => LdMatrixNum::X4,
                    other => return Err(format!("unknown ldmatrix num {other:?} (x1|x2|x4)")),
                };
                Ok(Workload::Ldmatrix { num })
            }
            _ => MmaInstr::parse_spec(spec).map(Workload::from_instr).map_err(|e| {
                format!(
                    "{e} (or start the spec with a workload kind: \
                     mma | mma.sp | ldmatrix | ld.shared | wmma)"
                )
            }),
        }
    }

    /// Canonical spec string — round-trips through
    /// [`Workload::parse_spec`] and carries *every* parameter of the
    /// workload, so it is safe to use as a cache-key coordinate.
    pub fn to_spec(&self) -> String {
        match *self {
            Workload::Mma { ab, cd, shape } => {
                format!("mma {} {} {}", ab.spec_name(), cd.spec_name(), shape)
            }
            Workload::MmaSp { ab, cd, shape } => {
                format!("mma.sp {} {} {}", ab.spec_name(), cd.spec_name(), shape)
            }
            Workload::Ldmatrix { num } => format!("ldmatrix x{}", num.count()),
            Workload::LdShared { width, ways } => {
                let w = match width {
                    LdSharedWidth::U32 => "u32",
                    LdSharedWidth::U64 => "u64",
                };
                format!("ld.shared {w} {ways}")
            }
            Workload::Wmma { ab, cd, shape } => format!(
                "wmma {} {} m{}n{}k{}",
                ab.spec_name(),
                cd.spec_name(),
                shape.m,
                shape.n,
                shape.k
            ),
        }
    }

    /// Is this workload well-formed and runnable on `device`? Returns a
    /// user-facing reason when not.
    pub fn validate(&self, device: &Device) -> Result<(), String> {
        match *self {
            Workload::Mma { .. } | Workload::MmaSp { .. } => {
                let instr = self.mma_instr().expect("mma workload");
                if !instr.is_well_formed() {
                    Err(format!(
                        "{instr} is not well-formed (illegal operand/accumulator pairing)"
                    ))
                } else if !device.supports(&instr) {
                    Err(format!("{instr} is not supported on {}", device.name))
                } else {
                    Ok(())
                }
            }
            Workload::Ldmatrix { .. } => {
                if device.arch.supports_ldmatrix() {
                    Ok(())
                } else {
                    Err(format!(
                        "ldmatrix is not available on {} ({:?})",
                        device.name, device.arch
                    ))
                }
            }
            Workload::LdShared { width, ways } => {
                if !(1..=32).contains(&ways) || !ways.is_power_of_two() {
                    return Err(format!(
                        "ld.shared conflict ways must be a power of two in 1..=32, got {ways}"
                    ));
                }
                if ways < width.min_transactions() {
                    return Err(format!(
                        "{width} is intrinsically {}-transaction wide; ways must be >= {}",
                        width.min_transactions(),
                        width.min_transactions()
                    ));
                }
                Ok(())
            }
            Workload::Wmma { ab, cd, shape } => {
                // compiled_mmas fragments along n into m x 8 x k pieces,
                // so any other n would silently measure (and cache) a
                // different workload than the one named
                if shape.m == 0 || shape.k == 0 || shape.n == 0 || shape.n % 8 != 0 {
                    return Err(format!(
                        "wmma shape m{}n{}k{} is not fragmentable: m and k must be \
                         positive and n a positive multiple of 8",
                        shape.m, shape.n, shape.k
                    ));
                }
                for piece in shape.compiled_mmas(ab, cd) {
                    if !piece.is_well_formed() {
                        return Err(format!(
                            "wmma piece {piece} is not well-formed \
                             (illegal operand/accumulator pairing)"
                        ));
                    }
                    if !device.supports(&piece) {
                        return Err(format!(
                            "wmma compiles to {piece}, which is not supported on {}",
                            device.name
                        ));
                    }
                }
                Ok(())
            }
        }
    }

    /// Measure this workload at one (#warps, ILP) point on the cycle
    /// simulator. Panics on workloads the device does not support — call
    /// [`Workload::validate`] first (the [`Plan`] compiler does).
    pub fn measure(&self, device: &Device, point: ExecPoint) -> Measurement {
        let ExecPoint { warps, ilp } = point;
        match *self {
            Workload::Mma { .. } | Workload::MmaSp { .. } => {
                measure_mma(device, &self.mma_instr().expect("mma workload"), warps, ilp)
            }
            Workload::Ldmatrix { num } => measure_ldmatrix(device, num, warps, ilp),
            Workload::LdShared { width, ways } => {
                measure_ld_shared_at(device, width, ways, warps, ilp)
            }
            Workload::Wmma { ab, cd, shape } => measure_wmma(device, shape, ab, cd, warps, ilp),
        }
    }

    /// Completion/issue latency (§4 step 1): one warp, ILP = 1.
    pub fn completion_latency(&self, device: &Device) -> f64 {
        self.measure(device, ExecPoint::new(1, 1)).latency
    }

    /// Full (ILP, #warps) grid over the paper's sweep axes (§4 step 2) —
    /// one code path for all five workload kinds.
    pub fn sweep(&self, device: &Device) -> Sweep {
        let mut cells = Vec::with_capacity(SWEEP_WARPS.len() * SWEEP_ILPS.len());
        for &warps in &SWEEP_WARPS {
            for &ilp in &SWEEP_ILPS {
                let m = self.measure(device, ExecPoint::new(warps, ilp));
                cells.push(SweepCell {
                    warps,
                    ilp,
                    latency: m.latency,
                    throughput: m.throughput,
                });
            }
        }
        Sweep {
            label: self.to_string(),
            warps_axis: SWEEP_WARPS.to_vec(),
            ilp_axis: SWEEP_ILPS.to_vec(),
            cells,
        }
    }
}

impl fmt::Display for Workload {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            Workload::Mma { .. } | Workload::MmaSp { .. } => {
                write!(f, "{}", self.mma_instr().expect("mma workload"))
            }
            Workload::Ldmatrix { num } => write!(f, "{num}"),
            Workload::LdShared { width, ways } => write!(f, "{width} ({ways}-way)"),
            Workload::Wmma { ab, cd, shape } => {
                write!(f, "wmma.m{}n{}k{} {ab}/{cd}", shape.m, shape.n, shape.k)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{a100, rtx2080ti};
    use crate::isa::shapes::*;
    use crate::microbench::{measure_ld_shared, sweep_mma};

    fn all_kinds() -> Vec<Workload> {
        vec![
            Workload::Mma { ab: AbType::Bf16, cd: CdType::Fp32, shape: M16N8K16 },
            Workload::MmaSp { ab: AbType::Fp16, cd: CdType::Fp32, shape: M16N8K32 },
            Workload::Ldmatrix { num: LdMatrixNum::X4 },
            Workload::LdShared { width: LdSharedWidth::U64, ways: 8 },
            Workload::Wmma {
                ab: AbType::Fp16,
                cd: CdType::Fp32,
                shape: WmmaShape { m: 16, n: 16, k: 16 },
            },
        ]
    }

    #[test]
    fn spec_round_trips_for_all_five_kinds() {
        for w in all_kinds() {
            let spec = w.to_spec();
            let parsed = Workload::parse_spec(&spec)
                .unwrap_or_else(|e| panic!("{spec:?} failed to re-parse: {e}"));
            assert_eq!(parsed, w, "{spec:?}");
            assert_eq!(parsed.to_spec(), spec);
        }
    }

    #[test]
    fn parse_accepts_aliases_and_legacy_mma_specs() {
        // legacy MmaInstr specs (no kind keyword) still parse
        let legacy = Workload::parse_spec("bf16,f32,m16n8k16").unwrap();
        assert_eq!(
            legacy,
            Workload::Mma { ab: AbType::Bf16, cd: CdType::Fp32, shape: M16N8K16 }
        );
        let sp = Workload::parse_spec("fp16 f32 m16n8k32 sparse").unwrap();
        assert_eq!(sp.kind(), "mma.sp");
        // ldmatrix display form parses back
        assert_eq!(
            Workload::parse_spec("ldmatrix.x2").unwrap(),
            Workload::Ldmatrix { num: LdMatrixNum::X2 }
        );
        assert_eq!(
            Workload::parse_spec("ldmatrix 4").unwrap(),
            Workload::Ldmatrix { num: LdMatrixNum::X4 }
        );
        // kind keywords are case-insensitive
        assert_eq!(
            Workload::parse_spec("LD.SHARED u32 8").unwrap(),
            Workload::LdShared { width: LdSharedWidth::U32, ways: 8 }
        );
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(Workload::parse_spec("").is_err());
        assert!(Workload::parse_spec("mma bf16 f32").is_err());
        assert!(Workload::parse_spec("mma.sp bf16 f32 m16n8k16 extra").is_err());
        assert!(Workload::parse_spec("ldmatrix x8").is_err());
        assert!(Workload::parse_spec("ld.shared u128 2").is_err());
        assert!(Workload::parse_spec("ld.shared u32 many").is_err());
        assert!(Workload::parse_spec("wmma fp16 f32").is_err());
        // unknown head falls through to the legacy parser, whose error
        // mentions the workload-kind syntax
        let err = Workload::parse_spec("garbage").unwrap_err();
        assert!(err.contains("mma | mma.sp | ldmatrix | ld.shared | wmma"), "{err}");
    }

    #[test]
    fn validate_enforces_device_legality() {
        let ampere = a100();
        let turing = rtx2080ti();
        for w in all_kinds() {
            assert!(w.validate(&ampere).is_ok(), "{w} should be valid on a100");
        }
        // no sparse Tensor Cores on Turing
        let sp = Workload::MmaSp { ab: AbType::Fp16, cd: CdType::Fp32, shape: M16N8K32 };
        assert!(sp.validate(&turing).unwrap_err().contains("not supported"));
        // wmma pieces must exist in the device's calibration
        let wmma = Workload::Wmma {
            ab: AbType::Fp16,
            cd: CdType::Fp32,
            shape: WmmaShape { m: 16, n: 16, k: 16 },
        };
        assert!(wmma.validate(&turing).unwrap_err().contains("wmma"));
        // conflict ways must be a power of two, and u64 is 2-way minimum
        let odd = Workload::LdShared { width: LdSharedWidth::U32, ways: 3 };
        assert!(odd.validate(&ampere).unwrap_err().contains("power of two"));
        let narrow = Workload::LdShared { width: LdSharedWidth::U64, ways: 1 };
        assert!(narrow.validate(&ampere).unwrap_err().contains("ways must be >= 2"));
        // wmma shapes must fragment exactly into n=8 pieces — anything
        // else would mislabel the measured workload
        for (m, n, k) in [(16, 9, 16), (16, 0, 16), (0, 16, 16), (16, 12, 16)] {
            let w = Workload::Wmma {
                ab: AbType::Fp16,
                cd: CdType::Fp32,
                shape: WmmaShape { m, n, k },
            };
            assert!(
                w.validate(&ampere).unwrap_err().contains("fragmentable"),
                "m{m}n{n}k{k} must be rejected"
            );
        }
        // malformed pairing is caught before the device lookup
        let bad = Workload::Mma { ab: AbType::Bf16, cd: CdType::Fp16, shape: M16N8K16 };
        assert!(bad.validate(&ampere).unwrap_err().contains("well-formed"));
    }

    #[test]
    fn measure_matches_the_legacy_free_functions() {
        let d = a100();
        let w = Workload::Mma { ab: AbType::Fp16, cd: CdType::Fp32, shape: M16N8K16 };
        let via_workload = w.measure(&d, ExecPoint::new(8, 2));
        let via_free = crate::microbench::measure_mma(
            &d,
            &MmaInstr::dense(AbType::Fp16, CdType::Fp32, M16N8K16),
            8,
            2,
        );
        assert_eq!(via_workload, via_free);

        let ld = Workload::LdShared { width: LdSharedWidth::U32, ways: 4 };
        assert_eq!(
            ld.measure(&d, ExecPoint::new(1, 1)),
            measure_ld_shared(&d, LdSharedWidth::U32, 4)
        );
    }

    #[test]
    fn workload_sweep_matches_legacy_sweep_mma() {
        let d = a100();
        let instr = MmaInstr::dense(AbType::Bf16, CdType::Fp32, M16N8K16);
        let via_workload = Workload::from_instr(instr).sweep(&d);
        let via_free = sweep_mma(&d, &instr);
        assert_eq!(via_workload.cells.len(), via_free.cells.len());
        for (a, b) in via_workload.cells.iter().zip(&via_free.cells) {
            assert_eq!((a.warps, a.ilp), (b.warps, b.ilp));
            assert_eq!(a.latency, b.latency);
            assert_eq!(a.throughput, b.throughput);
        }
    }

    #[test]
    fn completion_latency_is_the_1_1_point() {
        let d = a100();
        let w = Workload::Ldmatrix { num: LdMatrixNum::X1 };
        let lat = w.completion_latency(&d);
        assert!((lat - 23.0).abs() < 1.5, "{lat}"); // Table 9
    }

    #[test]
    fn exec_point_validation() {
        assert!(ExecPoint::new(4, 3).validate().is_ok());
        assert!(ExecPoint::new(0, 1).validate().is_err());
        assert!(ExecPoint::new(33, 1).validate().is_err());
        assert!(ExecPoint::new(4, 0).validate().is_err());
        assert!(ExecPoint::new(4, 9).validate().is_err());
    }
}
