//! Unified workload API — one typed execution layer for every
//! microbenchmarked instruction family.
//!
//! The paper's central §2.2 contrast is between programming interfaces
//! (legacy `wmma` vs. current `mma`/`mma.sp`); this module removes the
//! same fragmentation from our own programming interface. Instead of a
//! separate family of free functions per instruction kind
//! (`measure_mma`, `sweep_ldmatrix`, `completion_latency_mma`, …) there
//! is one [`Workload`] enum covering all seven benchmarked kinds —
//! `mma`, `mma.sp`, `ldmatrix`, `ld.shared`, `wmma`, the Appendix-A
//! `gemm` pipeline and the §8 `numeric` behavior probes — with
//! per-variant typed parameters, a shared [`ExecPoint`] coordinate, and
//! spec-string round-tripping ([`Workload::parse_spec`] /
//! [`Workload::to_spec`]).
//!
//! The exec point is (#warps, ILP) for the instruction families; for
//! `gemm` the same coordinate reads as (CTA warps, `cp.async` pipeline
//! stages), so tables 16/17 and arbitrary tile-pipeline sweeps run
//! through the identical plan/cache machinery. `numeric` probes carry
//! every parameter in the spec and pin the point to `(1,1)`; their
//! backend (native softfloat vs PJRT artifacts) is the [`Runner`]'s
//! numeric leg, so tables 12–15 and Fig. 17 cache and single-flight
//! like every other unit.
//!
//! On top of it, [`Plan`] builds a [`BenchPlan`] — a batch of runnable
//! units (fixed points, a full sweep, a completion-latency probe) that a
//! [`Runner`] executes, producing a uniform [`BenchResult`] consumed by
//! [`crate::report::render_bench`] and [`crate::report::bench_to_json`].
//! The CLI `repro sweep`, the coordinator's table/figure experiments and
//! the tcserved `POST /v1/plan` endpoint are all thin translators into
//! this one path.
//!
//! Below the unit layer sits the **cell-level execution engine**: the
//! unit of scheduling and caching is one (workload, device, point,
//! backend) *cell* simulation. Sweep units decompose into per-cell jobs
//! fanned out over the coordinator worker pool
//! ([`Workload::sweep_via`]), and every timing cell — whether requested
//! by a point unit, a sweep cell or the completion probe — reads
//! through the process-wide, content-addressed [`CellCache`]
//! ([`Workload::measure_cached`]), so a `Point(4,2)` unit after a sweep
//! is a cache hit, `completion_latency` reuses cell (1,1), and
//! overlapping experiments stop re-simulating shared cells.
//!
//! ```
//! use tcbench::workload::{Plan, SimRunner, Workload};
//!
//! let w = Workload::parse_spec("mma bf16 f32 m16n8k16").unwrap();
//! let plan = Plan::new(w)
//!     .device("a100")
//!     .point(8, 2)
//!     .completion_latency()
//!     .compile()
//!     .unwrap();
//! let result = plan.run(&SimRunner, 1).unwrap();
//! assert!(result.point(8, 2).unwrap().throughput > 900.0);
//! ```

mod cell;
mod numeric;
mod plan;
mod runner;
mod tune;

pub use cell::{
    cell_cache_stats, cell_store_stats, CellCache, CellCacheStats, CellStore, CellStoreStats,
    DEFAULT_CELL_CAPACITY,
};
pub use numeric::{
    AccDtype, NumericOutput, NumericProbe, ProbeDtype, ProbeKind, CHAIN_MAX_LEN, CHAIN_SEED,
    CHAIN_TRIALS, PROFILE_SEED, PROFILE_TRIALS,
};
pub use plan::{BenchPlan, BenchResult, LintRecord, Plan, UnitKind, UnitOutput};
pub use runner::{
    run_unit_budgeted, runner_for, ArtifactRunner, Runner, SimRunner, UnitError, UnitRun,
};
pub use tune::{
    tune_workload, Objective, TuneReport, TunedConfig, DEFAULT_TUNE_TOP_K, GEMM_TUNE_TILES,
    TUNE_SCHEMA,
};

use std::fmt;
use std::sync::Arc;

use crate::coordinator::{default_threads, run_parallel};
use crate::device::Device;
use crate::gemm::{self, GemmConfig};
use crate::isa::{AbType, CdType, LdMatrixNum, LdSharedWidth, MmaInstr, MmaShape};
use crate::microbench::wmma::{measure_wmma_profiled, wmma_program, WmmaShape};
use crate::microbench::{
    ld_shared_program, ldmatrix_program, measure_ld_shared_at_profiled,
    measure_ldmatrix_profiled, measure_mma_profiled, mma_program, Measurement, Sweep,
    SweepCell, ITERS, SWEEP_ILPS, SWEEP_WARPS,
};
use crate::sim::{
    budget, predict_gemm, predict_ld_shared, predict_ldmatrix, predict_mma, predict_wmma,
    AnalyticPrediction, Budget, BudgetBlown, ProfileMode, Profiler, SimProfile, WarpProgram,
};

/// One (#warps, ILP) execution coordinate — the paper's per-measurement
/// configuration, shared by every workload kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ExecPoint {
    pub warps: u32,
    pub ilp: u32,
}

impl ExecPoint {
    pub const fn new(warps: u32, ilp: u32) -> ExecPoint {
        ExecPoint { warps, ilp }
    }

    /// Range check against what the SM simulator meaningfully models
    /// (the paper sweeps warps up to 32 and ILP up to 6).
    pub fn validate(&self) -> Result<(), String> {
        if !(1..=32).contains(&self.warps) {
            return Err(format!("warps must be in 1..=32, got {}", self.warps));
        }
        if !(1..=8).contains(&self.ilp) {
            return Err(format!("ilp must be in 1..=8, got {}", self.ilp));
        }
        Ok(())
    }
}

impl fmt::Display for ExecPoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "({},{})", self.warps, self.ilp)
    }
}

/// Pipeline-stage axis of a gemm sweep (the `ilp` coordinate of its
/// [`Sweep`] grid): depths 1 (fully synchronous `cp.async`) through 4.
pub const GEMM_SWEEP_STAGES: [u32; 4] = [1, 2, 3, 4];

/// Typed parameters of a [`Workload::Gemm`]: everything that *names* the
/// problem. The execution coordinates — CTA warp count and `cp.async`
/// stage depth — ride in the [`ExecPoint`] instead, exactly like #warps
/// and ILP do for the instruction families, so the per-unit cache token
/// (spec + point) carries every parameter.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GemmParams {
    pub variant: gemm::Variant,
    /// A/B element type (16-bit: bf16 or fp16).
    pub ab: AbType,
    /// Accumulator type.
    pub cd: CdType,
    /// Square problem dimension (the paper's experiment is 2048^3).
    pub size: u32,
    pub tile_m: u32,
    pub tile_n: u32,
    pub tile_k: u32,
    /// Run in the L2-resident memory regime (Table 17's layout
    /// experiment isolates on-chip behaviour).
    pub l2_resident: bool,
}

impl GemmParams {
    /// The paper's canonical Appendix-A problem: 2048^3 BF16/FP32 with a
    /// 128x128x32 CTA tile.
    pub fn paper(variant: gemm::Variant, l2_resident: bool) -> GemmParams {
        GemmParams {
            variant,
            ab: AbType::Bf16,
            cd: CdType::Fp32,
            size: 2048,
            tile_m: 128,
            tile_n: 128,
            tile_k: 32,
            l2_resident,
        }
    }

    /// Materialize the kernel configuration at one execution point
    /// (warps = CTA warp count, ilp = pipeline stages).
    pub fn config(&self, point: ExecPoint) -> GemmConfig {
        GemmConfig {
            ab: self.ab,
            cd: self.cd,
            size: self.size,
            tile_m: self.tile_m,
            tile_n: self.tile_n,
            tile_k: self.tile_k,
            warps: point.warps,
            stages: point.ilp,
        }
    }
}

/// One benchmarkable workload: the five instruction families of the
/// paper plus the Appendix-A GEMM pipeline, each with its typed
/// parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Workload {
    /// Dense Tensor-Core FMA (`mma.sync`, §5).
    Mma { ab: AbType, cd: CdType, shape: MmaShape },
    /// 2:4 structured-sparse FMA (`mma.sp.sync`, §6).
    MmaSp { ab: AbType, cd: CdType, shape: MmaShape },
    /// Fragment loads from shared memory (`ldmatrix.xN`, §7).
    Ldmatrix { num: LdMatrixNum },
    /// Plain shared-memory loads under `ways`-way bank conflicts
    /// (`ld.shared`, Table 10).
    LdShared { width: LdSharedWidth, ways: u32 },
    /// The legacy `wmma.mma` interface, modeled as its compiled HMMA
    /// sequence (§2.2, Fig. 2/3).
    Wmma { ab: AbType, cd: CdType, shape: WmmaShape },
    /// The Appendix-A tiled GEMM pipeline (tables 16/17): one kernel
    /// variant at one problem/tile configuration, executed at
    /// (CTA warps, stages) points.
    Gemm(GemmParams),
    /// A §8 numeric-behavior study (Tables 12–15 profiling, Fig. 17
    /// chain matmul). No (#warps, ILP) coordinate — every parameter is
    /// in the probe; runs on a [`Runner`]'s numeric leg.
    Numeric(NumericProbe),
}

impl Workload {
    /// Lift an [`MmaInstr`] into the workload space (`sparse` selects
    /// [`Workload::MmaSp`]).
    pub fn from_instr(instr: MmaInstr) -> Workload {
        if instr.sparse {
            Workload::MmaSp { ab: instr.ab, cd: instr.cd, shape: instr.shape }
        } else {
            Workload::Mma { ab: instr.ab, cd: instr.cd, shape: instr.shape }
        }
    }

    /// The `mma`/`mma.sp` instruction behind this workload, if any.
    pub fn mma_instr(&self) -> Option<MmaInstr> {
        match *self {
            Workload::Mma { ab, cd, shape } => Some(MmaInstr::dense(ab, cd, shape)),
            Workload::MmaSp { ab, cd, shape } => Some(MmaInstr::sp(ab, cd, shape)),
            _ => None,
        }
    }

    /// The workload family keyword (first token of the spec).
    pub fn kind(&self) -> &'static str {
        match self {
            Workload::Mma { .. } => "mma",
            Workload::MmaSp { .. } => "mma.sp",
            Workload::Ldmatrix { .. } => "ldmatrix",
            Workload::LdShared { .. } => "ld.shared",
            Workload::Wmma { .. } => "wmma",
            Workload::Gemm { .. } => "gemm",
            Workload::Numeric { .. } => "numeric",
        }
    }

    /// Unit of the throughput column (paper convention: FMA/clk/SM for
    /// compute, bytes/clk/SM for data movement; the numeric probes
    /// measure errors, not rates).
    pub fn throughput_unit(&self) -> &'static str {
        match self {
            Workload::Mma { .. }
            | Workload::MmaSp { .. }
            | Workload::Wmma { .. }
            | Workload::Gemm { .. } => "FMA/clk/SM",
            Workload::Ldmatrix { .. } | Workload::LdShared { .. } => "bytes/clk/SM",
            Workload::Numeric(p) => match p.kind {
                ProbeKind::Profile { .. } => "mean |err|",
                ProbeKind::Chain { .. } => "l2 rel err",
            },
        }
    }

    /// Parse a workload spec: the kind keyword followed by its typed
    /// parameters, whitespace- or comma-separated —
    ///
    /// ```text
    /// mma <ab> <cd> <shape>          mma bf16 f32 m16n8k16
    /// mma.sp <ab> <cd> <shape>       mma.sp fp16 f32 m16n8k32
    /// ldmatrix <x1|x2|x4>            ldmatrix x4   (also "ldmatrix.x4")
    /// ld.shared <u32|u64> <ways>     ld.shared u32 8
    /// wmma <ab> <cd> <shape>         wmma fp16 f32 m16n16k16
    /// gemm <variant> <ab> <cd> <size> <MxNxK> [l2]
    ///                                gemm pipeline bf16 f32 2048 128x128x32
    /// numeric profile <ab> <cd> <op> [init]
    ///                                numeric profile bf16 f32 acc fp32
    /// numeric chain <ab> <cd> <len> [init]
    ///                                numeric chain tf32 f32 14
    /// ```
    ///
    /// The gemm variant is `baseline`, `pipeline` or `permuted`; the
    /// trailing `l2` token selects the L2-resident memory regime
    /// (Table 17). CTA warps and pipeline stages are *not* part of the
    /// spec — they are the plan's execution coordinates. Numeric probes
    /// are the opposite: every parameter is in the spec (op ∈
    /// `mul|inner|acc`, init ∈ `low|fp32` defaulting to `low`) and the
    /// only legal execution point is `(1,1)`.
    ///
    /// A legacy `mma` spec without the keyword (`"<ab> <cd> <shape>
    /// [sparse]"`, as accepted by [`MmaInstr::parse_spec`]) keeps
    /// working. The exact inverse of [`Workload::to_spec`].
    pub fn parse_spec(spec: &str) -> Result<Workload, String> {
        let parts: Vec<&str> = spec
            .split(|c: char| c.is_whitespace() || c == ',')
            .filter(|s| !s.is_empty())
            .collect();
        let Some(&head) = parts.first() else {
            return Err(format!("empty workload spec {spec:?}"));
        };
        let head_lower = head.to_ascii_lowercase();
        match head_lower.as_str() {
            "mma" | "mma.sp" => {
                if parts.len() != 4 {
                    return Err(format!(
                        "{head_lower} workload spec must be \"{head_lower} <ab> <cd> <shape>\", \
                         got {spec:?}"
                    ));
                }
                let ab = AbType::parse_spec(parts[1])?;
                let cd = CdType::parse_spec(parts[2])?;
                let shape: MmaShape = parts[3].parse()?;
                Ok(if head_lower == "mma.sp" {
                    Workload::MmaSp { ab, cd, shape }
                } else {
                    Workload::Mma { ab, cd, shape }
                })
            }
            "wmma" => {
                if parts.len() != 4 {
                    return Err(format!(
                        "wmma workload spec must be \"wmma <ab> <cd> <shape>\", got {spec:?}"
                    ));
                }
                let ab = AbType::parse_spec(parts[1])?;
                let cd = CdType::parse_spec(parts[2])?;
                let s: MmaShape = parts[3].parse()?;
                Ok(Workload::Wmma { ab, cd, shape: WmmaShape { m: s.m, n: s.n, k: s.k } })
            }
            "gemm" => {
                if parts.len() != 6 && parts.len() != 7 {
                    return Err(format!(
                        "gemm workload spec must be \
                         \"gemm <baseline|pipeline|permuted> <ab> <cd> <size> <MxNxK> [l2]\", \
                         got {spec:?}"
                    ));
                }
                let variant = gemm::Variant::parse_spec(parts[1])?;
                let ab = AbType::parse_spec(parts[2])?;
                let cd = CdType::parse_spec(parts[3])?;
                let size: u32 = parts[4]
                    .parse()
                    .map_err(|_| format!("gemm size must be a number, got {:?}", parts[4]))?;
                let (tile_m, tile_n, tile_k) = Self::parse_gemm_tile(parts[5])?;
                let l2_resident = match parts.get(6) {
                    None => false,
                    Some(tok) if tok.eq_ignore_ascii_case("l2") => true,
                    Some(other) => {
                        return Err(format!(
                            "unknown gemm spec token {other:?} (only \"l2\" may follow the tile)"
                        ))
                    }
                };
                Ok(Workload::Gemm(GemmParams {
                    variant,
                    ab,
                    cd,
                    size,
                    tile_m,
                    tile_n,
                    tile_k,
                    l2_resident,
                }))
            }
            "numeric" => NumericProbe::parse_tokens(&parts[1..]).map(Workload::Numeric),
            "ld.shared" => {
                if parts.len() != 3 {
                    return Err(format!(
                        "ld.shared workload spec must be \"ld.shared <u32|u64> <ways>\", \
                         got {spec:?}"
                    ));
                }
                let width = match parts[1].to_ascii_lowercase().as_str() {
                    "u32" => LdSharedWidth::U32,
                    "u64" => LdSharedWidth::U64,
                    other => return Err(format!("unknown ld.shared width {other:?} (u32|u64)")),
                };
                let ways: u32 = parts[2]
                    .parse()
                    .map_err(|_| format!("ld.shared conflict ways must be a number, got {:?}", parts[2]))?;
                Ok(Workload::LdShared { width, ways })
            }
            tok if tok == "ldmatrix" || tok.starts_with("ldmatrix.") => {
                let num_tok = if let Some(suffix) = tok.strip_prefix("ldmatrix.") {
                    if parts.len() != 1 {
                        return Err(format!(
                            "ldmatrix workload spec must be \"ldmatrix <x1|x2|x4>\", got {spec:?}"
                        ));
                    }
                    suffix.to_string()
                } else {
                    if parts.len() != 2 {
                        return Err(format!(
                            "ldmatrix workload spec must be \"ldmatrix <x1|x2|x4>\", got {spec:?}"
                        ));
                    }
                    parts[1].to_ascii_lowercase()
                };
                let num = match num_tok.as_str() {
                    "x1" | "1" => LdMatrixNum::X1,
                    "x2" | "2" => LdMatrixNum::X2,
                    "x4" | "4" => LdMatrixNum::X4,
                    other => return Err(format!("unknown ldmatrix num {other:?} (x1|x2|x4)")),
                };
                Ok(Workload::Ldmatrix { num })
            }
            _ => MmaInstr::parse_spec(spec).map(Workload::from_instr).map_err(|e| {
                format!(
                    "{e} (or start the spec with a workload kind: \
                     mma | mma.sp | ldmatrix | ld.shared | wmma | gemm | numeric)"
                )
            }),
        }
    }

    /// Parse the `<M>x<N>x<K>` tile token of a gemm workload spec.
    fn parse_gemm_tile(token: &str) -> Result<(u32, u32, u32), String> {
        let dims: Vec<&str> = token.split(['x', 'X']).collect();
        if dims.len() != 3 {
            return Err(format!("gemm tile must be <M>x<N>x<K> (e.g. 128x128x32), got {token:?}"));
        }
        let parse = |s: &str, what: &str| -> Result<u32, String> {
            s.parse::<u32>()
                .map_err(|_| format!("gemm tile {what} must be a number, got {s:?} in {token:?}"))
        };
        Ok((parse(dims[0], "M")?, parse(dims[1], "N")?, parse(dims[2], "K")?))
    }

    /// Canonical spec string — round-trips through
    /// [`Workload::parse_spec`] and carries *every* parameter of the
    /// workload, so it is safe to use as a cache-key coordinate.
    pub fn to_spec(&self) -> String {
        match *self {
            Workload::Mma { ab, cd, shape } => {
                format!("mma {} {} {}", ab.spec_name(), cd.spec_name(), shape)
            }
            Workload::MmaSp { ab, cd, shape } => {
                format!("mma.sp {} {} {}", ab.spec_name(), cd.spec_name(), shape)
            }
            Workload::Ldmatrix { num } => format!("ldmatrix x{}", num.count()),
            Workload::LdShared { width, ways } => {
                let w = match width {
                    LdSharedWidth::U32 => "u32",
                    LdSharedWidth::U64 => "u64",
                };
                format!("ld.shared {w} {ways}")
            }
            Workload::Wmma { ab, cd, shape } => format!(
                "wmma {} {} m{}n{}k{}",
                ab.spec_name(),
                cd.spec_name(),
                shape.m,
                shape.n,
                shape.k
            ),
            Workload::Gemm(g) => format!(
                "gemm {} {} {} {} {}x{}x{}{}",
                g.variant.spec_name(),
                g.ab.spec_name(),
                g.cd.spec_name(),
                g.size,
                g.tile_m,
                g.tile_n,
                g.tile_k,
                if g.l2_resident { " l2" } else { "" }
            ),
            Workload::Numeric(p) => p.to_spec(),
        }
    }

    /// Is this workload well-formed and runnable on `device`? Returns a
    /// user-facing reason when not.
    pub fn validate(&self, device: &Device) -> Result<(), String> {
        match *self {
            Workload::Mma { .. } | Workload::MmaSp { .. } => {
                let instr = self.mma_instr().expect("mma workload");
                if !instr.is_well_formed() {
                    Err(format!(
                        "{instr} is not well-formed (illegal operand/accumulator pairing)"
                    ))
                } else if !device.supports(&instr) {
                    Err(format!("{instr} is not supported on {}", device.name))
                } else {
                    Ok(())
                }
            }
            Workload::Ldmatrix { .. } => {
                if device.arch.supports_ldmatrix() {
                    Ok(())
                } else {
                    Err(format!(
                        "ldmatrix is not available on {} ({:?})",
                        device.name, device.arch
                    ))
                }
            }
            Workload::LdShared { width, ways } => {
                if !(1..=32).contains(&ways) || !ways.is_power_of_two() {
                    return Err(format!(
                        "ld.shared conflict ways must be a power of two in 1..=32, got {ways}"
                    ));
                }
                if ways < width.min_transactions() {
                    return Err(format!(
                        "{width} is intrinsically {}-transaction wide; ways must be >= {}",
                        width.min_transactions(),
                        width.min_transactions()
                    ));
                }
                Ok(())
            }
            Workload::Wmma { ab, cd, shape } => {
                // compiled_mmas fragments along n into m x 8 x k pieces,
                // so any other n would silently measure (and cache) a
                // different workload than the one named
                if shape.m == 0 || shape.k == 0 || shape.n == 0 || shape.n % 8 != 0 {
                    return Err(format!(
                        "wmma shape m{}n{}k{} is not fragmentable: m and k must be \
                         positive and n a positive multiple of 8",
                        shape.m, shape.n, shape.k
                    ));
                }
                for piece in shape.compiled_mmas(ab, cd) {
                    if !piece.is_well_formed() {
                        return Err(format!(
                            "wmma piece {piece} is not well-formed \
                             (illegal operand/accumulator pairing)"
                        ));
                    }
                    if !device.supports(&piece) {
                        return Err(format!(
                            "wmma compiles to {piece}, which is not supported on {}",
                            device.name
                        ));
                    }
                }
                Ok(())
            }
            Workload::Gemm(g) => {
                // Static shape/size legality at the weakest (1-warp) grid;
                // stricter warp-grid divisibility is per execution point
                // (validate_point).
                let cfg = g.config(ExecPoint::new(1, 1));
                cfg.validate()?;
                let instr = cfg.instr();
                if !instr.is_well_formed() {
                    return Err(format!(
                        "gemm compute instruction {instr} is not well-formed \
                         (illegal operand/accumulator pairing)"
                    ));
                }
                if !device.supports(&instr) {
                    return Err(format!(
                        "gemm needs {instr}, which is not supported on {}",
                        device.name
                    ));
                }
                if g.variant == gemm::Variant::Pipeline && !device.arch.supports_cp_async() {
                    return Err(format!(
                        "the gemm pipeline variant needs cp.async, which {} ({:?}) lacks",
                        device.name, device.arch
                    ));
                }
                Ok(())
            }
            Workload::Numeric(p) => p.validate(device),
        }
    }

    /// Is `point` a legal execution coordinate for this workload? The
    /// instruction families accept any in-range (#warps, ILP); gemm
    /// additionally requires the warp count to map onto the tile's warp
    /// grid (power of two, divisibility) with `ilp` read as the
    /// `cp.async` stage depth.
    pub fn validate_point(&self, point: ExecPoint) -> Result<(), String> {
        point.validate()?;
        if let Workload::Numeric(_) = self {
            // every probe parameter lives in the spec; pinning the point
            // keeps exactly one cache token per probe
            if point != ExecPoint::new(1, 1) {
                return Err(format!(
                    "numeric probes have no (#warps, ILP) coordinate; the only legal \
                     point is (1,1), got {point}"
                ));
            }
            return Ok(());
        }
        if let Workload::Gemm(g) = self {
            // the synchronous variants never read the stage depth;
            // pinning it to 1 keeps one canonical cache token per
            // computation instead of eight identical entries
            if g.variant != gemm::Variant::Pipeline && point.ilp != 1 {
                return Err(format!(
                    "the gemm {} variant has no cp.async pipeline; stages (the ilp \
                     coordinate) must be 1, got {}",
                    g.variant.spec_name(),
                    point.ilp
                ));
            }
            g.config(point).validate()?;
        }
        Ok(())
    }

    /// Score this workload at one execution point with the closed-form
    /// analytic model ([`crate::sim`]'s `predict_*` family) — no cycle
    /// is simulated. This is the tuner's fast path: calibrated against
    /// the cycle simulator per family (`tests/analytic_calibration.rs`
    /// pins the bounds in [`crate::sim::CALIBRATION_BOUNDS`]) and orders
    /// of magnitude cheaper than [`Workload::measure`]. Numeric probes
    /// have no timing model; malformed workloads or points are typed
    /// errors, never panics.
    pub fn predict(
        &self,
        device: &Device,
        point: ExecPoint,
    ) -> Result<AnalyticPrediction, String> {
        self.validate_point(point)?;
        let ExecPoint { warps, ilp } = point;
        match *self {
            Workload::Mma { .. } | Workload::MmaSp { .. } => {
                predict_mma(device, &self.mma_instr().expect("mma workload"), warps, ilp)
            }
            Workload::Ldmatrix { num } => predict_ldmatrix(device, num, warps, ilp),
            Workload::LdShared { width, ways } => {
                predict_ld_shared(device, width, ways, warps, ilp)
            }
            Workload::Wmma { ab, cd, shape } => predict_wmma(device, shape, ab, cd, warps, ilp),
            Workload::Gemm(g) => predict_gemm(device, &g.config(point), g.variant, g.l2_resident),
            Workload::Numeric(_) => Err(
                "numeric probes have no timing model — they measure error, not cycles"
                    .to_string(),
            ),
        }
    }

    /// The #warps axis a sweep of this workload covers: the paper's
    /// [`SWEEP_WARPS`] for the instruction families, restricted to the
    /// tile-legal warp counts for gemm. Numeric probes reinterpret the
    /// axis as the chain step (`[1]` for profile probes).
    pub fn sweep_warps_axis(&self) -> Vec<u32> {
        match self {
            Workload::Gemm(_) => SWEEP_WARPS
                .iter()
                .copied()
                .filter(|&w| self.validate_point(ExecPoint::new(w, 1)).is_ok())
                .collect(),
            Workload::Numeric(p) => p.sweep_first_axis(),
            _ => SWEEP_WARPS.to_vec(),
        }
    }

    /// The second sweep axis: ILP for the instruction families,
    /// `cp.async` stage depth ([`GEMM_SWEEP_STAGES`], capped at the
    /// problem's k-step count) for the gemm pipeline variant, the init
    /// kind (`1` = low-precision, `2` = FP32) for numeric probes. The
    /// synchronous gemm variants never read the stage depth, so their
    /// axis collapses to `[1]` instead of recomputing identical cells.
    pub fn sweep_ilp_axis(&self) -> Vec<u32> {
        match self {
            Workload::Numeric(p) => p.sweep_init_axis(),
            Workload::Gemm(g) => {
                if g.variant != gemm::Variant::Pipeline {
                    return vec![1];
                }
                GEMM_SWEEP_STAGES
                    .iter()
                    .copied()
                    .filter(|&s| self.validate_point(ExecPoint::new(1, s)).is_ok())
                    .collect()
            }
            _ => SWEEP_ILPS.to_vec(),
        }
    }

    /// Measure this workload at one (#warps, ILP) point on the cycle
    /// simulator. Panics on workloads the device does not support — call
    /// [`Workload::validate`] first (the [`Plan`] compiler does).
    ///
    /// Numeric probes are backend experiments, not timing measurements:
    /// this native-datapath convenience reports the probe's headline
    /// error in the `latency` field (runners use their own numeric leg
    /// and return the full [`NumericOutput`] instead).
    pub fn measure(&self, device: &Device, point: ExecPoint) -> Measurement {
        self.measure_profiled(device, point, &mut Profiler::Null)
    }

    /// [`Workload::measure`] with stall attribution: the cycle
    /// simulation behind the measurement runs through `profiler`
    /// (identical schedule; a [`Profiler::Null`] is the plain path).
    /// Numeric probes run no cycle simulation and leave the profiler
    /// untouched.
    pub fn measure_profiled(
        &self,
        device: &Device,
        point: ExecPoint,
        profiler: &mut Profiler,
    ) -> Measurement {
        let ExecPoint { warps, ilp } = point;
        match *self {
            Workload::Mma { .. } | Workload::MmaSp { .. } => measure_mma_profiled(
                device,
                &self.mma_instr().expect("mma workload"),
                warps,
                ilp,
                profiler,
            ),
            Workload::Ldmatrix { num } => {
                measure_ldmatrix_profiled(device, num, warps, ilp, profiler)
            }
            Workload::LdShared { width, ways } => {
                measure_ld_shared_at_profiled(device, width, ways, warps, ilp, profiler)
            }
            Workload::Wmma { ab, cd, shape } => {
                measure_wmma_profiled(device, shape, ab, cd, warps, ilp, profiler)
            }
            Workload::Gemm(g) => {
                let cfg = g.config(point);
                let r = if g.l2_resident {
                    let mut dev = device.clone();
                    dev.gmem_bytes_per_cycle =
                        dev.gmem_bytes_per_cycle.max(gemm::L2_RESIDENT_BYTES_PER_CYCLE);
                    gemm::run_gemm_profiled(&dev, cfg, g.variant, profiler)
                } else {
                    gemm::run_gemm_profiled(device, cfg, g.variant, profiler)
                };
                // latency = cycles per k-step (the iteration of this
                // kernel); throughput stays in FMA/clk/SM like the
                // compute instruction families.
                Measurement {
                    warps: point.warps,
                    ilp: point.ilp,
                    latency: r.cta_cycles as f64 / cfg.k_steps() as f64,
                    throughput: r.fma_per_clk,
                }
            }
            Workload::Numeric(p) => {
                let out = p.run_native();
                Measurement {
                    warps: point.warps,
                    ilp: point.ilp,
                    latency: NumericProbe::headline(&out),
                    throughput: 0.0,
                }
            }
        }
    }

    /// The warp programs a [`Workload::measure`] at `point` would hand
    /// to the cycle simulator (warp `i` runs entry `i`, the
    /// `SmSim::from_shared` contract) — built without simulating a
    /// cycle. This is the tclint seam: `BenchPlan::lint`, `repro lint`
    /// and `POST /v1/lint` feed these to [`crate::analysis::verify`].
    /// Numeric probes are pure datapath experiments and compile to an
    /// empty launch. Panics on unsupported workloads, exactly like
    /// [`Workload::measure`] — validate first.
    pub fn programs(&self, device: &Device, point: ExecPoint) -> Vec<Arc<WarpProgram>> {
        let ExecPoint { warps, ilp } = point;
        let replicate = |p: WarpProgram| {
            let shared = Arc::new(p);
            (0..warps).map(|_| Arc::clone(&shared)).collect::<Vec<_>>()
        };
        match *self {
            Workload::Mma { .. } | Workload::MmaSp { .. } => replicate(mma_program(
                device,
                &self.mma_instr().expect("mma workload"),
                ilp,
                ITERS,
            )),
            Workload::Ldmatrix { num } => {
                replicate(ldmatrix_program(device, num, ilp, ITERS))
            }
            Workload::LdShared { width, ways } => {
                replicate(ld_shared_program(device, width, ways, ilp, ITERS))
            }
            Workload::Wmma { ab, cd, shape } => {
                replicate(wmma_program(device, shape, ab, cd, ilp, ITERS))
            }
            Workload::Gemm(g) => {
                let cfg = g.config(point);
                (0..cfg.warps)
                    .map(|w| Arc::new(gemm::build_program(device, cfg, g.variant, w)))
                    .collect()
            }
            Workload::Numeric(_) => Vec::new(),
        }
    }

    /// Is `device` the registry device of its name — i.e. may its cells
    /// use the name-keyed cache? An ad-hoc or modified [`Device`] must
    /// not: it would alias the registry device's cells. The registry is
    /// materialized once (this runs on every cell access, including
    /// warm hits).
    fn device_cacheable(device: &Device) -> bool {
        use std::sync::OnceLock;
        static REGISTRY: OnceLock<Vec<Device>> = OnceLock::new();
        REGISTRY
            .get_or_init(crate::device::registry)
            .iter()
            .any(|reg| reg.name == device.name && reg == device)
    }

    /// Measure one timing cell through the process-wide [`CellCache`]:
    /// a cache hit returns the memoized simulation bit-identically; a
    /// miss runs [`Workload::measure`] and memoizes it. `backend` is the
    /// [`Runner::name`] coordinate of the cell's content address (pass
    /// `"sim"` when no runner is in play — timing cells are
    /// simulator-measured on every backend).
    ///
    /// The cache keys devices by registry *name*, so only a device that
    /// is bit-for-bit its registry entry reads through it; an ad-hoc or
    /// modified device falls back to an uncached [`Workload::measure`]
    /// (correct, just unmemoized) instead of silently serving the
    /// registry device's cells. Numeric probes bypass the cell cache
    /// too: their results come from a runner's numeric leg and are
    /// cached per unit by tcserved instead.
    pub fn measure_cached(&self, device: &Device, point: ExecPoint, backend: &str) -> Measurement {
        self.measure_cached_profiled(device, point, backend, ProfileMode::Off).0
    }

    /// [`Workload::measure_cached`] with stall attribution. Counting
    /// profiles are stored *with* the cell, so a warm hit still reports
    /// attribution; a cell first simulated unprofiled is upgraded in
    /// place on its first profiled request. Tracing requests bypass the
    /// cache entirely (traces are per-request artifacts, never
    /// memoized), and numeric probes run no cycle simulation, so the
    /// profile leg is always `None` for them.
    pub fn measure_cached_profiled(
        &self,
        device: &Device,
        point: ExecPoint,
        backend: &str,
        mode: ProfileMode,
    ) -> (Measurement, Option<SimProfile>) {
        if matches!(self, Workload::Numeric(_)) {
            return (self.measure(device, point), None);
        }
        if !Self::device_cacheable(device) || mode == ProfileMode::Tracing {
            // Ad-hoc devices must not alias registry cells; traces are
            // never cached. Both run uncached, but still under the
            // process-wide simulation gate.
            let mut profiler = mode.profiler();
            let m = cell::run_gated(|| self.measure_profiled(device, point, &mut profiler));
            return (m, profiler.take_profile());
        }
        CellCache::global().get_or_simulate_profiled(
            &self.to_spec(),
            device.name,
            point,
            backend,
            mode != ProfileMode::Off,
            |profiler| self.measure_profiled(device, point, profiler),
        )
    }

    /// Completion/issue latency (§4 step 1): one warp, ILP = 1 — cell
    /// (1,1) of the sweep grid, read through the cell cache (a sweep
    /// that already ran makes this free).
    pub fn completion_latency(&self, device: &Device) -> f64 {
        self.measure_cached(device, ExecPoint::new(1, 1), "sim").latency
    }

    /// [`Workload::measure_cached`] under a per-request [`Budget`]. A
    /// warm cell serves regardless of the deadline (a cache read costs
    /// nothing worth degrading over); a cold cell whose simulation blows
    /// the budget — detected by the sim loop's iteration-mark watchdog —
    /// returns `Err(BudgetBlown)` and caches *nothing*, so a later
    /// un-budgeted request re-simulates and gets the bit-exact answer.
    /// An already-expired budget fails fast without starting the sim.
    pub fn measure_cached_budgeted(
        &self,
        device: &Device,
        point: ExecPoint,
        backend: &str,
        budget: Budget,
    ) -> Result<Measurement, BudgetBlown> {
        if budget.exceeded() {
            return Err(BudgetBlown);
        }
        let (m, blown) =
            budget::scoped(Some(budget), || self.measure_cached(device, point, backend));
        if blown {
            Err(BudgetBlown)
        } else {
            Ok(m)
        }
    }

    /// [`Workload::sweep_via`] under a per-request [`Budget`]: every
    /// cell reads through the cache budgeted
    /// ([`Workload::measure_cached_budgeted`]), fanned out over
    /// `threads` pool workers with the budget re-installed inside each
    /// job (the thread-local does not cross the pool boundary on its
    /// own). The first blown cell fails the whole sweep — once the
    /// deadline has passed every remaining job fails fast before
    /// simulating, so abandonment is prompt — but cells that *did*
    /// complete were cached normally and make a retry cheaper. Timing
    /// workloads only; numeric sweeps have no budget path (their
    /// datapath runs have no watchdog seam) and are handled at the unit
    /// layer.
    pub fn sweep_via_budgeted(
        &self,
        device: &Device,
        backend: &str,
        threads: usize,
        budget: Budget,
    ) -> Result<Sweep, BudgetBlown> {
        debug_assert!(
            !matches!(self, Workload::Numeric(_)),
            "numeric sweeps are budgeted at the unit layer"
        );
        if budget.exceeded() {
            return Err(BudgetBlown);
        }
        let warps_axis = self.sweep_warps_axis();
        let ilp_axis = self.sweep_ilp_axis();
        let points: Vec<ExecPoint> = warps_axis
            .iter()
            .flat_map(|&warps| ilp_axis.iter().map(move |&ilp| ExecPoint::new(warps, ilp)))
            .collect();
        // No warm/cold phase split here: each cell is read exactly once
        // through the cache, so hit/miss accounting stays truthful.
        let jobs: Vec<_> = points
            .iter()
            .map(|&point| {
                let workload = *self;
                move || workload.measure_cached_budgeted(device, point, backend, budget)
            })
            .collect();
        let mut cells = Vec::with_capacity(points.len());
        for result in run_parallel(jobs, threads) {
            let m = result?;
            cells.push(SweepCell {
                warps: m.warps,
                ilp: m.ilp,
                latency: m.latency,
                throughput: m.throughput,
            });
        }
        Ok(Sweep { label: self.to_string(), warps_axis, ilp_axis, cells })
    }

    /// The analytic stand-in for a full sweep: every grid cell scored by
    /// the closed-form model ([`Workload::predict`]), no cycle
    /// simulated. This is what a blown-budget sweep degrades to —
    /// same axes, same cell order, `latency`/`throughput` from the
    /// calibrated predictor. Errors only where `predict` does (numeric
    /// probes, malformed points).
    pub fn predict_sweep(&self, device: &Device) -> Result<Sweep, String> {
        let warps_axis = self.sweep_warps_axis();
        let ilp_axis = self.sweep_ilp_axis();
        let mut cells = Vec::with_capacity(warps_axis.len() * ilp_axis.len());
        for &warps in &warps_axis {
            for &ilp in &ilp_axis {
                let p = self.predict(device, ExecPoint::new(warps, ilp))?;
                cells.push(SweepCell {
                    warps,
                    ilp,
                    latency: p.latency,
                    throughput: p.throughput,
                });
            }
        }
        Ok(Sweep { label: self.to_string(), warps_axis, ilp_axis, cells })
    }

    /// Full grid over this workload's sweep axes (§4 step 2) — one code
    /// path for all seven workload kinds. Instruction families sweep
    /// (ILP, #warps); gemm sweeps (stages, CTA warps) over the
    /// tile-legal warp counts, with the stage depth riding the `ilp`
    /// axis of the returned [`Sweep`]; numeric probes sweep
    /// (init kind, chain step).
    ///
    /// Convenience form of [`Workload::sweep_via`] with the simulator
    /// backend name and the default pool width.
    pub fn sweep(&self, device: &Device) -> Sweep {
        self.sweep_via(device, "sim", default_threads())
    }

    /// The cell-level execution engine's sweep: one job per *cold*
    /// (warps, ilp) cell, fanned out across `threads` pool workers,
    /// each reading through the process-wide [`CellCache`] under
    /// `backend`'s name ([`Workload::measure_cached`]) — a warm
    /// re-sweep (the overlapping `repro all` experiments, `/v1/sweep`
    /// after a plan) finds no cold cells and skips the pool entirely.
    /// Cell order in the returned grid is row-major like the serial
    /// sweep always was, and — the simulator being deterministic — the
    /// cells are bit-identical to a cold serial sweep whatever mix of
    /// hits and misses served them.
    ///
    /// Numeric probes have no timing cells; their sweep runs the probe
    /// grid on the native datapath (runners route each variant through
    /// their own numeric leg instead). An ad-hoc (non-registry) device
    /// cannot use the name-keyed cache, so its grid runs fully parallel
    /// and uncached.
    pub fn sweep_via(&self, device: &Device, backend: &str, threads: usize) -> Sweep {
        self.sweep_via_profiled(device, backend, threads, ProfileMode::Off).0
    }

    /// [`Workload::sweep_via`] with stall attribution: every cell's
    /// profile — served warm from the cell cache or simulated cold — is
    /// merged into one sweep-level [`SimProfile`] (`runs` counts the
    /// cells folded in). `None` when `mode` is off or the workload is
    /// numeric.
    pub fn sweep_via_profiled(
        &self,
        device: &Device,
        backend: &str,
        threads: usize,
        mode: ProfileMode,
    ) -> (Sweep, Option<SimProfile>) {
        if let Workload::Numeric(p) = self {
            let sweep = p
                .sweep_with(self.to_string(), |probe| Ok(probe.run_native()))
                .expect("the native numeric sweep is infallible");
            return (sweep, None);
        }
        let warps_axis = self.sweep_warps_axis();
        let ilp_axis = self.sweep_ilp_axis();
        let points: Vec<ExecPoint> = warps_axis
            .iter()
            .flat_map(|&warps| ilp_axis.iter().map(move |&ilp| ExecPoint::new(warps, ilp)))
            .collect();
        let measured: Vec<(Measurement, Option<SimProfile>)> = if Self::device_cacheable(device)
            && mode != ProfileMode::Tracing
        {
            // phase 1: simulate the cold cells in parallel; their
            // measurements come back in grid order (run_parallel
            // preserves it) AND land in the cache for everyone else
            let spec = self.to_spec();
            let cold_mask: Vec<bool> = points
                .iter()
                .map(|&p| !CellCache::global().contains(&spec, device.name, p, backend))
                .collect();
            let jobs: Vec<_> = points
                .iter()
                .zip(&cold_mask)
                .filter(|&(_, &cold)| cold)
                .map(|(&point, _)| {
                    let workload = *self;
                    move || workload.measure_cached_profiled(device, point, backend, mode)
                })
                .collect();
            let mut cold_results = run_parallel(jobs, threads).into_iter();
            // phase 2: assemble the grid — cold cells from phase 1
            // directly (re-reading them through the cache would record
            // one spurious "hit" per cell we just simulated), warm
            // cells as the genuine cache hits they are
            points
                .iter()
                .zip(&cold_mask)
                .map(|(&p, &cold)| {
                    if cold {
                        cold_results.next().expect("one phase-1 result per cold cell")
                    } else {
                        self.measure_cached_profiled(device, p, backend, mode)
                    }
                })
                .collect()
        } else {
            // ad-hoc device (or a tracing request, which never caches):
            // fully uncached, but the gating inside
            // `measure_cached_profiled` still bounds concurrency
            let jobs: Vec<_> = points
                .iter()
                .map(|&point| {
                    let workload = *self;
                    move || workload.measure_cached_profiled(device, point, backend, mode)
                })
                .collect();
            run_parallel(jobs, threads)
        };
        let mut profile: Option<SimProfile> = None;
        for (_, cell_profile) in &measured {
            if let Some(p) = cell_profile {
                match &mut profile {
                    None => profile = Some(p.clone()),
                    Some(acc) => acc.merge(p),
                }
            }
        }
        let cells: Vec<SweepCell> = measured
            .into_iter()
            .map(|(m, _)| SweepCell {
                warps: m.warps,
                ilp: m.ilp,
                latency: m.latency,
                throughput: m.throughput,
            })
            .collect();
        (Sweep { label: self.to_string(), warps_axis, ilp_axis, cells }, profile)
    }
}

impl fmt::Display for Workload {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            Workload::Mma { .. } | Workload::MmaSp { .. } => {
                write!(f, "{}", self.mma_instr().expect("mma workload"))
            }
            Workload::Ldmatrix { num } => write!(f, "{num}"),
            Workload::LdShared { width, ways } => write!(f, "{width} ({ways}-way)"),
            Workload::Wmma { ab, cd, shape } => {
                write!(f, "wmma.m{}n{}k{} {ab}/{cd}", shape.m, shape.n, shape.k)
            }
            Workload::Gemm(g) => write!(
                f,
                "gemm.{} {}^3 {}/{} t{}x{}x{}{}",
                g.variant.spec_name(),
                g.size,
                g.ab,
                g.cd,
                g.tile_m,
                g.tile_n,
                g.tile_k,
                if g.l2_resident { " (L2)" } else { "" }
            ),
            Workload::Numeric(p) => match p.kind {
                ProbeKind::Profile { op, init } => write!(
                    f,
                    "numeric.profile {}/{} {} (init {})",
                    p.ab.name(),
                    p.cd.name(),
                    op.spec_name(),
                    init.spec_name()
                ),
                ProbeKind::Chain { len, init } => write!(
                    f,
                    "numeric.chain {}/{} N={} (init {})",
                    p.ab.name(),
                    p.cd.name(),
                    len,
                    init.spec_name()
                ),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{a100, rtx2080ti};
    use crate::isa::shapes::*;
    use crate::microbench::{measure_ld_shared, sweep_mma};

    fn all_kinds() -> Vec<Workload> {
        vec![
            Workload::Mma { ab: AbType::Bf16, cd: CdType::Fp32, shape: M16N8K16 },
            Workload::MmaSp { ab: AbType::Fp16, cd: CdType::Fp32, shape: M16N8K32 },
            Workload::Ldmatrix { num: LdMatrixNum::X4 },
            Workload::LdShared { width: LdSharedWidth::U64, ways: 8 },
            Workload::Wmma {
                ab: AbType::Fp16,
                cd: CdType::Fp32,
                shape: WmmaShape { m: 16, n: 16, k: 16 },
            },
            Workload::Gemm(GemmParams::paper(gemm::Variant::Pipeline, false)),
            Workload::Gemm(GemmParams::paper(gemm::Variant::Permuted, true)),
            Workload::Numeric(NumericProbe::profile(
                ProbeDtype::Bf16,
                AccDtype::F32,
                crate::numerics::ProfileOp::Accumulation,
                crate::numerics::InitKind::Fp32,
            )),
            Workload::Numeric(NumericProbe::chain(
                ProbeDtype::Tf32,
                AccDtype::F32,
                6,
                crate::numerics::InitKind::LowPrecision,
            )),
        ]
    }

    #[test]
    fn spec_round_trips_for_all_seven_kinds() {
        for w in all_kinds() {
            let spec = w.to_spec();
            let parsed = Workload::parse_spec(&spec)
                .unwrap_or_else(|e| panic!("{spec:?} failed to re-parse: {e}"));
            assert_eq!(parsed, w, "{spec:?}");
            assert_eq!(parsed.to_spec(), spec);
        }
    }

    #[test]
    fn parse_accepts_aliases_and_legacy_mma_specs() {
        // legacy MmaInstr specs (no kind keyword) still parse
        let legacy = Workload::parse_spec("bf16,f32,m16n8k16").unwrap();
        assert_eq!(
            legacy,
            Workload::Mma { ab: AbType::Bf16, cd: CdType::Fp32, shape: M16N8K16 }
        );
        let sp = Workload::parse_spec("fp16 f32 m16n8k32 sparse").unwrap();
        assert_eq!(sp.kind(), "mma.sp");
        // ldmatrix display form parses back
        assert_eq!(
            Workload::parse_spec("ldmatrix.x2").unwrap(),
            Workload::Ldmatrix { num: LdMatrixNum::X2 }
        );
        assert_eq!(
            Workload::parse_spec("ldmatrix 4").unwrap(),
            Workload::Ldmatrix { num: LdMatrixNum::X4 }
        );
        // kind keywords are case-insensitive
        assert_eq!(
            Workload::parse_spec("LD.SHARED u32 8").unwrap(),
            Workload::LdShared { width: LdSharedWidth::U32, ways: 8 }
        );
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(Workload::parse_spec("").is_err());
        assert!(Workload::parse_spec("mma bf16 f32").is_err());
        assert!(Workload::parse_spec("mma.sp bf16 f32 m16n8k16 extra").is_err());
        assert!(Workload::parse_spec("ldmatrix x8").is_err());
        assert!(Workload::parse_spec("ld.shared u128 2").is_err());
        assert!(Workload::parse_spec("ld.shared u32 many").is_err());
        assert!(Workload::parse_spec("wmma fp16 f32").is_err());
        // unknown head falls through to the legacy parser, whose error
        // mentions the workload-kind syntax
        let err = Workload::parse_spec("garbage").unwrap_err();
        assert!(err.contains("mma | mma.sp | ldmatrix | ld.shared | wmma"), "{err}");
    }

    #[test]
    fn validate_enforces_device_legality() {
        let ampere = a100();
        let turing = rtx2080ti();
        for w in all_kinds() {
            assert!(w.validate(&ampere).is_ok(), "{w} should be valid on a100");
        }
        // no sparse Tensor Cores on Turing
        let sp = Workload::MmaSp { ab: AbType::Fp16, cd: CdType::Fp32, shape: M16N8K32 };
        assert!(sp.validate(&turing).unwrap_err().contains("not supported"));
        // wmma pieces must exist in the device's calibration
        let wmma = Workload::Wmma {
            ab: AbType::Fp16,
            cd: CdType::Fp32,
            shape: WmmaShape { m: 16, n: 16, k: 16 },
        };
        assert!(wmma.validate(&turing).unwrap_err().contains("wmma"));
        // conflict ways must be a power of two, and u64 is 2-way minimum
        let odd = Workload::LdShared { width: LdSharedWidth::U32, ways: 3 };
        assert!(odd.validate(&ampere).unwrap_err().contains("power of two"));
        let narrow = Workload::LdShared { width: LdSharedWidth::U64, ways: 1 };
        assert!(narrow.validate(&ampere).unwrap_err().contains("ways must be >= 2"));
        // wmma shapes must fragment exactly into n=8 pieces — anything
        // else would mislabel the measured workload
        for (m, n, k) in [(16, 9, 16), (16, 0, 16), (0, 16, 16), (16, 12, 16)] {
            let w = Workload::Wmma {
                ab: AbType::Fp16,
                cd: CdType::Fp32,
                shape: WmmaShape { m, n, k },
            };
            assert!(
                w.validate(&ampere).unwrap_err().contains("fragmentable"),
                "m{m}n{n}k{k} must be rejected"
            );
        }
        // malformed pairing is caught before the device lookup
        let bad = Workload::Mma { ab: AbType::Bf16, cd: CdType::Fp16, shape: M16N8K16 };
        assert!(bad.validate(&ampere).unwrap_err().contains("well-formed"));
    }

    #[test]
    fn measure_matches_the_legacy_free_functions() {
        let d = a100();
        let w = Workload::Mma { ab: AbType::Fp16, cd: CdType::Fp32, shape: M16N8K16 };
        let via_workload = w.measure(&d, ExecPoint::new(8, 2));
        let via_free = crate::microbench::measure_mma(
            &d,
            &MmaInstr::dense(AbType::Fp16, CdType::Fp32, M16N8K16),
            8,
            2,
        );
        assert_eq!(via_workload, via_free);

        let ld = Workload::LdShared { width: LdSharedWidth::U32, ways: 4 };
        assert_eq!(
            ld.measure(&d, ExecPoint::new(1, 1)),
            measure_ld_shared(&d, LdSharedWidth::U32, 4)
        );
    }

    #[test]
    fn workload_sweep_matches_legacy_sweep_mma() {
        let d = a100();
        let instr = MmaInstr::dense(AbType::Bf16, CdType::Fp32, M16N8K16);
        let via_workload = Workload::from_instr(instr).sweep(&d);
        let via_free = sweep_mma(&d, &instr);
        assert_eq!(via_workload.cells.len(), via_free.cells.len());
        for (a, b) in via_workload.cells.iter().zip(&via_free.cells) {
            assert_eq!((a.warps, a.ilp), (b.warps, b.ilp));
            assert_eq!(a.latency, b.latency);
            assert_eq!(a.throughput, b.throughput);
        }
    }

    #[test]
    fn completion_latency_is_the_1_1_point() {
        let d = a100();
        let w = Workload::Ldmatrix { num: LdMatrixNum::X1 };
        let lat = w.completion_latency(&d);
        assert!((lat - 23.0).abs() < 1.5, "{lat}"); // Table 9
    }

    fn small_gemm(variant: gemm::Variant) -> Workload {
        // 256^3 keeps measurement-driven tests fast (8 k-steps)
        Workload::Gemm(GemmParams { size: 256, ..GemmParams::paper(variant, false) })
    }

    #[test]
    fn gemm_spec_parsing() {
        let w = Workload::parse_spec("gemm pipeline bf16 f32 2048 128x128x32").unwrap();
        assert_eq!(w, Workload::Gemm(GemmParams::paper(gemm::Variant::Pipeline, false)));
        assert_eq!(w.kind(), "gemm");
        assert_eq!(w.throughput_unit(), "FMA/clk/SM");
        let l2 = Workload::parse_spec("gemm permuted bf16 f32 2048 128X128X32 L2").unwrap();
        assert_eq!(l2, Workload::Gemm(GemmParams::paper(gemm::Variant::Permuted, true)));
        for bad in [
            "gemm",
            "gemm pipeline bf16 f32 2048",
            "gemm fancy bf16 f32 2048 128x128x32",
            "gemm pipeline qf8 f32 2048 128x128x32",
            "gemm pipeline bf16 f32 big 128x128x32",
            "gemm pipeline bf16 f32 2048 128x128",
            "gemm pipeline bf16 f32 2048 128xNx32",
            "gemm pipeline bf16 f32 2048 128x128x32 dram",
            "gemm pipeline bf16 f32 2048 128x128x32 l2 extra",
        ] {
            assert!(Workload::parse_spec(bad).is_err(), "{bad:?} should be rejected");
        }
    }

    #[test]
    fn gemm_validation() {
        let ampere = a100();
        let turing = rtx2080ti();
        let pipe = Workload::Gemm(GemmParams::paper(gemm::Variant::Pipeline, false));
        assert!(pipe.validate(&ampere).is_ok());
        // Turing has neither cp.async nor the m16n8k16 shape
        assert!(pipe.validate(&turing).is_err());
        // int8 operands are rejected before any device lookup
        let int8 = Workload::Gemm(GemmParams {
            ab: AbType::Int8,
            cd: CdType::Int32,
            ..GemmParams::paper(gemm::Variant::Baseline, false)
        });
        assert!(int8.validate(&ampere).unwrap_err().contains("16-bit"));
        // bf16 with an fp16 accumulator is an illegal pairing
        let bad_cd = Workload::Gemm(GemmParams {
            cd: CdType::Fp16,
            ..GemmParams::paper(gemm::Variant::Baseline, false)
        });
        assert!(bad_cd.validate(&ampere).is_err());
        // a size that does not tile is caught statically
        let ragged = Workload::Gemm(GemmParams {
            size: 2000,
            ..GemmParams::paper(gemm::Variant::Baseline, false)
        });
        assert!(ragged.validate(&ampere).unwrap_err().contains("tile"));
    }

    #[test]
    fn gemm_point_validation_and_sweep_axes() {
        let w = small_gemm(gemm::Variant::Pipeline);
        assert!(w.validate_point(ExecPoint::new(8, 2)).is_ok());
        // 6 warps do not form a power-of-two warp grid
        assert!(w.validate_point(ExecPoint::new(6, 2)).is_err());
        assert!(w.validate_point(ExecPoint::new(8, 0)).is_err());
        // the sweep axes drop the grid-illegal warp counts and ride the
        // stage depths on the ilp axis
        assert_eq!(w.sweep_warps_axis(), vec![1, 2, 4, 8, 16, 32]);
        assert_eq!(w.sweep_ilp_axis(), GEMM_SWEEP_STAGES.to_vec());
        // the synchronous variants never read the stage depth: one cell
        // per warp count instead of four identical ones, and the stage
        // coordinate is pinned to 1 so each computation has exactly one
        // cache token
        let sync_variant = small_gemm(gemm::Variant::Baseline);
        assert_eq!(sync_variant.sweep_ilp_axis(), vec![1]);
        assert!(sync_variant.validate_point(ExecPoint::new(8, 1)).is_ok());
        let err = sync_variant.validate_point(ExecPoint::new(8, 2)).unwrap_err();
        assert!(err.contains("stages"), "{err}");
        // a pipeline deeper than the k-loop is not a legal point
        let tiny = Workload::Gemm(GemmParams {
            size: 64,
            tile_m: 16,
            tile_n: 16,
            tile_k: 16,
            ..GemmParams::paper(gemm::Variant::Pipeline, false)
        });
        assert!(tiny.validate_point(ExecPoint::new(1, 5)).is_err());
        assert_eq!(tiny.sweep_ilp_axis(), vec![1, 2, 3, 4]); // k_steps = 4
        // instruction families keep the paper's axes
        let mma = Workload::Mma { ab: AbType::Bf16, cd: CdType::Fp32, shape: M16N8K16 };
        assert_eq!(mma.sweep_warps_axis(), SWEEP_WARPS.to_vec());
        assert_eq!(mma.sweep_ilp_axis(), SWEEP_ILPS.to_vec());
    }

    #[test]
    fn gemm_measure_matches_run_gemm() {
        let d = a100();
        let w = small_gemm(gemm::Variant::Pipeline);
        let Workload::Gemm(g) = w else { unreachable!() };
        let point = ExecPoint::new(8, 2);
        let m = w.measure(&d, point);
        let direct = gemm::run_gemm(&d, g.config(point), gemm::Variant::Pipeline);
        let k_steps = g.config(point).k_steps() as f64;
        assert!((m.latency - direct.cta_cycles as f64 / k_steps).abs() < 1e-9);
        assert!((m.throughput - direct.fma_per_clk).abs() < 1e-9);
        assert!(m.throughput > 0.0 && m.latency > 0.0, "{m:?}");
        // the L2-resident regime must speed the memory-bound baseline up
        let base = small_gemm(gemm::Variant::Baseline);
        let Workload::Gemm(gb) = base else { unreachable!() };
        let l2 = Workload::Gemm(GemmParams { l2_resident: true, ..gb });
        let slow = base.measure(&d, ExecPoint::new(8, 1));
        let fast = l2.measure(&d, ExecPoint::new(8, 1));
        assert!(fast.latency < slow.latency, "{fast:?} vs {slow:?}");
    }

    #[test]
    fn numeric_specs_pin_the_exec_point() {
        let w = Workload::parse_spec("numeric profile bf16 f32 acc fp32").unwrap();
        assert_eq!(w.kind(), "numeric");
        assert_eq!(w.throughput_unit(), "mean |err|");
        assert!(w.validate_point(ExecPoint::new(1, 1)).is_ok());
        let err = w.validate_point(ExecPoint::new(4, 1)).unwrap_err();
        assert!(err.contains("(1,1)"), "{err}");
        // sweep axes reinterpret as (chain step, init kind)
        let c = Workload::parse_spec("numeric chain tf32 f32 5").unwrap();
        assert_eq!(c.sweep_warps_axis(), vec![1, 2, 3, 4, 5]);
        assert_eq!(c.sweep_ilp_axis(), vec![1, 2]);
        assert_eq!(c.throughput_unit(), "l2 rel err");
        // measure() reports the headline error on the native datapath
        let m = w.measure(&a100(), ExecPoint::new(1, 1));
        assert!(m.latency > 0.0 && m.throughput == 0.0, "{m:?}");
    }

    #[test]
    fn exec_point_validation() {
        assert!(ExecPoint::new(4, 3).validate().is_ok());
        assert!(ExecPoint::new(0, 1).validate().is_err());
        assert!(ExecPoint::new(33, 1).validate().is_err());
        assert!(ExecPoint::new(4, 0).validate().is_err());
        assert!(ExecPoint::new(4, 9).validate().is_err());
    }
}
