//! The process-wide **cell cache** — content-addressed memoization of
//! single-cell simulations.
//!
//! A *cell* is the atom of every timing measurement: one (workload,
//! device, exec point, backend) simulation producing a latency and a
//! throughput. The same cell is requested from many directions — a
//! sweep unit covers 48 of them, a later `Point(4,2)` unit re-asks for
//! one, `completion_latency` is cell (1,1), and the 19 experiments of
//! `repro all` overlap heavily (Fig. 6 *is* the sweep of Table 3's BF16
//! row) — so the cache sits below every one of those paths: points,
//! sweeps and completion units all read through
//! [`Workload::measure_cached`](super::Workload::measure_cached).
//!
//! Keys are the canonical string
//! `cell|backend=<backend>|device=<name>|spec=<workload spec>|w=<warps>|i=<ilp>`
//! hashed with the shared [`fnv1a`] content address. The workload spec
//! carries *every* workload parameter (that is the
//! [`Workload::to_spec`](super::Workload::to_spec) contract) and the
//! backend coordinate is the runner's
//! [`Runner::timing_backend`](super::Runner::timing_backend) — the
//! simulator's name for every current backend, because timing cells are
//! simulator-measured everywhere — so the two cache layers share one
//! key discipline while backends that ever measure timing on their own
//! datapath get their own cells. Devices are keyed by registry name —
//! `measure_cached` verifies the device is bit-for-bit its registry
//! entry and measures ad-hoc devices uncached instead of letting them
//! alias another device's cells.
//!
//! The map is sharded 16 ways (hash-picked shard, one mutex each) so
//! parallel sweep cells do not convoy on a single lock; simulations run
//! *outside* the lock. Concurrent first requests for the same cell may
//! therefore both simulate (last insert wins) — the simulator is
//! deterministic, so both compute identical bits and correctness is
//! unaffected; tcserved's single-flight layer already coalesces the
//! request-level stampedes that matter. Capacity is bounded per shard
//! with oldest-use eviction, and hit/miss/eviction/simulation counters
//! are exported at `/v1/metrics` (`cell_cache`).
//!
//! Below the in-memory cache sits the optional **disk-backed
//! [`CellStore`]**: one JSON file per cell under a shared directory,
//! named by the same FNV-1a address and written with the same atomic
//! temp+rename discipline as tcserved's `results/cache/`. A memory miss
//! consults the store before simulating and every simulation is written
//! back, so warm state survives a process restart and is shared by
//! every replica pointing at the same directory. The read path is
//! corruption-tolerant — an unreadable, truncated or foreign file is a
//! miss (recorded in the `corrupt` counter), never a panic — and f64s
//! round-trip through their exact `to_bits()` hex encoding, so a cell
//! served from the store is bit-identical to the simulation that
//! produced it.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

use crate::coordinator::default_threads;
use crate::microbench::Measurement;
use crate::sim::{Profiler, SimProfile};
use crate::util::{fnv1a, Json};

use super::ExecPoint;

/// Number of independently locked shards (hash-picked).
const SHARDS: usize = 16;

/// Bounds concurrently *running* cell simulations process-wide. Nested
/// pool fan-outs (campaign jobs x table rows x sweep cells) can spawn
/// far more workers than cores; gating the CPU-bound simulate calls at
/// the machine width turns the excess into cheap condvar sleepers
/// instead of scheduler thrash. Never held across another permit
/// acquisition (simulations do not recurse into the cache), so it
/// cannot deadlock.
struct SimGate {
    permits: Mutex<usize>,
    freed: Condvar,
}

impl SimGate {
    fn global() -> &'static SimGate {
        static GATE: OnceLock<SimGate> = OnceLock::new();
        GATE.get_or_init(|| SimGate {
            permits: Mutex::new(default_threads()),
            freed: Condvar::new(),
        })
    }

    /// Run `f` while holding one permit; the permit is returned even if
    /// `f` panics (callers above catch_unwind must not strand permits).
    fn run<T>(&self, f: impl FnOnce() -> T) -> T {
        struct Permit<'a>(&'a SimGate);
        impl Drop for Permit<'_> {
            fn drop(&mut self) {
                *self.0.permits.lock().unwrap() += 1;
                self.0.freed.notify_one();
            }
        }
        let mut permits = self.permits.lock().unwrap();
        while *permits == 0 {
            permits = self.freed.wait(permits).unwrap();
        }
        *permits -= 1;
        drop(permits);
        let _permit = Permit(self);
        f()
    }
}

/// Default cell capacity of the process-wide cache. A full sweep is 48
/// cells and `repro all` touches a few hundred distinct cells, so the
/// default never evicts in practice while still bounding a pathological
/// spec-enumerating client.
pub const DEFAULT_CELL_CAPACITY: usize = 16_384;

struct CellEntry {
    /// Full canonical key, kept to rule out FNV collisions serving the
    /// wrong cell (a colliding key recomputes instead).
    canonical: String,
    latency: f64,
    throughput: f64,
    /// Stall attribution of the simulation that produced this cell
    /// (Counting mode). `None` when the cell was simulated unprofiled;
    /// a later profiled request upgrades the entry in place.
    profile: Option<SimProfile>,
    last_used: u64,
}

/// Occupancy and traffic counters, exported at `/v1/metrics`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CellCacheStats {
    pub entries: usize,
    pub capacity: usize,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Simulations actually run. Differs from `misses` when a memory
    /// miss was filled from the disk store (no simulation) or when two
    /// threads raced on the same cold cell (both simulate once).
    pub cells_simulated: u64,
}

/// Schema marker written into every cell file; a file claiming any
/// other schema is treated as corrupt.
const CELL_STORE_SCHEMA: &str = "tcbench/cell/v1";

/// Traffic counters of a [`CellStore`], exported at `/v1/metrics`
/// (`cell_store`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CellStoreStats {
    pub hits: u64,
    pub misses: u64,
    pub writes: u64,
    /// Files that existed but failed to decode (unparsable JSON, wrong
    /// schema, foreign canonical key, bad bit patterns). Each is also
    /// counted as a miss.
    pub corrupt: u64,
}

/// Disk-backed cell store shared across restarts and replicas.
///
/// Layout: one `<fnv1a hash:016x>.json` file per cell under `dir`,
/// holding the full canonical key (verified on load, so an FNV
/// collision on disk recomputes instead of serving the wrong cell),
/// human-readable latency/throughput, and the exact `to_bits()` hex
/// encodings that the read path decodes — bit-identity does not depend
/// on decimal float formatting. Writes go to a pid-suffixed temp file
/// renamed into place, so replicas sharing the directory never observe
/// (or clobber each other with) half-written files.
pub struct CellStore {
    dir: PathBuf,
    hits: AtomicU64,
    misses: AtomicU64,
    writes: AtomicU64,
    corrupt: AtomicU64,
}

impl CellStore {
    /// A store rooted at `dir`. The directory is created lazily on the
    /// first write; a missing directory reads as all-miss.
    pub fn new(dir: impl Into<PathBuf>) -> CellStore {
        CellStore {
            dir: dir.into(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            writes: AtomicU64::new(0),
            corrupt: AtomicU64::new(0),
        }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn cell_path(&self, hash: u64) -> PathBuf {
        self.dir.join(format!("{hash:016x}.json"))
    }

    /// Load one cell, verifying the canonical key. Any failure — no
    /// file, unreadable file, bad JSON, wrong schema, foreign key, bad
    /// bit patterns — is a miss, never a panic.
    ///
    /// This is the `store.read` tcchaos seam: an injected `err` fails
    /// the read exactly like an unreadable file (counted miss, caller
    /// re-simulates — results stay bit-identical); an injected delay
    /// has already been served inside [`crate::chaos::inject`].
    pub fn load(&self, hash: u64, canonical: &str) -> Option<(f64, f64)> {
        if crate::chaos::inject(crate::chaos::Site::StoreRead).is_some() {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let text = match std::fs::read_to_string(self.cell_path(hash)) {
            Ok(t) => t,
            Err(_) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        };
        match Self::decode(&text, canonical) {
            Some(cell) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(cell)
            }
            None => {
                self.corrupt.fetch_add(1, Ordering::Relaxed);
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    fn decode(text: &str, canonical: &str) -> Option<(f64, f64)> {
        let json = Json::parse(text).ok()?;
        if json.get_str("schema") != Some(CELL_STORE_SCHEMA)
            || json.get_str("key") != Some(canonical)
        {
            return None;
        }
        let bits = |field: &str| u64::from_str_radix(json.get_str(field)?, 16).ok();
        Some((f64::from_bits(bits("latency_bits")?), f64::from_bits(bits("throughput_bits")?)))
    }

    /// Persist one cell (best-effort: an unwritable directory degrades
    /// the store to memory-only rather than failing the measurement).
    pub fn save(&self, hash: u64, canonical: &str, latency: f64, throughput: f64) {
        if std::fs::create_dir_all(&self.dir).is_err() {
            return;
        }
        let body = Json::obj(vec![
            ("schema", Json::str(CELL_STORE_SCHEMA)),
            ("key", Json::str(canonical)),
            ("latency", Json::num(latency)),
            ("throughput", Json::num(throughput)),
            ("latency_bits", Json::Str(format!("{:016x}", latency.to_bits()))),
            ("throughput_bits", Json::Str(format!("{:016x}", throughput.to_bits()))),
        ]);
        let tmp = self.dir.join(format!("{hash:016x}.{}.tmp", std::process::id()));
        if std::fs::write(&tmp, body.pretty().as_bytes()).is_ok()
            && std::fs::rename(&tmp, self.cell_path(hash)).is_ok()
        {
            self.writes.fetch_add(1, Ordering::Relaxed);
        } else {
            let _ = std::fs::remove_file(&tmp);
        }
    }

    pub fn stats(&self) -> CellStoreStats {
        CellStoreStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            writes: self.writes.load(Ordering::Relaxed),
            corrupt: self.corrupt.load(Ordering::Relaxed),
        }
    }
}

/// Sharded, content-addressed cache of cell simulations.
pub struct CellCache {
    shards: Vec<Mutex<HashMap<u64, CellEntry>>>,
    per_shard_capacity: usize,
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    simulated: AtomicU64,
    /// Optional disk tier, configure-once (replica topology is fixed at
    /// startup; swapping stores mid-flight would tear the counters).
    store: OnceLock<CellStore>,
}

impl CellCache {
    /// A cache holding at most ~`capacity` cells (rounded up to a
    /// per-shard bound; at least one cell per shard).
    pub fn new(capacity: usize) -> CellCache {
        CellCache {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            per_shard_capacity: capacity.div_ceil(SHARDS).max(1),
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            simulated: AtomicU64::new(0),
            store: OnceLock::new(),
        }
    }

    /// Attach the disk tier. Configure-once: the first caller wins and
    /// later calls return `false` (with the original store untouched).
    pub fn attach_store(&self, store: CellStore) -> bool {
        self.store.set(store).is_ok()
    }

    /// The attached disk tier, if any.
    pub fn store(&self) -> Option<&CellStore> {
        self.store.get()
    }

    /// The one process-wide instance every execution path reads through.
    pub fn global() -> &'static CellCache {
        static GLOBAL: OnceLock<CellCache> = OnceLock::new();
        GLOBAL.get_or_init(|| CellCache::new(DEFAULT_CELL_CAPACITY))
    }

    /// The canonical (pre-hash) content address of one cell.
    pub fn canonical_key(spec: &str, device: &str, point: ExecPoint, backend: &str) -> String {
        format!(
            "cell|backend={backend}|device={device}|spec={spec}|w={}|i={}",
            point.warps, point.ilp
        )
    }

    /// Serve the cell from cache or run `simulate` and memoize it. The
    /// returned measurement is bit-identical to a cold `simulate()` call
    /// (the cache stores the raw f64s).
    pub fn get_or_simulate(
        &self,
        spec: &str,
        device: &str,
        point: ExecPoint,
        backend: &str,
        simulate: impl FnOnce() -> Measurement,
    ) -> Measurement {
        self.get_or_simulate_profiled(spec, device, point, backend, false, |_| simulate()).0
    }

    /// [`get_or_simulate`](Self::get_or_simulate) with stall
    /// attribution. `simulate` receives the profiler to thread into the
    /// simulator ([`Profiler::Null`] when `want_profile` is off — the
    /// unprofiled path is unchanged, including its counter pins).
    ///
    /// Profiles are stored *with* the cell, so a warm hit still reports
    /// attribution without re-simulating. A cell first simulated
    /// unprofiled is upgraded in place the first time a profiled
    /// request lands on it (counted as a miss + simulation: the work is
    /// real).
    pub fn get_or_simulate_profiled(
        &self,
        spec: &str,
        device: &str,
        point: ExecPoint,
        backend: &str,
        want_profile: bool,
        simulate: impl FnOnce(&mut Profiler) -> Measurement,
    ) -> (Measurement, Option<SimProfile>) {
        let canonical = Self::canonical_key(spec, device, point, backend);
        let hash = fnv1a(canonical.as_bytes());
        let shard = &self.shards[(hash % SHARDS as u64) as usize];

        let mut collision = false;
        {
            let tick = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
            let mut map = shard.lock().unwrap();
            if let Some(e) = map.get_mut(&hash) {
                if e.canonical == canonical {
                    if !want_profile || e.profile.is_some() {
                        e.last_used = tick;
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        let m = Measurement {
                            warps: point.warps,
                            ilp: point.ilp,
                            latency: e.latency,
                            throughput: e.throughput,
                        };
                        return (m, if want_profile { e.profile.clone() } else { None });
                    }
                    // Cached without attribution but the caller wants
                    // one: fall through to re-simulate with profiling on
                    // and upgrade the entry in place.
                } else {
                    // FNV collision between two live cells: serve the
                    // other cell's slot untouched and recompute this one
                    // uncached.
                    collision = true;
                }
            }
        }
        // Memory miss. Consult the shared disk store first — profiled
        // requests skip it (the store carries timing only, and a
        // profile request must run the simulator anyway to attribute
        // it), as do colliding keys (their slot belongs to another
        // cell, in the store as in memory).
        self.misses.fetch_add(1, Ordering::Relaxed);
        if !collision && !want_profile {
            if let Some(store) = self.store.get() {
                if let Some((latency, throughput)) = store.load(hash, &canonical) {
                    let tick = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
                    let mut map = shard.lock().unwrap();
                    map.insert(
                        hash,
                        CellEntry {
                            canonical,
                            latency,
                            throughput,
                            profile: None,
                            last_used: tick,
                        },
                    );
                    self.evict_over_capacity(&mut map);
                    let m = Measurement { warps: point.warps, ilp: point.ilp, latency, throughput };
                    return (m, None);
                }
            }
        }
        // Simulate outside the shard lock so a 32-warp cell does not
        // serialize every other cell hashed into its shard, but inside
        // the process-wide gate so nested pool fan-outs cannot run more
        // CPU-bound simulations than the machine has cores.
        self.simulated.fetch_add(1, Ordering::Relaxed);
        let mut profiler = if want_profile { Profiler::counting() } else { Profiler::Null };
        let m = SimGate::global().run(|| simulate(&mut profiler));
        let profile = profiler.take_profile();
        if crate::sim::budget::blown() {
            // The request's budget expired mid-simulation: the sim loop
            // bailed at an iteration mark and `m` is a truncated trace.
            // It must reach neither the memory cache nor the disk store
            // — a later un-budgeted request re-simulates from scratch
            // and gets the bit-exact answer.
            return (m, profile);
        }
        if !collision {
            if let Some(store) = self.store.get() {
                store.save(hash, &canonical, m.latency, m.throughput);
            }
            let tick = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
            let mut map = shard.lock().unwrap();
            map.insert(
                hash,
                CellEntry {
                    canonical,
                    latency: m.latency,
                    throughput: m.throughput,
                    profile: profile.clone(),
                    last_used: tick,
                },
            );
            self.evict_over_capacity(&mut map);
        }
        (m, profile)
    }

    fn evict_over_capacity(&self, map: &mut HashMap<u64, CellEntry>) {
        while map.len() > self.per_shard_capacity {
            let oldest = map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
                .expect("non-empty shard");
            map.remove(&oldest);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Is this cell currently memoized? Pure lookup: no counters, no
    /// LRU touch — the deterministic hook the tests pin cache-population
    /// claims on (the traffic counters are process-global and racy
    /// across concurrent tests).
    pub fn contains(&self, spec: &str, device: &str, point: ExecPoint, backend: &str) -> bool {
        let canonical = Self::canonical_key(spec, device, point, backend);
        let hash = fnv1a(canonical.as_bytes());
        let map = self.shards[(hash % SHARDS as u64) as usize].lock().unwrap();
        map.get(&hash).is_some_and(|e| e.canonical == canonical)
    }

    pub fn stats(&self) -> CellCacheStats {
        CellCacheStats {
            entries: self.shards.iter().map(|s| s.lock().unwrap().len()).sum(),
            capacity: self.per_shard_capacity * SHARDS,
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            cells_simulated: self.simulated.load(Ordering::Relaxed),
        }
    }
}

/// Counters of the process-wide cell cache (the `/v1/metrics`
/// `cell_cache` section).
pub fn cell_cache_stats() -> CellCacheStats {
    CellCache::global().stats()
}

/// Counters of the disk store attached to the process-wide cache (the
/// `/v1/metrics` `cell_store` section); `None` when the process serves
/// purely from memory.
pub fn cell_store_stats() -> Option<CellStoreStats> {
    CellCache::global().store().map(CellStore::stats)
}

/// Run one uncacheable simulation under the process-wide gate — the
/// escape hatch for work that must not enter the cache (ad-hoc
/// devices) but must still respect the concurrency bound.
pub(crate) fn run_gated<T>(f: impl FnOnce() -> T) -> T {
    SimGate::global().run(f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn fake(lat: f64) -> Measurement {
        Measurement { warps: 0, ilp: 0, latency: lat, throughput: 2.0 * lat }
    }

    #[test]
    fn first_request_simulates_later_requests_hit() {
        let cache = CellCache::new(64);
        let calls = AtomicUsize::new(0);
        let p = ExecPoint::new(4, 2);
        let a = cache.get_or_simulate("mma bf16 f32 m16n8k16", "a100", p, "sim", || {
            calls.fetch_add(1, Ordering::SeqCst);
            fake(32.5)
        });
        assert_eq!(a.latency.to_bits(), 32.5f64.to_bits());
        assert_eq!((a.warps, a.ilp), (4, 2));
        // the second request is served from the cache, bit-identical,
        // without running the closure
        let b = cache.get_or_simulate("mma bf16 f32 m16n8k16", "a100", p, "sim", || {
            calls.fetch_add(1, Ordering::SeqCst);
            fake(99.0)
        });
        assert_eq!(b.latency.to_bits(), a.latency.to_bits());
        assert_eq!(b.throughput.to_bits(), a.throughput.to_bits());
        assert_eq!(calls.load(Ordering::SeqCst), 1);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.cells_simulated, s.entries), (1, 1, 1, 1));
        // contains() is a pure lookup: answers without moving counters
        assert!(cache.contains("mma bf16 f32 m16n8k16", "a100", p, "sim"));
        assert!(!cache.contains("mma bf16 f32 m16n8k16", "a100", ExecPoint::new(8, 2), "sim"));
        assert_eq!(cache.stats().hits, 1);
    }

    #[test]
    fn every_coordinate_is_part_of_the_address() {
        let cache = CellCache::new(64);
        let p = ExecPoint::new(4, 2);
        let base = ("mma bf16 f32 m16n8k16", "a100", p, "sim");
        cache.get_or_simulate(base.0, base.1, base.2, base.3, || fake(1.0));
        // spec, device, point and backend each address a distinct slot
        for (spec, dev, point, backend) in [
            ("mma fp16 f32 m16n8k16", base.1, base.2, base.3),
            (base.0, "rtx3070ti", base.2, base.3),
            (base.0, base.1, ExecPoint::new(4, 3), base.3),
            (base.0, base.1, ExecPoint::new(8, 2), base.3),
            (base.0, base.1, base.2, "pjrt"),
        ] {
            let m = cache.get_or_simulate(spec, dev, point, backend, || fake(7.0));
            assert_eq!(m.latency.to_bits(), 7.0f64.to_bits(), "{spec} {dev} {point} {backend}");
        }
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 6, 6));
    }

    #[test]
    fn profiled_requests_upgrade_and_then_hit_warm() {
        let cache = CellCache::new(64);
        let p = ExecPoint::new(2, 1);
        let sim_profiled = |profiler: &mut Profiler| {
            profiler.begin(2);
            profiler.account(&[crate::sim::Stall::Issued, crate::sim::Stall::Done], 10);
            fake(11.0)
        };
        // cold unprofiled fill -> entry has no profile
        cache.get_or_simulate("spec", "dev", p, "sim", || fake(11.0));
        assert_eq!(cache.stats().misses, 1);
        // first profiled request re-simulates (upgrade) and returns the
        // attribution
        let (m, prof) = cache.get_or_simulate_profiled("spec", "dev", p, "sim", true, sim_profiled);
        assert_eq!(m.latency.to_bits(), 11.0f64.to_bits());
        let prof = prof.expect("profiled miss must return a profile");
        assert_eq!(prof.warp_cycles, 20);
        let s = cache.stats();
        assert_eq!((s.misses, s.cells_simulated), (2, 2));
        // the profile is stored with the cell: a warm profiled request
        // is a pure hit and still reports attribution
        let (m2, prof2) = cache.get_or_simulate_profiled("spec", "dev", p, "sim", true, |_| {
            panic!("warm profiled request must not re-simulate")
        });
        assert_eq!(m2.latency.to_bits(), m.latency.to_bits());
        assert_eq!(prof2.unwrap(), prof);
        // unprofiled requests keep hitting too, with no profile attached
        let (_, none) = cache.get_or_simulate_profiled("spec", "dev", p, "sim", false, |_| {
            panic!("warm request must not re-simulate")
        });
        assert!(none.is_none());
        assert_eq!(cache.stats().hits, 2);
    }

    #[test]
    fn capacity_bound_evicts_oldest_and_counts() {
        // per-shard capacity 1 => 16 cells total
        let cache = CellCache::new(16);
        for i in 0..200u32 {
            cache.get_or_simulate("spec", "dev", ExecPoint::new(1, i), "sim", || fake(i as f64));
        }
        let s = cache.stats();
        assert!(s.entries <= 16, "{s:?}");
        assert!(s.evictions > 0, "{s:?}");
        assert_eq!(s.misses, 200);
    }

    #[test]
    fn global_cache_is_one_instance() {
        assert!(std::ptr::eq(CellCache::global(), CellCache::global()));
        assert!(CellCache::global().stats().capacity >= DEFAULT_CELL_CAPACITY);
    }

    /// Fresh scratch directory under the system temp dir (pid-scoped so
    /// parallel `cargo test` invocations never share state).
    fn scratch_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("tcbench_cell_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn store_round_trips_bit_identical_across_cache_instances() {
        let dir = scratch_dir("roundtrip");
        let p = ExecPoint::new(4, 2);
        // a latency with no short decimal form: bit-identity must come
        // from the hex bit encoding, not float formatting
        let odd = f64::from_bits(0x3ff5_5555_5555_5555);
        let a = CellCache::new(64);
        assert!(a.attach_store(CellStore::new(&dir)));
        assert!(!a.attach_store(CellStore::new(&dir)), "attach is configure-once");
        let m = a.get_or_simulate("spec", "dev", p, "sim", || Measurement {
            warps: 0,
            ilp: 0,
            latency: odd,
            throughput: odd * 3.0,
        });
        assert_eq!(a.store().unwrap().stats().writes, 1);
        // a second cache over the same directory — a restarted process,
        // or another replica — serves the cell from the store without
        // simulating, bit-identical
        let b = CellCache::new(64);
        assert!(b.attach_store(CellStore::new(&dir)));
        let n = b.get_or_simulate("spec", "dev", p, "sim", || panic!("must not simulate"));
        assert_eq!(n.latency.to_bits(), m.latency.to_bits());
        assert_eq!(n.throughput.to_bits(), m.throughput.to_bits());
        let s = b.stats();
        assert_eq!((s.misses, s.cells_simulated, s.entries), (1, 0, 1));
        let store = b.store().unwrap().stats();
        assert_eq!((store.hits, store.misses, store.corrupt), (1, 0, 0));
        // once filled from the store, repeats are pure memory hits
        b.get_or_simulate("spec", "dev", p, "sim", || panic!("memory hit must not simulate"));
        assert_eq!(b.stats().hits, 1);
        assert_eq!(b.store().unwrap().stats().hits, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_or_foreign_store_files_are_misses_not_panics() {
        let dir = scratch_dir("corrupt");
        let store = CellStore::new(&dir);
        let canonical = CellCache::canonical_key("spec", "dev", ExecPoint::new(1, 1), "sim");
        let hash = fnv1a(canonical.as_bytes());
        // missing directory / missing file: plain miss
        assert_eq!(store.load(hash, &canonical), None);
        // truncated JSON: corrupt, not a panic
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(format!("{hash:016x}.json")), b"{\"schema\": \"tcbench/").unwrap();
        assert_eq!(store.load(hash, &canonical), None);
        // a well-formed file whose canonical key is another cell's (an
        // FNV collision on disk): miss, never the wrong cell's numbers
        store.save(hash, "cell|some-other-cell", 1.0, 2.0);
        assert_eq!(store.load(hash, &canonical), None);
        let s = store.stats();
        assert_eq!((s.hits, s.writes), (0, 1));
        assert_eq!(s.misses, 3);
        assert_eq!(s.corrupt, 2);
        // the real cell then round-trips over the same slot
        store.save(hash, &canonical, 32.5, 65.0);
        let (lat, thr) = store.load(hash, &canonical).expect("round-trip");
        assert_eq!((lat.to_bits(), thr.to_bits()), (32.5f64.to_bits(), 65.0f64.to_bits()));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn profiled_requests_bypass_the_store_but_share_its_timing() {
        let dir = scratch_dir("profiled");
        let cache = CellCache::new(64);
        assert!(cache.attach_store(CellStore::new(&dir)));
        let p = ExecPoint::new(2, 1);
        cache.get_or_simulate("spec", "dev", p, "sim", || fake(11.0));
        // a fresh cache over the same store: a *profiled* request must
        // re-simulate (the store holds timing only) — the store's
        // counters stay untouched
        let warm = CellCache::new(64);
        assert!(warm.attach_store(CellStore::new(&dir)));
        let (m, prof) = warm.get_or_simulate_profiled("spec", "dev", p, "sim", true, |profiler| {
            profiler.begin(1);
            profiler.account(&[crate::sim::Stall::Done], 4);
            fake(11.0)
        });
        assert_eq!(m.latency.to_bits(), 11.0f64.to_bits());
        assert!(prof.is_some());
        let s = warm.store().unwrap().stats();
        assert_eq!((s.hits, s.misses), (0, 0));
        // while the re-simulation refreshed the stored timing
        assert_eq!(s.writes, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn parallel_requests_for_one_cold_cell_agree() {
        let cache = CellCache::new(64);
        let p = ExecPoint::new(8, 2);
        let lats: Vec<u64> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    scope.spawn(|| {
                        cache
                            .get_or_simulate("spec", "dev", p, "sim", || fake(42.0))
                            .latency
                            .to_bits()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(lats.iter().all(|&l| l == 42.0f64.to_bits()));
        let s = cache.stats();
        assert_eq!(s.entries, 1);
        assert_eq!(s.hits + s.cells_simulated, 8);
    }
}
