//! [`Workload::Numeric`](super::Workload::Numeric) — the §8
//! numeric-behavior studies as first-class workloads.
//!
//! A [`NumericProbe`] names one numeric experiment completely:
//!
//! * **profile** probes (§8.1, Tables 12–15): operand/accumulator dtype
//!   x [`ProfileOp`] (multiplication / inner-product add / accumulation)
//!   x [`InitKind`] (low-precision vs FP32 initialization);
//! * **chain** probes (§8.2, Fig. 17): dtype x chain length (x init).
//!
//! Spec grammar (round-tripping via [`NumericProbe::parse_tokens`] /
//! [`NumericProbe::to_spec`]):
//!
//! ```text
//! numeric profile <ab> <cd> <op> [init]   numeric profile bf16 f32 acc fp32
//! numeric chain <ab> <cd> <len> [init]    numeric chain tf32 f32 14
//! ```
//!
//! with `<ab>` one of `bf16|fp16|tf32|fp8e4m3|fp8e5m2` (the FP8 formats
//! are the paper's Table 11 Hopper extension and validate only on
//! fp8-capable devices), `<cd>` one of `f32|f16`, `<op>` one of
//! `mul|inner|acc` and `[init]` one of `low|fp32` (default `low`).
//!
//! Unlike the timing families, a probe has no (#warps, ILP) coordinate:
//! its only legal [`ExecPoint`](super::ExecPoint) is `(1,1)` and every
//! parameter lives in the spec, so the per-unit cache token
//! (`spec|point:w1:i1` under the resolved backend name) is the full
//! content address. Trial counts and PRNG seeds are fixed constants of
//! the probe ([`PROFILE_TRIALS`]/[`PROFILE_SEED`], [`CHAIN_TRIALS`]/
//! [`CHAIN_SEED`], the values the paper-pinned tables use) — they are
//! part of the probe's definition, not free parameters, precisely so
//! cached results stay comparable.
//!
//! A numeric *sweep* reuses the shared [`Sweep`] grid with reinterpreted
//! axes (the same move gemm makes with warps/stages): the first axis is
//! the chain step (`1..=len`; `[1]` for profile probes), the second the
//! init kind (`1` = low-precision, `2` = FP32). Cell `latency` carries
//! the probe's error metric — mean |err| for profile cells, the l2
//! relative error after that step for chain cells — and `throughput`
//! carries the Table 14 secondary baseline (error vs
//! `CPU_FP32cvtFP16`) for profile cells and `0` for chain cells.

use crate::device::Device;
use crate::microbench::{Sweep, SweepCell};
use crate::numerics::{
    chain_errors, profile_op, ChainResult, InitKind, MmaExec, NativeExec, NumericCfg,
    ProfileOp, ProfileResult,
};

/// Trials per profile probe (the paper's batch; Tables 12–15).
pub const PROFILE_TRIALS: usize = 1000;
/// PRNG seed of every profile probe.
pub const PROFILE_SEED: u64 = 7;
/// Trials per chain probe (x4 artifact batches ≈ the paper's 1000).
pub const CHAIN_TRIALS: usize = 250;
/// PRNG seed of every chain probe.
pub const CHAIN_SEED: u64 = 11;
/// Longest supported chain (Fig. 17 plots N = 14).
pub const CHAIN_MAX_LEN: u32 = 32;

/// Operand (A/B) dtype of a numeric probe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ProbeDtype {
    Bf16,
    Fp16,
    Tf32,
    /// OCP FP8 E4M3 (Hopper, Table 11) — saturating, no infinities.
    Fp8E4m3,
    /// OCP FP8 E5M2 (Hopper, Table 11) — IEEE-style overflow to inf.
    Fp8E5m2,
}

impl ProbeDtype {
    /// The `NumericCfg`/`quantize` dtype string.
    pub fn name(self) -> &'static str {
        match self {
            ProbeDtype::Bf16 => "bf16",
            ProbeDtype::Fp16 => "fp16",
            ProbeDtype::Tf32 => "tf32",
            ProbeDtype::Fp8E4m3 => "fp8e4m3",
            ProbeDtype::Fp8E5m2 => "fp8e5m2",
        }
    }

    pub fn is_fp8(self) -> bool {
        matches!(self, ProbeDtype::Fp8E4m3 | ProbeDtype::Fp8E5m2)
    }

    pub fn parse_spec(s: &str) -> Result<ProbeDtype, String> {
        match s.to_ascii_lowercase().as_str() {
            "bf16" => Ok(ProbeDtype::Bf16),
            "fp16" | "f16" => Ok(ProbeDtype::Fp16),
            "tf32" => Ok(ProbeDtype::Tf32),
            "fp8e4m3" | "e4m3" => Ok(ProbeDtype::Fp8E4m3),
            "fp8e5m2" | "e5m2" => Ok(ProbeDtype::Fp8E5m2),
            other => Err(format!(
                "unknown numeric operand dtype {other:?} (bf16|fp16|tf32|fp8e4m3|fp8e5m2)"
            )),
        }
    }
}

/// Accumulator (C/D) dtype of a numeric probe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AccDtype {
    F32,
    F16,
}

impl AccDtype {
    /// The `NumericCfg` dtype string.
    pub fn name(self) -> &'static str {
        match self {
            AccDtype::F32 => "f32",
            AccDtype::F16 => "f16",
        }
    }

    pub fn parse_spec(s: &str) -> Result<AccDtype, String> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" => Ok(AccDtype::F32),
            "f16" | "fp16" => Ok(AccDtype::F16),
            other => Err(format!("unknown numeric accumulator dtype {other:?} (f32|f16)")),
        }
    }
}

/// Which §8 study a probe runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ProbeKind {
    /// §8.1 element-wise profiling (one operation, one init strategy).
    Profile { op: ProfileOp, init: InitKind },
    /// §8.2 chain matmul, `len` steps.
    Chain { len: u32, init: InitKind },
}

/// Typed parameters of a [`Workload::Numeric`](super::Workload::Numeric):
/// everything that names the experiment. There is no free execution
/// coordinate — see the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct NumericProbe {
    pub ab: ProbeDtype,
    pub cd: AccDtype,
    pub kind: ProbeKind,
}

/// The output of one executed numeric probe.
#[derive(Debug, Clone)]
pub enum NumericOutput {
    Profile(ProfileResult),
    Chain(ChainResult),
}

impl NumericProbe {
    pub const fn profile(ab: ProbeDtype, cd: AccDtype, op: ProfileOp, init: InitKind) -> Self {
        NumericProbe { ab, cd, kind: ProbeKind::Profile { op, init } }
    }

    pub const fn chain(ab: ProbeDtype, cd: AccDtype, len: u32, init: InitKind) -> Self {
        NumericProbe { ab, cd, kind: ProbeKind::Chain { len, init } }
    }

    /// The emulated-instruction configuration this probe runs on: the
    /// paper's profiling shape m16n8k8 (k = n, as the chain study's
    /// D -> A feedback requires).
    pub fn cfg(&self) -> NumericCfg {
        NumericCfg::new(self.ab.name(), self.cd.name(), 16, 8, 8)
    }

    /// This probe with a different init strategy (the sweep's second
    /// axis varies init while everything else stays fixed).
    pub fn with_init(&self, init: InitKind) -> NumericProbe {
        let kind = match self.kind {
            ProbeKind::Profile { op, .. } => ProbeKind::Profile { op, init },
            ProbeKind::Chain { len, .. } => ProbeKind::Chain { len, init },
        };
        NumericProbe { kind, ..*self }
    }

    /// Parse the tokens after the `numeric` keyword. The inverse of
    /// [`NumericProbe::to_spec`].
    pub fn parse_tokens(parts: &[&str]) -> Result<NumericProbe, String> {
        let usage = "numeric workload spec must be \"numeric profile <ab> <cd> <op> [init]\" \
                     or \"numeric chain <ab> <cd> <len> [init]\"";
        let Some(&study) = parts.first() else {
            return Err(format!("{usage}, got a bare \"numeric\""));
        };
        if parts.len() < 4 || parts.len() > 5 {
            return Err(format!("{usage}, got {} tokens", parts.len() + 1));
        }
        let ab = ProbeDtype::parse_spec(parts[1])?;
        let cd = AccDtype::parse_spec(parts[2])?;
        let init = match parts.get(4) {
            Some(tok) => InitKind::parse_spec(tok)?,
            None => InitKind::LowPrecision,
        };
        match study.to_ascii_lowercase().as_str() {
            "profile" => {
                let op = ProfileOp::parse_spec(parts[3])?;
                Ok(NumericProbe::profile(ab, cd, op, init))
            }
            "chain" => {
                let len: u32 = parts[3]
                    .parse()
                    .map_err(|_| format!("chain length must be a number, got {:?}", parts[3]))?;
                Ok(NumericProbe::chain(ab, cd, len, init))
            }
            other => Err(format!("unknown numeric study {other:?} (profile|chain)")),
        }
    }

    /// Canonical spec string, including the `numeric` keyword. Always
    /// emits the init token so the cache-key coordinate is explicit.
    pub fn to_spec(&self) -> String {
        match self.kind {
            ProbeKind::Profile { op, init } => format!(
                "numeric profile {} {} {} {}",
                self.ab.name(),
                self.cd.name(),
                op.spec_name(),
                init.spec_name()
            ),
            ProbeKind::Chain { len, init } => format!(
                "numeric chain {} {} {} {}",
                self.ab.name(),
                self.cd.name(),
                len,
                init.spec_name()
            ),
        }
    }

    /// Is this probe well-formed and runnable on `device`?
    pub fn validate(&self, device: &Device) -> Result<(), String> {
        if self.cd == AccDtype::F16 && self.ab != ProbeDtype::Fp16 {
            return Err(format!(
                "numeric probes accumulate in f32 except the paper's fp16/f16 \
                 configuration; {}/f16 is not a Tensor-Core pairing",
                self.ab.name()
            ));
        }
        if self.ab.is_fp8() && !device.supports_fp8() {
            return Err(format!(
                "{} probes need FP8 Tensor Cores, which {} lacks \
                 (Table 11 lists FP8 for Hopper: try hopper-projected)",
                self.ab.name(),
                device.name
            ));
        }
        if let ProbeKind::Chain { len, .. } = self.kind {
            if !(1..=CHAIN_MAX_LEN).contains(&len) {
                return Err(format!(
                    "chain length must be in 1..={CHAIN_MAX_LEN}, got {len}"
                ));
            }
        }
        Ok(())
    }

    /// Run this probe on an executor — the only call site of
    /// [`profile_op`]/[`chain_errors`] outside `numerics/` itself.
    pub fn run_on(&self, exec: &mut dyn MmaExec) -> NumericOutput {
        match self.kind {
            ProbeKind::Profile { op, init } => {
                NumericOutput::Profile(profile_op(exec, op, init, PROFILE_TRIALS, PROFILE_SEED))
            }
            ProbeKind::Chain { len, init } => NumericOutput::Chain(chain_errors(
                exec,
                len as usize,
                CHAIN_TRIALS,
                init == InitKind::LowPrecision,
                CHAIN_SEED,
            )),
        }
    }

    /// Run this probe on the native softfloat datapath (the simulator
    /// backend's numeric leg).
    pub fn run_native(&self) -> NumericOutput {
        self.run_on(&mut NativeExec::new(self.cfg()))
    }

    /// The headline error of one probe output: mean |err| for profile
    /// probes, the final-step l2 relative error for chain probes.
    pub fn headline(output: &NumericOutput) -> f64 {
        match output {
            NumericOutput::Profile(p) => p.mean_abs_err,
            NumericOutput::Chain(c) => c.rel_err.last().copied().unwrap_or(f64::NAN),
        }
    }

    /// First sweep axis: chain steps for chain probes, `[1]` otherwise.
    pub fn sweep_first_axis(&self) -> Vec<u32> {
        match self.kind {
            ProbeKind::Chain { len, .. } => (1..=len).collect(),
            ProbeKind::Profile { .. } => vec![1],
        }
    }

    /// Second sweep axis: the init kinds (`1` = low-precision, `2` = FP32).
    pub fn sweep_init_axis(&self) -> Vec<u32> {
        vec![1, 2]
    }

    const INIT_AXIS: [InitKind; 2] = [InitKind::LowPrecision, InitKind::Fp32];

    /// Assemble the numeric sweep grid by running one probe variant per
    /// init kind through `run` (the backend seam: runners pass their
    /// numeric leg in). Chain probes fill the whole step axis from a
    /// single run per init — `chain_errors` reports every intermediate
    /// step.
    pub fn sweep_with(
        &self,
        label: String,
        mut run: impl FnMut(&NumericProbe) -> Result<NumericOutput, String>,
    ) -> Result<Sweep, String> {
        let warps_axis = self.sweep_first_axis();
        let ilp_axis = self.sweep_init_axis();
        let mut columns: Vec<Vec<(f64, f64)>> = Vec::with_capacity(ilp_axis.len());
        for init in Self::INIT_AXIS {
            let out = run(&self.with_init(init))?;
            let column: Vec<(f64, f64)> = match out {
                NumericOutput::Profile(p) => vec![(p.mean_abs_err, p.mean_abs_err_vs_cvt_fp16)],
                NumericOutput::Chain(c) => c.rel_err.iter().map(|&e| (e, 0.0)).collect(),
            };
            if column.len() != warps_axis.len() {
                return Err(format!(
                    "numeric sweep shape mismatch: {} cells for a {}-step axis",
                    column.len(),
                    warps_axis.len()
                ));
            }
            columns.push(column);
        }
        let mut cells = Vec::with_capacity(warps_axis.len() * ilp_axis.len());
        for (si, &step) in warps_axis.iter().enumerate() {
            for (ii, &init_coord) in ilp_axis.iter().enumerate() {
                let (latency, throughput) = columns[ii][si];
                cells.push(SweepCell { warps: step, ilp: init_coord, latency, throughput });
            }
        }
        Ok(Sweep { label, warps_axis, ilp_axis, cells })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{a100, hopper_projected};

    #[test]
    fn spec_round_trips() {
        for spec in [
            "numeric profile bf16 f32 mul low",
            "numeric profile fp16 f16 acc fp32",
            "numeric profile fp8e4m3 f32 inner low",
            "numeric chain tf32 f32 14 low",
            "numeric chain fp16 f16 10 fp32",
        ] {
            let parts: Vec<&str> = spec.split_whitespace().skip(1).collect();
            let probe = NumericProbe::parse_tokens(&parts).unwrap();
            assert_eq!(probe.to_spec(), spec, "{spec}");
        }
        // init defaults to low-precision and the canonical form makes
        // the default explicit
        let parts = ["profile", "bf16", "f32", "acc"];
        let probe = NumericProbe::parse_tokens(&parts).unwrap();
        assert_eq!(probe.to_spec(), "numeric profile bf16 f32 acc low");
    }

    #[test]
    fn parse_rejects_malformed_probes() {
        for parts in [
            vec![],
            vec!["profile"],
            vec!["profile", "bf16", "f32"],
            vec!["profile", "int8", "f32", "mul"],
            vec!["profile", "bf16", "i32", "mul"],
            vec!["profile", "bf16", "f32", "divide"],
            vec!["profile", "bf16", "f32", "mul", "maybe"],
            vec!["profile", "bf16", "f32", "mul", "low", "extra"],
            vec!["chain", "tf32", "f32", "many"],
            vec!["chain", "tf32", "f32", "0"],      // parses, fails validate
            vec!["anneal", "bf16", "f32", "mul"],
        ] {
            let r = NumericProbe::parse_tokens(&parts);
            let ok = r.is_ok() && r.unwrap().validate(&a100()).is_ok();
            assert!(!ok, "{parts:?} should be rejected");
        }
    }

    #[test]
    fn validation_gates_fp8_and_pairings() {
        let ampere = a100();
        let hopper = hopper_projected();
        let fp8 = NumericProbe::profile(
            ProbeDtype::Fp8E4m3,
            AccDtype::F32,
            ProfileOp::Multiplication,
            InitKind::Fp32,
        );
        let err = fp8.validate(&ampere).unwrap_err();
        assert!(err.contains("hopper-projected"), "{err}");
        assert!(fp8.validate(&hopper).is_ok());
        // f16 accumulation is the paper's fp16-only configuration
        let bad = NumericProbe::profile(
            ProbeDtype::Bf16,
            AccDtype::F16,
            ProfileOp::Multiplication,
            InitKind::LowPrecision,
        );
        assert!(bad.validate(&ampere).is_err());
        // chain lengths are bounded
        let long = NumericProbe::chain(ProbeDtype::Tf32, AccDtype::F32, 33, InitKind::LowPrecision);
        assert!(long.validate(&ampere).unwrap_err().contains("1..=32"));
    }

    #[test]
    fn run_on_matches_direct_numerics_calls() {
        let probe = NumericProbe::profile(
            ProbeDtype::Bf16,
            AccDtype::F32,
            ProfileOp::Accumulation,
            InitKind::LowPrecision,
        );
        let NumericOutput::Profile(got) = probe.run_native() else { panic!("profile output") };
        let want = profile_op(
            &mut NativeExec::new(probe.cfg()),
            ProfileOp::Accumulation,
            InitKind::LowPrecision,
            PROFILE_TRIALS,
            PROFILE_SEED,
        );
        assert_eq!(got.mean_abs_err.to_bits(), want.mean_abs_err.to_bits());

        let chain = NumericProbe::chain(ProbeDtype::Tf32, AccDtype::F32, 6, InitKind::LowPrecision);
        let NumericOutput::Chain(got) = chain.run_native() else { panic!("chain output") };
        let want = chain_errors(&mut NativeExec::new(chain.cfg()), 6, CHAIN_TRIALS, true, CHAIN_SEED);
        assert_eq!(got.rel_err, want.rel_err);
        assert_eq!(got.overflow_at, want.overflow_at);
    }

    #[test]
    fn sweep_reinterprets_axes_as_step_and_init() {
        let chain = NumericProbe::chain(ProbeDtype::Tf32, AccDtype::F32, 5, InitKind::LowPrecision);
        let sweep = chain
            .sweep_with("chain".into(), |p| Ok(p.run_native()))
            .unwrap();
        assert_eq!(sweep.warps_axis, vec![1, 2, 3, 4, 5]);
        assert_eq!(sweep.ilp_axis, vec![1, 2]);
        assert_eq!(sweep.cells.len(), 10);
        // error grows with chain length on both init columns, and FP32
        // init is strictly worse at every step (§8.2)
        for init in [1, 2] {
            assert!(sweep.cell(5, init).unwrap().latency > sweep.cell(1, init).unwrap().latency);
        }
        for step in 1..=5 {
            let low = sweep.cell(step, 1).unwrap().latency;
            let f32i = sweep.cell(step, 2).unwrap().latency;
            assert!(f32i > low, "step {step}: {f32i:e} vs {low:e}");
        }

        let profile = NumericProbe::profile(
            ProbeDtype::Fp16,
            AccDtype::F32,
            ProfileOp::Multiplication,
            InitKind::LowPrecision,
        );
        let sweep = profile.sweep_with("profile".into(), |p| Ok(p.run_native())).unwrap();
        assert_eq!(sweep.warps_axis, vec![1]);
        assert_eq!(sweep.ilp_axis, vec![1, 2]);
        // Table 13: zero error under low-precision init, nonzero under FP32
        assert_eq!(sweep.cell(1, 1).unwrap().latency, 0.0);
        assert!(sweep.cell(1, 2).unwrap().latency > 0.0);
    }

    #[test]
    fn fp8_probes_run_on_the_native_datapath() {
        // forward-looking Table 11 formats: fewer mantissa bits than
        // bf16 -> strictly larger multiplication error under FP32 init
        let err_of = |ab| {
            let p = NumericProbe::profile(ab, AccDtype::F32, ProfileOp::Multiplication, InitKind::Fp32);
            let NumericOutput::Profile(r) = p.run_native() else { panic!() };
            r.mean_abs_err
        };
        let e5m2 = err_of(ProbeDtype::Fp8E5m2);
        let e4m3 = err_of(ProbeDtype::Fp8E4m3);
        let bf16 = err_of(ProbeDtype::Bf16);
        assert!(e5m2 > e4m3 && e4m3 > bf16, "{e5m2:e} {e4m3:e} {bf16:e}");
    }
}
