//! [`Runner`] — the backend seam of the workload layer.
//!
//! A runner executes [`BenchPlan`] units. Backend selection happens
//! exactly once, when a runner is constructed ([`runner_for`]), instead
//! of per call site: [`SimRunner`] is the cycle-level simulator backend
//! (timing on tcsim, numerics on the native softfloat datapath),
//! [`ArtifactRunner`] is the PJRT artifact runtime (or its offline
//! stub, whose construction fails with an actionable message, sending
//! callers down the simulator path).
//!
//! The **numeric leg** ([`Runner::run_numeric`]) is where the backends
//! genuinely differ: a [`Workload::Numeric`] point or sweep unit runs
//! the §8 probe on the runner's own datapath — `NativeExec` softfloat
//! for [`SimRunner`], the AOT Pallas artifacts through PJRT for
//! [`ArtifactRunner`] — while timing units are simulator-measured on
//! every backend (the artifacts cover the numeric datapath, not cycle
//! timing). tcserved keys every cached unit under [`Runner::name`], so
//! the resolved backend is part of each content address.

use std::sync::Mutex;

use crate::coordinator::{default_threads, BackendKind};
use crate::microbench::convergence_point;
use crate::runtime::{ArtifactExec, ArtifactStore};
use crate::sim::{ProfileMode, SimProfile};

use super::numeric::{NumericOutput, NumericProbe};
use super::plan::{BenchPlan, UnitKind, UnitOutput};
use super::{ExecPoint, Workload};

/// Executes plan units against one backend. Implementations must be
/// [`Sync`]: the plan executor and tcserved both fan units out across
/// worker threads sharing one runner.
pub trait Runner: Sync {
    /// Stable backend name — a cache-key coordinate in tcserved.
    fn name(&self) -> &'static str;

    /// The backend-name coordinate of this runner's *timing* cells in
    /// the process-wide cell cache. Timing units are simulator-measured
    /// on every current backend — the PJRT artifacts cover the numeric
    /// datapath, not cycle timing — so the default shares the
    /// simulator's cells across runners instead of re-simulating
    /// identical work per backend name. A future backend that measures
    /// timing on its own datapath must override this.
    fn timing_backend(&self) -> &'static str {
        "sim"
    }

    /// Execute one unit of a compiled plan.
    fn run_unit(&self, plan: &BenchPlan, unit: &UnitKind) -> Result<UnitOutput, String>;

    /// [`Runner::run_unit`] with stall attribution: the simulations
    /// behind timing units run through a profiler of `mode`, and the
    /// unit's merged [`SimProfile`] rides alongside the output (`None`
    /// when `mode` is off, the unit is numeric, or — the default
    /// implementation — the backend has no profiled path).
    fn run_unit_profiled(
        &self,
        plan: &BenchPlan,
        unit: &UnitKind,
        mode: ProfileMode,
    ) -> Result<(UnitOutput, Option<SimProfile>), String> {
        let _ = mode;
        Ok((self.run_unit(plan, unit)?, None))
    }

    /// The numeric leg: execute one §8 probe on this backend's numeric
    /// datapath.
    fn run_numeric(&self, probe: &NumericProbe) -> Result<NumericOutput, String>;
}

/// Shared unit dispatch: numeric workloads route through the runner's
/// numeric leg (point = one probe, sweep = one probe variant per init
/// kind assembled into the step x init grid); timing workloads run on
/// the cycle simulator regardless of backend — through the cell-level
/// execution engine, so every point/sweep-cell/completion simulation is
/// memoized in the process-wide [`CellCache`](super::CellCache) under
/// the runner's [`Runner::timing_backend`] name (the simulator's, for
/// every current backend) and sweep cells fan out across the worker
/// pool.
fn dispatch_unit(
    runner: &dyn Runner,
    plan: &BenchPlan,
    unit: &UnitKind,
) -> Result<UnitOutput, String> {
    dispatch_unit_profiled(runner, plan, unit, ProfileMode::Off).map(|(out, _)| out)
}

/// [`dispatch_unit`] with stall attribution: timing units thread a
/// profiler of `mode` through the cell-level execution engine (profiles
/// are cached with the cells, so warm units still report attribution);
/// numeric units run no cycle simulation and carry no profile.
fn dispatch_unit_profiled(
    runner: &dyn Runner,
    plan: &BenchPlan,
    unit: &UnitKind,
    mode: ProfileMode,
) -> Result<(UnitOutput, Option<SimProfile>), String> {
    if let Workload::Numeric(probe) = plan.workload {
        return match unit {
            UnitKind::Completion => Err(format!(
                "numeric probe {} has no completion latency (the plan compiler \
                 rejects this unit)",
                plan.workload
            )),
            UnitKind::Point(_) => Ok((UnitOutput::Numeric(runner.run_numeric(&probe)?), None)),
            UnitKind::Sweep => {
                let sweep = probe
                    .sweep_with(plan.workload.to_string(), |p| runner.run_numeric(p))?;
                let convergence = plan
                    .convergence_warps
                    .iter()
                    .map(|&w| convergence_point(&sweep, w))
                    .collect();
                Ok((UnitOutput::Sweep { sweep, convergence }, None))
            }
        };
    }
    let backend = runner.timing_backend();
    Ok(match unit {
        UnitKind::Completion => {
            let (m, profile) = plan.workload.measure_cached_profiled(
                &plan.device,
                ExecPoint::new(1, 1),
                backend,
                mode,
            );
            (UnitOutput::Completion(m.latency), profile)
        }
        UnitKind::Point(p) => {
            let (m, profile) =
                plan.workload.measure_cached_profiled(&plan.device, *p, backend, mode);
            (UnitOutput::Point(m), profile)
        }
        UnitKind::Sweep => {
            let (sweep, profile) = plan.workload.sweep_via_profiled(
                &plan.device,
                backend,
                default_threads(),
                mode,
            );
            let convergence = plan
                .convergence_warps
                .iter()
                .map(|&w| convergence_point(&sweep, w))
                .collect();
            (UnitOutput::Sweep { sweep, convergence }, profile)
        }
    })
}

/// The cycle-level SM-simulator backend (always available); its numeric
/// leg is the native softfloat datapath.
pub struct SimRunner;

impl Runner for SimRunner {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn run_unit(&self, plan: &BenchPlan, unit: &UnitKind) -> Result<UnitOutput, String> {
        dispatch_unit(self, plan, unit)
    }

    fn run_unit_profiled(
        &self,
        plan: &BenchPlan,
        unit: &UnitKind,
        mode: ProfileMode,
    ) -> Result<(UnitOutput, Option<SimProfile>), String> {
        dispatch_unit_profiled(self, plan, unit, mode)
    }

    fn run_numeric(&self, probe: &NumericProbe) -> Result<NumericOutput, String> {
        Ok(probe.run_native())
    }
}

/// The PJRT artifact-runtime backend. Construction opens the artifact
/// store (it is not openable in offline builds — the stub runtime
/// returns an error, sending callers down the simulator path).
///
/// Timing workloads are simulator-measured on every backend — the AOT
/// artifacts cover the §8 numeric datapath, not cycle timing — so those
/// units delegate to the shared simulator dispatch while keying results
/// under this runner's backend name. Numeric probes execute on the
/// artifacts; the store is a single stateful compilation cache, so the
/// numeric leg serializes on a mutex (matching the old campaign's
/// serial numeric phase).
pub struct ArtifactRunner {
    store: Mutex<ArtifactStore>,
}

impl ArtifactRunner {
    pub fn new() -> Result<ArtifactRunner, String> {
        let store = ArtifactStore::open_default().map_err(|e| format!("{e:#}"))?;
        Ok(ArtifactRunner { store: Mutex::new(store) })
    }
}

impl Runner for ArtifactRunner {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn run_unit(&self, plan: &BenchPlan, unit: &UnitKind) -> Result<UnitOutput, String> {
        dispatch_unit(self, plan, unit)
    }

    fn run_numeric(&self, probe: &NumericProbe) -> Result<NumericOutput, String> {
        // a panic in an earlier probe (caught upstream) poisons the
        // lock, but the store is only a compilation cache — at worst an
        // entry is missing — so recover instead of failing every later
        // numeric request until restart
        let mut store = self.store.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        let mut exec = ArtifactExec::new(&mut store, probe.cfg()).map_err(|e| {
            if probe.ab.is_fp8() {
                format!("{e:#} (fp8 probes have no AOT artifacts yet)")
            } else {
                format!("{e:#}")
            }
        })?;
        Ok(probe.run_on(&mut exec))
    }
}

/// Resolve a requested backend kind to a runner, once. `Auto` picks
/// PJRT when artifacts are available and the simulator backend
/// otherwise — including when the artifact store turns out not to be
/// *openable* (manifest present but the PJRT runtime unavailable or the
/// manifest corrupt), so `Auto` never fails, exactly like the retired
/// `Backend::auto()`. An explicit `Pjrt` request still surfaces the
/// open error.
pub fn runner_for(kind: BackendKind) -> Result<Box<dyn Runner>, String> {
    match kind.resolve() {
        BackendKind::Native => Ok(Box::new(SimRunner)),
        BackendKind::Pjrt => match ArtifactRunner::new() {
            Ok(r) => Ok(Box::new(r)),
            Err(_) if kind == BackendKind::Auto => Ok(Box::new(SimRunner)),
            Err(e) => Err(e),
        },
        BackendKind::Auto => unreachable!("resolve() returns a concrete kind"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_resolves_to_the_sim_runner() {
        assert_eq!(runner_for(BackendKind::Native).unwrap().name(), "sim");
    }

    #[test]
    fn sim_runner_executes_gemm_units() {
        use crate::gemm::Variant;
        use crate::workload::{GemmParams, Plan, Workload};
        let w = Workload::Gemm(GemmParams {
            size: 256,
            ..GemmParams::paper(Variant::Baseline, false)
        });
        let plan = Plan::new(w).point(8, 1).compile().unwrap();
        let out = SimRunner.run_unit(&plan, &plan.units[0]).unwrap();
        match out {
            UnitOutput::Point(m) => assert!(m.throughput > 0.0 && m.latency > 0.0, "{m:?}"),
            other => panic!("expected a point output, got {other:?}"),
        }
    }

    #[test]
    fn sim_runner_numeric_leg_is_the_native_datapath() {
        use crate::numerics::{profile_op, InitKind, NativeExec, ProfileOp};
        use crate::workload::{Plan, Workload, PROFILE_SEED, PROFILE_TRIALS};
        let w = Workload::parse_spec("numeric profile tf32 f32 inner fp32").unwrap();
        let plan = Plan::new(w).point(1, 1).compile().unwrap();
        let out = SimRunner.run_unit(&plan, &plan.units[0]).unwrap();
        let UnitOutput::Numeric(NumericOutput::Profile(got)) = out else {
            panic!("expected a numeric profile output")
        };
        let Workload::Numeric(probe) = w else { unreachable!() };
        let want = profile_op(
            &mut NativeExec::new(probe.cfg()),
            ProfileOp::InnerProduct,
            InitKind::Fp32,
            PROFILE_TRIALS,
            PROFILE_SEED,
        );
        assert_eq!(got.mean_abs_err.to_bits(), want.mean_abs_err.to_bits());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_runner_unavailable_offline() {
        let err = runner_for(BackendKind::Pjrt).unwrap_err();
        assert!(err.contains("pjrt") || err.contains("PJRT"), "{err}");
        // auto therefore falls back to the simulator backend
        assert_eq!(runner_for(BackendKind::Auto).unwrap().name(), "sim");
    }
}
