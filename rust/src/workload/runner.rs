//! [`Runner`] — the backend seam of the workload layer.
//!
//! A runner executes [`BenchPlan`] units. Backend selection happens
//! exactly once, when a runner is constructed ([`runner_for`]), instead
//! of per call site: [`SimRunner`] is the cycle-level simulator backend
//! (timing on tcsim, numerics on the native softfloat datapath),
//! [`ArtifactRunner`] is the PJRT artifact runtime (or its offline
//! stub, whose construction fails with an actionable message, sending
//! callers down the simulator path).
//!
//! The **numeric leg** ([`Runner::run_numeric`]) is where the backends
//! genuinely differ: a [`Workload::Numeric`] point or sweep unit runs
//! the §8 probe on the runner's own datapath — `NativeExec` softfloat
//! for [`SimRunner`], the AOT Pallas artifacts through PJRT for
//! [`ArtifactRunner`] — while timing units are simulator-measured on
//! every backend (the artifacts cover the numeric datapath, not cycle
//! timing). tcserved keys every cached unit under [`Runner::name`], so
//! the resolved backend is part of each content address.

use std::sync::Mutex;

use crate::coordinator::{default_threads, BackendKind};
use crate::microbench::{convergence_point, Measurement};
use crate::runtime::{ArtifactExec, ArtifactStore};
use crate::sim::{calibration_bound, Budget, BudgetBlown, ProfileMode, SimProfile};

use super::numeric::{NumericOutput, NumericProbe};
use super::plan::{BenchPlan, UnitKind, UnitOutput};
use super::{ExecPoint, Workload};

/// Executes plan units against one backend. Implementations must be
/// [`Sync`]: the plan executor and tcserved both fan units out across
/// worker threads sharing one runner.
pub trait Runner: Sync {
    /// Stable backend name — a cache-key coordinate in tcserved.
    fn name(&self) -> &'static str;

    /// The backend-name coordinate of this runner's *timing* cells in
    /// the process-wide cell cache. Timing units are simulator-measured
    /// on every current backend — the PJRT artifacts cover the numeric
    /// datapath, not cycle timing — so the default shares the
    /// simulator's cells across runners instead of re-simulating
    /// identical work per backend name. A future backend that measures
    /// timing on its own datapath must override this.
    fn timing_backend(&self) -> &'static str {
        "sim"
    }

    /// Execute one unit of a compiled plan.
    fn run_unit(&self, plan: &BenchPlan, unit: &UnitKind) -> Result<UnitOutput, String>;

    /// [`Runner::run_unit`] with stall attribution: the simulations
    /// behind timing units run through a profiler of `mode`, and the
    /// unit's merged [`SimProfile`] rides alongside the output (`None`
    /// when `mode` is off, the unit is numeric, or — the default
    /// implementation — the backend has no profiled path).
    fn run_unit_profiled(
        &self,
        plan: &BenchPlan,
        unit: &UnitKind,
        mode: ProfileMode,
    ) -> Result<(UnitOutput, Option<SimProfile>), String> {
        let _ = mode;
        Ok((self.run_unit(plan, unit)?, None))
    }

    /// The numeric leg: execute one §8 probe on this backend's numeric
    /// datapath.
    fn run_numeric(&self, probe: &NumericProbe) -> Result<NumericOutput, String>;
}

/// Shared unit dispatch: numeric workloads route through the runner's
/// numeric leg (point = one probe, sweep = one probe variant per init
/// kind assembled into the step x init grid); timing workloads run on
/// the cycle simulator regardless of backend — through the cell-level
/// execution engine, so every point/sweep-cell/completion simulation is
/// memoized in the process-wide [`CellCache`](super::CellCache) under
/// the runner's [`Runner::timing_backend`] name (the simulator's, for
/// every current backend) and sweep cells fan out across the worker
/// pool.
fn dispatch_unit(
    runner: &dyn Runner,
    plan: &BenchPlan,
    unit: &UnitKind,
) -> Result<UnitOutput, String> {
    dispatch_unit_profiled(runner, plan, unit, ProfileMode::Off).map(|(out, _)| out)
}

/// [`dispatch_unit`] with stall attribution: timing units thread a
/// profiler of `mode` through the cell-level execution engine (profiles
/// are cached with the cells, so warm units still report attribution);
/// numeric units run no cycle simulation and carry no profile.
fn dispatch_unit_profiled(
    runner: &dyn Runner,
    plan: &BenchPlan,
    unit: &UnitKind,
    mode: ProfileMode,
) -> Result<(UnitOutput, Option<SimProfile>), String> {
    if let Workload::Numeric(probe) = plan.workload {
        return match unit {
            UnitKind::Completion => Err(format!(
                "numeric probe {} has no completion latency (the plan compiler \
                 rejects this unit)",
                plan.workload
            )),
            UnitKind::Point(_) => Ok((UnitOutput::Numeric(runner.run_numeric(&probe)?), None)),
            UnitKind::Sweep => {
                let sweep = probe
                    .sweep_with(plan.workload.to_string(), |p| runner.run_numeric(p))?;
                let convergence = plan
                    .convergence_warps
                    .iter()
                    .map(|&w| convergence_point(&sweep, w))
                    .collect();
                Ok((UnitOutput::Sweep { sweep, convergence }, None))
            }
        };
    }
    let backend = runner.timing_backend();
    Ok(match unit {
        UnitKind::Completion => {
            let (m, profile) = plan.workload.measure_cached_profiled(
                &plan.device,
                ExecPoint::new(1, 1),
                backend,
                mode,
            );
            (UnitOutput::Completion(m.latency), profile)
        }
        UnitKind::Point(p) => {
            let (m, profile) =
                plan.workload.measure_cached_profiled(&plan.device, *p, backend, mode);
            (UnitOutput::Point(m), profile)
        }
        UnitKind::Sweep => {
            let (sweep, profile) = plan.workload.sweep_via_profiled(
                &plan.device,
                backend,
                default_threads(),
                mode,
            );
            let convergence = plan
                .convergence_warps
                .iter()
                .map(|&w| convergence_point(&sweep, w))
                .collect();
            (UnitOutput::Sweep { sweep, convergence }, profile)
        }
    })
}

/// How one budgeted unit was produced ([`run_unit_budgeted`]).
#[derive(Debug)]
pub enum UnitRun {
    /// The cycle simulation (or numeric datapath run) completed within
    /// the budget — or no budget was set.
    Simulated(UnitOutput),
    /// The budget blew before (or during) the cycle simulation: the
    /// output is the calibrated analytic prediction instead.
    Degraded {
        output: UnitOutput,
        /// Human-readable account of why the unit degraded.
        reason: String,
        /// Whether this workload family's analytic error is pinned by a
        /// CI-enforced [`CalibrationBound`](crate::sim::CalibrationBound).
        within_calibration: bool,
    },
}

/// Typed failure of a budgeted unit run.
#[derive(Debug)]
pub enum UnitError {
    /// The deadline passed and the unit has no analytic model to
    /// degrade to (numeric probes run the real datapath or nothing).
    DeadlineExceeded(String),
    /// Ordinary execution failure, budget aside.
    Failed(String),
}

/// [`Runner::run_unit`] under an optional per-request wall-clock
/// [`Budget`]. Timing units that blow the budget — up front or
/// mid-simulation, via the [`budget`](crate::sim::budget) watchdog in
/// the cycle loop — degrade to the calibrated analytic `predict_*`
/// family instead of failing: a point or completion unit serves
/// [`Workload::predict`], a sweep serves [`Workload::predict_sweep`]
/// with convergence points recomputed over the predicted grid. Numeric
/// units have no analytic stand-in, so an already-expired budget is a
/// typed [`UnitError::DeadlineExceeded`]; once started they run to
/// completion (the probes are fast and have no watchdog seam).
///
/// Degraded outputs are never inserted into the cell cache or the disk
/// store (the cell layer checks the blown flag), so a later request
/// without a deadline re-simulates and gets the bit-exact answer.
pub fn run_unit_budgeted(
    runner: &dyn Runner,
    plan: &BenchPlan,
    unit: &UnitKind,
    budget: Option<Budget>,
) -> Result<UnitRun, UnitError> {
    let Some(budget) = budget else {
        return runner.run_unit(plan, unit).map(UnitRun::Simulated).map_err(UnitError::Failed);
    };
    if matches!(plan.workload, Workload::Numeric(_)) {
        if budget.exceeded() {
            return Err(UnitError::DeadlineExceeded(format!(
                "deadline passed before numeric unit {} started (numeric probes \
                 have no analytic model to degrade to)",
                unit.label()
            )));
        }
        return runner.run_unit(plan, unit).map(UnitRun::Simulated).map_err(UnitError::Failed);
    }
    let backend = runner.timing_backend();
    let w = &plan.workload;
    let dev = &plan.device;
    match unit {
        UnitKind::Completion => {
            match w.measure_cached_budgeted(dev, ExecPoint::new(1, 1), backend, budget) {
                Ok(m) => Ok(UnitRun::Simulated(UnitOutput::Completion(m.latency))),
                Err(BudgetBlown) => {
                    let pred = predict_or_deadline(plan, ExecPoint::new(1, 1))?;
                    degraded(plan, UnitOutput::Completion(pred.latency))
                }
            }
        }
        UnitKind::Point(p) => match w.measure_cached_budgeted(dev, *p, backend, budget) {
            Ok(m) => Ok(UnitRun::Simulated(UnitOutput::Point(m))),
            Err(BudgetBlown) => {
                let pred = predict_or_deadline(plan, *p)?;
                degraded(
                    plan,
                    UnitOutput::Point(Measurement {
                        warps: p.warps,
                        ilp: p.ilp,
                        latency: pred.latency,
                        throughput: pred.throughput,
                    }),
                )
            }
        },
        UnitKind::Sweep => {
            match w.sweep_via_budgeted(dev, backend, default_threads(), budget) {
                Ok(sweep) => {
                    let convergence = plan
                        .convergence_warps
                        .iter()
                        .map(|&cw| convergence_point(&sweep, cw))
                        .collect();
                    Ok(UnitRun::Simulated(UnitOutput::Sweep { sweep, convergence }))
                }
                Err(BudgetBlown) => {
                    let sweep = w.predict_sweep(dev).map_err(|e| {
                        UnitError::DeadlineExceeded(format!(
                            "deadline exceeded and the analytic fallback failed: {e}"
                        ))
                    })?;
                    let convergence = plan
                        .convergence_warps
                        .iter()
                        .map(|&cw| convergence_point(&sweep, cw))
                        .collect();
                    degraded(plan, UnitOutput::Sweep { sweep, convergence })
                }
            }
        }
    }
}

/// Analytic prediction for one point, or a typed deadline error when the
/// family has no model (should not happen for any current timing family).
fn predict_or_deadline(
    plan: &BenchPlan,
    p: ExecPoint,
) -> Result<crate::sim::AnalyticPrediction, UnitError> {
    plan.workload.predict(&plan.device, p).map_err(|e| {
        UnitError::DeadlineExceeded(format!(
            "deadline exceeded and the analytic fallback failed: {e}"
        ))
    })
}

/// Wrap a predicted output in the degraded envelope for `plan`'s family.
fn degraded(plan: &BenchPlan, output: UnitOutput) -> Result<UnitRun, UnitError> {
    let family = plan.workload.kind();
    Ok(UnitRun::Degraded {
        output,
        reason: format!(
            "deadline_ms budget exhausted before the cycle simulation finished; \
             served the calibrated analytic prediction for {family}"
        ),
        within_calibration: calibration_bound(family).is_some(),
    })
}

/// The cycle-level SM-simulator backend (always available); its numeric
/// leg is the native softfloat datapath.
pub struct SimRunner;

impl Runner for SimRunner {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn run_unit(&self, plan: &BenchPlan, unit: &UnitKind) -> Result<UnitOutput, String> {
        dispatch_unit(self, plan, unit)
    }

    fn run_unit_profiled(
        &self,
        plan: &BenchPlan,
        unit: &UnitKind,
        mode: ProfileMode,
    ) -> Result<(UnitOutput, Option<SimProfile>), String> {
        dispatch_unit_profiled(self, plan, unit, mode)
    }

    fn run_numeric(&self, probe: &NumericProbe) -> Result<NumericOutput, String> {
        Ok(probe.run_native())
    }
}

/// The PJRT artifact-runtime backend. Construction opens the artifact
/// store (it is not openable in offline builds — the stub runtime
/// returns an error, sending callers down the simulator path).
///
/// Timing workloads are simulator-measured on every backend — the AOT
/// artifacts cover the §8 numeric datapath, not cycle timing — so those
/// units delegate to the shared simulator dispatch while keying results
/// under this runner's backend name. Numeric probes execute on the
/// artifacts; the store is a single stateful compilation cache, so the
/// numeric leg serializes on a mutex (matching the old campaign's
/// serial numeric phase).
pub struct ArtifactRunner {
    store: Mutex<ArtifactStore>,
}

impl ArtifactRunner {
    pub fn new() -> Result<ArtifactRunner, String> {
        let store = ArtifactStore::open_default().map_err(|e| format!("{e:#}"))?;
        Ok(ArtifactRunner { store: Mutex::new(store) })
    }
}

impl Runner for ArtifactRunner {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn run_unit(&self, plan: &BenchPlan, unit: &UnitKind) -> Result<UnitOutput, String> {
        dispatch_unit(self, plan, unit)
    }

    fn run_numeric(&self, probe: &NumericProbe) -> Result<NumericOutput, String> {
        // a panic in an earlier probe (caught upstream) poisons the
        // lock, but the store is only a compilation cache — at worst an
        // entry is missing — so recover instead of failing every later
        // numeric request until restart
        let mut store = self.store.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        let mut exec = ArtifactExec::new(&mut store, probe.cfg()).map_err(|e| {
            if probe.ab.is_fp8() {
                format!("{e:#} (fp8 probes have no AOT artifacts yet)")
            } else {
                format!("{e:#}")
            }
        })?;
        Ok(probe.run_on(&mut exec))
    }
}

/// Resolve a requested backend kind to a runner, once. `Auto` picks
/// PJRT when artifacts are available and the simulator backend
/// otherwise — including when the artifact store turns out not to be
/// *openable* (manifest present but the PJRT runtime unavailable or the
/// manifest corrupt), so `Auto` never fails, exactly like the retired
/// `Backend::auto()`. An explicit `Pjrt` request still surfaces the
/// open error.
pub fn runner_for(kind: BackendKind) -> Result<Box<dyn Runner>, String> {
    match kind.resolve() {
        BackendKind::Native => Ok(Box::new(SimRunner)),
        BackendKind::Pjrt => match ArtifactRunner::new() {
            Ok(r) => Ok(Box::new(r)),
            Err(_) if kind == BackendKind::Auto => Ok(Box::new(SimRunner)),
            Err(e) => Err(e),
        },
        BackendKind::Auto => unreachable!("resolve() returns a concrete kind"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_resolves_to_the_sim_runner() {
        assert_eq!(runner_for(BackendKind::Native).unwrap().name(), "sim");
    }

    #[test]
    fn sim_runner_executes_gemm_units() {
        use crate::gemm::Variant;
        use crate::workload::{GemmParams, Plan, Workload};
        let w = Workload::Gemm(GemmParams {
            size: 256,
            ..GemmParams::paper(Variant::Baseline, false)
        });
        let plan = Plan::new(w).point(8, 1).compile().unwrap();
        let out = SimRunner.run_unit(&plan, &plan.units[0]).unwrap();
        match out {
            UnitOutput::Point(m) => assert!(m.throughput > 0.0 && m.latency > 0.0, "{m:?}"),
            other => panic!("expected a point output, got {other:?}"),
        }
    }

    #[test]
    fn sim_runner_numeric_leg_is_the_native_datapath() {
        use crate::numerics::{profile_op, InitKind, NativeExec, ProfileOp};
        use crate::workload::{Plan, Workload, PROFILE_SEED, PROFILE_TRIALS};
        let w = Workload::parse_spec("numeric profile tf32 f32 inner fp32").unwrap();
        let plan = Plan::new(w).point(1, 1).compile().unwrap();
        let out = SimRunner.run_unit(&plan, &plan.units[0]).unwrap();
        let UnitOutput::Numeric(NumericOutput::Profile(got)) = out else {
            panic!("expected a numeric profile output")
        };
        let Workload::Numeric(probe) = w else { unreachable!() };
        let want = profile_op(
            &mut NativeExec::new(probe.cfg()),
            ProfileOp::InnerProduct,
            InitKind::Fp32,
            PROFILE_TRIALS,
            PROFILE_SEED,
        );
        assert_eq!(got.mean_abs_err.to_bits(), want.mean_abs_err.to_bits());
    }

    #[test]
    fn expired_budget_degrades_timing_point_to_the_analytic_prediction() {
        use crate::workload::Plan;
        let w = Workload::parse_spec("mma fp16 f32 m16n8k16").unwrap();
        let plan = Plan::new(w).point(4, 2).compile().unwrap();
        let run =
            run_unit_budgeted(&SimRunner, &plan, &plan.units[0], Some(Budget::from_ms(0)))
                .unwrap();
        let UnitRun::Degraded { output, reason, within_calibration } = run else {
            panic!("a 0 ms budget must degrade, got {run:?}")
        };
        assert!(within_calibration, "mma has a pinned calibration bound");
        assert!(reason.contains("analytic"), "{reason}");
        let UnitOutput::Point(m) = output else { panic!("expected a point") };
        let pred = w.predict(&plan.device, ExecPoint::new(4, 2)).unwrap();
        assert_eq!(m.latency.to_bits(), pred.latency.to_bits());
        assert_eq!(m.throughput.to_bits(), pred.throughput.to_bits());
    }

    #[test]
    fn expired_budget_is_a_typed_error_for_numeric_units() {
        use crate::workload::Plan;
        let w = Workload::parse_spec("numeric profile tf32 f32 inner fp32").unwrap();
        let plan = Plan::new(w).point(1, 1).compile().unwrap();
        let err =
            run_unit_budgeted(&SimRunner, &plan, &plan.units[0], Some(Budget::from_ms(0)))
                .unwrap_err();
        assert!(
            matches!(err, UnitError::DeadlineExceeded(_)),
            "numeric units have no analytic fallback: {err:?}"
        );
    }

    #[test]
    fn absent_budget_runs_the_simulation() {
        use crate::workload::Plan;
        let w = Workload::parse_spec("mma fp16 f32 m16n8k16").unwrap();
        let plan = Plan::new(w).point(1, 1).compile().unwrap();
        let run = run_unit_budgeted(&SimRunner, &plan, &plan.units[0], None).unwrap();
        let UnitRun::Simulated(UnitOutput::Point(m)) = run else {
            panic!("expected a simulated point, got {run:?}")
        };
        // bit-identical to the unbudgeted dispatch path (same cell cache)
        let direct = SimRunner.run_unit(&plan, &plan.units[0]).unwrap();
        let UnitOutput::Point(d) = direct else { unreachable!() };
        assert_eq!(m.latency.to_bits(), d.latency.to_bits());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_runner_unavailable_offline() {
        let err = runner_for(BackendKind::Pjrt).unwrap_err();
        assert!(err.contains("pjrt") || err.contains("PJRT"), "{err}");
        // auto therefore falls back to the simulator backend
        assert_eq!(runner_for(BackendKind::Auto).unwrap().name(), "sim");
    }
}
