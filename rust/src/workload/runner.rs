//! [`Runner`] — the backend seam of the workload layer.
//!
//! A runner executes [`BenchPlan`] units. Backend selection happens
//! exactly once, when a runner is constructed ([`runner_for`]), instead
//! of per call site: [`SimRunner`] is the cycle-level simulator backend,
//! [`ArtifactRunner`] is the PJRT artifact runtime (or its offline
//! stub, whose construction fails with an actionable message, sending
//! callers down the simulator path — the same contract as
//! [`crate::coordinator::BackendKind::instantiate`]).

use crate::coordinator::BackendKind;
use crate::microbench::convergence_point;
use crate::runtime::ArtifactStore;

use super::plan::{BenchPlan, UnitKind, UnitOutput};

/// Executes plan units against one backend. Implementations must be
/// [`Sync`]: the plan executor and tcserved both fan units out across
/// worker threads sharing one runner.
pub trait Runner: Sync {
    /// Stable backend name — a cache-key coordinate in tcserved.
    fn name(&self) -> &'static str;

    /// Execute one unit of a compiled plan.
    fn run_unit(&self, plan: &BenchPlan, unit: &UnitKind) -> Result<UnitOutput, String>;
}

/// The cycle-level SM-simulator backend (always available).
pub struct SimRunner;

impl Runner for SimRunner {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn run_unit(&self, plan: &BenchPlan, unit: &UnitKind) -> Result<UnitOutput, String> {
        Ok(match unit {
            UnitKind::Completion => {
                UnitOutput::Completion(plan.workload.completion_latency(&plan.device))
            }
            UnitKind::Point(p) => UnitOutput::Point(plan.workload.measure(&plan.device, *p)),
            UnitKind::Sweep => {
                let sweep = plan.workload.sweep(&plan.device);
                let convergence = plan
                    .convergence_warps
                    .iter()
                    .map(|&w| convergence_point(&sweep, w))
                    .collect();
                UnitOutput::Sweep { sweep, convergence }
            }
        })
    }
}

/// The PJRT artifact-runtime backend. Construction proves the artifact
/// store is openable (it is not in offline builds — the stub runtime
/// returns an error, exactly like `BackendKind::Pjrt.instantiate()`).
///
/// Timing workloads are simulator-measured on every backend — the AOT
/// artifacts cover the §8 numeric datapath, not cycle timing — so this
/// runner delegates unit execution to [`SimRunner`] while keying results
/// under its own backend name.
pub struct ArtifactRunner {
    _proof: (),
}

impl ArtifactRunner {
    pub fn new() -> Result<ArtifactRunner, String> {
        let _store = ArtifactStore::open_default().map_err(|e| format!("{e:#}"))?;
        Ok(ArtifactRunner { _proof: () })
    }
}

impl Runner for ArtifactRunner {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn run_unit(&self, plan: &BenchPlan, unit: &UnitKind) -> Result<UnitOutput, String> {
        SimRunner.run_unit(plan, unit)
    }
}

/// Resolve a requested backend kind to a runner, once. `Auto` picks
/// PJRT when artifacts are available and the simulator backend
/// otherwise, mirroring [`BackendKind::resolve`].
pub fn runner_for(kind: BackendKind) -> Result<Box<dyn Runner>, String> {
    match kind.resolve() {
        BackendKind::Native => Ok(Box::new(SimRunner)),
        BackendKind::Pjrt => Ok(Box::new(ArtifactRunner::new()?)),
        BackendKind::Auto => unreachable!("resolve() returns a concrete kind"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_resolves_to_the_sim_runner() {
        assert_eq!(runner_for(BackendKind::Native).unwrap().name(), "sim");
    }

    #[test]
    fn sim_runner_executes_gemm_units() {
        use crate::gemm::Variant;
        use crate::workload::{GemmParams, Plan, Workload};
        let w = Workload::Gemm(GemmParams {
            size: 256,
            ..GemmParams::paper(Variant::Baseline, false)
        });
        let plan = Plan::new(w).point(8, 1).compile().unwrap();
        let out = SimRunner.run_unit(&plan, &plan.units[0]).unwrap();
        match out {
            UnitOutput::Point(m) => assert!(m.throughput > 0.0 && m.latency > 0.0, "{m:?}"),
            other => panic!("expected a point output, got {other:?}"),
        }
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_runner_unavailable_offline() {
        let err = runner_for(BackendKind::Pjrt).unwrap_err();
        assert!(err.contains("pjrt") || err.contains("PJRT"), "{err}");
        // auto therefore falls back to the simulator backend
        assert_eq!(runner_for(BackendKind::Auto).unwrap().name(), "sim");
    }
}
