//! Offline stand-in for the PJRT artifact runtime (built when the
//! `pjrt` feature is off, i.e. whenever the `xla` crate is unavailable).
//!
//! [`ArtifactStore::open`] always fails, so [`ArtifactStore`] — and with
//! it [`ArtifactExec`] — can never be constructed: the store holds an
//! uninhabited field and every method body is an empty `match` on it.
//! Callers keep type-checking against the same API as the real runtime,
//! and at run time they all take their native-backend fallback paths.

use std::collections::HashMap;
use std::convert::Infallible;
use std::path::Path;

use anyhow::{bail, Result};

use crate::numerics::{MmaExec, NumericCfg};

/// One entry of `artifacts/manifest.json` (API parity with the real
/// runtime; never constructed in this build).
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub name: String,
    pub file: String,
    pub ab: String,
    pub cd: String,
    pub acc_rnd: String,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub batch: usize,
}

/// Uninhabited stand-in for the PJRT artifact store.
pub struct ArtifactStore {
    never: Infallible,
}

impl ArtifactStore {
    /// Always fails in this build: the PJRT runtime needs the `xla`
    /// crate, which is unavailable offline.
    pub fn open(_dir: impl AsRef<Path>) -> Result<Self> {
        bail!(
            "PJRT runtime not compiled in (the `xla` crate is unavailable offline); \
             build with `--features pjrt` after vendoring it, or use the native backend"
        )
    }

    /// Same default lookup as the real runtime; always fails here.
    pub fn open_default() -> Result<Self> {
        let dir = std::env::var("TCBENCH_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::open(dir)
    }

    /// Cheap availability probe (no I/O beyond a stat in the real
    /// runtime; constant `false` here) — used on request hot paths
    /// where opening the store per request would be wasteful.
    pub fn available() -> bool {
        false
    }

    pub fn manifest(&self) -> &HashMap<String, ManifestEntry> {
        match self.never {}
    }

    pub fn entry(&self, _name: &str) -> Result<&ManifestEntry> {
        match self.never {}
    }

    pub fn run_tcmma(&mut self, _name: &str, _a: &[f32], _b: &[f32], _c: &[f32]) -> Result<Vec<f32>> {
        match self.never {}
    }
}

/// Uninhabited stand-in for the PJRT-backed [`MmaExec`] executor.
pub struct ArtifactExec<'s> {
    store: &'s mut ArtifactStore,
}

impl<'s> ArtifactExec<'s> {
    pub fn new(store: &'s mut ArtifactStore, _cfg: NumericCfg) -> Result<Self> {
        match store.never {}
    }
}

impl MmaExec for ArtifactExec<'_> {
    fn cfg(&self) -> NumericCfg {
        match self.store.never {}
    }

    fn run(&mut self, _batch: usize, _a: &[f32], _b: &[f32], _c: &[f32]) -> Vec<f32> {
        match self.store.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_fails_with_actionable_message() {
        let err = ArtifactStore::open("artifacts").unwrap_err();
        assert!(format!("{err:#}").contains("pjrt"), "{err:#}");
        assert!(ArtifactStore::open_default().is_err());
    }
}
