//! PJRT runtime: load the AOT HLO-text artifacts emitted by
//! `python/compile/aot.py` and execute them on the request path.
//!
//! Python never runs here — the artifacts are compiled once by
//! `make artifacts`; this module parses `manifest.json`, loads each
//! `*.hlo.txt` through `HloModuleProto::from_text_file`, compiles it on
//! the PJRT CPU client and caches the executable
//! (see /opt/xla-example/load_hlo for the reference wiring).
//!
//! The PJRT client needs the `xla` crate, which is not available in the
//! offline build environment, so the real implementation is gated behind
//! the `pjrt` cargo feature. Without it [`ArtifactStore::open`] returns
//! an error and every caller falls back to the native softfloat backend
//! — `runner_for(BackendKind::Auto)` resolves to the simulator runner,
//! and the PJRT integration tests skip themselves with a note, exactly
//! as when artifacts are missing.

#[cfg(feature = "pjrt")]
mod artifact;
#[cfg(feature = "pjrt")]
pub use artifact::{ArtifactExec, ArtifactStore, ManifestEntry};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{ArtifactExec, ArtifactStore, ManifestEntry};
