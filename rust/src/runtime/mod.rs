//! PJRT runtime: load the AOT HLO-text artifacts emitted by
//! `python/compile/aot.py` and execute them on the request path.
//!
//! Python never runs here — the artifacts are compiled once by
//! `make artifacts`; this module parses `manifest.json`, loads each
//! `*.hlo.txt` through `HloModuleProto::from_text_file`, compiles it on
//! the PJRT CPU client and caches the executable
//! (see /opt/xla-example/load_hlo for the reference wiring).

mod artifact;

pub use artifact::{ArtifactExec, ArtifactStore, ManifestEntry};
