//! Artifact loading, compilation caching and typed execution.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::numerics::{MmaExec, NumericCfg};
use crate::util::Json;

/// One entry of `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub name: String,
    pub file: String,
    pub ab: String,
    pub cd: String,
    pub acc_rnd: String,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub batch: usize,
}

impl ManifestEntry {
    fn from_json(name: &str, j: &Json) -> Result<Self> {
        let s = |k: &str| -> Result<String> {
            Ok(j.get(k)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("manifest[{name}].{k} missing"))?
                .to_string())
        };
        let u = |k: &str| -> Result<usize> {
            Ok(j.get(k)
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("manifest[{name}].{k} missing"))? as usize)
        };
        Ok(Self {
            name: name.to_string(),
            file: s("file")?,
            ab: s("ab")?,
            cd: s("cd")?,
            acc_rnd: s("acc_rnd")?,
            m: u("m")?,
            n: u("n")?,
            k: u("k")?,
            batch: u("batch")?,
        })
    }
}

/// Loads + compiles artifacts on demand and caches the executables.
pub struct ArtifactStore {
    dir: PathBuf,
    client: xla::PjRtClient,
    manifest: HashMap<String, ManifestEntry>,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl ArtifactStore {
    /// Open the artifact directory (usually `artifacts/`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {} — run `make artifacts` first", manifest_path.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;
        let obj = json.as_obj().ok_or_else(|| anyhow!("manifest is not an object"))?;
        let mut manifest = HashMap::new();
        for (name, entry) in obj {
            manifest.insert(name.clone(), ManifestEntry::from_json(name, entry)?);
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Self { dir, client, manifest, executables: HashMap::new() })
    }

    /// Default artifact directory: `$TCBENCH_ARTIFACTS` or `artifacts/`
    /// relative to the working directory.
    pub fn open_default() -> Result<Self> {
        let dir =
            std::env::var("TCBENCH_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::open(dir)
    }

    /// Cheap availability probe: does the default manifest exist? Used
    /// on request hot paths where opening the store (and creating a
    /// PJRT client) per request would be wasteful.
    pub fn available() -> bool {
        let dir =
            std::env::var("TCBENCH_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Path::new(&dir).join("manifest.json").is_file()
    }

    pub fn manifest(&self) -> &HashMap<String, ManifestEntry> {
        &self.manifest
    }

    pub fn entry(&self, name: &str) -> Result<&ManifestEntry> {
        self.manifest
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }

    /// Compile (or fetch the cached) executable for an artifact.
    pub fn load(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(name) {
            let entry = self.entry(name)?.clone();
            let path = self.dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.executables.insert(name.to_string(), exe);
        }
        Ok(&self.executables[name])
    }

    /// Execute one batched MMA artifact: `a[batch,m,k] b[batch,k,n]
    /// c[batch,m,n] -> d[batch,m,n]` (f32, row-major flattened).
    pub fn run_tcmma(
        &mut self,
        name: &str,
        a: &[f32],
        b: &[f32],
        c: &[f32],
    ) -> Result<Vec<f32>> {
        let entry = self.entry(name)?.clone();
        let (bt, m, n, k) = (entry.batch, entry.m, entry.n, entry.k);
        if a.len() != bt * m * k || b.len() != bt * k * n || c.len() != bt * m * n {
            bail!(
                "operand sizes {}x{}x{} do not match artifact {name} (batch {bt}, m{m} n{n} k{k})",
                a.len(),
                b.len(),
                c.len()
            );
        }
        let lit_a = xla::Literal::vec1(a).reshape(&[bt as i64, m as i64, k as i64])
            .map_err(|e| anyhow!("reshape a: {e:?}"))?;
        let lit_b = xla::Literal::vec1(b).reshape(&[bt as i64, k as i64, n as i64])
            .map_err(|e| anyhow!("reshape b: {e:?}"))?;
        let lit_c = xla::Literal::vec1(c).reshape(&[bt as i64, m as i64, n as i64])
            .map_err(|e| anyhow!("reshape c: {e:?}"))?;
        let exe = self.load(name)?;
        let result = exe
            .execute::<xla::Literal>(&[lit_a, lit_b, lit_c])
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e:?}"))?;
        // lowered with return_tuple=True -> 1-tuple
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }
}

/// [`MmaExec`] backend running on the PJRT executables — the §8
/// experiments run identically on this and on the native softfloat path.
///
/// The artifact batch size is fixed at AOT time; `run` splits larger
/// batches into artifact-sized executions and zero-pads the tail.
pub struct ArtifactExec<'s> {
    store: &'s mut ArtifactStore,
    name: String,
    cfg: NumericCfg,
    batch: usize,
}

impl<'s> ArtifactExec<'s> {
    pub fn new(store: &'s mut ArtifactStore, cfg: NumericCfg) -> Result<Self> {
        let name = cfg.artifact_name();
        let entry = store.entry(&name)?;
        if entry.m != cfg.m || entry.n != cfg.n || entry.k != cfg.k {
            bail!("artifact {name} shape mismatch");
        }
        let batch = entry.batch;
        // Pre-compile eagerly so the request path never pays it.
        store.load(&name)?;
        Ok(Self { store, name, cfg, batch })
    }
}

impl MmaExec for ArtifactExec<'_> {
    fn cfg(&self) -> NumericCfg {
        self.cfg
    }

    fn run(&mut self, batch: usize, a: &[f32], b: &[f32], c: &[f32]) -> Vec<f32> {
        let (m, n, k) = (self.cfg.m, self.cfg.n, self.cfg.k);
        let bs = self.batch;
        let mut out = Vec::with_capacity(batch * m * n);
        let mut t = 0;
        let (mut pa, mut pb, mut pc) =
            (vec![0.0f32; bs * m * k], vec![0.0f32; bs * k * n], vec![0.0f32; bs * m * n]);
        while t < batch {
            let chunk = (batch - t).min(bs);
            pa[..chunk * m * k].copy_from_slice(&a[t * m * k..(t + chunk) * m * k]);
            pb[..chunk * k * n].copy_from_slice(&b[t * k * n..(t + chunk) * k * n]);
            pc[..chunk * m * n].copy_from_slice(&c[t * m * n..(t + chunk) * m * n]);
            if chunk < bs {
                pa[chunk * m * k..].fill(0.0);
                pb[chunk * k * n..].fill(0.0);
                pc[chunk * m * n..].fill(0.0);
            }
            let d = self
                .store
                .run_tcmma(&self.name, &pa, &pb, &pc)
                .expect("artifact execution failed");
            out.extend_from_slice(&d[..chunk * m * n]);
            t += chunk;
        }
        out
    }
}
