//! NVIDIA RTX 3070 Ti (GA104, Ampere gaming die) calibration —
//! paper Tables 4 and 7.
//!
//! Two structural differences from the A100 (paper §5):
//! * lower Tensor-Core peaks across all data types;
//! * FP16 `mma` with an FP32 accumulator runs at **half** the FP16-
//!   accumulator rate (the GA10x gaming rule) — encoded as doubled ii.
//!
//! Notably the sparse small-k anomaly of the A100 does **not** occur
//! here (Table 7): every `mma.sp` shape reaches its ideal ii.

use crate::isa::shapes::*;
use crate::isa::{AbType, CdType, MmaInstr};

use super::config::{Arch, Device, FpuFallback, MmaTiming, PeakTable};

fn t(latency: u32, ii: u32) -> MmaTiming {
    MmaTiming { latency, ii, fpu_fallback: FpuFallback::No }
}

/// Build the calibrated RTX 3070 Ti device.
pub fn rtx3070ti() -> Device {
    use AbType::*;
    use CdType::{Fp16 as C16, Fp32 as C32, Int32 as I32};

    let dense: Vec<(MmaInstr, MmaTiming)> = vec![
        // Table 4 rows. Peaks: FP16/FP16 512, FP16/FP32 256 (half rate),
        // TF32 128, INT8 1024, INT4 2048, Binary 8192 FMA/clk/SM.
        (MmaInstr::dense(Fp16, C32, M16N8K16), t(32, 32)),
        (MmaInstr::dense(Fp16, C32, M16N8K8), t(18, 16)),
        (MmaInstr::dense(Fp16, C16, M16N8K16), t(23, 16)),
        (MmaInstr::dense(Fp16, C16, M16N8K8), t(17, 8)),
        (MmaInstr::dense(Tf32, C32, M16N8K8), t(32, 32)),
        (MmaInstr::dense(Tf32, C32, M16N8K4), t(18, 16)),
        (MmaInstr::dense(Int8, I32, M8N8K16), t(15, 4)), // full rate here
        (MmaInstr::dense(Int8, I32, M16N8K32), t(23, 16)),
        (MmaInstr::dense(Int8, I32, M16N8K16), t(17, 8)),
        (MmaInstr::dense(Int4, I32, M16N8K32), t(16, 8)),
        (MmaInstr::dense(Int4, I32, M16N8K64), t(24, 16)),
        (MmaInstr::dense(Binary, I32, M16N8K128), t(16, 8)),
        (MmaInstr::dense(Binary, I32, M16N8K256), t(24, 16)),
        // BF16 == FP16 timing (with FP32 accumulator, so half rate).
        (MmaInstr::dense(Bf16, C32, M16N8K16), t(32, 32)),
        (MmaInstr::dense(Bf16, C32, M16N8K8), t(18, 16)),
        (
            MmaInstr::dense(Fp16, C32, M8N8K4),
            MmaTiming { latency: 30, ii: 20, fpu_fallback: FpuFallback::Yes },
        ),
    ];

    let sparse: Vec<(MmaInstr, MmaTiming)> = vec![
        // Table 7 rows — no small-k anomaly: ideal ii throughout.
        (MmaInstr::sp(Fp16, C32, M16N8K32), t(32, 32)),
        (MmaInstr::sp(Fp16, C32, M16N8K16), t(18, 16)),
        (MmaInstr::sp(Fp16, C16, M16N8K32), t(23, 16)),
        (MmaInstr::sp(Fp16, C16, M16N8K16), t(17, 8)),
        (MmaInstr::sp(Tf32, C32, M16N8K16), t(32, 32)),
        (MmaInstr::sp(Tf32, C32, M16N8K8), t(18, 16)),
        (MmaInstr::sp(Int8, I32, M16N8K64), t(23, 16)),
        (MmaInstr::sp(Int8, I32, M16N8K32), t(17, 8)),
        (MmaInstr::sp(Bf16, C32, M16N8K32), t(32, 32)),
        (MmaInstr::sp(Bf16, C32, M16N8K16), t(18, 16)),
    ];

    let paper_dense_rows = dense[..13].iter().map(|(i, _)| *i).collect();
    let paper_sparse_rows = sparse[..8].iter().map(|(i, _)| *i).collect();

    let mut mma_timings = dense;
    mma_timings.extend(sparse);

    Device {
        name: "rtx3070ti",
        product: "NVIDIA RTX 3070 Ti (GA104)",
        arch: Arch::Ampere,
        sms: 48,
        subcores: 4,
        lsu_units: 2,
        lsu_txn_cycles: 2,
        lsu_tail: 21,
        lsu_pending_per_warp: 4,
        smem_banks: 32,
        smem_bank_bytes: 4,
        smem_bytes_per_sm: 100 * 1024, // GA104: up to 100 KB/SM
        sync_cost: 1,
        gmem_latency: 420,
        gmem_bytes_per_cycle: 10,
        peaks: PeakTable {
            fp16_fp32: 256,
            fp16_fp16: 512,
            bf16: 256,
            tf32: 128,
            int8: 1024,
            int4: 2048,
            binary: 8192,
            fp8: 0, // no FP8 before Hopper (Table 11)
        },
        mma_timings,
        paper_dense_rows,
        paper_sparse_rows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp32_accumulator_runs_at_half_rate() {
        // Table 4 key finding: C/D=FP32 halves throughput vs C/D=FP16.
        let d = rtx3070ti();
        let f32acc = d.timing(&MmaInstr::dense(AbType::Fp16, CdType::Fp32, M16N8K16)).unwrap();
        let f16acc = d.timing(&MmaInstr::dense(AbType::Fp16, CdType::Fp16, M16N8K16)).unwrap();
        assert_eq!(f32acc.ii, 2 * f16acc.ii);
    }

    #[test]
    fn no_sparse_small_k_anomaly() {
        // Table 7: unlike the A100, small-k sparse shapes hit ideal ii.
        let d = rtx3070ti();
        for (instr, timing) in &d.mma_timings {
            if instr.sparse {
                assert_eq!(timing.ii, d.ideal_ii(instr), "{instr}");
            }
        }
    }

    #[test]
    fn int8_m8n8k16_full_rate_unlike_a100() {
        let d = rtx3070ti();
        let i = MmaInstr::dense(AbType::Int8, CdType::Int32, M8N8K16);
        assert_eq!(d.timing(&i).unwrap().ii, d.ideal_ii(&i));
    }

    #[test]
    fn peaks_below_a100() {
        let d = rtx3070ti();
        let a = crate::device::a100();
        assert!(d.peaks.fp16_fp32 < a.peaks.fp16_fp32);
        assert!(d.peaks.int8 < a.peaks.int8);
    }
}
