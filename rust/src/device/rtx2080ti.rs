//! NVIDIA RTX 2080 Ti (TU102, Turing) calibration — paper Table 5.
//!
//! The Turing predecessor: fewer shapes and data types, no `mma.sp`,
//! no `cp.async`. The paper's observation that "Dense FMA latency of
//! Ampere Tensor Cores does not improve compared to Turing" shows up as
//! near-identical completion latencies for the shared shapes.

use crate::isa::shapes::*;
use crate::isa::{AbType, CdType, MmaInstr};

use super::config::{Arch, Device, FpuFallback, MmaTiming, PeakTable};

fn t(latency: u32, ii: u32) -> MmaTiming {
    MmaTiming { latency, ii, fpu_fallback: FpuFallback::No }
}

/// Build the calibrated RTX 2080 Ti device.
pub fn rtx2080ti() -> Device {
    use AbType::*;
    use CdType::{Fp16 as C16, Fp32 as C32, Int32 as I32};

    let dense: Vec<(MmaInstr, MmaTiming)> = vec![
        // Table 5 rows. Peaks: FP16/FP32 256, FP16/FP16 512, INT8 1024.
        (MmaInstr::dense(Fp16, C32, M16N8K8), t(17, 16)),
        (MmaInstr::dense(Fp16, C16, M16N8K8), t(14, 8)),
        (MmaInstr::dense(Int8, I32, M8N8K16), t(10, 4)),
        // m8n8k4 compiles to HMMA.884 pairs on Turing (§2.2) — still on
        // the Tensor Cores, at the FP16/FP32 rate.
        (MmaInstr::dense(Fp16, C32, M8N8K4), t(14, 4)),
    ];

    let paper_dense_rows = dense[..3].iter().map(|(i, _)| *i).collect();

    Device {
        name: "rtx2080ti",
        product: "NVIDIA RTX 2080 Ti (TU102)",
        arch: Arch::Turing,
        sms: 68,
        subcores: 4,
        lsu_units: 2,
        lsu_txn_cycles: 2,
        lsu_tail: 21,
        lsu_pending_per_warp: 4,
        smem_banks: 32,
        smem_bank_bytes: 4,
        smem_bytes_per_sm: 64 * 1024, // TU102: up to 64 KB/SM
        sync_cost: 1,
        gmem_latency: 440,
        gmem_bytes_per_cycle: 10,
        peaks: PeakTable {
            fp16_fp32: 256,
            fp16_fp16: 512,
            bf16: 0, // no BF16 on Turing (Table 1)
            tf32: 0, // no TF32 on Turing
            int8: 1024,
            int4: 2048,
            binary: 8192,
            fp8: 0, // no FP8 before Hopper (Table 11)
        },
        mma_timings: dense,
        paper_dense_rows,
        paper_sparse_rows: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn turing_has_no_sparse_or_ampere_dtypes() {
        let d = rtx2080ti();
        assert!(d.paper_sparse_rows.is_empty());
        assert!(!d.supports(&MmaInstr::dense(AbType::Bf16, CdType::Fp32, M16N8K8)));
        assert!(!d.supports(&MmaInstr::dense(AbType::Tf32, CdType::Fp32, M16N8K8)));
        assert!(!d.supports(&MmaInstr::sp(AbType::Fp16, CdType::Fp32, M16N8K32)));
    }

    #[test]
    fn latency_close_to_ampere_counterpart() {
        // paper: 17.3 cycles (Turing) vs 17.7 (A100) for mma.m16n8k8
        let turing = rtx2080ti();
        let ampere = crate::device::a100();
        let i = MmaInstr::dense(AbType::Fp16, CdType::Fp32, M16N8K8);
        assert_eq!(
            turing.timing(&i).unwrap().latency,
            ampere.timing(&i).unwrap().latency
        );
    }

    #[test]
    fn m8n8k4_stays_on_tensor_cores_on_turing() {
        let d = rtx2080ti();
        let i = MmaInstr::dense(AbType::Fp16, CdType::Fp32, M8N8K4);
        assert_eq!(d.timing(&i).unwrap().fpu_fallback, FpuFallback::No);
    }
}
