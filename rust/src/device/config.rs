//! Device configuration schema: SM structure + calibrated pipeline table.


use crate::isa::{AbType, CdType, MmaInstr};

/// Tensor-Core architecture generation (paper Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Arch {
    Volta,
    Turing,
    Ampere,
}

impl Arch {
    /// Tensor Cores per SM (Table 1: 8 on Volta/Turing doing 4x4x4 each,
    /// 4 on Ampere doing 8x4x8 each).
    pub fn tensor_cores_per_sm(self) -> u32 {
        match self {
            Arch::Volta | Arch::Turing => 8,
            Arch::Ampere => 4,
        }
    }

    /// Per-Tensor-Core MM shape (m, n, k) from Table 1.
    pub fn tc_unit_shape(self) -> (u32, u32, u32) {
        match self {
            Arch::Volta | Arch::Turing => (4, 4, 4),
            Arch::Ampere => (8, 4, 8),
        }
    }

    pub fn supports_sparse(self) -> bool {
        matches!(self, Arch::Ampere)
    }

    pub fn supports_ldmatrix(self) -> bool {
        matches!(self, Arch::Turing | Arch::Ampere)
    }

    /// Is `cp.async` (asynchronous global->shared copy) available?
    pub fn supports_cp_async(self) -> bool {
        matches!(self, Arch::Ampere)
    }
}

/// Whether an `mma` variant executes on CUDA-core FPUs instead of the
/// Tensor Cores (`mma.m8n8k4` on Ampere, §2.2), with ~10x lower rate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FpuFallback {
    No,
    Yes,
}

/// Calibrated pipeline timing of one `mma`/`mma.sp` variant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MmaTiming {
    /// Pipeline depth in cycles; the microbenchmark's measured completion
    /// latency is `latency + sync_cost` (paper's Tables report ≈ this).
    pub latency: u32,
    /// Initiation interval per sub-core pipeline: sustained acceptance of
    /// one instruction every `ii` cycles.
    pub ii: u32,
    pub fpu_fallback: FpuFallback,
}

/// Vendor peak dense throughput per data type, FMA/clk/SM
/// (captions of Tables 3/4; [30]/[31] whitepapers).
#[derive(Debug, Clone, PartialEq)]
pub struct PeakTable {
    pub fp16_fp32: u64,
    pub fp16_fp16: u64,
    pub bf16: u64,
    pub tf32: u64,
    pub int8: u64,
    pub int4: u64,
    pub binary: u64,
    /// FP8 (E4M3/E5M2) — Table 11's Hopper addition; `0` everywhere the
    /// paper measured. Gates the fp8 numeric probes.
    pub fp8: u64,
}

impl PeakTable {
    pub fn dense_peak(&self, ab: AbType, cd: CdType) -> u64 {
        match (ab, cd) {
            (AbType::Fp16, CdType::Fp16) => self.fp16_fp16,
            (AbType::Fp16, _) => self.fp16_fp32,
            (AbType::Bf16, _) => self.bf16,
            (AbType::Tf32, _) => self.tf32,
            (AbType::Int8, _) => self.int8,
            (AbType::Int4, _) => self.int4,
            (AbType::Binary, _) => self.binary,
            (AbType::Fp64, _) => 0,
        }
    }

    /// Sparse `mma.sp` doubles the dense peak (§6, Fig. 9).
    pub fn sparse_peak(&self, ab: AbType, cd: CdType) -> u64 {
        2 * self.dense_peak(ab, cd)
    }
}

/// A calibrated GPU device. `PartialEq` is load-bearing: the cell
/// cache keys cells by device *name*, so the workload layer compares a
/// device against its registry entry at run time and routes ad-hoc or
/// modified devices to the uncached measurement path.
#[derive(Debug, Clone, PartialEq)]
pub struct Device {
    pub name: &'static str,
    pub product: &'static str,
    pub arch: Arch,
    /// Streaming multiprocessors on the die (throughput scaling only —
    /// the microbenchmarks run on a single SM like the paper's).
    pub sms: u32,
    /// Warp schedulers / sub-cores per SM (four on every generation).
    pub subcores: u32,
    /// Data-movement units between shared memory and the register file
    /// (§7 finding 2: "there could be two data movement units").
    pub lsu_units: u32,
    /// Cycles one 128-byte shared-memory transaction occupies an LSU
    /// (2 ⇒ 64 B/clk per unit, 128 B/clk/SM with two units).
    pub lsu_txn_cycles: u32,
    /// Pipe latency after the last transaction of a load completes
    /// (calibrated: `23 = txn(2) + tail(21)` for a conflict-free u32).
    pub lsu_tail: u32,
    /// Maximum outstanding loads per warp before issue stalls
    /// (calibrated from Table 9's ldmatrix.x1 4-warp point).
    pub lsu_pending_per_warp: u32,
    /// Shared-memory banks x bank width (32 x 4 B on Volta..Ampere, §7).
    pub smem_banks: u32,
    pub smem_bank_bytes: u32,
    /// Maximum shared memory per SM in bytes (vendor whitepapers; the
    /// largest carve-out configuration). The tclint resource rule bounds
    /// staged cp.async footprints against this.
    pub smem_bytes_per_sm: u32,
    /// Issue-side cost of `__syncwarp()` per loop iteration.
    pub sync_cost: u32,
    /// Global-memory round-trip latency in cycles (Appendix A model).
    pub gmem_latency: u32,
    /// Sustained global-memory bandwidth per SM, bytes/clk (Appendix A).
    pub gmem_bytes_per_cycle: u32,
    pub peaks: PeakTable,
    /// Calibrated (instruction -> timing) table; also the legality
    /// matrix: an instruction absent here is not supported on the device.
    pub mma_timings: Vec<(MmaInstr, MmaTiming)>,
    /// Exact dense rows of the paper's Table 3/4/5 for this device, in
    /// paper order (BF16 rows exist in `mma_timings` for the Fig. 6/7
    /// sweeps but are not separate table rows — the paper found BF16 and
    /// FP16 performance identical).
    pub paper_dense_rows: Vec<MmaInstr>,
    /// Exact sparse rows of the paper's Table 6/7, in paper order.
    pub paper_sparse_rows: Vec<MmaInstr>,
}

impl Device {
    pub fn timing(&self, instr: &MmaInstr) -> Option<MmaTiming> {
        self.mma_timings.iter().find(|(i, _)| i == instr).map(|(_, t)| *t)
    }

    pub fn supports(&self, instr: &MmaInstr) -> bool {
        self.timing(instr).is_some()
    }

    /// Theoretical peak FMA/clk/SM for an instruction on this device.
    pub fn peak(&self, instr: &MmaInstr) -> u64 {
        if instr.sparse {
            self.peaks.sparse_peak(instr.ab, instr.cd)
        } else {
            self.peaks.dense_peak(instr.ab, instr.cd)
        }
    }

    /// Shared-memory fabric bandwidth bound, bytes/clk/SM (§7: 32 banks
    /// x 4 B = 128 B/clk — "also the bandwidth bound of ldmatrix").
    pub fn smem_peak_bytes_per_clk(&self) -> u32 {
        self.smem_banks * self.smem_bank_bytes
    }

    /// Does this device have FP8 Tensor Cores (Table 11: Hopper only)?
    /// The fp8 numeric probes validate against this.
    pub fn supports_fp8(&self) -> bool {
        self.peaks.fp8 > 0
    }

    /// The ideal initiation interval for an instruction from the vendor
    /// peak: `fmas / (peak / subcores)`, i.e. the cycles one sub-core
    /// pipeline must spend per instruction to sustain the peak.
    pub fn ideal_ii(&self, instr: &MmaInstr) -> u32 {
        let peak = self.peak(instr);
        if peak == 0 {
            return u32::MAX;
        }
        let per_subcore = peak as f64 / self.subcores as f64;
        (instr.fmas() as f64 / per_subcore).round().max(1.0) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::shapes::*;

    #[test]
    fn arch_table1_facts() {
        assert_eq!(Arch::Ampere.tensor_cores_per_sm(), 4);
        assert_eq!(Arch::Turing.tensor_cores_per_sm(), 8);
        assert_eq!(Arch::Ampere.tc_unit_shape(), (8, 4, 8));
        assert!(Arch::Ampere.supports_sparse());
        assert!(!Arch::Turing.supports_sparse());
        assert!(Arch::Turing.supports_ldmatrix());
        assert!(!Arch::Volta.supports_ldmatrix());
        assert!(!Arch::Turing.supports_cp_async());
    }

    #[test]
    fn ideal_ii_from_peak() {
        let d = crate::device::a100();
        // FP16 m16n8k16: 2048 FMA / (1024/4 per subcore) = 8
        let i = MmaInstr::dense(AbType::Fp16, CdType::Fp32, M16N8K16);
        assert_eq!(d.ideal_ii(&i), 8);
        // sparse m16n8k32: 4096 FMA / (2048/4) = 8
        let s = MmaInstr::sp(AbType::Fp16, CdType::Fp32, M16N8K32);
        assert_eq!(d.ideal_ii(&s), 8);
    }

    #[test]
    fn smem_peak_is_128() {
        assert_eq!(crate::device::a100().smem_peak_bytes_per_clk(), 128);
    }
}
